"""SMOL numerics invariants (the shared ground truth the rust side
mirrors): code/value mapping, quantizer properties, s <-> precision."""

import math

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import smol


def test_paper_mapping_examples():
    # 4-bit 1101 -> 1.375, 2-bit 10 -> 0.5, 1-bit {0,1} -> {-1,+1}
    assert float(smol.code_to_value(0b1101, 4)) == 1.375
    assert float(smol.code_to_value(0b10, 2)) == 0.5
    assert float(smol.code_to_value(0, 1)) == -1.0
    assert float(smol.code_to_value(1, 1)) == 1.0


def test_code_roundtrip_all_precisions():
    for p in (1, 2, 4, 8):
        codes = np.arange(2**p)
        vals = np.asarray(smol.code_to_value(codes, p))
        back = np.asarray(smol.value_to_code(vals, p))
        assert np.array_equal(back, codes), p
        # odd mantissas, no zero, symmetric
        m = vals / smol.step_for(p)
        assert np.all(np.abs(m % 2) == 1)
        assert 0.0 not in vals
        assert_allclose(np.sort(vals), -np.sort(-vals)[::-1])


@settings(max_examples=50, deadline=None)
@given(st.floats(-10, 10), st.sampled_from([1, 2, 4]))
def test_quantize_idempotent_and_bounded(x, p):
    q = float(smol.quantize_bits(jnp.float32(x), p))
    q2 = float(smol.quantize_bits(jnp.float32(q), p))
    assert q == q2
    assert abs(q) <= smol.qmax_for(p) + 1e-6
    assert abs(q) >= smol.step_for(p) - 1e-6


@settings(max_examples=50, deadline=None)
@given(st.floats(-1.8, 1.8), st.sampled_from([2, 4]))
def test_quantize_error_bound(x, p):
    q = float(smol.quantize_bits(jnp.float32(x), p))
    assert abs(q - x) <= smol.step_for(p) + 1e-6


def test_s_init_consistency():
    # sigma(s_init(p)) = 2^{1-p} and precision_bits inverts it
    for p in (2, 3, 4, 8):
        s = smol.s_init_for(p)
        assert_allclose(float(smol.sigma(jnp.float32(s))), 2.0 ** (1 - p), rtol=1e-5)
        assert float(smol.precision_bits(jnp.float32(s))) == p


def test_snap_precision_boundaries():
    got = np.asarray(smol.snap_precision(jnp.asarray([1.0, 1.4, 1.5, 2.0, 2.9, 3.0, 5.0])))
    assert got.tolist() == [1.0, 1.0, 2.0, 2.0, 2.0, 4.0, 4.0]


def test_soft_bits_matches_log2():
    s = jnp.asarray([-2.0, 0.0, 3.0])
    want = np.log2(1 + np.exp(-np.asarray(s)))
    assert_allclose(np.asarray(smol.soft_bits(s)), want, rtol=1e-6)


def test_products_exact_in_16_6():
    # all pairwise products of supported precisions land on the 2^-6 grid
    for p in (1, 2, 4):
        vals = [float(smol.code_to_value(u, p)) for u in range(2**p)]
        for a in vals:
            for b in vals:
                prod = a * b
                assert prod == math.floor(prod * 64) / 64.0


def test_fixed_point_round_identity_on_grid():
    xs = jnp.asarray([0.0, 1.0 / 64, -3.5, 1.875 * 1.875])
    assert_allclose(np.asarray(smol.fixed_point_round(xs)), np.asarray(xs))
