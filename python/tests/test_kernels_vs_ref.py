"""Pallas kernels vs pure-jnp oracles: the CORE correctness signal.

Hypothesis sweeps shapes and per-channel precision mixes; every comparison
is exact (assert_allclose atol=0) because SMOL arithmetic is dyadic-rational
and therefore exact in f32 — any drift is a real bug.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import smol
from compile.kernels import noise, qmac, quantize, ref

jax.config.update("jax_platform_name", "cpu")


def _prec_vec(rng, k):
    return rng.choice([1, 2, 4], size=k).astype(np.float32)


def _rand(rng, *shape):
    return rng.uniform(-3.0, 3.0, size=shape).astype(np.float32)


shapes = st.tuples(
    st.integers(1, 40), st.integers(1, 70), st.integers(1, 50)
)


@settings(max_examples=10, deadline=None)
@given(shapes, st.integers(0, 2**31 - 1))
def test_qmatmul_matches_ref(shape, seed):
    m, k, n = shape
    rng = np.random.default_rng(seed)
    x = _rand(rng, m, k)
    prec = _prec_vec(rng, k)
    step = (2.0 ** (1.0 - prec)).astype(np.float32)
    qmax = (2.0 - step).astype(np.float32)
    # weights pre-quantized to the channel precisions
    wq = np.asarray(smol.quantize_odd(_rand(rng, k, n), step[:, None], qmax[:, None]))
    got = qmac.qmatmul(jnp.asarray(x), jnp.asarray(wq), jnp.asarray(step), jnp.asarray(qmax))
    want = ref.ref_qmatmul(jnp.asarray(x), jnp.asarray(wq), jnp.asarray(step), jnp.asarray(qmax))
    assert_allclose(np.asarray(got), np.asarray(want), atol=0, rtol=0)


@settings(max_examples=8, deadline=None)
@given(shapes, st.integers(0, 2**31 - 1))
def test_qmatmul_matches_integer_alu_model(shape, seed):
    """Float kernel == bit-exact integer ALU model (the rust simd contract)."""
    m, k, n = shape
    rng = np.random.default_rng(seed)
    x = _rand(rng, m, k)
    prec = _prec_vec(rng, k)
    step = (2.0 ** (1.0 - prec)).astype(np.float32)
    qmax = (2.0 - step).astype(np.float32)
    wq = np.asarray(smol.quantize_odd(_rand(rng, k, n), step[:, None], qmax[:, None]))
    got = qmac.qmatmul(jnp.asarray(x), jnp.asarray(wq), jnp.asarray(step), jnp.asarray(qmax))
    want = ref.ref_qmatmul_int(jnp.asarray(x), jnp.asarray(wq), jnp.asarray(prec))
    assert_allclose(np.asarray(got), np.asarray(want), atol=0, rtol=0)


@settings(max_examples=10, deadline=None)
@given(
    st.tuples(st.integers(1, 60), st.integers(1, 60)),
    st.integers(0, 2**31 - 1),
)
def test_quantize_matches_ref(shape, seed):
    r, c = shape
    rng = np.random.default_rng(seed)
    x = _rand(rng, r, c)
    prec = _prec_vec(rng, c)
    step = jnp.asarray(2.0 ** (1.0 - prec))
    qmax = 2.0 - step
    got = quantize.quantize(jnp.asarray(x), step[None, :], qmax[None, :])
    want = ref.ref_quantize(jnp.asarray(x), step[None, :], qmax[None, :])
    assert_allclose(np.asarray(got), np.asarray(want), atol=0, rtol=0)


@settings(max_examples=10, deadline=None)
@given(
    st.tuples(st.integers(1, 30), st.integers(1, 30), st.integers(1, 8)),
    st.integers(0, 2**31 - 1),
)
def test_inject_noise_matches_ref(shape, seed):
    o, i, khw = shape
    rng = np.random.default_rng(seed)
    w = _rand(rng, o, i, khw)
    scale = rng.uniform(0.01, 1.0, size=(1, i, 1)).astype(np.float32)
    eps = rng.choice([-1.0, 1.0], size=w.shape).astype(np.float32)
    got = noise.inject_noise(jnp.asarray(w), jnp.asarray(scale), jnp.asarray(eps))
    want = ref.ref_inject_noise(jnp.asarray(w), jnp.asarray(scale), jnp.asarray(eps))
    assert_allclose(np.asarray(got), np.asarray(want), atol=0, rtol=0)


def test_noise_gradients():
    """d/dw = g, d/dscale = sum(g * eps) over broadcast dims."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(_rand(rng, 4, 6))
    scale = jnp.asarray(rng.uniform(0.1, 1.0, size=(1, 6)).astype(np.float32))
    eps = jnp.asarray(rng.choice([-1.0, 1.0], size=(4, 6)).astype(np.float32))
    f = lambda w, s: jnp.sum(noise.inject_noise(w, s, eps) ** 2)
    dw, ds = jax.grad(f, argnums=(0, 1))(w, scale)
    out = w + scale * eps
    assert_allclose(np.asarray(dw), np.asarray(2 * out), rtol=1e-6)
    assert_allclose(np.asarray(ds), np.asarray((2 * out * eps).sum(0, keepdims=True)), rtol=1e-6)


def test_qmatmul_ste_gradients():
    """STE backward: dx masked by clip indicator; dw = xq^T @ g."""
    rng = np.random.default_rng(1)
    m, k, n = 5, 7, 3
    x = jnp.asarray(_rand(rng, m, k) * 2.0)  # some values outside clip
    prec = _prec_vec(rng, k)
    step = jnp.asarray(2.0 ** (1.0 - prec))
    qmax = 2.0 - step
    wq = smol.quantize_odd(jnp.asarray(_rand(rng, k, n)), step[:, None], qmax[:, None])
    f = lambda x, w: jnp.sum(qmac.qmatmul_ste(x, w, step, qmax))
    dx, dw = jax.grad(f, argnums=(0, 1))(x, wq)
    g = jnp.ones((m, n))
    inside = (jnp.abs(x) <= qmax[None, :]).astype(jnp.float32)
    assert_allclose(np.asarray(dx), np.asarray((g @ wq.T) * inside), rtol=1e-6)
    xq = smol.quantize_odd(x, step[None, :], qmax[None, :])
    assert_allclose(np.asarray(dw), np.asarray(xq.T @ g), rtol=1e-6)
