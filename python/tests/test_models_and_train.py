"""Model zoo + training step tests: shapes, modes, gradient flow, the
phase-I regularizer, and the quant/eval parity that anchors the rust
simulator cross-validation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import smol, train
from compile.models import build

jax.config.update("jax_platform_name", "cpu")

MODELS = [
    ("tinynet", dict(width=8, image=16), 16),
    ("resnet18", dict(width=4), 32),
    ("mobilenetv2", dict(width_mult=1.0), 32),
    ("shufflenetv2", dict(width_mult=1.0), 32),
]


def _uniform_prec(specs, bits):
    step = smol.step_for(bits) if hasattr(smol, "step_for") else 2.0 ** (1 - bits)
    return {
        sp["name"]: (
            jnp.full((sp["cin"],), 2.0 ** (1.0 - bits), jnp.float32),
            jnp.full((sp["cin"],), 2.0 - 2.0 ** (1.0 - bits), jnp.float32),
        )
        for sp in specs
    }


@pytest.mark.parametrize("name,kw,img", MODELS)
def test_forward_shapes_all_modes(name, kw, img):
    init, apply, specs = build(name, **kw)
    state = init(jax.random.PRNGKey(0))
    x = jnp.zeros((2, img, img, 3))
    prec = _uniform_prec(specs, 4)
    for mode in ["fp32", "noise", "quant"]:
        logits, new_bn = apply(state, prec, x, mode, jax.random.PRNGKey(1), True)
        assert logits.shape == (2, 10), f"{name}/{mode}"
        assert all(k in new_bn for k in state["bn"]), f"{name}/{mode} bn keys"


@pytest.mark.parametrize("name,kw,img", MODELS[:1])
def test_eval_matches_quant_path_exactly(name, kw, img):
    """Pallas eval path == jnp STE path at inference (exact)."""
    init, apply, specs = build(name, **kw)
    state = init(jax.random.PRNGKey(3))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(-1.5, 1.5, (2, img, img, 3)).astype(np.float32))
    prec = _uniform_prec(specs, 4)
    le, _ = apply(state, prec, x, "eval", jax.random.PRNGKey(0), False)
    lq, _ = apply(state, prec, x, "quant", jax.random.PRNGKey(0), False)
    assert_allclose(np.asarray(le), np.asarray(lq), atol=0, rtol=0)


def test_phase1_gradients_flow_to_s():
    init, apply, specs = build("tinynet", width=8, image=16)
    state = init(jax.random.PRNGKey(0))
    steps = train.make_steps(apply, specs)
    rng = np.random.default_rng(1)
    imgs = jnp.asarray(rng.uniform(-1, 1, (8, 16, 16, 3)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 10, (8,)).astype(np.int32))
    ns, loss, _ = steps["phase1_step"](state, imgs, labels, jax.random.PRNGKey(2), 0.1, 1e-3)
    assert float(loss) > 0
    moved = sum(
        float(jnp.max(jnp.abs(ns["s"][k] - state["s"][k]))) for k in state["s"]
    )
    assert moved > 0, "s must receive gradients in phase I"


def test_phase1_regularizer_pushes_s_up():
    """With a huge lambda, the bits regularizer dominates and drives s up
    (toward lower precision)."""
    init, apply, specs = build("tinynet", width=8, image=16)
    state = init(jax.random.PRNGKey(0))
    steps = train.make_steps(apply, specs)
    imgs = jnp.zeros((4, 16, 16, 3))
    labels = jnp.zeros((4,), jnp.int32)
    ns = state
    for i in range(5):
        ns, _, _ = steps["phase1_step"](ns, imgs, labels, jax.random.PRNGKey(i), 0.5, 10.0)
    before = np.mean([float(jnp.mean(v)) for v in state["s"].values()])
    after = np.mean([float(jnp.mean(v)) for v in ns["s"].values()])
    assert after > before, f"{before} -> {after}"


def test_phase1_clips_weights():
    init, apply, specs = build("tinynet", width=8, image=16)
    state = init(jax.random.PRNGKey(0))
    # blow up a weight; one phase1 step must clip it to +-(2 - sigma(s))
    state["params"]["c1"] = state["params"]["c1"].at[0, 0, 0, 0].set(100.0)
    steps = train.make_steps(apply, specs)
    ns, _, _ = steps["phase1_step"](
        state, jnp.zeros((4, 16, 16, 3)), jnp.zeros((4,), jnp.int32),
        jax.random.PRNGKey(1), 0.0, 0.0,
    )
    wmax = float(jnp.max(jnp.abs(ns["params"]["c1"])))
    assert wmax <= 2.0, wmax


def test_phase2_quantized_loss_decreases():
    init, apply, specs = build("tinynet", width=8, image=16)
    state = init(jax.random.PRNGKey(0))
    steps = train.make_steps(apply, specs)
    rng = np.random.default_rng(5)
    imgs = jnp.asarray(rng.uniform(-1, 1, (16, 16, 16, 3)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 10, (16,)).astype(np.int32))
    prec = _uniform_prec(specs, 4)
    step = jax.jit(steps["phase2_step"])
    losses = []
    ns = state
    for _ in range(25):
        ns, loss, _ = step(ns, prec, imgs, labels, 0.05)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_bn_running_stats_update():
    init, apply, specs = build("tinynet", width=8, image=16)
    state = init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(0, 5, (8, 16, 16, 3)).astype(np.float32))
    _, new_bn = apply(state, None, x, "fp32", jax.random.PRNGKey(0), True)
    # running stats moved toward batch stats
    assert float(jnp.max(jnp.abs(new_bn["c1/var"] - state["bn"]["c1/var"]))) > 0
    # eval mode: unchanged
    _, eval_bn = apply(state, None, x, "fp32", jax.random.PRNGKey(0), False)
    assert_allclose(np.asarray(eval_bn["c1/var"]), np.asarray(state["bn"]["c1/var"]))
