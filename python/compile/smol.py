"""Core SMOL quantization numerics, shared by kernels, models and tests.

SMOL's bitstring -> value mapping (paper Sec. II-B) is

    v = sum_{i=1..n} (2 b_i - 1) * 2^{-(i-1)}        (b_1 = MSB)

which, with the unsigned code u = sum b_i 2^{n-i}, is equivalently

    v = (2u - (2^n - 1)) * 2^{1-n}  =  m * step,   m odd,  step = 2^{1-n}.

So an n-bit SMOL value is an *odd* multiple of step = 2^{1-n}, in the range
[-(2^n - 1) * step, +(2^n - 1) * step] = [-(2 - step), +(2 - step)].
There is no zero value; 1-bit values are {-1, +1}.

Examples from the paper: 4-bit 1101 -> 1.375, 2-bit 10 -> 0.5.

The noise-scale parameterization: sigma(s) = sigmoid(s) is the noise
half-step; precision p = 1 + round(log2(1 + e^{-s})); s_init for an initial
precision p is -ln(2^{p-1} - 1) so that sigmoid(s_init) = 2^{1-p}.

All quantized values and their pairwise products are exactly representable
in the paper's 16.6 fixed-point lanes (units of 2^-6): a p-bit x p-bit
product has units 2^{2-2p} >= 2^-6 for p <= 4.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Fraction bits of the fixed-point accumulator (paper: 16.6 lanes widened to
# 32-bit with 6 fraction bits by vpaddlq_s16 / vaddvq_s32).
ACC_FRAC_BITS = 6
ACC_SCALE = float(1 << ACC_FRAC_BITS)  # 64.0

# Precisions the system-aware variant allows (Observation 2).
SUPPORTED_PRECISIONS = (1, 2, 4)


def step_for(p):
    """Quantization step 2^{1-p} for a p-bit SMOL value."""
    return 2.0 ** (1.0 - p)


def qmax_for(p):
    """Largest representable magnitude (2^p - 1) * 2^{1-p} = 2 - 2^{1-p}."""
    return 2.0 - step_for(p)


def s_init_for(p_init: int) -> float:
    """s_init = -ln(2^{p_init-1} - 1); sigmoid(s_init) = 2^{1-p_init}.

    p_init = 1 gives -ln(0) = +inf; the paper initializes with p_init >= 2.
    """
    import math

    return -math.log(2.0 ** (p_init - 1) - 1.0)


def sigma(s):
    """Noise scale sigma(s) = sigmoid(s) (the quantization half-step)."""
    return jax.nn.sigmoid(s)


def precision_bits(s):
    """p = 1 + round(log2(1 + e^{-s})) (Algorithm 1 line 9)."""
    return 1.0 + jnp.round(jnp.log2(1.0 + jnp.exp(-s)))


def soft_bits(s):
    """The regularizer term log2(1 + e^{-s}) (a smooth bits-per-value proxy).

    Computed via softplus for numerical stability at large |s|.
    """
    return jax.nn.softplus(-s) / jnp.log(2.0)


def snap_precision(p):
    """Snap a real precision to the closest value in {1, 2, 4} (Alg. 2 l.11)."""
    p = jnp.asarray(p)
    # Boundaries by absolute distance: p < 1.5 -> 1; 1.5 <= p < 3 -> 2; else 4.
    return jnp.where(p < 1.5, 1.0, jnp.where(p < 3.0, 2.0, 4.0))


def s_for_precision(p):
    """Inverse of precision_bits on the representative grid: s with
    sigmoid(s) = 2^{1-p}, i.e. s = -ln(2^{p-1} - 1) for p > 1, large for p=1."""
    p = jnp.asarray(p, dtype=jnp.float32)
    # For p == 1, 2^{p-1} - 1 == 0 -> s = +inf; clamp to a large finite value.
    raw = -jnp.log(jnp.maximum(2.0 ** (p - 1.0) - 1.0, 1e-9))
    return jnp.where(p <= 1.0, 20.0, raw)


def quantize_odd(x, step, qmax):
    """Quantize x to the nearest odd multiple of `step`, clamped to +-qmax.

    step/qmax broadcast against x (typically per-input-channel vectors).
    This is the deterministic phase-II / inference quantizer.
    """
    u = x / step
    # Nearest odd integer to u: 2*round((u - 1) / 2) + 1.
    o = 2.0 * jnp.round((u - 1.0) * 0.5) + 1.0
    m_max = qmax / step  # = 2^p - 1
    o = jnp.clip(o, -m_max, m_max)
    return o * step


def quantize_bits(x, p):
    """Quantize x to p-bit SMOL values (p may be an array broadcast to x)."""
    p = jnp.asarray(p, dtype=jnp.float32)
    step = 2.0 ** (1.0 - p)
    return quantize_odd(x, step, 2.0 - step)


@jax.custom_vjp
def quantize_ste(x, step, qmax):
    """Quantizer with straight-through gradient (phase II training).

    Forward: quantize_odd. Backward: pass-through on x inside the clip
    range, zero outside; zero gradient to step/qmax.
    """
    return quantize_odd(x, step, qmax)


def _quantize_ste_fwd(x, step, qmax):
    return quantize_odd(x, step, qmax), (x, jnp.broadcast_to(qmax, x.shape))


def _quantize_ste_bwd(res, g):
    x, qmax = res
    inside = (jnp.abs(x) <= qmax).astype(g.dtype)
    return g * inside, None, None


quantize_ste.defvjp(_quantize_ste_fwd, _quantize_ste_bwd)


def fixed_point_round(x, frac_bits: int = ACC_FRAC_BITS):
    """Round to the fixed-point grid with `frac_bits` fraction bits.

    For exact SMOL arithmetic this is the identity; it models the hardware's
    accumulator format and guards the oracle against drift.
    """
    scale = 2.0**frac_bits
    return jnp.round(x * scale) / scale


def code_to_value(u, p):
    """Unsigned n-bit code -> SMOL value: (2u - (2^p - 1)) * 2^{1-p}."""
    u = jnp.asarray(u, dtype=jnp.float32)
    p = jnp.asarray(p, dtype=jnp.float32)
    return (2.0 * u - (2.0**p - 1.0)) * 2.0 ** (1.0 - p)


def value_to_code(v, p):
    """SMOL value -> unsigned n-bit code (inverse of code_to_value)."""
    v = jnp.asarray(v, dtype=jnp.float32)
    p = jnp.asarray(p, dtype=jnp.float32)
    m = v / 2.0 ** (1.0 - p)  # odd integer
    return jnp.round((m + (2.0**p - 1.0)) * 0.5)
