"""L2 quantized building blocks (functional, NHWC).

Every conv/linear layer carries a per-input-channel SMOL parameter:

- mode "fp32":  plain float layer (the full-precision baseline).
- mode "noise": SASMOL phase I — uniform +-1 noise scaled by sigma(s^{l,i})
  injected into both the layer inputs and the weights along the input-
  channel axis (Algorithm 2 line 6), via the L1 noise kernel.
- mode "quant": phase II / QAT — inputs and weights quantized to the fixed
  per-channel precisions with straight-through gradients.
- mode "eval":  inference path — dense convs/FC run through the fused L1
  Pallas qmac kernel (quantize-inside-MAC, 16.6 fixed-point accumulator),
  exactly the datapath the rust SIMD simulator models.

Weight layout is HWIO; im2col patches are channel-major (c, kh, kw) which
matches jax.lax.conv_general_dilated_patches (asserted in tests), so the
per-channel step/qmax vectors are jnp.repeat(step_c, kh*kw).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile import smol
from compile.kernels import noise as noise_k
from compile.kernels import qmac
from compile.kernels import quantize as quant_k

DN = ("NHWC", "HWIO", "NHWC")


def conv_fp(x, w, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=DN,
        feature_group_count=groups,
    )


def _prec_arrays(s):
    """Per-channel (step, qmax) from the trainable s (eval/quant use the
    snapped {1,2,4} precisions; callers may instead pass explicit arrays)."""
    p = smol.snap_precision(smol.precision_bits(s))
    step = 2.0 ** (1.0 - p)
    return step, 2.0 - step


def qconv2d(x, w, step_in, qmax_in, *, stride=1, groups=1, mode="quant", noise_ctx=None):
    """Quantized conv. step_in/qmax_in: (Cin,) arrays for the layer's input
    channels (for grouped convs, Cin = full input channel count of x).

    noise_ctx: (sigma_per_channel (Cin,), rng key) — required for mode
    "noise"; sigma = smol.sigma(s) computed by the caller so gradients flow
    to s.
    """
    if mode == "fp32":
        return conv_fp(x, w, stride, groups)

    if mode == "noise":
        sig, key = noise_ctx
        kx, kw_ = jax.random.split(key)
        eps_x = jax.random.rademacher(kx, x.shape, dtype=x.dtype)
        eps_w = jax.random.rademacher(kw_, w.shape, dtype=w.dtype)
        xn = noise_k.inject_noise(x, sig[None, None, None, :], eps_x)
        # HWIO: input-channel axis is 2. Grouped convs have Cin/groups
        # weight input channels; each group g sees channels [g*cg, (g+1)*cg).
        cg = w.shape[2]
        if groups == 1:
            sig_w = sig[None, None, :, None]
        else:
            # output channels are ordered by group; weight in-channel i of
            # group g corresponds to input channel g*cg + i.
            sig_w = _grouped_in_scale(sig, w.shape, groups)
        wn = noise_k.inject_noise(w, sig_w, eps_w)
        return conv_fp(xn, wn, stride, groups)

    # quant / eval: quantize inputs per channel and weights per in-channel.
    if mode == "quant" or groups > 1:
        xq = smol.quantize_ste(x, step_in[None, None, None, :], qmax_in[None, None, None, :])
        if groups == 1:
            sw = step_in[None, None, :, None]
            qw = qmax_in[None, None, :, None]
        else:
            sw = _grouped_in_scale(step_in, w.shape, groups)
            qw = _grouped_in_scale(qmax_in, w.shape, groups)
        wq = smol.quantize_ste(w, jnp.broadcast_to(sw, w.shape), jnp.broadcast_to(qw, w.shape))
        return conv_fp(xq, wq, stride, groups)

    # mode == "eval", dense conv: Pallas quantize kernel on the activations
    # (SAME-padding zeros are structural — hardware skips out-of-bounds
    # taps, so quantization must happen *before* padding), then the Pallas
    # fixed-point MAC over im2col patches.
    kh, kw2, cin, cout = w.shape
    xq = quant_k.quantize(x, step_in[None, None, None, :], qmax_in[None, None, None, :])
    patches = jax.lax.conv_general_dilated_patches(
        xq, (kh, kw2), (stride, stride), "SAME", dimension_numbers=DN
    )  # (N, H', W', Cin*kh*kw), channel-major features
    n, ho, wo, kdim = patches.shape
    step_k = jnp.repeat(step_in, kh * kw2)
    qmax_k = jnp.repeat(qmax_in, kh * kw2)
    # HWIO -> (I, kh, kw, O) -> (I*kh*kw, O) to match patch ordering
    wmat = jnp.transpose(w, (2, 0, 1, 3)).reshape(cin * kh * kw2, cout)
    wq = smol.quantize_odd(wmat, step_k[:, None], qmax_k[:, None])
    out = qmac.fmatmul(patches.reshape(n * ho * wo, kdim), wq)
    return out.reshape(n, ho, wo, cout)


def _grouped_in_scale(vec, wshape, groups):
    """Broadcast a per-input-channel (Cin,) vector onto HWIO grouped weights.

    HWIO grouped weights have shape (kh, kw, Cin/groups, Cout); output
    channel o belongs to group o // (Cout/groups) and its weight in-channel
    i maps to input channel  group*Cg + i.
    """
    kh, kw, cg, cout = wshape
    og = cout // groups
    # (groups, cg) -> for each group, its slice of vec
    per_group = vec.reshape(groups, cg)  # input channels are contiguous
    # expand to (cg, cout): column o uses per_group[o // og]
    cols = jnp.repeat(per_group, og, axis=0).reshape(groups * og, cg).T
    return cols[None, None, :, :]


def qlinear(x, w, step_in, qmax_in, *, mode="quant", noise_ctx=None):
    """Quantized dense layer; x: (N, K), w: (K, M)."""
    if mode == "fp32":
        return x @ w
    if mode == "noise":
        sig, key = noise_ctx
        kx, kw_ = jax.random.split(key)
        eps_x = jax.random.rademacher(kx, x.shape, dtype=x.dtype)
        eps_w = jax.random.rademacher(kw_, w.shape, dtype=w.dtype)
        xn = noise_k.inject_noise(x, sig[None, :], eps_x)
        wn = noise_k.inject_noise(w, sig[:, None], eps_w)
        return xn @ wn
    if mode == "quant":
        xq = smol.quantize_ste(x, step_in[None, :], qmax_in[None, :])
        wq = smol.quantize_ste(w, jnp.broadcast_to(step_in[:, None], w.shape), jnp.broadcast_to(qmax_in[:, None], w.shape))
        return xq @ wq
    # eval: fused Pallas kernel
    wq = smol.quantize_odd(w, step_in[:, None], qmax_in[:, None])
    return qmac.qmatmul(x, wq, step_in, qmax_in)


def batch_norm(x, scale, bias, mean, var, *, training, momentum=0.9, eps=1e-5):
    """BN over NHWC (or NC). Returns (y, new_mean, new_var)."""
    axes = tuple(range(x.ndim - 1))
    if training:
        m = jnp.mean(x, axis=axes)
        v = jnp.var(x, axis=axes)
        new_mean = momentum * mean + (1 - momentum) * m
        new_var = momentum * var + (1 - momentum) * v
    else:
        m, v = mean, var
        new_mean, new_var = mean, var
    y = (x - m) * jax.lax.rsqrt(v + eps) * scale + bias
    return y, new_mean, new_var


def global_avg_pool(x):
    return jnp.mean(x, axis=(1, 2))


def channel_shuffle(x, groups):
    """ShuffleNet channel shuffle over NHWC."""
    n, h, w, c = x.shape
    x = x.reshape(n, h, w, groups, c // groups)
    x = jnp.swapaxes(x, 3, 4)
    return x.reshape(n, h, w, c)
