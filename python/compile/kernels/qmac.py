"""L1 Pallas kernel: fused per-channel quantize + fixed-point matmul MAC.

This is the paper's compute hot-spot: the inner loop of every ULFlexiNet
layer quantizes incoming 32-bit fixed-point activations to the per-input-
channel precisions and multiply-accumulates them against pre-quantized
weights, exactly what the configurable SIMD ALU (Fig. 3) does per 16-bit
lane. On TPU this maps to VMEM-tiled channel blocks (see DESIGN.md
Hardware-Adaptation): BlockSpec plays the role the paper's vector registers
play, and the 16.6 fixed-point accumulator is exact in f32 because all SMOL
values/products are dyadic rationals with >= 2^-6 granularity.

The kernel MUST be lowered with interpret=True on this CPU testbed (real
TPU lowering emits a Mosaic custom-call the CPU PJRT plugin cannot run).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile import smol

# Default block sizes. Tuned for VMEM residency: a (64 x 128) f32 x-block,
# (128 x 128) w-block and (64 x 128) out-block total ~160 KiB << 16 MiB VMEM,
# leaving room for double buffering across the K grid dimension.
BLOCK_M = 64
BLOCK_N = 128
BLOCK_K = 128


def _qmm_kernel(x_ref, w_ref, step_ref, qmax_ref, o_ref, *, n_k: int):
    """One (m, n, k) grid step: quantize the x-block per-channel, MAC."""
    k = pl.program_id(2)

    x = x_ref[...]
    step = step_ref[...][None, :]  # (1, bk) broadcast over rows
    qmax = qmax_ref[...][None, :]

    # Nearest odd multiple of step, clamped to +-qmax (SMOL quantizer).
    u = x / step
    o = 2.0 * jnp.round((u - 1.0) * 0.5) + 1.0
    o = jnp.clip(o, -qmax / step, qmax / step)
    xq = o * step

    partial = jnp.dot(xq, w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(k > 0)
    def _acc():
        o_ref[...] += partial

    # Model the 32-bit / 6-fraction-bit accumulator of the paper's datapath
    # (exactness makes this the identity for in-range SMOL data, but it
    # pins the semantics the rust simulator is validated against).
    @pl.when(k == n_k - 1)
    def _round():
        acc = o_ref[...]
        o_ref[...] = jnp.round(acc * smol.ACC_SCALE) * (1.0 / smol.ACC_SCALE)


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def qmatmul(
    x,
    wq,
    step,
    qmax,
    *,
    block_m: int = BLOCK_M,
    block_n: int = BLOCK_N,
    block_k: int = BLOCK_K,
    interpret: bool = True,
):
    """out = quantize_odd(x, step, qmax) @ wq with 16.6 fixed-point rounding.

    x:    (M, K) f32 raw activations (e.g. 32-bit fixed-point layer inputs)
    wq:   (K, N) f32 pre-quantized SMOL weight values
    step: (K,)   f32 per-input-channel quantization step 2^{1-p}
    qmax: (K,)   f32 per-input-channel clip magnitude 2 - 2^{1-p}
    """
    m, k = x.shape
    k2, n = wq.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert step.shape == (k,) and qmax.shape == (k,)

    block_m = min(block_m, max(8, m))
    block_n = min(block_n, max(8, n))
    block_k = min(block_k, max(8, k))

    xp = _pad_to(_pad_to(x, block_m, 0), block_k, 1)
    wp = _pad_to(_pad_to(wq, block_k, 0), block_n, 1)
    # Padded channels get step=1/qmax=1 so the quantizer is well-defined on
    # the zero padding; quantize(0)=+-step there, but wq padding is zero so
    # the products vanish.
    sp = _pad_to(step + 0.0, block_k, 0) + _pad_to(jnp.zeros_like(step), block_k, 0)
    sp = jnp.where(sp == 0.0, 1.0, sp)
    qp = _pad_to(qmax, block_k, 0)
    qp = jnp.where(qp == 0.0, 1.0, qp)

    mp, kp = xp.shape
    np_ = wp.shape[1]
    grid = (mp // block_m, np_ // block_n, kp // block_k)

    out = pl.pallas_call(
        functools.partial(_qmm_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_k,), lambda i, j, kk: (kk,)),
            pl.BlockSpec((block_k,), lambda i, j, kk: (kk,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp, sp, qp)
    return out[:m, :n]


def _fmm_kernel(x_ref, w_ref, o_ref, *, n_k: int):
    """Fixed-point MAC without input quantization (inputs pre-quantized;
    structural SAME-padding zeros must stay zero — hardware skips
    out-of-bounds taps, see Algorithm 4's masking)."""
    k = pl.program_id(2)
    partial = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(k > 0)
    def _acc():
        o_ref[...] += partial

    @pl.when(k == n_k - 1)
    def _round():
        acc = o_ref[...]
        o_ref[...] = jnp.round(acc * smol.ACC_SCALE) * (1.0 / smol.ACC_SCALE)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def fmatmul(
    xq,
    wq,
    *,
    block_m: int = BLOCK_M,
    block_n: int = BLOCK_N,
    block_k: int = BLOCK_K,
    interpret: bool = True,
):
    """out = xq @ wq with 16.6 fixed-point rounding (operands already
    SMOL-quantized; padding zeros contribute exactly zero)."""
    m, k = xq.shape
    k2, n = wq.shape
    assert k == k2
    block_m = min(block_m, max(8, m))
    block_n = min(block_n, max(8, n))
    block_k = min(block_k, max(8, k))
    xp = _pad_to(_pad_to(xq, block_m, 0), block_k, 1)
    wp = _pad_to(_pad_to(wq, block_k, 0), block_n, 1)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    grid = (mp // block_m, np_ // block_n, kp // block_k)
    out = pl.pallas_call(
        functools.partial(_fmm_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]


@jax.custom_vjp
def qmatmul_ste(x, wq, step, qmax):
    """qmatmul with straight-through gradients for phase-II training.

    Forward runs the fused Pallas kernel; backward treats the quantizer as
    identity inside the clip range (STE) so dL/dx = g @ wq^T masked by the
    clip indicator, dL/dwq = xq^T @ g.
    """
    return qmatmul(x, wq, step, qmax)


def _qmatmul_ste_fwd(x, wq, step, qmax):
    out = qmatmul(x, wq, step, qmax)
    return out, (x, wq, step, qmax)


def _qmatmul_ste_bwd(res, g):
    x, wq, step, qmax = res
    inside = (jnp.abs(x) <= qmax[None, :]).astype(g.dtype)
    xq = smol.quantize_odd(x, step[None, :], qmax[None, :])
    dx = (g @ wq.T) * inside
    dw = xq.T @ g
    return dx, dw, None, None


qmatmul_ste.defvjp(_qmatmul_ste_fwd, _qmatmul_ste_bwd)
