"""L1 Pallas kernel: SMOL phase-I noise injection  w + sigma(s) * eps.

Phase I of SMOL perturbs every weight/activation with uniform noise scaled
by the trainable per-input-channel sigma(s) (Algorithm 2 line 6). The
forward runs as a Pallas elementwise kernel over 2-D tiles; the backward is
analytic (d/dw = g, d/dscale = g * eps) via custom_vjp so the whole phase-I
step stays differentiable with respect to both w and s.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 256
BLOCK_C = 256


def _noise_kernel(w_ref, scale_ref, eps_ref, o_ref):
    o_ref[...] = w_ref[...] + scale_ref[...] * eps_ref[...]


def _pad2(x, br, bc):
    r, c = x.shape
    pr, pc = (-r) % br, (-c) % bc
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x


@functools.partial(jax.jit, static_argnames=("interpret",))
def inject_noise_2d(w, scale, eps, *, interpret: bool = True):
    """Elementwise w + scale * eps over a 2-D view (all args same shape)."""
    assert w.shape == scale.shape == eps.shape and w.ndim == 2
    r, c = w.shape
    br, bc = min(BLOCK_R, r), min(BLOCK_C, c)
    wp, sp, ep = (_pad2(a, br, bc) for a in (w, scale, eps))
    grid = (wp.shape[0] // br, wp.shape[1] // bc)
    out = pl.pallas_call(
        _noise_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))] * 3,
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(wp.shape, jnp.float32),
        interpret=interpret,
    )(wp, sp, ep)
    return out[:r, :c]


@jax.custom_vjp
def inject_noise(w, scale, eps):
    """w + scale * eps with shapes broadcast from scale to w.

    w:     any shape
    scale: broadcastable to w (per-channel sigma(s) pre-broadcast by caller)
    eps:   same shape as w (uniform +-1 noise)
    """
    scale_b = jnp.broadcast_to(scale, w.shape)
    flat = lambda a: a.reshape(-1, w.shape[-1]) if w.ndim > 1 else a.reshape(1, -1)
    out = inject_noise_2d(flat(w), flat(scale_b), flat(eps))
    return out.reshape(w.shape)


def _inject_fwd(w, scale, eps):
    return inject_noise(w, scale, eps), (jnp.broadcast_to(scale, w.shape).shape != scale.shape, scale.shape, eps)


def _inject_bwd(res, g):
    _, scale_shape, eps = res
    dw = g
    dscale_full = g * eps
    # Sum-reduce the scale gradient back to its (broadcast) shape.
    dscale = _reduce_to_shape(dscale_full, scale_shape)
    return dw, dscale, None


def _reduce_to_shape(x, shape):
    # Sum over leading extra dims, then over broadcast dims of size 1.
    while x.ndim > len(shape):
        x = x.sum(axis=0)
    for ax, s in enumerate(shape):
        if s == 1 and x.shape[ax] != 1:
            x = x.sum(axis=ax, keepdims=True)
    return x.reshape(shape)


inject_noise.defvjp(_inject_fwd, _inject_bwd)
