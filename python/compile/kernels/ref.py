"""Pure-jnp oracles for every L1 Pallas kernel (the correctness ground
truth pytest compares against — and the reference the rust SIMD simulator
is cross-validated with through the eval HLO artifacts)."""

from __future__ import annotations

import jax.numpy as jnp

from compile import smol


def ref_quantize(x, step, qmax):
    """Oracle for kernels.quantize: nearest odd multiple of step, clamped."""
    return smol.quantize_odd(x, step, qmax)


def ref_inject_noise(w, scale, eps):
    """Oracle for kernels.noise: w + scale * eps (broadcast)."""
    return w + jnp.broadcast_to(scale, w.shape) * eps


def ref_qmatmul(x, wq, step, qmax):
    """Oracle for kernels.qmac.qmatmul: quantize then exact matmul, rounded
    to the 2^-6 fixed-point accumulator grid."""
    xq = smol.quantize_odd(x, step[None, :], qmax[None, :])
    out = xq @ wq
    return smol.fixed_point_round(out)


def ref_qmatmul_int(x, wq, prec):
    """Bit-exact integer-arithmetic model of the configurable ALU's MAC,
    mirroring what rust/src/simd does: per-channel odd integer codes,
    products shifted into 2^-6 accumulator units, int32 accumulation.

    prec: (K,) integer precisions in {1, 2, 4}. Proves the float kernel
    path == the hardware integer path.
    """
    prec = jnp.asarray(prec, dtype=jnp.float32)
    step = 2.0 ** (1.0 - prec)
    qmax = 2.0 - step
    xq = smol.quantize_odd(x, step[None, :], qmax[None, :])
    # odd integer mantissas m = v / step (K is axis 0 of wq, axis 1 of x)
    xm = jnp.round(xq / step[None, :]).astype(jnp.int32)
    wm = jnp.round(wq / step[:, None]).astype(jnp.int32)
    # product units: step^2 = 2^{2-2p}; scale into 2^-6 units: << (8 - 2p)
    shift = jnp.round(8.0 - 2.0 * prec).astype(jnp.int32)
    scale = (1 << shift).astype(jnp.int32)
    # out[m,n] = sum_k xm[m,k] * scale[k] * wm[k,n]   (int32, exact)
    acc = jnp.einsum("mk,kn->mn", xm * scale[None, :], wm)
    return acc.astype(jnp.float32) / smol.ACC_SCALE
