"""L1 Pallas kernel: elementwise SMOL quantizer (nearest odd multiple).

Used at build time to bake weight tensors into their fixed phase-II
precisions, and as the quantize half of the fused qmac kernel's oracle
decomposition. Same numerics as smol.quantize_odd.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 256
BLOCK_C = 256


def _quant_kernel(x_ref, step_ref, qmax_ref, o_ref):
    x = x_ref[...]
    step = step_ref[...]
    qmax = qmax_ref[...]
    u = x / step
    o = 2.0 * jnp.round((u - 1.0) * 0.5) + 1.0
    o = jnp.clip(o, -qmax / step, qmax / step)
    o_ref[...] = o * step


def _pad2(x, br, bc, fill=0.0):
    r, c = x.shape
    pr, pc = (-r) % br, (-c) % bc
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)), constant_values=fill)
    return x


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_2d(x, step, qmax, *, interpret: bool = True):
    """Quantize a 2-D array; step/qmax have the same 2-D shape (pad-safe)."""
    assert x.shape == step.shape == qmax.shape and x.ndim == 2
    r, c = x.shape
    br, bc = min(BLOCK_R, r), min(BLOCK_C, c)
    xp = _pad2(x, br, bc)
    sp = _pad2(step, br, bc, fill=1.0)
    qp = _pad2(qmax, br, bc, fill=1.0)
    grid = (xp.shape[0] // br, xp.shape[1] // bc)
    out = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))] * 3,
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.float32),
        interpret=interpret,
    )(xp, sp, qp)
    return out[:r, :c]


def quantize(x, step, qmax):
    """Quantize any-rank x; step/qmax broadcastable to x."""
    step_b = jnp.broadcast_to(step, x.shape)
    qmax_b = jnp.broadcast_to(qmax, x.shape)
    if x.ndim == 2:
        return quantize_2d(x, step_b, qmax_b)
    last = x.shape[-1] if x.ndim >= 1 and x.shape[-1] > 0 else 1
    flat = lambda a: a.reshape(-1, last)
    return quantize_2d(flat(x), flat(step_b), flat(qmax_b)).reshape(x.shape)
