"""L2 SASMOL training/eval steps (the functions AOT-lowered to HLO).

Each step is a pure function over a `state` pytree:

    state = {"params": {...}, "vel": {...}, "bn": {...},
             "s": {...},      "svel": {...}}

- phase1_step: SASMOL phase I — noise-injected forward (L1 noise kernel),
  loss + lambda * ||log2(1+e^-s)||_1, SGD-momentum on params and s,
  weight clip to +-(2 - sigma(s)) along input channels (Algorithm 2).
- phase2_step: phase II / uniform QAT — STE-quantized forward under fixed
  per-channel (step, qmax) arrays supplied by the rust coordinator (covers
  U2/U4/INT8 and P4/P8/P45 with one artifact per model).
- fp32_step:   full-precision baseline.
- eval_quant:  inference path through the fused Pallas qmac kernel.
- eval_fp32:   full-precision inference.

The rust coordinator drives these via PJRT; python never runs at that time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile import smol

MOMENTUM = 0.9


def cross_entropy(logits, labels, num_classes):
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(labels, num_classes, dtype=logits.dtype)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def _sgd(params, vel, grads, lr):
    new_vel = jax.tree_util.tree_map(lambda v, g: MOMENTUM * v + g, vel, grads)
    new_params = jax.tree_util.tree_map(lambda p, v: p - lr * v, params, new_vel)
    return new_params, new_vel


def _clip_weights(params, s, specs):
    """Clip conv/fc weights to +-(2 - sigma(s)) per input channel (phase I)."""
    out = dict(params)
    for spec in specs:
        name = spec["name"]
        w = out[name]
        lim = 2.0 - smol.sigma(s[name])
        if spec["op"] == "conv":
            groups = spec["groups"]
            if groups == 1:
                limb = lim[None, None, :, None]
            else:
                from compile.layers import _grouped_in_scale

                limb = _grouped_in_scale(lim, w.shape, groups)
            limb = jnp.broadcast_to(limb, w.shape)
        else:
            limb = jnp.broadcast_to(lim[:, None], w.shape)
        out[name] = jnp.clip(w, -limb, limb)
    return out


def make_steps(apply_fn, specs, num_classes=10):
    """Build the five step functions for one model."""

    def _forward_loss_noise(params, s, bn, vel, svel, images, labels, key, lam):
        state = {"params": params, "bn": bn, "s": s, "vel": vel, "svel": svel}
        logits, new_bn = apply_fn(state, None, images, "noise", key, True)
        ce = cross_entropy(logits, labels, num_classes)
        reg = sum(jnp.sum(smol.soft_bits(v)) for v in s.values())
        return ce + lam * reg, (logits, new_bn, ce)

    def phase1_step(state, images, labels, key, lr, lam):
        grad_fn = jax.grad(_forward_loss_noise, argnums=(0, 1), has_aux=True)
        (gp, gs), (logits, new_bn, ce) = grad_fn(
            state["params"], state["s"], state["bn"], state["vel"], state["svel"],
            images, labels, key, lam,
        )
        new_params, new_vel = _sgd(state["params"], state["vel"], gp, lr)
        new_s, new_svel = _sgd(state["s"], state["svel"], gs, lr)
        new_params = _clip_weights(new_params, new_s, specs)
        new_state = {
            "params": new_params,
            "vel": new_vel,
            "bn": {**state["bn"], **new_bn},
            "s": new_s,
            "svel": new_svel,
        }
        return new_state, ce, accuracy(logits, labels)

    def _forward_loss_quant(params, bn, rest, prec, images, labels):
        state = {"params": params, "bn": bn, **rest}
        logits, new_bn = apply_fn(state, prec, images, "quant", jax.random.PRNGKey(0), True)
        ce = cross_entropy(logits, labels, num_classes)
        return ce, (logits, new_bn)

    def phase2_step(state, prec, images, labels, lr):
        rest = {"s": state["s"], "vel": state["vel"], "svel": state["svel"]}
        grad_fn = jax.grad(_forward_loss_quant, has_aux=True)
        gp, (logits, new_bn) = grad_fn(
            state["params"], state["bn"], rest, prec, images, labels
        )
        new_params, new_vel = _sgd(state["params"], state["vel"], gp, lr)
        new_state = {
            "params": new_params,
            "vel": new_vel,
            "bn": {**state["bn"], **new_bn},
            "s": state["s"],
            "svel": state["svel"],
        }
        return new_state, ce_out(logits, labels, num_classes), accuracy(logits, labels)

    def _forward_loss_fp(params, bn, rest, images, labels):
        state = {"params": params, "bn": bn, **rest}
        logits, new_bn = apply_fn(state, None, images, "fp32", jax.random.PRNGKey(0), True)
        ce = cross_entropy(logits, labels, num_classes)
        return ce, (logits, new_bn)

    def fp32_step(state, images, labels, lr):
        rest = {"s": state["s"], "vel": state["vel"], "svel": state["svel"]}
        grad_fn = jax.grad(_forward_loss_fp, has_aux=True)
        gp, (logits, new_bn) = grad_fn(state["params"], state["bn"], rest, images, labels)
        new_params, new_vel = _sgd(state["params"], state["vel"], gp, lr)
        new_state = {
            "params": new_params,
            "vel": new_vel,
            "bn": {**state["bn"], **new_bn},
            "s": state["s"],
            "svel": state["svel"],
        }
        return new_state, ce_out(logits, labels, num_classes), accuracy(logits, labels)

    def eval_quant(state, prec, images):
        logits, _ = apply_fn(state, prec, images, "eval", jax.random.PRNGKey(0), False)
        return logits

    def eval_fp32(state, images):
        logits, _ = apply_fn(state, None, images, "fp32", jax.random.PRNGKey(0), False)
        return logits

    return dict(
        phase1_step=phase1_step,
        phase2_step=phase2_step,
        fp32_step=fp32_step,
        eval_quant=eval_quant,
        eval_fp32=eval_fp32,
    )


def ce_out(logits, labels, num_classes):
    return cross_entropy(logits, labels, num_classes)
