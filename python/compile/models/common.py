"""Shared machinery for spec-driven quantized models.

A model build() returns (init_fn, apply_fn, specs):

- specs: ordered list of layer descriptors
    {name, op ("conv"|"fc"), cin, cout, k, stride, groups, hin, win}
  `hin/win` are the layer's input spatial dims — the rust code generator
  and timing simulator consume this table verbatim (emitted to meta.json).
- init_fn(key) -> state dict:
    {"params": {name: w, name+"/bn_scale": g, ...},
     "bn":     {name+"/mean": m, name+"/var": v},
     "s":      {name: (cin,)},
     "vel":    momentum buffers, same tree as params}
- apply_fn(state, prec, x, mode, key, training) -> (logits, new_bn)
    prec: {name: (step (cin,), qmax (cin,))}, ignored for fp32/noise modes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile import layers, smol

MODELS = {}


def register(name):
    def deco(fn):
        MODELS[name] = fn
        return fn

    return deco


def build(name, **kw):
    return MODELS[name](**kw)


class Ctx:
    """Per-forward context threading mode/prec/rng/bn through blocks."""

    def __init__(self, state, prec, mode, key, training):
        self.params = state["params"]
        self.bn_in = state["bn"]
        self.s = state["s"]
        self.prec = prec
        self.mode = mode
        self.key = key
        self.training = training
        self.bn_out = {}

    def next_key(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    def layer_prec(self, name, cin):
        if self.prec is not None and name in self.prec:
            return self.prec[name]
        # derive from s (used by python-side tests; rust always passes prec)
        p = smol.snap_precision(smol.precision_bits(self.s[name]))
        step = 2.0 ** (1.0 - p)
        return step, 2.0 - step

    def noise_ctx(self, name):
        return (smol.sigma(self.s[name]), self.next_key())


def conv(ctx: Ctx, name, x, *, stride=1, groups=1, relu=True, bn=True):
    w = ctx.params[name]
    cin_full = x.shape[-1]
    step, qmax = ctx.layer_prec(name, cin_full)
    nk = ctx.noise_ctx(name) if ctx.mode == "noise" else None
    y = layers.qconv2d(
        x, w, step, qmax, stride=stride, groups=groups, mode=ctx.mode, noise_ctx=nk
    )
    if bn:
        y, m, v = layers.batch_norm(
            y,
            ctx.params[name + "/bn_scale"],
            ctx.params[name + "/bn_bias"],
            ctx.bn_in[name + "/mean"],
            ctx.bn_in[name + "/var"],
            training=ctx.training,
        )
        ctx.bn_out[name + "/mean"] = m
        ctx.bn_out[name + "/var"] = v
    if relu:
        y = jax.nn.relu(y)
    return y


def fc(ctx: Ctx, name, x):
    w = ctx.params[name]
    step, qmax = ctx.layer_prec(name, x.shape[-1])
    nk = ctx.noise_ctx(name) if ctx.mode == "noise" else None
    return layers.qlinear(x, w, step, qmax, mode=ctx.mode, noise_ctx=nk)


class Registry:
    """Collects layer specs + parameter initializers during build()."""

    def __init__(self, p_init=4):
        self.specs = []
        self.inits = {}  # name -> (shape, kind)
        self.p_init = p_init

    def conv(self, name, cin, cout, k, stride, groups, hin, win, bn=True):
        self.specs.append(
            dict(name=name, op="conv", cin=cin, cout=cout, k=k, stride=stride, groups=groups, hin=hin, win=win)
        )
        self.inits[name] = ((k, k, cin // groups, cout), "conv_w")
        if bn:
            self.inits[name + "/bn_scale"] = ((cout,), "ones")
            self.inits[name + "/bn_bias"] = ((cout,), "zeros")
        return (hin + stride - 1) // stride, (win + stride - 1) // stride

    def fc(self, name, cin, cout):
        self.specs.append(
            dict(name=name, op="fc", cin=cin, cout=cout, k=1, stride=1, groups=1, hin=1, win=1)
        )
        self.inits[name] = ((cin, cout), "fc_w")

    def init_state(self, key):
        params, s, bn = {}, {}, {}
        names = sorted(self.inits)
        keys = jax.random.split(key, len(names))
        for kk, name in zip(keys, names):
            shape, kind = self.inits[name]
            if kind == "conv_w":
                fan_in = shape[0] * shape[1] * shape[2]
                params[name] = jax.random.normal(kk, shape) * jnp.sqrt(2.0 / fan_in)
            elif kind == "fc_w":
                params[name] = jax.random.normal(kk, shape) * jnp.sqrt(1.0 / shape[0])
            elif kind == "ones":
                params[name] = jnp.ones(shape)
            else:
                params[name] = jnp.zeros(shape)
        for spec in self.specs:
            s[spec["name"]] = jnp.full((spec["cin"],), smol.s_init_for(self.p_init), jnp.float32)
            if spec["op"] == "conv":
                bn[spec["name"] + "/mean"] = jnp.zeros((spec["cout"],))
                bn[spec["name"] + "/var"] = jnp.ones((spec["cout"],))
        vel = jax.tree_util.tree_map(jnp.zeros_like, params)
        svel = jax.tree_util.tree_map(jnp.zeros_like, s)
        return {"params": params, "bn": bn, "s": s, "vel": vel, "svel": svel}
