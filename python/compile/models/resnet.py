"""ResNet-18 (CIFAR variant), width-scaled. Paper workload: ResNet-18 on
ImageNet; here scaled to the synthetic CIFAR-like testbed (DESIGN.md
substitution table)."""

from __future__ import annotations

import jax.numpy as jnp

from compile.models.common import Ctx, Registry, conv, fc, register
from compile import layers


@register("resnet18")
def build(width=8, num_classes=10, image=32):
    reg = Registry()
    stages = [width, 2 * width, 4 * width, 8 * width]
    blocks = [2, 2, 2, 2]
    strides = [1, 2, 2, 2]

    h = w = image
    h, w = reg.conv("stem", 3, width, 3, 1, 1, h, w)
    cin = width
    shortcuts = set()
    for si, (c, n, st) in enumerate(zip(stages, blocks, strides)):
        for bi in range(n):
            s0 = st if bi == 0 else 1
            base = f"s{si}b{bi}"
            h2, w2 = reg.conv(base + "/c1", cin, c, 3, s0, 1, h, w)
            reg.conv(base + "/c2", c, c, 3, 1, 1, h2, w2)
            if s0 != 1 or cin != c:
                reg.conv(base + "/sc", cin, c, 1, s0, 1, h, w)
                shortcuts.add(base)
            h, w = h2, w2
            cin = c
    reg.fc("fc", cin, num_classes)

    def apply(state, prec, x, mode, key, training):
        ctx = Ctx(state, prec, mode, key, training)
        y = conv(ctx, "stem", x)
        cin_ = width
        for si, (c, n, st) in enumerate(zip(stages, blocks, strides)):
            for bi in range(n):
                s0 = st if bi == 0 else 1
                base = f"s{si}b{bi}"
                z = conv(ctx, base + "/c1", y, stride=s0)
                z = conv(ctx, base + "/c2", z, relu=False)
                sc = conv(ctx, base + "/sc", y, stride=s0, relu=False) if base in shortcuts else y
                y = jnp.maximum(z + sc, 0.0)
                cin_ = c
        y = layers.global_avg_pool(y)
        logits = fc(ctx, "fc", y)
        return logits, ctx.bn_out

    return reg.init_state, apply, reg.specs
