"""MobileNetV2 (CIFAR-scale, width-multiplied): inverted residual blocks
with 1x1 expand -> 3x3 depthwise -> 1x1 project."""

from __future__ import annotations

import jax.numpy as jnp

from compile.models.common import Ctx, Registry, conv, fc, register
from compile import layers

# (expansion t, out channels base, repeats n, stride s) — scaled-down CIFAR
# analogue of the paper's MobileNetV2 table.
CFG = [
    (1, 8, 1, 1),
    (4, 12, 2, 1),
    (4, 16, 2, 2),
    (4, 24, 2, 2),
    (4, 32, 1, 1),
]


def _c(base, mult):
    return max(4, int(round(base * mult / 4)) * 4)


@register("mobilenetv2")
def build(width_mult=1.0, num_classes=10, image=32, head=64):
    reg = Registry()
    h = w = image
    c0 = _c(8, width_mult)
    h, w = reg.conv("stem", 3, c0, 3, 1, 1, h, w)
    cin = c0
    blocks = []
    for gi, (t, c, n, s) in enumerate(CFG):
        cout = _c(c, width_mult)
        for bi in range(n):
            st = s if bi == 0 else 1
            base = f"g{gi}b{bi}"
            hidden = cin * t
            if t != 1:
                reg.conv(base + "/exp", cin, hidden, 1, 1, 1, h, w)
            h2, w2 = reg.conv(base + "/dw", hidden, hidden, 3, st, hidden, h, w)
            reg.conv(base + "/proj", hidden, cout, 1, 1, 1, h2, w2)
            blocks.append((base, t, cin, cout, st))
            h, w = h2, w2
            cin = cout
    reg.conv("head", cin, head, 1, 1, 1, h, w)
    reg.fc("fc", head, num_classes)

    def apply(state, prec, x, mode, key, training):
        ctx = Ctx(state, prec, mode, key, training)
        y = conv(ctx, "stem", x)
        for base, t, ci, co, st in blocks:
            inp = y
            if t != 1:
                y = conv(ctx, base + "/exp", y)
            y = conv(ctx, base + "/dw", y, stride=st, groups=y.shape[-1])
            y = conv(ctx, base + "/proj", y, relu=False)
            if st == 1 and ci == co:
                y = y + inp
        y = conv(ctx, "head", y)
        y = layers.global_avg_pool(y)
        logits = fc(ctx, "fc", y)
        return logits, ctx.bn_out

    return reg.init_state, apply, reg.specs
