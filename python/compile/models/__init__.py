"""L2 model zoo: CIFAR-scale ResNet-18, MobileNetV2, ShuffleNetV2 plus a
TinyNet used for fast integration tests. Every conv/FC layer is a
ULFlexiNet layer with per-input-channel SMOL precision parameters."""

from compile.models.common import MODELS, build  # noqa: F401
from compile.models import mobilenet, resnet, shufflenet, tinynet  # noqa: F401
