"""ShuffleNetV2 (CIFAR-scale): channel split / shuffle units with depthwise
convs — the paper's Table I / Fig. 9 workload."""

from __future__ import annotations

import jax.numpy as jnp

from compile.models.common import Ctx, Registry, conv, fc, register
from compile import layers


@register("shufflenetv2")
def build(width_mult=1.0, num_classes=10, image=32, head=64):
    reg = Registry()

    def _c(base):
        return max(8, int(round(base * width_mult / 4)) * 4)

    stage_c = [_c(24), _c(48), _c(96)]
    stage_n = [2, 2, 2]
    h = w = image
    c0 = _c(12)
    h, w = reg.conv("stem", 3, c0, 3, 1, 1, h, w)
    cin = c0
    units = []
    for si, (c, n) in enumerate(zip(stage_c, stage_n)):
        for bi in range(n):
            base = f"s{si}b{bi}"
            if bi == 0:
                # downsample unit: both branches convolved, stride 2
                half = c // 2
                reg.conv(base + "/l_dw", cin, cin, 3, 2, cin, h, w)
                reg.conv(base + "/l_pw", cin, half, 1, 1, 1, (h + 1) // 2, (w + 1) // 2)
                reg.conv(base + "/r_pw1", cin, half, 1, 1, 1, h, w)
                reg.conv(base + "/r_dw", half, half, 3, 2, half, h, w)
                h, w = (h + 1) // 2, (w + 1) // 2
                reg.conv(base + "/r_pw2", half, half, 1, 1, 1, h, w)
                units.append((base, "down", cin, c))
                cin = c
            else:
                half = cin // 2
                reg.conv(base + "/r_pw1", half, half, 1, 1, 1, h, w)
                reg.conv(base + "/r_dw", half, half, 3, 1, half, h, w)
                reg.conv(base + "/r_pw2", half, half, 1, 1, 1, h, w)
                units.append((base, "basic", cin, cin))
    reg.conv("head", cin, head, 1, 1, 1, h, w)
    reg.fc("fc", head, num_classes)

    def apply(state, prec, x, mode, key, training):
        ctx = Ctx(state, prec, mode, key, training)
        y = conv(ctx, "stem", x)
        for base, kind, ci, co in units:
            if kind == "down":
                left = conv(ctx, base + "/l_dw", y, stride=2, groups=y.shape[-1], relu=False)
                left = conv(ctx, base + "/l_pw", left)
                right = conv(ctx, base + "/r_pw1", y)
                right = conv(ctx, base + "/r_dw", right, stride=2, groups=right.shape[-1], relu=False)
                right = conv(ctx, base + "/r_pw2", right)
                y = jnp.concatenate([left, right], axis=-1)
            else:
                half = ci // 2
                left, right = y[..., :half], y[..., half:]
                right = conv(ctx, base + "/r_pw1", right)
                right = conv(ctx, base + "/r_dw", right, groups=half, relu=False)
                right = conv(ctx, base + "/r_pw2", right)
                y = jnp.concatenate([left, right], axis=-1)
            y = layers.channel_shuffle(y, 2)
        y = conv(ctx, "head", y)
        y = layers.global_avg_pool(y)
        logits = fc(ctx, "fc", y)
        return logits, ctx.bn_out

    return reg.init_state, apply, reg.specs
