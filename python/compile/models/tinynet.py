"""TinyNet: a 3-conv + FC network for fast kernel/runtime integration tests
(not a paper workload; everything else about it is identical to the real
models)."""

from __future__ import annotations

from compile.models.common import Ctx, Registry, conv, fc, register
from compile import layers


@register("tinynet")
def build(width=8, num_classes=10, image=16):
    reg = Registry()
    h = w = image
    h, w = reg.conv("c1", 3, width, 3, 1, 1, h, w)
    h, w = reg.conv("c2", width, 2 * width, 3, 2, 1, h, w)
    h, w = reg.conv("c3", 2 * width, 2 * width, 3, 2, 1, h, w)
    reg.fc("fc", 2 * width, num_classes)

    def apply(state, prec, x, mode, key, training):
        ctx = Ctx(state, prec, mode, key, training)
        y = conv(ctx, "c1", x)
        y = conv(ctx, "c2", y, stride=2)
        y = conv(ctx, "c3", y, stride=2)
        y = layers.global_avg_pool(y)
        return fc(ctx, "fc", y), ctx.bn_out

    return reg.init_state, apply, reg.specs
