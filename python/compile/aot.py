"""AOT compile path: lower every {model, step} pair to HLO *text* plus a
meta.json manifest and an initial-state binary for the rust coordinator.

HLO text (NOT .serialize()) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts written to --out (default ../artifacts):

  <model>_<step>.hlo.txt   one per step in {phase1_step, phase2_step,
                           fp32_step, eval_quant, eval_fp32}
  <model>.meta.json        layer specs (for the rust codegen/simulator),
                           per-step input/output layouts (flatten order =
                           HLO parameter order), init-state tensor index
  <model>_init.bin         f32 little-endian concat of the initial state
  kernel_qmm.hlo.txt       standalone fused qmac kernel (runtime smoke)

Python runs ONLY here; the rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import train
from compile.models import build

# Model configurations compiled into artifacts. Scaled for the CPU-PJRT
# testbed (DESIGN.md substitution table); paper-scale widths are a flag away.
MODEL_CONFIGS = {
    "tinynet": dict(kw=dict(width=8, image=16), image=16, train_batch=32, eval_batch=64),
    "resnet18": dict(kw=dict(width=8), image=32, train_batch=64, eval_batch=128),
    "mobilenetv2": dict(kw=dict(width_mult=1.0), image=32, train_batch=64, eval_batch=128),
    "shufflenetv2": dict(kw=dict(width_mult=1.0), image=32, train_batch=64, eval_batch=128),
}

NUM_CLASSES = 10


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def layout_of(tree):
    """Flattened (name, shape, dtype) list in jax flatten order == the HLO
    parameter order the rust runtime must feed."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        out.append(
            dict(
                name=_path_str(path),
                shape=[int(d) for d in leaf.shape],
                dtype=str(leaf.dtype),
            )
        )
    return out


def spec_like(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


def make_prec_spec(specs):
    return {
        sp["name"]: (
            jax.ShapeDtypeStruct((sp["cin"],), jnp.float32),
            jax.ShapeDtypeStruct((sp["cin"],), jnp.float32),
        )
        for sp in specs
    }


def lower_model(name, cfg, out_dir, seed=0):
    init, apply, specs = build(name, **cfg["kw"])
    steps = train.make_steps(apply, specs, NUM_CLASSES)
    state = init(jax.random.PRNGKey(seed))
    img = cfg["image"]
    tb, eb = cfg["train_batch"], cfg["eval_batch"]

    state_spec = spec_like(state)
    prec_spec = make_prec_spec(specs)
    f32 = jnp.float32
    timg = jax.ShapeDtypeStruct((tb, img, img, 3), f32)
    eimg = jax.ShapeDtypeStruct((eb, img, img, 3), f32)
    tlbl = jax.ShapeDtypeStruct((tb,), jnp.int32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    scalar = jax.ShapeDtypeStruct((), f32)

    step_args = {
        "phase1_step": (state_spec, timg, tlbl, key, scalar, scalar),
        "phase2_step": (state_spec, prec_spec, timg, tlbl, scalar),
        "fp32_step": (state_spec, timg, tlbl, scalar),
        "eval_quant": (state_spec, prec_spec, eimg),
        "eval_fp32": (state_spec, eimg),
    }

    meta = dict(
        model=name,
        image=img,
        train_batch=tb,
        eval_batch=eb,
        num_classes=NUM_CLASSES,
        layers=specs,
        steps={},
    )

    for sname, args in step_args.items():
        lowered = jax.jit(steps[sname], keep_unused=True).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}_{sname}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_spec = jax.eval_shape(steps[sname], *args)
        meta["steps"][sname] = dict(
            hlo=os.path.basename(path),
            inputs=layout_of(args),
            outputs=layout_of(out_spec),
        )
        print(f"  {name}/{sname}: {len(text)} chars, "
              f"{len(meta['steps'][sname]['inputs'])} inputs")

    # Initial state binary (f32 concat in flatten order) + index.
    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    index, offset = [], 0
    with open(os.path.join(out_dir, f"{name}_init.bin"), "wb") as f:
        for path, leaf in leaves:
            arr = np.asarray(leaf, dtype=np.float32)
            f.write(arr.tobytes())
            index.append(
                dict(name=_path_str(path), shape=list(arr.shape), offset=offset)
            )
            offset += arr.size
    meta["init"] = dict(bin=f"{name}_init.bin", tensors=index, total_f32=offset)

    with open(os.path.join(out_dir, f"{name}.meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return meta


def lower_kernel_smoke(out_dir):
    """Standalone fused qmac kernel artifact for rust runtime unit tests."""
    from compile.kernels import qmac

    m, k, n = 32, 64, 16
    f = lambda x, w, s, q: (qmac.qmatmul(x, w, s, q),)
    args = [
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
        jax.ShapeDtypeStruct((k,), jnp.float32),
        jax.ShapeDtypeStruct((k,), jnp.float32),
    ]
    text = to_hlo_text(jax.jit(f, keep_unused=True).lower(*args))
    with open(os.path.join(out_dir, "kernel_qmm.hlo.txt"), "w") as f_:
        f_.write(text)
    print(f"  kernel_qmm: {len(text)} chars")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(MODEL_CONFIGS))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    jax.config.update("jax_platform_name", "cpu")

    lower_kernel_smoke(args.out)
    for name in args.models.split(","):
        print(f"lowering {name} ...")
        lower_model(name, MODEL_CONFIGS[name], args.out, args.seed)
    with open(os.path.join(args.out, ".stamp"), "w") as f:
        f.write("ok\n")
    print("artifacts complete")


if __name__ == "__main__":
    main()
