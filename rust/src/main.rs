//! SONIQ leader binary: the co-design CLI.
//!
//! Subcommands:
//!   train       — run one design point end to end (train -> eval -> sim)
//!   explore     — sweep design points for one or more models (Fig. 7/8)
//!   hw          — print hardware cost / timing reports (Table V, Sec. V-B)
//!   patterns    — print the 45 precision patterns (Table II) and subsets
//!   serve-bench — batched serving engine vs the legacy one-shot path
//!
//! Examples:
//!   soniq train --model tinynet --design P4 --p1-steps 60 --p2-steps 60
//!   soniq explore --models tinynet --designs FP32,U4,U2,P4
//!   soniq hw
//!   soniq serve-bench --model tinynet --design P4 --requests 1024 \
//!         --workers 4 --max-batch 16
//!   soniq serve-bench --model tinyattn --design P4   # Transformer encoder
//!   soniq serve-bench --model tinydec --decode --steps 64 --sessions 4 \
//!         # KV-cached autoregressive decode vs prefix-repack baseline
//!   soniq serve-bench --models tinynet,tinyattn,tinydec --requests 384 \
//!         # mixed multi-model traffic through ONE worker pool
//!   soniq serve-bench --model tinywide --shards 2 [--worker-budget BYTES] \
//!         # shard-aware placement: the wide layer splits across workers,
//!         # scatter/gather outputs bit-identical to the unsharded run
//!   soniq serve-bench --model tinynet --open-loop --rate 200,800 \
//!         --deadline-ms 20 --queue-depth 256 \
//!         # offered-load sweep: goodput + tail latency per rate point,
//!         # overload shed at the admission gate as typed rejections
//!   soniq serve-bench --model tinydec --decode --sessions 1000 \
//!         --kv-pages 256 --kv-policy spill \
//!         # paged KV-cache: sessions draw fixed-size pages from a
//!         # per-worker pool; over budget, pages spill to a host arena
//!         # and fault back bit-exact (or: refuse new work / evict
//!         # the coldest session); --v-bits 2 stores V low-precision

use anyhow::{bail, Result};
use soniq::coordinator::{
    print_table, run_design_point, synthetic_bpp, synthetic_inputs, synthetic_network,
    DesignPoint, TrainCfg,
};
use soniq::hw::{gates, timing};
use soniq::simd::patterns;
use soniq::util::cli::Args;

fn parse_design(s: &str) -> Result<DesignPoint> {
    Ok(match s {
        "FP32" | "fp32" => DesignPoint::Fp32,
        "INT8" | "int8" => DesignPoint::Int8,
        "U2" | "u2" => DesignPoint::Uniform(2),
        "U4" | "u4" => DesignPoint::Uniform(4),
        "P4" | "p4" => DesignPoint::Patterns(4),
        "P8" | "p8" => DesignPoint::Patterns(8),
        "P45" | "p45" => DesignPoint::Patterns(45),
        other => bail!("unknown design point {other}"),
    })
}

fn train_cfg(args: &Args) -> TrainCfg {
    TrainCfg {
        p1_steps: args.get_usize("p1-steps", 120),
        p2_steps: args.get_usize("p2-steps", 120),
        lr: args.get_f32("lr", 0.05),
        lambda: args.get_f32("lambda", 1e-7),
        eval_batches: args.get_usize("eval-batches", 4),
        seed: args.get_usize("seed", 0) as u32,
    }
}

/// Shared serve-bench output sinks: `--json` prints the versioned
/// report to stdout (see `serve::SERVE_REPORT_SCHEMA` for the current
/// schema number), `--json-out FILE` writes the same JSON to disk, and
/// `--trace FILE` writes the Chrome trace-event file (load it in
/// Perfetto or `chrome://tracing`).
fn emit_serve_outputs(
    args: &Args,
    report: &soniq::serve::ServeReport,
    server: &soniq::serve::Server,
) -> Result<()> {
    if args.has_flag("json") {
        println!("{}", report.to_json().to_string());
    }
    if let Some(path) = args.get("json-out") {
        std::fs::write(path, report.to_json().to_string() + "\n")?;
    }
    if let Some(path) = args.get("trace") {
        std::fs::write(path, server.obs().chrome_trace_json().to_string() + "\n")?;
    }
    Ok(())
}

/// Copy what a faulted shutdown lost into the report, so dead serving
/// threads show up in the bench output instead of silently shrinking
/// the completion count.
fn attach_faults(report: &mut soniq::serve::ServeReport, server: &soniq::serve::Server) {
    if let Some(f) = server.faults() {
        report.lost = f.lost.clone();
        report.partial = f.partial.clone();
    }
}

/// `serve-bench --verify`: print the static-analysis report and refuse
/// to serve on any violation. Debug builds verify unconditionally
/// inside `prepare()`; this flag extends the same proof to release
/// benches (see `soniq::analysis`).
fn gate_on_verify(report: soniq::analysis::VerifyReport) -> Result<()> {
    println!("{report}");
    if !report.is_clean() {
        bail!(
            "--verify: refusing to serve with {} violations",
            report.num_violations()
        );
    }
    Ok(())
}

/// Single-model verify report: shape-propagate the one-shot (and, for
/// decoders, step) graphs, verify every prepared kernel program, and
/// check KV page geometry when a paged pool is configured.
fn single_model_report(
    key: &soniq::serve::ModelKey,
    net: &soniq::coordinator::SyntheticNet,
    prepared: &soniq::serve::PreparedModel,
    kv: Option<&soniq::serve::KvPoolCfg>,
) -> soniq::analysis::VerifyReport {
    use soniq::analysis;
    let mut m = analysis::verify_model(&key.to_string(), prepared);
    m.plan_violations.extend(analysis::verify_graph(&net.nodes, net.input_shape));
    if let (Some(step_nodes), Some(shape)) = (net.step_nodes.as_deref(), net.step_input_shape) {
        m.plan_violations.extend(analysis::verify_graph(step_nodes, shape));
    }
    if let (Some(kc), Some(step)) = (kv, prepared.step.as_ref()) {
        m.plan_violations.extend(analysis::verify_kv(kc, &step.slot_geoms));
    }
    analysis::VerifyReport { models: vec![m] }
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let artifacts = args.get_or("artifacts", "artifacts");
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => {
            let model = args.get_or("model", "tinynet");
            let design = parse_design(&args.get_or("design", "P4"))?;
            let cfg = train_cfg(&args);
            let m = run_design_point(&artifacts, &model, design, &cfg)?;
            print_table(std::slice::from_ref(&m), None);
        }
        Some("explore") => {
            let models = args.get_or("models", "tinynet");
            let designs = args.get_or("designs", "FP32,U4,U2,P4,P8,P45");
            let cfg = train_cfg(&args);
            let mut rows = Vec::new();
            for model in models.split(',') {
                for d in designs.split(',') {
                    let dp = parse_design(d)?;
                    eprintln!("== {model} / {d} ==");
                    rows.push(run_design_point(&artifacts, model, dp, &cfg)?);
                }
            }
            print_table(&rows, Some("U4"));
        }
        Some("hw") => {
            println!("Table V — NAND2-equivalent gate counts");
            let lane = gates::lane_gates();
            println!(
                "  configurable ALU (structural): {:.0} per lane x 8 = {:.0}",
                lane.total(),
                8.0 * lane.total()
            );
            println!("  paper-reported:                2805 per lane x 8 = 22440");
            for np in [4usize, 8, 16, 45] {
                println!("  control block P{np}: {:.0}", gates::control_block_gates(np));
            }
            println!("\nSec. V-B — critical path:");
            for s in timing::CRITICAL_PATH {
                println!("  {:<12} {:>6.1} ps", s.name, s.delay_ps);
            }
            println!(
                "  total {:.1} ps; 2 GHz slack {:.1} ps (meets timing: {})",
                timing::critical_path_ps(),
                timing::slack_ps(2.0),
                timing::meets_timing(2.0, 0.05)
            );
        }
        Some("patterns") => {
            println!("Table II — all 45 precision patterns (n1, n2, n4):");
            for (i, p) in patterns::all_patterns().iter().enumerate() {
                print!("  {:>2}: ({:>3},{:>2},{:>2})", i + 1, p.n1, p.n2, p.n4);
                if (i + 1) % 5 == 0 {
                    println!();
                }
            }
            println!(
                "\nTable III subsets: P4 {:?}  P8 {:?}",
                patterns::design_subset(4)
                    .iter()
                    .map(|p| patterns::index_of(p).unwrap())
                    .collect::<Vec<_>>(),
                patterns::design_subset(8)
                    .iter()
                    .map(|p| patterns::index_of(p).unwrap())
                    .collect::<Vec<_>>()
            );
            println!(
                "arbitrary-mix ALU configurations: {:.3e} (paper ~1.12e62); grouped: {}",
                patterns::arbitrary_mix_configurations(),
                patterns::grouped_configurations()
            );
        }
        Some("serve-bench") => {
            use soniq::coordinator::{synthetic_network_seq, synthetic_step_inputs};
            use soniq::serve::{self, BatchConfig, KvPolicy, KvPoolCfg, ServeConfig, SetupTiming};
            use soniq::sim::network::{run_network, Tensor};
            use std::sync::Arc;
            use std::time::{Duration, Instant};

            let model = args.get_or("model", "tinynet");
            let design = parse_design(&args.get_or("design", "P4"))?;
            let n_requests = args.get_usize("requests", 1024).max(1);
            let workers = args.get_usize("workers", 4).max(1);
            let max_batch = args.get_usize("max-batch", 16).max(1);
            let max_delay_ms = args.get_usize("max-delay-ms", 2);
            let seed = args.get_usize("seed", 0) as u64;
            let decode = args.has_flag("decode");
            let shards = args.get_usize("shards", 0); // 0/1 = no explicit split
            let worker_budget = args.get_usize("worker-budget", 0); // bytes; 0 = unlimited
            let open_loop = args.has_flag("open-loop");
            let verify = args.has_flag("verify");
            let queue_depth = args.get_usize("queue-depth", 0); // 0 = unbounded

            // paged KV-cache: any of these flags switches sessions from
            // growable K/V buffers to fixed-size pages from a per-worker
            // pool (see serve::kvpool)
            let kv_pages = args.get_usize("kv-pages", 0); // 0 = unbounded pool
            let kv_policy = args.get_or("kv-policy", "");
            let page_positions = args.get_usize("page-positions", 0); // 0 = default
            let v_bits = args.get_usize("v-bits", 0); // 0 = same precision as K
            let kv = if kv_pages > 0
                || !kv_policy.is_empty()
                || page_positions > 0
                || v_bits > 0
            {
                let policy = match KvPolicy::parse(&kv_policy) {
                    _ if kv_policy.is_empty() => KvPolicy::Refuse,
                    Some(p) => p,
                    None => bail!(
                        "--kv-policy wants refuse, evict or spill (got `{kv_policy}`)"
                    ),
                };
                if !matches!(v_bits, 0 | 1 | 2 | 4) {
                    bail!("--v-bits wants 1, 2 or 4 (got {v_bits})");
                }
                let mut kc = KvPoolCfg::default();
                if page_positions > 0 {
                    kc.page_positions = page_positions;
                }
                kc.pages_per_worker = (kv_pages > 0).then_some(kv_pages);
                kc.policy = policy;
                kc.v_bits = (v_bits > 0).then_some(v_bits as u8);
                Some(kc)
            } else {
                None
            };

            let registry = serve::ModelRegistry::new();
            let cfg = ServeConfig {
                workers,
                batch: BatchConfig {
                    max_batch,
                    max_delay: Duration::from_millis(max_delay_ms as u64),
                },
                resident_models: args.get_usize("resident-models", usize::MAX).max(1),
                worker_budget: (worker_budget > 0).then_some(worker_budget),
                trace: args.get("trace").is_some(),
                queue_depth: (queue_depth > 0).then_some(queue_depth),
                kv,
            };

            let models_arg = args.get_or("models", "");
            if !models_arg.is_empty() {
                // --- mixed multi-model traffic through ONE worker pool ---
                if decode {
                    bail!(
                        "--decode benchmarks one decoder's sessions; it does not \
                         combine with --models (use --model tinydec --decode)"
                    );
                }
                if shards >= 2 {
                    bail!(
                        "--shards applies to a single --model deployment; it does \
                         not combine with --models"
                    );
                }
                if open_loop {
                    bail!(
                        "--open-loop drives a single --model deployment (stateless \
                         or --decode); it does not combine with --models"
                    );
                }
                let names: Vec<String> = models_arg
                    .split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect();
                if names.is_empty() {
                    bail!("--models wants a comma-separated model list");
                }
                println!(
                    "== soniq serve-bench — multi-model pool [{}] / {} ==",
                    names.join(", "),
                    design.label()
                );
                // split the requested total across models without
                // dropping the division remainder: the first
                // `n_requests % k` models take one extra request, so
                // the counts sum to exactly `n_requests`
                let k = names.len();
                let rem = n_requests % k;
                let counts: Vec<usize> =
                    (0..k).map(|mi| n_requests / k + usize::from(mi < rem)).collect();

                let mut nets = Vec::new(); // (key, net, inputs)
                for (mi, name) in names.iter().enumerate() {
                    let net = synthetic_network(name, design, seed)?;
                    let key = serve::ModelKey::new(name.clone(), design.label());
                    let inputs = synthetic_inputs(&net, counts[mi], seed + 1);
                    nets.push((key, net, inputs));
                }
                // time only preparation (codegen + packing), matching
                // what the single-model path reports as prepare_ms
                let t1 = Instant::now();
                let fleet: Vec<_> = nets
                    .into_iter()
                    .map(|(key, net, inputs)| {
                        let prepared = registry.get_or_prepare(&key, || net.prepare());
                        (key, net, prepared, inputs)
                    })
                    .collect();
                let prepare = t1.elapsed();
                println!(
                    "prepared {} models in {prepare:.2?} (registry caches them for reuse)",
                    fleet.len()
                );
                if verify {
                    // same full report as the single-model path, per
                    // model: kernels + graphs + KV geometry when a
                    // paged pool is configured
                    let mut report = soniq::analysis::VerifyReport::default();
                    for (key, net, prepared, _) in &fleet {
                        report
                            .models
                            .extend(single_model_report(key, net, prepared, cfg.kv.as_ref()).models);
                    }
                    gate_on_verify(report)?;
                }

                // dedicated single-model engines: the bit-exactness oracle
                let dedicated: Vec<Vec<Vec<f32>>> = fleet
                    .iter()
                    .map(|(_, _, prepared, inputs)| {
                        let mut engine = serve::EngineMachine::new(prepared);
                        inputs.iter().map(|x| engine.run(x).output.data.clone()).collect()
                    })
                    .collect();

                let total: usize = counts.iter().sum();
                println!(
                    "one pool, {} models interleaved ({workers} workers, max batch \
                     {max_batch}, {total} requests total):",
                    fleet.len()
                );
                let t2 = Instant::now();
                let mut server = serve::Server::start_pool(&cfg);
                for (key, _, prepared, _) in &fleet {
                    server.register(key.clone(), Arc::clone(prepared));
                }
                // round-robin submission: every batching window sees
                // every model, the worst case for bind-table churn.
                // counts differ by at most one, so the last round only
                // visits the remainder models — record each sequential
                // id's (model, request) owner instead of assuming a
                // uniform stride
                let mut owner: Vec<(usize, usize)> = Vec::with_capacity(total);
                for i in 0..counts[0] {
                    for (mi, (key, _, _, inputs)) in fleet.iter().enumerate() {
                        if i < inputs.len() {
                            server.submit_model(key, inputs[i].clone());
                            owner.push((mi, i));
                        }
                    }
                }
                let mut done = server.shutdown();
                let wall = t2.elapsed();
                done.sort_by_key(|c| c.id);
                let bind = server.bind_times().into_iter().max().unwrap_or_default();
                let snap = server.snapshot();
                let mut report =
                    serve::summarize_with(&done, wall, SetupTiming { prepare, bind }, Some(&snap));
                attach_faults(&mut report, &server);
                report.print();

                let bitexact = done.len() == total
                    && done.iter().all(|c| {
                        let (mi, ri) = owner[c.id as usize];
                        c.output.data == dedicated[mi][ri]
                    });
                println!("  outputs bit-identical to dedicated single-model engines: {bitexact}");
                emit_serve_outputs(&args, &report, &server)?;
                if !bitexact {
                    bail!("multi-model pool outputs diverged from dedicated engines");
                }
                return Ok(());
            }

            let net = synthetic_network(&model, design, seed)?;
            let key = serve::ModelKey::new(model.clone(), design.label());
            println!("== soniq serve-bench — {key} ==");

            if decode && shards >= 2 {
                bail!(
                    "--shards does not combine with --decode: sharded decoders are \
                     unsupported (KV sessions pin whole models)"
                );
            }
            if open_loop {
                // --- open-loop harness: offered load, not backlog ---
                // a fresh server per rate point takes a deterministic
                // Poisson (or bursty) arrival schedule; the driver
                // never waits for completions, so tail latency, good-
                // put under a deadline, and admission rejections are
                // measured against load the pool did not choose
                if shards >= 2 || worker_budget > 0 {
                    bail!(
                        "--open-loop does not combine with --shards/--worker-budget \
                         (sharded open-loop serving is an open roadmap item)"
                    );
                }
                let burst = args.has_flag("burst");
                let deadline_ms = args.get_f32("deadline-ms", 50.0) as f64;
                if deadline_ms <= 0.0 || deadline_ms.is_nan() {
                    bail!("--deadline-ms wants a positive latency budget");
                }
                let rates: Vec<f64> = args
                    .get_or("rate", "100,400")
                    .split(',')
                    .map(|s| s.trim().parse::<f64>())
                    .collect::<Result<_, _>>()?;
                if rates.is_empty() || rates.iter().any(|r| *r <= 0.0 || r.is_nan()) {
                    bail!("--rate wants a comma-separated list of positive req/s rates");
                }

                // drain completions while waiting out a schedule gap:
                // the driver never blocks on results (open loop), but
                // it must keep the channel empty so in-flight depth
                // reflects real backlog, not undrained finishes
                fn pump(
                    server: &mut soniq::serve::Server,
                    done: &mut Vec<soniq::serve::Completion>,
                    start: std::time::Instant,
                    off: std::time::Duration,
                ) {
                    loop {
                        done.extend(server.drain_ready());
                        let elapsed = start.elapsed();
                        if elapsed >= off {
                            return;
                        }
                        std::thread::sleep((off - elapsed).min(Duration::from_micros(200)));
                    }
                }

                let n_sessions = args.get_usize("sessions", 4).max(1);
                let steps_cap = n_requests.div_ceil(n_sessions);
                if decode {
                    if net.step_nodes.is_none() {
                        bail!("--decode needs a decoder model (try --model tinydec)");
                    }
                    if steps_cap > net.max_positions {
                        bail!(
                            "open-loop decode offers up to {steps_cap} steps/session \
                             but max_positions is {}; raise --sessions or lower \
                             --requests",
                            net.max_positions
                        );
                    }
                }
                let tokens: Vec<Vec<Tensor>> = if decode {
                    (0..n_sessions)
                        .map(|s| synthetic_step_inputs(&net, s as u64, steps_cap, seed + 1))
                        .collect()
                } else {
                    Vec::new()
                };
                let inputs =
                    if decode { Vec::new() } else { synthetic_inputs(&net, n_requests, seed + 1) };

                let t1 = Instant::now();
                let prepared = registry.get_or_prepare(&key, || net.prepare());
                let prepare = t1.elapsed();
                println!(
                    "prepared `{key}` in {prepare:.2?}; open-loop sweep: {n_requests} \
                     {} per point, deadline {deadline_ms} ms{}{}",
                    if decode { "decode-step arrivals" } else { "arrivals" },
                    if burst { ", bursty arrivals" } else { "" },
                    match cfg.queue_depth {
                        Some(d) => format!(", queue depth {d}"),
                        None => ", unbounded queue".to_string(),
                    }
                );
                if verify {
                    gate_on_verify(single_model_report(&key, &net, &prepared, cfg.kv.as_ref()))?;
                }

                let mut points: Vec<serve::OpenLoopPoint> = Vec::new();
                let mut last = None;
                for (pi, &rate) in rates.iter().enumerate() {
                    let spec = serve::ArrivalSpec {
                        rate,
                        n: n_requests,
                        burst,
                        seed: seed + pi as u64,
                    };
                    let offsets = serve::arrival_offsets(&spec);
                    let mut server =
                        serve::Server::start_named(key.clone(), Arc::clone(&prepared), &cfg);
                    let mut done: Vec<serve::Completion> = Vec::new();
                    let start = Instant::now();
                    if decode {
                        // arrivals are decode steps round-robined over
                        // a fixed session set: they land in per-session
                        // lanes mid-flight, which is exactly what
                        // iteration-level scheduling re-batches
                        // under a Refuse-policy page budget some opens
                        // shed whole sessions; load round-robins over
                        // whichever sessions were admitted
                        let sids: Vec<serve::SessionId> = (0..n_sessions)
                            .filter_map(|_| server.try_open_session().ok())
                            .collect();
                        if sids.is_empty() {
                            bail!(
                                "the page budget admitted no session at all; raise \
                                 --kv-pages or lower --page-positions"
                            );
                        }
                        let mut steps_in = vec![0usize; sids.len()];
                        for (i, off) in offsets.iter().enumerate() {
                            pump(&mut server, &mut done, start, *off);
                            let si = i % sids.len();
                            if steps_in[si] < tokens[si].len() {
                                let tok = tokens[si][steps_in[si]].clone();
                                if server.try_submit_step(sids[si], tok).is_ok() {
                                    steps_in[si] += 1;
                                }
                            }
                        }
                        for sid in &sids {
                            server.close_session(*sid);
                        }
                    } else {
                        for (i, off) in offsets.iter().enumerate() {
                            pump(&mut server, &mut done, start, *off);
                            let _ = server.try_submit(inputs[i].clone());
                        }
                    }
                    done.extend(server.shutdown());
                    let wall = start.elapsed();
                    let snap = server.snapshot();
                    let mut lat: Vec<f64> =
                        done.iter().map(|c| c.latency.as_secs_f64() * 1e3).collect();
                    lat.sort_by(|a, b| a.total_cmp(b));
                    let good = done
                        .iter()
                        .filter(|c| c.latency.as_secs_f64() * 1e3 <= deadline_ms)
                        .count();
                    let point = serve::OpenLoopPoint {
                        offered_rps: rate,
                        offered: n_requests,
                        completed: done.len(),
                        good,
                        rejected: snap.rejected,
                        deadline_ms,
                        goodput_rps: good as f64 / wall.as_secs_f64().max(1e-9),
                        p50_ms: serve::percentile(&lat, 0.50),
                        p95_ms: serve::percentile(&lat, 0.95),
                        p99_ms: serve::percentile(&lat, 0.99),
                    };
                    println!(
                        "  @ {rate:.0} req/s: completed {}/{} (good {}, rejected {}) \
                         in {wall:.2?} -> {:.1} goodput rps, p99 {:.2} ms",
                        point.completed,
                        point.offered,
                        point.good,
                        point.rejected,
                        point.goodput_rps,
                        point.p99_ms
                    );
                    points.push(point);
                    last = Some((done, wall, server));
                }

                let (done, wall, server) = last.expect("at least one rate point");
                let bind = server.bind_times().into_iter().max().unwrap_or_default();
                let snap = server.snapshot();
                let mut report =
                    serve::summarize_with(&done, wall, SetupTiming { prepare, bind }, Some(&snap));
                report.open_loop = points;
                attach_faults(&mut report, &server);
                report.print();
                emit_serve_outputs(&args, &report, &server)?;
                return Ok(());
            }
            if !decode && (shards >= 2 || worker_budget > 0) {
                // --- shard-aware placement: scatter/gather across workers ---
                let dcfg = serve::DeployConfig {
                    worker_budget: cfg.worker_budget,
                    shards: (shards >= 2).then_some(shards),
                };
                let t1 = Instant::now();
                let dep = std::sync::Arc::new(serve::Deployment::build(
                    key.clone(),
                    &net.nodes,
                    net.step_nodes.as_deref(),
                    &dcfg,
                )?);
                let prepare = t1.elapsed();
                println!("deployment plan: {}", dep.describe());
                if verify {
                    let mut models =
                        soniq::analysis::verify_deployment(&dep, &net.nodes, cfg.worker_budget);
                    models[0]
                        .plan_violations
                        .extend(soniq::analysis::verify_graph(&net.nodes, net.input_shape));
                    gate_on_verify(soniq::analysis::VerifyReport { models })?;
                }
                if worker_budget > 0 && dep.num_shards() > workers {
                    bail!(
                        "{} shards need {} workers under --worker-budget (each shard \
                         is sized for a machine of its own); raise --workers or the \
                         budget",
                        dep.num_shards(),
                        dep.num_shards()
                    );
                }

                // unsharded oracle on one budget-less machine
                let whole = registry.get_or_prepare(&key, || net.prepare());
                let mut oracle = serve::EngineMachine::new(&whole);
                let inputs = synthetic_inputs(&net, n_requests, seed + 1);
                let want: Vec<Vec<f32>> =
                    inputs.iter().map(|x| oracle.run(x).output.data.clone()).collect();

                println!(
                    "sharded serving ({} shards across {workers} workers, max batch \
                     {max_batch}):",
                    dep.num_shards()
                );
                let t2 = Instant::now();
                let mut server = serve::Server::start_deployment(Arc::clone(&dep), &cfg);
                for x in inputs.iter().cloned() {
                    server.submit(x);
                }
                let mut done = server.shutdown();
                let wall = t2.elapsed();
                done.sort_by_key(|c| c.id);
                let bind = server.bind_times().into_iter().max().unwrap_or_default();
                let snap = server.snapshot();
                let mut report =
                    serve::summarize_with(&done, wall, SetupTiming { prepare, bind }, Some(&snap));
                attach_faults(&mut report, &server);
                report.print();

                let bitexact = done.len() == inputs.len()
                    && done.iter().all(|c| c.output.data == want[c.id as usize]);
                println!(
                    "  sharded outputs bit-identical to unsharded single-machine run: \
                     {bitexact}"
                );
                emit_serve_outputs(&args, &report, &server)?;
                if !bitexact {
                    bail!("sharded outputs diverged from the unsharded run");
                }
                return Ok(());
            }

            if decode {
                // --- KV-cached autoregressive decode vs prefix repack ---
                let steps = args.get_usize("steps", 64).max(1);
                let n_sessions = args.get_usize("sessions", 4).max(1);
                if net.step_nodes.is_none() {
                    bail!("--decode needs a decoder model (try --model tinydec)");
                }
                if steps > net.max_positions {
                    bail!("--steps {steps} exceeds max_positions {}", net.max_positions);
                }
                let tokens: Vec<Vec<Tensor>> = (0..n_sessions)
                    .map(|k| synthetic_step_inputs(&net, k as u64, steps, seed + 1))
                    .collect();

                let t1 = Instant::now();
                let prepared = registry.get_or_prepare(&key, || net.prepare());
                let prepare = t1.elapsed();
                // (decoder models always cache their decoder form under
                // this key — see ModelRegistry::get_or_prepare)
                if let Some(b) = cfg.worker_budget {
                    let need = prepared.bind_bytes();
                    if need > b {
                        bail!(
                            "decoder bind needs {need} B but --worker-budget is {b} \
                             (sharded decoders are unsupported; raise the budget)"
                        );
                    }
                }
                println!(
                    "prepared decoder `{key}` in {prepare:.2?} \
                     ({} kernels; sessions cache packed K/V per step)",
                    prepared.num_layers()
                );
                if verify {
                    gate_on_verify(single_model_report(&key, &net, &prepared, cfg.kv.as_ref()))?;
                }

                println!(
                    "cached decode ({n_sessions} sessions x {steps} steps, \
                     {workers} workers, session-affine batching):"
                );
                let t2 = Instant::now();
                let mut server =
                    serve::Server::start_named(key.clone(), Arc::clone(&prepared), &cfg);
                let sids: Vec<serve::SessionId> =
                    (0..n_sessions).map(|_| server.open_session()).collect();
                for t in 0..steps {
                    for (si, sid) in sids.iter().enumerate() {
                        server.submit_step(*sid, tokens[si][t].clone());
                    }
                }
                let mut done = server.shutdown();
                let wall = t2.elapsed();
                done.sort_by_key(|c| c.id);
                let bind = server.bind_times().into_iter().max().unwrap_or_default();
                let snap = server.snapshot();
                let mut report =
                    serve::summarize_with(&done, wall, SetupTiming { prepare, bind }, Some(&snap));
                attach_faults(&mut report, &server);
                report.print();

                // prefix-repack baseline: re-run session 0's whole prefix
                // through the one-shot causal graph at every step
                println!("prefix-repack baseline (one-shot causal graph per step, 1 session):");
                let t3 = Instant::now();
                let mut baseline_cycles = 0u64;
                let mut baseline_last: Vec<Vec<f32>> = Vec::with_capacity(steps);
                for t in 0..steps {
                    let net_t = synthetic_network_seq(&model, design, seed, Some(t + 1))?;
                    let (h, w, c) = net_t.input_shape;
                    let mut data = Vec::with_capacity(w * c);
                    for tok in tokens[0].iter().take(t + 1) {
                        data.extend_from_slice(&tok.data);
                    }
                    let res = run_network(&net_t.nodes, &Tensor { h, w, c, data });
                    baseline_cycles += res.total.cycles();
                    baseline_last.push(res.output.data[t * c..(t + 1) * c].to_vec());
                }
                let baseline_wall = t3.elapsed();

                let s0: Vec<_> =
                    done.iter().filter(|c| c.session == Some(sids[0].0)).collect();
                let cached_cycles: u64 = s0.iter().map(|c| c.total.cycles()).sum();
                let bitexact = s0.len() == steps
                    && s0
                        .iter()
                        .enumerate()
                        .all(|(t, c)| c.output.data == baseline_last[t]);
                println!(
                    "  {} simulated cycles/session ({:.2?} host wall)",
                    baseline_cycles, baseline_wall
                );
                println!("  cached decode: {cached_cycles} simulated cycles/session");
                println!("  decode steps bit-identical to prefix re-runs: {bitexact}");
                println!(
                    "  cached vs prefix-repack: {:.2}x fewer simulated cycles",
                    baseline_cycles as f64 / cached_cycles.max(1) as f64
                );
                emit_serve_outputs(&args, &report, &server)?;
                return Ok(());
            }

            // --- stateless serving vs the legacy one-shot path ---
            // the legacy loop re-packs weights + re-runs codegen per call;
            // cap it separately so huge request counts stay benchable
            let legacy_n = args
                .get_usize("legacy-requests", n_requests.min(256))
                .clamp(1, n_requests);
            let inputs = synthetic_inputs(&net, n_requests, seed + 1);

            println!("legacy one-shot path ({legacy_n} requests, pack + codegen every call):");
            let t0 = Instant::now();
            let mut legacy_out = Vec::with_capacity(legacy_n);
            for x in inputs.iter().take(legacy_n) {
                legacy_out.push(run_network(&net.nodes, x).output);
            }
            let legacy_wall = t0.elapsed();
            let legacy_rps = legacy_n as f64 / legacy_wall.as_secs_f64().max(1e-9);
            println!("  {legacy_n} requests in {legacy_wall:.2?}  ->  {legacy_rps:.1} req/s");

            let t1 = Instant::now();
            // decoder models cache their decoder form even for stateless
            // serving, so one registry entry per key serves both paths
            let prepared = registry.get_or_prepare(&key, || net.prepare());
            let prepare = t1.elapsed();
            println!(
                "prepared model `{key}` in {prepare:.2?} \
                 ({} layers; registry caches it for reuse)",
                prepared.num_layers()
            );
            if let Some(bpp) = synthetic_bpp(&net) {
                println!("  weight size: {bpp:.2} bits/param (incl. pattern metadata)");
            }
            if verify {
                gate_on_verify(single_model_report(&key, &net, &prepared, cfg.kv.as_ref()))?;
            }

            println!(
                "serving engine ({workers} workers, max batch {max_batch}, \
                 deadline {max_delay_ms} ms):"
            );
            let t2 = Instant::now();
            let mut server = serve::Server::start_named(key.clone(), Arc::clone(&prepared), &cfg);
            for x in inputs.iter().cloned() {
                server.submit(x);
            }
            let mut completions = server.shutdown();
            let wall = t2.elapsed();
            completions.sort_by_key(|c| c.id);
            let bind = server.bind_times().into_iter().max().unwrap_or_default();
            let snap = server.snapshot();
            let mut report = serve::summarize_with(
                &completions,
                wall,
                SetupTiming { prepare, bind },
                Some(&snap),
            );
            attach_faults(&mut report, &server);
            report.print();

            let bitexact = completions
                .iter()
                .take(legacy_n)
                .all(|c| c.output.data == legacy_out[c.id as usize].data);
            println!("  outputs bit-identical to legacy path: {bitexact}");
            println!(
                "  serving throughput vs legacy: {:.2}x",
                report.throughput_rps / legacy_rps
            );
            emit_serve_outputs(&args, &report, &server)?;
        }
        _ => {
            eprintln!(
                "usage: soniq <train|explore|hw|patterns|serve-bench> \
                 [--model M] [--design D] [--artifacts DIR]"
            );
            eprintln!(
                "       serve-bench [--model M | --models A,B,C] [--design D] \
                 [--requests N] [--seed N] [--workers W] [--max-batch B] \
                 [--max-delay-ms MS] [--resident-models R] [--shards S] \
                 [--worker-budget BYTES] [--decode --steps N --sessions S] \
                 [--queue-depth N] [--legacy-requests N] \
                 [--kv-pages P --kv-policy refuse|evict|spill \
                 --page-positions N --v-bits B] \
                 [--open-loop --rate R1,R2 [--burst] [--deadline-ms MS]] \
                 [--verify] [--json] [--json-out FILE] [--trace FILE]"
            );
            eprintln!("       see README.md for the full CLI");
        }
    }
    Ok(())
}
