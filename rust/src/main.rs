//! SONIQ leader binary: the co-design CLI.
//!
//! Subcommands:
//!   train    — run one design point end to end (train -> eval -> sim)
//!   explore  — sweep design points for one or more models (Fig. 7/8)
//!   hw       — print hardware cost / timing reports (Table V, Sec. V-B)
//!   patterns — print the 45 precision patterns (Table II) and subsets
//!
//! Examples:
//!   soniq train --model tinynet --design P4 --p1-steps 60 --p2-steps 60
//!   soniq explore --models tinynet --designs FP32,U4,U2,P4
//!   soniq hw

use anyhow::{bail, Result};
use soniq::coordinator::{print_table, run_design_point, DesignPoint, TrainCfg};
use soniq::hw::{gates, timing};
use soniq::simd::patterns;
use soniq::util::cli::Args;

fn parse_design(s: &str) -> Result<DesignPoint> {
    Ok(match s {
        "FP32" | "fp32" => DesignPoint::Fp32,
        "INT8" | "int8" => DesignPoint::Int8,
        "U2" | "u2" => DesignPoint::Uniform(2),
        "U4" | "u4" => DesignPoint::Uniform(4),
        "P4" | "p4" => DesignPoint::Patterns(4),
        "P8" | "p8" => DesignPoint::Patterns(8),
        "P45" | "p45" => DesignPoint::Patterns(45),
        other => bail!("unknown design point {other}"),
    })
}

fn train_cfg(args: &Args) -> TrainCfg {
    TrainCfg {
        p1_steps: args.get_usize("p1-steps", 120),
        p2_steps: args.get_usize("p2-steps", 120),
        lr: args.get_f32("lr", 0.05),
        lambda: args.get_f32("lambda", 1e-7),
        eval_batches: args.get_usize("eval-batches", 4),
        seed: args.get_usize("seed", 0) as u32,
    }
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let artifacts = args.get_or("artifacts", "artifacts");
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => {
            let model = args.get_or("model", "tinynet");
            let design = parse_design(&args.get_or("design", "P4"))?;
            let cfg = train_cfg(&args);
            let m = run_design_point(&artifacts, &model, design, &cfg)?;
            print_table(std::slice::from_ref(&m), None);
        }
        Some("explore") => {
            let models = args.get_or("models", "tinynet");
            let designs = args.get_or("designs", "FP32,U4,U2,P4,P8,P45");
            let cfg = train_cfg(&args);
            let mut rows = Vec::new();
            for model in models.split(',') {
                for d in designs.split(',') {
                    let dp = parse_design(d)?;
                    eprintln!("== {model} / {d} ==");
                    rows.push(run_design_point(&artifacts, model, dp, &cfg)?);
                }
            }
            print_table(&rows, Some("U4"));
        }
        Some("hw") => {
            println!("Table V — NAND2-equivalent gate counts");
            let lane = gates::lane_gates();
            println!(
                "  configurable ALU (structural): {:.0} per lane x 8 = {:.0}",
                lane.total(),
                8.0 * lane.total()
            );
            println!("  paper-reported:                2805 per lane x 8 = 22440");
            for np in [4usize, 8, 16, 45] {
                println!("  control block P{np}: {:.0}", gates::control_block_gates(np));
            }
            println!("\nSec. V-B — critical path:");
            for s in timing::CRITICAL_PATH {
                println!("  {:<12} {:>6.1} ps", s.name, s.delay_ps);
            }
            println!(
                "  total {:.1} ps; 2 GHz slack {:.1} ps (meets timing: {})",
                timing::critical_path_ps(),
                timing::slack_ps(2.0),
                timing::meets_timing(2.0, 0.05)
            );
        }
        Some("patterns") => {
            println!("Table II — all 45 precision patterns (n1, n2, n4):");
            for (i, p) in patterns::all_patterns().iter().enumerate() {
                print!("  {:>2}: ({:>3},{:>2},{:>2})", i + 1, p.n1, p.n2, p.n4);
                if (i + 1) % 5 == 0 {
                    println!();
                }
            }
            println!(
                "\nTable III subsets: P4 {:?}  P8 {:?}",
                patterns::design_subset(4)
                    .iter()
                    .map(|p| patterns::index_of(p).unwrap())
                    .collect::<Vec<_>>(),
                patterns::design_subset(8)
                    .iter()
                    .map(|p| patterns::index_of(p).unwrap())
                    .collect::<Vec<_>>()
            );
            println!(
                "arbitrary-mix ALU configurations: {:.3e} (paper ~1.12e62); grouped: {}",
                patterns::arbitrary_mix_configurations(),
                patterns::grouped_configurations()
            );
        }
        _ => {
            eprintln!("usage: soniq <train|explore|hw|patterns> [--model M] [--design D] [--artifacts DIR]");
            eprintln!("       see README.md for the full CLI");
        }
    }
    Ok(())
}
