//! Build simulator network graphs from the meta.json layer table + the
//! trained state, mirroring the python model topologies (resnet18,
//! mobilenetv2, shufflenetv2, tinynet). Layer names are the single source
//! of truth — every lookup fails loudly if the table diverges.

use crate::codegen::{DataFormat, LayerKind, LayerPlan};
use crate::runtime::{ModelMeta, StateStore};
use crate::sim::network::{ConvLayerCfg, Node, INPUT};
use crate::smol::pattern_match::Assignment;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

/// Per-layer precision assignments for a design point.
pub type AsgMap = HashMap<String, Assignment>;

/// Build one conv/fc layer's simulator config.
fn conv_cfg(
    meta: &ModelMeta,
    state: &StateStore,
    asg: &AsgMap,
    fmt: DataFormat,
    name: &str,
    relu: bool,
) -> Result<ConvLayerCfg> {
    let spec = meta.layer(name).ok_or_else(|| anyhow!("layer {name} not in meta"))?;
    let kind = if spec.groups > 1 {
        if spec.groups != spec.cin {
            bail!("{name}: grouped (non-depthwise) convs not used by these models");
        }
        LayerKind::Depthwise
    } else {
        LayerKind::Dense
    };
    let weights = state.get(&format!("params.{name}"))?.as_f32()?.to_vec();
    let assignment = asg
        .get(name)
        .cloned()
        .unwrap_or_else(|| Assignment::uniform(spec.cin, 4));
    let plan = LayerPlan {
        name: name.to_string(),
        kind,
        cin: spec.cin,
        cout: spec.cout,
        kh: spec.k,
        kw: spec.k,
        stride: spec.stride,
        hin: spec.hin,
        win: spec.win,
        asg: assignment,
        fmt,
    };
    let (bn_scale, bn_bias, bn_mean, bn_var) = if spec.op == "conv" {
        (
            state.get(&format!("params.{name}/bn_scale"))?.as_f32()?.to_vec(),
            state.get(&format!("params.{name}/bn_bias"))?.as_f32()?.to_vec(),
            state.get(&format!("bn.{name}/mean"))?.as_f32()?.to_vec(),
            state.get(&format!("bn.{name}/var"))?.as_f32()?.to_vec(),
        )
    } else {
        (vec![], vec![], vec![], vec![])
    };
    Ok(ConvLayerCfg { plan, weights, bn_scale, bn_bias, bn_mean, bn_var, relu })
}

/// Build the simulator graph for a model (mirrors python apply()).
pub fn build_graph(
    meta: &ModelMeta,
    state: &StateStore,
    asg: &AsgMap,
    fmt: DataFormat,
) -> Result<Vec<Node>> {
    let has = |name: &str| meta.layer(name).is_some();
    let mut nodes: Vec<Node> = Vec::new();
    let mut conv = |nodes: &mut Vec<Node>, name: &str, relu: bool, input: usize| -> Result<usize> {
        let cfg = conv_cfg(meta, state, asg, fmt, name, relu)?;
        nodes.push(Node::Conv { cfg: Box::new(cfg), input });
        Ok(nodes.len() - 1)
    };

    match meta.model.as_str() {
        "tinynet" => {
            let c1 = conv(&mut nodes, "c1", true, INPUT)?;
            let c2 = conv(&mut nodes, "c2", true, c1)?;
            let c3 = conv(&mut nodes, "c3", true, c2)?;
            nodes.push(Node::Gap { x: c3 });
            let gap = nodes.len() - 1;
            conv(&mut nodes, "fc", false, gap)?;
        }
        "resnet18" => {
            let mut y = conv(&mut nodes, "stem", true, INPUT)?;
            for si in 0..4 {
                for bi in 0..8 {
                    let base = format!("s{si}b{bi}");
                    if !has(&format!("{base}/c1")) {
                        break;
                    }
                    let z1 = conv(&mut nodes, &format!("{base}/c1"), true, y)?;
                    let z2 = conv(&mut nodes, &format!("{base}/c2"), false, z1)?;
                    let sc = if has(&format!("{base}/sc")) {
                        conv(&mut nodes, &format!("{base}/sc"), false, y)?
                    } else {
                        y
                    };
                    nodes.push(Node::Add { a: z2, b: sc, relu: true });
                    y = nodes.len() - 1;
                }
            }
            nodes.push(Node::Gap { x: y });
            let gap = nodes.len() - 1;
            conv(&mut nodes, "fc", false, gap)?;
        }
        "mobilenetv2" => {
            let mut y = conv(&mut nodes, "stem", true, INPUT)?;
            for gi in 0..8 {
                for bi in 0..8 {
                    let base = format!("g{gi}b{bi}");
                    if !has(&format!("{base}/dw")) {
                        break;
                    }
                    let inp = y;
                    let mut cur = y;
                    if has(&format!("{base}/exp")) {
                        cur = conv(&mut nodes, &format!("{base}/exp"), true, cur)?;
                    }
                    cur = conv(&mut nodes, &format!("{base}/dw"), true, cur)?;
                    cur = conv(&mut nodes, &format!("{base}/proj"), false, cur)?;
                    let dw = meta.layer(&format!("{base}/dw")).unwrap();
                    let proj = meta.layer(&format!("{base}/proj")).unwrap();
                    let block_cin = meta
                        .layer(&format!("{base}/exp"))
                        .map(|e| e.cin)
                        .unwrap_or(dw.cin);
                    if dw.stride == 1 && block_cin == proj.cout {
                        nodes.push(Node::Add { a: cur, b: inp, relu: false });
                        cur = nodes.len() - 1;
                    }
                    y = cur;
                }
            }
            y = conv(&mut nodes, "head", true, y)?;
            nodes.push(Node::Gap { x: y });
            let gap = nodes.len() - 1;
            conv(&mut nodes, "fc", false, gap)?;
        }
        "shufflenetv2" => {
            let mut y = conv(&mut nodes, "stem", true, INPUT)?;
            for si in 0..4 {
                for bi in 0..8 {
                    let base = format!("s{si}b{bi}");
                    let down = has(&format!("{base}/l_dw"));
                    if !down && !has(&format!("{base}/r_pw1")) {
                        break;
                    }
                    if down {
                        let l1 = conv(&mut nodes, &format!("{base}/l_dw"), false, y)?;
                        let l2 = conv(&mut nodes, &format!("{base}/l_pw"), true, l1)?;
                        let r1 = conv(&mut nodes, &format!("{base}/r_pw1"), true, y)?;
                        let r2 = conv(&mut nodes, &format!("{base}/r_dw"), false, r1)?;
                        let r3 = conv(&mut nodes, &format!("{base}/r_pw2"), true, r2)?;
                        nodes.push(Node::ConcatC { a: l2, b: r3 });
                    } else {
                        let cin = meta.layer(&format!("{base}/r_pw1")).unwrap().cin;
                        nodes.push(Node::SliceC { x: y, from: 0, to: cin });
                        let left = nodes.len() - 1;
                        nodes.push(Node::SliceC { x: y, from: cin, to: 2 * cin });
                        let right0 = nodes.len() - 1;
                        let r1 = conv(&mut nodes, &format!("{base}/r_pw1"), true, right0)?;
                        let r2 = conv(&mut nodes, &format!("{base}/r_dw"), false, r1)?;
                        let r3 = conv(&mut nodes, &format!("{base}/r_pw2"), true, r2)?;
                        nodes.push(Node::ConcatC { a: left, b: r3 });
                    }
                    nodes.push(Node::ShuffleC { x: nodes.len() - 1, groups: 2 });
                    y = nodes.len() - 1;
                }
            }
            y = conv(&mut nodes, "head", true, y)?;
            nodes.push(Node::Gap { x: y });
            let gap = nodes.len() - 1;
            conv(&mut nodes, "fc", false, gap)?;
        }
        other => bail!("no graph builder for model {other}"),
    }
    Ok(nodes)
}
