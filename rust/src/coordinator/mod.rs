//! The co-design coordinator (Fig. 1 / Fig. 5): for each design point it
//! runs the full loop — SASMOL training through the PJRT artifacts,
//! Problem-1 pattern selection + pattern matching, channel rearrangement,
//! code generation, timing/energy simulation, and hardware cost — and
//! aggregates the paper's four design metrics (hardware cost, run-time /
//! energy efficiency, network accuracy, network size).

pub mod netbuild;
pub mod paperscale;

use crate::codegen::DataFormat;
use crate::data::Dataset;
use crate::hw::gates;
use crate::runtime::Runtime;
use crate::sim::network::{run_network, Tensor};
use crate::sim::RunStats;
use crate::simd::patterns::design_subset;
use crate::smol::pattern_match::{pattern_match, Assignment};
use crate::smol::stats::{network_bpp, per_layer_bpp, LayerShape};
use crate::train::{lr_schedule, uniform_prec, PrecMap, Trainer};
use anyhow::Result;
use std::collections::HashMap;

/// A hardware/software design point (paper Sec. V).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DesignPoint {
    /// full-precision baseline
    Fp32,
    /// INT8 baseline (Key Finding 1; run-time/energy only)
    Int8,
    /// uniform fixed-point ALUs
    Uniform(u8),
    /// configurable ALU with np supported patterns (4, 8 or 45)
    Patterns(usize),
}

impl DesignPoint {
    pub fn label(&self) -> String {
        match self {
            DesignPoint::Fp32 => "FP32".into(),
            DesignPoint::Int8 => "INT8".into(),
            DesignPoint::Uniform(p) => format!("U{p}"),
            DesignPoint::Patterns(np) => format!("P{np}"),
        }
    }

    pub fn fmt(&self) -> DataFormat {
        match self {
            DesignPoint::Fp32 => DataFormat::Fp32,
            DesignPoint::Int8 => DataFormat::Int8,
            _ => DataFormat::Smol,
        }
    }
}

/// Training schedule for one design point.
#[derive(Debug, Clone, Copy)]
pub struct TrainCfg {
    /// phase-I steps (precision search; P-points only)
    pub p1_steps: usize,
    /// phase-II / QAT / fp32 steps
    pub p2_steps: usize,
    pub lr: f32,
    /// regularizer weight (paper: 1e-7 CIFAR, 4e-8 ImageNet)
    pub lambda: f32,
    pub eval_batches: usize,
    pub seed: u32,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg { p1_steps: 120, p2_steps: 120, lr: 0.05, lambda: 1e-7, eval_batches: 4, seed: 0 }
    }
}

/// The paper's design metrics for one {model, design point}.
#[derive(Debug, Clone)]
pub struct Metrics {
    pub model: String,
    pub design: String,
    pub accuracy: f32,
    /// bits per parameter incl. pattern metadata (NaN for FP32/INT8)
    pub bpp: f64,
    /// simulated cycles for one inference (batch 1)
    pub cycles: u64,
    pub energy_pj: f64,
    /// per-layer average bits (Fig. 9)
    pub layer_bpp: Vec<(String, f64)>,
    /// per-layer simulated cycles
    pub layer_cycles: Vec<(String, u64)>,
    /// ALU + control block NAND2-equivalent gates
    pub hw_gates: f64,
    /// training loss trace
    pub loss_history: Vec<f32>,
    pub sim_total: RunStats,
    /// per-layer (name, fraction of 4-bit channels, fraction of 2-bit
    /// channels) — consumed by the paper-scale Fig. 8 timing harness
    pub layer_fractions: Vec<(String, f64, f64)>,
}

/// Run the complete co-design pipeline for one design point.
pub fn run_design_point(
    artifacts: &str,
    model: &str,
    dp: DesignPoint,
    cfg: &TrainCfg,
) -> Result<Metrics> {
    let steps_needed: Vec<&str> = match dp {
        DesignPoint::Fp32 => vec!["fp32_step", "eval_fp32"],
        DesignPoint::Int8 => vec!["eval_fp32"],
        DesignPoint::Uniform(_) => vec!["phase2_step", "eval_quant"],
        DesignPoint::Patterns(_) => vec!["phase1_step", "phase2_step", "eval_quant"],
    };
    let rt = Runtime::load(artifacts, model, Some(&steps_needed))?;
    let dataset = Dataset::new(rt.meta.image, rt.meta.num_classes, 0);
    let mut trainer = Trainer::new(&rt, &dataset)?;
    trainer.seed = cfg.seed;

    // --- training + precision assignment ---
    let (assignments, prec): (HashMap<String, Assignment>, Option<PrecMap>) = match dp {
        DesignPoint::Fp32 | DesignPoint::Int8 => {
            if dp == DesignPoint::Fp32 {
                for i in 0..cfg.p2_steps {
                    let lr = lr_schedule(i, cfg.p2_steps, cfg.lr);
                    trainer.fp32_step(i, lr)?;
                }
            }
            let asg = rt
                .meta
                .layers
                .iter()
                .map(|l| (l.name.clone(), Assignment::uniform(l.cin, 4)))
                .collect();
            (asg, None)
        }
        DesignPoint::Uniform(bits) => {
            let prec = uniform_prec(&rt.meta.layers, bits);
            for i in 0..cfg.p2_steps {
                let lr = lr_schedule(i, cfg.p2_steps, cfg.lr);
                trainer.phase2_step(i, &prec, lr)?;
            }
            let asg = rt
                .meta
                .layers
                .iter()
                .map(|l| (l.name.clone(), Assignment::uniform(l.cin, bits)))
                .collect();
            (asg, Some(prec))
        }
        DesignPoint::Patterns(np) => {
            // Phase I: noise-injected precision search
            for i in 0..cfg.p1_steps {
                let lr = lr_schedule(i, cfg.p1_steps, cfg.lr);
                trainer.phase1_step(i, lr, cfg.lambda)?;
            }
            // Pattern selection (Problem 1) + PatternMatch per layer
            let supported = design_subset(np);
            let s_vecs = trainer.state.s_vectors();
            let mut asg = HashMap::new();
            let mut prec = PrecMap::new();
            for layer in &rt.meta.layers {
                let s = s_vecs
                    .get(&layer.name)
                    .unwrap_or_else(|| panic!("s vector for {} missing", layer.name));
                let a = pattern_match(s, &supported);
                let (step_v, qmax_v) = a.step_qmax();
                prec.insert(layer.name.clone(), (step_v, qmax_v));
                asg.insert(layer.name.clone(), a);
            }
            // Phase II: fine-tune under the matched precisions
            for i in 0..cfg.p2_steps {
                let lr = lr_schedule(i, cfg.p2_steps, cfg.lr);
                trainer.phase2_step(cfg.p1_steps + i, &prec, lr)?;
            }
            (asg, Some(prec))
        }
    };

    // --- accuracy ---
    let accuracy = match dp {
        DesignPoint::Int8 => f32::NAN, // paper cites external INT8 results
        _ => trainer.eval(prec.as_ref(), cfg.eval_batches)?,
    };

    // --- network size (bpp) ---
    let shapes: Vec<(LayerShape, Assignment)> = rt
        .meta
        .layers
        .iter()
        .map(|l| {
            let elems = if l.groups > 1 {
                l.k * l.k
            } else if l.op == "fc" {
                l.cout
            } else {
                l.cout * l.k * l.k
            };
            (
                LayerShape { name: l.name.clone(), cin: l.cin, elems_per_channel: elems },
                assignments[&l.name].clone(),
            )
        })
        .collect();
    let bpp = match dp {
        DesignPoint::Fp32 => 32.0,
        DesignPoint::Int8 => 8.0,
        _ => network_bpp(&shapes),
    };

    // --- run-time / energy (timing simulation, batch-1 inference) ---
    let graph = netbuild::build_graph(&rt.meta, &trainer.state, &assignments, dp.fmt())?;
    let img = rt.meta.image;
    let sample = dataset.batch(2, 0, 1);
    let input = Tensor { h: img, w: img, c: 3, data: sample.images };
    let net = run_network(&graph, &input);

    // --- hardware cost ---
    let hw_gates = match dp {
        DesignPoint::Fp32 | DesignPoint::Int8 => 0.0, // existing SIMD datapath
        DesignPoint::Uniform(_) => gates::alu_gates() / 3.0, // fixed-precision subset
        DesignPoint::Patterns(np) => gates::alu_gates() + gates::control_block_gates(np),
    };

    let layer_fractions = rt
        .meta
        .layers
        .iter()
        .map(|l| {
            let a = &assignments[&l.name];
            let n = a.precision.len().max(1) as f64;
            let f4 = a.precision.iter().filter(|&&p| p == 4).count() as f64 / n;
            let f2 = a.precision.iter().filter(|&&p| p == 2).count() as f64 / n;
            (l.name.clone(), f4, f2)
        })
        .collect();

    Ok(Metrics {
        model: model.to_string(),
        design: dp.label(),
        accuracy,
        bpp,
        cycles: net.total.cycles(),
        energy_pj: net.total.energy_pj,
        layer_bpp: per_layer_bpp(&shapes),
        layer_cycles: net.layers.iter().map(|l| (l.name.clone(), l.stats.cycles())).collect(),
        hw_gates,
        loss_history: trainer.history.iter().map(|h| h.loss).collect(),
        sim_total: net.total,
        layer_fractions,
    })
}

/// Paper-scale run-time simulation (the Fig. 8 run-time axis): time the
/// full-width shape table of `model` under a design point, mapping the
/// trained scaled-model per-layer precision fractions onto the full-width
/// layers by relative depth. Returns (total stats, per-layer cycles).
pub fn simulate_paper_scale(
    model: &str,
    dp: DesignPoint,
    trained_fractions: &[(String, f64, f64)],
) -> (RunStats, Vec<(String, u64)>) {
    use crate::codegen::{LayerKind, LayerPlan};
    use crate::sim::machine::Machine;
    use crate::sim::network::{run_conv, ConvLayerCfg, Tensor};

    let shapes = paperscale::shapes_for(model);
    let supported: Vec<crate::simd::patterns::Pattern> = match dp {
        DesignPoint::Patterns(np) => design_subset(np),
        _ => design_subset(45),
    };
    let mut machine = Machine::new();
    let mut total = RunStats::default();
    let mut per_layer = Vec::new();
    for (li, shp) in shapes.iter().enumerate() {
        let asg = match dp {
            DesignPoint::Uniform(b) => Assignment::uniform(shp.cin, b),
            DesignPoint::Fp32 | DesignPoint::Int8 => Assignment::uniform(shp.cin, 4),
            DesignPoint::Patterns(_) => {
                // nearest-depth mapping of trained fractions
                let n = trained_fractions.len().max(1);
                let j = (li * n) / shapes.len().max(1);
                let (_, f4, f2) = &trained_fractions[j.min(n - 1)];
                paperscale::assignment_from_fractions(shp.cin, *f4, *f2, &supported)
            }
        };
        let kind = if shp.groups > 1 { LayerKind::Depthwise } else { LayerKind::Dense };
        let nw = match kind {
            LayerKind::Dense => shp.k * shp.k * shp.cin * shp.cout,
            LayerKind::Depthwise => shp.k * shp.k * shp.cin,
        };
        let cfg = ConvLayerCfg {
            plan: LayerPlan {
                name: shp.name.clone(),
                kind,
                cin: shp.cin,
                cout: shp.cout,
                kh: shp.k,
                kw: shp.k,
                stride: shp.stride,
                hin: shp.hin,
                win: shp.win,
                asg,
                fmt: dp.fmt(),
            },
            weights: vec![0.5; nw],
            bn_scale: vec![],
            bn_bias: vec![],
            bn_mean: vec![],
            bn_var: vec![],
            relu: false,
        };
        let x = Tensor::zeros(shp.hin, shp.win, shp.cin);
        let (_, stats) = run_conv(&mut machine, &cfg, &x);
        per_layer.push((shp.name.clone(), stats.cycles()));
        total.merge(&stats);
        // cap simulator memory growth across many layers
        if machine.buffers.len() > 64 {
            machine = Machine::new();
        }
    }
    (total, per_layer)
}

/// Pretty-print a metrics table (paper Fig. 7/8 style rows).
pub fn print_table(rows: &[Metrics], baseline: Option<&str>) {
    let base_cycles: HashMap<&str, u64> = rows
        .iter()
        .filter(|m| Some(m.design.as_str()) == baseline)
        .map(|m| (m.model.as_str(), m.cycles))
        .collect();
    println!(
        "{:<14} {:<6} {:>9} {:>7} {:>14} {:>9} {:>13} {:>10}",
        "model", "design", "accuracy", "bpp", "cycles", "speedup", "energy(uJ)", "gates"
    );
    for m in rows {
        let speedup = base_cycles
            .get(m.model.as_str())
            .map(|&b| b as f64 / m.cycles as f64)
            .unwrap_or(f64::NAN);
        println!(
            "{:<14} {:<6} {:>9.4} {:>7.2} {:>14} {:>9.2} {:>13.1} {:>10.0}",
            m.model,
            m.design,
            m.accuracy,
            m.bpp,
            m.cycles,
            speedup,
            m.energy_pj / 1e6,
            m.hw_gates
        );
    }
}
