//! The co-design coordinator (Fig. 1 / Fig. 5): for each design point it
//! runs the full loop — SASMOL training through the PJRT artifacts,
//! Problem-1 pattern selection + pattern matching, channel rearrangement,
//! code generation, timing/energy simulation, and hardware cost — and
//! aggregates the paper's four design metrics (hardware cost, run-time /
//! energy efficiency, network accuracy, network size).

pub mod netbuild;
pub mod paperscale;

use crate::codegen::DataFormat;
use crate::data::Dataset;
use crate::hw::gates;
use crate::runtime::Runtime;
use crate::sim::network::{run_network, Tensor};
use crate::sim::RunStats;
use crate::simd::patterns::design_subset;
use crate::smol::pattern_match::{pattern_match, Assignment};
use crate::smol::stats::{network_bpp, per_layer_bpp, LayerShape};
use crate::train::{lr_schedule, uniform_prec, PrecMap, Trainer};
use anyhow::Result;
use std::collections::HashMap;

/// A hardware/software design point (paper Sec. V).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DesignPoint {
    /// full-precision baseline
    Fp32,
    /// INT8 baseline (Key Finding 1; run-time/energy only)
    Int8,
    /// uniform fixed-point ALUs
    Uniform(u8),
    /// configurable ALU with np supported patterns (4, 8 or 45)
    Patterns(usize),
}

impl DesignPoint {
    pub fn label(&self) -> String {
        match self {
            DesignPoint::Fp32 => "FP32".into(),
            DesignPoint::Int8 => "INT8".into(),
            DesignPoint::Uniform(p) => format!("U{p}"),
            DesignPoint::Patterns(np) => format!("P{np}"),
        }
    }

    pub fn fmt(&self) -> DataFormat {
        match self {
            DesignPoint::Fp32 => DataFormat::Fp32,
            DesignPoint::Int8 => DataFormat::Int8,
            _ => DataFormat::Smol,
        }
    }
}

/// Training schedule for one design point.
#[derive(Debug, Clone, Copy)]
pub struct TrainCfg {
    /// phase-I steps (precision search; P-points only)
    pub p1_steps: usize,
    /// phase-II / QAT / fp32 steps
    pub p2_steps: usize,
    pub lr: f32,
    /// regularizer weight (paper: 1e-7 CIFAR, 4e-8 ImageNet)
    pub lambda: f32,
    pub eval_batches: usize,
    pub seed: u32,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg { p1_steps: 120, p2_steps: 120, lr: 0.05, lambda: 1e-7, eval_batches: 4, seed: 0 }
    }
}

/// The paper's design metrics for one {model, design point}.
#[derive(Debug, Clone)]
pub struct Metrics {
    pub model: String,
    pub design: String,
    pub accuracy: f32,
    /// bits per parameter incl. pattern metadata (NaN for FP32/INT8)
    pub bpp: f64,
    /// simulated cycles for one inference (batch 1)
    pub cycles: u64,
    pub energy_pj: f64,
    /// per-layer average bits (Fig. 9)
    pub layer_bpp: Vec<(String, f64)>,
    /// per-layer simulated cycles
    pub layer_cycles: Vec<(String, u64)>,
    /// ALU + control block NAND2-equivalent gates
    pub hw_gates: f64,
    /// training loss trace
    pub loss_history: Vec<f32>,
    pub sim_total: RunStats,
    /// per-layer (name, fraction of 4-bit channels, fraction of 2-bit
    /// channels) — consumed by the paper-scale Fig. 8 timing harness
    pub layer_fractions: Vec<(String, f64, f64)>,
}

/// Run the complete co-design pipeline for one design point.
pub fn run_design_point(
    artifacts: &str,
    model: &str,
    dp: DesignPoint,
    cfg: &TrainCfg,
) -> Result<Metrics> {
    let steps_needed: Vec<&str> = match dp {
        DesignPoint::Fp32 => vec!["fp32_step", "eval_fp32"],
        DesignPoint::Int8 => vec!["eval_fp32"],
        DesignPoint::Uniform(_) => vec!["phase2_step", "eval_quant"],
        DesignPoint::Patterns(_) => vec!["phase1_step", "phase2_step", "eval_quant"],
    };
    let rt = Runtime::load(artifacts, model, Some(&steps_needed))?;
    let dataset = Dataset::new(rt.meta.image, rt.meta.num_classes, 0);
    let mut trainer = Trainer::new(&rt, &dataset)?;
    trainer.seed = cfg.seed;

    // --- training + precision assignment ---
    let (assignments, prec): (HashMap<String, Assignment>, Option<PrecMap>) = match dp {
        DesignPoint::Fp32 | DesignPoint::Int8 => {
            if dp == DesignPoint::Fp32 {
                for i in 0..cfg.p2_steps {
                    let lr = lr_schedule(i, cfg.p2_steps, cfg.lr);
                    trainer.fp32_step(i, lr)?;
                }
            }
            let asg = rt
                .meta
                .layers
                .iter()
                .map(|l| (l.name.clone(), Assignment::uniform(l.cin, 4)))
                .collect();
            (asg, None)
        }
        DesignPoint::Uniform(bits) => {
            let prec = uniform_prec(&rt.meta.layers, bits);
            for i in 0..cfg.p2_steps {
                let lr = lr_schedule(i, cfg.p2_steps, cfg.lr);
                trainer.phase2_step(i, &prec, lr)?;
            }
            let asg = rt
                .meta
                .layers
                .iter()
                .map(|l| (l.name.clone(), Assignment::uniform(l.cin, bits)))
                .collect();
            (asg, Some(prec))
        }
        DesignPoint::Patterns(np) => {
            // Phase I: noise-injected precision search
            for i in 0..cfg.p1_steps {
                let lr = lr_schedule(i, cfg.p1_steps, cfg.lr);
                trainer.phase1_step(i, lr, cfg.lambda)?;
            }
            // Pattern selection (Problem 1) + PatternMatch per layer
            let supported = design_subset(np);
            let s_vecs = trainer.state.s_vectors();
            let mut asg = HashMap::new();
            let mut prec = PrecMap::new();
            for layer in &rt.meta.layers {
                let s = s_vecs
                    .get(&layer.name)
                    .unwrap_or_else(|| panic!("s vector for {} missing", layer.name));
                let a = pattern_match(s, &supported);
                let (step_v, qmax_v) = a.step_qmax();
                prec.insert(layer.name.clone(), (step_v, qmax_v));
                asg.insert(layer.name.clone(), a);
            }
            // Phase II: fine-tune under the matched precisions
            for i in 0..cfg.p2_steps {
                let lr = lr_schedule(i, cfg.p2_steps, cfg.lr);
                trainer.phase2_step(cfg.p1_steps + i, &prec, lr)?;
            }
            (asg, Some(prec))
        }
    };

    // --- accuracy ---
    let accuracy = match dp {
        DesignPoint::Int8 => f32::NAN, // paper cites external INT8 results
        _ => trainer.eval(prec.as_ref(), cfg.eval_batches)?,
    };

    // --- network size (bpp) ---
    let shapes: Vec<(LayerShape, Assignment)> = rt
        .meta
        .layers
        .iter()
        .map(|l| {
            let elems = if l.groups > 1 {
                l.k * l.k
            } else if l.op == "fc" {
                l.cout
            } else {
                l.cout * l.k * l.k
            };
            (
                LayerShape { name: l.name.clone(), cin: l.cin, elems_per_channel: elems },
                assignments[&l.name].clone(),
            )
        })
        .collect();
    let bpp = match dp {
        DesignPoint::Fp32 => 32.0,
        DesignPoint::Int8 => 8.0,
        _ => network_bpp(&shapes),
    };

    // --- run-time / energy (timing simulation, batch-1 inference) ---
    let graph = netbuild::build_graph(&rt.meta, &trainer.state, &assignments, dp.fmt())?;
    let img = rt.meta.image;
    let sample = dataset.batch(2, 0, 1);
    let input = Tensor { h: img, w: img, c: 3, data: sample.images };
    let net = run_network(&graph, &input);

    // --- hardware cost ---
    let hw_gates = match dp {
        DesignPoint::Fp32 | DesignPoint::Int8 => 0.0, // existing SIMD datapath
        DesignPoint::Uniform(_) => gates::alu_gates() / 3.0, // fixed-precision subset
        DesignPoint::Patterns(np) => gates::alu_gates() + gates::control_block_gates(np),
    };

    let layer_fractions = rt
        .meta
        .layers
        .iter()
        .map(|l| {
            let a = &assignments[&l.name];
            let n = a.precision.len().max(1) as f64;
            let f4 = a.precision.iter().filter(|&&p| p == 4).count() as f64 / n;
            let f2 = a.precision.iter().filter(|&&p| p == 2).count() as f64 / n;
            (l.name.clone(), f4, f2)
        })
        .collect();

    Ok(Metrics {
        model: model.to_string(),
        design: dp.label(),
        accuracy,
        bpp,
        cycles: net.total.cycles(),
        energy_pj: net.total.energy_pj,
        layer_bpp: per_layer_bpp(&shapes),
        layer_cycles: net.layers.iter().map(|l| (l.name.clone(), l.stats.cycles())).collect(),
        hw_gates,
        loss_history: trainer.history.iter().map(|h| h.loss).collect(),
        sim_total: net.total,
        layer_fractions,
    })
}

/// Paper-scale run-time simulation (the Fig. 8 run-time axis): time the
/// full-width shape table of `model` under a design point, mapping the
/// trained scaled-model per-layer precision fractions onto the full-width
/// layers by relative depth. Returns (total stats, per-layer cycles).
pub fn simulate_paper_scale(
    model: &str,
    dp: DesignPoint,
    trained_fractions: &[(String, f64, f64)],
) -> (RunStats, Vec<(String, u64)>) {
    use crate::codegen::{LayerKind, LayerPlan};
    use crate::sim::machine::Machine;
    use crate::sim::network::{run_conv, ConvLayerCfg, Tensor};

    let shapes = paperscale::shapes_for(model);
    let supported: Vec<crate::simd::patterns::Pattern> = match dp {
        DesignPoint::Patterns(np) => design_subset(np),
        _ => design_subset(45),
    };
    let mut machine = Machine::new();
    let mut total = RunStats::default();
    let mut per_layer = Vec::new();
    for (li, shp) in shapes.iter().enumerate() {
        let asg = match dp {
            DesignPoint::Uniform(b) => Assignment::uniform(shp.cin, b),
            DesignPoint::Fp32 | DesignPoint::Int8 => Assignment::uniform(shp.cin, 4),
            DesignPoint::Patterns(_) => {
                // nearest-depth mapping of trained fractions
                let n = trained_fractions.len().max(1);
                let j = (li * n) / shapes.len().max(1);
                let (_, f4, f2) = &trained_fractions[j.min(n - 1)];
                paperscale::assignment_from_fractions(shp.cin, *f4, *f2, &supported)
            }
        };
        let kind = if shp.groups > 1 { LayerKind::Depthwise } else { LayerKind::Dense };
        let nw = match kind {
            LayerKind::Dense => shp.k * shp.k * shp.cin * shp.cout,
            LayerKind::Depthwise => shp.k * shp.k * shp.cin,
        };
        let cfg = ConvLayerCfg {
            plan: LayerPlan {
                name: shp.name.clone(),
                kind,
                cin: shp.cin,
                cout: shp.cout,
                kh: shp.k,
                kw: shp.k,
                stride: shp.stride,
                hin: shp.hin,
                win: shp.win,
                asg,
                fmt: dp.fmt(),
            },
            weights: vec![0.5; nw],
            bn_scale: vec![],
            bn_bias: vec![],
            bn_mean: vec![],
            bn_var: vec![],
            relu: false,
        };
        let x = Tensor::zeros(shp.hin, shp.win, shp.cin);
        let (_, stats) = run_conv(&mut machine, &cfg, &x);
        per_layer.push((shp.name.clone(), stats.cycles()));
        total.merge(&stats);
        // cap simulator memory growth across many layers
        if machine.buffers.len() > 64 {
            machine = Machine::new();
        }
    }
    (total, per_layer)
}

/// A synthetic, artifact-free network (serving benchmarks / tests).
#[derive(Debug, Clone)]
pub struct SyntheticNet {
    pub nodes: Vec<crate::sim::network::Node>,
    /// network input shape `(h, w, c)`; image models use `(img, img, 3)`,
    /// sequence models `(1, seq_len, d_model)`
    pub input_shape: (usize, usize, usize),
    pub num_classes: usize,
    /// decoder models: the per-token decode step graph over the same
    /// weights as `nodes` (whose attention is then causal); prepare both
    /// via `serve::PreparedModel::prepare_decoder`
    pub step_nodes: Option<Vec<crate::sim::network::Node>>,
    /// decode step input shape (`(1, 1, d_model)`)
    pub step_input_shape: Option<(usize, usize, usize)>,
    /// decoder models: KV caches / decode buffers are sized for this
    /// many positions (0 for encoders)
    pub max_positions: usize,
}

impl SyntheticNet {
    /// Prepare this graph for serving: the decoder form (full + step
    /// graph) whenever the model has one, the plain form otherwise —
    /// the single dispatch the CLI, benches and tests must agree on (a
    /// step-less `prepare()` cached for a decoder would panic a later
    /// `open_session`).
    pub fn prepare(&self) -> crate::serve::PreparedModel {
        match &self.step_nodes {
            Some(sn) => crate::serve::PreparedModel::prepare_decoder(&self.nodes, sn),
            None => crate::serve::PreparedModel::prepare(&self.nodes),
        }
    }
}

/// Build a small deterministic network for a design point without any
/// trained artifacts: weights/BN come from a seeded xorshift stream and
/// P-point precision assignments run PatternMatch on synthetic
/// per-channel sensitivities (DESIGN.md). Used by `soniq serve-bench`,
/// the serving integration tests and `benches/serving.rs`, where the
/// PJRT training pipeline is unavailable or unnecessary.
///
/// Models: `tinynet` (3 dense convs + GAP + FC, the netbuild topology),
/// `tinydw` (dense stem + depthwise + pointwise + GAP + FC, to exercise
/// the two-cycle multiply path), `tinywide` (stem + a 1x1 conv whose
/// `cout` dwarfs every other layer + GAP + plain FC contracting that
/// axis — the shard-aware deployment workload: its middle layer is
/// built to exceed a budgeted worker machine, and the stem/wide/GAP/FC
/// chain is exactly the replicate -> cout-split -> channel-aligned ->
/// reduce shape `serve::Deployment` shards), `tinyattn` (a 2-block
/// pre-LN Transformer encoder: static Q/K/V/out/FFN projections on the
/// GEMM emitter plus dynamic-operand QK^T and A·V,
/// softmax/layernorm/GELU epilogues) and `tinydec` (the causal
/// *decoder* twin of `tinyattn`, with a per-token decode step graph for
/// KV-cached serving — see [`synthetic_decoder`]).
pub fn synthetic_network(model: &str, dp: DesignPoint, seed: u64) -> Result<SyntheticNet> {
    synthetic_network_seq(model, dp, seed, None)
}

/// [`synthetic_network`] with an explicit sequence length for the
/// sequence models (`tinyattn`, `tinydec`); `None` keeps the default
/// (8). For `tinydec` the rng stream does not depend on the length, so
/// the same `(dp, seed)` at two lengths is the identical model over a
/// shorter or longer sequence — the decode tests compare cached steps
/// against one-shot prefix runs this way. (`tinyattn` carries no such
/// contract: its A·V node draws per-*position* sensitivities under
/// P-points, so its stream shifts with the length.)
pub fn synthetic_network_seq(
    model: &str,
    dp: DesignPoint,
    seed: u64,
    seq_len: Option<usize>,
) -> Result<SyntheticNet> {
    use crate::codegen::gemm::GemmPlan;
    use crate::codegen::{LayerKind, LayerPlan};
    use crate::sim::network::{ConvLayerCfg, MatmulCfg, Node, INPUT};
    use crate::util::rng::Rng;
    use anyhow::bail;

    let fmt = dp.fmt();
    let mut rng = Rng::new(0x5049_4e4f ^ seed);

    let assign = |rng: &mut Rng, cin: usize| -> Assignment {
        match dp {
            DesignPoint::Fp32 | DesignPoint::Int8 => Assignment::uniform(cin, 4),
            DesignPoint::Uniform(b) => Assignment::uniform(cin, b),
            DesignPoint::Patterns(np) => {
                let s: Vec<f32> = (0..cin).map(|_| rng.range(-3.0, 6.0)).collect();
                pattern_match(&s, &design_subset(np))
            }
        }
    };

    #[allow(clippy::too_many_arguments)]
    fn conv(
        rng: &mut Rng,
        asg: Assignment,
        fmt: DataFormat,
        name: &str,
        kind: LayerKind,
        cin: usize,
        cout: usize,
        k: usize,
        stride: usize,
        hw: usize,
        bn: bool,
        relu: bool,
    ) -> ConvLayerCfg {
        let nw = match kind {
            LayerKind::Dense => k * k * cin * cout,
            LayerKind::Depthwise => k * k * cin,
        };
        let weights: Vec<f32> = (0..nw).map(|_| rng.range(-1.2, 1.2)).collect();
        let bn_ch = match kind {
            LayerKind::Dense => cout,
            LayerKind::Depthwise => cin,
        };
        let (bn_scale, bn_bias, bn_mean, bn_var) = if bn {
            (
                (0..bn_ch).map(|_| rng.range(0.6, 1.4)).collect(),
                (0..bn_ch).map(|_| rng.range(-0.3, 0.3)).collect(),
                (0..bn_ch).map(|_| rng.range(-0.5, 0.5)).collect(),
                (0..bn_ch).map(|_| rng.range(0.4, 1.6)).collect(),
            )
        } else {
            (vec![], vec![], vec![], vec![])
        };
        ConvLayerCfg {
            plan: LayerPlan {
                name: name.into(),
                kind,
                cin,
                cout,
                kh: k,
                kw: k,
                stride,
                hin: hw,
                win: hw,
                asg,
                fmt,
            },
            weights,
            bn_scale,
            bn_bias,
            bn_mean,
            bn_var,
            relu,
        }
    }

    /// Static-operand GEMM node (`X · W`) with seeded weights.
    #[allow(clippy::too_many_arguments)]
    fn matmul(
        rng: &mut Rng,
        asg: Assignment,
        fmt: DataFormat,
        name: &str,
        m: usize,
        k: usize,
        n: usize,
        input: usize,
    ) -> Node {
        let weights: Vec<f32> = (0..k * n).map(|_| rng.range(-0.8, 0.8)).collect();
        Node::Matmul {
            cfg: Box::new(MatmulCfg {
                plan: GemmPlan { name: name.into(), m, k, n, asg, fmt },
                scale: 1.0,
                causal: false,
            }),
            weights,
            input,
        }
    }

    /// Dynamic-operand GEMM node (both sides are node outputs).
    #[allow(clippy::too_many_arguments)]
    fn matmul_dyn(
        asg: Assignment,
        fmt: DataFormat,
        name: &str,
        m: usize,
        k: usize,
        n: usize,
        scale: f32,
        a: usize,
        b: usize,
        transpose_b: bool,
    ) -> Node {
        Node::MatmulDyn {
            cfg: Box::new(MatmulCfg {
                plan: GemmPlan { name: name.into(), m, k, n, asg, fmt },
                scale,
                causal: false,
            }),
            a,
            b,
            transpose_b,
        }
    }

    let mut input_shape = (8usize, 8usize, 3usize);
    let num_classes = 10usize;
    let mut nodes: Vec<Node> = Vec::new();
    match model {
        "tinynet" => {
            let a = assign(&mut rng, 3);
            let c1 = conv(&mut rng, a, fmt, "c1", LayerKind::Dense, 3, 16, 3, 1, 8, true, true);
            nodes.push(Node::Conv { cfg: Box::new(c1), input: INPUT });
            let a = assign(&mut rng, 16);
            let c2 = conv(&mut rng, a, fmt, "c2", LayerKind::Dense, 16, 32, 3, 2, 8, true, true);
            nodes.push(Node::Conv { cfg: Box::new(c2), input: 0 });
            let a = assign(&mut rng, 32);
            let c3 = conv(&mut rng, a, fmt, "c3", LayerKind::Dense, 32, 32, 3, 1, 4, true, true);
            nodes.push(Node::Conv { cfg: Box::new(c3), input: 1 });
            nodes.push(Node::Gap { x: 2 });
            let a = assign(&mut rng, 32);
            let fc = conv(
                &mut rng, a, fmt, "fc", LayerKind::Dense, 32, num_classes, 1, 1, 1, false, false,
            );
            nodes.push(Node::Conv { cfg: Box::new(fc), input: 3 });
        }
        "tinydw" => {
            let a = assign(&mut rng, 3);
            let c1 = conv(&mut rng, a, fmt, "c1", LayerKind::Dense, 3, 24, 3, 1, 8, true, true);
            nodes.push(Node::Conv { cfg: Box::new(c1), input: INPUT });
            let a = assign(&mut rng, 24);
            let dw = conv(
                &mut rng, a, fmt, "dw", LayerKind::Depthwise, 24, 24, 3, 1, 8, true, true,
            );
            nodes.push(Node::Conv { cfg: Box::new(dw), input: 0 });
            let a = assign(&mut rng, 24);
            let pw = conv(&mut rng, a, fmt, "pw", LayerKind::Dense, 24, 32, 1, 1, 8, true, true);
            nodes.push(Node::Conv { cfg: Box::new(pw), input: 1 });
            nodes.push(Node::Gap { x: 2 });
            let a = assign(&mut rng, 32);
            let fc = conv(
                &mut rng, a, fmt, "fc", LayerKind::Dense, 32, num_classes, 1, 1, 1, false, false,
            );
            nodes.push(Node::Conv { cfg: Box::new(fc), input: 3 });
        }
        "tinywide" => {
            // the sharded-serving workload: `wide`'s bind footprint
            // (dominated by its 4x4 x 512-channel accumulator buffer)
            // exceeds any reasonable single-machine budget for this
            // model family, and the graph is the canonical shardable
            // chain — stem (replicated per shard), wide (cout-split),
            // GAP (channel-aligned, runs in sliced space), fc (plain:
            // no BN/ReLU, so per-shard partial sums reduce exactly)
            let a = assign(&mut rng, 3);
            let c1 = conv(&mut rng, a, fmt, "c1", LayerKind::Dense, 3, 16, 3, 2, 8, true, true);
            nodes.push(Node::Conv { cfg: Box::new(c1), input: INPUT });
            let a = assign(&mut rng, 16);
            let wide =
                conv(&mut rng, a, fmt, "wide", LayerKind::Dense, 16, 512, 1, 1, 4, true, true);
            nodes.push(Node::Conv { cfg: Box::new(wide), input: 0 });
            nodes.push(Node::Gap { x: 1 });
            let a = assign(&mut rng, 512);
            let fc = conv(
                &mut rng, a, fmt, "fc", LayerKind::Dense, 512, num_classes, 1, 1, 1, false, false,
            );
            nodes.push(Node::Conv { cfg: Box::new(fc), input: 2 });
        }
        "tinyattn" => {
            // 2-block pre-LN Transformer encoder over (1, s, d) sequence
            // tensors. Q/K/V/out/FFN projections are static GEMMs
            // (prepare-once packed weights); QK^T and A·V are dynamic-
            // operand GEMMs whose "weight" side is packed per request.
            let (s, d, heads, ffn) = (seq_len.unwrap_or(8), 16usize, 2usize, 32usize);
            let dh = d / heads;
            let mut x = INPUT;
            for blk in 0..2 {
                let nm = |op: &str| format!("b{blk}/{op}");
                let ln_params = |rng: &mut Rng| -> (Vec<f32>, Vec<f32>) {
                    (
                        (0..d).map(|_| rng.range(0.7, 1.3)).collect(),
                        (0..d).map(|_| rng.range(-0.2, 0.2)).collect(),
                    )
                };
                let (gamma, beta) = ln_params(&mut rng);
                nodes.push(Node::LayerNorm { x, gamma, beta });
                let ln1 = nodes.len() - 1;
                let a = assign(&mut rng, d);
                nodes.push(matmul(&mut rng, a, fmt, &nm("wq"), s, d, d, ln1));
                let q = nodes.len() - 1;
                let a = assign(&mut rng, d);
                nodes.push(matmul(&mut rng, a, fmt, &nm("wk"), s, d, d, ln1));
                let k = nodes.len() - 1;
                let a = assign(&mut rng, d);
                nodes.push(matmul(&mut rng, a, fmt, &nm("wv"), s, d, d, ln1));
                let v = nodes.len() - 1;
                nodes.push(Node::SplitHeads { x: q, heads });
                let qh = nodes.len() - 1;
                nodes.push(Node::SplitHeads { x: k, heads });
                let kh = nodes.len() - 1;
                nodes.push(Node::SplitHeads { x: v, heads });
                let vh = nodes.len() - 1;
                let a = assign(&mut rng, dh);
                let scale = 1.0 / (dh as f32).sqrt();
                nodes.push(matmul_dyn(a, fmt, &nm("qk"), s, dh, s, scale, qh, kh, true));
                nodes.push(Node::Softmax { x: nodes.len() - 1 });
                let attn = nodes.len() - 1;
                let a = assign(&mut rng, s);
                nodes.push(matmul_dyn(a, fmt, &nm("av"), s, s, dh, 1.0, attn, vh, false));
                nodes.push(Node::MergeHeads { x: nodes.len() - 1 });
                let merged = nodes.len() - 1;
                let a = assign(&mut rng, d);
                nodes.push(matmul(&mut rng, a, fmt, &nm("wo"), s, d, d, merged));
                nodes.push(Node::Add { a: nodes.len() - 1, b: x, relu: false });
                let res1 = nodes.len() - 1;
                let (gamma, beta) = ln_params(&mut rng);
                nodes.push(Node::LayerNorm { x: res1, gamma, beta });
                let ln2 = nodes.len() - 1;
                let a = assign(&mut rng, d);
                nodes.push(matmul(&mut rng, a, fmt, &nm("ff1"), s, d, ffn, ln2));
                nodes.push(Node::Gelu { x: nodes.len() - 1 });
                let gelu = nodes.len() - 1;
                let a = assign(&mut rng, ffn);
                nodes.push(matmul(&mut rng, a, fmt, &nm("ff2"), s, ffn, d, gelu));
                nodes.push(Node::Add { a: nodes.len() - 1, b: res1, relu: false });
                x = nodes.len() - 1;
            }
            input_shape = (1, s, d);
        }
        "tinydec" => {
            let cfg = DecoderCfg { seq: seq_len.unwrap_or(8), ..DecoderCfg::default() };
            return synthetic_decoder(dp, seed, &cfg);
        }
        other => {
            bail!(
                "no synthetic topology for model {other} \
                 (try tinynet, tinydw, tinywide, tinyattn or tinydec)"
            )
        }
    }
    Ok(SyntheticNet {
        nodes,
        input_shape,
        num_classes,
        step_nodes: None,
        step_input_shape: None,
        max_positions: 0,
    })
}

/// Shape of a synthetic decoder ([`synthetic_decoder`]).
#[derive(Debug, Clone, Copy)]
pub struct DecoderCfg {
    /// prefill / one-shot sequence length (the step graph is length-free)
    pub seq: usize,
    pub d_model: usize,
    pub heads: usize,
    pub ffn: usize,
    pub blocks: usize,
    /// session KV caches and decode buffers are sized for this many
    /// positions
    pub max_positions: usize,
}

impl Default for DecoderCfg {
    fn default() -> Self {
        DecoderCfg { seq: 8, d_model: 16, heads: 2, ffn: 32, blocks: 2, max_positions: 128 }
    }
}

/// Build a pre-LN *decoder* as twin graphs over one weight draw: a full
/// causal (prefill / one-shot) graph at `cfg.seq` positions — causal
/// QK^T scores, softmax, causal A·V — and the per-token decode step
/// graph whose attention is the fused KV-cached [`Node::CachedAttn`]
/// (`Node` = [`crate::sim::network::Node`]). The rng stream does not
/// depend on `cfg.seq`, so rebuilding at another length yields the
/// identical model; each cached decode step is bit-identical to running
/// its full prefix through the one-shot graph.
pub fn synthetic_decoder(dp: DesignPoint, seed: u64, cfg: &DecoderCfg) -> Result<SyntheticNet> {
    use crate::codegen::gemm::GemmPlan;
    use crate::sim::network::{AttnCfg, MatmulCfg, Node, INPUT};
    use crate::util::rng::Rng;
    use anyhow::bail;

    let fmt = dp.fmt();
    if fmt != DataFormat::Smol {
        bail!("tinydec decode needs a quantized (SMOL) design point, got {}", dp.label());
    }
    let (s, d, heads, ffn) = (cfg.seq, cfg.d_model, cfg.heads, cfg.ffn);
    assert!((1..=cfg.max_positions).contains(&s), "seq {s} out of [1, max_positions]");
    assert_eq!(d % heads, 0, "d_model not divisible by heads");
    let dh = d / heads;
    // positions stream in one at a time, so the position (context
    // contraction) axis carries a uniform precision: the design point's
    // own width for U-points, 4 bits otherwise
    let pos_prec: u8 = match dp {
        DesignPoint::Uniform(b) => b,
        _ => 4,
    };
    let scale = 1.0 / (dh as f32).sqrt();
    let mut rng = Rng::new(0x4445_434f ^ seed);

    let assign = |rng: &mut Rng, cin: usize| -> Assignment {
        match dp {
            DesignPoint::Fp32 | DesignPoint::Int8 => Assignment::uniform(cin, 4),
            DesignPoint::Uniform(b) => Assignment::uniform(cin, b),
            DesignPoint::Patterns(np) => {
                let sv: Vec<f32> = (0..cin).map(|_| rng.range(-3.0, 6.0)).collect();
                pattern_match(&sv, &design_subset(np))
            }
        }
    };

    /// Static projection GEMM node over pre-drawn weights.
    #[allow(clippy::too_many_arguments)]
    fn proj(
        name: &str,
        m: usize,
        k: usize,
        n: usize,
        asg: Assignment,
        weights: Vec<f32>,
        input: usize,
        fmt: DataFormat,
    ) -> Node {
        Node::Matmul {
            cfg: Box::new(MatmulCfg {
                plan: GemmPlan { name: name.into(), m, k, n, asg, fmt },
                scale: 1.0,
                causal: false,
            }),
            weights,
            input,
        }
    }

    let mut full: Vec<Node> = Vec::new();
    let mut step: Vec<Node> = Vec::new();
    let (mut xf, mut xs) = (INPUT, INPUT);
    for blk in 0..cfg.blocks {
        let nm = |op: &str| format!("b{blk}/{op}");
        let gamma: Vec<f32> = (0..d).map(|_| rng.range(0.7, 1.3)).collect();
        let beta: Vec<f32> = (0..d).map(|_| rng.range(-0.2, 0.2)).collect();
        full.push(Node::LayerNorm { x: xf, gamma: gamma.clone(), beta: beta.clone() });
        step.push(Node::LayerNorm { x: xs, gamma, beta });
        let (ln1f, ln1s) = (full.len() - 1, step.len() - 1);

        // q/k/v projections + head split, same weights in both graphs
        let mut qkv_f = [0usize; 3];
        let mut qkv_s = [0usize; 3];
        for (pi, pname) in ["wq", "wk", "wv"].iter().enumerate() {
            let a = assign(&mut rng, d);
            let w: Vec<f32> = (0..d * d).map(|_| rng.range(-0.8, 0.8)).collect();
            full.push(proj(&nm(pname), s, d, d, a.clone(), w.clone(), ln1f, fmt));
            step.push(proj(&nm(pname), 1, d, d, a, w, ln1s, fmt));
            full.push(Node::SplitHeads { x: full.len() - 1, heads });
            step.push(Node::SplitHeads { x: step.len() - 1, heads });
            qkv_f[pi] = full.len() - 1;
            qkv_s[pi] = step.len() - 1;
        }

        let qk_asg = assign(&mut rng, dh);
        // full graph: causal scores -> softmax -> causal A·V
        full.push(Node::MatmulDyn {
            cfg: Box::new(MatmulCfg {
                plan: GemmPlan { name: nm("qk"), m: s, k: dh, n: s, asg: qk_asg.clone(), fmt },
                scale,
                causal: true,
            }),
            a: qkv_f[0],
            b: qkv_f[1],
            transpose_b: true,
        });
        full.push(Node::Softmax { x: full.len() - 1 });
        full.push(Node::MatmulDyn {
            cfg: Box::new(MatmulCfg {
                plan: GemmPlan {
                    name: nm("av"),
                    m: s,
                    k: s,
                    n: dh,
                    asg: Assignment::uniform(s, pos_prec),
                    fmt,
                },
                scale: 1.0,
                causal: true,
            }),
            a: full.len() - 1,
            b: qkv_f[2],
            transpose_b: false,
        });
        // step graph: the fused KV-cached attention over the same
        // precisions (qk_asg on the dh axis, uniform on positions)
        step.push(Node::CachedAttn {
            cfg: Box::new(AttnCfg {
                name: nm("attn"),
                heads,
                dh,
                scale,
                pos_prec,
                dh_asg: qk_asg,
                max_positions: cfg.max_positions,
                fmt,
            }),
            q: qkv_s[0],
            k: qkv_s[1],
            v: qkv_s[2],
        });
        full.push(Node::MergeHeads { x: full.len() - 1 });
        step.push(Node::MergeHeads { x: step.len() - 1 });

        let a = assign(&mut rng, d);
        let w: Vec<f32> = (0..d * d).map(|_| rng.range(-0.8, 0.8)).collect();
        full.push(proj(&nm("wo"), s, d, d, a.clone(), w.clone(), full.len() - 1, fmt));
        step.push(proj(&nm("wo"), 1, d, d, a, w, step.len() - 1, fmt));
        full.push(Node::Add { a: full.len() - 1, b: xf, relu: false });
        step.push(Node::Add { a: step.len() - 1, b: xs, relu: false });
        let (res1f, res1s) = (full.len() - 1, step.len() - 1);

        let gamma2: Vec<f32> = (0..d).map(|_| rng.range(0.7, 1.3)).collect();
        let beta2: Vec<f32> = (0..d).map(|_| rng.range(-0.2, 0.2)).collect();
        full.push(Node::LayerNorm { x: res1f, gamma: gamma2.clone(), beta: beta2.clone() });
        step.push(Node::LayerNorm { x: res1s, gamma: gamma2, beta: beta2 });

        let a = assign(&mut rng, d);
        let w: Vec<f32> = (0..d * ffn).map(|_| rng.range(-0.8, 0.8)).collect();
        full.push(proj(&nm("ff1"), s, d, ffn, a.clone(), w.clone(), full.len() - 1, fmt));
        step.push(proj(&nm("ff1"), 1, d, ffn, a, w, step.len() - 1, fmt));
        full.push(Node::Gelu { x: full.len() - 1 });
        step.push(Node::Gelu { x: step.len() - 1 });

        let a = assign(&mut rng, ffn);
        let w: Vec<f32> = (0..ffn * d).map(|_| rng.range(-0.8, 0.8)).collect();
        full.push(proj(&nm("ff2"), s, ffn, d, a.clone(), w.clone(), full.len() - 1, fmt));
        step.push(proj(&nm("ff2"), 1, ffn, d, a, w, step.len() - 1, fmt));
        full.push(Node::Add { a: full.len() - 1, b: res1f, relu: false });
        step.push(Node::Add { a: step.len() - 1, b: res1s, relu: false });
        xf = full.len() - 1;
        xs = step.len() - 1;
    }

    Ok(SyntheticNet {
        nodes: full,
        input_shape: (1, s, d),
        num_classes: d,
        step_nodes: Some(step),
        step_input_shape: Some((1, 1, d)),
        max_positions: cfg.max_positions,
    })
}

/// Deterministic decode-step token tensors (`(1, 1, d_model)`) for a
/// decoder model; stream `k` is independent of the others, so one
/// session's tokens can be replayed as a one-shot prefix.
pub fn synthetic_step_inputs(net: &SyntheticNet, k: u64, n: usize, seed: u64) -> Vec<Tensor> {
    use crate::util::rng::Rng;
    let (h, w, c) = net.step_input_shape.expect("not a decoder model");
    let mut rng = Rng::new(seed.wrapping_mul(0x9e37_79b9).wrapping_add(k.wrapping_mul(2) + 3));
    (0..n)
        .map(|_| {
            let data: Vec<f32> = (0..h * w * c).map(|_| rng.range(-2.0, 2.0)).collect();
            Tensor { h, w, c, data }
        })
        .collect()
}

/// Weight bits-per-parameter of a synthetic network, including pattern
/// metadata: conv/FC layers count like the coordinator metric and static
/// GEMM ("linear") layers count `k x n` weights over the `k` precision
/// axis. Dynamic-operand GEMMs store no weights and are skipped. `None`
/// for baseline (non-SMOL) formats, whose bpp is fixed (32/8).
pub fn synthetic_bpp(net: &SyntheticNet) -> Option<f64> {
    use crate::codegen::LayerKind;
    use crate::sim::network::Node;
    use crate::smol::stats::LayerShape;

    let mut shapes: Vec<(LayerShape, Assignment)> = Vec::new();
    for node in &net.nodes {
        match node {
            Node::Conv { cfg, .. } => {
                if cfg.plan.fmt != DataFormat::Smol {
                    return None;
                }
                let elems = match cfg.plan.kind {
                    LayerKind::Dense => cfg.plan.cout * cfg.plan.kh * cfg.plan.kw,
                    LayerKind::Depthwise => cfg.plan.kh * cfg.plan.kw,
                };
                shapes.push((
                    LayerShape {
                        name: cfg.plan.name.clone(),
                        cin: cfg.plan.cin,
                        elems_per_channel: elems,
                    },
                    cfg.plan.asg.clone(),
                ));
            }
            Node::Matmul { cfg, .. } => {
                if cfg.plan.fmt != DataFormat::Smol {
                    return None;
                }
                shapes.push((
                    LayerShape::linear(&cfg.plan.name, cfg.plan.k, cfg.plan.n),
                    cfg.plan.asg.clone(),
                ));
            }
            _ => {}
        }
    }
    if shapes.is_empty() {
        None
    } else {
        Some(crate::smol::stats::network_bpp(&shapes))
    }
}

/// Deterministic request inputs matching a synthetic network's shape.
pub fn synthetic_inputs(net: &SyntheticNet, n: usize, seed: u64) -> Vec<Tensor> {
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed.wrapping_mul(0x9e37_79b9).wrapping_add(1));
    let (h, w, c) = net.input_shape;
    (0..n)
        .map(|_| {
            let data: Vec<f32> = (0..h * w * c).map(|_| rng.range(-2.0, 2.0)).collect();
            Tensor { h, w, c, data }
        })
        .collect()
}

/// Pretty-print a metrics table (paper Fig. 7/8 style rows).
pub fn print_table(rows: &[Metrics], baseline: Option<&str>) {
    let base_cycles: HashMap<&str, u64> = rows
        .iter()
        .filter(|m| Some(m.design.as_str()) == baseline)
        .map(|m| (m.model.as_str(), m.cycles))
        .collect();
    println!(
        "{:<14} {:<6} {:>9} {:>7} {:>14} {:>9} {:>13} {:>10}",
        "model", "design", "accuracy", "bpp", "cycles", "speedup", "energy(uJ)", "gates"
    );
    for m in rows {
        let speedup = base_cycles
            .get(m.model.as_str())
            .map(|&b| b as f64 / m.cycles as f64)
            .unwrap_or(f64::NAN);
        println!(
            "{:<14} {:<6} {:>9.4} {:>7.2} {:>14} {:>9.2} {:>13.1} {:>10.0}",
            m.model,
            m.design,
            m.accuracy,
            m.bpp,
            m.cycles,
            speedup,
            m.energy_pj / 1e6,
            m.hw_gates
        );
    }
}
