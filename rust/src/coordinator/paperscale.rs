//! Paper-scale layer shape tables for the run-time axis of Fig. 8.
//!
//! The trained models on this testbed are width-scaled (DESIGN.md), which
//! caps their channel counts at 8-96 — too narrow to exercise the
//! vectorization win the paper measures on full-width networks. Run-time
//! simulation needs only layer *shapes* and a precision distribution, so
//! the Fig. 8 harness times the full-width CIFAR-scale shape tables below
//! while taking accuracy/bpp from the trained scaled models, mapping each
//! trained layer's precision *fractions* onto the full-width layer.

use crate::simd::patterns::Pattern;
use crate::smol::pattern_match::Assignment;
use crate::smol::problem1::{solve, Demand};

/// A layer shape for timing: (name, cin, cout, k, stride, groups, hin, win).
#[derive(Debug, Clone)]
pub struct Shape {
    pub name: String,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub groups: usize,
    pub hin: usize,
    pub win: usize,
}

fn sh(name: &str, cin: usize, cout: usize, k: usize, stride: usize, groups: usize, hin: usize) -> Shape {
    Shape { name: name.into(), cin, cout, k, stride, groups, hin, win: hin }
}

/// ResNet-18 (CIFAR-10 variant, full width 64..512).
pub fn resnet18_shapes() -> Vec<Shape> {
    let mut v = vec![sh("stem", 3, 64, 3, 1, 1, 32)];
    let stages = [(64usize, 1usize, 32usize), (128, 2, 32), (256, 2, 16), (512, 2, 8)];
    let mut cin = 64;
    for (si, &(c, st, hin)) in stages.iter().enumerate() {
        for bi in 0..2 {
            let s0 = if bi == 0 { st } else { 1 };
            let h = if bi == 0 { hin } else { hin.div_ceil(st) };
            v.push(sh(&format!("s{si}b{bi}/c1"), cin, c, 3, s0, 1, h));
            v.push(sh(&format!("s{si}b{bi}/c2"), c, c, 3, 1, 1, h.div_ceil(s0)));
            if s0 != 1 || cin != c {
                v.push(sh(&format!("s{si}b{bi}/sc"), cin, c, 1, s0, 1, h));
            }
            cin = c;
        }
    }
    v.push(sh("fc", 512, 10, 1, 1, 1, 1));
    v
}

/// MobileNetV2 (CIFAR-scale, full width).
pub fn mobilenetv2_shapes() -> Vec<Shape> {
    let mut v = vec![sh("stem", 3, 32, 3, 1, 1, 32)];
    // (t, c, n, s) from the paper's table, CIFAR strides
    let cfg = [(1usize, 16usize, 1usize, 1usize), (6, 24, 2, 1), (6, 32, 3, 2), (6, 64, 4, 2), (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)];
    let mut cin = 32;
    let mut hin = 32usize;
    for (gi, &(t, c, n, s)) in cfg.iter().enumerate() {
        for bi in 0..n {
            let st = if bi == 0 { s } else { 1 };
            let hidden = cin * t;
            let base = format!("g{gi}b{bi}");
            if t != 1 {
                v.push(sh(&format!("{base}/exp"), cin, hidden, 1, 1, 1, hin));
            }
            v.push(sh(&format!("{base}/dw"), hidden, hidden, 3, st, hidden, hin));
            hin = hin.div_ceil(st);
            v.push(sh(&format!("{base}/proj"), hidden, c, 1, 1, 1, hin));
            cin = c;
        }
    }
    v.push(sh("head", cin, 1280, 1, 1, 1, hin));
    v.push(sh("fc", 1280, 10, 1, 1, 1, 1));
    v
}

/// ShuffleNetV2 1x (CIFAR-scale, full width).
pub fn shufflenetv2_shapes() -> Vec<Shape> {
    let mut v = vec![sh("stem", 3, 24, 3, 1, 1, 32)];
    let stages = [(116usize, 4usize, 32usize), (232, 8, 16), (464, 4, 8)];
    let mut cin = 24;
    for (si, &(c, n, hin)) in stages.iter().enumerate() {
        for bi in 0..n {
            let base = format!("s{si}b{bi}");
            if bi == 0 {
                let half = c / 2;
                v.push(sh(&format!("{base}/l_dw"), cin, cin, 3, 2, cin, hin));
                v.push(sh(&format!("{base}/l_pw"), cin, half, 1, 1, 1, hin / 2));
                v.push(sh(&format!("{base}/r_pw1"), cin, half, 1, 1, 1, hin));
                v.push(sh(&format!("{base}/r_dw"), half, half, 3, 2, half, hin));
                v.push(sh(&format!("{base}/r_pw2"), half, half, 1, 1, 1, hin / 2));
                cin = c;
            } else {
                let half = cin / 2;
                let h = hin / 2;
                v.push(sh(&format!("{base}/r_pw1"), half, half, 1, 1, 1, h));
                v.push(sh(&format!("{base}/r_dw"), half, half, 3, 1, half, h));
                v.push(sh(&format!("{base}/r_pw2"), half, half, 1, 1, 1, h));
            }
        }
    }
    v.push(sh("head", cin, 1024, 1, 1, 1, 4));
    v.push(sh("fc", 1024, 10, 1, 1, 1, 1));
    v
}

pub fn shapes_for(model: &str) -> Vec<Shape> {
    match model {
        "resnet18" => resnet18_shapes(),
        "mobilenetv2" => mobilenetv2_shapes(),
        "shufflenetv2" => shufflenetv2_shapes(),
        other => panic!("no paper-scale shapes for {other}"),
    }
}

/// Build an Assignment for `channels` channels from precision *fractions*
/// (f4, f2; the rest is 1-bit), via Problem 1 under the supported set.
/// Channel importance is taken as the identity order — for timing only.
pub fn assignment_from_fractions(
    channels: usize,
    f4: f64,
    f2: f64,
    supported: &[Pattern],
) -> Assignment {
    let n4 = ((channels as f64) * f4).round() as u32;
    let n2 = (((channels as f64) * f2).round() as u32).min(channels as u32 - n4);
    let n1 = channels as u32 - n4 - n2;
    let comb = solve(&Demand { n1, n2, n4 }, supported).expect("non-empty pattern set");
    // rank: first n4 channels 4-bit, next n2 2-bit, rest 1-bit; then lay
    // out into the combination's chunks exactly as pattern_match does.
    let (s4, s2) = (comb.slots(4) as usize, comb.slots(2) as usize);
    let mut precision = vec![0u8; channels];
    for (i, p) in precision.iter_mut().enumerate() {
        *p = if i < s4 {
            4
        } else if i < s4 + s2 {
            2
        } else {
            1
        };
    }
    let mut order = Vec::with_capacity(channels);
    let mut valid = Vec::with_capacity(comb.chunks.len());
    let mut next = [0usize, s4, s4 + s2]; // next channel per pool
    for pat in &comb.chunks {
        let mut v = 0u32;
        for (pool, want, limit) in
            [(0usize, pat.n4, s4), (1, pat.n2, s4 + s2), (2, pat.n1, channels)]
        {
            for _ in 0..want {
                if next[pool] < limit && next[pool] < channels {
                    order.push(next[pool] as u32);
                    next[pool] += 1;
                    v += 1;
                }
            }
        }
        valid.push(v);
    }
    Assignment { chunks: comb.chunks, valid, precision, order }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::patterns::all_patterns;

    #[test]
    fn shape_tables_consistent() {
        for model in ["resnet18", "mobilenetv2", "shufflenetv2"] {
            let shapes = shapes_for(model);
            assert!(shapes.len() > 10, "{model}");
            for s in &shapes {
                assert!(s.cin > 0 && s.cout > 0 && s.hin > 0, "{model}/{}", s.name);
                if s.groups > 1 {
                    assert_eq!(s.groups, s.cin, "{model}/{}", s.name);
                }
            }
        }
    }

    #[test]
    fn fraction_assignment_covers_all_channels() {
        let a = assignment_from_fractions(116, 0.3, 0.4, &all_patterns());
        assert_eq!(a.precision.len(), 116);
        let total: u32 = a.valid.iter().sum();
        assert_eq!(total, 116);
        let mut seen = vec![false; 116];
        for &c in &a.order {
            assert!(!seen[c as usize]);
            seen[c as usize] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }
}
