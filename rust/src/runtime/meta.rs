//! Parsed form of `artifacts/<model>.meta.json` (written by aot.py).

use crate::util::json::{parse, Json};
use anyhow::{bail, Result};
use std::collections::HashMap;

/// Element dtype of a tensor crossing the PJRT boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "float32" => Dtype::F32,
            "int32" => Dtype::I32,
            "uint32" => Dtype::U32,
            other => bail!("unsupported dtype {other}"),
        })
    }
}

/// One tensor in a step's input/output layout (HLO parameter order).
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
    /// The leading path segment ("0", "1", ...) = the step argument index.
    pub fn arg_index(&self) -> usize {
        self.name.split('.').next().unwrap().parse().unwrap_or(0)
    }
    /// The path with the leading argument index stripped.
    pub fn sub_path(&self) -> &str {
        match self.name.split_once('.') {
            Some((_, rest)) => rest,
            None => "",
        }
    }
}

/// One step (train/eval) of a model.
#[derive(Debug, Clone)]
pub struct StepMeta {
    pub hlo: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One conv/fc layer, as registered by the python model builders; the
/// codegen/simulator consume this table verbatim.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub name: String,
    pub op: String,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub groups: usize,
    pub hin: usize,
    pub win: usize,
}

/// Index entry of the initial-state binary.
#[derive(Debug, Clone)]
pub struct InitTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub model: String,
    pub image: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub num_classes: usize,
    pub layers: Vec<LayerSpec>,
    pub steps: HashMap<String, StepMeta>,
    pub init_bin: String,
    pub init_tensors: Vec<InitTensor>,
}

fn tensor_specs(v: &Json) -> Result<Vec<TensorSpec>> {
    v.as_arr()?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t.get("name")?.as_str()?.to_string(),
                shape: t.get("shape")?.as_arr()?.iter().map(|d| d.as_usize().unwrap()).collect(),
                dtype: Dtype::parse(t.get("dtype")?.as_str()?)?,
            })
        })
        .collect()
}

impl ModelMeta {
    pub fn parse(text: &str) -> Result<ModelMeta> {
        let v = parse(text)?;
        let layers = v
            .get("layers")?
            .as_arr()?
            .iter()
            .map(|l| {
                Ok(LayerSpec {
                    name: l.get("name")?.as_str()?.to_string(),
                    op: l.get("op")?.as_str()?.to_string(),
                    cin: l.get("cin")?.as_usize()?,
                    cout: l.get("cout")?.as_usize()?,
                    k: l.get("k")?.as_usize()?,
                    stride: l.get("stride")?.as_usize()?,
                    groups: l.get("groups")?.as_usize()?,
                    hin: l.get("hin")?.as_usize()?,
                    win: l.get("win")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut steps = HashMap::new();
        for (name, s) in v.get("steps")?.as_obj()? {
            steps.insert(
                name.clone(),
                StepMeta {
                    hlo: s.get("hlo")?.as_str()?.to_string(),
                    inputs: tensor_specs(s.get("inputs")?)?,
                    outputs: tensor_specs(s.get("outputs")?)?,
                },
            );
        }
        let init = v.get("init")?;
        let init_tensors = init
            .get("tensors")?
            .as_arr()?
            .iter()
            .map(|t| {
                Ok(InitTensor {
                    name: t.get("name")?.as_str()?.to_string(),
                    shape: t
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize().unwrap())
                        .collect(),
                    offset: t.get("offset")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ModelMeta {
            model: v.get("model")?.as_str()?.to_string(),
            image: v.get("image")?.as_usize()?,
            train_batch: v.get("train_batch")?.as_usize()?,
            eval_batch: v.get("eval_batch")?.as_usize()?,
            num_classes: v.get("num_classes")?.as_usize()?,
            layers,
            steps,
            init_bin: init.get("bin")?.as_str()?.to_string(),
            init_tensors,
        })
    }

    pub fn layer(&self, name: &str) -> Option<&LayerSpec> {
        self.layers.iter().find(|l| l.name == name)
    }
}
