//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the PJRT CPU client.
//! Python never runs here — the rust binary is self-contained once
//! `make artifacts` has been run.
//!
//! The PJRT executor needs the external `xla` bindings and is gated
//! behind the `pjrt` cargo feature. Without it, artifact metadata and
//! state loading still work (they feed the simulator/serving paths), but
//! `Runtime::execute` reports that training/eval support is not compiled
//! in.
//!
//! Interchange contract (see aot.py): each model ships
//! - `<model>_<step>.hlo.txt` — HLO text (xla_extension 0.5.1 rejects
//!   jax>=0.5 serialized protos; the text parser reassigns ids),
//! - `<model>.meta.json` — layer table + per-step input/output layouts
//!   (flatten order == HLO parameter order) + init-state index,
//! - `<model>_init.bin` — f32 initial state.

pub mod meta;
pub mod state;

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

pub use meta::{Dtype, LayerSpec, ModelMeta, StepMeta, TensorSpec};
pub use state::{HostTensor, StateStore};

/// A compiled, ready-to-execute step (train/eval) of one model.
pub struct Step {
    pub meta: StepMeta,
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: one CPU client + the compiled steps of one model.
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    dir: PathBuf,
    pub meta: ModelMeta,
    steps: HashMap<String, Step>,
}

impl Runtime {
    /// Load a model's artifacts from `dir` and eagerly compile the listed
    /// steps (pass `None` to compile all of them). Without the `pjrt`
    /// feature only the metadata is loaded; steps are registered but not
    /// executable.
    pub fn load(dir: impl AsRef<Path>, model: &str, steps: Option<&[&str]>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let meta_text = std::fs::read_to_string(dir.join(format!("{model}.meta.json")))
            .with_context(|| format!("reading {model}.meta.json (run `make artifacts`)"))?;
        let meta = ModelMeta::parse(&meta_text)?;
        #[cfg(feature = "pjrt")]
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt: {e:?}"))?;
        let mut rt = Runtime {
            #[cfg(feature = "pjrt")]
            client,
            dir,
            meta,
            steps: HashMap::new(),
        };
        let names: Vec<String> = match steps {
            Some(list) => list.iter().map(|s| s.to_string()).collect(),
            None => rt.meta.steps.keys().cloned().collect(),
        };
        for name in names {
            rt.compile_step(&name)?;
        }
        Ok(rt)
    }

    /// Directory the artifacts live in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    #[cfg(feature = "pjrt")]
    fn compile_step(&mut self, name: &str) -> Result<()> {
        let smeta = self
            .meta
            .steps
            .get(name)
            .ok_or_else(|| anyhow!("unknown step {name}"))?
            .clone();
        let path = self.dir.join(&smeta.hlo);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("hlo parse {name}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.steps.insert(name.to_string(), Step { meta: smeta, exe });
        Ok(())
    }

    /// Without PJRT: register the step so its metadata (input/output
    /// layouts) is queryable, but leave it non-executable.
    #[cfg(not(feature = "pjrt"))]
    fn compile_step(&mut self, name: &str) -> Result<()> {
        let smeta = self
            .meta
            .steps
            .get(name)
            .ok_or_else(|| anyhow!("unknown step {name}"))?
            .clone();
        self.steps.insert(name.to_string(), Step { meta: smeta });
        Ok(())
    }

    pub fn step(&self, name: &str) -> Result<&Step> {
        self.steps.get(name).ok_or_else(|| anyhow!("step {name} not compiled"))
    }

    /// Execute a step. `resolve` supplies one [`HostTensor`] per input
    /// spec (called in HLO parameter order); returns the flattened
    /// outputs, one per output spec.
    #[cfg(feature = "pjrt")]
    pub fn execute(
        &self,
        name: &str,
        mut resolve: impl FnMut(&TensorSpec) -> Result<HostTensor>,
    ) -> Result<Vec<HostTensor>> {
        let step = self.step(name)?;
        let mut literals = Vec::with_capacity(step.meta.inputs.len());
        for spec in &step.meta.inputs {
            let t = resolve(spec)
                .with_context(|| format!("resolving input {} of {name}", spec.name))?;
            anyhow::ensure!(
                t.shape == spec.shape,
                "shape mismatch for {}: got {:?}, want {:?}",
                spec.name,
                t.shape,
                spec.shape
            );
            literals.push(t.to_literal()?);
        }
        let result = step
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        let parts = tuple.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        anyhow::ensure!(
            parts.len() == step.meta.outputs.len(),
            "output arity mismatch: got {}, want {}",
            parts.len(),
            step.meta.outputs.len()
        );
        parts
            .into_iter()
            .zip(&step.meta.outputs)
            .map(|(lit, spec)| HostTensor::from_literal(&lit, spec))
            .collect()
    }

    /// Stub executor for builds without the `pjrt` feature.
    #[cfg(not(feature = "pjrt"))]
    pub fn execute(
        &self,
        name: &str,
        _resolve: impl FnMut(&TensorSpec) -> Result<HostTensor>,
    ) -> Result<Vec<HostTensor>> {
        let _ = self.step(name)?;
        Err(anyhow!(
            "cannot execute step {name}: this build does not include PJRT support \
             (rebuild with `--features pjrt` and an xla crate in the dependency graph)"
        ))
    }
}
