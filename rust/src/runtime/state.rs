//! Host-side tensors and the training-state store the coordinator threads
//! through the PJRT step executions.

use crate::runtime::meta::InitTensor;
#[cfg(feature = "pjrt")]
use crate::runtime::meta::{Dtype, TensorSpec};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::Path;

/// A host tensor crossing the PJRT boundary.
#[derive(Debug, Clone)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

#[derive(Debug, Clone)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape, data: TensorData::F32(data) }
    }
    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape, data: TensorData::I32(data) }
    }
    pub fn u32(shape: Vec<usize>, data: Vec<u32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape, data: TensorData::U32(data) }
    }
    pub fn scalar_f32(v: f32) -> Self {
        HostTensor { shape: vec![], data: TensorData::F32(vec![v]) }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => Err(anyhow!("not f32")),
        }
    }

    pub fn scalar(&self) -> Result<f32> {
        Ok(self.as_f32()?[0])
    }

    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => xla::Literal::vec1(v),
            TensorData::I32(v) => xla::Literal::vec1(v),
            TensorData::U32(v) => xla::Literal::vec1(v),
        };
        lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))
    }

    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<HostTensor> {
        let data = match spec.dtype {
            Dtype::F32 => TensorData::F32(lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?),
            Dtype::I32 => TensorData::I32(lit.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?),
            Dtype::U32 => TensorData::U32(lit.to_vec::<u32>().map_err(|e| anyhow!("{e:?}"))?),
        };
        Ok(HostTensor { shape: spec.shape.clone(), data })
    }
}

/// The model's training state: a flat map of path -> tensor, fed back
/// into each step call (names are the aot.py flatten paths with the
/// leading argument index stripped, e.g. `params.stem`, `s.s0b0/c1`,
/// `bn.stem/mean`).
#[derive(Debug, Clone, Default)]
pub struct StateStore {
    pub tensors: HashMap<String, HostTensor>,
}

impl StateStore {
    /// Load the initial state written by aot.py.
    pub fn load_init(dir: impl AsRef<Path>, bin: &str, index: &[InitTensor]) -> Result<StateStore> {
        let bytes = std::fs::read(dir.as_ref().join(bin))?;
        let mut tensors = HashMap::new();
        for t in index {
            let n: usize = t.shape.iter().product();
            let start = t.offset * 4;
            let mut data = vec![0f32; n];
            for (i, chunk) in bytes[start..start + 4 * n].chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            }
            tensors.insert(t.name.clone(), HostTensor::f32(t.shape.clone(), data));
        }
        Ok(StateStore { tensors })
    }

    pub fn get(&self, path: &str) -> Result<&HostTensor> {
        self.tensors.get(path).ok_or_else(|| anyhow!("state tensor {path} missing"))
    }

    pub fn set(&mut self, path: &str, t: HostTensor) {
        self.tensors.insert(path.to_string(), t);
    }

    /// All per-layer `s` vectors (phase-I sensitivities), keyed by layer.
    pub fn s_vectors(&self) -> HashMap<String, Vec<f32>> {
        self.tensors
            .iter()
            .filter_map(|(k, v)| {
                k.strip_prefix("s.").map(|layer| {
                    (layer.to_string(), v.as_f32().unwrap().to_vec())
                })
            })
            .collect()
    }
}
