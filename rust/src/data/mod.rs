//! Synthetic CIFAR-like dataset (DESIGN.md substitution for CIFAR-10 /
//! ImageNet, which are unavailable on this testbed).
//!
//! Deterministic 10-class image generator: each class has a fixed random
//! template (low-frequency color gratings + a class-positioned blob);
//! samples are the template under a random translation, amplitude jitter
//! and additive noise. The task is CNN-learnable but not linearly trivial
//! (translations force some shift tolerance), so quantization-induced
//! accuracy differences show the same ordering the paper reports.

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Batch {
    /// NHWC f32 in [-2, 2]
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub n: usize,
    pub image: usize,
}

pub struct Dataset {
    pub image: usize,
    pub num_classes: usize,
    templates: Vec<Vec<f32>>, // per class, HWC
    noise: f32,
}

impl Dataset {
    pub fn new(image: usize, num_classes: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xDA7A);
        let mut templates = Vec::with_capacity(num_classes);
        for class in 0..num_classes {
            let mut t = vec![0f32; image * image * 3];
            // class-specific frequencies and phases per color channel
            let fx: f32 = 1.0 + rng.below(3) as f32 + (class % 3) as f32;
            let fy: f32 = 1.0 + rng.below(3) as f32 + (class % 4) as f32;
            let phase = rng.range(0.0, std::f32::consts::TAU);
            let (bx, by) = (
                rng.range(0.2, 0.8) * image as f32,
                rng.range(0.2, 0.8) * image as f32,
            );
            let chan_w: [f32; 3] = [rng.range(-1.0, 1.0), rng.range(-1.0, 1.0), rng.range(-1.0, 1.0)];
            for h in 0..image {
                for w in 0..image {
                    let u = h as f32 / image as f32;
                    let v = w as f32 / image as f32;
                    let grid = (std::f32::consts::TAU * (fx * u + fy * v) + phase).sin();
                    let d2 = ((h as f32 - by).powi(2) + (w as f32 - bx).powi(2))
                        / (image as f32 * 0.25).powi(2);
                    let blob = (-d2).exp();
                    for c in 0..3 {
                        t[(h * image + w) * 3 + c] =
                            0.6 * grid * chan_w[c] + 0.8 * blob * chan_w[(c + 1) % 3];
                    }
                }
            }
            templates.push(t);
        }
        Dataset { image, num_classes, templates, noise: 0.25 }
    }

    /// Deterministic batch by index (same `split` + `batch_idx` always
    /// yields the same data — train/eval reproducibility without storage).
    pub fn batch(&self, split: u64, batch_idx: u64, n: usize) -> Batch {
        let mut rng = Rng::new(0xBA7C_u64 ^ (split << 32) ^ batch_idx);
        let img = self.image;
        let mut images = vec![0f32; n * img * img * 3];
        let mut labels = vec![0i32; n];
        for i in 0..n {
            let class = rng.below(self.num_classes as u64) as usize;
            labels[i] = class as i32;
            let t = &self.templates[class];
            let dh = rng.below(7) as isize - 3;
            let dw = rng.below(7) as isize - 3;
            let amp = rng.range(0.7, 1.3);
            for h in 0..img {
                for w in 0..img {
                    let sh = (h as isize + dh).rem_euclid(img as isize) as usize;
                    let sw = (w as isize + dw).rem_euclid(img as isize) as usize;
                    for c in 0..3 {
                        let v = amp * t[(sh * img + sw) * 3 + c] + self.noise * rng.normal();
                        images[((i * img + h) * img + w) * 3 + c] = v.clamp(-2.0, 2.0);
                    }
                }
            }
        }
        Batch { images, labels, n, image: img }
    }

    #[cfg(test)]
    pub(crate) fn template(&self, class: usize) -> &[f32] {
        &self.templates[class]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_batches() {
        let d = Dataset::new(16, 10, 0);
        let a = d.batch(0, 3, 8);
        let b = d.batch(0, 3, 8);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.images, b.images);
        let c = d.batch(0, 4, 8);
        assert_ne!(a.images, c.images);
    }

    #[test]
    fn values_bounded() {
        let d = Dataset::new(16, 10, 1);
        let b = d.batch(1, 0, 16);
        assert!(b.images.iter().all(|v| v.abs() <= 2.0));
        assert!(b.labels.iter().all(|&l| (0..10).contains(&l)));
    }

    #[test]
    fn classes_are_separable_by_template_correlation() {
        // nearest-template classification (translation-max correlation)
        // should beat chance by a wide margin — the task is learnable
        let d = Dataset::new(16, 10, 2);
        let b = d.batch(7, 0, 48);
        let img = 16usize;
        let mut correct = 0;
        for i in 0..b.n {
            let x = &b.images[i * img * img * 3..(i + 1) * img * img * 3];
            let mut best = (f32::MIN, 0usize);
            for cl in 0..d.num_classes {
                let t = d.template(cl);
                let mut m = f32::MIN;
                for dh in -3isize..=3 {
                    for dw in -3isize..=3 {
                        let mut s = 0f32;
                        for h in 0..img {
                            for w in 0..img {
                                let sh = (h as isize + dh).rem_euclid(img as isize) as usize;
                                let sw = (w as isize + dw).rem_euclid(img as isize) as usize;
                                for c in 0..3 {
                                    s += x[(h * img + w) * 3 + c] * t[(sh * img + sw) * 3 + c];
                                }
                            }
                        }
                        m = m.max(s);
                    }
                }
                if m > best.0 {
                    best = (m, cl);
                }
            }
            if best.1 == b.labels[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f32 / b.n as f32;
        assert!(acc > 0.5, "template-matching accuracy {acc}");
    }
}
