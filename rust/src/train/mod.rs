//! Training orchestrator: drives the AOT-compiled SASMOL steps through
//! PJRT. Owns the state store, feeds batches/keys/hyperparameters, and
//! implements the two-phase schedule (phase I noise search -> pattern
//! match -> phase II fine-tune) plus the uniform/fp32 baselines.

use crate::data::Dataset;
use crate::runtime::{HostTensor, Runtime, StateStore, TensorSpec};
use anyhow::{bail, Result};
use std::collections::HashMap;

/// Per-layer (step, qmax) arrays fed to phase2/eval steps.
pub type PrecMap = HashMap<String, (Vec<f32>, Vec<f32>)>;

/// One logged training step.
#[derive(Debug, Clone, Copy)]
pub struct StepLog {
    pub step: usize,
    pub loss: f32,
    pub acc: f32,
}

pub struct Trainer<'a> {
    pub rt: &'a Runtime,
    pub state: StateStore,
    pub dataset: &'a Dataset,
    pub seed: u32,
    pub history: Vec<StepLog>,
}

impl<'a> Trainer<'a> {
    pub fn new(rt: &'a Runtime, dataset: &'a Dataset) -> Result<Self> {
        let state = StateStore::load_init(rt.dir(), &rt.meta.init_bin, &rt.meta.init_tensors)?;
        Ok(Trainer { rt, state, dataset, seed: 0, history: Vec::new() })
    }

    fn batch_tensors(&self, step_idx: usize) -> (HostTensor, HostTensor) {
        let b = self.dataset.batch(0, step_idx as u64, self.rt.meta.train_batch);
        let img = self.rt.meta.image;
        (
            HostTensor::f32(vec![b.n, img, img, 3], b.images),
            HostTensor::i32(vec![b.n], b.labels),
        )
    }

    fn apply_outputs(&mut self, outs: Vec<HostTensor>, specs: &[TensorSpec]) -> (f32, f32) {
        let mut loss = f32::NAN;
        let mut acc = f32::NAN;
        for (out, spec) in outs.into_iter().zip(specs) {
            if let Some(path) = spec.name.strip_prefix("0.") {
                self.state.set(path, out);
            } else if spec.name == "1" {
                loss = out.scalar().unwrap_or(f32::NAN);
            } else if spec.name == "2" {
                acc = out.scalar().unwrap_or(f32::NAN);
            }
        }
        (loss, acc)
    }

    fn run_train_step(
        &mut self,
        step_name: &str,
        step_idx: usize,
        prec: Option<&PrecMap>,
        lr: f32,
        lam: f32,
    ) -> Result<(f32, f32)> {
        let (images, labels) = self.batch_tensors(step_idx);
        let key = HostTensor::u32(vec![2], vec![self.seed, step_idx as u32]);
        let state = &self.state;
        let out_specs = self.rt.step(step_name)?.meta.outputs.clone();
        let outs = self.rt.execute(step_name, |spec| {
            resolve_input(
                step_name, spec, state, prec, &images, &labels, &key, lr, lam,
            )
        })?;
        let (loss, acc) = self.apply_outputs(outs, &out_specs);
        self.history.push(StepLog { step: step_idx, loss, acc });
        Ok((loss, acc))
    }

    /// SASMOL phase I (noise-injected precision search).
    pub fn phase1_step(&mut self, step_idx: usize, lr: f32, lam: f32) -> Result<(f32, f32)> {
        self.run_train_step("phase1_step", step_idx, None, lr, lam)
    }

    /// Phase II / uniform QAT under fixed per-channel precisions.
    pub fn phase2_step(&mut self, step_idx: usize, prec: &PrecMap, lr: f32) -> Result<(f32, f32)> {
        self.run_train_step("phase2_step", step_idx, Some(prec), lr, 0.0)
    }

    /// Full-precision baseline step.
    pub fn fp32_step(&mut self, step_idx: usize, lr: f32) -> Result<(f32, f32)> {
        self.run_train_step("fp32_step", step_idx, None, lr, 0.0)
    }

    /// Evaluate accuracy over `n_batches` deterministic eval batches.
    /// `prec` selects the quantized path (`eval_quant`); `None` = fp32.
    pub fn eval(&self, prec: Option<&PrecMap>, n_batches: usize) -> Result<f32> {
        let step_name = if prec.is_some() { "eval_quant" } else { "eval_fp32" };
        let img = self.rt.meta.image;
        let eb = self.rt.meta.eval_batch;
        let mut correct = 0usize;
        let mut total = 0usize;
        for bi in 0..n_batches {
            let b = self.dataset.batch(1, bi as u64, eb);
            let images = HostTensor::f32(vec![eb, img, img, 3], b.images.clone());
            let logits = self.eval_logits_inner(step_name, prec, &images)?;
            let classes = self.rt.meta.num_classes;
            for (i, &label) in b.labels.iter().enumerate() {
                let row = &logits[i * classes..(i + 1) * classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred == label as usize {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok(correct as f32 / total as f32)
    }

    /// Raw logits for a batch of images (integration tests / serving).
    pub fn eval_logits(&self, prec: Option<&PrecMap>, images: &HostTensor) -> Result<Vec<f32>> {
        let step_name = if prec.is_some() { "eval_quant" } else { "eval_fp32" };
        self.eval_logits_inner(step_name, prec, images)
    }

    fn eval_logits_inner(
        &self,
        step_name: &str,
        prec: Option<&PrecMap>,
        images: &HostTensor,
    ) -> Result<Vec<f32>> {
        let state = &self.state;
        let dummy_labels = HostTensor::i32(vec![1], vec![0]);
        let dummy_key = HostTensor::u32(vec![2], vec![0, 0]);
        let outs = self.rt.execute(step_name, |spec| {
            resolve_input(
                step_name, spec, state, prec, images, &dummy_labels, &dummy_key, 0.0, 0.0,
            )
        })?;
        Ok(outs.into_iter().next().unwrap().as_f32()?.to_vec())
    }
}

/// Map one HLO input parameter to its host tensor, per step signature:
/// phase1: (state, images, labels, key, lr, lam)
/// phase2: (state, prec, images, labels, lr)
/// fp32:   (state, images, labels, lr)
/// eval_quant: (state, prec, images);  eval_fp32: (state, images)
#[allow(clippy::too_many_arguments)]
fn resolve_input(
    step_name: &str,
    spec: &TensorSpec,
    state: &StateStore,
    prec: Option<&PrecMap>,
    images: &HostTensor,
    labels: &HostTensor,
    key: &HostTensor,
    lr: f32,
    lam: f32,
) -> Result<HostTensor> {
    let arg = spec.arg_index();
    let has_prec = matches!(step_name, "phase2_step" | "eval_quant");
    // positional role of this argument index
    let role = match (step_name, arg) {
        (_, 0) => "state",
        ("phase1_step", 1) | ("fp32_step", 1) | ("eval_fp32", 1) => "images",
        ("phase2_step", 1) | ("eval_quant", 1) => "prec",
        ("phase1_step", 2) | ("fp32_step", 2) => "labels",
        ("phase2_step", 2) | ("eval_quant", 2) => "images",
        ("phase1_step", 3) => "key",
        ("phase2_step", 3) => "labels",
        ("phase1_step", 4) | ("phase2_step", 4) | ("fp32_step", 3) => "lr",
        ("phase1_step", 5) => "lam",
        _ => bail!("unexpected arg {arg} for {step_name}"),
    };
    let _ = has_prec;
    Ok(match role {
        "state" => state.get(spec.sub_path())?.clone(),
        "images" => images.clone(),
        "labels" => labels.clone(),
        "key" => key.clone(),
        "lr" => HostTensor::scalar_f32(lr),
        "lam" => HostTensor::scalar_f32(lam),
        "prec" => {
            let prec = prec.ok_or_else(|| anyhow::anyhow!("prec map required"))?;
            // sub_path is "<layer>.<0|1>" (layer names contain no '.')
            let sub = spec.sub_path();
            let (layer, which) = sub
                .rsplit_once('.')
                .ok_or_else(|| anyhow::anyhow!("bad prec path {sub}"))?;
            let (step_v, qmax_v) = prec
                .get(layer)
                .ok_or_else(|| anyhow::anyhow!("prec for layer {layer} missing"))?;
            let v = if which == "0" { step_v } else { qmax_v };
            HostTensor::f32(vec![v.len()], v.clone())
        }
        _ => unreachable!(),
    })
}

/// Cosine-with-floor learning-rate schedule used by the experiments.
pub fn lr_schedule(step: usize, total: usize, base: f32) -> f32 {
    let t = step as f32 / total.max(1) as f32;
    let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
    base * (0.1 + 0.9 * cos)
}

/// Build a uniform-precision PrecMap for a model's layers.
pub fn uniform_prec(layers: &[crate::runtime::LayerSpec], bits: u8) -> PrecMap {
    use crate::smol::quant;
    layers
        .iter()
        .map(|l| {
            (
                l.name.clone(),
                (
                    vec![quant::step_for(bits); l.cin],
                    vec![quant::qmax_for(bits); l.cin],
                ),
            )
        })
        .collect()
}
