//! Critical-path timing model (paper Sec. V-B): the reported path is
//!
//!   BoothRecode -> BoothMux -> 3:2 CSA -> HalfAdder -> 3:1 Mux ->
//!   4:2 CSA -> 4:2 CSA -> 12-bit CPA -> 2:1 Mux
//!
//! and all designs meet timing at 2 GHz. We assign per-stage delays in
//! picoseconds (generic 7nm-class standard-cell figures) and check slack.

/// One named stage of the critical path with its delay in ps.
#[derive(Debug, Clone, Copy)]
pub struct Stage {
    pub name: &'static str,
    pub delay_ps: f64,
}

/// The Fig. 3 critical path, in order.
pub const CRITICAL_PATH: [Stage; 9] = [
    Stage { name: "BoothRecode", delay_ps: 38.0 },
    Stage { name: "BoothMux", delay_ps: 34.0 },
    Stage { name: "3:2 CSA", delay_ps: 55.0 },
    Stage { name: "HalfAdder", delay_ps: 32.0 },
    Stage { name: "3:1 Mux", delay_ps: 42.0 },
    Stage { name: "4:2 CSA", delay_ps: 72.0 },
    Stage { name: "4:2 CSA", delay_ps: 72.0 },
    Stage { name: "12-bit CPA", delay_ps: 98.0 },
    Stage { name: "2:1 Mux", delay_ps: 30.0 },
];

/// Total critical-path delay in ps.
pub fn critical_path_ps() -> f64 {
    CRITICAL_PATH.iter().map(|s| s.delay_ps).sum()
}

/// Does the design meet timing at `freq_ghz` (with `margin` fraction of
/// the cycle reserved for clock skew/setup)?
pub fn meets_timing(freq_ghz: f64, margin: f64) -> bool {
    let cycle_ps = 1000.0 / freq_ghz;
    critical_path_ps() <= cycle_ps * (1.0 - margin)
}

/// Slack at `freq_ghz` in ps.
pub fn slack_ps(freq_ghz: f64) -> f64 {
    1000.0 / freq_ghz - critical_path_ps()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meets_2ghz_as_paper_reports() {
        assert!(meets_timing(2.0, 0.05), "path = {} ps", critical_path_ps());
        assert!(slack_ps(2.0) > 0.0);
    }

    #[test]
    fn path_has_nine_stages_in_paper_order() {
        assert_eq!(CRITICAL_PATH.len(), 9);
        assert_eq!(CRITICAL_PATH[0].name, "BoothRecode");
        assert_eq!(CRITICAL_PATH[7].name, "12-bit CPA");
    }
}
