//! Hardware cost models: NAND2-equivalent gate counts (Table V) and
//! critical-path timing (Sec. V-B) for the configurable ALU + control
//! blocks.

pub mod gates;
pub mod timing;
