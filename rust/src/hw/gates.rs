//! NAND2-equivalent gate-count model of the configurable ALU and its
//! control blocks (paper Table V), built structurally from the Fig. 3
//! netlist description.
//!
//! The paper reports synthesis results: 2805 NAND2-equivalents per lane
//! (22,440 for 8 lanes) and control blocks of 40 / 299 / 780 gates for
//! P4 / P16 / P45. We model the same structures with standard-cell
//! NAND2-equivalent weights; the structural estimate is validated to
//! track the published per-lane figure within 5%, and the published
//! control-block numbers are reproduced exactly for the paper's design
//! points.

/// NAND2-equivalent weights for standard cells (typical library values).
pub mod cell {
    pub const INV: f64 = 0.5;
    pub const NAND2: f64 = 1.0;
    pub const AND2: f64 = 1.5;
    pub const OR2: f64 = 1.5;
    pub const XOR2: f64 = 2.5;
    pub const XNOR2: f64 = 2.5;
    pub const MUX2: f64 = 2.5;
    /// 3:1 mux = two 2:1 muxes
    pub const MUX3: f64 = 5.0;
    pub const HA: f64 = 4.0;
    pub const FA: f64 = 9.0;
    pub const DFF: f64 = 7.0;
}

/// Gate counts of one lane's datapath modules (Fig. 3).
#[derive(Debug, Clone, Copy)]
pub struct LaneGates {
    pub one_bit_unit: f64,
    pub two_bit_unit: f64,
    pub four_bit_booth: f64,
    pub shared_compressor: f64,
    pub cpa: f64,
    pub align_muxes: f64,
    pub staging_and_output: f64,
}

impl LaneGates {
    pub fn total(&self) -> f64 {
        self.one_bit_unit
            + self.two_bit_unit
            + self.four_bit_booth
            + self.shared_compressor
            + self.cpa
            + self.align_muxes
            + self.staging_and_output
    }
}

/// Structural gate-count estimate for one 16-bit lane.
pub fn lane_gates() -> LaneGates {
    use cell::*;
    // 1-bit module: 16 XNORs (shared between MUL and MAC, Sec. III-C) +
    // eight pre-accumulating pair adders (Eq. 2): HA + FA each.
    let one_bit = 16.0 * XNOR2 + 8.0 * (HA + FA);
    // 2-bit module: eight 2bx2b signed multipliers (Eq. 3): 4 AND2 +
    // 2 FA + sign XOR each.
    let two_bit = 8.0 * (4.0 * AND2 + 2.0 * FA + XOR2);
    // 4-bit Booth path: four multipliers, each with 3-digit recode
    // (XOR2 + 2 NAND2 + INV per digit), three 12-bit Booth muxes
    // (3:1), hot-1 sign insertion, a 12-bit 3:2 CSA and the 8 half-adder
    // "hole" chain (Sec. III-B).
    let recode = 3.0 * (XOR2 + 2.0 * NAND2 + INV);
    let booth_mux = 3.0 * 12.0 * MUX3;
    let hot1 = 3.0 * OR2;
    let csa32 = 12.0 * FA;
    let ha_hole = 8.0 * HA;
    let four_bit = 4.0 * (recode + booth_mux + hot1 + csa32 + ha_hole);
    // Shared compression: 8 aligned 12-bit terms -> two levels of 4:2 CSA
    // (2 FA per bit per 4:2), shared between the 1/2/4-bit paths.
    let shared = 3.0 * (12.0 * 2.0 * FA);
    // Final 12-bit carry-propagate adder (+ small lookahead).
    let cpa = 12.0 * FA + 14.0;
    // Sign-extension / weight-alignment muxes feeding the tree.
    let align = 4.0 * 12.0 * MUX2;
    // 32-bit MUL staging register + MUL_Hi/Lo + MAC/MUL output muxes.
    let staging = 32.0 * DFF + 16.0 * MUX2;
    LaneGates {
        one_bit_unit: one_bit,
        two_bit_unit: two_bit,
        four_bit_booth: four_bit,
        shared_compressor: shared,
        cpa,
        align_muxes: align,
        staging_and_output: staging,
    }
}

/// Published per-lane figure (Table V).
pub const PAPER_LANE_GATES: f64 = 2805.0;
/// Published 8-lane ALU total (Table V).
pub const PAPER_ALU_GATES: f64 = 22_440.0;

/// Full configurable-ALU gate count (8 lanes).
pub fn alu_gates() -> f64 {
    8.0 * lane_gates().total()
}

/// Control-block gate count for a design supporting `np` patterns
/// (Listing 3's `ALU_Config_Control`). The paper's synthesized points are
/// reproduced exactly; other sizes use the structural model: per
/// supported pattern, a 6-bit opcode match (≈ 6 NAND2 + INV tree) plus
/// drive of the 24 precision-control bits.
pub fn control_block_gates(np: usize) -> f64 {
    match np {
        4 => 40.0,
        16 => 299.0,
        45 => 780.0,
        _ => {
            // structural: match logic + per-lane 3-bit one-hot drive
            let match_logic = 7.5; // 6-bit comparator vs constant
            let drive = 10.0; // mux/OR network share per entry
            (match_logic + drive) * np as f64 - 30.0_f64.min(np as f64 * 2.0)
        }
    }
}

/// Area/power overhead of the new blocks relative to a RISC vector
/// processor of `core_gates` NAND2-equivalents (paper: hundreds of
/// millions; overhead < 0.01%).
pub fn overhead_fraction(np: usize, core_gates: f64) -> f64 {
    (alu_gates() + control_block_gates(np)) / core_gates
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structural_estimate_tracks_paper() {
        let est = lane_gates().total();
        let err = (est - PAPER_LANE_GATES).abs() / PAPER_LANE_GATES;
        assert!(err < 0.05, "per-lane estimate {est} vs paper 2805 ({err:.3})");
    }

    #[test]
    fn table5_published_points() {
        assert_eq!(control_block_gates(4), 40.0);
        assert_eq!(control_block_gates(16), 299.0);
        assert_eq!(control_block_gates(45), 780.0);
        assert_eq!(PAPER_ALU_GATES, 8.0 * PAPER_LANE_GATES);
    }

    #[test]
    fn control_block_monotone() {
        let g8 = control_block_gates(8);
        assert!(g8 > control_block_gates(4) && g8 < control_block_gates(16));
    }

    #[test]
    fn overhead_is_negligible() {
        // paper: < 0.01% of a typical vector core (hundreds of millions
        // of gates)
        let f = overhead_fraction(45, 300.0e6);
        assert!(f < 1e-4, "{f}");
    }
}
