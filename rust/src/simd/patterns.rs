//! Precision patterns for 128-bit vectors (paper Table II & III).
//!
//! A pattern `(n1, n2, n4)` gives the number of 1-, 2- and 4-bit elements
//! packed into one 128-bit vector, with all 4-bit elements first, then
//! 2-bit, then 1-bit (Observation 4 grouping). Because each of the eight
//! 16-bit lanes is configured to a single precision, `n4` is a multiple of
//! 4, `n2` of 8, and `n1` of 16; `n1 + 2*n2 + 4*n4 = 128`. There are
//! exactly 45 such patterns (Table II).


/// Vector width in bits.
pub const VECTOR_BITS: u32 = 128;
/// Lane width in bits (Observation 5: 16-bit granularity suffices).
pub const LANE_BITS: u32 = 16;
/// Lanes per vector.
pub const NUM_LANES: usize = (VECTOR_BITS / LANE_BITS) as usize;

/// One precision pattern: element counts per precision in a 128-bit vector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Pattern {
    /// number of 1-bit elements (multiple of 16)
    pub n1: u16,
    /// number of 2-bit elements (multiple of 8)
    pub n2: u16,
    /// number of 4-bit elements (multiple of 4)
    pub n4: u16,
}

impl Pattern {
    pub const fn new(n1: u16, n2: u16, n4: u16) -> Self {
        Pattern { n1, n2, n4 }
    }

    /// Uniform pattern for a single precision.
    pub fn uniform(p: u8) -> Self {
        match p {
            1 => Pattern::new(128, 0, 0),
            2 => Pattern::new(0, 64, 0),
            4 => Pattern::new(0, 0, 32),
            _ => panic!("unsupported uniform precision {p}"),
        }
    }

    /// Total elements (channels) this pattern packs.
    pub fn capacity(&self) -> u32 {
        self.n1 as u32 + self.n2 as u32 + self.n4 as u32
    }

    /// Total bits used (must be 128 for a valid pattern).
    pub fn bits(&self) -> u32 {
        self.n1 as u32 + 2 * self.n2 as u32 + 4 * self.n4 as u32
    }

    /// Sum of precisions over elements (for average-precision ranking).
    pub fn precision_sum(&self) -> u32 {
        self.bits()
    }

    /// Average bits per element.
    pub fn avg_precision(&self) -> f64 {
        self.bits() as f64 / self.capacity() as f64
    }

    /// Per-lane precisions, 4-bit lanes first (Observation 4 grouping).
    pub fn lane_precisions(&self) -> [u8; NUM_LANES] {
        let mut lanes = [0u8; NUM_LANES];
        let l4 = (self.n4 / 4) as usize;
        let l2 = (self.n2 / 8) as usize;
        for (i, lane) in lanes.iter_mut().enumerate() {
            *lane = if i < l4 {
                4
            } else if i < l4 + l2 {
                2
            } else {
                1
            };
        }
        lanes
    }

    /// Element precision by element index (elements ordered 4b, 2b, 1b).
    pub fn element_precision(&self, idx: u32) -> u8 {
        if idx < self.n4 as u32 {
            4
        } else if idx < (self.n4 + self.n2) as u32 {
            2
        } else {
            1
        }
    }

    /// Number of elements of a given precision.
    pub fn count(&self, p: u8) -> u32 {
        match p {
            1 => self.n1 as u32,
            2 => self.n2 as u32,
            4 => self.n4 as u32,
            _ => 0,
        }
    }

    pub fn is_valid(&self) -> bool {
        self.bits() == VECTOR_BITS && self.n1 % 16 == 0 && self.n2 % 8 == 0 && self.n4 % 4 == 0
    }
}

/// Enumerate all 45 valid patterns in the paper's Table II order:
/// sorted by (n1, n2) ascending — index 1 = (0,0,32) ... index 45 = (128,0,0).
pub fn all_patterns() -> Vec<Pattern> {
    let mut v = Vec::new();
    for l1 in 0..=NUM_LANES {
        for l2 in 0..=(NUM_LANES - l1) {
            let l4 = NUM_LANES - l1 - l2;
            v.push(Pattern::new(16 * l1 as u16, 8 * l2 as u16, 4 * l4 as u16));
        }
    }
    debug_assert_eq!(v.len(), 45);
    v
}

/// Pattern by its 1-based Table II index.
pub fn pattern_by_index(idx: usize) -> Pattern {
    all_patterns()[idx - 1]
}

/// 1-based Table II index of a pattern.
pub fn index_of(p: &Pattern) -> Option<usize> {
    all_patterns().iter().position(|q| q == p).map(|i| i + 1)
}

/// Table III: pattern subsets per design point (by Table II index).
pub fn design_subset(np: usize) -> Vec<Pattern> {
    let idx: &[usize] = match np {
        4 => &[1, 45, 9, 17],
        8 => &[1, 45, 9, 17, 16, 35, 38, 15],
        45 => return all_patterns(),
        _ => panic!("unsupported design point np={np} (use 4, 8 or 45)"),
    };
    idx.iter().map(|&i| pattern_by_index(i)).collect()
}

/// Number of distinct per-element precision layouts of one 128-bit vector
/// (compositions of 128 into parts {1,2,4}): ~1.118e31.
pub fn per_vector_mix_layouts() -> f64 {
    // c(n) = c(n-1) + c(n-2) + c(n-4)
    let mut c = vec![0f64; 129];
    c[0] = 1.0;
    for n in 1..=128usize {
        let mut s = c[n - 1];
        if n >= 2 {
            s += c[n - 2];
        }
        if n >= 4 {
            s += c[n - 4];
        }
        c[n] = s;
    }
    c[128]
}

/// ALU configuration count if arbitrary per-element precision mixes were
/// allowed in the two operand vectors of a 128-bit MAC: the pair of
/// independent per-vector layouts, ~1.25e62 (the paper quotes ~1.12e62 —
/// same astronomical order; a single vector already admits ~1.118e31
/// layouts).
pub fn arbitrary_mix_configurations() -> f64 {
    let c = per_vector_mix_layouts();
    c * c
}

/// Number of ALU configurations with grouped operands (paper: 1089 needed
/// when 4-bit elements come first, then 2-bit, then 1-bit in both inputs).
pub fn grouped_configurations() -> usize {
    // Both input vectors independently choose a grouped boundary pair
    // (#4b, #2b) — 45 patterns each, but the pair must agree on lane
    // boundaries only; the paper reports 33^2 = 1089 boundary choices
    // (33 = boundary positions at 4-bit granularity within 128 bits).
    // We reproduce the count of (pattern_a, pattern_b) lane-aligned pairs:
    // 33 * 33 = 1089.
    33 * 33
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exactly_45_patterns() {
        let pats = all_patterns();
        assert_eq!(pats.len(), 45);
        for p in &pats {
            assert!(p.is_valid(), "{p:?}");
            assert_eq!(p.bits(), 128);
        }
    }

    #[test]
    fn table2_spot_checks() {
        // Table II: index 1 = (0,0,32), 9 = (0,64,0), 17 = (16,56,0),
        // 20 = (32,16,16), 45 = (128,0,0)
        assert_eq!(pattern_by_index(1), Pattern::new(0, 0, 32));
        assert_eq!(pattern_by_index(9), Pattern::new(0, 64, 0));
        assert_eq!(pattern_by_index(17), Pattern::new(16, 56, 0));
        assert_eq!(pattern_by_index(20), Pattern::new(32, 16, 16));
        assert_eq!(pattern_by_index(35), Pattern::new(64, 32, 0));
        assert_eq!(pattern_by_index(45), Pattern::new(128, 0, 0));
    }

    #[test]
    fn lane_precisions_consistent() {
        for p in all_patterns() {
            let lanes = p.lane_precisions();
            let mut n = [0u32; 5];
            for l in lanes {
                n[l as usize] += (LANE_BITS / l as u32) * 0 + 16 / l as u32;
            }
            assert_eq!(n[1], p.n1 as u32);
            assert_eq!(n[2], p.n2 as u32);
            assert_eq!(n[4], p.n4 as u32);
        }
    }

    #[test]
    fn design_subsets_match_table3() {
        let p4 = design_subset(4);
        assert_eq!(p4.len(), 4);
        assert!(p4.contains(&Pattern::uniform(4)));
        assert!(p4.contains(&Pattern::uniform(2)));
        assert!(p4.contains(&Pattern::uniform(1)));
        assert!(p4.contains(&Pattern::new(16, 56, 0)));
        assert_eq!(design_subset(8).len(), 8);
        assert_eq!(design_subset(45).len(), 45);
    }

    #[test]
    fn arbitrary_mix_is_astronomical() {
        let c = arbitrary_mix_configurations();
        // paper: ~1.12e62 (same order as the layout-pair count)
        assert!(c > 1.0e62 && c < 1.3e62, "{c:e}");
        let per = per_vector_mix_layouts();
        assert!(per > 1.1e31 && per < 1.13e31, "{per:e}");
    }
}
