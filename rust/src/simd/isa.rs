//! The vector ISA the code generator targets (ARMv8-NEON analog + the two
//! new instructions `vmac_Pn` / `vmul_Pn` from Sec. IV-B, Fig. 6).
//!
//! Instruction encodings follow Fig. 6 (11-bit opcode, Qn/Qm/Qd register
//! fields, 6-bit Pn pattern-index field); [`encode`] produces the 32-bit
//! word and the decoder in [`crate::sim`] consumes the structured form.


/// Vector register id (32 architectural registers, as in NEON).
pub type Reg = u8;
pub const NUM_VREGS: usize = 32;

/// Buffer handle into simulator memory (activations / weights / outputs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BufId(pub u16);

/// A memory operand: byte offset into a buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Addr {
    pub buf: BufId,
    pub off: u32,
}

/// Pattern-table index local to a generated program (the `Pn` field).
pub type PatId = u8;

/// One instruction of the generated inference kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Instr {
    /// 128-bit vector load.
    LdQ { dst: Reg, addr: Addr },
    /// 128-bit vector store.
    StQ { src: Reg, addr: Addr },
    /// Zero a vector register (`vmov(0)` in Algorithm 4).
    VmovZ { dst: Reg },
    /// Bitwise AND (tail masking, Algorithm 4 line 20).
    Vand { dst: Reg, a: Reg, b: Reg },
    /// New: configurable mixed-precision MAC (`vmac_Pn`).
    VmacP { dst: Reg, a: Reg, b: Reg, pat: PatId },
    /// New: configurable mixed-precision MUL (`vmul_Pn`) — two-cycle;
    /// results land in `dst` (cycle 1) and `dst2` (cycle 2).
    VmulP { dst: Reg, dst2: Reg, a: Reg, b: Reg, pat: PatId },
    /// `vaddq_s16` lanewise accumulate.
    Vaddq16 { dst: Reg, a: Reg, b: Reg },
    /// `vaddvq_s32(vpaddlq_s16(src))` then `out[addr] += sum` (i32, 2^-6
    /// units). The paper's Algorithm 4 line 26 (reduce + store), fused
    /// here with the cross-chunk scalar accumulate; costed as 2 vector
    /// ops + 1 load + 1 store.
    ReduceAcc { src: Reg, addr: Addr },
    /// Depthwise epilogue: decode the two-cycle MUL product registers
    /// (`lo`,`hi`), apply the software LSB correction (Sec. III-C),
    /// scale each product to 2^-6 units and accumulate into out[addr +
    /// 4*e] for the first `n_valid` elements. Costed as the correction +
    /// widen + add sequence (4 vector ops + n/4 stores).
    MulAcc { lo: Reg, hi: Reg, pat: PatId, addr: Addr, n_valid: u16 },
    /// Full-precision baseline: 4 x f32 FMA (`vfmaq_f32`).
    VfmaF32 { dst: Reg, a: Reg, b: Reg },
    /// INT8 baseline MAC: 16 x i8 dot into 16.6-style lanes (`vdotq`-like).
    VmacI8 { dst: Reg, a: Reg, b: Reg },
}

/// Static cost/class of one instruction for the timing model.
#[derive(Debug, Clone, Copy)]
pub struct InstrCost {
    /// vector-ALU issue cycles
    pub alu: u32,
    /// memory accesses as (addr, bytes, is_store) count
    pub mem: u32,
    /// extra pipeline bubbles (e.g. the vmul second-cycle stall)
    pub bubble: u32,
}

impl Instr {
    pub fn cost(&self) -> InstrCost {
        match self {
            Instr::LdQ { .. } => InstrCost { alu: 0, mem: 1, bubble: 0 },
            Instr::StQ { .. } => InstrCost { alu: 0, mem: 1, bubble: 0 },
            Instr::VmovZ { .. } => InstrCost { alu: 1, mem: 0, bubble: 0 },
            Instr::Vand { .. } => InstrCost { alu: 1, mem: 0, bubble: 0 },
            Instr::VmacP { .. } => InstrCost { alu: 1, mem: 0, bubble: 0 },
            // MUL returns over two cycles with an auto-inserted bubble
            // (Sec. III-D).
            Instr::VmulP { .. } => InstrCost { alu: 2, mem: 0, bubble: 1 },
            Instr::Vaddq16 { .. } => InstrCost { alu: 1, mem: 0, bubble: 0 },
            Instr::ReduceAcc { .. } => InstrCost { alu: 2, mem: 2, bubble: 0 },
            // unpack-correct-accumulate epilogue for depthwise products
            Instr::MulAcc { .. } => InstrCost { alu: 4, mem: 1, bubble: 0 },
            Instr::VfmaF32 { .. } => InstrCost { alu: 1, mem: 0, bubble: 0 },
            Instr::VmacI8 { .. } => InstrCost { alu: 1, mem: 0, bubble: 0 },
        }
    }

    /// Memory operand, if any.
    pub fn addr(&self) -> Option<(Addr, bool)> {
        match self {
            Instr::LdQ { addr, .. } => Some((*addr, false)),
            Instr::StQ { addr, .. } => Some((*addr, true)),
            Instr::ReduceAcc { addr, .. } => Some((*addr, true)),
            Instr::MulAcc { addr, .. } => Some((*addr, true)),
            _ => None,
        }
    }
}

/// Encode an instruction word per Fig. 6 (for the decoder round-trip test
/// and the I-cache footprint model; the simulator executes the structured
/// form). Layout: [31:21] opcode, [20:16] Qn, [15:11] Qm, [10:5] Pn,
/// [4:0] Qd.
pub fn encode(i: &Instr) -> u32 {
    let (op, qn, qm, pn, qd) = match *i {
        Instr::LdQ { dst, .. } => (0b000_0000_0001u32, 0, 0, 0, dst),
        Instr::StQ { src, .. } => (0b000_0000_0010, src, 0, 0, 0),
        Instr::VmovZ { dst } => (0b000_0000_0011, 0, 0, 0, dst),
        Instr::Vand { dst, a, b } => (0b000_0000_0100, a, b, 0, dst),
        Instr::VmacP { dst, a, b, pat } => (0b100_0000_0000, a, b, pat, dst),
        Instr::VmulP { dst, a, b, pat, .. } => (0b100_0000_0001, a, b, pat, dst),
        Instr::Vaddq16 { dst, a, b } => (0b000_0000_0101, a, b, 0, dst),
        Instr::ReduceAcc { src, .. } => (0b000_0000_0110, src, 0, 0, 0),
        Instr::MulAcc { lo, hi, pat, .. } => (0b100_0000_0010, lo, hi, pat, 0),
        Instr::VfmaF32 { dst, a, b } => (0b000_0000_0111, a, b, 0, dst),
        Instr::VmacI8 { dst, a, b } => (0b000_0000_1000, a, b, 0, dst),
    };
    (op << 21) | ((qn as u32) << 16) | ((qm as u32) << 11) | ((pn as u32) << 5) | qd as u32
}

/// One-hot precision control signals for all 8 lanes from a pattern index
/// (Listing 1/3's `one_hot_precision_decoder`): 3 bits per lane,
/// 0b001 = 1-bit, 0b010 = 2-bit, 0b100 = 4-bit.
pub fn one_hot_precision_decoder(pattern: &crate::simd::patterns::Pattern) -> [u8; 8] {
    let mut out = [0u8; 8];
    for (o, p) in out.iter_mut().zip(pattern.lane_precisions()) {
        *o = match p {
            1 => 0b001,
            2 => 0b010,
            4 => 0b100,
            _ => unreachable!(),
        };
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::patterns::Pattern;

    #[test]
    fn encoding_fields_fit() {
        let i = Instr::VmacP { dst: 31, a: 30, b: 29, pat: 44 };
        let w = encode(&i);
        assert_eq!(w >> 21, 0b100_0000_0000);
        assert_eq!((w >> 16) & 0x1F, 30);
        assert_eq!((w >> 11) & 0x1F, 29);
        assert_eq!((w >> 5) & 0x3F, 44);
        assert_eq!(w & 0x1F, 31);
    }

    #[test]
    fn one_hot_decoder_uniform() {
        assert_eq!(one_hot_precision_decoder(&Pattern::uniform(4)), [0b100; 8]);
        assert_eq!(one_hot_precision_decoder(&Pattern::uniform(1)), [0b001; 8]);
        // P3 = (0,16,24): 6 4-bit lanes then 2 2-bit lanes (Listing 3)
        let p3 = Pattern::new(0, 16, 24);
        assert_eq!(
            one_hot_precision_decoder(&p3),
            [0b100, 0b100, 0b100, 0b100, 0b100, 0b100, 0b010, 0b010]
        );
    }
}
