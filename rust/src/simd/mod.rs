//! The configurable ultra-low-precision SIMD architecture (paper Sec. III):
//! precision patterns (Table II), the bit-exact configurable ALU (Fig. 3),
//! 128-bit vector registers with SMOL code packing, and the extended ISA
//! (`vmac_Pn` / `vmul_Pn`, Fig. 6).

pub mod alu;
pub mod isa;
pub mod patterns;
pub mod vector;

pub use patterns::{all_patterns, design_subset, Pattern};
pub use vector::V128;
