//! Bit-exact model of the configurable, ultra-low-precision ALU (Fig. 3).
//!
//! Each 16-bit lane is configured to one precision and performs:
//! - MAC: four 4-bit, eight 2-bit, or sixteen 1-bit multiplies, reduced to
//!   one signed 16-bit sum in the 16.6 fixed-point format (units of 2^-6).
//! - MUL: the individual products, returned over two cycles through the
//!   staging register with the always-1 LSB dropped for 2/4-bit products
//!   (Sec. III-C); software corrects with sign-extend, x2, +1.
//!
//! Datapath structure mirrors the paper:
//! - 1-bit: XNOR + pre-accumulated pairs (Eq. 1-2).
//! - 2-bit: direct 5-bit signed products (Eq. 3).
//! - 4-bit: radix-4 Booth multiplication (Eq. 4-6) — implemented as the
//!   actual Booth digit decomposition (asserted against the direct
//!   product), with the CSA compression tree modeled in `hw::gates` for
//!   cost and in the shared reduction below for value.

use crate::simd::patterns::{Pattern, NUM_LANES};
use crate::simd::vector::V128;

/// Per-lane precision configuration, derived from the instruction's
/// pattern index by the ALU config control block (Listing 3).
pub type LaneConfig = [u8; NUM_LANES];

/// Signed SMOL mantissa of an n-bit code: `m = 2u - (2^n - 1)` (odd).
#[inline]
pub fn mantissa(code: u32, p: u8) -> i32 {
    2 * code as i32 - ((1i32 << p) - 1)
}

/// Radix-4 Booth multiply of two 4-bit-precision mantissas (5-bit signed
/// values in [-15, 15]). Returns the 9-bit signed product.
///
/// The multiplier is recoded into three radix-4 Booth digits in {-2..2}
/// (Eq. 5-6); each digit selects a partial product of the 5-bit
/// multiplicand (Eq. 4); partial products are summed (hardware: 3:2 CSA
/// with the half-adder "hole" for the hot-1 sign, then the shared 4:2
/// tree + CPA).
#[inline]
pub fn booth_mul_4bit(mn: i32, mm: i32) -> i32 {
    debug_assert!((-15..=15).contains(&mn) && mn % 2 != 0);
    debug_assert!((-15..=15).contains(&mm) && mm % 2 != 0);
    // 5-bit two's complement of the multiplier, sign-extended to 6 bits,
    // with an implicit 0 appended below the LSB.
    let b = (mm as u32) & 0x3F; // 6-bit view (sign-extended within 6 bits)
    let bit = |i: i32| -> i32 {
        if i < 0 {
            0
        } else if i >= 5 {
            ((mm >> 4) & 1) as i32 // sign extension
        } else {
            ((b >> i) & 1) as i32
        }
    };
    let mut acc: i32 = 0;
    for d in 0..3 {
        let i = 2 * d as i32;
        // Booth digit from bits (2i+1, 2i, 2i-1): -2*b_{i+1} + b_i + b_{i-1}
        let digit = -2 * bit(i + 1) + bit(i) + bit(i - 1);
        // partial product, weighted 4^d (12-bit in hardware)
        acc += digit * mn * (1 << (2 * d));
    }
    debug_assert_eq!(acc, mn * mm, "booth mismatch {mn}*{mm}");
    acc
}

/// Precomputed 4-bit x 4-bit product table, indexed by (code_a << 4) |
/// code_b. Built from the same mantissa map as the Booth datapath (perf
/// fast path; §Perf in EXPERIMENTS.md — equality with `booth_mul_4bit`
/// is unit-tested for all 256 entries).
static PROD4: [i16; 256] = {
    let mut t = [0i16; 256];
    let mut a = 0usize;
    while a < 16 {
        let mut b = 0usize;
        while b < 16 {
            let ma = 2 * a as i32 - 15;
            let mb = 2 * b as i32 - 15;
            t[(a << 4) | b] = (ma * mb) as i16;
            b += 1;
        }
        a += 1;
    }
    t
};

/// One lane's MAC: multiply packed operand pairs and reduce to a signed
/// sum in 2^-6 fixed-point units. `p` is the lane precision.
#[inline]
pub fn mac_lane(qn: u16, qm: u16, p: u8) -> i16 {
    match p {
        4 => {
            // four 4-bit pairs via the product LUT (== Booth datapath)
            let mut acc: i32 = 0;
            let (mut n, mut m) = (qn, qm);
            for _ in 0..4 {
                acc += PROD4[(((n & 0xF) << 4) | (m & 0xF)) as usize] as i32;
                n >>= 4;
                m >>= 4;
            }
            acc as i16
        }
        2 => {
            // eight 2-bit pairs; product units 2^-2 -> shift left 4
            let mut acc: i32 = 0;
            for k in 0..8 {
                let a = mantissa(((qn >> (2 * k)) & 0x3) as u32, 2);
                let b = mantissa(((qm >> (2 * k)) & 0x3) as u32, 2);
                acc += a * b; // 5-bit signed product (Eq. 3)
            }
            (acc << 4) as i16
        }
        1 => {
            // sixteen 1-bit pairs via XNOR, pre-accumulated in pairs
            // (Eq. 1-2); product units 2^0 -> shift left 6
            let xnor = !(qn ^ qm);
            // sum of (2*bit - 1) over 16 bits = 2*popcount - 16
            let acc = 2 * xnor.count_ones() as i32 - 16;
            (acc << 6) as i16
        }
        _ => panic!("unsupported lane precision {p}"),
    }
}

/// Full-vector MAC under a precision pattern: returns eight 16.6 lane sums.
pub fn vmac(qn: &V128, qm: &V128, pattern: &Pattern) -> V128 {
    let lanes = pattern.lane_precisions();
    let mut out = [0i16; NUM_LANES];
    for (i, &p) in lanes.iter().enumerate() {
        out[i] = mac_lane(qn.lanes[i], qm.lanes[i], p);
    }
    V128::from_i16(out)
}

/// One lane's MUL: individual products packed into a 32-bit staging value
/// (Listing 2). 4-bit: 4 x 8-bit encoded products; 2-bit: 8 x 4-bit;
/// 1-bit: 16 x 2-bit two's-complement products (no LSB drop).
#[inline]
pub fn mul_lane(qn: u16, qm: u16, p: u8) -> u32 {
    match p {
        4 => {
            let mut buf: u32 = 0;
            for k in 0..4 {
                let a = mantissa(((qn >> (4 * k)) & 0xF) as u32, 4);
                let b = mantissa(((qm >> (4 * k)) & 0xF) as u32, 4);
                let prod = booth_mul_4bit(a, b); // odd, 9-bit signed
                let enc = ((prod >> 1) & 0xFF) as u32; // drop always-1 LSB
                buf |= enc << (8 * k);
            }
            buf
        }
        2 => {
            let mut buf: u32 = 0;
            for k in 0..8 {
                let a = mantissa(((qn >> (2 * k)) & 0x3) as u32, 2);
                let b = mantissa(((qm >> (2 * k)) & 0x3) as u32, 2);
                let prod = a * b; // odd, 5-bit signed
                let enc = ((prod >> 1) & 0xF) as u32;
                buf |= enc << (4 * k);
            }
            buf
        }
        1 => {
            let mut buf: u32 = 0;
            for k in 0..16 {
                let a = (qn >> k) & 1;
                let b = (qm >> k) & 1;
                // product is +1 (0b01) iff bits match, else -1 (0b11)
                let enc: u32 = if a == b { 0b01 } else { 0b11 };
                buf |= enc << (2 * k);
            }
            buf
        }
        _ => panic!("unsupported lane precision {p}"),
    }
}

/// Full-vector MUL: returns (cycle-1 vector, cycle-2 vector) — lower and
/// upper 16 bits of each lane's 32-bit staging buffer (Listing 2 +
/// Sec. III-D two-cycle return through the staging register).
pub fn vmul(qn: &V128, qm: &V128, pattern: &Pattern) -> (V128, V128) {
    let lanes = pattern.lane_precisions();
    let mut lo = [0u16; NUM_LANES];
    let mut hi = [0u16; NUM_LANES];
    for (i, &p) in lanes.iter().enumerate() {
        let buf = mul_lane(qn.lanes[i], qm.lanes[i], p);
        lo[i] = (buf & 0xFFFF) as u16;
        hi[i] = (buf >> 16) as u16;
    }
    (V128::from_lanes(lo), V128::from_lanes(hi))
}

/// Software correction for an encoded 2/4-bit MUL product (Sec. III-C):
/// sign-extend the `width`-bit encoding, multiply by two and add one.
#[inline]
pub fn mul_correct(enc: u32, width: u32) -> i32 {
    let shift = 32 - width;
    let se = ((enc << shift) as i32) >> shift;
    2 * se + 1
}

/// Decode all products of a two-cycle MUL result for one lane.
pub fn decode_mul_lane(lo: u16, hi: u16, p: u8) -> Vec<i32> {
    let buf = (lo as u32) | ((hi as u32) << 16);
    match p {
        4 => (0..4).map(|k| mul_correct((buf >> (8 * k)) & 0xFF, 8)).collect(),
        2 => (0..8).map(|k| mul_correct((buf >> (4 * k)) & 0xF, 4)).collect(),
        1 => (0..16)
            .map(|k| {
                let enc = (buf >> (2 * k)) & 0x3;
                ((enc << 30) as i32) >> 30 // 2-bit two's complement as-is
            })
            .collect(),
        _ => panic!("unsupported lane precision {p}"),
    }
}

// ---- existing ARM NEON instructions used by the paper's kernel ----

/// `vaddq_s16`: lanewise signed 16-bit add (wrapping, as on ARM).
pub fn vaddq_s16(a: &V128, b: &V128) -> V128 {
    let mut out = [0i16; NUM_LANES];
    let (ai, bi) = (a.as_i16(), b.as_i16());
    for i in 0..NUM_LANES {
        out[i] = ai[i].wrapping_add(bi[i]);
    }
    V128::from_i16(out)
}

/// `vpaddlq_s16`: add adjacent pairs of signed 16-bit into four i32.
pub fn vpaddlq_s16(a: &V128) -> [i32; 4] {
    let ai = a.as_i16();
    [
        ai[0] as i32 + ai[1] as i32,
        ai[2] as i32 + ai[3] as i32,
        ai[4] as i32 + ai[5] as i32,
        ai[6] as i32 + ai[7] as i32,
    ]
}

/// `vaddvq_s32`: horizontal sum of four i32 to one i32.
pub fn vaddvq_s32(a: [i32; 4]) -> i32 {
    a[0].wrapping_add(a[1]).wrapping_add(a[2]).wrapping_add(a[3])
}

/// The full reduction the paper's kernel performs on a 16.6 accumulator
/// vector: `vaddvq_s32(vpaddlq_s16(acc))` -> one i32 in 2^-6 units.
pub fn reduce_acc(acc: &V128) -> i32 {
    vaddvq_s32(vpaddlq_s16(acc))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::patterns::all_patterns;
    use crate::simd::vector::pack_values;
    use crate::smol::quant;

    fn all_values(p: u8) -> Vec<f32> {
        (0..1u32 << p).map(|u| quant::code_to_value(u, p)).collect()
    }

    #[test]
    fn booth_exhaustive() {
        for a in (-15..=15).step_by(2) {
            for b in (-15..=15).step_by(2) {
                assert_eq!(booth_mul_4bit(a, b), a * b);
            }
        }
    }

    #[test]
    fn prod4_lut_matches_booth_datapath() {
        for ca in 0u32..16 {
            for cb in 0u32..16 {
                let want = booth_mul_4bit(mantissa(ca, 4), mantissa(cb, 4));
                assert_eq!(PROD4[((ca << 4) | cb) as usize] as i32, want);
            }
        }
    }

    #[test]
    fn mac_lane_exhaustive_small() {
        // 1-bit lane: all 2^16 x selected qm patterns would be 2^32; use
        // structured sweep instead.
        for qn in [0u16, 0xFFFF, 0xAAAA, 0x5555, 0x1234, 0x8001] {
            for qm in [0u16, 0xFFFF, 0xAAAA, 0x5555, 0x4321, 0x7FFF] {
                let want: i32 = (0..16)
                    .map(|k| {
                        let a = if (qn >> k) & 1 == 1 { 1i32 } else { -1 };
                        let b = if (qm >> k) & 1 == 1 { 1i32 } else { -1 };
                        a * b * 64
                    })
                    .sum();
                assert_eq!(mac_lane(qn, qm, 1) as i32, want);
            }
        }
    }

    #[test]
    fn mac_lane_matches_float_all_precisions() {
        let mut rng = 0x12345678u64;
        let mut next = move || {
            rng ^= rng << 13;
            rng ^= rng >> 7;
            rng ^= rng << 17;
            rng
        };
        for p in [1u8, 2, 4] {
            let vals = all_values(p);
            let n = 16 / p as usize;
            for _ in 0..200 {
                let a: Vec<f32> = (0..n).map(|_| vals[(next() as usize) % vals.len()]).collect();
                let b: Vec<f32> = (0..n).map(|_| vals[(next() as usize) % vals.len()]).collect();
                let mut qn = 0u16;
                let mut qm = 0u16;
                for k in 0..n {
                    qn |= (quant::value_to_code(a[k], p) as u16) << (k * p as usize);
                    qm |= (quant::value_to_code(b[k], p) as u16) << (k * p as usize);
                }
                let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
                let got = mac_lane(qn, qm, p) as f32 / 64.0;
                assert_eq!(got, want, "p={p} a={a:?} b={b:?}");
            }
        }
    }

    #[test]
    fn vmac_matches_unpacked_dot_all_patterns() {
        let mut seed = 42u64;
        let mut next = move || {
            seed ^= seed << 13;
            seed ^= seed >> 7;
            seed ^= seed << 17;
            seed
        };
        for pat in all_patterns() {
            let gen = |next: &mut dyn FnMut() -> u64| -> Vec<f32> {
                (0..pat.capacity())
                    .map(|i| {
                        let p = pat.element_precision(i);
                        quant::code_to_value((next() as u32) & ((1 << p) - 1), p)
                    })
                    .collect()
            };
            let a = gen(&mut next);
            let b = gen(&mut next);
            let va = pack_values(&pat, &a);
            let vb = pack_values(&pat, &b);
            let sum = reduce_acc(&vmac(&va, &vb, &pat)) as f32 / 64.0;
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert_eq!(sum, want, "pattern {pat:?}");
        }
    }

    #[test]
    fn mul_roundtrip_all_precisions() {
        for p in [1u8, 2, 4] {
            let vals = all_values(p);
            let n = 16 / p as usize;
            // exhaustive over single-slot pairs
            for &x in &vals {
                for &y in &vals {
                    let mut qn = 0u16;
                    let mut qm = 0u16;
                    qn |= (quant::value_to_code(x, p) as u16) << 0;
                    qm |= (quant::value_to_code(y, p) as u16) << 0;
                    let buf = mul_lane(qn, qm, p);
                    let prods = decode_mul_lane((buf & 0xFFFF) as u16, (buf >> 16) as u16, p);
                    assert_eq!(prods.len(), n);
                    // slot 0 carries x*y in mantissa units (2^{2-2p} each)
                    let unit = quant::step_for(p) * quant::step_for(p);
                    assert_eq!(prods[0] as f32 * unit, x * y, "p={p} {x}*{y}");
                }
            }
        }
    }

    #[test]
    fn lane_sums_fit_16_6() {
        // max per-lane sums: 4*225 = 900, 8*9*16 = 1152, 16*64 = 1024 (in
        // 2^-6 units) — all well inside i16.
        let max4 = 4 * 225;
        let max2 = 8 * 9 << 4;
        let max1 = 16i32 << 6;
        assert!(max4 < i16::MAX as i32 && max2 < i16::MAX as i32 && max1 < i16::MAX as i32);
    }
}
