//! 128-bit vector register model and SMOL code packing.
//!
//! A [`V128`] is eight 16-bit lanes. For low-precision data, each lane
//! packs 4/8/16 SMOL codes of 4/2/1 bits (per its configured precision),
//! element 0 in the least-significant bits of lane 0 (little-endian within
//! the lane, lanes ordered low to high). A vector's element layout is
//! given by a [`Pattern`]: all 4-bit elements first, then 2-bit, then
//! 1-bit (Observation 4 grouping).

use crate::simd::patterns::{Pattern, NUM_LANES};
use crate::smol::quant;

/// One 128-bit vector register (eight 16-bit lanes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct V128 {
    pub lanes: [u16; NUM_LANES],
}

impl V128 {
    pub const ZERO: V128 = V128 { lanes: [0; 8] };

    pub fn from_lanes(lanes: [u16; NUM_LANES]) -> Self {
        V128 { lanes }
    }

    pub fn from_i16(vals: [i16; NUM_LANES]) -> Self {
        let mut lanes = [0u16; NUM_LANES];
        for (l, v) in lanes.iter_mut().zip(vals) {
            *l = v as u16;
        }
        V128 { lanes }
    }

    pub fn as_i16(&self) -> [i16; NUM_LANES] {
        let mut out = [0i16; NUM_LANES];
        for (o, l) in out.iter_mut().zip(self.lanes) {
            *o = l as i16;
        }
        out
    }

    pub fn to_bytes(&self) -> [u8; 16] {
        let mut b = [0u8; 16];
        for (i, l) in self.lanes.iter().enumerate() {
            b[2 * i..2 * i + 2].copy_from_slice(&l.to_le_bytes());
        }
        b
    }

    pub fn from_bytes(b: &[u8]) -> Self {
        let mut lanes = [0u16; NUM_LANES];
        for (i, l) in lanes.iter_mut().enumerate() {
            *l = u16::from_le_bytes([b[2 * i], b[2 * i + 1]]);
        }
        V128 { lanes }
    }

    pub fn and(&self, other: &V128) -> V128 {
        let mut lanes = [0u16; NUM_LANES];
        for (l, (a, b)) in lanes.iter_mut().zip(self.lanes.iter().zip(other.lanes)) {
            *l = a & b;
        }
        V128 { lanes }
    }

    /// Read the `idx`-th element under `pattern` as an unsigned code.
    pub fn get_code(&self, pattern: &Pattern, idx: u32) -> u32 {
        let (lane, slot, width) = element_slot(pattern, idx);
        let mask = (1u32 << width) - 1;
        ((self.lanes[lane] as u32) >> (slot * width)) & mask
    }

    /// Write the `idx`-th element under `pattern` as an unsigned code.
    pub fn set_code(&mut self, pattern: &Pattern, idx: u32, code: u32) {
        let (lane, slot, width) = element_slot(pattern, idx);
        let mask = ((1u32 << width) - 1) << (slot * width);
        let l = self.lanes[lane] as u32;
        self.lanes[lane] = ((l & !mask) | ((code << (slot * width)) & mask)) as u16;
    }
}

/// (lane, slot-within-lane, bit-width) of element `idx` under `pattern`.
fn element_slot(pattern: &Pattern, idx: u32) -> (usize, u32, u32) {
    let n4 = pattern.n4 as u32;
    let n2 = pattern.n2 as u32;
    let l4 = n4 / 4; // 4-bit lanes
    let l2 = n2 / 8;
    if idx < n4 {
        ((idx / 4) as usize, idx % 4, 4)
    } else if idx < n4 + n2 {
        let j = idx - n4;
        ((l4 + j / 8) as usize, j % 8, 2)
    } else {
        let j = idx - n4 - n2;
        ((l4 + l2 + j / 16) as usize, j % 16, 1)
    }
}

/// Pack quantized SMOL values into a vector under `pattern`.
///
/// `values[i]` must already be quantized to `pattern.element_precision(i)`;
/// missing tail values (fewer than capacity) are packed as code 0 and must
/// be masked by the caller (Algorithm 4's `vand` tail handling).
pub fn pack_values(pattern: &Pattern, values: &[f32]) -> V128 {
    let mut v = V128::ZERO;
    for idx in 0..pattern.capacity() {
        let p = pattern.element_precision(idx);
        let code = match values.get(idx as usize) {
            Some(&x) => quant::value_to_code(x, p),
            None => 0,
        };
        v.set_code(pattern, idx, code);
    }
    v
}

/// Unpack a vector into SMOL values under `pattern`.
pub fn unpack_values(pattern: &Pattern, v: &V128) -> Vec<f32> {
    (0..pattern.capacity())
        .map(|i| quant::code_to_value(v.get_code(pattern, i), pattern.element_precision(i)))
        .collect()
}

/// Tail mask: a vector with all-ones for the first `n_valid` elements of
/// `pattern` and zeros after — both operands of a masked `vmac` are ANDed
/// with this so out-of-range elements contribute code 0 x code 0.
///
/// NOTE: code 0 is NOT value 0 in SMOL (there is no zero), so masking both
/// operands makes tail products equal (+1-ish constants); the generated
/// code instead *subtracts a precomputed tail bias* — see
/// `codegen::tail_bias`. This mirrors the paper's `vand` + correction.
pub fn tail_mask(pattern: &Pattern, n_valid: u32) -> V128 {
    let mut m = V128::ZERO;
    for idx in 0..n_valid.min(pattern.capacity()) {
        let p = pattern.element_precision(idx);
        m.set_code(pattern, idx, (1u32 << p) - 1);
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::patterns::all_patterns;

    #[test]
    fn pack_unpack_roundtrip_all_patterns() {
        for pat in all_patterns() {
            let vals: Vec<f32> = (0..pat.capacity())
                .map(|i| {
                    let p = pat.element_precision(i);
                    let codes = 1u32 << p;
                    quant::code_to_value(i % codes, p)
                })
                .collect();
            let v = pack_values(&pat, &vals);
            let back = unpack_values(&pat, &v);
            assert_eq!(vals, back, "pattern {pat:?}");
        }
    }

    #[test]
    fn bytes_roundtrip() {
        let v = V128::from_lanes([1, 2, 0xFFFF, 4, 5, 6, 7, 0x8000]);
        assert_eq!(V128::from_bytes(&v.to_bytes()), v);
    }

    #[test]
    fn element_slots_disjoint() {
        for pat in all_patterns() {
            let mut used = [0u16; NUM_LANES];
            for idx in 0..pat.capacity() {
                let (lane, slot, w) = element_slot(&pat, idx);
                let mask = (((1u32 << w) - 1) << (slot * w)) as u16;
                assert_eq!(used[lane] & mask, 0, "overlap in {pat:?} at {idx}");
                used[lane] |= mask;
            }
            // all 128 bits covered
            assert!(used.iter().all(|&m| m == 0xFFFF), "{pat:?}");
        }
    }
}
