//! The execution engine: runs generated instruction streams both
//! *functionally* (bit-exact through the Fig. 3 ALU model) and for
//! *timing* (decoupled vector/memory pipelines + Table IV caches, a
//! substitute for the authors' gem5 O3 setup).
//!
//! Timing model: the O3 core's scalar front end dual-issues; the vector
//! unit and the (decoupled) vector memory pipeline run in parallel
//! (Fig. 4), so a layer's cycle count is
//!
//!   max(issue_slots/2, vector_alu_cycles, memory_cycles) + bubbles
//!
//! where memory cycles include cache hit/miss latencies with half of the
//! miss latency assumed hidden by the out-of-order window.

use crate::sim::cache::{Hierarchy, Level};
use crate::sim::energy::EnergyConfig;
use crate::simd::alu;
use crate::simd::isa::{Addr, BufId, Instr, NUM_VREGS};
use crate::simd::patterns::Pattern;
use crate::simd::vector::V128;

/// A simulated memory buffer (byte-addressed, with a global base for the
/// cache model).
pub struct Buffer {
    pub data: Vec<u8>,
    pub base: u64,
}

/// Run statistics for one program execution.
#[derive(Debug, Clone, Copy, Default)]
pub struct RunStats {
    pub instrs: u64,
    pub vmac: u64,
    pub vmul: u64,
    pub vfma32: u64,
    pub vmac_i8: u64,
    pub vec_simple: u64,
    pub loads: u64,
    pub stores: u64,
    pub alu_cycles: u64,
    pub mem_cycles: u64,
    pub bubbles: u64,
    pub l1_hits: u64,
    pub l2_hits: u64,
    pub mem_accesses: u64,
    pub energy_pj: f64,
}

impl RunStats {
    /// Total cycles under the decoupled-pipeline model.
    pub fn cycles(&self) -> u64 {
        let issue = self.instrs.div_ceil(2);
        issue.max(self.alu_cycles).max(self.mem_cycles) + self.bubbles
    }

    pub fn merge(&mut self, o: &RunStats) {
        self.instrs += o.instrs;
        self.vmac += o.vmac;
        self.vmul += o.vmul;
        self.vfma32 += o.vfma32;
        self.vmac_i8 += o.vmac_i8;
        self.vec_simple += o.vec_simple;
        self.loads += o.loads;
        self.stores += o.stores;
        self.alu_cycles += o.alu_cycles;
        self.mem_cycles += o.mem_cycles;
        self.bubbles += o.bubbles;
        self.l1_hits += o.l1_hits;
        self.l2_hits += o.l2_hits;
        self.mem_accesses += o.mem_accesses;
        self.energy_pj += o.energy_pj;
    }

    /// Add a bulk epilogue/packing cost: `n` element-wise fp operations
    /// (vectorized 4-wide) plus `bytes` of streaming memory traffic.
    pub fn add_bulk(&mut self, n_elems: u64, bytes: u64, energy: &EnergyConfig) {
        let vec_ops = n_elems.div_ceil(4) * 3; // scale+shift+relu style
        self.instrs += vec_ops + bytes.div_ceil(16);
        self.vec_simple += vec_ops;
        self.alu_cycles += vec_ops;
        self.mem_cycles += bytes.div_ceil(16) * 2; // streaming, L1-resident
        self.energy_pj += vec_ops as f64 * energy.vec_simple
            + bytes.div_ceil(64) as f64 * energy.l1_access;
    }
}

/// The machine: vector register file + buffers + caches + stats.
pub struct Machine {
    pub vregs: [V128; NUM_VREGS],
    pub buffers: Vec<Buffer>,
    pub patterns: Vec<Pattern>,
    pub cache: Hierarchy,
    pub energy_cfg: EnergyConfig,
    pub stats: RunStats,
    next_base: u64,
    pc: u64,
    /// freed buffer-id slots awaiting reuse (see [`Machine::free`])
    free_slots: Vec<u16>,
    /// optional buffer-byte budget: [`Machine::alloc`] refuses to grow
    /// resident bytes past it (a worker machine models finite on-device
    /// memory — models too wide for it deploy sharded instead)
    capacity: Option<usize>,
}

impl Default for Machine {
    fn default() -> Self {
        Self::new()
    }
}

impl Machine {
    pub fn new() -> Self {
        Machine {
            vregs: [V128::ZERO; NUM_VREGS],
            buffers: Vec::new(),
            patterns: Vec::new(),
            cache: Hierarchy::default(),
            energy_cfg: EnergyConfig::default(),
            stats: RunStats::default(),
            next_base: 0x1000_0000,
            pc: 0x40_0000,
            free_slots: Vec::new(),
            capacity: None,
        }
    }

    /// A machine with a finite buffer budget: allocations past `bytes`
    /// of live buffer memory panic. Serving workers run under this to
    /// model per-machine memory — a layer that cannot bind within the
    /// budget must be deployed sharded across machines instead.
    pub fn with_capacity(bytes: usize) -> Self {
        let mut m = Machine::new();
        m.capacity = Some(bytes);
        m
    }

    /// This machine's buffer-byte budget, if any.
    pub fn capacity(&self) -> Option<usize> {
        self.capacity
    }

    /// Allocate a buffer of `bytes`, returning its id. Freed id slots
    /// are recycled (at a fresh base address), so sustained bind/evict
    /// churn is bounded by the *peak live* buffer count, not the total
    /// ever allocated. Panics if the allocation would exceed the
    /// machine's buffer budget (see [`Machine::with_capacity`]).
    pub fn alloc(&mut self, bytes: usize) -> BufId {
        if let Some(cap) = self.capacity {
            let live = self.resident_bytes();
            assert!(
                live + bytes <= cap,
                "machine buffer budget exceeded: {live} B live + {bytes} B requested > \
                 {cap} B capacity (deploy the model sharded across workers)"
            );
        }
        let base = self.next_base;
        // 4 KiB-align buffer bases so distinct buffers never share
        // lines; freed slots still get a fresh base, so a recycled id
        // never aliases a previous tenant's cached lines
        self.next_base += ((bytes as u64 + 4095) / 4096) * 4096 + 4096;
        if let Some(slot) = self.free_slots.pop() {
            self.buffers[slot as usize] = Buffer { data: vec![0u8; bytes], base };
            return BufId(slot);
        }
        self.buffers.push(Buffer { data: vec![0u8; bytes], base });
        assert!(self.buffers.len() <= u16::MAX as usize, "machine buffer ids exhausted");
        BufId((self.buffers.len() - 1) as u16)
    }

    /// Release a buffer's backing bytes (model eviction) and recycle
    /// its id slot for a later `alloc`. Until then the slot is empty,
    /// so any further access through the stale id is a bounds panic
    /// rather than a silent read of stale data. Each id must be freed
    /// at most once per tenancy (a double free would hand one slot to
    /// two future allocations).
    pub fn free(&mut self, buf: BufId) {
        debug_assert!(!self.free_slots.contains(&buf.0), "double free of buffer {}", buf.0);
        self.buffers[buf.0 as usize].data = Vec::new();
        self.free_slots.push(buf.0);
    }

    /// Bytes currently backing machine buffers (freed buffers count 0).
    pub fn resident_bytes(&self) -> usize {
        self.buffers.iter().map(|b| b.data.len()).sum()
    }

    pub fn write_bytes(&mut self, buf: BufId, off: usize, bytes: &[u8]) {
        self.buffers[buf.0 as usize].data[off..off + bytes.len()].copy_from_slice(bytes);
    }

    /// Zero a buffer in place (reused accumulator scratch between runs;
    /// functional only, no cache traffic — fresh allocations are zeroed
    /// the same way).
    pub fn clear_buffer(&mut self, buf: BufId) {
        self.buffers[buf.0 as usize].data.fill(0);
    }

    /// Charge a bulk epilogue/packing pass against this machine's energy
    /// model (avoids cloning the energy config at every call site).
    pub fn charge_bulk(&mut self, n_elems: u64, bytes: u64) {
        self.stats.add_bulk(n_elems, bytes, &self.energy_cfg);
    }

    pub fn read_i32(&self, buf: BufId, off: usize) -> i32 {
        let d = &self.buffers[buf.0 as usize].data;
        i32::from_le_bytes([d[off], d[off + 1], d[off + 2], d[off + 3]])
    }

    pub fn write_i32(&mut self, buf: BufId, off: usize, v: i32) {
        self.buffers[buf.0 as usize].data[off..off + 4].copy_from_slice(&v.to_le_bytes());
    }

    fn global_addr(&self, a: Addr) -> u64 {
        self.buffers[a.buf.0 as usize].base + a.off as u64
    }

    fn touch_mem(&mut self, a: Addr, bytes: u64, store: bool) {
        let ga = self.global_addr(a);
        let (lvl, lat) = self.cache.access_data(ga, bytes);
        // half of miss latency assumed hidden by the OOO window
        let charged = match lvl {
            Level::L1 => lat,
            _ => self.cache.lat.l1_hit + (lat - self.cache.lat.l1_hit) / 2,
        };
        self.stats.mem_cycles += charged;
        match lvl {
            Level::L1 => {
                self.stats.l1_hits += 1;
                self.stats.energy_pj += self.energy_cfg.l1_access;
            }
            Level::L2 => {
                self.stats.l2_hits += 1;
                self.stats.energy_pj += self.energy_cfg.l2_access;
            }
            Level::Mem => {
                self.stats.mem_accesses += 1;
                self.stats.energy_pj += self.energy_cfg.mem_access;
            }
        }
        if store {
            self.stats.stores += 1;
        } else {
            self.stats.loads += 1;
        }
    }

    /// Charge a streaming pass over `[0, len)` of a buffer through the
    /// cache (used for the epilogue quantize/re-pack passes between
    /// layers; functional writes go through `write_bytes`).
    pub fn stream_touch(&mut self, buf: BufId, len: usize, store: bool) {
        let mut off = 0usize;
        while off < len {
            self.touch_mem(Addr { buf, off: off as u32 }, 64, store);
            off += 64;
        }
    }

    /// Execute one instruction (functional + timing).
    pub fn exec(&mut self, i: &Instr) {
        let cost = i.cost();
        self.stats.instrs += 1;
        self.stats.alu_cycles += cost.alu as u64;
        self.stats.bubbles += cost.bubble as u64;
        // i-cache: 4-byte instruction words. Generated kernels are loop
        // bodies (Algorithm 4), so the fetch stream revisits a small
        // footprint; model an 8 KiB rolling loop window.
        self.pc = 0x40_0000 + (self.stats.instrs % 2048) * 4;
        self.stats.mem_cycles += self.cache.access_inst(self.pc);

        match *i {
            Instr::LdQ { dst, addr } => {
                self.touch_mem(addr, 16, false);
                let d = &self.buffers[addr.buf.0 as usize].data;
                let off = addr.off as usize;
                self.vregs[dst as usize] = V128::from_bytes(&d[off..off + 16]);
            }
            Instr::StQ { src, addr } => {
                self.touch_mem(addr, 16, true);
                let bytes = self.vregs[src as usize].to_bytes();
                self.write_bytes(addr.buf, addr.off as usize, &bytes);
            }
            Instr::VmovZ { dst } => {
                self.vregs[dst as usize] = V128::ZERO;
                self.stats.vec_simple += 1;
                self.stats.energy_pj += self.energy_cfg.vec_simple;
            }
            Instr::Vand { dst, a, b } => {
                self.vregs[dst as usize] = self.vregs[a as usize].and(&self.vregs[b as usize]);
                self.stats.vec_simple += 1;
                self.stats.energy_pj += self.energy_cfg.vec_simple;
            }
            Instr::VmacP { dst, a, b, pat } => {
                let p = self.patterns[pat as usize];
                self.vregs[dst as usize] =
                    alu::vmac(&self.vregs[a as usize], &self.vregs[b as usize], &p);
                self.stats.vmac += 1;
                self.stats.energy_pj += self.energy_cfg.vmac_energy(&p);
            }
            Instr::VmulP { dst, dst2, a, b, pat } => {
                let p = self.patterns[pat as usize];
                let (lo, hi) = alu::vmul(&self.vregs[a as usize], &self.vregs[b as usize], &p);
                self.vregs[dst as usize] = lo;
                self.vregs[dst2 as usize] = hi;
                self.stats.vmul += 1;
                self.stats.energy_pj += self.energy_cfg.vmac_energy(&p) * 0.8;
            }
            Instr::Vaddq16 { dst, a, b } => {
                self.vregs[dst as usize] =
                    alu::vaddq_s16(&self.vregs[a as usize], &self.vregs[b as usize]);
                self.stats.vec_simple += 1;
                self.stats.energy_pj += self.energy_cfg.vec_simple;
            }
            Instr::ReduceAcc { src, addr } => {
                self.touch_mem(addr, 4, true);
                let sum = alu::reduce_acc(&self.vregs[src as usize]);
                let cur = self.read_i32(addr.buf, addr.off as usize);
                self.write_i32(addr.buf, addr.off as usize, cur.wrapping_add(sum));
                self.stats.vec_simple += 2;
                self.stats.energy_pj += 2.0 * self.energy_cfg.vec_simple + self.energy_cfg.scalar;
            }
            Instr::MulAcc { lo, hi, pat, addr, n_valid } => {
                self.touch_mem(addr, 4 * n_valid as u64, true);
                let p = self.patterns[pat as usize];
                let vlo = self.vregs[lo as usize];
                let vhi = self.vregs[hi as usize];
                let lanes = p.lane_precisions();
                let mut e_idx = 0u32;
                for (li, &lp) in lanes.iter().enumerate() {
                    let prods = alu::decode_mul_lane(vlo.lanes[li], vhi.lanes[li], lp);
                    let shift = 8 - 2 * lp as i32; // to 2^-6 units
                    for prod in prods {
                        if e_idx >= n_valid as u32 {
                            break;
                        }
                        let off = addr.off as usize + 4 * e_idx as usize;
                        let cur = self.read_i32(addr.buf, off);
                        self.write_i32(addr.buf, off, cur.wrapping_add(prod << shift));
                        e_idx += 1;
                    }
                }
                self.stats.vec_simple += 4;
                self.stats.energy_pj += 4.0 * self.energy_cfg.vec_simple;
            }
            Instr::VfmaF32 { .. } => {
                // timing/energy-only baseline op (functional fp path is
                // handled at the network level)
                self.stats.vfma32 += 1;
                self.stats.energy_pj += self.energy_cfg.fma32_energy();
            }
            Instr::VmacI8 { .. } => {
                self.stats.vmac_i8 += 1;
                self.stats.energy_pj += self.energy_cfg.mac_i8_energy();
            }
        }
    }

    pub fn run(&mut self, prog: &[Instr]) {
        for i in prog {
            self.exec(i);
        }
    }

    /// Reset per-run statistics (keeps buffers, registers, caches).
    pub fn take_stats(&mut self) -> RunStats {
        std::mem::take(&mut self.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::vector::pack_values;
    use crate::smol::quant;

    #[test]
    fn mac_program_computes_dot_product() {
        let mut m = Machine::new();
        let pat = Pattern::uniform(4);
        m.patterns.push(pat);
        let a: Vec<f32> = (0..32).map(|i| quant::quantize(0.1 * i as f32 - 1.2, 4)).collect();
        let b: Vec<f32> = (0..32).map(|i| quant::quantize(0.7 - 0.05 * i as f32, 4)).collect();
        let abuf = m.alloc(16);
        let bbuf = m.alloc(16);
        let obuf = m.alloc(4);
        m.write_bytes(abuf, 0, &pack_values(&pat, &a).to_bytes());
        m.write_bytes(bbuf, 0, &pack_values(&pat, &b).to_bytes());
        let prog = vec![
            Instr::LdQ { dst: 0, addr: Addr { buf: abuf, off: 0 } },
            Instr::LdQ { dst: 1, addr: Addr { buf: bbuf, off: 0 } },
            Instr::VmacP { dst: 2, a: 0, b: 1, pat: 0 },
            Instr::ReduceAcc { src: 2, addr: Addr { buf: obuf, off: 0 } },
        ];
        m.run(&prog);
        let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let got = m.read_i32(obuf, 0) as f32 / 64.0;
        assert_eq!(got, want);
        assert_eq!(m.stats.vmac, 1);
        assert!(m.stats.cycles() > 0);
    }

    #[test]
    fn mul_acc_matches_products() {
        let mut m = Machine::new();
        let pat = Pattern::uniform(2);
        m.patterns.push(pat);
        let a: Vec<f32> = (0..64).map(|i| quant::quantize(0.05 * i as f32 - 1.0, 2)).collect();
        let b: Vec<f32> = (0..64).map(|i| quant::quantize(1.0 - 0.03 * i as f32, 2)).collect();
        let abuf = m.alloc(16);
        let bbuf = m.alloc(16);
        let obuf = m.alloc(4 * 64);
        m.write_bytes(abuf, 0, &pack_values(&pat, &a).to_bytes());
        m.write_bytes(bbuf, 0, &pack_values(&pat, &b).to_bytes());
        let prog = vec![
            Instr::LdQ { dst: 0, addr: Addr { buf: abuf, off: 0 } },
            Instr::LdQ { dst: 1, addr: Addr { buf: bbuf, off: 0 } },
            Instr::VmulP { dst: 2, dst2: 3, a: 0, b: 1, pat: 0 },
            Instr::MulAcc { lo: 2, hi: 3, pat: 0, addr: Addr { buf: obuf, off: 0 }, n_valid: 64 },
        ];
        m.run(&prog);
        for e in 0..64usize {
            let got = m.read_i32(obuf, 4 * e) as f32 / 64.0;
            assert_eq!(got, a[e] * b[e], "elem {e}");
        }
        assert_eq!(m.stats.bubbles, 1); // the vmul two-cycle bubble
    }

    #[test]
    fn cycles_scale_with_work() {
        let mut m = Machine::new();
        m.patterns.push(Pattern::uniform(1));
        let abuf = m.alloc(1 << 16);
        let prog: Vec<Instr> = (0..1000)
            .map(|i| Instr::LdQ {
                dst: (i % 30) as u8,
                addr: Addr { buf: abuf, off: (i * 16) % 65536 },
            })
            .collect();
        m.run(&prog);
        let c1 = m.stats.cycles();
        m.run(&prog); // second pass: warm cache, fewer cycles per stats
        assert!(c1 > 0);
        assert!(m.stats.loads == 2000);
    }
}
