//! The timing/energy simulator — the gem5 substitute (Table IV): cache
//! hierarchy, per-instruction execution (functional + timing) and the
//! network-level inference driver.

pub mod cache;
pub mod eltwise;
pub mod energy;
pub mod machine;
pub mod network;

pub use machine::{Machine, RunStats};
pub use network::{run_network, NetResult, Node, Tensor, INPUT};
