//! Element-wise f32 epilogues for the Transformer path: row softmax,
//! layer normalization and GELU.
//!
//! Like the conv path's BN + ReLU, these run in the inter-layer 32-bit
//! fixed-point domain (f32-carried) and are charged as vectorized bulk
//! work by the caller. They live in one place so the execution engine
//! and the oracle tests share the *exact* f32 operation sequence —
//! bit-identical serving outputs depend on it.

/// Epsilon inside the layer-norm variance square root.
pub const LN_EPS: f32 = 1e-5;

/// In-place softmax over each consecutive `row`-length slice
/// (numerically stabilized by the row max).
pub fn softmax_rows(data: &mut [f32], row: usize) {
    assert!(row > 0 && data.len() % row == 0, "softmax row length {row}");
    for r in data.chunks_mut(row) {
        let max = r.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in r.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in r.iter_mut() {
            *v *= inv;
        }
    }
}

/// In-place layer normalization over each consecutive `row`-length slice,
/// with per-feature `gamma` / `beta` (lengths = `row`).
pub fn layernorm_rows(data: &mut [f32], row: usize, gamma: &[f32], beta: &[f32]) {
    assert!(row > 0 && data.len() % row == 0, "layernorm row length {row}");
    assert_eq!(gamma.len(), row);
    assert_eq!(beta.len(), row);
    for r in data.chunks_mut(row) {
        let mean = r.iter().sum::<f32>() / row as f32;
        let var = r.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / row as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for (v, (g, b)) in r.iter_mut().zip(gamma.iter().zip(beta)) {
            *v = (*v - mean) * inv * g + b;
        }
    }
}

/// GELU, tanh approximation:
/// `0.5 x (1 + tanh(sqrt(2/pi) (x + 0.044715 x^3)))`.
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// In-place GELU over a tensor.
pub fn gelu_rows(data: &mut [f32]) {
    for v in data.iter_mut() {
        *v = gelu(*v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_rows_sum_to_one_and_order() {
        let mut d = vec![0.0, 1.0, 2.0, -3.0, 5.0, 5.0];
        softmax_rows(&mut d, 3);
        for r in d.chunks(3) {
            let s: f32 = r.iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "{s}");
            assert!(r.iter().all(|&v| v > 0.0 && v <= 1.0));
        }
        assert!(d[0] < d[1] && d[1] < d[2]);
        assert_eq!(d[4], d[5]); // ties stay tied
    }

    #[test]
    fn softmax_is_shift_invariant() {
        let mut a = vec![0.5, -1.0, 2.0, 0.25];
        let mut b: Vec<f32> = a.iter().map(|v| v + 100.0).collect();
        softmax_rows(&mut a, 4);
        softmax_rows(&mut b, 4);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn layernorm_centers_and_scales() {
        let mut d = vec![1.0, 2.0, 3.0, 4.0];
        let gamma = vec![1.0; 4];
        let beta = vec![0.0; 4];
        layernorm_rows(&mut d, 4, &gamma, &beta);
        let mean: f32 = d.iter().sum::<f32>() / 4.0;
        let var: f32 = d.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6, "{mean}");
        assert!((var - 1.0).abs() < 1e-3, "{var}");
        // affine: gamma scales, beta shifts
        let mut d2 = vec![1.0, 2.0, 3.0, 4.0];
        layernorm_rows(&mut d2, 4, &[2.0; 4], &[1.0; 4]);
        for (a, b) in d.iter().zip(&d2) {
            assert!((2.0 * a + 1.0 - b).abs() < 1e-6);
        }
    }

    #[test]
    fn gelu_fixed_points_and_sign() {
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(10.0) - 10.0).abs() < 1e-4); // ~identity for large x
        assert!(gelu(-10.0).abs() < 1e-4); // ~zero for very negative x
        assert!(gelu(1.0) > 0.8 && gelu(1.0) < 0.9); // ~0.8412
        assert!(gelu(-1.0) < 0.0 && gelu(-1.0) > -0.2);
    }
}
