//! Cache hierarchy model with the paper's gem5 parameters (Table IV):
//! L1I 16KB/4-way, L1D 64KB/4-way, L2 256KB/8-way, 64B lines, LRU.
//!
//! The simulator is a substitute for the authors' gem5 setup (DESIGN.md
//! substitution table): the paper's run-time results are *relative*
//! (normalized to uniform-4-bit), which depend on instruction counts and
//! locality, both captured here.


pub const LINE_BYTES: u64 = 64;

/// Which level served an access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    L1,
    L2,
    Mem,
}

/// One set-associative LRU cache.
pub struct Cache {
    sets: Vec<Vec<u64>>, // per-set stack of line tags, MRU first
    ways: usize,
    set_mask: u64,
    pub hits: u64,
    pub misses: u64,
}

impl Cache {
    pub fn new(size_bytes: u64, ways: usize) -> Self {
        let n_sets = (size_bytes / LINE_BYTES / ways as u64).max(1);
        assert!(n_sets.is_power_of_two(), "sets must be a power of two");
        Cache {
            sets: vec![Vec::with_capacity(ways); n_sets as usize],
            ways,
            set_mask: n_sets - 1,
            hits: 0,
            misses: 0,
        }
    }

    /// Access one line; returns true on hit. Misses fill (allocate-on-miss,
    /// LRU eviction).
    pub fn access_line(&mut self, line_addr: u64) -> bool {
        let set = (line_addr & self.set_mask) as usize;
        let stack = &mut self.sets[set];
        if let Some(pos) = stack.iter().position(|&t| t == line_addr) {
            stack.remove(pos);
            stack.insert(0, line_addr);
            self.hits += 1;
            true
        } else {
            if stack.len() >= self.ways {
                stack.pop();
            }
            stack.insert(0, line_addr);
            self.misses += 1;
            false
        }
    }
}

/// Latency parameters (cycles at the 2 GHz clock).
#[derive(Debug, Clone, Copy)]
pub struct LatencyConfig {
    pub l1_hit: u64,
    pub l2_hit: u64,
    pub mem: u64,
}

impl Default for LatencyConfig {
    fn default() -> Self {
        LatencyConfig { l1_hit: 1, l2_hit: 12, mem: 80 }
    }
}

/// The Table IV hierarchy: separate L1I/L1D in front of a unified L2.
pub struct Hierarchy {
    pub l1d: Cache,
    pub l1i: Cache,
    pub l2: Cache,
    pub lat: LatencyConfig,
}

impl Default for Hierarchy {
    fn default() -> Self {
        Hierarchy {
            l1d: Cache::new(64 * 1024, 4),
            l1i: Cache::new(16 * 1024, 4),
            l2: Cache::new(256 * 1024, 8),
            lat: LatencyConfig::default(),
        }
    }
}

impl Hierarchy {
    /// Data access covering `[addr, addr+bytes)`; returns (worst level
    /// touched, total latency cycles across touched lines).
    pub fn access_data(&mut self, addr: u64, bytes: u64) -> (Level, u64) {
        let first = addr / LINE_BYTES;
        let last = (addr + bytes.max(1) - 1) / LINE_BYTES;
        let mut worst = Level::L1;
        let mut cycles = 0;
        for line in first..=last {
            if self.l1d.access_line(line) {
                cycles += self.lat.l1_hit;
            } else if self.l2.access_line(line) {
                cycles += self.lat.l2_hit;
                worst = worst.max_level(Level::L2);
            } else {
                cycles += self.lat.mem;
                worst = worst.max_level(Level::Mem);
            }
        }
        (worst, cycles)
    }

    /// Instruction fetch for a PC (i-cache side; one line per fetch group).
    pub fn access_inst(&mut self, pc: u64) -> u64 {
        let line = pc / LINE_BYTES;
        if self.l1i.access_line(line) {
            0 // overlapped by fetch pipeline
        } else if self.l2.access_line(line) {
            self.lat.l2_hit
        } else {
            self.lat.mem
        }
    }
}

impl Level {
    fn max_level(self, other: Level) -> Level {
        use Level::*;
        match (self, other) {
            (Mem, _) | (_, Mem) => Mem,
            (L2, _) | (_, L2) => L2,
            _ => L1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeated_access_hits() {
        let mut h = Hierarchy::default();
        let (lvl, _) = h.access_data(0x1000, 16);
        assert_eq!(lvl, Level::Mem);
        let (lvl, c) = h.access_data(0x1000, 16);
        assert_eq!(lvl, Level::L1);
        assert_eq!(c, h.lat.l1_hit);
    }

    #[test]
    fn capacity_eviction() {
        let mut c = Cache::new(4 * 1024, 4); // 16 sets
        // fill one set's 4 ways plus one more (stride = sets * line)
        for i in 0..5u64 {
            c.access_line(i * 16);
        }
        assert_eq!(c.misses, 5);
        // first line was LRU-evicted
        assert!(!c.access_line(0));
    }

    #[test]
    fn straddling_access_touches_two_lines() {
        let mut h = Hierarchy::default();
        let (_, cycles) = h.access_data(LINE_BYTES - 8, 16);
        assert_eq!(cycles, 2 * h.lat.mem);
    }

    #[test]
    fn table_iv_geometry() {
        let h = Hierarchy::default();
        assert_eq!(h.l1d.sets.len(), 64 * 1024 / 64 / 4);
        assert_eq!(h.l1i.sets.len(), 16 * 1024 / 64 / 4);
        assert_eq!(h.l2.sets.len(), 256 * 1024 / 64 / 8);
    }
}
