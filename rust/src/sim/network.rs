//! Network-level inference simulation: executes a whole ULFlexiNet on the
//! simulated SIMD machine, layer by layer — functionally (bit-exact MAC
//! datapath + f32 epilogues) and for timing/energy (Fig. 8's run-time
//! results).
//!
//! Between layers, tensors live as f32 HWC (the paper's 32-bit / 6
//! fraction-bit fixed-point domain); at each conv/FC entry the driver
//! quantizes + rearranges + packs the *activations* to the layer's
//! precision patterns (charged as streaming cache traffic), then the
//! generated Algorithm-4 kernel runs on the machine.
//!
//! Transformer-encoder graphs use the same tensor type with sequence
//! data mapped as `(h = heads-or-1, w = position, c = features)`:
//! [`Node::Matmul`] / [`Node::MatmulDyn`] run on the GEMM emitter
//! ([`crate::codegen::gemm`]) and [`Node::Softmax`] /
//! [`Node::LayerNorm`] / [`Node::Gelu`] are f32 epilogues
//! ([`crate::sim::eltwise`]).
//!
//! The execution engine itself lives in [`crate::serve::engine`]: every
//! node kind implements the [`crate::serve::engine::PreparedOp`] trait
//! (`prepare -> bind -> run(ctx)`), models are prepared once (codegen +
//! weight packing cached per layer) and replayed per request. The
//! one-shot entry points here — [`run_conv`] and [`run_network`] — are
//! thin clients of that same API, with outputs bit-identical to the
//! prepared serving path.

use crate::codegen::gemm::GemmPlan;
use crate::codegen::{DataFormat, LayerPlan};
use crate::serve::engine::{
    EngineMachine, ExecCtx, PreparedConv, PreparedModel, PreparedOp, WorkerScratch,
};
use crate::sim::machine::{Machine, RunStats};
use crate::smol::pattern_match::Assignment;
use std::sync::Arc;

/// A tensor in the inter-layer 32-bit fixed-point domain (f32-carried).
#[derive(Debug, Clone)]
pub struct Tensor {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    /// HWC order
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(h: usize, w: usize, c: usize) -> Self {
        Tensor { h, w, c, data: vec![0.0; h * w * c] }
    }
    pub fn at(&self, h: usize, w: usize, c: usize) -> f32 {
        self.data[(h * self.w + w) * self.c + c]
    }
}

/// One conv/FC layer with its trained parameters (inference form).
#[derive(Debug, Clone)]
pub struct ConvLayerCfg {
    pub plan: LayerPlan,
    /// dense: HWIO `[r][s][cin][cout]`; depthwise: `[r][s][c]`
    pub weights: Vec<f32>,
    /// per-output-channel BN (empty = no BN, e.g. FC)
    pub bn_scale: Vec<f32>,
    pub bn_bias: Vec<f32>,
    pub bn_mean: Vec<f32>,
    pub bn_var: Vec<f32>,
    pub relu: bool,
}

/// One GEMM node's configuration (inference form). Sequence tensors map
/// onto the HWC layout as `(h = heads-or-1, w = sequence position,
/// c = features)`; the GEMM batches over `h` and contracts over `c`.
#[derive(Debug, Clone)]
pub struct MatmulCfg {
    pub plan: GemmPlan,
    /// f32 epilogue scaling applied after dequantization
    /// (e.g. `1/sqrt(d_head)` for attention scores); 1.0 = none
    pub scale: f32,
    /// causal (autoregressive) masking for dynamic-operand GEMMs over
    /// `(position, position)` shapes: with `transpose_b` (the QK^T
    /// score shape) the upper triangle is skipped at codegen time and
    /// epilogued to `-inf`; without it (the A·V context shape) row `i`
    /// contracts only positions `<= i` — the one-shot twin of the
    /// serving engine's KV-cached decode step
    pub causal: bool,
}

/// Configuration of a fused KV-cached decode attention node
/// ([`Node::CachedAttn`]). The position (contraction) axis of the
/// context GEMM must be *uniform* precision: positions arrive one at a
/// time, and PatternMatch's importance reordering is undefined for
/// positions that have not been seen yet. The `dh` axis of the score
/// GEMM carries an arbitrary per-channel assignment, exactly like the
/// encoder's QK^T node.
#[derive(Debug, Clone)]
pub struct AttnCfg {
    pub name: String,
    pub heads: usize,
    /// per-head feature dimension (the score GEMM's contraction axis)
    pub dh: usize,
    /// score scale (`1/sqrt(dh)`)
    pub scale: f32,
    /// uniform precision of the position axis (context-GEMM contraction)
    pub pos_prec: u8,
    /// per-channel precisions of the `dh` axis (score-GEMM contraction)
    pub dh_asg: Assignment,
    /// session K/V caches (and bound buffers) are sized for this many
    /// positions; a session that decodes past it panics
    pub max_positions: usize,
    pub fmt: DataFormat,
}

/// Graph node (indices refer to node outputs; usize::MAX = network input).
#[derive(Debug, Clone)]
pub enum Node {
    Conv { cfg: Box<ConvLayerCfg>, input: usize },
    /// static-operand GEMM `X · W` (projections, FFN): `weights` is
    /// `[k][n]` row-major and packs once at prepare time
    Matmul { cfg: Box<MatmulCfg>, weights: Vec<f32>, input: usize },
    /// dynamic-operand GEMM between two node outputs (QK^T, A·V): the
    /// "weight" side `b` is quantized + packed per request.
    /// `transpose_b = false` contracts `a`'s channels with `b`'s
    /// sequence axis (`C[h,i,j] = sum_c a[h,i,c] * b[h,c->w,j->c]`);
    /// `transpose_b = true` contracts channels with channels
    /// (`C[h,i,j] = sum_c a[h,i,c] * b[h,j,c]`, the QK^T shape)
    MatmulDyn { cfg: Box<MatmulCfg>, a: usize, b: usize, transpose_b: bool },
    /// fused KV-cached decode attention over split-head `(heads, 1, dh)`
    /// step tensors: appends this step's K/V to the request session's
    /// packed operand caches, then runs score GEMM + softmax + context
    /// GEMM against the cached prefix. Only valid in a decode *step*
    /// graph executed through a session (`serve::Server::submit_step`).
    CachedAttn { cfg: Box<AttnCfg>, q: usize, k: usize, v: usize },
    /// row softmax along `c` for every (h, w)
    Softmax { x: usize },
    /// layer normalization along `c` with per-feature affine
    LayerNorm { x: usize, gamma: Vec<f32>, beta: Vec<f32> },
    /// GELU activation (tanh approximation)
    Gelu { x: usize },
    /// swap the `h` and `w` axes
    TransposeHW { x: usize },
    /// `(1, s, heads*dh)` -> `(heads, s, dh)`
    SplitHeads { x: usize, heads: usize },
    /// `(heads, s, dh)` -> `(1, s, heads*dh)` (inverse of SplitHeads)
    MergeHeads { x: usize },
    Add { a: usize, b: usize, relu: bool },
    ConcatC { a: usize, b: usize },
    SliceC { x: usize, from: usize, to: usize },
    ShuffleC { x: usize, groups: usize },
    Gap { x: usize },
}

pub const INPUT: usize = usize::MAX;

impl Node {
    /// Indices of the node outputs this node consumes ([`INPUT`] = the
    /// graph input tensor) — the single source of dataflow truth,
    /// shared by the graph executor (`serve::engine::prepare_nodes`)
    /// and the shard planner (`serve::deploy`), so the two can never
    /// disagree about a graph's shape.
    pub fn inputs(&self) -> Vec<usize> {
        match self {
            Node::Conv { input, .. } | Node::Matmul { input, .. } => vec![*input],
            Node::MatmulDyn { a, b, .. } => vec![*a, *b],
            Node::CachedAttn { q, k, v, .. } => vec![*q, *k, *v],
            Node::Softmax { x }
            | Node::LayerNorm { x, .. }
            | Node::Gelu { x }
            | Node::TransposeHW { x }
            | Node::SplitHeads { x, .. }
            | Node::MergeHeads { x }
            | Node::SliceC { x, .. }
            | Node::ShuffleC { x, .. }
            | Node::Gap { x } => vec![*x],
            Node::Add { a, b, .. } | Node::ConcatC { a, b } => vec![*a, *b],
        }
    }
}

/// Per-layer simulation result.
#[derive(Debug, Clone)]
pub struct LayerStat {
    pub name: String,
    /// which shard of a sharded deployment produced this stat (`None`
    /// for whole-model execution); gathered serving completions tag it
    /// so reports can attribute cycles/energy per `(model, layer, shard)`
    pub shard: Option<usize>,
    pub stats: RunStats,
}

/// Full-network result.
#[derive(Debug)]
pub struct NetResult {
    /// final node output (logits for classifier graphs ending in Gap+Fc)
    pub output: Tensor,
    pub layers: Vec<LayerStat>,
    pub total: RunStats,
}

/// Run one conv/FC layer on the machine. Returns the epilogued output.
///
/// One-shot client of the engine's [`PreparedOp`] API in *streaming*
/// mode (no bound kernel): weights are packed and the kernel is emitted
/// straight into the machine for this single call (O(1) memory even for
/// paper-scale layers). Callers that run the same layer repeatedly
/// should prepare + bind once instead (see [`crate::serve`]).
pub fn run_conv(m: &mut Machine, cfg: &ConvLayerCfg, x: &Tensor) -> (Tensor, RunStats) {
    let op = PreparedConv::streaming(cfg);
    let mut scratch = WorkerScratch::default();
    let mut ctx =
        ExecCtx { m: &mut *m, bound: None, scratch: &mut scratch, session: None, kv: None };
    let out = op.run(&mut ctx, &[x]);
    (out, m.take_stats())
}

/// Execute a network graph on a fresh machine.
///
/// Thin wrapper over [`PreparedModel`]: prepares every layer, binds one
/// machine and runs a single inference. For serving many requests, keep
/// the prepared model (see [`crate::serve`]) — preparation is the
/// expensive part and is fully reusable.
pub fn run_network(nodes: &[Node], input: &Tensor) -> NetResult {
    let model = Arc::new(PreparedModel::prepare(nodes));
    EngineMachine::new(&model).run(input)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::{DataFormat, LayerKind};
    use crate::smol::pattern_match::Assignment;
    use crate::smol::quant;

    /// Reference conv in plain f64 on quantized values (the oracle the
    /// packed-vector datapath must match exactly).
    fn ref_conv(cfg: &ConvLayerCfg, x: &Tensor) -> Tensor {
        let p = &cfg.plan;
        let (hout, wout) = (p.hout(), p.wout());
        let (pt, pl) = (p.pad_top(), p.pad_left());
        let mut t = Tensor::zeros(hout, wout, p.cout);
        for k in 0..p.cout {
            for h in 0..hout {
                for w in 0..wout {
                    let mut acc = 0f64;
                    for r in 0..p.kh {
                        for s in 0..p.kw {
                            let ih = h as isize * p.stride as isize + r as isize - pt;
                            let iw = w as isize * p.stride as isize + s as isize - pl;
                            if ih < 0 || iw < 0 || ih >= p.hin as isize || iw >= p.win as isize {
                                continue;
                            }
                            for c in 0..p.cin {
                                let prec = cfg.plan.asg.precision[c];
                                let xv =
                                    quant::quantize(x.at(ih as usize, iw as usize, c), prec);
                                let wv = quant::quantize(
                                    cfg.weights[((r * p.kw + s) * p.cin + c) * p.cout + k],
                                    prec,
                                );
                                acc += (xv as f64) * (wv as f64);
                            }
                        }
                    }
                    t.data[(h * wout + w) * p.cout + k] = acc as f32;
                }
            }
        }
        t
    }

    fn mk_cfg(
        cin: usize,
        cout: usize,
        k: usize,
        stride: usize,
        hw: usize,
        asg: Assignment,
    ) -> ConvLayerCfg {
        let mut w = vec![0f32; k * k * cin * cout];
        let mut st = 77u64;
        for v in w.iter_mut() {
            st ^= st << 13;
            st ^= st >> 7;
            st ^= st << 17;
            *v = ((st % 1000) as f32 / 500.0) - 1.0;
        }
        ConvLayerCfg {
            plan: LayerPlan {
                name: "test".into(),
                kind: LayerKind::Dense,
                cin,
                cout,
                kh: k,
                kw: k,
                stride,
                hin: hw,
                win: hw,
                asg,
                fmt: DataFormat::Smol,
            },
            weights: w,
            bn_scale: vec![],
            bn_bias: vec![],
            bn_mean: vec![],
            bn_var: vec![],
            relu: false,
        }
    }

    fn rand_tensor(h: usize, w: usize, c: usize, seed: u64) -> Tensor {
        let mut t = Tensor::zeros(h, w, c);
        let mut st = seed | 1;
        for v in t.data.iter_mut() {
            st ^= st << 13;
            st ^= st >> 7;
            st ^= st << 17;
            *v = ((st % 4000) as f32 / 1000.0) - 2.0;
        }
        t
    }

    #[test]
    fn simulated_conv_matches_reference_uniform4() {
        let cfg = mk_cfg(32, 4, 3, 1, 6, Assignment::uniform(32, 4));
        let x = rand_tensor(6, 6, 32, 9);
        let mut m = Machine::new();
        let (got, stats) = run_conv(&mut m, &cfg, &x);
        let want = ref_conv(&cfg, &x);
        for i in 0..got.data.len() {
            assert_eq!(got.data[i], want.data[i], "elem {i}");
        }
        assert!(stats.vmac > 0 && stats.cycles() > 0);
    }

    #[test]
    fn simulated_conv_matches_reference_partial_chunk() {
        // 24 channels in a 32-capacity chunk: tail masking + bias path
        let cfg = mk_cfg(24, 3, 3, 2, 8, Assignment::uniform(24, 4));
        let x = rand_tensor(8, 8, 24, 5);
        let mut m = Machine::new();
        let (got, _) = run_conv(&mut m, &cfg, &x);
        let want = ref_conv(&cfg, &x);
        for i in 0..got.data.len() {
            assert_eq!(got.data[i], want.data[i], "elem {i}");
        }
    }

    #[test]
    fn simulated_conv_matches_reference_mixed_precision() {
        use crate::simd::patterns::all_patterns;
        use crate::smol::pattern_match::pattern_match;
        // mixed importance: low s -> 4 bits for first 8 channels
        let mut s = vec![3.0f32; 40];
        for i in 0..8 {
            s[i] = -2.0;
        }
        for i in 8..20 {
            s[i] = 0.5;
        }
        let asg = pattern_match(&s, &all_patterns());
        let cfg = mk_cfg(40, 5, 3, 1, 5, asg);
        let x = rand_tensor(5, 5, 40, 11);
        let mut m = Machine::new();
        let (got, _) = run_conv(&mut m, &cfg, &x);
        let want = ref_conv(&cfg, &x);
        for i in 0..got.data.len() {
            assert_eq!(got.data[i], want.data[i], "elem {i}");
        }
    }

    #[test]
    fn depthwise_matches_reference() {
        let asg = Assignment::uniform(24, 2);
        let mut w = vec![0f32; 9 * 24];
        let mut st = 3u64;
        for v in w.iter_mut() {
            st ^= st << 13;
            st ^= st >> 7;
            st ^= st << 17;
            *v = ((st % 1000) as f32 / 500.0) - 1.0;
        }
        let cfg = ConvLayerCfg {
            plan: LayerPlan {
                name: "dw".into(),
                kind: LayerKind::Depthwise,
                cin: 24,
                cout: 24,
                kh: 3,
                kw: 3,
                stride: 1,
                hin: 4,
                win: 4,
                asg,
                fmt: DataFormat::Smol,
            },
            weights: w.clone(),
            bn_scale: vec![],
            bn_bias: vec![],
            bn_mean: vec![],
            bn_var: vec![],
            relu: false,
        };
        let x = rand_tensor(4, 4, 24, 21);
        let mut m = Machine::new();
        let (got, stats) = run_conv(&mut m, &cfg, &x);
        // reference depthwise
        let p = &cfg.plan;
        for h in 0..4 {
            for w_ in 0..4 {
                for c in 0..24 {
                    let mut acc = 0f64;
                    for r in 0..3 {
                        for s in 0..3 {
                            let ih = h as isize + r as isize - 1;
                            let iw = w_ as isize + s as isize - 1;
                            if ih < 0 || iw < 0 || ih >= 4 || iw >= 4 {
                                continue;
                            }
                            let xv = quant::quantize(x.at(ih as usize, iw as usize, c), 2);
                            let wv = quant::quantize(cfg.weights[(r * 3 + s) * 24 + c], 2);
                            acc += (xv * wv) as f64;
                        }
                    }
                    assert_eq!(got.at(h, w_, c), acc as f32, "h{h} w{w_} c{c}");
                }
            }
        }
        let _ = p;
        assert!(stats.vmul > 0);
    }
}
