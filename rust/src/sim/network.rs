//! Network-level inference simulation: executes a whole ULFlexiNet on the
//! simulated SIMD machine, layer by layer — functionally (bit-exact MAC
//! datapath + f32 epilogues) and for timing/energy (Fig. 8's run-time
//! results).
//!
//! Between layers, tensors live as f32 HWC (the paper's 32-bit / 6
//! fraction-bit fixed-point domain); at each conv/FC entry the driver
//! quantizes + rearranges + packs to the layer's precision patterns (the
//! cost of that pass is charged via streaming cache traffic), then the
//! generated Algorithm-4 kernel runs on the machine.

use crate::codegen::{self, pack, DataFormat, LayerBufs, LayerKind, LayerPlan};
use crate::sim::machine::{Machine, RunStats};
use crate::smol::quant;

/// A tensor in the inter-layer 32-bit fixed-point domain (f32-carried).
#[derive(Debug, Clone)]
pub struct Tensor {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    /// HWC order
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(h: usize, w: usize, c: usize) -> Self {
        Tensor { h, w, c, data: vec![0.0; h * w * c] }
    }
    pub fn at(&self, h: usize, w: usize, c: usize) -> f32 {
        self.data[(h * self.w + w) * self.c + c]
    }
}

/// One conv/FC layer with its trained parameters (inference form).
#[derive(Debug, Clone)]
pub struct ConvLayerCfg {
    pub plan: LayerPlan,
    /// dense: HWIO `[r][s][cin][cout]`; depthwise: `[r][s][c]`
    pub weights: Vec<f32>,
    /// per-output-channel BN (empty = no BN, e.g. FC)
    pub bn_scale: Vec<f32>,
    pub bn_bias: Vec<f32>,
    pub bn_mean: Vec<f32>,
    pub bn_var: Vec<f32>,
    pub relu: bool,
}

/// Graph node (indices refer to node outputs; usize::MAX = network input).
#[derive(Debug, Clone)]
pub enum Node {
    Conv { cfg: Box<ConvLayerCfg>, input: usize },
    Add { a: usize, b: usize, relu: bool },
    ConcatC { a: usize, b: usize },
    SliceC { x: usize, from: usize, to: usize },
    ShuffleC { x: usize, groups: usize },
    Gap { x: usize },
}

pub const INPUT: usize = usize::MAX;

/// Per-layer simulation result.
#[derive(Debug, Clone)]
pub struct LayerStat {
    pub name: String,
    pub stats: RunStats,
}

/// Full-network result.
#[derive(Debug)]
pub struct NetResult {
    /// final node output (logits for classifier graphs ending in Gap+Fc)
    pub output: Tensor,
    pub layers: Vec<LayerStat>,
    pub total: RunStats,
}

/// Run one conv/FC layer on the machine. Returns the epilogued output.
pub fn run_conv(m: &mut Machine, cfg: &ConvLayerCfg, x: &Tensor) -> (Tensor, RunStats) {
    let plan = &cfg.plan;
    assert_eq!(x.c, plan.cin, "{}: cin mismatch", plan.name);
    assert_eq!((x.h, x.w), (plan.hin, plan.win), "{}: spatial mismatch", plan.name);
    let (hout, wout) = (plan.hout(), plan.wout());

    // pack inputs + weights + masks into fresh machine buffers
    let act = pack::pack_activations(plan, &x.data);
    let wts = pack::pack_weights(plan, &cfg.weights);
    let msk = pack::pack_masks(plan);
    let out_elems = match plan.kind {
        LayerKind::Dense => plan.cout * hout * wout,
        LayerKind::Depthwise => plan.cin * hout * wout,
    };
    // baseline depthwise stores whole 16B chunk vectors per position,
    // which can exceed cin*4 bytes when cin is not a multiple of the
    // lane capacity — size the buffer for both layouts
    let out_bytes = (out_elems * 4).max(hout * wout * plan.chunks().len() * 16);
    let bufs = LayerBufs {
        input: m.alloc(act.len()),
        weights: m.alloc(wts.len()),
        out: m.alloc(out_bytes),
        masks: m.alloc(msk.len()),
    };
    m.write_bytes(bufs.input, 0, &act);
    m.write_bytes(bufs.weights, 0, &wts);
    m.write_bytes(bufs.masks, 0, &msk);

    // charge the quantize/rearrange/pack pass (reads raw f32, writes
    // packed) as streaming traffic through the cache
    m.stream_touch(bufs.input, act.len(), true);
    m.stats.add_bulk((x.data.len()) as u64, 0, &m.energy_cfg.clone());

    // generate + execute the Algorithm-4 kernel
    m.patterns.clear();
    let base = codegen::register_patterns(plan, &mut m.patterns);
    codegen::emit_layer(plan, &bufs, base, m);

    // epilogue: accumulators -> f32, tail-bias correction, BN, ReLU
    let bias = plan.tail_bias();
    let mut out = match plan.kind {
        LayerKind::Dense => {
            let mut t = Tensor::zeros(hout, wout, plan.cout);
            for k in 0..plan.cout {
                for h in 0..hout {
                    for w in 0..wout {
                        let acc = m.read_i32(bufs.out, ((k * hout + h) * wout + w) * 4);
                        let taps = valid_taps(plan, h, w) as i64;
                        let v = (acc as i64 - bias * taps) as f32 / quant::ACC_SCALE;
                        t.data[(h * wout + w) * plan.cout + k] = v;
                    }
                }
            }
            t
        }
        LayerKind::Depthwise => {
            // depthwise MulAcc wrote in *packed* channel order; un-permute
            let mut t = Tensor::zeros(hout, wout, plan.cin);
            for h in 0..hout {
                for w in 0..wout {
                    for (pos, &ch) in plan.asg.order.iter().enumerate() {
                        let acc = m.read_i32(bufs.out, ((h * wout + w) * plan.cin + pos) * 4);
                        t.data[(h * wout + w) * plan.cin + ch as usize] =
                            acc as f32 / quant::ACC_SCALE;
                    }
                }
            }
            t
        }
    };

    // BN + ReLU epilogue (f32, vectorized in hardware; bulk-costed)
    if !cfg.bn_scale.is_empty() {
        let cch = out.c;
        for i in 0..out.data.len() {
            let k = i % cch;
            let inv = 1.0 / (cfg.bn_var[k] + 1e-5).sqrt();
            out.data[i] = (out.data[i] - cfg.bn_mean[k]) * inv * cfg.bn_scale[k] + cfg.bn_bias[k];
        }
    }
    if cfg.relu {
        for v in out.data.iter_mut() {
            *v = v.max(0.0);
        }
    }
    m.stream_touch(bufs.out, out_elems * 4, false);
    m.stats.add_bulk(out.data.len() as u64, (out.data.len() * 4) as u64, &m.energy_cfg.clone());

    (out, m.take_stats())
}

/// Number of in-bounds taps for output position (h, w).
fn valid_taps(plan: &LayerPlan, h: usize, w: usize) -> usize {
    let (pt, pl) = (plan.pad_top(), plan.pad_left());
    let mut n = 0;
    for r in 0..plan.kh {
        for s in 0..plan.kw {
            let ih = h as isize * plan.stride as isize + r as isize - pt;
            let iw = w as isize * plan.stride as isize + s as isize - pl;
            if ih >= 0 && iw >= 0 && ih < plan.hin as isize && iw < plan.win as isize {
                n += 1;
            }
        }
    }
    n
}

/// Execute a network graph on a fresh machine.
pub fn run_network(nodes: &[Node], input: &Tensor) -> NetResult {
    let mut m = Machine::new();
    let mut outputs: Vec<Tensor> = Vec::with_capacity(nodes.len());
    let mut layers = Vec::new();
    let mut total = RunStats::default();
    let get = |outputs: &Vec<Tensor>, id: usize| -> Tensor {
        if id == INPUT {
            input.clone()
        } else {
            outputs[id].clone()
        }
    };
    for node in nodes {
        let out = match node {
            Node::Conv { cfg, input: id } => {
                let x = get(&outputs, *id);
                let (t, stats) = run_conv(&mut m, cfg, &x);
                total.merge(&stats);
                layers.push(LayerStat { name: cfg.plan.name.clone(), stats });
                t
            }
            Node::Add { a, b, relu } => {
                let ta = get(&outputs, *a);
                let tb = get(&outputs, *b);
                assert_eq!(ta.data.len(), tb.data.len());
                let mut t = ta.clone();
                for (v, w) in t.data.iter_mut().zip(&tb.data) {
                    *v += w;
                    if *relu {
                        *v = v.max(0.0);
                    }
                }
                total.add_bulk(t.data.len() as u64, (t.data.len() * 8) as u64, &m.energy_cfg);
                t
            }
            Node::ConcatC { a, b } => {
                let ta = get(&outputs, *a);
                let tb = get(&outputs, *b);
                assert_eq!((ta.h, ta.w), (tb.h, tb.w));
                let mut t = Tensor::zeros(ta.h, ta.w, ta.c + tb.c);
                for h in 0..ta.h {
                    for w in 0..ta.w {
                        for c in 0..ta.c {
                            t.data[(h * t.w + w) * t.c + c] = ta.at(h, w, c);
                        }
                        for c in 0..tb.c {
                            t.data[(h * t.w + w) * t.c + ta.c + c] = tb.at(h, w, c);
                        }
                    }
                }
                t
            }
            Node::SliceC { x, from, to } => {
                let tx = get(&outputs, *x);
                let mut t = Tensor::zeros(tx.h, tx.w, to - from);
                for h in 0..tx.h {
                    for w in 0..tx.w {
                        for c in *from..*to {
                            t.data[(h * t.w + w) * t.c + (c - from)] = tx.at(h, w, c);
                        }
                    }
                }
                t
            }
            Node::ShuffleC { x, groups } => {
                let tx = get(&outputs, *x);
                let g = *groups;
                let per = tx.c / g;
                let mut t = Tensor::zeros(tx.h, tx.w, tx.c);
                // NHWC shuffle: out[.., i*g + j] = in[.., j*per + i]
                for h in 0..tx.h {
                    for w in 0..tx.w {
                        for j in 0..g {
                            for i in 0..per {
                                t.data[(h * t.w + w) * t.c + (i * g + j)] =
                                    tx.at(h, w, j * per + i);
                            }
                        }
                    }
                }
                t
            }
            Node::Gap { x } => {
                let tx = get(&outputs, *x);
                let mut t = Tensor::zeros(1, 1, tx.c);
                for c in 0..tx.c {
                    let mut s = 0.0f32;
                    for h in 0..tx.h {
                        for w in 0..tx.w {
                            s += tx.at(h, w, c);
                        }
                    }
                    t.data[c] = s / (tx.h * tx.w) as f32;
                }
                total.add_bulk(tx.data.len() as u64, (tx.data.len() * 4) as u64, &m.energy_cfg);
                t
            }
        };
        outputs.push(out);
    }
    NetResult { output: outputs.pop().unwrap(), layers, total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::smol::pattern_match::Assignment;

    /// Reference conv in plain f64 on quantized values (the oracle the
    /// packed-vector datapath must match exactly).
    fn ref_conv(cfg: &ConvLayerCfg, x: &Tensor) -> Tensor {
        let p = &cfg.plan;
        let (hout, wout) = (p.hout(), p.wout());
        let (pt, pl) = (p.pad_top(), p.pad_left());
        let mut t = Tensor::zeros(hout, wout, p.cout);
        for k in 0..p.cout {
            for h in 0..hout {
                for w in 0..wout {
                    let mut acc = 0f64;
                    for r in 0..p.kh {
                        for s in 0..p.kw {
                            let ih = h as isize * p.stride as isize + r as isize - pt;
                            let iw = w as isize * p.stride as isize + s as isize - pl;
                            if ih < 0 || iw < 0 || ih >= p.hin as isize || iw >= p.win as isize {
                                continue;
                            }
                            for c in 0..p.cin {
                                let prec = cfg.plan.asg.precision[c];
                                let xv =
                                    quant::quantize(x.at(ih as usize, iw as usize, c), prec);
                                let wv = quant::quantize(
                                    cfg.weights[((r * p.kw + s) * p.cin + c) * p.cout + k],
                                    prec,
                                );
                                acc += (xv as f64) * (wv as f64);
                            }
                        }
                    }
                    t.data[(h * wout + w) * p.cout + k] = acc as f32;
                }
            }
        }
        t
    }

    fn mk_cfg(cin: usize, cout: usize, k: usize, stride: usize, hw: usize, asg: Assignment) -> ConvLayerCfg {
        let mut w = vec![0f32; k * k * cin * cout];
        let mut st = 77u64;
        for v in w.iter_mut() {
            st ^= st << 13;
            st ^= st >> 7;
            st ^= st << 17;
            *v = ((st % 1000) as f32 / 500.0) - 1.0;
        }
        ConvLayerCfg {
            plan: LayerPlan {
                name: "test".into(),
                kind: LayerKind::Dense,
                cin,
                cout,
                kh: k,
                kw: k,
                stride,
                hin: hw,
                win: hw,
                asg,
                fmt: DataFormat::Smol,
            },
            weights: w,
            bn_scale: vec![],
            bn_bias: vec![],
            bn_mean: vec![],
            bn_var: vec![],
            relu: false,
        }
    }

    fn rand_tensor(h: usize, w: usize, c: usize, seed: u64) -> Tensor {
        let mut t = Tensor::zeros(h, w, c);
        let mut st = seed | 1;
        for v in t.data.iter_mut() {
            st ^= st << 13;
            st ^= st >> 7;
            st ^= st << 17;
            *v = ((st % 4000) as f32 / 1000.0) - 2.0;
        }
        t
    }

    #[test]
    fn simulated_conv_matches_reference_uniform4() {
        let cfg = mk_cfg(32, 4, 3, 1, 6, Assignment::uniform(32, 4));
        let x = rand_tensor(6, 6, 32, 9);
        let mut m = Machine::new();
        let (got, stats) = run_conv(&mut m, &cfg, &x);
        let want = ref_conv(&cfg, &x);
        for i in 0..got.data.len() {
            assert_eq!(got.data[i], want.data[i], "elem {i}");
        }
        assert!(stats.vmac > 0 && stats.cycles() > 0);
    }

    #[test]
    fn simulated_conv_matches_reference_partial_chunk() {
        // 24 channels in a 32-capacity chunk: tail masking + bias path
        let cfg = mk_cfg(24, 3, 3, 2, 8, Assignment::uniform(24, 4));
        let x = rand_tensor(8, 8, 24, 5);
        let mut m = Machine::new();
        let (got, _) = run_conv(&mut m, &cfg, &x);
        let want = ref_conv(&cfg, &x);
        for i in 0..got.data.len() {
            assert_eq!(got.data[i], want.data[i], "elem {i}");
        }
    }

    #[test]
    fn simulated_conv_matches_reference_mixed_precision() {
        use crate::simd::patterns::all_patterns;
        use crate::smol::pattern_match::pattern_match;
        // mixed importance: low s -> 4 bits for first 8 channels
        let mut s = vec![3.0f32; 40];
        for i in 0..8 {
            s[i] = -2.0;
        }
        for i in 8..20 {
            s[i] = 0.5;
        }
        let asg = pattern_match(&s, &all_patterns());
        let cfg = mk_cfg(40, 5, 3, 1, 5, asg);
        let x = rand_tensor(5, 5, 40, 11);
        let mut m = Machine::new();
        let (got, _) = run_conv(&mut m, &cfg, &x);
        let want = ref_conv(&cfg, &x);
        for i in 0..got.data.len() {
            assert_eq!(got.data[i], want.data[i], "elem {i}");
        }
    }

    #[test]
    fn depthwise_matches_reference() {
        let asg = Assignment::uniform(24, 2);
        let mut w = vec![0f32; 9 * 24];
        let mut st = 3u64;
        for v in w.iter_mut() {
            st ^= st << 13;
            st ^= st >> 7;
            st ^= st << 17;
            *v = ((st % 1000) as f32 / 500.0) - 1.0;
        }
        let cfg = ConvLayerCfg {
            plan: LayerPlan {
                name: "dw".into(),
                kind: LayerKind::Depthwise,
                cin: 24,
                cout: 24,
                kh: 3,
                kw: 3,
                stride: 1,
                hin: 4,
                win: 4,
                asg,
                fmt: DataFormat::Smol,
            },
            weights: w.clone(),
            bn_scale: vec![],
            bn_bias: vec![],
            bn_mean: vec![],
            bn_var: vec![],
            relu: false,
        };
        let x = rand_tensor(4, 4, 24, 21);
        let mut m = Machine::new();
        let (got, stats) = run_conv(&mut m, &cfg, &x);
        // reference depthwise
        let p = &cfg.plan;
        for h in 0..4 {
            for w_ in 0..4 {
                for c in 0..24 {
                    let mut acc = 0f64;
                    for r in 0..3 {
                        for s in 0..3 {
                            let ih = h as isize + r as isize - 1;
                            let iw = w_ as isize + s as isize - 1;
                            if ih < 0 || iw < 0 || ih >= 4 || iw >= 4 {
                                continue;
                            }
                            let xv = quant::quantize(x.at(ih as usize, iw as usize, c), 2);
                            let wv = quant::quantize(cfg.weights[(r * 3 + s) * 24 + c], 2);
                            acc += (xv * wv) as f64;
                        }
                    }
                    assert_eq!(got.at(h, w_, c), acc as f32, "h{h} w{w_} c{c}");
                }
            }
        }
        let _ = p;
        assert!(stats.vmul > 0);
    }
}
