//! Energy model: per-operation and per-memory-access energies (pJ).
//!
//! Absolute joules are not the claim — the paper's Key Finding 1 compares
//! *relative* energy (U4 ≈ 8x better than FP32, ≈ 2x better than INT8);
//! the constants below are representative 7nm-class figures whose ratios
//! drive those comparisons. Vector MAC energy scales with configured lane
//! precision (gate activity of the Fig. 3 datapath).

use crate::simd::patterns::Pattern;

/// Energy constants in picojoules.
#[derive(Debug, Clone, Copy)]
pub struct EnergyConfig {
    /// per 16-bit lane doing 4-bit MACs
    pub lane_mac_4b: f64,
    /// per lane doing 2-bit MACs
    pub lane_mac_2b: f64,
    /// per lane doing 1-bit MACs (xnor/popcount)
    pub lane_mac_1b: f64,
    /// per 32-bit f32 FMA lane (4 lanes per vector op)
    pub lane_fma_f32: f64,
    /// per 8-bit int MAC lane (16 lanes per vector op)
    pub lane_mac_i8: f64,
    /// simple vector ALU op (add/and/mov), whole vector
    pub vec_simple: f64,
    /// scalar/reduce op
    pub scalar: f64,
    /// memory energies per access
    pub l1_access: f64,
    pub l2_access: f64,
    pub mem_access: f64,
}

impl Default for EnergyConfig {
    fn default() -> Self {
        EnergyConfig {
            lane_mac_4b: 0.9,
            lane_mac_2b: 0.55,
            lane_mac_1b: 0.3,
            lane_fma_f32: 4.5,
            lane_mac_i8: 1.1,
            vec_simple: 1.2,
            scalar: 0.4,
            l1_access: 6.0,
            l2_access: 25.0,
            mem_access: 300.0,
        }
    }
}

impl EnergyConfig {
    /// Energy of one `vmac_Pn` under a pattern (sum over lanes).
    pub fn vmac_energy(&self, pattern: &Pattern) -> f64 {
        pattern
            .lane_precisions()
            .iter()
            .map(|&p| match p {
                4 => self.lane_mac_4b,
                2 => self.lane_mac_2b,
                1 => self.lane_mac_1b,
                _ => 0.0,
            })
            .sum()
    }

    /// Energy of one f32 FMA vector op (4 lanes).
    pub fn fma32_energy(&self) -> f64 {
        4.0 * self.lane_fma_f32
    }

    /// Energy of one int8 MAC vector op (16 lanes).
    pub fn mac_i8_energy(&self) -> f64 {
        16.0 * self.lane_mac_i8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_ordering() {
        let e = EnergyConfig::default();
        let u4 = e.vmac_energy(&Pattern::uniform(4));
        let u2 = e.vmac_energy(&Pattern::uniform(2));
        let u1 = e.vmac_energy(&Pattern::uniform(1));
        assert!(u4 > u2 && u2 > u1);
        // fp32 vector op costs more than the whole low-precision vector op
        assert!(e.fma32_energy() > u4);
    }

    #[test]
    fn mixed_between_uniforms() {
        let e = EnergyConfig::default();
        let mixed = e.vmac_energy(&Pattern::new(16, 24, 16));
        assert!(mixed < e.vmac_energy(&Pattern::uniform(4)));
        assert!(mixed > e.vmac_energy(&Pattern::uniform(1)));
    }
}
