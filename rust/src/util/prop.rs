//! Seeded property-test driver (offline substitute for proptest):
//! runs a property over many generated cases; on failure, reports the
//! seed and case index for exact reproduction.

use crate::util::rng::Rng;

/// Run `cases` random trials of `prop`, which receives a seeded RNG.
/// Panics with the reproducing seed on the first failure.
pub fn check<F: FnMut(&mut Rng) -> Result<(), String>>(name: &str, cases: u64, mut prop: F) {
    let base = 0x50319_u64 ^ fxhash(name);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// FNV-1a, const so property names hash at compile time where possible.
const fn fxhash(s: &str) -> u64 {
    let b = s.as_bytes();
    let mut h = 0xcbf29ce484222325u64;
    let mut i = 0;
    while i < b.len() {
        h ^= b[i] as u64;
        h = h.wrapping_mul(0x100000001b3);
        i += 1;
    }
    h
}

/// Assert-eq helper returning Err instead of panicking (for use in
/// properties).
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr, $($ctx:tt)*) => {
        if $a != $b {
            return Err(format!(
                "{} != {} ({})",
                stringify!($a),
                stringify!($b),
                format!($($ctx)*)
            ));
        }
    };
}
