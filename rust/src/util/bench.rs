//! Micro-benchmark harness (offline substitute for criterion): warmup,
//! timed iterations, median/mean/min reporting. Benches are plain
//! `harness = false` binaries using this module.

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn throughput(&self, items: f64) -> f64 {
        items / (self.mean_ns / 1e9)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Run a closure repeatedly and report timing. The closure should return a
/// value to keep the optimizer honest (it is black-boxed).
pub fn bench<T, F: FnMut() -> T>(name: &str, mut f: F) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    let mut warm = 0u64;
    loop {
        std::hint::black_box(f());
        warm += 1;
        if t0.elapsed().as_millis() > 50 || warm >= 1000 {
            break;
        }
    }
    let per_iter = t0.elapsed().as_nanos() as f64 / warm as f64;
    // aim for ~0.5 s of samples, between 5 and 200 sample groups
    let group_iters = ((5e6 / per_iter).ceil() as u64).clamp(1, 10_000);
    let groups = ((5e8 / (per_iter * group_iters as f64)).ceil() as u64).clamp(5, 200);

    let mut samples = Vec::with_capacity(groups as usize);
    for _ in 0..groups {
        let t = Instant::now();
        for _ in 0..group_iters {
            std::hint::black_box(f());
        }
        samples.push(t.elapsed().as_nanos() as f64 / group_iters as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let median = samples[samples.len() / 2];
    let min = samples[0];
    let r = BenchResult {
        name: name.to_string(),
        iters: groups * group_iters,
        mean_ns: mean,
        median_ns: median,
        min_ns: min,
    };
    println!(
        "{:<48} mean {:>12}  median {:>12}  min {:>12}  ({} iters)",
        r.name,
        fmt_ns(r.mean_ns),
        fmt_ns(r.median_ns),
        fmt_ns(r.min_ns),
        r.iters
    );
    r
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("noop-add", || std::hint::black_box(1u64) + 1);
        assert!(r.mean_ns > 0.0 && r.iters > 0);
    }
}
