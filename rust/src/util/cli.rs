//! Tiny CLI argument parser (offline substitute for clap): supports
//! `--flag`, `--key value` and positional arguments.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(raw: impl Iterator<Item = String>) -> Self {
        let mut out = Args::default();
        let raw: Vec<String> = raw.collect();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.options.insert(name.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).map(|v| v.parse().expect("integer option")).unwrap_or(default)
    }

    pub fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.get(key).map(|v| v.parse().expect("float option")).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_mixed() {
        let a = Args::parse(
            ["train", "--model", "resnet18", "--steps=50", "--quick"]
                .iter()
                .map(|s| s.to_string()),
        );
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("model"), Some("resnet18"));
        assert_eq!(a.get_usize("steps", 0), 50);
        assert!(a.has_flag("quick"));
    }
}
