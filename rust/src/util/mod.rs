//! Small in-tree substitutes for crates unavailable in this offline build
//! environment (see Cargo.toml): JSON (serde_json), a micro-benchmark
//! harness (criterion), a seeded property-test driver (proptest), CLI
//! parsing (clap) and a splitmix/xoshiro RNG (rand).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
