//! Deterministic PRNG (xoshiro256**) — offline substitute for `rand`.

/// xoshiro256** with splitmix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next_sm(), next_sm(), next_sm(), next_sm()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// uniform in [0, n)
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n.max(1)
    }

    /// uniform f32 in [0, 1)
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// uniform f32 in [lo, hi)
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// standard normal (Box-Muller)
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-7);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (std::f32::consts::TAU * u2).cos()
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "{mean}");
        assert!((var - 1.0).abs() < 0.1, "{var}");
    }
}
