//! Minimal JSON parser/serializer (offline substitute for serde_json;
//! this environment builds against the vendored crate set only —
//! see Cargo.toml). Parses the `artifacts/*.meta.json` manifests and
//! serializes experiment reports.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key}")),
            _ => bail!("not an object (key {key})"),
        }
    }
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }
    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing characters at {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s}: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at {}", self.i),
                    }
                }
                c => {
                    // re-walk utf8: collect continuation bytes
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let bytes = &self.b[self.i - 1..self.i - 1 + len];
                        out.push_str(std::str::from_utf8(bytes)?);
                        self.i += len - 1;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']' at {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => bail!("expected ',' or '}}' at {}, found '{}'", self.i, c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": true, "e": null}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "x\ny");
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("1e3").unwrap().as_f64().unwrap(), 1000.0);
        assert_eq!(parse("-0.5").unwrap().as_f64().unwrap(), -0.5);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse(r#""héllo é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo é");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{}x").is_err());
    }
}
