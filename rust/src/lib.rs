//! # SONIQ / SySMOL — hardware-software co-design for ULFlexiNets
//!
//! Rust implementation of the paper's full system: the configurable
//! ultra-low-precision SIMD architecture (bit-exact ALU + ISA), the
//! inference code generator, the timing/energy simulator (gem5
//! substitute), the hardware cost model, the SMOL pattern-selection
//! optimizer, the co-design coordinator that drives SASMOL training
//! through AOT-compiled JAX/Pallas artifacts via PJRT, and the batched
//! multi-threaded inference serving engine ([`serve`]) with prepared-
//! model caching.
//!
//! Layer map (see DESIGN.md):
//! - L3 (this crate): coordination, simulation, codegen, optimization.
//! - L2/L1 (python/compile, build-time only): JAX model + Pallas kernels,
//!   lowered once to `artifacts/*.hlo.txt`; loaded here by [`runtime`].

pub mod analysis;
pub mod codegen;
pub mod coordinator;
pub mod data;
pub mod hw;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod simd;
pub mod smol;
pub mod train;
pub mod util;
