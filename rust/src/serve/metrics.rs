//! Serving metrics: host-side throughput and latency percentiles plus
//! aggregated simulated-hardware counters (cycles / energy), serialized
//! to a [`ServeReport`] JSON via `util::json`.
//!
//! Multi-model pools aggregate per model ([`ModelAgg`]: request count,
//! throughput, simulated totals) and per `(model, layer)` ([`LayerAgg`])
//! — two models that happen to share a layer name never merge.
//!
//! Setup cost is reported *separately* from steady-state throughput:
//! model preparation (once per model, amortized by the registry) and
//! per-worker bind time are one-off costs that would otherwise be
//! folded into the request rate and understate the cached-path win. A
//! run whose wall clock is entirely bind time has no steady-state
//! window at all; its `steady_rps` is NaN (JSON `null`), never a
//! divide-by-almost-zero fantasy number.

use crate::serve::obs::{KvPoolSnapshot, ObsSnapshot};
use crate::serve::workers::Completion;
use crate::sim::machine::RunStats;
use crate::util::json::Json;
use std::collections::{BTreeMap, HashMap, HashSet};
use std::time::Duration;

/// JSON schema version of [`ServeReport::to_json`]. Bumped to 2 when
/// per-layer rows gained the `shard` dimension (sharded deployments
/// attribute cycles/energy per `(model, layer, shard)`); to 3 when the
/// report grew the span breakdown (queue/bind/service/gather wait),
/// per-worker utilization rows and bind/eviction totals; to 4 when it
/// grew admission/fault accounting (`rejected`, `lost_requests`,
/// `partial_requests`) and the `open_loop` offered-load points
/// (goodput + percentiles per rate); to 5 when it grew the `kv_pool`
/// block (paged KV-cache occupancy: page budget, used/free/spilled
/// pages, spill/fault/eviction/refusal counters) and per-worker
/// `kv_pages`. Bench tooling asserts it instead of guessing from row
/// shapes.
pub const SERVE_REPORT_SCHEMA: u64 = 5;

/// Aggregated simulated cost of one model's layer across all served
/// requests. Keyed by `(model, name, shard)`: layer names repeat across
/// models, and a sharded deployment runs the same layer name on every
/// shard.
#[derive(Debug, Clone)]
pub struct LayerAgg {
    /// the owning model (`ModelKey` display form, `model/design`)
    pub model: String,
    pub name: String,
    /// which shard of a sharded deployment ran the layer (`None` =
    /// whole-model execution)
    pub shard: Option<usize>,
    pub cycles: u64,
    pub energy_pj: f64,
}

/// Aggregated serving stats of one model in a (possibly multi-model)
/// run.
#[derive(Debug, Clone)]
pub struct ModelAgg {
    /// `ModelKey` display form (`model/design`)
    pub model: String,
    pub requests: usize,
    /// this model's completions over the whole run's wall clock
    pub throughput_rps: f64,
    pub cycles: u64,
    pub energy_pj: f64,
}

/// Exact mean/p99 of one lifecycle span over a run's completions
/// (computed from [`Completion::spans`] at summary time, not from the
/// streaming histograms, so end-of-run reports stay exact).
#[derive(Debug, Clone, Copy)]
pub struct SpanAgg {
    pub mean_ms: f64,
    pub p99_ms: f64,
}

impl SpanAgg {
    fn over(completions: &[Completion], f: impl Fn(&Completion) -> Duration) -> SpanAgg {
        let mut ms: Vec<f64> = completions.iter().map(|c| f(c).as_secs_f64() * 1e3).collect();
        sort_latencies(&mut ms);
        let mean =
            if ms.is_empty() { f64::NAN } else { ms.iter().sum::<f64>() / ms.len() as f64 };
        SpanAgg { mean_ms: mean, p99_ms: percentile(&ms, 0.99) }
    }
}

/// One worker's utilization row (from the [`ObsSnapshot`] passed to
/// [`summarize_with`]; reports built without one have no rows).
#[derive(Debug, Clone)]
pub struct WorkerRow {
    pub worker: usize,
    /// busy / (busy + idle); NaN if the worker never woke
    pub utilization: f64,
    pub busy_ms: f64,
    pub batches: u64,
    pub requests: u64,
    pub binds: u64,
    pub evictions: u64,
    pub resident_bytes: u64,
    pub kv_bytes: u64,
    /// resident KV-pool pages (0 when the pool is unpaged)
    pub kv_pages: u64,
}

/// One offered-load point of an open-loop run: requests arrive on a
/// generated schedule (Poisson or bursty) at `offered_rps` regardless
/// of completion rate, and the row reports what the pool actually
/// achieved — goodput counts only completions within the deadline, and
/// admission rejections are load shed at the gate, not failures.
#[derive(Debug, Clone, Copy)]
pub struct OpenLoopPoint {
    /// mean arrival rate of the generated schedule (req/s)
    pub offered_rps: f64,
    /// arrivals the generator attempted to submit
    pub offered: usize,
    /// completions drained (deadline met or not)
    pub completed: usize,
    /// completions within the per-request deadline
    pub good: usize,
    /// arrivals refused at the admission gate
    pub rejected: u64,
    /// the per-request latency deadline
    pub deadline_ms: f64,
    /// `good / wall` — the throughput that met the deadline
    pub goodput_rps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

impl OpenLoopPoint {
    pub fn to_json(&self) -> Json {
        let mut o: BTreeMap<String, Json> = BTreeMap::new();
        o.insert("offered_rps".into(), num(self.offered_rps));
        o.insert("offered".into(), num(self.offered as f64));
        o.insert("completed".into(), num(self.completed as f64));
        o.insert("good".into(), num(self.good as f64));
        o.insert("rejected".into(), num(self.rejected as f64));
        o.insert("deadline_ms".into(), num(self.deadline_ms));
        o.insert("goodput_rps".into(), num(self.goodput_rps));
        o.insert("p50_ms".into(), num(self.p50_ms));
        o.insert("p95_ms".into(), num(self.p95_ms));
        o.insert("p99_ms".into(), num(self.p99_ms));
        Json::Obj(o)
    }
}

/// One-off setup cost of a serving run, kept out of the steady-state
/// throughput numbers.
#[derive(Debug, Clone, Copy, Default)]
pub struct SetupTiming {
    /// model preparation (codegen + weight packing; once per model)
    pub prepare: Duration,
    /// slowest worker's model-to-machine bind (buffers + resident
    /// weights; once per worker, overlapped across workers)
    pub bind: Duration,
}

/// The serving run summary.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub requests: usize,
    pub batches: usize,
    pub mean_batch_size: f64,
    pub wall: Duration,
    /// host-side requests per second over the whole run (incl. bind)
    pub throughput_rps: f64,
    /// requests per second over the full-pool window (`wall - bind`,
    /// the time after the slowest worker finished binding). Slightly
    /// optimistic: requests served by already-bound workers during that
    /// bind are credited to the shrunken window. NaN (JSON `null`) when
    /// the window is empty or negligible (`bind` at or within jitter of
    /// `wall`, e.g. a tiny run).
    pub steady_rps: f64,
    pub setup: SetupTiming,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// simulated-hardware totals summed over all requests
    pub sim: RunStats,
    /// enqueue → worker pop: time before the executing worker first
    /// touched the request
    pub queue_wait: SpanAgg,
    /// worker pop → model resident (LRU bind/rebind cost)
    pub bind_wait: SpanAgg,
    /// a request's own execution time
    pub service: SpanAgg,
    /// sharded requests: shard 0 waiting on the slowest sibling
    pub gather_wait: SpanAgg,
    /// per-worker utilization rows (empty without a snapshot)
    pub workers: Vec<WorkerRow>,
    /// cold binds across all workers (0 without a snapshot)
    pub binds: u64,
    /// LRU evictions across all workers (0 without a snapshot)
    pub evictions: u64,
    /// per-model aggregation, in first-completion order
    pub per_model: Vec<ModelAgg>,
    /// per-(model, layer) aggregation, in first-completion order
    pub per_layer: Vec<LayerAgg>,
    /// submissions refused at the admission gate (0 without a snapshot
    /// or without a configured queue depth)
    pub rejected: u64,
    /// aggregated paged KV-pool state (`None` without a snapshot or
    /// when the pool serves from growable caches)
    pub kv_pool: Option<KvPoolSnapshot>,
    /// request ids lost to dead serving threads (empty on a healthy
    /// run; filled by callers from [`Server::faults`])
    ///
    /// [`Server::faults`]: crate::serve::Server::faults
    pub lost: Vec<u64>,
    /// sharded request ids whose gather was stranded partway (subset
    /// of the loss accounting; empty on a healthy run)
    pub partial: Vec<u64>,
    /// open-loop offered-load points (empty for closed-loop runs;
    /// filled by the open-loop harness)
    pub open_loop: Vec<OpenLoopPoint>,
}

/// Percentile over an ascending-sorted slice by rounded linear index
/// (`round(q * (n-1))`); `q` in [0,1]. NaN on an empty slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Ascending latency sort under `f64::total_cmp`: a degenerate value
/// (NaN from a future latency source) sorts last instead of panicking
/// report generation the way `partial_cmp(..).unwrap()` did.
fn sort_latencies(lat_ms: &mut [f64]) {
    lat_ms.sort_by(|a, b| a.total_cmp(b));
}

/// Fold a run's completions into a [`ServeReport`]. `setup` carries the
/// one-off prepare/bind costs measured by the caller
/// (`SetupTiming::default()` when not measured).
pub fn summarize(completions: &[Completion], wall: Duration, setup: SetupTiming) -> ServeReport {
    summarize_with(completions, wall, setup, None)
}

/// [`summarize`] plus an end-of-run [`ObsSnapshot`], which fills the
/// per-worker utilization rows and the bind/eviction totals (the span
/// breakdown comes from the completions either way).
pub fn summarize_with(
    completions: &[Completion],
    wall: Duration,
    setup: SetupTiming,
    snap: Option<&ObsSnapshot>,
) -> ServeReport {
    let n = completions.len();
    let mut lat_ms: Vec<f64> =
        completions.iter().map(|c| c.latency.as_secs_f64() * 1e3).collect();
    sort_latencies(&mut lat_ms);
    let mean_ms = if n == 0 { f64::NAN } else { lat_ms.iter().sum::<f64>() / n as f64 };

    let mut sim = RunStats::default();
    let mut batch_ids: HashSet<u64> = HashSet::new();
    // per-(model, layer, shard), first-seen order
    type LayerKey = (String, String, Option<usize>);
    let mut layer_order: Vec<LayerKey> = Vec::new();
    let mut layer_agg: HashMap<LayerKey, (u64, f64)> = HashMap::new();
    // per-model, first-seen order
    let mut model_order: Vec<String> = Vec::new();
    let mut model_agg: HashMap<String, (usize, u64, f64)> = HashMap::new();
    for c in completions {
        sim.merge(&c.total);
        batch_ids.insert(c.batch_id);
        let model = c.model.to_string();
        if !model_agg.contains_key(&model) {
            model_order.push(model.clone());
        }
        let me = model_agg.entry(model.clone()).or_insert((0, 0, 0.0));
        me.0 += 1;
        me.1 += c.total.cycles();
        me.2 += c.total.energy_pj;
        for l in &c.per_layer {
            let key = (model.clone(), l.name.clone(), l.shard);
            if !layer_agg.contains_key(&key) {
                layer_order.push(key.clone());
            }
            let e = layer_agg.entry(key).or_insert((0, 0.0));
            e.0 += l.stats.cycles();
            e.1 += l.stats.energy_pj;
        }
    }
    let batches = batch_ids.len();
    let per_layer = layer_order
        .into_iter()
        .map(|key| {
            let &(cycles, energy_pj) = &layer_agg[&key];
            let (model, name, shard) = key;
            LayerAgg { model, name, shard, cycles, energy_pj }
        })
        .collect();
    // a degenerate zero-wall run has no rate — report NaN (JSON null),
    // the same convention as steady_rps, never a clamped-denominator
    // fantasy number
    let wall_s = wall.as_secs_f64();
    let rps = |count: f64| if wall_s > 0.0 { count / wall_s } else { f64::NAN };
    let per_model = model_order
        .into_iter()
        .map(|model| {
            let &(requests, cycles, energy_pj) = &model_agg[&model];
            ModelAgg { model, requests, throughput_rps: rps(requests as f64), cycles, energy_pj }
        })
        .collect();

    let workers: Vec<WorkerRow> = snap
        .map(|s| {
            s.workers
                .iter()
                .map(|w| WorkerRow {
                    worker: w.worker,
                    utilization: w.utilization,
                    busy_ms: w.busy.as_secs_f64() * 1e3,
                    batches: w.batches,
                    requests: w.requests,
                    binds: w.binds,
                    evictions: w.evictions,
                    resident_bytes: w.resident_bytes,
                    kv_bytes: w.kv_bytes,
                    kv_pages: w.kv_pages,
                })
                .collect()
        })
        .unwrap_or_default();
    let binds = workers.iter().map(|w| w.binds).sum();
    let evictions = workers.iter().map(|w| w.evictions).sum();

    let steady = wall.saturating_sub(setup.bind);
    let steady_s = steady.as_secs_f64();
    ServeReport {
        requests: n,
        batches,
        mean_batch_size: if batches == 0 { 0.0 } else { n as f64 / batches as f64 },
        wall,
        throughput_rps: rps(n as f64),
        // an empty steady window means "no steady state was observed",
        // not "infinitely fast": report NaN -> JSON null. bind and wall
        // are measured on different threads, so bind can land within
        // measurement jitter of wall — a window under 0.1% of the run
        // is that jitter, never a denominator
        steady_rps: if steady.is_zero() || steady_s < wall_s * 1e-3 {
            f64::NAN
        } else {
            n as f64 / steady_s
        },
        setup,
        mean_ms,
        p50_ms: percentile(&lat_ms, 0.50),
        p95_ms: percentile(&lat_ms, 0.95),
        p99_ms: percentile(&lat_ms, 0.99),
        sim,
        queue_wait: SpanAgg::over(completions, |c| c.spans.queue_wait()),
        bind_wait: SpanAgg::over(completions, |c| c.spans.bind_wait()),
        service: SpanAgg::over(completions, |c| c.spans.service()),
        gather_wait: SpanAgg::over(completions, |c| c.spans.gather_wait()),
        workers,
        binds,
        evictions,
        per_model,
        per_layer,
        rejected: snap.map_or(0, |s| s.rejected),
        kv_pool: snap.and_then(|s| s.kv_pool),
        lost: Vec::new(),
        partial: Vec::new(),
        open_loop: Vec::new(),
    }
}

/// NaN/inf (e.g. percentiles of an empty run) have no JSON encoding;
/// emit null instead of an unparseable literal.
fn num(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

/// `{v:.prec$}` with non-finite values rendered as `n/a` (the print
/// analogue of the JSON-null convention), never a literal `NaN`.
fn fmt_or_na(v: f64, prec: usize) -> String {
    if v.is_finite() {
        format!("{v:.prec$}")
    } else {
        "n/a".to_string()
    }
}

impl ServeReport {
    /// Serialize for dashboards / regression tracking.
    pub fn to_json(&self) -> Json {
        let mut o: BTreeMap<String, Json> = BTreeMap::new();
        o.insert("schema".into(), num(SERVE_REPORT_SCHEMA as f64));
        o.insert("requests".into(), num(self.requests as f64));
        o.insert("batches".into(), num(self.batches as f64));
        o.insert("mean_batch_size".into(), num(self.mean_batch_size));
        o.insert("wall_ms".into(), num(self.wall.as_secs_f64() * 1e3));
        o.insert("prepare_ms".into(), num(self.setup.prepare.as_secs_f64() * 1e3));
        o.insert("bind_ms".into(), num(self.setup.bind.as_secs_f64() * 1e3));
        o.insert("throughput_rps".into(), num(self.throughput_rps));
        o.insert("steady_throughput_rps".into(), num(self.steady_rps));
        o.insert("latency_mean_ms".into(), num(self.mean_ms));
        o.insert("latency_p50_ms".into(), num(self.p50_ms));
        o.insert("latency_p95_ms".into(), num(self.p95_ms));
        o.insert("latency_p99_ms".into(), num(self.p99_ms));
        o.insert("sim_cycles".into(), num(self.sim.cycles() as f64));
        o.insert("sim_energy_pj".into(), num(self.sim.energy_pj));
        o.insert("sim_instrs".into(), num(self.sim.instrs as f64));
        o.insert("queue_wait_mean_ms".into(), num(self.queue_wait.mean_ms));
        o.insert("queue_wait_p99_ms".into(), num(self.queue_wait.p99_ms));
        o.insert("bind_wait_mean_ms".into(), num(self.bind_wait.mean_ms));
        o.insert("bind_wait_p99_ms".into(), num(self.bind_wait.p99_ms));
        o.insert("service_mean_ms".into(), num(self.service.mean_ms));
        o.insert("service_p99_ms".into(), num(self.service.p99_ms));
        o.insert("gather_wait_mean_ms".into(), num(self.gather_wait.mean_ms));
        o.insert("gather_wait_p99_ms".into(), num(self.gather_wait.p99_ms));
        o.insert("binds".into(), num(self.binds as f64));
        o.insert("evictions".into(), num(self.evictions as f64));
        o.insert("rejected".into(), num(self.rejected as f64));
        // present only for paged-KV runs, so its presence is greppable
        if let Some(p) = &self.kv_pool {
            o.insert("kv_pool".into(), p.to_json());
        }
        o.insert(
            "lost_requests".into(),
            Json::Arr(self.lost.iter().map(|&id| num(id as f64)).collect()),
        );
        o.insert(
            "partial_requests".into(),
            Json::Arr(self.partial.iter().map(|&id| num(id as f64)).collect()),
        );
        o.insert(
            "open_loop".into(),
            Json::Arr(self.open_loop.iter().map(OpenLoopPoint::to_json).collect()),
        );
        let workers: Vec<Json> = self
            .workers
            .iter()
            .map(|w| {
                let mut wo: BTreeMap<String, Json> = BTreeMap::new();
                wo.insert("worker".into(), num(w.worker as f64));
                wo.insert("utilization".into(), num(w.utilization));
                wo.insert("busy_ms".into(), num(w.busy_ms));
                wo.insert("batches".into(), num(w.batches as f64));
                wo.insert("requests".into(), num(w.requests as f64));
                wo.insert("binds".into(), num(w.binds as f64));
                wo.insert("evictions".into(), num(w.evictions as f64));
                wo.insert("resident_bytes".into(), num(w.resident_bytes as f64));
                wo.insert("kv_bytes".into(), num(w.kv_bytes as f64));
                wo.insert("kv_pages".into(), num(w.kv_pages as f64));
                Json::Obj(wo)
            })
            .collect();
        o.insert("workers".into(), Json::Arr(workers));
        let models: Vec<Json> = self
            .per_model
            .iter()
            .map(|m| {
                let mut mo: BTreeMap<String, Json> = BTreeMap::new();
                mo.insert("model".into(), Json::Str(m.model.clone()));
                mo.insert("requests".into(), num(m.requests as f64));
                mo.insert("throughput_rps".into(), num(m.throughput_rps));
                mo.insert("cycles".into(), num(m.cycles as f64));
                mo.insert("energy_pj".into(), num(m.energy_pj));
                Json::Obj(mo)
            })
            .collect();
        o.insert("per_model".into(), Json::Arr(models));
        let layers: Vec<Json> = self
            .per_layer
            .iter()
            .map(|l| {
                let mut lo: BTreeMap<String, Json> = BTreeMap::new();
                lo.insert("model".into(), Json::Str(l.model.clone()));
                lo.insert("name".into(), Json::Str(l.name.clone()));
                lo.insert(
                    "shard".into(),
                    match l.shard {
                        Some(s) => num(s as f64),
                        None => Json::Null,
                    },
                );
                lo.insert("cycles".into(), num(l.cycles as f64));
                lo.insert("energy_pj".into(), num(l.energy_pj));
                Json::Obj(lo)
            })
            .collect();
        o.insert("per_layer".into(), Json::Arr(layers));
        Json::Obj(o)
    }

    /// Human-readable summary block.
    pub fn print(&self) {
        println!(
            "  requests {:>6}   batches {:>5}   mean batch {:>5.1}   wall {:>8.1?}",
            self.requests, self.batches, self.mean_batch_size, self.wall
        );
        println!(
            "  setup: prepare {:.2?} (once per model)   bind {:.2?} (slowest worker)",
            self.setup.prepare, self.setup.bind
        );
        println!(
            "  throughput {:>9} req/s (incl. bind)   steady-state {:>9} req/s",
            fmt_or_na(self.throughput_rps, 1),
            fmt_or_na(self.steady_rps, 1)
        );
        println!(
            "  latency mean {} ms  p50 {}  p95 {}  p99 {}",
            fmt_or_na(self.mean_ms, 2),
            fmt_or_na(self.p50_ms, 2),
            fmt_or_na(self.p95_ms, 2),
            fmt_or_na(self.p99_ms, 2)
        );
        println!(
            "  breakdown mean/p99 ms: queue {}/{}  bind {}/{}  service {}/{}  gather {}/{}",
            fmt_or_na(self.queue_wait.mean_ms, 2),
            fmt_or_na(self.queue_wait.p99_ms, 2),
            fmt_or_na(self.bind_wait.mean_ms, 2),
            fmt_or_na(self.bind_wait.p99_ms, 2),
            fmt_or_na(self.service.mean_ms, 2),
            fmt_or_na(self.service.p99_ms, 2),
            fmt_or_na(self.gather_wait.mean_ms, 2),
            fmt_or_na(self.gather_wait.p99_ms, 2)
        );
        println!(
            "  simulated: {} cycles, {:.1} uJ over {} instrs",
            self.sim.cycles(),
            self.sim.energy_pj / 1e6,
            self.sim.instrs
        );
        for w in &self.workers {
            println!(
                "  worker {:<3} util% {:>5}  busy {:>9} ms  {:>6} batches  {:>7} req  \
                 binds {:>4}  evict {:>4}  resident {} B  kv {} B",
                w.worker,
                fmt_or_na(w.utilization * 100.0, 1),
                fmt_or_na(w.busy_ms, 1),
                w.batches,
                w.requests,
                w.binds,
                w.evictions,
                w.resident_bytes,
                w.kv_bytes
            );
        }
        if self.per_model.len() > 1 {
            for m in &self.per_model {
                println!(
                    "  model {:<20} {:>6} req  {:>9} req/s  {} cycles  {:.1} uJ",
                    m.model,
                    m.requests,
                    fmt_or_na(m.throughput_rps, 1),
                    m.cycles,
                    m.energy_pj / 1e6
                );
            }
        }
        for p in &self.open_loop {
            println!(
                "  open-loop @ {:>8} req/s offered: {:>6} in  {:>6} done  {:>6} good  \
                 {:>5} rejected  goodput {:>8} req/s  p50 {} p95 {} p99 {} (deadline {} ms)",
                fmt_or_na(p.offered_rps, 1),
                p.offered,
                p.completed,
                p.good,
                p.rejected,
                fmt_or_na(p.goodput_rps, 1),
                fmt_or_na(p.p50_ms, 2),
                fmt_or_na(p.p95_ms, 2),
                fmt_or_na(p.p99_ms, 2),
                fmt_or_na(p.deadline_ms, 1)
            );
        }
        if self.rejected > 0 && self.open_loop.is_empty() {
            println!("  admission rejections: {}", self.rejected);
        }
        if let Some(p) = &self.kv_pool {
            let budget = p
                .pages_per_worker
                .map_or("unbounded".to_string(), |b| format!("{b}/worker"));
            println!(
                "  kv pool: {} pages used ({} free, {} spilled; budget {})  \
                 spills {}  faults {}  evictions {}  refusals {}",
                p.pages_used,
                p.pages_free,
                p.spilled_pages,
                budget,
                p.spills,
                p.faults,
                p.evictions,
                p.refusals
            );
        }
        if !self.lost.is_empty() || !self.partial.is_empty() {
            println!(
                "  WARNING: {} request(s) lost to dead serving threads ({} stranded mid-gather)",
                self.lost.len(),
                self.partial.len()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_rounded_linear_index() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&v, 0.50), 51.0); // round(99*0.5)=50 -> v[50]
        assert!(percentile(&[], 0.5).is_nan());
    }

    #[test]
    fn latency_sort_survives_nan() {
        // regression: the old partial_cmp(..).unwrap() comparator
        // panicked on NaN, taking down report generation for the whole
        // run; total_cmp orders NaN after every finite latency
        let mut v = vec![3.0, f64::NAN, 1.0, 2.0];
        sort_latencies(&mut v);
        assert_eq!(&v[..3], &[1.0, 2.0, 3.0]);
        assert!(v[3].is_nan());
        // and percentiles over the finite prefix still behave
        assert_eq!(percentile(&v[..3], 0.5), 2.0);
    }

    #[test]
    fn non_finite_prints_as_na() {
        assert_eq!(fmt_or_na(1.25, 1), "1.2");
        assert_eq!(fmt_or_na(f64::NAN, 2), "n/a");
        assert_eq!(fmt_or_na(f64::INFINITY, 1), "n/a");
    }

    #[test]
    fn zero_wall_run_has_no_rate() {
        // unified with the steady_rps convention: NaN -> JSON null,
        // not a clamped-denominator fantasy throughput
        let r = summarize(&[], Duration::ZERO, SetupTiming::default());
        assert!(r.throughput_rps.is_nan());
        assert!(r.steady_rps.is_nan());
        assert_eq!(r.to_json().get("throughput_rps").unwrap(), &Json::Null);
    }
}
