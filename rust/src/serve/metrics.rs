//! Serving metrics: host-side throughput and latency percentiles plus
//! aggregated simulated-hardware counters (cycles / energy, per layer
//! and total), serialized to a [`ServeReport`] JSON via `util::json`.
//!
//! Setup cost is reported *separately* from steady-state throughput:
//! model preparation (once per model, amortized by the registry) and
//! per-worker bind time are one-off costs that would otherwise be
//! folded into the request rate and understate the cached-path win.

use crate::serve::workers::Completion;
use crate::sim::machine::RunStats;
use crate::util::json::Json;
use std::collections::{BTreeMap, HashMap, HashSet};
use std::time::Duration;

/// Aggregated simulated cost of one layer across all served requests.
#[derive(Debug, Clone)]
pub struct LayerAgg {
    pub name: String,
    pub cycles: u64,
    pub energy_pj: f64,
}

/// One-off setup cost of a serving run, kept out of the steady-state
/// throughput numbers.
#[derive(Debug, Clone, Copy, Default)]
pub struct SetupTiming {
    /// model preparation (codegen + weight packing; once per model)
    pub prepare: Duration,
    /// slowest worker's model-to-machine bind (buffers + resident
    /// weights; once per worker, overlapped across workers)
    pub bind: Duration,
}

/// The serving run summary.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub requests: usize,
    pub batches: usize,
    pub mean_batch_size: f64,
    pub wall: Duration,
    /// host-side requests per second over the whole run (incl. bind)
    pub throughput_rps: f64,
    /// requests per second over the full-pool window (`wall - bind`,
    /// the time after the slowest worker finished binding). Slightly
    /// optimistic: requests served by already-bound workers during that
    /// bind are credited to the shrunken window.
    pub steady_rps: f64,
    pub setup: SetupTiming,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// simulated-hardware totals summed over all requests
    pub sim: RunStats,
    pub per_layer: Vec<LayerAgg>,
}

/// Percentile over an ascending-sorted slice by rounded linear index
/// (`round(q * (n-1))`); `q` in [0,1]. NaN on an empty slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Fold a run's completions into a [`ServeReport`]. `setup` carries the
/// one-off prepare/bind costs measured by the caller
/// (`SetupTiming::default()` when not measured).
pub fn summarize(completions: &[Completion], wall: Duration, setup: SetupTiming) -> ServeReport {
    let n = completions.len();
    let mut lat_ms: Vec<f64> =
        completions.iter().map(|c| c.latency.as_secs_f64() * 1e3).collect();
    lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean_ms = if n == 0 { f64::NAN } else { lat_ms.iter().sum::<f64>() / n as f64 };

    let mut sim = RunStats::default();
    let mut batch_ids: HashSet<u64> = HashSet::new();
    let mut order: Vec<String> = Vec::new();
    let mut agg: HashMap<String, (u64, f64)> = HashMap::new();
    for c in completions {
        sim.merge(&c.total);
        batch_ids.insert(c.batch_id);
        for l in &c.per_layer {
            if !agg.contains_key(&l.name) {
                order.push(l.name.clone());
            }
            let e = agg.entry(l.name.clone()).or_insert((0, 0.0));
            e.0 += l.stats.cycles();
            e.1 += l.stats.energy_pj;
        }
    }
    let batches = batch_ids.len();
    let per_layer = order
        .into_iter()
        .map(|name| {
            let &(cycles, energy_pj) = &agg[&name];
            LayerAgg { name, cycles, energy_pj }
        })
        .collect();

    let steady = wall.saturating_sub(setup.bind);
    ServeReport {
        requests: n,
        batches,
        mean_batch_size: if batches == 0 { 0.0 } else { n as f64 / batches as f64 },
        wall,
        throughput_rps: n as f64 / wall.as_secs_f64().max(1e-9),
        steady_rps: n as f64 / steady.as_secs_f64().max(1e-9),
        setup,
        mean_ms,
        p50_ms: percentile(&lat_ms, 0.50),
        p95_ms: percentile(&lat_ms, 0.95),
        p99_ms: percentile(&lat_ms, 0.99),
        sim,
        per_layer,
    }
}

/// NaN/inf (e.g. percentiles of an empty run) have no JSON encoding;
/// emit null instead of an unparseable literal.
fn num(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

impl ServeReport {
    /// Serialize for dashboards / regression tracking.
    pub fn to_json(&self) -> Json {
        let mut o: BTreeMap<String, Json> = BTreeMap::new();
        o.insert("requests".into(), num(self.requests as f64));
        o.insert("batches".into(), num(self.batches as f64));
        o.insert("mean_batch_size".into(), num(self.mean_batch_size));
        o.insert("wall_ms".into(), num(self.wall.as_secs_f64() * 1e3));
        o.insert("prepare_ms".into(), num(self.setup.prepare.as_secs_f64() * 1e3));
        o.insert("bind_ms".into(), num(self.setup.bind.as_secs_f64() * 1e3));
        o.insert("throughput_rps".into(), num(self.throughput_rps));
        o.insert("steady_throughput_rps".into(), num(self.steady_rps));
        o.insert("latency_mean_ms".into(), num(self.mean_ms));
        o.insert("latency_p50_ms".into(), num(self.p50_ms));
        o.insert("latency_p95_ms".into(), num(self.p95_ms));
        o.insert("latency_p99_ms".into(), num(self.p99_ms));
        o.insert("sim_cycles".into(), num(self.sim.cycles() as f64));
        o.insert("sim_energy_pj".into(), num(self.sim.energy_pj));
        o.insert("sim_instrs".into(), num(self.sim.instrs as f64));
        let layers: Vec<Json> = self
            .per_layer
            .iter()
            .map(|l| {
                let mut lo: BTreeMap<String, Json> = BTreeMap::new();
                lo.insert("name".into(), Json::Str(l.name.clone()));
                lo.insert("cycles".into(), num(l.cycles as f64));
                lo.insert("energy_pj".into(), num(l.energy_pj));
                Json::Obj(lo)
            })
            .collect();
        o.insert("per_layer".into(), Json::Arr(layers));
        Json::Obj(o)
    }

    /// Human-readable summary block.
    pub fn print(&self) {
        println!(
            "  requests {:>6}   batches {:>5}   mean batch {:>5.1}   wall {:>8.1?}",
            self.requests, self.batches, self.mean_batch_size, self.wall
        );
        println!(
            "  setup: prepare {:.2?} (once per model)   bind {:.2?} (slowest worker)",
            self.setup.prepare, self.setup.bind
        );
        println!(
            "  throughput {:>9.1} req/s (incl. bind)   steady-state {:>9.1} req/s",
            self.throughput_rps, self.steady_rps
        );
        println!(
            "  latency mean {:.2} ms  p50 {:.2}  p95 {:.2}  p99 {:.2}",
            self.mean_ms, self.p50_ms, self.p95_ms, self.p99_ms
        );
        println!(
            "  simulated: {} cycles, {:.1} uJ over {} instrs",
            self.sim.cycles(),
            self.sim.energy_pj / 1e6,
            self.sim.instrs
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_rounded_linear_index() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&v, 0.50), 51.0); // round(99*0.5)=50 -> v[50]
        assert!(percentile(&[], 0.5).is_nan());
    }
}
