//! Per-session decode state and the KV-cached attention ops.
//!
//! An autoregressive decode session's K/V operands only *grow*: position
//! `t` adds one packed K column (the score GEMM's "weight" layout is
//! append-only: `(position * n_chunks + chunk) * 16`) and one quantized
//! V value per feature (the context GEMM chunks along the position
//! axis, so only each feature's *tail* chunk vector is rewritten in
//! place). [`CachedAttnOp`] appends, then runs score GEMM -> softmax ->
//! context GEMM for the single new row — O(prefix) work per step, with
//! no per-step heap allocation in the append path beyond amortized
//! cache growth.
//!
//! Storage comes in two shapes behind [`KvSlot`]:
//!
//! * **Growable** (the PR-3 legacy layout): one worst-case host vec
//!   per operand, fine for a handful of sessions.
//! * **Paged** (`serve::kvpool`): fixed-size chunk-aligned pages from
//!   the worker's [`KvPool`], staged into the machine's weight buffer
//!   through a page-table indirection — page `i` of a slot holds
//!   positions `[i*P, (i+1)*P)`, and the staging loop writes each
//!   page's fragment at the exact offset the growable layout would
//!   occupy, so the machine reads **byte-identical** buffers either
//!   way (the bit-exactness proptests pin this down).
//!
//! [`CausalAvOp`] is the one-shot twin: the causal A·V of a *full*
//! prefix run, which re-quantizes and re-packs the whole V prefix for
//! every row (the cost the session cache amortizes away). Both funnel
//! through [`run_gemm_row`], so a cached step is bit-identical to
//! re-running its full prefix through the one-shot causal graph.
//!
//! The position axis must carry a *uniform* precision: positions stream
//! in one at a time, and PatternMatch's importance reordering is
//! undefined for positions that have not been seen yet. The `dh` axis
//! keeps its arbitrary per-channel assignment. Paged sessions may store
//! V at a *lower* uniform level than compute ([`SessionKvCfg::v_bits`],
//! clamped per slot to the compute precision) — a capacity/accuracy
//! knob; decode is bit-identical only at compute precision.

use crate::analysis::{KernelSpec, ProgramToVerify};
use crate::codegen::gemm::{emit_gemm, GemmPlan};
use crate::codegen::{self, pack, DataFormat, LayerBufs};
use crate::simd::isa::BufId;
use crate::serve::engine::{BoundKernel, ExecCtx, PreparedOp};
use crate::serve::kvpool::{effective_v_prec, KvPage, KvPool, PageGeom, SessionKvCfg};
use crate::sim::eltwise;
use crate::sim::machine::Machine;
use crate::sim::network::{AttnCfg, MatmulCfg, Tensor};
use crate::simd::patterns::Pattern;
use crate::simd::vector::pack_values;
use crate::smol::pattern_match::Assignment;
use crate::smol::quant;

/// The storage backing one slot's K/V operands.
#[derive(Debug, Clone)]
enum KvStore {
    /// PR-3 layout: one growable host vec per operand.
    Growable {
        /// per head: packed K columns, `(position * nch_dh + chunk) *
        /// 16` layout — append-only bytes
        k_packed: Vec<Vec<u8>>,
        /// per head: quantized V values, position-major `[pos * dh +
        /// feat]`
        v_quant: Vec<Vec<f32>>,
        /// per head, per feature: packed V chunk vectors along the
        /// position axis (the last chunk is partial and rewritten in
        /// place on append)
        v_packed: Vec<Vec<Vec<u8>>>,
    },
    /// Fixed-size pages from the worker's [`KvPool`]; `pages[i]` holds
    /// positions `[i*P, (i+1)*P)` for every head.
    Paged(PagedSlot),
}

#[derive(Debug, Clone)]
struct PagedSlot {
    geom: PageGeom,
    pages: Vec<KvPage>,
}

/// One attention node's K/V cache within a session.
#[derive(Debug, Default, Clone)]
pub struct KvSlot {
    /// positions appended so far
    pub len: usize,
    /// `None` until the first step initializes the shape
    store: Option<KvStore>,
}

impl KvSlot {
    fn ensure(
        &mut self,
        heads: usize,
        dh: usize,
        nch_dh: usize,
        v_prec: u8,
        kv: Option<SessionKvCfg>,
    ) {
        if self.store.is_some() {
            return;
        }
        self.store = Some(match kv {
            Some(cfg) => KvStore::Paged(PagedSlot {
                geom: PageGeom::new(heads, dh, nch_dh, v_prec, cfg.page_positions),
                pages: Vec::new(),
            }),
            None => KvStore::Growable {
                k_packed: vec![Vec::new(); heads],
                v_quant: vec![Vec::new(); heads],
                v_packed: vec![vec![Vec::new(); dh]; heads],
            },
        });
    }

    /// Bytes resident in this slot's packed/quantized caches (paged
    /// slots count whole resident pages — the allocation granularity).
    pub fn kv_bytes(&self) -> usize {
        match &self.store {
            None => 0,
            Some(KvStore::Growable { k_packed, v_quant, v_packed }) => {
                k_packed.iter().map(Vec::len).sum::<usize>()
                    + v_quant.iter().map(|v| v.len() * 4).sum::<usize>()
                    + v_packed.iter().flatten().map(Vec::len).sum::<usize>()
            }
            Some(KvStore::Paged(ps)) => ps.pages.len() * ps.geom.page_bytes(),
        }
    }

    /// Pages currently resident in this slot (0 for growable slots).
    pub fn pages(&self) -> usize {
        match &self.store {
            Some(KvStore::Paged(ps)) => ps.pages.len(),
            _ => 0,
        }
    }

    fn take_pages(&mut self) -> Vec<KvPage> {
        match &mut self.store {
            Some(KvStore::Paged(ps)) => std::mem::take(&mut ps.pages),
            _ => Vec::new(),
        }
    }

    fn restore_pages(&mut self, pages: Vec<KvPage>) {
        match &mut self.store {
            Some(KvStore::Paged(ps)) => {
                debug_assert!(ps.pages.is_empty(), "restore over resident pages");
                ps.pages = pages;
            }
            _ => debug_assert!(pages.is_empty(), "pages restored into a non-paged slot"),
        }
    }
}

/// All KV caches of one decode session (one [`KvSlot`] per
/// `CachedAttn` node of the step graph, in graph order). Owned by the
/// worker the session is pinned to.
#[derive(Debug, Default, Clone)]
pub struct SessionState {
    pub slots: Vec<KvSlot>,
    /// `Some` = slots use paged storage from the worker's pool.
    pub(crate) kv: Option<SessionKvCfg>,
}

impl SessionState {
    pub fn new(slots: usize) -> SessionState {
        SessionState { slots: vec![KvSlot::default(); slots], kv: None }
    }

    /// A session whose slots allocate fixed-size pages from the
    /// worker's [`KvPool`] instead of growing host vecs.
    pub fn new_paged(slots: usize, kv: SessionKvCfg) -> SessionState {
        SessionState { slots: vec![KvSlot::default(); slots], kv: Some(kv) }
    }

    /// Decoded positions so far (0 for a fresh session).
    pub fn positions(&self) -> usize {
        self.slots.first().map(|s| s.len).unwrap_or(0)
    }

    /// Bytes resident across all of this session's KV caches — the
    /// per-session footprint that worker placement balances on.
    pub fn kv_bytes(&self) -> usize {
        self.slots.iter().map(KvSlot::kv_bytes).sum()
    }

    /// Pages resident across all slots (0 for growable sessions).
    pub fn pages(&self) -> usize {
        self.slots.iter().map(KvSlot::pages).sum()
    }

    /// Move every slot's pages out (spill): lengths stay, storage
    /// empties. Returns one page run per slot, restorable verbatim by
    /// [`SessionState::restore_all_pages`].
    pub(crate) fn take_all_pages(&mut self) -> Vec<Vec<KvPage>> {
        self.slots.iter_mut().map(KvSlot::take_pages).collect()
    }

    /// Fault spilled pages back in (inverse of
    /// [`SessionState::take_all_pages`]).
    pub(crate) fn restore_all_pages(&mut self, slots: Vec<Vec<KvPage>>) {
        debug_assert_eq!(slots.len(), self.slots.len(), "spilled slot count");
        for (slot, pages) in self.slots.iter_mut().zip(slots) {
            slot.restore_pages(pages);
        }
    }

    /// Return every resident page to the pool's free lists (session
    /// close / eviction).
    pub(crate) fn release_into(&mut self, pool: &mut KvPool) {
        for slot in &mut self.slots {
            if let Some(KvStore::Paged(ps)) = &mut slot.store {
                let pages = std::mem::take(&mut ps.pages);
                if !pages.is_empty() {
                    pool.release(&ps.geom, pages);
                }
            }
        }
    }
}

/// Execute one `m = 1` GEMM row: quantize + pack `a_vals` (original
/// channel order) as the single activation row, write this contraction
/// length's tail masks, stream-emit the Algorithm-4 GEMM kernel into
/// the machine (no instruction stream is materialized — the kernel
/// varies with the prefix length), and read the epilogued outputs.
/// The right operand must already be resident in `bufs.weights` in the
/// `(column * n_chunks + chunk) * 16` layout.
///
/// Both the cached decode step and the one-shot causal A·V run their
/// rows through this function, which is what makes them bit-identical.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_gemm_row(
    m: &mut Machine,
    bufs: &LayerBufs,
    plan: &GemmPlan,
    a_vals: &[f32],
    scale: f32,
    vals: &mut Vec<f32>,
    packed_act: &mut Vec<u8>,
    masks: &mut Vec<u8>,
    out: &mut [f32],
) {
    assert_eq!(plan.m, 1, "{}: row GEMMs are single-row", plan.name);
    assert_eq!(out.len(), plan.n, "{}: output row length", plan.name);
    let lp = plan.layer_plan();

    // stage the A row (quantize + rearrange + pack through scratch,
    // charged as streaming traffic like every kernel's staging)
    packed_act.clear();
    pack::pack_column_into(&plan.asg, a_vals, vals, packed_act);
    m.write_bytes(bufs.input, 0, packed_act);
    m.clear_buffer(bufs.out);
    m.stream_touch(bufs.input, packed_act.len(), true);
    m.charge_bulk(a_vals.len() as u64, 0);

    // this contraction length's tail masks
    pack::pack_masks_into(&lp, masks);
    m.write_bytes(bufs.masks, 0, masks);

    // stream-emit the kernel under the row's chunk patterns
    m.patterns.clear();
    let base = codegen::register_patterns(&lp, &mut m.patterns);
    emit_gemm(plan, bufs, base, m);

    // epilogue: accumulators -> f32, single-tap tail bias + scale
    let bias = lp.tail_bias();
    for (j, o) in out.iter_mut().enumerate() {
        let acc = m.read_i32(bufs.out, j * 4);
        *o = (acc as i64 - bias) as f32 / quant::ACC_SCALE * scale;
    }
    m.stream_touch(bufs.out, out.len() * 4, false);
    m.charge_bulk(out.len() as u64, (out.len() * 4) as u64);
}

/// Emit one row GEMM's kernel for the static verifier, exactly as
/// [`run_gemm_row`] stream-emits it at request time (same plan, same
/// pattern registration, symbolic buffer ids), with the spec's buffer
/// extents overridden to the op's shared bind-time allocation.
fn rep_row_program(
    plan: &GemmPlan,
    (input, weights, out, masks): (usize, usize, usize, usize),
) -> ProgramToVerify<'static> {
    let symbolic = LayerBufs {
        input: BufId(0),
        weights: BufId(1),
        out: BufId(2),
        masks: BufId(3),
    };
    let lp = plan.layer_plan();
    let mut patterns = Vec::new();
    let base = codegen::register_patterns(&lp, &mut patterns);
    let mut program = Vec::new();
    emit_gemm(plan, &symbolic, base, &mut program);
    ProgramToVerify {
        spec: KernelSpec::for_gemm(plan).with_buffers(input, weights, out, masks),
        program: std::borrow::Cow::Owned(program),
        terms: crate::analysis::TermSpec::for_gemm(plan, false),
    }
}

/// Fused KV-cached decode attention (one step): append this position's
/// K/V to the session's packed caches, score the new query row against
/// the cached prefix, softmax, and contract the probabilities with the
/// cached packed V.
#[derive(Debug)]
pub struct CachedAttnOp {
    name: String,
    /// index into [`SessionState::slots`]
    slot: usize,
    heads: usize,
    dh: usize,
    scale: f32,
    pos_prec: u8,
    dh_asg: Assignment,
    max_positions: usize,
    fmt: DataFormat,
    /// chunk count of the dh (score contraction) axis
    nch_dh: usize,
}

impl CachedAttnOp {
    /// (input, weights, out, masks) buffer bytes [`PreparedOp::bind`]
    /// allocates — one place, so `bind` and `bind_bytes` cannot drift.
    /// Sized for compute precision; a lower V tier only *shrinks* the
    /// position-chunk count, so the buffers always suffice.
    fn buf_bytes(&self) -> (usize, usize, usize, usize) {
        let cap = Pattern::uniform(self.pos_prec).capacity() as usize;
        let nch_pos = self.max_positions.div_ceil(cap);
        let nch_max = self.nch_dh.max(nch_pos);
        (
            16 * nch_max,
            16 * (self.max_positions * self.nch_dh).max(self.dh * nch_pos),
            (4 * self.max_positions.max(self.dh)).max(16 * nch_max),
            16 * nch_max,
        )
    }

    pub fn prepare(cfg: &AttnCfg, slot: usize) -> CachedAttnOp {
        assert_eq!(cfg.fmt, DataFormat::Smol, "{}: cached decode needs SMOL operands", cfg.name);
        assert_eq!(cfg.dh_asg.num_channels(), cfg.dh, "{}: dh assignment size", cfg.name);
        assert!(cfg.max_positions > 0, "{}: max_positions must be positive", cfg.name);
        let nch_dh = cfg
            .dh_asg
            .chunks
            .iter()
            .zip(cfg.dh_asg.valid.iter())
            .filter(|&(_, &v)| v > 0)
            .count();
        CachedAttnOp {
            name: cfg.name.clone(),
            slot,
            heads: cfg.heads,
            dh: cfg.dh,
            scale: cfg.scale,
            pos_prec: cfg.pos_prec,
            dh_asg: cfg.dh_asg.clone(),
            max_positions: cfg.max_positions,
            fmt: cfg.fmt,
            nch_dh,
        }
    }
}

impl PreparedOp for CachedAttnOp {
    fn name(&self) -> Option<&str> {
        Some(&self.name)
    }

    /// Buffers sized once for `max_positions`, shared by the score and
    /// context GEMMs of every session on this worker.
    fn bind(&self, m: &mut Machine) -> Option<BoundKernel> {
        let (input, weights, out, masks) = self.buf_bytes();
        let bufs = LayerBufs {
            input: m.alloc(input),
            weights: m.alloc(weights),
            out: m.alloc(out),
            masks: m.alloc(masks),
        };
        Some(BoundKernel { bufs, program: Vec::new() })
    }

    fn bind_bytes(&self) -> usize {
        let (input, weights, out, masks) = self.buf_bytes();
        input + weights + out + masks
    }

    /// Representative per-length row programs covering this op's whole
    /// emission space against its shared `max_positions`-sized
    /// buffers: the score GEMM at prefix lengths 1 and `max_positions`
    /// (the dh-axis assignment is fixed, so the kernels at every other
    /// length are structural prefixes of the longest), and the context
    /// GEMM at both lengths for every V storage tier a session config
    /// could select (`v_bits` clamps to `pos_prec`, so the tiers are
    /// exactly the SMOL levels <= compute precision).
    fn verify_programs(&self) -> Vec<ProgramToVerify<'_>> {
        let bufs = self.buf_bytes();
        let mut out = Vec::new();
        let lens = if self.max_positions > 1 { vec![1, self.max_positions] } else { vec![1] };
        for &len in &lens {
            let qk = GemmPlan {
                name: format!("{}@qk/len{len}", self.name),
                m: 1,
                k: self.dh,
                n: len,
                asg: self.dh_asg.clone(),
                fmt: self.fmt,
            };
            out.push(rep_row_program(&qk, bufs));
            for v_prec in [1u8, 2, 4] {
                if v_prec > self.pos_prec {
                    continue;
                }
                let av = GemmPlan {
                    name: format!("{}@av/len{len}/v{v_prec}", self.name),
                    m: 1,
                    k: len,
                    n: self.dh,
                    asg: Assignment::uniform(len, v_prec),
                    fmt: self.fmt,
                };
                out.push(rep_row_program(&av, bufs));
            }
        }
        out
    }

    fn run(&self, ctx: &mut ExecCtx<'_>, inputs: &[&Tensor]) -> Tensor {
        let (q, k, v) = (inputs[0], inputs[1], inputs[2]);
        for t in [q, k, v] {
            assert_eq!(
                (t.h, t.w, t.c),
                (self.heads, 1, self.dh),
                "{}: step tensors must be (heads, 1, dh)",
                self.name
            );
        }
        let bound = ctx.bound.expect("cached attention runs against bound buffers");
        let state = ctx
            .session
            .as_deref_mut()
            .expect("CachedAttn needs a session (decode step graphs run via submit_step)");
        let kv_cfg = state.kv;
        // effective V storage precision: the session's tier, clamped so
        // it never exceeds compute (a lower level has *larger* chunk
        // capacity, so compute-sized buffers always fit)
        let v_prec = effective_v_prec(self.pos_prec, kv_cfg.and_then(|c| c.v_bits));
        let slot = &mut state.slots[self.slot];
        slot.ensure(self.heads, self.dh, self.nch_dh, v_prec, kv_cfg);
        assert!(
            slot.len < self.max_positions,
            "{}: session exceeded max_positions = {}",
            self.name,
            self.max_positions
        );
        let m = &mut *ctx.m;
        let scratch = &mut *ctx.scratch;
        let cap_v = Pattern::uniform(v_prec).capacity() as usize;
        let pat_v = Pattern::uniform(v_prec);
        let t = slot.len;

        // paged slots allocate their next page at every page boundary
        // (budget policy already ran in the engine before this step)
        if let Some(KvStore::Paged(ps)) = slot.store.as_mut() {
            if t % ps.geom.page_positions == 0 {
                let pool = ctx
                    .kv
                    .as_deref_mut()
                    .expect("paged sessions need a KvPool in the exec context");
                ps.pages.push(pool.alloc(&ps.geom));
            }
        }

        // --- append this position's K/V (no per-step allocation beyond
        // amortized cache growth: the gather buffer is worker scratch) ---
        for h in 0..self.heads {
            let k_vals = &k.data[h * self.dh..(h + 1) * self.dh];
            match slot.store.as_mut().expect("ensured above") {
                KvStore::Growable { k_packed, v_quant, v_packed } => {
                    pack::pack_column_into(
                        &self.dh_asg,
                        k_vals,
                        &mut scratch.vals,
                        &mut k_packed[h],
                    );
                    for j in 0..self.dh {
                        v_quant[h].push(quant::quantize(v.data[h * self.dh + j], v_prec));
                    }
                    // refresh the tail chunk of each feature's packed V
                    let chunk = t / cap_v;
                    let start = chunk * cap_v;
                    for j in 0..self.dh {
                        scratch.vals.clear();
                        for pos in start..=t {
                            scratch.vals.push(v_quant[h][pos * self.dh + j]);
                        }
                        let bytes = pack_values(&pat_v, &scratch.vals).to_bytes();
                        let col = &mut v_packed[h][j];
                        if t % cap_v == 0 {
                            col.extend_from_slice(&bytes);
                        } else {
                            col[chunk * 16..chunk * 16 + 16].copy_from_slice(&bytes);
                        }
                    }
                }
                KvStore::Paged(ps) => {
                    let p = ps.geom.page_positions;
                    let cpp = ps.geom.chunks_per_page();
                    let (pi, tp) = (t / p, t % p);
                    let page = &mut ps.pages[pi];
                    // K column at this position's in-page offset (pack
                    // into scratch, then copy — pack appends to a vec)
                    scratch.packed_b.clear();
                    pack::pack_column_into(
                        &self.dh_asg,
                        k_vals,
                        &mut scratch.vals,
                        &mut scratch.packed_b,
                    );
                    let ko = (h * p + tp) * self.nch_dh * 16;
                    page.k[ko..ko + self.nch_dh * 16].copy_from_slice(&scratch.packed_b);
                    for j in 0..self.dh {
                        page.v_quant[(h * p + tp) * self.dh + j] =
                            quant::quantize(v.data[h * self.dh + j], v_prec);
                    }
                    // refresh the tail packed V chunk — always within
                    // this page: page_positions is a multiple of cap_v
                    let ci = tp / cap_v;
                    let start = ci * cap_v;
                    for j in 0..self.dh {
                        scratch.vals.clear();
                        for pos in start..=tp {
                            scratch.vals.push(page.v_quant[(h * p + pos) * self.dh + j]);
                        }
                        let bytes = pack_values(&pat_v, &scratch.vals).to_bytes();
                        let vo = ((h * self.dh + j) * cpp + ci) * 16;
                        page.v_packed[vo..vo + 16].copy_from_slice(&bytes);
                    }
                }
            }
        }
        // quantize/pack charge for the appended position only (the
        // prefix-repack baseline pays this for the *whole* prefix)
        m.charge_bulk((2 * self.heads * self.dh) as u64, 0);
        slot.len += 1;
        let len = slot.len;

        // --- score GEMM against the cached packed K, then softmax ---
        let mut scores = Tensor::zeros(self.heads, 1, len);
        let qk_plan = GemmPlan {
            name: self.name.clone(),
            m: 1,
            k: self.dh,
            n: len,
            asg: self.dh_asg.clone(),
            fmt: self.fmt,
        };
        for h in 0..self.heads {
            // stage K: contiguous for growable, page fragments at the
            // positions' exact offsets for paged — identical bytes
            match slot.store.as_ref().expect("ensured above") {
                KvStore::Growable { k_packed, .. } => {
                    m.write_bytes(bound.bufs.weights, 0, &k_packed[h]);
                }
                KvStore::Paged(ps) => {
                    let p = ps.geom.page_positions;
                    for (pi, page) in ps.pages.iter().enumerate() {
                        let n_pos = p.min(len - pi * p);
                        let src = h * p * self.nch_dh * 16;
                        m.write_bytes(
                            bound.bufs.weights,
                            pi * p * self.nch_dh * 16,
                            &page.k[src..src + n_pos * self.nch_dh * 16],
                        );
                    }
                }
            }
            m.stream_touch(bound.bufs.weights, len * self.nch_dh * 16, true);
            let q_vals = &q.data[h * self.dh..(h + 1) * self.dh];
            run_gemm_row(
                m,
                &bound.bufs,
                &qk_plan,
                q_vals,
                self.scale,
                &mut scratch.vals,
                &mut scratch.packed_act,
                &mut scratch.masks,
                &mut scores.data[h * len..(h + 1) * len],
            );
        }
        eltwise::softmax_rows(&mut scores.data, len);
        m.charge_bulk(scores.data.len() as u64, (scores.data.len() * 8) as u64);

        // --- context GEMM against the cached packed V ---
        let mut out = Tensor::zeros(self.heads, 1, self.dh);
        let av_plan = GemmPlan {
            name: self.name.clone(),
            m: 1,
            k: len,
            n: self.dh,
            asg: Assignment::uniform(len, v_prec),
            fmt: self.fmt,
        };
        let nch_pos = len.div_ceil(cap_v);
        for h in 0..self.heads {
            match slot.store.as_ref().expect("ensured above") {
                KvStore::Growable { v_packed, .. } => {
                    for j in 0..self.dh {
                        m.write_bytes(bound.bufs.weights, j * nch_pos * 16, &v_packed[h][j]);
                    }
                }
                KvStore::Paged(ps) => {
                    // each feature column gathers its chunk run across
                    // pages into the growable layout's exact offsets
                    let cpp = ps.geom.chunks_per_page();
                    for j in 0..self.dh {
                        for (pi, page) in ps.pages.iter().enumerate() {
                            let lo = pi * cpp;
                            if lo >= nch_pos {
                                break;
                            }
                            let n = cpp.min(nch_pos - lo);
                            let src = (h * self.dh + j) * cpp * 16;
                            m.write_bytes(
                                bound.bufs.weights,
                                (j * nch_pos + lo) * 16,
                                &page.v_packed[src..src + n * 16],
                            );
                        }
                    }
                }
            }
            m.stream_touch(bound.bufs.weights, self.dh * nch_pos * 16, true);
            run_gemm_row(
                m,
                &bound.bufs,
                &av_plan,
                &scores.data[h * len..(h + 1) * len],
                1.0,
                &mut scratch.vals,
                &mut scratch.packed_act,
                &mut scratch.masks,
                &mut out.data[h * self.dh..(h + 1) * self.dh],
            );
        }
        out
    }
}

/// The one-shot causal A·V: row `i` contracts the probability row with
/// the V prefix `<= i` only, re-quantizing and re-packing that prefix
/// for every row — the prefix-repack baseline the session KV cache is
/// measured against, and the bit-exact oracle for cached decode.
#[derive(Debug)]
pub struct CausalAvOp {
    name: String,
    /// sequence length (= m = k of the underlying GEMM)
    s: usize,
    dh: usize,
    scale: f32,
    pos_prec: u8,
    fmt: DataFormat,
}

impl CausalAvOp {
    pub fn prepare(cfg: &MatmulCfg) -> CausalAvOp {
        let plan = &cfg.plan;
        assert!(cfg.causal, "{}: CausalAvOp needs a causal cfg", plan.name);
        assert_eq!(plan.m, plan.k, "{}: causal A·V contracts positions", plan.name);
        assert_eq!(plan.fmt, DataFormat::Smol, "{}: causal A·V needs SMOL operands", plan.name);
        let p = plan.asg.precision.first().copied().unwrap_or(4);
        assert!(
            plan.asg.precision.iter().all(|&q| q == p),
            "{}: causal A·V needs a uniform position-axis assignment",
            plan.name
        );
        CausalAvOp {
            name: plan.name.clone(),
            s: plan.m,
            dh: plan.n,
            scale: cfg.scale,
            pos_prec: p,
            fmt: plan.fmt,
        }
    }
}

impl CausalAvOp {
    /// (input, weights, out, masks) buffer bytes [`PreparedOp::bind`]
    /// allocates — one place, so `bind` and `bind_bytes` cannot drift.
    fn buf_bytes(&self) -> (usize, usize, usize, usize) {
        let cap = Pattern::uniform(self.pos_prec).capacity() as usize;
        let nch = self.s.div_ceil(cap);
        (16 * nch, 16 * self.dh * nch, (4 * self.dh).max(16 * nch), 16 * nch)
    }
}

impl PreparedOp for CausalAvOp {
    fn name(&self) -> Option<&str> {
        Some(&self.name)
    }

    fn bind(&self, m: &mut Machine) -> Option<BoundKernel> {
        let (input, weights, out, masks) = self.buf_bytes();
        let bufs = LayerBufs {
            input: m.alloc(input),
            weights: m.alloc(weights),
            out: m.alloc(out),
            masks: m.alloc(masks),
        };
        Some(BoundKernel { bufs, program: Vec::new() })
    }

    fn bind_bytes(&self) -> usize {
        let (input, weights, out, masks) = self.buf_bytes();
        input + weights + out + masks
    }

    /// Per-row programs of the one-shot causal A·V. Short sequences
    /// verify every row's kernel; longer ones sample the structural
    /// corners (first rows, a middle row, the tail-partial and full
    /// rows — each contraction length is an independent emission).
    fn verify_programs(&self) -> Vec<ProgramToVerify<'_>> {
        let bufs = self.buf_bytes();
        let mut lens: Vec<usize> = if self.s <= 16 {
            (1..=self.s).collect()
        } else {
            vec![1, 2, self.s / 2, self.s - 1, self.s]
        };
        lens.dedup();
        lens.iter()
            .map(|&len| {
                let plan = GemmPlan {
                    name: format!("{}@row/len{len}", self.name),
                    m: 1,
                    k: len,
                    n: self.dh,
                    asg: Assignment::uniform(len, self.pos_prec),
                    fmt: self.fmt,
                };
                rep_row_program(&plan, bufs)
            })
            .collect()
    }

    fn run(&self, ctx: &mut ExecCtx<'_>, inputs: &[&Tensor]) -> Tensor {
        let (a, b) = (inputs[0], inputs[1]);
        assert_eq!((a.w, a.c), (self.s, self.s), "{}: probs shape", self.name);
        assert_eq!(b.h, a.h, "{}: head-batch mismatch", self.name);
        assert_eq!((b.w, b.c), (self.s, self.dh), "{}: V shape", self.name);
        let bound = ctx.bound.expect("causal A·V runs against bound buffers");
        let m = &mut *ctx.m;
        let scratch = &mut *ctx.scratch;
        let heads = a.h;
        let mut out = Tensor::zeros(heads, self.s, self.dh);
        for h in 0..heads {
            for t in 0..self.s {
                let len = t + 1;
                let asg = Assignment::uniform(len, self.pos_prec);
                // re-quantize + re-pack the whole V prefix for this row,
                // one feature column at a time (the same append unit the
                // KV cache uses, so the bytes are identical)
                scratch.packed_b.clear();
                for j in 0..self.dh {
                    scratch.b.clear();
                    for pos in 0..len {
                        scratch.b.push(b.at(h, pos, j));
                    }
                    pack::pack_column_into(
                        &asg,
                        &scratch.b,
                        &mut scratch.vals,
                        &mut scratch.packed_b,
                    );
                }
                m.write_bytes(bound.bufs.weights, 0, &scratch.packed_b);
                m.stream_touch(bound.bufs.weights, scratch.packed_b.len(), true);
                m.charge_bulk((len * self.dh) as u64, 0);

                let plan = GemmPlan {
                    name: self.name.clone(),
                    m: 1,
                    k: len,
                    n: self.dh,
                    asg,
                    fmt: self.fmt,
                };
                let row = (h * self.s + t) * self.s;
                run_gemm_row(
                    m,
                    &bound.bufs,
                    &plan,
                    &a.data[row..row + len],
                    self.scale,
                    &mut scratch.vals,
                    &mut scratch.packed_act,
                    &mut scratch.masks,
                    &mut out.data[(h * self.s + t) * self.dh..(h * self.s + t + 1) * self.dh],
                );
            }
        }
        out
    }
}
