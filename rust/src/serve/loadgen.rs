//! Open-loop traffic generation: arrival schedules that do not wait
//! for completions. A closed-loop bench (submit everything, drain at
//! shutdown) measures backlog throughput; an open-loop one offers load
//! at a fixed rate regardless of how the server keeps up, which is the
//! only way tail latency, goodput-under-deadline, and admission
//! behavior mean anything. The generator is deterministic (seeded
//! xorshift64*, no external RNG), so a given `(rate, n, burst, seed)`
//! always produces the same schedule — benches are reproducible and
//! two backends see identical traffic.
//!
//! Two arrival processes:
//! - **Poisson**: i.i.d. exponential inter-arrival gaps at `rate`
//!   (memoryless — the classic open-system model).
//! - **Bursty**: geometrically sized bursts (mean [`MEAN_BURST`])
//!   arriving as a Poisson process at `rate / MEAN_BURST`, so the
//!   long-run offered rate matches `rate` while arrivals clump — the
//!   adversarial case for admission control and batch formation.

use std::time::Duration;

/// Mean burst size of the bursty arrival process.
pub const MEAN_BURST: f64 = 4.0;

/// Deterministic xorshift64* generator (Vigna 2016): tiny, seedable,
/// and good enough for arrival-schedule sampling; serving code must
/// not pull in an RNG crate for this.
#[derive(Debug, Clone)]
pub struct Rng64 {
    state: u64,
}

impl Rng64 {
    /// `seed` may be anything; the zero state (a fixed point of the
    /// xorshift) is remapped.
    pub fn new(seed: u64) -> Rng64 {
        Rng64 { state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed } }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform sample in `(0, 1]` (53-bit mantissa; never 0, so
    /// `ln(u)` is always finite).
    pub fn uniform(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64 + 1.0) / (1u64 << 53) as f64
    }
}

/// An open-loop arrival schedule request.
#[derive(Debug, Clone, Copy)]
pub struct ArrivalSpec {
    /// mean offered rate, requests per second (> 0)
    pub rate: f64,
    /// number of arrivals to generate
    pub n: usize,
    /// clump arrivals into geometric bursts (same long-run rate)
    pub burst: bool,
    pub seed: u64,
}

/// Generate `spec.n` arrival offsets from time zero, non-decreasing.
/// The driver submits request `i` once `offsets[i]` has elapsed —
/// never earlier, and without waiting for earlier completions.
pub fn arrival_offsets(spec: &ArrivalSpec) -> Vec<Duration> {
    let rate = spec.rate.max(1e-9);
    let mut rng = Rng64::new(spec.seed ^ 0x6A09_E667_F3BC_C909);
    let mut out = Vec::with_capacity(spec.n);
    let mut t = 0.0f64;
    if !spec.burst {
        for _ in 0..spec.n {
            t += -rng.uniform().ln() / rate;
            out.push(Duration::from_secs_f64(t));
        }
        return out;
    }
    // bursts arrive as a Poisson process at rate / MEAN_BURST; each
    // carries a geometric number of simultaneous requests with mean
    // MEAN_BURST, so the long-run offered rate is still `rate`
    let p = 1.0 / MEAN_BURST;
    while out.len() < spec.n {
        t += -rng.uniform().ln() / (rate * p);
        let size = 1 + (rng.uniform().ln() / (1.0 - p).ln()).floor() as usize;
        for _ in 0..size.min(spec.n - out.len()) {
            out.push(Duration::from_secs_f64(t));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(rate: f64, n: usize, burst: bool, seed: u64) -> ArrivalSpec {
        ArrivalSpec { rate, n, burst, seed }
    }

    #[test]
    fn schedules_are_deterministic_and_monotone() {
        for burst in [false, true] {
            let a = arrival_offsets(&spec(500.0, 256, burst, 7));
            let b = arrival_offsets(&spec(500.0, 256, burst, 7));
            assert_eq!(a, b, "same seed must replay the same schedule");
            assert_eq!(a.len(), 256);
            assert!(a.windows(2).all(|w| w[0] <= w[1]), "offsets must be non-decreasing");
            let c = arrival_offsets(&spec(500.0, 256, burst, 8));
            assert_ne!(a, c, "a different seed must vary the schedule");
        }
    }

    #[test]
    fn poisson_long_run_rate_matches_offered() {
        let n = 20_000;
        let offsets = arrival_offsets(&spec(1000.0, n, false, 42));
        let span = offsets[n - 1].as_secs_f64();
        let rate = n as f64 / span;
        assert!((rate - 1000.0).abs() < 50.0, "empirical rate {rate} far from offered 1000");
        // memoryless gaps: distinct, strictly increasing almost surely
        let distinct = offsets.windows(2).filter(|w| w[0] < w[1]).count();
        assert!(distinct > n * 9 / 10, "Poisson arrivals should rarely coincide");
    }

    #[test]
    fn bursty_clumps_but_keeps_the_long_run_rate() {
        let n = 20_000;
        let offsets = arrival_offsets(&spec(1000.0, n, true, 42));
        let span = offsets[n - 1].as_secs_f64();
        let rate = n as f64 / span;
        assert!((rate - 1000.0).abs() < 100.0, "empirical rate {rate} far from offered 1000");
        // arrivals inside one burst share an offset exactly
        let coincident = offsets.windows(2).filter(|w| w[0] == w[1]).count();
        let frac = coincident as f64 / (n - 1) as f64;
        // mean burst 4 => ~3 of every 4 consecutive pairs coincide
        assert!(frac > 0.5, "burst mode should clump arrivals (got {frac})");
    }

    #[test]
    fn zero_seed_is_remapped_not_degenerate() {
        // state 0 is the xorshift fixed point: without the remap in
        // `Rng64::new` every draw would be 0 and the stream constant.
        // The constructor must swap it for a nonzero state that keeps
        // the generator live and distinct from nearby seeds.
        let mut z = Rng64::new(0);
        let first = z.next_u64();
        assert_ne!(first, 0, "zero seed must not emit the fixed point");
        let draws: Vec<u64> = (0..64).map(|_| z.next_u64()).collect();
        assert!(
            draws.iter().any(|&d| d != first),
            "zero-seeded stream must vary, not repeat one value"
        );
        // and it must behave like any other seed: deterministic replay,
        // but a stream of its own
        let a: Vec<u64> = (0..16).map(|_| Rng64::new(0).next_u64()).collect();
        assert!(a.windows(2).all(|w| w[0] == w[1]), "zero seed must replay deterministically");
        let mut one = Rng64::new(1);
        let b: Vec<u64> = (0..16).map(|_| one.next_u64()).collect();
        assert_ne!(&draws[..16], &b[..], "seed 0 and seed 1 must diverge");
    }

    #[test]
    fn uniform_stays_in_half_open_unit_interval() {
        let mut rng = Rng64::new(0); // zero seed is remapped, not a fixed point
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!(u > 0.0 && u <= 1.0, "uniform sample {u} out of (0, 1]");
        }
    }
}
