//! `soniq::serve` — the batched, multi-threaded inference serving engine.
//!
//! The deployability story of the paper (simple, fast mixed-precision
//! kernels on commodity SIMD) only pays off when the quantize/pack/
//! codegen work is amortized across requests. This subsystem prepares a
//! model **once** — every graph op is a [`engine::PreparedOp`]
//! (`prepare -> bind -> run(ctx)`), with codegen plans, SMOL-packed
//! weights and mask tables cached per layer ([`engine`]) — and then
//! serves request streams through a session-affine dynamic batcher
//! ([`batcher`]: per-`(model, target)` groups, max-batch +
//! latency-deadline close policy) feeding a pool of worker threads, one
//! simulated SIMD machine per worker ([`workers`]).
//!
//! One pool serves **many** models: every request carries a
//! [`ModelHandle`], each worker machine keeps a per-model bind table
//! populated lazily on the first batch of that model (and evicted LRU
//! under a configurable resident-model budget), and reports aggregate
//! per `(model, layer)`.
//!
//! Models wider than one machine deploy **sharded**: a [`Deployment`]
//! ([`deploy`]) owns a [`ShardPlan`] splitting the widest layer's
//! `cout` range across per-worker shards, requests scatter to each
//! shard's pinned worker and gather (concat or exact fixed-point
//! reduce) before completion, bit-identical to the whole-model run,
//! with per-shard cycles/energy reported under `(model, layer, shard)`.
//! `ShardPlan::Whole` is the degenerate single-worker case, so plain
//! registrations are unchanged.
//!
//! Decoder models additionally serve **autoregressive decode**: a
//! [`workers::Server`] session ([`workers::Server::open_session`] /
//! [`workers::Server::submit_step`]) owns growable packed K/V operand
//! caches ([`session`]) on its pinned worker, so each step appends one
//! position instead of re-packing the whole prefix. [`metrics`]
//! aggregates host throughput / latency percentiles (setup reported
//! separately from steady state) and the simulated per-layer
//! cycle/energy totals into a JSON [`ServeReport`].
//!
//! Decode is **iteration-level scheduled**: steps land in per-session
//! lanes on the pinned worker, which re-forms its step batch every
//! token from whichever sessions currently have one pending — sessions
//! admit mid-flight and retire immediately, so long decodes never
//! stall short ones. The pool takes open-loop load with backpressure:
//! [`loadgen`] generates deterministic Poisson/bursty arrival
//! schedules (`serve-bench --open-loop`), and a configured
//! [`ServeConfig::queue_depth`] turns overload into typed
//! [`Rejected`] outcomes at the `try_*` submission forms instead of
//! unbounded queuing.
//!
//! Session K/V lives in **pages**: any [`ServeConfig::kv`]
//! configuration switches sessions from growable buffers to fixed-size
//! chunk-aligned pages from a per-worker [`KvPool`] ([`kvpool`]) with
//! exact page accounting — placement charges sessions by the pages
//! they actually hold, and a `--kv-pages` budget is enforced by policy:
//! [`KvPolicy::Refuse`] gates admission, [`KvPolicy::Evict`] drops the
//! coldest session's pages, [`KvPolicy::Spill`] parks them in a host
//! arena and faults them back bit-exactly. Paged decode is
//! bit-identical to growable decode; an optional low-precision V tier
//! (`--v-bits`) trades context accuracy for capacity. Pool gauges and
//! spill/evict/refuse counters land in the snapshot and the schema-5
//! report's `kv_pool` block.
//!
//! Every request additionally carries a lifecycle span
//! ([`obs::SpanTrack`]: enqueued → batch-closed → dispatched → bound →
//! executed → gathered), and the pool keeps a live, lock-cheap metrics
//! registry ([`obs::Obs`]) queryable mid-run through
//! [`workers::Server::snapshot`] and exportable as a Chrome
//! `trace_event` file (`serve-bench --trace`); see [`obs`].
//!
//! Outputs are bit-identical to the one-shot path; see DESIGN.md for
//! the architecture and `soniq serve-bench` (with `--decode` for the
//! KV-cache comparison) for the end-to-end numbers.

pub mod batcher;
pub mod deploy;
pub mod engine;
pub mod kvpool;
pub mod loadgen;
pub mod metrics;
pub mod obs;
pub mod session;
pub mod workers;

pub use batcher::{Batch, BatchConfig, DynamicBatcher, Payload, Request};
pub use deploy::{DeployConfig, Deployment, GatherMode, ShardPlan};
pub use engine::{
    BoundKernel, EngineMachine, ExecCtx, PreparedConv, PreparedMatmul, PreparedModel,
    PreparedNode, PreparedOp, StepModel, WorkerScratch,
};
pub use kvpool::{KvPage, KvPolicy, KvPool, KvPoolCfg, KvPoolStats, PageGeom, SessionKvCfg};
pub use loadgen::{arrival_offsets, ArrivalSpec, Rng64, MEAN_BURST};
pub use metrics::{
    percentile, summarize, summarize_with, LayerAgg, ModelAgg, OpenLoopPoint, ServeReport,
    SetupTiming, SpanAgg, WorkerRow, SERVE_REPORT_SCHEMA,
};
pub use obs::{
    GroupDepth, HistSummary, KvPoolSnapshot, LogHist, Obs, ObsSnapshot, SpanTrack, WorkerSnapshot,
};
pub use session::SessionState;
pub use workers::{Completion, Rejected, ServeConfig, ServeFaults, Server, SessionId};

use crate::sim::network::Tensor;
use std::collections::HashMap;
use std::fmt;
use std::sync::{Arc, Mutex};

/// Typed registry key for a `{model, design point}` pair (replaces the
/// old stringly `"model/design"` key).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ModelKey {
    pub model: String,
    pub design: String,
}

impl ModelKey {
    pub fn new(model: impl Into<String>, design: impl Into<String>) -> ModelKey {
        ModelKey { model: model.into(), design: design.into() }
    }
}

impl fmt::Display for ModelKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/{}", self.model, self.design)
    }
}

/// A `{key, prepared model}` pair — the unit requests, batches and
/// per-worker bind tables route by. Cloning is two `Arc` bumps, so a
/// handle rides every [`Request`] without copying the model, and the
/// worker that executes the request can lazily bind the model from the
/// handle alone (no shared registry lookup on the hot path).
///
/// A key must identify one `PreparedModel` instance for the lifetime of
/// a server: workers cache bind tables per *key*, so two different
/// prepared instances under one key would replay the first instance's
/// kernels for both. [`ModelRegistry`] guarantees this by construction.
#[derive(Debug, Clone)]
pub struct ModelHandle {
    pub key: Arc<ModelKey>,
    pub prepared: Arc<PreparedModel>,
}

impl ModelHandle {
    pub fn new(key: ModelKey, prepared: Arc<PreparedModel>) -> ModelHandle {
        ModelHandle { key: Arc::new(key), prepared }
    }
}

/// Process-wide cache of prepared models, keyed by [`ModelKey`]: a
/// model is prepared on first request and every later lookup reuses the
/// cached plans + packed weights.
#[derive(Default)]
pub struct ModelRegistry {
    inner: Mutex<HashMap<ModelKey, Arc<PreparedModel>>>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// Look up `key`, preparing the model from `build()` on a miss.
    ///
    /// The key does not encode *how* the model was prepared, so a
    /// decoder model must always be built with
    /// [`PreparedModel::prepare_decoder`] — its full graph serves
    /// stateless traffic too, while a step-less `prepare()` cached
    /// under the same key would make a later `open_session` panic.
    ///
    /// Preparation runs outside the registry lock so cached lookups
    /// never wait behind an unrelated expensive miss; if two threads
    /// race the same cold key both may build, and the first insert wins
    /// (later callers all share that one).
    pub fn get_or_prepare(
        &self,
        key: &ModelKey,
        build: impl FnOnce() -> PreparedModel,
    ) -> Arc<PreparedModel> {
        if let Some(m) = self.inner.lock().unwrap().get(key) {
            return Arc::clone(m);
        }
        let prepared = Arc::new(build());
        let mut guard = self.inner.lock().unwrap();
        Arc::clone(guard.entry(key.clone()).or_insert(prepared))
    }

    pub fn contains(&self, key: &ModelKey) -> bool {
        self.inner.lock().unwrap().contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Convenience driver: start a server, submit every input, drain and
/// return all completions (sorted by request id).
pub fn serve_all(
    model: &Arc<PreparedModel>,
    cfg: &ServeConfig,
    inputs: Vec<Tensor>,
) -> Vec<Completion> {
    let mut server = Server::start(Arc::clone(model), cfg);
    for x in inputs {
        server.submit(x);
    }
    let mut done = server.shutdown();
    done.sort_by_key(|c| c.id);
    done
}
