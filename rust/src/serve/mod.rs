//! `soniq::serve` — the batched, multi-threaded inference serving engine.
//!
//! The deployability story of the paper (simple, fast mixed-precision
//! kernels on commodity SIMD) only pays off when the quantize/pack/
//! codegen work is amortized across requests. This subsystem prepares a
//! model **once** — codegen plans, SMOL-packed weights, mask tables and
//! scratch buffers cached per layer ([`engine`]) — and then serves
//! request streams through a dynamic batcher ([`batcher`]: max-batch +
//! latency-deadline close policy) feeding a pool of worker threads, one
//! simulated SIMD machine per worker ([`workers`]). [`metrics`]
//! aggregates host throughput / latency percentiles and the simulated
//! per-layer cycle/energy totals into a JSON [`ServeReport`].
//!
//! Outputs are bit-identical to the legacy one-shot path; see DESIGN.md
//! for the architecture and `soniq serve-bench` for the end-to-end
//! throughput comparison.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod workers;

pub use batcher::{Batch, BatchConfig, DynamicBatcher, Request};
pub use engine::{
    prepare_conv, prepare_matmul, run_matmul, EngineMachine, MatmulScratch, PreparedConv,
    PreparedMatmul, PreparedModel,
};
pub use metrics::{percentile, summarize, LayerAgg, ServeReport};
pub use workers::{Completion, ServeConfig, Server};

use crate::sim::network::{Node, Tensor};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Canonical registry key for a `{model, design point}` pair.
pub fn model_key(model: &str, design: &str) -> String {
    format!("{model}/{design}")
}

/// Process-wide cache of prepared models, keyed by
/// [`model_key`]`(model, design)`: a model is prepared on first request
/// and every later lookup reuses the cached plans + packed weights.
#[derive(Default)]
pub struct ModelRegistry {
    inner: Mutex<HashMap<String, Arc<PreparedModel>>>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// Look up `key`, preparing the model from `build()`'s graph on a
    /// miss. Preparation runs outside the registry lock so cached
    /// lookups never wait behind an unrelated expensive miss; if two
    /// threads race the same cold key both may build, and the first
    /// insert wins (later callers all share that one).
    pub fn get_or_prepare(
        &self,
        key: &str,
        build: impl FnOnce() -> Vec<Node>,
    ) -> Arc<PreparedModel> {
        if let Some(m) = self.inner.lock().unwrap().get(key) {
            return Arc::clone(m);
        }
        let prepared = Arc::new(PreparedModel::prepare(&build()));
        let mut guard = self.inner.lock().unwrap();
        Arc::clone(guard.entry(key.to_string()).or_insert(prepared))
    }

    pub fn contains(&self, key: &str) -> bool {
        self.inner.lock().unwrap().contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Convenience driver: start a server, submit every input, drain and
/// return all completions (sorted by request id).
pub fn serve_all(
    model: &Arc<PreparedModel>,
    cfg: &ServeConfig,
    inputs: Vec<Tensor>,
) -> Vec<Completion> {
    let mut server = Server::start(Arc::clone(model), cfg);
    for x in inputs {
        server.submit(x);
    }
    let mut done = server.shutdown();
    done.sort_by_key(|c| c.id);
    done
}
