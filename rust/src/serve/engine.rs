//! Prepare-once execution engine (refactored out of `sim::network`).
//!
//! The legacy path re-quantized and re-packed every layer's weights, re-
//! emitted the Algorithm-4 kernel and re-allocated machine buffers on
//! *every* inference. Serving amortizes all of that: [`prepare_conv`]
//! runs codegen + weight/mask packing exactly once per layer, and
//! [`EngineMachine`] binds the prepared layers to per-worker machine
//! buffers exactly once, so a request only pays for activation packing,
//! kernel replay and the epilogue. Outputs are bit-identical to the
//! legacy path (`sim::network::run_conv` / `run_network` are now thin
//! wrappers over this module).

use crate::codegen::gemm;
use crate::codegen::{self, pack, LayerBufs, LayerKind, LayerPlan};
use crate::sim::eltwise;
use crate::sim::machine::{Machine, RunStats};
use crate::sim::network::{ConvLayerCfg, LayerStat, MatmulCfg, NetResult, Node, Tensor, INPUT};
use crate::simd::isa::{Addr, BufId, Instr};
use crate::simd::patterns::Pattern;
use crate::smol::quant;
use std::sync::Arc;

/// One conv/FC layer with everything per-request work does NOT need to
/// recompute: the emitted kernel, SMOL-packed weights, tail masks, the
/// pattern table and the epilogue parameters.
#[derive(Debug, Clone)]
pub struct PreparedConv {
    pub plan: LayerPlan,
    bn_scale: Vec<f32>,
    bn_bias: Vec<f32>,
    bn_mean: Vec<f32>,
    bn_var: Vec<f32>,
    relu: bool,
    /// Algorithm-4 kernel emitted against the symbolic buffer ids
    /// 0=input, 1=weights, 2=out, 3=masks (retargeted at bind time).
    program: Vec<Instr>,
    /// the layer's chunk patterns (machine table base 0, as emitted)
    patterns: Vec<Pattern>,
    packed_weights: Vec<u8>,
    packed_masks: Vec<u8>,
    act_bytes: usize,
    out_bytes: usize,
    out_elems: usize,
}

/// A prepared kernel (conv or GEMM) bound to concrete buffers of one
/// [`Machine`]: masks — and, for static operands, weights — are written
/// once; input/out (and dynamic-operand weights) act as reusable scratch.
#[derive(Debug, Clone)]
pub struct BoundKernel {
    bufs: LayerBufs,
    program: Vec<Instr>,
}

/// Buffer sizing shared by the prepared and streaming paths:
/// (packed-activation bytes, output elements, output-buffer bytes).
fn layer_sizes(plan: &LayerPlan) -> (usize, usize, usize) {
    let (hout, wout) = (plan.hout(), plan.wout());
    let n_chunks = plan.chunks().len();
    let act_bytes = plan.hin * plan.win * n_chunks * 16;
    let out_elems = match plan.kind {
        LayerKind::Dense => plan.cout * hout * wout,
        LayerKind::Depthwise => plan.cin * hout * wout,
    };
    // baseline depthwise stores whole 16B chunk vectors per position,
    // which can exceed cin*4 bytes when cin is not a multiple of the
    // lane capacity — size the buffer for both layouts
    let out_bytes = (out_elems * 4).max(hout * wout * n_chunks * 16);
    (act_bytes, out_elems, out_bytes)
}

/// Run codegen + weight/mask packing for one layer (the prepare-once
/// half of what `run_conv` used to do per call).
pub fn prepare_conv(cfg: &ConvLayerCfg) -> PreparedConv {
    let plan = cfg.plan.clone();
    let (act_bytes, out_elems, out_bytes) = layer_sizes(&plan);

    let packed_weights = pack::pack_weights(&plan, &cfg.weights);
    let packed_masks = pack::pack_masks(&plan);

    let mut patterns = Vec::new();
    let base = codegen::register_patterns(&plan, &mut patterns);
    let symbolic = LayerBufs {
        input: BufId(0),
        weights: BufId(1),
        out: BufId(2),
        masks: BufId(3),
    };
    let mut program = Vec::new();
    codegen::emit_layer(&plan, &symbolic, base, &mut program);

    PreparedConv {
        plan,
        bn_scale: cfg.bn_scale.clone(),
        bn_bias: cfg.bn_bias.clone(),
        bn_mean: cfg.bn_mean.clone(),
        bn_var: cfg.bn_var.clone(),
        relu: cfg.relu,
        program,
        patterns,
        packed_weights,
        packed_masks,
        act_bytes,
        out_bytes,
        out_elems,
    }
}

impl PreparedConv {
    /// Allocate this layer's buffers on `m` (same order and sizes as the
    /// legacy per-call path: input, weights, out, masks), write the
    /// cached weights + masks once, and retarget the kernel to the
    /// allocated buffer ids.
    pub fn bind(&self, m: &mut Machine) -> BoundKernel {
        let bufs = LayerBufs {
            input: m.alloc(self.act_bytes),
            weights: m.alloc(self.packed_weights.len()),
            out: m.alloc(self.out_bytes),
            masks: m.alloc(self.packed_masks.len()),
        };
        m.write_bytes(bufs.weights, 0, &self.packed_weights);
        m.write_bytes(bufs.masks, 0, &self.packed_masks);
        let program = retarget(&self.program, &bufs);
        BoundKernel { bufs, program }
    }
}

/// One GEMM node with everything per-request work does NOT need to
/// recompute. Static projections (`X · W`) cache their packed weights
/// here exactly like a conv layer; dynamic-operand GEMMs (QK^T, A·V)
/// cache the kernel, masks and pattern table but pack their "weight"
/// side per request into the bound scratch buffer.
#[derive(Debug, Clone)]
pub struct PreparedMatmul {
    /// the GEMM lowered to its 1x1 dense plan (`hin=m, win=1, cin=k,
    /// cout=n`) — packing, chunking and tail bias reuse the conv view
    pub plan: LayerPlan,
    scale: f32,
    program: Vec<Instr>,
    patterns: Vec<Pattern>,
    /// `Some` = static operand packed once; `None` = dynamic operand
    packed_weights: Option<Vec<u8>>,
    packed_masks: Vec<u8>,
    act_bytes: usize,
    weight_bytes: usize,
    out_bytes: usize,
}

/// Run codegen (+ static weight packing) for one GEMM node. `weights`
/// is the `[k][n]` row-major static operand, or `None` for a
/// dynamic-operand GEMM.
pub fn prepare_matmul(cfg: &MatmulCfg, weights: Option<&[f32]>) -> PreparedMatmul {
    let plan = cfg.plan.layer_plan();
    let (act_bytes, _, out_bytes) = layer_sizes(&plan);
    let weight_bytes = plan.cout * plan.chunks().len() * 16;

    let packed_weights = weights.map(|w| pack::pack_weights(&plan, w));
    let packed_masks = pack::pack_masks(&plan);

    let mut patterns = Vec::new();
    let base = codegen::register_patterns(&plan, &mut patterns);
    let symbolic = LayerBufs {
        input: BufId(0),
        weights: BufId(1),
        out: BufId(2),
        masks: BufId(3),
    };
    let mut program = Vec::new();
    gemm::emit_gemm(&cfg.plan, &symbolic, base, &mut program);

    PreparedMatmul {
        plan,
        scale: cfg.scale,
        program,
        patterns,
        packed_weights,
        packed_masks,
        act_bytes,
        weight_bytes,
        out_bytes,
    }
}

impl PreparedMatmul {
    /// Allocate this GEMM's buffers on `m`, write masks (and, for a
    /// static operand, the cached packed weights) once, and retarget the
    /// kernel. For dynamic operands the weights buffer is per-worker
    /// scratch refilled on every request.
    pub fn bind(&self, m: &mut Machine) -> BoundKernel {
        let bufs = LayerBufs {
            input: m.alloc(self.act_bytes),
            weights: m.alloc(self.weight_bytes),
            out: m.alloc(self.out_bytes),
            masks: m.alloc(self.packed_masks.len()),
        };
        if let Some(w) = &self.packed_weights {
            m.write_bytes(bufs.weights, 0, w);
        }
        m.write_bytes(bufs.masks, 0, &self.packed_masks);
        let program = retarget(&self.program, &bufs);
        BoundKernel { bufs, program }
    }
}

/// Rewrite the symbolic buffer ids of a prepared kernel to the buffers a
/// machine actually allocated.
fn retarget(prog: &[Instr], bufs: &LayerBufs) -> Vec<Instr> {
    let map = |a: Addr| -> Addr {
        let buf = match a.buf.0 {
            0 => bufs.input,
            1 => bufs.weights,
            2 => bufs.out,
            3 => bufs.masks,
            _ => a.buf,
        };
        Addr { buf, off: a.off }
    };
    prog.iter()
        .map(|i| match *i {
            Instr::LdQ { dst, addr } => Instr::LdQ { dst, addr: map(addr) },
            Instr::StQ { src, addr } => Instr::StQ { src, addr: map(addr) },
            Instr::ReduceAcc { src, addr } => Instr::ReduceAcc { src, addr: map(addr) },
            Instr::MulAcc { lo, hi, pat, addr, n_valid } => {
                Instr::MulAcc { lo, hi, pat, addr: map(addr), n_valid }
            }
            other => other,
        })
        .collect()
}

/// Number of in-bounds taps for output position (h, w).
pub(crate) fn valid_taps(plan: &LayerPlan, h: usize, w: usize) -> usize {
    let (pt, pl) = (plan.pad_top(), plan.pad_left());
    let mut n = 0;
    for r in 0..plan.kh {
        for s in 0..plan.kw {
            let ih = h as isize * plan.stride as isize + r as isize - pt;
            let iw = w as isize * plan.stride as isize + s as isize - pl;
            if ih >= 0 && iw >= 0 && ih < plan.hin as isize && iw < plan.win as isize {
                n += 1;
            }
        }
    }
    n
}

/// Per-request input staging, shared by every execution path (conv and
/// GEMM, one-shot and prepared): pack the activations into the input
/// buffer through caller-owned scratch, zero the accumulator scratch
/// and charge the quantize/rearrange/pack pass as streaming cache
/// traffic.
fn stage_input(
    m: &mut Machine,
    plan: &LayerPlan,
    bufs: &LayerBufs,
    x: &[f32],
    scratch: &mut Vec<u8>,
) {
    pack::pack_activations_into(plan, x, scratch);
    m.write_bytes(bufs.input, 0, scratch);
    m.clear_buffer(bufs.out);
    m.stream_touch(bufs.input, scratch.len(), true);
    m.charge_bulk(x.len() as u64, 0);
}

/// Epilogue shared by both execution paths: accumulators -> f32 with
/// tail-bias correction, BN, ReLU, output traffic charge; returns the
/// layer output and this layer's run statistics.
#[allow(clippy::too_many_arguments)]
fn finish_layer(
    m: &mut Machine,
    plan: &LayerPlan,
    bn: (&[f32], &[f32], &[f32], &[f32]),
    relu: bool,
    bufs: &LayerBufs,
    out_elems: usize,
) -> (Tensor, RunStats) {
    let (bn_scale, bn_bias, bn_mean, bn_var) = bn;
    let (hout, wout) = (plan.hout(), plan.wout());
    let bias = plan.tail_bias();
    let mut out = match plan.kind {
        LayerKind::Dense => {
            let mut t = Tensor::zeros(hout, wout, plan.cout);
            for k in 0..plan.cout {
                for h in 0..hout {
                    for w in 0..wout {
                        let acc = m.read_i32(bufs.out, ((k * hout + h) * wout + w) * 4);
                        let taps = valid_taps(plan, h, w) as i64;
                        let v = (acc as i64 - bias * taps) as f32 / quant::ACC_SCALE;
                        t.data[(h * wout + w) * plan.cout + k] = v;
                    }
                }
            }
            t
        }
        LayerKind::Depthwise => {
            // depthwise MulAcc wrote in *packed* channel order; un-permute
            let mut t = Tensor::zeros(hout, wout, plan.cin);
            for h in 0..hout {
                for w in 0..wout {
                    for (pos, &ch) in plan.asg.order.iter().enumerate() {
                        let acc = m.read_i32(bufs.out, ((h * wout + w) * plan.cin + pos) * 4);
                        t.data[(h * wout + w) * plan.cin + ch as usize] =
                            acc as f32 / quant::ACC_SCALE;
                    }
                }
            }
            t
        }
    };

    // BN + ReLU epilogue (f32, vectorized in hardware; bulk-costed)
    if !bn_scale.is_empty() {
        let cch = out.c;
        for i in 0..out.data.len() {
            let k = i % cch;
            let inv = 1.0 / (bn_var[k] + 1e-5).sqrt();
            out.data[i] = (out.data[i] - bn_mean[k]) * inv * bn_scale[k] + bn_bias[k];
        }
    }
    if relu {
        for v in out.data.iter_mut() {
            *v = v.max(0.0);
        }
    }
    m.stream_touch(bufs.out, out_elems * 4, false);
    m.charge_bulk(out.data.len() as u64, (out.data.len() * 4) as u64);

    (out, m.take_stats())
}

/// Execute one bound layer: pack + write the activations, replay the
/// cached kernel, run the epilogue. This is the per-request half of the
/// legacy `run_conv` — weight packing and codegen are gone from it.
pub fn run_bound(
    m: &mut Machine,
    prep: &PreparedConv,
    bound: &BoundKernel,
    x: &Tensor,
) -> (Tensor, RunStats) {
    run_bound_with_scratch(m, prep, bound, x, &mut Vec::new())
}

/// [`run_bound`] through reusable caller scratch for the packed
/// activations — the serving hot path, where per-request allocations
/// are unwelcome.
pub fn run_bound_with_scratch(
    m: &mut Machine,
    prep: &PreparedConv,
    bound: &BoundKernel,
    x: &Tensor,
    scratch: &mut Vec<u8>,
) -> (Tensor, RunStats) {
    let plan = &prep.plan;
    assert_eq!(x.c, plan.cin, "{}: cin mismatch", plan.name);
    assert_eq!((x.h, x.w), (plan.hin, plan.win), "{}: spatial mismatch", plan.name);
    stage_input(m, plan, &bound.bufs, &x.data, scratch);

    // replay the cached Algorithm-4 kernel under the layer's patterns
    m.patterns.clear();
    m.patterns.extend_from_slice(&prep.patterns);
    m.run(&bound.program);

    let bn = (
        prep.bn_scale.as_slice(),
        prep.bn_bias.as_slice(),
        prep.bn_mean.as_slice(),
        prep.bn_var.as_slice(),
    );
    finish_layer(m, plan, bn, prep.relu, &bound.bufs, prep.out_elems)
}

/// Reusable per-worker packing scratch: the transposed/materialized
/// dynamic "weight" matrix, its packed bytes, and the packed-activation
/// bytes every layer's staging runs through. One per [`EngineMachine`],
/// reused across all requests the worker serves (no per-request
/// allocation in the hot path).
#[derive(Debug, Default, Clone)]
pub struct MatmulScratch {
    b: Vec<f32>,
    packed_b: Vec<u8>,
    packed_act: Vec<u8>,
}

/// Execute one bound GEMM, batched over the `h` (head) axis of `a`.
///
/// `b_dyn = None` runs the static-operand form (weights already resident
/// from bind time). `b_dyn = Some((tensor, transpose_b))` quantizes +
/// packs the dynamic operand per head through `scratch` and writes it
/// into the bound weights buffer before replaying the kernel — the
/// per-request half of a dynamic-operand GEMM.
pub fn run_matmul(
    m: &mut Machine,
    prep: &PreparedMatmul,
    bound: &BoundKernel,
    a: &Tensor,
    b_dyn: Option<(&Tensor, bool)>,
    scratch: &mut MatmulScratch,
) -> (Tensor, RunStats) {
    let plan = &prep.plan;
    let (mm, kk, nn) = (plan.hin, plan.cin, plan.cout);
    assert_eq!(a.w, mm, "{}: row (sequence) mismatch", plan.name);
    assert_eq!(a.c, kk, "{}: contraction dim mismatch", plan.name);
    if let Some((b, transpose_b)) = b_dyn {
        assert_eq!(b.h, a.h, "{}: head-batch mismatch", plan.name);
        if transpose_b {
            assert_eq!((b.c, b.w), (kk, nn), "{}: B^T shape mismatch", plan.name);
        } else {
            assert_eq!((b.w, b.c), (kk, nn), "{}: B shape mismatch", plan.name);
        }
    }

    let bias = plan.tail_bias();
    let mut out = Tensor::zeros(a.h, mm, nn);
    for h in 0..a.h {
        // stage this head's A rows (quantize + pack, charged as
        // streaming traffic like conv activation staging)
        let a_head = &a.data[h * mm * kk..(h + 1) * mm * kk];
        stage_input(m, plan, &bound.bufs, a_head, &mut scratch.packed_act);

        if let Some((b, transpose_b)) = b_dyn {
            // pack the dynamic operand: quantize to the contraction
            // axis's per-channel precisions, exactly like static weights
            let b_head = &b.data[h * b.w * b.c..(h + 1) * b.w * b.c];
            if transpose_b {
                // materialize B^T ([k][n] row-major) in scratch
                scratch.b.clear();
                scratch.b.reserve(kk * nn);
                for kx in 0..kk {
                    for j in 0..nn {
                        scratch.b.push(b_head[j * kk + kx]);
                    }
                }
                pack::pack_weights_into(plan, &scratch.b, &mut scratch.packed_b);
            } else {
                pack::pack_weights_into(plan, b_head, &mut scratch.packed_b);
            }
            m.write_bytes(bound.bufs.weights, 0, &scratch.packed_b);
            m.stream_touch(bound.bufs.weights, scratch.packed_b.len(), true);
            m.charge_bulk(b_head.len() as u64, 0);
        }

        // replay the cached GEMM kernel under the layer's patterns
        m.patterns.clear();
        m.patterns.extend_from_slice(&prep.patterns);
        m.run(&bound.program);

        // epilogue: accumulators -> f32 (single-tap tail bias) + scale
        for j in 0..nn {
            for i in 0..mm {
                let acc = m.read_i32(bound.bufs.out, (j * mm + i) * 4);
                let v = (acc as i64 - bias) as f32 / quant::ACC_SCALE * prep.scale;
                out.data[(h * mm + i) * nn + j] = v;
            }
        }
        m.stream_touch(bound.bufs.out, mm * nn * 4, false);
        m.charge_bulk((mm * nn) as u64, (mm * nn * 4) as u64);
    }
    (out, m.take_stats())
}

/// One-shot streaming execution (the legacy `run_conv` shape): pack
/// weights, allocate fresh buffers and emit the kernel *directly into
/// the executing machine*, so no instruction stream is ever
/// materialized. Keeps single-call memory O(1) for paper-scale layers;
/// repeated inference should use [`prepare_conv`] + [`run_bound`]
/// instead. Staging and epilogue are shared with the prepared path, so
/// outputs are bit-identical between the two.
pub fn run_conv_streaming(m: &mut Machine, cfg: &ConvLayerCfg, x: &Tensor) -> (Tensor, RunStats) {
    let plan = &cfg.plan;
    let (act_bytes, out_elems, out_bytes) = layer_sizes(plan);
    let wts = pack::pack_weights(plan, &cfg.weights);
    let msk = pack::pack_masks(plan);
    let bufs = LayerBufs {
        input: m.alloc(act_bytes),
        weights: m.alloc(wts.len()),
        out: m.alloc(out_bytes),
        masks: m.alloc(msk.len()),
    };
    m.write_bytes(bufs.weights, 0, &wts);
    m.write_bytes(bufs.masks, 0, &msk);
    assert_eq!(x.c, plan.cin, "{}: cin mismatch", plan.name);
    assert_eq!((x.h, x.w), (plan.hin, plan.win), "{}: spatial mismatch", plan.name);
    stage_input(m, plan, &bufs, &x.data, &mut Vec::new());

    // generate + execute the Algorithm-4 kernel (Machine is the Sink)
    m.patterns.clear();
    let base = codegen::register_patterns(plan, &mut m.patterns);
    codegen::emit_layer(plan, &bufs, base, m);

    let bn = (
        cfg.bn_scale.as_slice(),
        cfg.bn_bias.as_slice(),
        cfg.bn_mean.as_slice(),
        cfg.bn_var.as_slice(),
    );
    finish_layer(m, plan, bn, cfg.relu, &bufs, out_elems)
}

/// A prepared network node (conv/GEMM layers carry their prepared form).
#[derive(Debug, Clone)]
pub enum PreparedNode {
    Conv { prep: PreparedConv, input: usize },
    MatmulStatic { prep: PreparedMatmul, input: usize },
    MatmulDyn { prep: PreparedMatmul, a: usize, b: usize, transpose_b: bool },
    Softmax { x: usize },
    LayerNorm { x: usize, gamma: Vec<f32>, beta: Vec<f32> },
    Gelu { x: usize },
    TransposeHW { x: usize },
    SplitHeads { x: usize, heads: usize },
    MergeHeads { x: usize },
    Add { a: usize, b: usize, relu: bool },
    ConcatC { a: usize, b: usize },
    SliceC { x: usize, from: usize, to: usize },
    ShuffleC { x: usize, groups: usize },
    Gap { x: usize },
}

/// A whole network prepared once: codegen plans, packed weights and mask
/// tables cached per layer. Shareable across worker threads via `Arc`.
#[derive(Debug, Clone)]
pub struct PreparedModel {
    pub nodes: Vec<PreparedNode>,
}

impl PreparedModel {
    /// Prepare every conv/FC/GEMM layer of a graph exactly once.
    pub fn prepare(nodes: &[Node]) -> PreparedModel {
        let nodes = nodes
            .iter()
            .map(|n| match n {
                Node::Conv { cfg, input } => {
                    PreparedNode::Conv { prep: prepare_conv(cfg), input: *input }
                }
                Node::Matmul { cfg, weights, input } => PreparedNode::MatmulStatic {
                    prep: prepare_matmul(cfg, Some(weights)),
                    input: *input,
                },
                Node::MatmulDyn { cfg, a, b, transpose_b } => PreparedNode::MatmulDyn {
                    prep: prepare_matmul(cfg, None),
                    a: *a,
                    b: *b,
                    transpose_b: *transpose_b,
                },
                Node::Softmax { x } => PreparedNode::Softmax { x: *x },
                Node::LayerNorm { x, gamma, beta } => PreparedNode::LayerNorm {
                    x: *x,
                    gamma: gamma.clone(),
                    beta: beta.clone(),
                },
                Node::Gelu { x } => PreparedNode::Gelu { x: *x },
                Node::TransposeHW { x } => PreparedNode::TransposeHW { x: *x },
                Node::SplitHeads { x, heads } => {
                    PreparedNode::SplitHeads { x: *x, heads: *heads }
                }
                Node::MergeHeads { x } => PreparedNode::MergeHeads { x: *x },
                Node::Add { a, b, relu } => PreparedNode::Add { a: *a, b: *b, relu: *relu },
                Node::ConcatC { a, b } => PreparedNode::ConcatC { a: *a, b: *b },
                Node::SliceC { x, from, to } => {
                    PreparedNode::SliceC { x: *x, from: *from, to: *to }
                }
                Node::ShuffleC { x, groups } => {
                    PreparedNode::ShuffleC { x: *x, groups: *groups }
                }
                Node::Gap { x } => PreparedNode::Gap { x: *x },
            })
            .collect();
        PreparedModel { nodes }
    }

    /// Number of prepared kernels (conv/FC layers and GEMMs).
    pub fn num_layers(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| {
                matches!(
                    n,
                    PreparedNode::Conv { .. }
                        | PreparedNode::MatmulStatic { .. }
                        | PreparedNode::MatmulDyn { .. }
                )
            })
            .count()
    }
}

/// One worker's execution context: a simulated machine with every layer's
/// weights resident, reused across all requests the worker serves.
pub struct EngineMachine {
    model: Arc<PreparedModel>,
    m: Machine,
    bound: Vec<Option<BoundKernel>>,
    /// reusable pack scratch for dynamic GEMM operands
    scratch: MatmulScratch,
}

fn node_input<'a>(outputs: &'a [Tensor], input: &'a Tensor, id: usize) -> &'a Tensor {
    if id == INPUT {
        input
    } else {
        &outputs[id]
    }
}

impl EngineMachine {
    /// Bind a prepared model to a fresh simulated machine (one per
    /// worker): buffers allocated and weights/masks written exactly once.
    pub fn new(model: &Arc<PreparedModel>) -> EngineMachine {
        let mut m = Machine::new();
        let bound: Vec<Option<BoundKernel>> = model
            .nodes
            .iter()
            .map(|n| match n {
                PreparedNode::Conv { prep, .. } => Some(prep.bind(&mut m)),
                PreparedNode::MatmulStatic { prep, .. }
                | PreparedNode::MatmulDyn { prep, .. } => Some(prep.bind(&mut m)),
                _ => None,
            })
            .collect();
        EngineMachine { model: Arc::clone(model), m, bound, scratch: MatmulScratch::default() }
    }

    /// Run one inference over the prepared graph. Functionally identical
    /// to the legacy `run_network`, minus the per-call weight packing,
    /// codegen and buffer allocation.
    pub fn run(&mut self, input: &Tensor) -> NetResult {
        let model = Arc::clone(&self.model);
        let mut outputs: Vec<Tensor> = Vec::with_capacity(model.nodes.len());
        let mut layers = Vec::new();
        let mut total = RunStats::default();
        for (ni, node) in model.nodes.iter().enumerate() {
            let out = match node {
                PreparedNode::Conv { prep, input: id } => {
                    let x = node_input(&outputs, input, *id);
                    let bound = self.bound[ni].as_ref().expect("conv layer bound");
                    let (t, stats) = run_bound_with_scratch(
                        &mut self.m,
                        prep,
                        bound,
                        x,
                        &mut self.scratch.packed_act,
                    );
                    total.merge(&stats);
                    layers.push(LayerStat { name: prep.plan.name.clone(), stats });
                    t
                }
                PreparedNode::MatmulStatic { prep, input: id } => {
                    let x = node_input(&outputs, input, *id);
                    let bound = self.bound[ni].as_ref().expect("matmul bound");
                    let (t, stats) =
                        run_matmul(&mut self.m, prep, bound, x, None, &mut self.scratch);
                    total.merge(&stats);
                    layers.push(LayerStat { name: prep.plan.name.clone(), stats });
                    t
                }
                PreparedNode::MatmulDyn { prep, a, b, transpose_b } => {
                    let ta = node_input(&outputs, input, *a);
                    let tb = node_input(&outputs, input, *b);
                    let bound = self.bound[ni].as_ref().expect("matmul bound");
                    let (t, stats) = run_matmul(
                        &mut self.m,
                        prep,
                        bound,
                        ta,
                        Some((tb, *transpose_b)),
                        &mut self.scratch,
                    );
                    total.merge(&stats);
                    layers.push(LayerStat { name: prep.plan.name.clone(), stats });
                    t
                }
                PreparedNode::Softmax { x } => {
                    let tx = node_input(&outputs, input, *x);
                    let mut t = tx.clone();
                    eltwise::softmax_rows(&mut t.data, t.c);
                    let bytes = (t.data.len() * 8) as u64;
                    total.add_bulk(t.data.len() as u64, bytes, &self.m.energy_cfg);
                    t
                }
                PreparedNode::LayerNorm { x, gamma, beta } => {
                    let tx = node_input(&outputs, input, *x);
                    let mut t = tx.clone();
                    eltwise::layernorm_rows(&mut t.data, t.c, gamma, beta);
                    let bytes = (t.data.len() * 8) as u64;
                    total.add_bulk(t.data.len() as u64, bytes, &self.m.energy_cfg);
                    t
                }
                PreparedNode::Gelu { x } => {
                    let tx = node_input(&outputs, input, *x);
                    let mut t = tx.clone();
                    eltwise::gelu_rows(&mut t.data);
                    let bytes = (t.data.len() * 8) as u64;
                    total.add_bulk(t.data.len() as u64, bytes, &self.m.energy_cfg);
                    t
                }
                PreparedNode::TransposeHW { x } => {
                    let tx = node_input(&outputs, input, *x);
                    let mut t = Tensor::zeros(tx.w, tx.h, tx.c);
                    for h in 0..tx.h {
                        for w in 0..tx.w {
                            for c in 0..tx.c {
                                t.data[(w * t.w + h) * t.c + c] = tx.at(h, w, c);
                            }
                        }
                    }
                    let bytes = (t.data.len() * 8) as u64;
                    total.add_bulk(t.data.len() as u64, bytes, &self.m.energy_cfg);
                    t
                }
                PreparedNode::SplitHeads { x, heads } => {
                    let tx = node_input(&outputs, input, *x);
                    let hd = *heads;
                    assert_eq!(tx.h, 1, "SplitHeads expects an unsplit (h=1) tensor");
                    assert_eq!(tx.c % hd, 0, "channels not divisible by heads");
                    let dh = tx.c / hd;
                    let mut t = Tensor::zeros(hd, tx.w, dh);
                    for s in 0..tx.w {
                        for head in 0..hd {
                            for c in 0..dh {
                                t.data[(head * t.w + s) * dh + c] =
                                    tx.data[s * tx.c + head * dh + c];
                            }
                        }
                    }
                    let bytes = (t.data.len() * 8) as u64;
                    total.add_bulk(t.data.len() as u64, bytes, &self.m.energy_cfg);
                    t
                }
                PreparedNode::MergeHeads { x } => {
                    let tx = node_input(&outputs, input, *x);
                    let (hd, dh) = (tx.h, tx.c);
                    let mut t = Tensor::zeros(1, tx.w, hd * dh);
                    for s in 0..tx.w {
                        for head in 0..hd {
                            for c in 0..dh {
                                t.data[s * t.c + head * dh + c] =
                                    tx.data[(head * tx.w + s) * dh + c];
                            }
                        }
                    }
                    let bytes = (t.data.len() * 8) as u64;
                    total.add_bulk(t.data.len() as u64, bytes, &self.m.energy_cfg);
                    t
                }
                PreparedNode::Add { a, b, relu } => {
                    let ta = node_input(&outputs, input, *a);
                    let tb = node_input(&outputs, input, *b);
                    assert_eq!(ta.data.len(), tb.data.len());
                    let mut t = ta.clone();
                    for (v, w) in t.data.iter_mut().zip(&tb.data) {
                        *v += w;
                        if *relu {
                            *v = v.max(0.0);
                        }
                    }
                    let bytes = (t.data.len() * 8) as u64;
                    total.add_bulk(t.data.len() as u64, bytes, &self.m.energy_cfg);
                    t
                }
                PreparedNode::ConcatC { a, b } => {
                    let ta = node_input(&outputs, input, *a);
                    let tb = node_input(&outputs, input, *b);
                    assert_eq!((ta.h, ta.w), (tb.h, tb.w));
                    let mut t = Tensor::zeros(ta.h, ta.w, ta.c + tb.c);
                    for h in 0..ta.h {
                        for w in 0..ta.w {
                            for c in 0..ta.c {
                                t.data[(h * t.w + w) * t.c + c] = ta.at(h, w, c);
                            }
                            for c in 0..tb.c {
                                t.data[(h * t.w + w) * t.c + ta.c + c] = tb.at(h, w, c);
                            }
                        }
                    }
                    t
                }
                PreparedNode::SliceC { x, from, to } => {
                    let tx = node_input(&outputs, input, *x);
                    let mut t = Tensor::zeros(tx.h, tx.w, to - from);
                    for h in 0..tx.h {
                        for w in 0..tx.w {
                            for c in *from..*to {
                                t.data[(h * t.w + w) * t.c + (c - from)] = tx.at(h, w, c);
                            }
                        }
                    }
                    t
                }
                PreparedNode::ShuffleC { x, groups } => {
                    let tx = node_input(&outputs, input, *x);
                    let g = *groups;
                    let per = tx.c / g;
                    let mut t = Tensor::zeros(tx.h, tx.w, tx.c);
                    // NHWC shuffle: out[.., i*g + j] = in[.., j*per + i]
                    for h in 0..tx.h {
                        for w in 0..tx.w {
                            for j in 0..g {
                                for i in 0..per {
                                    t.data[(h * t.w + w) * t.c + (i * g + j)] =
                                        tx.at(h, w, j * per + i);
                                }
                            }
                        }
                    }
                    t
                }
                PreparedNode::Gap { x } => {
                    let tx = node_input(&outputs, input, *x);
                    let mut t = Tensor::zeros(1, 1, tx.c);
                    for c in 0..tx.c {
                        let mut s = 0.0f32;
                        for h in 0..tx.h {
                            for w in 0..tx.w {
                                s += tx.at(h, w, c);
                            }
                        }
                        t.data[c] = s / (tx.h * tx.w) as f32;
                    }
                    let bytes = (tx.data.len() * 4) as u64;
                    total.add_bulk(tx.data.len() as u64, bytes, &self.m.energy_cfg);
                    t
                }
            };
            outputs.push(out);
        }
        NetResult { output: outputs.pop().unwrap(), layers, total }
    }
}
