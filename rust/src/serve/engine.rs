//! The unified execution API: every graph node — conv/FC kernels, GEMMs,
//! f32 epilogues and the KV-cached decode attention — is a
//! [`PreparedOp`]: `prepare` (codegen + operand packing, once per model)
//! `-> bind` (machine buffers + resident weights, once per worker)
//! `-> run(ctx)` (the per-request work), with a typed [`ExecCtx`]
//! carrying the simulated machine, per-worker scratch and — for decode
//! steps — the request's per-session K/V state.
//!
//! The legacy free-function zoo (`run_bound`, `run_matmul`,
//! `run_conv_streaming`) and the `PreparedNode` match-dispatch are gone:
//! [`run_graph`] walks a prepared graph and dispatches through the trait
//! object, and `sim::network::{run_conv, run_network}` are thin clients
//! of the same API. Outputs are bit-identical to the pre-trait engine.
//!
//! Kernel ops support two execution modes: *bound* (the serving path —
//! replay a cached instruction stream against buffers bound once per
//! worker) and *streaming* (`ctx.bound == None` — emit the kernel
//! straight into the machine, O(1) memory even for paper-scale layers;
//! the one-shot `run_conv` mode). Both share staging and epilogue, so
//! their outputs and stats match exactly.

use crate::codegen::gemm::{emit_gemm, emit_gemm_causal, GemmPlan};
use crate::codegen::{self, pack, LayerBufs, LayerKind, LayerPlan};
use crate::serve::kvpool::{KvPolicy, KvPool, KvPoolCfg, KvPoolStats, SlotGeomSpec};
use crate::serve::session::{CachedAttnOp, CausalAvOp, SessionState};
use crate::serve::{ModelHandle, ModelKey};
use crate::sim::eltwise;
use crate::sim::machine::{Machine, RunStats};
use crate::sim::network::{ConvLayerCfg, LayerStat, MatmulCfg, NetResult, Node, Tensor, INPUT};
use crate::simd::isa::{Addr, BufId, Instr};
use crate::simd::patterns::Pattern;
use crate::smol::quant;
use std::collections::HashMap;
use std::sync::Arc;

/// Reusable per-worker scratch shared by every op a worker executes:
/// packed-activation staging bytes, the materialized/packed dynamic
/// GEMM operand, and the quantize/mask buffers of the variable-length
/// decode GEMMs. One per [`EngineMachine`], reused across all requests
/// the worker serves, so the staging/packing hot path — in particular
/// the decode K/V append path — performs no per-request allocation
/// (output tensors and per-length kernel plans still allocate).
#[derive(Debug, Default, Clone)]
pub struct WorkerScratch {
    /// materialized B^T for `transpose_b` dynamic operands
    pub(crate) b: Vec<f32>,
    /// packed dynamic "weight" operand bytes
    pub(crate) packed_b: Vec<u8>,
    /// packed-activation bytes every kernel's staging runs through
    pub(crate) packed_act: Vec<u8>,
    /// quantized-value gather buffer (KV appends, row packing)
    pub(crate) vals: Vec<f32>,
    /// per-chunk tail-mask bytes of variable-length row GEMMs
    pub(crate) masks: Vec<u8>,
}

/// Everything an op may touch while running: the worker's simulated
/// machine, this op's bound buffers (`None` in streaming mode), the
/// worker scratch, and — inside a decode step — the session state that
/// owns the packed K/V caches, plus the worker's page pool when the
/// session stores them paged.
pub struct ExecCtx<'a> {
    pub m: &'a mut Machine,
    pub bound: Option<&'a BoundKernel>,
    pub scratch: &'a mut WorkerScratch,
    pub session: Option<&'a mut SessionState>,
    /// the worker's paged KV pool ([`CachedAttnOp`] allocates pages
    /// from it at page boundaries; `None` on non-paged workers)
    pub kv: Option<&'a mut KvPool>,
}

/// One prepared graph operation. Object-safe: a prepared model is a
/// `Vec` of `Box<dyn PreparedOp>` plus input wiring, and the graph
/// runner dispatches through this trait instead of an enum match.
pub trait PreparedOp: std::fmt::Debug + Send + Sync {
    /// Stats label; `Some` for kernel ops (they appear in per-layer
    /// reports), `None` for epilogue/layout ops.
    fn name(&self) -> Option<&str> {
        None
    }

    /// Allocate this op's machine buffers and write its resident
    /// operands (packed weights, tail masks) once per worker. Ops with
    /// no machine state return `None`.
    fn bind(&self, _m: &mut Machine) -> Option<BoundKernel> {
        None
    }

    /// Machine buffer bytes [`bind`](Self::bind) allocates (0 for ops
    /// with no machine state). Kept exactly in sync with each op's
    /// `bind` so budgeted machines can evict LRU models *before* an
    /// allocation would overflow the buffer budget.
    fn bind_bytes(&self) -> usize {
        0
    }

    /// Execute against resolved input tensors, returning the output.
    /// Simulated-cost accounting accumulates on `ctx.m`; the graph
    /// runner collects it per node via `take_stats`.
    fn run(&self, ctx: &mut ExecCtx<'_>, inputs: &[&Tensor]) -> Tensor;

    /// Programs the static verifier ([`crate::analysis`]) should
    /// check, each paired with the buffer/pattern/chunk spec it runs
    /// under. Ops that cache a kernel return it; ops that emit per
    /// request return representative programs covering their emission
    /// space; stateless epilogue/layout ops return nothing.
    fn verify_programs(&self) -> Vec<crate::analysis::ProgramToVerify<'_>> {
        Vec::new()
    }
}

/// A prepared kernel bound to concrete buffers of one [`Machine`]:
/// masks — and, for static operands, weights — are written once;
/// input/out (and dynamic-operand weights) act as reusable scratch.
#[derive(Debug, Clone)]
pub struct BoundKernel {
    pub(crate) bufs: LayerBufs,
    pub(crate) program: Vec<Instr>,
}

/// Buffer sizing shared by the bound and streaming paths:
/// (packed-activation bytes, output elements, output-buffer bytes).
fn layer_sizes(plan: &LayerPlan) -> (usize, usize, usize) {
    let (hout, wout) = (plan.hout(), plan.wout());
    let n_chunks = plan.chunks().len();
    let act_bytes = plan.hin * plan.win * n_chunks * 16;
    let out_elems = match plan.kind {
        LayerKind::Dense => plan.cout * hout * wout,
        LayerKind::Depthwise => plan.cin * hout * wout,
    };
    // baseline depthwise stores whole 16B chunk vectors per position,
    // which can exceed cin*4 bytes when cin is not a multiple of the
    // lane capacity — size the buffer for both layouts
    let out_bytes = (out_elems * 4).max(hout * wout * n_chunks * 16);
    (act_bytes, out_elems, out_bytes)
}

/// Rewrite the symbolic buffer ids of a prepared kernel to the buffers a
/// machine actually allocated.
fn retarget(prog: &[Instr], bufs: &LayerBufs) -> Vec<Instr> {
    let map = |a: Addr| -> Addr {
        let buf = match a.buf.0 {
            0 => bufs.input,
            1 => bufs.weights,
            2 => bufs.out,
            3 => bufs.masks,
            _ => a.buf,
        };
        Addr { buf, off: a.off }
    };
    prog.iter()
        .map(|i| match *i {
            Instr::LdQ { dst, addr } => Instr::LdQ { dst, addr: map(addr) },
            Instr::StQ { src, addr } => Instr::StQ { src, addr: map(addr) },
            Instr::ReduceAcc { src, addr } => Instr::ReduceAcc { src, addr: map(addr) },
            Instr::MulAcc { lo, hi, pat, addr, n_valid } => {
                Instr::MulAcc { lo, hi, pat, addr: map(addr), n_valid }
            }
            other => other,
        })
        .collect()
}

/// Machine bytes [`PreparedConv::bind`] allocates for `plan` (input +
/// weights + out + masks buffers). Pure plan arithmetic — the shard
/// planner sizes candidate deployments against the per-worker buffer
/// budget with it, without packing any weights. Weight bytes come from
/// the same [`pack::packed_cout_row_bytes`] the pack layout and shard
/// slicer use, so estimate and layout cannot drift apart.
pub fn conv_bind_bytes(plan: &LayerPlan) -> usize {
    let (act_bytes, _, out_bytes) = layer_sizes(plan);
    let row = pack::packed_cout_row_bytes(plan);
    let weight_bytes = match plan.kind {
        LayerKind::Dense => plan.cout * row,
        LayerKind::Depthwise => row,
    };
    act_bytes + weight_bytes + out_bytes + plan.chunks().len().max(1) * 16
}

/// Machine bytes [`PreparedMatmul::bind`] allocates for `plan` (same
/// role as [`conv_bind_bytes`] for GEMM nodes).
pub fn matmul_bind_bytes(plan: &GemmPlan) -> usize {
    let lp = plan.layer_plan();
    let (act_bytes, _, out_bytes) = layer_sizes(&lp);
    let weight_bytes = lp.cout * pack::packed_cout_row_bytes(&lp);
    act_bytes + weight_bytes + out_bytes + lp.chunks().len().max(1) * 16
}

/// Number of in-bounds taps for output position (h, w).
pub(crate) fn valid_taps(plan: &LayerPlan, h: usize, w: usize) -> usize {
    let (pt, pl) = (plan.pad_top(), plan.pad_left());
    let mut n = 0;
    for r in 0..plan.kh {
        for s in 0..plan.kw {
            let ih = h as isize * plan.stride as isize + r as isize - pt;
            let iw = w as isize * plan.stride as isize + s as isize - pl;
            if ih >= 0 && iw >= 0 && ih < plan.hin as isize && iw < plan.win as isize {
                n += 1;
            }
        }
    }
    n
}

/// Per-request input staging, shared by every execution path (conv and
/// GEMM, streaming and bound): pack the activations into the input
/// buffer through caller-owned scratch, zero the accumulator scratch
/// and charge the quantize/rearrange/pack pass as streaming cache
/// traffic.
fn stage_input(
    m: &mut Machine,
    plan: &LayerPlan,
    bufs: &LayerBufs,
    x: &[f32],
    scratch: &mut Vec<u8>,
) {
    pack::pack_activations_into(plan, x, scratch);
    m.write_bytes(bufs.input, 0, scratch);
    m.clear_buffer(bufs.out);
    m.stream_touch(bufs.input, scratch.len(), true);
    m.charge_bulk(x.len() as u64, 0);
}

/// Epilogue shared by both execution paths: accumulators -> f32 with
/// tail-bias correction, BN, ReLU, output traffic charge; returns the
/// layer output.
fn finish_layer(
    m: &mut Machine,
    plan: &LayerPlan,
    bn: (&[f32], &[f32], &[f32], &[f32]),
    relu: bool,
    bufs: &LayerBufs,
    out_elems: usize,
) -> Tensor {
    let (bn_scale, bn_bias, bn_mean, bn_var) = bn;
    let (hout, wout) = (plan.hout(), plan.wout());
    let bias = plan.tail_bias();
    let mut out = match plan.kind {
        LayerKind::Dense => {
            let mut t = Tensor::zeros(hout, wout, plan.cout);
            for k in 0..plan.cout {
                for h in 0..hout {
                    for w in 0..wout {
                        let acc = m.read_i32(bufs.out, ((k * hout + h) * wout + w) * 4);
                        let taps = valid_taps(plan, h, w) as i64;
                        let v = (acc as i64 - bias * taps) as f32 / quant::ACC_SCALE;
                        t.data[(h * wout + w) * plan.cout + k] = v;
                    }
                }
            }
            t
        }
        LayerKind::Depthwise => {
            // depthwise MulAcc wrote in *packed* channel order; un-permute
            let mut t = Tensor::zeros(hout, wout, plan.cin);
            for h in 0..hout {
                for w in 0..wout {
                    for (pos, &ch) in plan.asg.order.iter().enumerate() {
                        let acc = m.read_i32(bufs.out, ((h * wout + w) * plan.cin + pos) * 4);
                        t.data[(h * wout + w) * plan.cin + ch as usize] =
                            acc as f32 / quant::ACC_SCALE;
                    }
                }
            }
            t
        }
    };

    // BN + ReLU epilogue (f32, vectorized in hardware; bulk-costed)
    if !bn_scale.is_empty() {
        let cch = out.c;
        for i in 0..out.data.len() {
            let k = i % cch;
            let inv = 1.0 / (bn_var[k] + 1e-5).sqrt();
            out.data[i] = (out.data[i] - bn_mean[k]) * inv * bn_scale[k] + bn_bias[k];
        }
    }
    if relu {
        for v in out.data.iter_mut() {
            *v = v.max(0.0);
        }
    }
    m.stream_touch(bufs.out, out_elems * 4, false);
    m.charge_bulk(out.data.len() as u64, (out.data.len() * 4) as u64);

    out
}

/// One conv/FC layer with everything per-request work does NOT need to
/// recompute: the emitted kernel (bound mode only), SMOL-packed weights,
/// tail masks, the pattern table and the epilogue parameters.
#[derive(Debug, Clone)]
pub struct PreparedConv {
    pub plan: LayerPlan,
    bn_scale: Vec<f32>,
    bn_bias: Vec<f32>,
    bn_mean: Vec<f32>,
    bn_var: Vec<f32>,
    relu: bool,
    /// Algorithm-4 kernel emitted against the symbolic buffer ids
    /// 0=input, 1=weights, 2=out, 3=masks (retargeted at bind time).
    /// `None` for a streaming-mode op: the kernel is emitted straight
    /// into the executing machine on every `run`, so no instruction
    /// stream is ever materialized (O(1) memory for paper-scale layers).
    program: Option<Vec<Instr>>,
    /// the layer's chunk patterns (machine table base 0, as emitted)
    patterns: Vec<Pattern>,
    packed_weights: Vec<u8>,
    packed_masks: Vec<u8>,
    act_bytes: usize,
    out_bytes: usize,
    out_elems: usize,
}

impl PreparedConv {
    fn build(cfg: &ConvLayerCfg, materialize: bool) -> PreparedConv {
        let plan = cfg.plan.clone();
        let (act_bytes, out_elems, out_bytes) = layer_sizes(&plan);

        let packed_weights = pack::pack_weights(&plan, &cfg.weights);
        let packed_masks = pack::pack_masks(&plan);

        let mut patterns = Vec::new();
        let program = if materialize {
            let base = codegen::register_patterns(&plan, &mut patterns);
            let symbolic = LayerBufs {
                input: BufId(0),
                weights: BufId(1),
                out: BufId(2),
                masks: BufId(3),
            };
            let mut program = Vec::new();
            codegen::emit_layer(&plan, &symbolic, base, &mut program);
            Some(program)
        } else {
            None
        };

        PreparedConv {
            plan,
            bn_scale: cfg.bn_scale.clone(),
            bn_bias: cfg.bn_bias.clone(),
            bn_mean: cfg.bn_mean.clone(),
            bn_var: cfg.bn_var.clone(),
            relu: cfg.relu,
            program,
            patterns,
            packed_weights,
            packed_masks,
            act_bytes,
            out_bytes,
            out_elems,
        }
    }

    /// Run codegen + weight/mask packing once; the resulting op is
    /// bindable (serving mode: replay the cached kernel per request).
    pub fn prepare(cfg: &ConvLayerCfg) -> PreparedConv {
        PreparedConv::build(cfg, true)
    }

    /// Streaming-mode op: weights/masks are packed but no instruction
    /// stream is materialized; every `run` emits the kernel directly
    /// into the machine against freshly allocated buffers. The one-shot
    /// `sim::network::run_conv` mode.
    pub fn streaming(cfg: &ConvLayerCfg) -> PreparedConv {
        PreparedConv::build(cfg, false)
    }

    fn bn(&self) -> (&[f32], &[f32], &[f32], &[f32]) {
        (&self.bn_scale, &self.bn_bias, &self.bn_mean, &self.bn_var)
    }
}

impl PreparedOp for PreparedConv {
    fn name(&self) -> Option<&str> {
        Some(&self.plan.name)
    }

    /// Allocate this layer's buffers (same order and sizes as the
    /// streaming path: input, weights, out, masks), write the cached
    /// weights + masks once, and retarget the kernel to the allocated
    /// buffer ids.
    fn bind(&self, m: &mut Machine) -> Option<BoundKernel> {
        let program = self.program.as_ref().expect("streaming-mode conv cannot be bound");
        let bufs = LayerBufs {
            input: m.alloc(self.act_bytes),
            weights: m.alloc(self.packed_weights.len()),
            out: m.alloc(self.out_bytes),
            masks: m.alloc(self.packed_masks.len()),
        };
        m.write_bytes(bufs.weights, 0, &self.packed_weights);
        m.write_bytes(bufs.masks, 0, &self.packed_masks);
        let program = retarget(program, &bufs);
        Some(BoundKernel { bufs, program })
    }

    fn bind_bytes(&self) -> usize {
        self.act_bytes + self.packed_weights.len() + self.out_bytes + self.packed_masks.len()
    }

    /// The cached kernel with the exact buffer extents `bind`
    /// allocates. Streaming-mode ops return nothing here — paper-scale
    /// layers verify by streaming the emitter into the verifier
    /// directly (it is a [`codegen::Sink`]) instead of materializing.
    fn verify_programs(&self) -> Vec<crate::analysis::ProgramToVerify<'_>> {
        let Some(program) = &self.program else { return Vec::new() };
        let spec = crate::analysis::KernelSpec::for_layer(&self.plan).with_buffers(
            self.act_bytes,
            self.packed_weights.len(),
            self.out_bytes,
            self.packed_masks.len(),
        );
        vec![crate::analysis::ProgramToVerify {
            spec,
            program: std::borrow::Cow::Borrowed(program),
            terms: crate::analysis::TermSpec::for_layer(&self.plan),
        }]
    }

    fn run(&self, ctx: &mut ExecCtx<'_>, inputs: &[&Tensor]) -> Tensor {
        let x = inputs[0];
        let plan = &self.plan;
        assert_eq!(x.c, plan.cin, "{}: cin mismatch", plan.name);
        assert_eq!((x.h, x.w), (plan.hin, plan.win), "{}: spatial mismatch", plan.name);

        match ctx.bound {
            Some(bound) => {
                // serving path: stage activations, replay the cached
                // kernel under the layer's patterns, epilogue
                stage_input(ctx.m, plan, &bound.bufs, &x.data, &mut ctx.scratch.packed_act);
                ctx.m.patterns.clear();
                ctx.m.patterns.extend_from_slice(&self.patterns);
                ctx.m.run(&bound.program);
                finish_layer(ctx.m, plan, self.bn(), self.relu, &bound.bufs, self.out_elems)
            }
            None => {
                // streaming path: fresh buffers, kernel emitted straight
                // into the machine (Machine is the Sink)
                let m = &mut *ctx.m;
                let bufs = LayerBufs {
                    input: m.alloc(self.act_bytes),
                    weights: m.alloc(self.packed_weights.len()),
                    out: m.alloc(self.out_bytes),
                    masks: m.alloc(self.packed_masks.len()),
                };
                m.write_bytes(bufs.weights, 0, &self.packed_weights);
                m.write_bytes(bufs.masks, 0, &self.packed_masks);
                stage_input(m, plan, &bufs, &x.data, &mut ctx.scratch.packed_act);
                m.patterns.clear();
                let base = codegen::register_patterns(plan, &mut m.patterns);
                codegen::emit_layer(plan, &bufs, base, m);
                finish_layer(m, plan, self.bn(), self.relu, &bufs, self.out_elems)
            }
        }
    }
}

/// One GEMM node with everything per-request work does NOT need to
/// recompute. Static projections (`X · W`) cache their packed weights
/// exactly like a conv layer; dynamic-operand GEMMs (QK^T, A·V) cache
/// the kernel, masks and pattern table but pack their "weight" side per
/// request into the bound scratch buffer. The causal score variant
/// emits the masked kernel and epilogues the upper triangle to `-inf`.
#[derive(Debug, Clone)]
pub struct PreparedMatmul {
    /// the GEMM lowered to its 1x1 dense plan (`hin=m, win=1, cin=k,
    /// cout=n`) — packing, chunking and tail bias reuse the conv view
    pub plan: LayerPlan,
    scale: f32,
    /// `None` = static operand (packed once at prepare); `Some(t)` =
    /// dynamic operand with `transpose_b = t`, packed per request
    dynamic: Option<bool>,
    causal: bool,
    program: Vec<Instr>,
    patterns: Vec<Pattern>,
    packed_weights: Option<Vec<u8>>,
    packed_masks: Vec<u8>,
    act_bytes: usize,
    weight_bytes: usize,
    out_bytes: usize,
}

impl PreparedMatmul {
    fn build(cfg: &MatmulCfg, weights: Option<&[f32]>, dynamic: Option<bool>) -> PreparedMatmul {
        let plan = cfg.plan.layer_plan();
        let (act_bytes, _, out_bytes) = layer_sizes(&plan);
        let weight_bytes = plan.cout * plan.chunks().len() * 16;

        let packed_weights = weights.map(|w| pack::pack_weights(&plan, w));
        let packed_masks = pack::pack_masks(&plan);

        let mut patterns = Vec::new();
        let base = codegen::register_patterns(&plan, &mut patterns);
        let symbolic = LayerBufs {
            input: BufId(0),
            weights: BufId(1),
            out: BufId(2),
            masks: BufId(3),
        };
        let mut program = Vec::new();
        if cfg.causal {
            emit_gemm_causal(&cfg.plan, &symbolic, base, &mut program);
        } else {
            emit_gemm(&cfg.plan, &symbolic, base, &mut program);
        }

        PreparedMatmul {
            plan,
            scale: cfg.scale,
            dynamic,
            causal: cfg.causal,
            program,
            patterns,
            packed_weights,
            packed_masks,
            act_bytes,
            weight_bytes,
            out_bytes,
        }
    }

    /// Run codegen + static weight packing for an `X · W` node.
    /// `weights` is the `[k][n]` row-major static operand.
    pub fn prepare_static(cfg: &MatmulCfg, weights: &[f32]) -> PreparedMatmul {
        assert!(!cfg.causal, "{}: causal masking needs a dynamic operand", cfg.plan.name);
        PreparedMatmul::build(cfg, Some(weights), None)
    }

    /// Run codegen for a dynamic-operand GEMM (both sides are node
    /// outputs); the "weight" side is quantized + packed per request.
    pub fn prepare_dyn(cfg: &MatmulCfg, transpose_b: bool) -> PreparedMatmul {
        PreparedMatmul::build(cfg, None, Some(transpose_b))
    }
}

impl PreparedOp for PreparedMatmul {
    fn name(&self) -> Option<&str> {
        Some(&self.plan.name)
    }

    /// Allocate this GEMM's buffers, write masks (and, for a static
    /// operand, the cached packed weights) once, and retarget the
    /// kernel. For dynamic operands the weights buffer is per-worker
    /// scratch refilled on every request.
    fn bind(&self, m: &mut Machine) -> Option<BoundKernel> {
        let bufs = LayerBufs {
            input: m.alloc(self.act_bytes),
            weights: m.alloc(self.weight_bytes),
            out: m.alloc(self.out_bytes),
            masks: m.alloc(self.packed_masks.len()),
        };
        if let Some(w) = &self.packed_weights {
            m.write_bytes(bufs.weights, 0, w);
        }
        m.write_bytes(bufs.masks, 0, &self.packed_masks);
        let program = retarget(&self.program, &bufs);
        Some(BoundKernel { bufs, program })
    }

    fn bind_bytes(&self) -> usize {
        self.act_bytes + self.weight_bytes + self.out_bytes + self.packed_masks.len()
    }

    /// The cached GEMM kernel (static and dynamic operands replay the
    /// same instruction stream) under the exact bind-time extents.
    /// The weights buffer is sized `cout * nch * 16` rather than the
    /// 1x1-dense-plan minimum, so the spec carries the real extent.
    fn verify_programs(&self) -> Vec<crate::analysis::ProgramToVerify<'_>> {
        let spec = crate::analysis::KernelSpec::for_layer(&self.plan).with_buffers(
            self.act_bytes,
            self.weight_bytes,
            self.out_bytes,
            self.packed_masks.len(),
        );
        vec![crate::analysis::ProgramToVerify {
            spec,
            program: std::borrow::Cow::Borrowed(&self.program),
            terms: crate::analysis::TermSpec::for_layer_causal(&self.plan, self.causal),
        }]
    }

    /// Execute the GEMM, batched over the `h` (head) axis of the first
    /// input. One input runs the static-operand form (weights resident
    /// from bind time); two inputs quantize + pack the second operand
    /// per head through the worker scratch before replaying the kernel.
    fn run(&self, ctx: &mut ExecCtx<'_>, inputs: &[&Tensor]) -> Tensor {
        let bound = ctx.bound.expect("GEMM ops run against bound buffers");
        let plan = &self.plan;
        let (mm, kk, nn) = (plan.hin, plan.cin, plan.cout);
        let a = inputs[0];
        assert_eq!(a.w, mm, "{}: row (sequence) mismatch", plan.name);
        assert_eq!(a.c, kk, "{}: contraction dim mismatch", plan.name);
        let b_dyn: Option<(&Tensor, bool)> = match self.dynamic {
            None => {
                assert_eq!(inputs.len(), 1, "{}: static GEMM takes one input", plan.name);
                None
            }
            Some(transpose_b) => {
                let b = inputs[1];
                assert_eq!(b.h, a.h, "{}: head-batch mismatch", plan.name);
                if transpose_b {
                    assert_eq!((b.c, b.w), (kk, nn), "{}: B^T shape mismatch", plan.name);
                } else {
                    assert_eq!((b.w, b.c), (kk, nn), "{}: B shape mismatch", plan.name);
                }
                Some((b, transpose_b))
            }
        };

        let m = &mut *ctx.m;
        let scratch = &mut *ctx.scratch;
        let bias = plan.tail_bias();
        let mut out = Tensor::zeros(a.h, mm, nn);
        for h in 0..a.h {
            // stage this head's A rows (quantize + pack, charged as
            // streaming traffic like conv activation staging)
            let a_head = &a.data[h * mm * kk..(h + 1) * mm * kk];
            stage_input(m, plan, &bound.bufs, a_head, &mut scratch.packed_act);

            if let Some((b, transpose_b)) = b_dyn {
                // pack the dynamic operand: quantize to the contraction
                // axis's per-channel precisions, exactly like static weights
                let b_head = &b.data[h * b.w * b.c..(h + 1) * b.w * b.c];
                if transpose_b {
                    // materialize B^T ([k][n] row-major) in scratch
                    scratch.b.clear();
                    scratch.b.reserve(kk * nn);
                    for kx in 0..kk {
                        for j in 0..nn {
                            scratch.b.push(b_head[j * kk + kx]);
                        }
                    }
                    pack::pack_weights_into(plan, &scratch.b, &mut scratch.packed_b);
                } else {
                    pack::pack_weights_into(plan, b_head, &mut scratch.packed_b);
                }
                m.write_bytes(bound.bufs.weights, 0, &scratch.packed_b);
                m.stream_touch(bound.bufs.weights, scratch.packed_b.len(), true);
                m.charge_bulk(b_head.len() as u64, 0);
            }

            // replay the cached GEMM kernel under the layer's patterns
            m.patterns.clear();
            m.patterns.extend_from_slice(&self.patterns);
            m.run(&bound.program);

            // epilogue: accumulators -> f32 (single-tap tail bias) +
            // scale; the causal upper triangle was never accumulated and
            // is filled with -inf for the downstream softmax
            for j in 0..nn {
                for i in 0..mm {
                    let v = if self.causal && j > i {
                        f32::NEG_INFINITY
                    } else {
                        let acc = m.read_i32(bound.bufs.out, (j * mm + i) * 4);
                        (acc as i64 - bias) as f32 / quant::ACC_SCALE * self.scale
                    };
                    out.data[(h * mm + i) * nn + j] = v;
                }
            }
            m.stream_touch(bound.bufs.out, mm * nn * 4, false);
            m.charge_bulk((mm * nn) as u64, (mm * nn * 4) as u64);
        }
        out
    }
}

/// Row softmax along `c` for every (h, w).
#[derive(Debug)]
struct SoftmaxOp;

impl PreparedOp for SoftmaxOp {
    fn run(&self, ctx: &mut ExecCtx<'_>, inputs: &[&Tensor]) -> Tensor {
        let mut t = inputs[0].clone();
        eltwise::softmax_rows(&mut t.data, t.c);
        let bytes = (t.data.len() * 8) as u64;
        ctx.m.charge_bulk(t.data.len() as u64, bytes);
        t
    }
}

/// Layer normalization along `c` with per-feature affine.
#[derive(Debug)]
struct LayerNormOp {
    gamma: Vec<f32>,
    beta: Vec<f32>,
}

impl PreparedOp for LayerNormOp {
    fn run(&self, ctx: &mut ExecCtx<'_>, inputs: &[&Tensor]) -> Tensor {
        let mut t = inputs[0].clone();
        eltwise::layernorm_rows(&mut t.data, t.c, &self.gamma, &self.beta);
        let bytes = (t.data.len() * 8) as u64;
        ctx.m.charge_bulk(t.data.len() as u64, bytes);
        t
    }
}

/// GELU activation (tanh approximation).
#[derive(Debug)]
struct GeluOp;

impl PreparedOp for GeluOp {
    fn run(&self, ctx: &mut ExecCtx<'_>, inputs: &[&Tensor]) -> Tensor {
        let mut t = inputs[0].clone();
        eltwise::gelu_rows(&mut t.data);
        let bytes = (t.data.len() * 8) as u64;
        ctx.m.charge_bulk(t.data.len() as u64, bytes);
        t
    }
}

/// Swap the `h` and `w` axes.
#[derive(Debug)]
struct TransposeHWOp;

impl PreparedOp for TransposeHWOp {
    fn run(&self, ctx: &mut ExecCtx<'_>, inputs: &[&Tensor]) -> Tensor {
        let tx = inputs[0];
        let mut t = Tensor::zeros(tx.w, tx.h, tx.c);
        for h in 0..tx.h {
            for w in 0..tx.w {
                for c in 0..tx.c {
                    t.data[(w * t.w + h) * t.c + c] = tx.at(h, w, c);
                }
            }
        }
        let bytes = (t.data.len() * 8) as u64;
        ctx.m.charge_bulk(t.data.len() as u64, bytes);
        t
    }
}

/// `(1, s, heads*dh)` -> `(heads, s, dh)`.
#[derive(Debug)]
struct SplitHeadsOp {
    heads: usize,
}

impl PreparedOp for SplitHeadsOp {
    fn run(&self, ctx: &mut ExecCtx<'_>, inputs: &[&Tensor]) -> Tensor {
        let tx = inputs[0];
        let hd = self.heads;
        assert_eq!(tx.h, 1, "SplitHeads expects an unsplit (h=1) tensor");
        assert_eq!(tx.c % hd, 0, "channels not divisible by heads");
        let dh = tx.c / hd;
        let mut t = Tensor::zeros(hd, tx.w, dh);
        for s in 0..tx.w {
            for head in 0..hd {
                for c in 0..dh {
                    t.data[(head * t.w + s) * dh + c] = tx.data[s * tx.c + head * dh + c];
                }
            }
        }
        let bytes = (t.data.len() * 8) as u64;
        ctx.m.charge_bulk(t.data.len() as u64, bytes);
        t
    }
}

/// `(heads, s, dh)` -> `(1, s, heads*dh)` (inverse of SplitHeads).
#[derive(Debug)]
struct MergeHeadsOp;

impl PreparedOp for MergeHeadsOp {
    fn run(&self, ctx: &mut ExecCtx<'_>, inputs: &[&Tensor]) -> Tensor {
        let tx = inputs[0];
        let (hd, dh) = (tx.h, tx.c);
        let mut t = Tensor::zeros(1, tx.w, hd * dh);
        for s in 0..tx.w {
            for head in 0..hd {
                for c in 0..dh {
                    t.data[s * t.c + head * dh + c] = tx.data[(head * tx.w + s) * dh + c];
                }
            }
        }
        let bytes = (t.data.len() * 8) as u64;
        ctx.m.charge_bulk(t.data.len() as u64, bytes);
        t
    }
}

/// Element-wise residual add, optionally fused with ReLU.
#[derive(Debug)]
struct AddOp {
    relu: bool,
}

impl PreparedOp for AddOp {
    fn run(&self, ctx: &mut ExecCtx<'_>, inputs: &[&Tensor]) -> Tensor {
        let (ta, tb) = (inputs[0], inputs[1]);
        assert_eq!(ta.data.len(), tb.data.len());
        let mut t = ta.clone();
        for (v, w) in t.data.iter_mut().zip(&tb.data) {
            *v += w;
            if self.relu {
                *v = v.max(0.0);
            }
        }
        let bytes = (t.data.len() * 8) as u64;
        ctx.m.charge_bulk(t.data.len() as u64, bytes);
        t
    }
}

/// Channel concatenation.
#[derive(Debug)]
struct ConcatCOp;

impl PreparedOp for ConcatCOp {
    fn run(&self, _ctx: &mut ExecCtx<'_>, inputs: &[&Tensor]) -> Tensor {
        let (ta, tb) = (inputs[0], inputs[1]);
        assert_eq!((ta.h, ta.w), (tb.h, tb.w));
        let mut t = Tensor::zeros(ta.h, ta.w, ta.c + tb.c);
        for h in 0..ta.h {
            for w in 0..ta.w {
                for c in 0..ta.c {
                    t.data[(h * t.w + w) * t.c + c] = ta.at(h, w, c);
                }
                for c in 0..tb.c {
                    t.data[(h * t.w + w) * t.c + ta.c + c] = tb.at(h, w, c);
                }
            }
        }
        t
    }
}

/// Channel slice `[from, to)`.
#[derive(Debug)]
struct SliceCOp {
    from: usize,
    to: usize,
}

impl PreparedOp for SliceCOp {
    fn run(&self, _ctx: &mut ExecCtx<'_>, inputs: &[&Tensor]) -> Tensor {
        let tx = inputs[0];
        let (from, to) = (self.from, self.to);
        let mut t = Tensor::zeros(tx.h, tx.w, to - from);
        for h in 0..tx.h {
            for w in 0..tx.w {
                for c in from..to {
                    t.data[(h * t.w + w) * t.c + (c - from)] = tx.at(h, w, c);
                }
            }
        }
        t
    }
}

/// Grouped channel shuffle.
#[derive(Debug)]
struct ShuffleCOp {
    groups: usize,
}

impl PreparedOp for ShuffleCOp {
    fn run(&self, _ctx: &mut ExecCtx<'_>, inputs: &[&Tensor]) -> Tensor {
        let tx = inputs[0];
        let g = self.groups;
        let per = tx.c / g;
        let mut t = Tensor::zeros(tx.h, tx.w, tx.c);
        // NHWC shuffle: out[.., i*g + j] = in[.., j*per + i]
        for h in 0..tx.h {
            for w in 0..tx.w {
                for j in 0..g {
                    for i in 0..per {
                        t.data[(h * t.w + w) * t.c + (i * g + j)] = tx.at(h, w, j * per + i);
                    }
                }
            }
        }
        t
    }
}

/// Global average pooling.
#[derive(Debug)]
struct GapOp;

impl PreparedOp for GapOp {
    fn run(&self, ctx: &mut ExecCtx<'_>, inputs: &[&Tensor]) -> Tensor {
        let tx = inputs[0];
        let mut t = Tensor::zeros(1, 1, tx.c);
        for c in 0..tx.c {
            let mut s = 0.0f32;
            for h in 0..tx.h {
                for w in 0..tx.w {
                    s += tx.at(h, w, c);
                }
            }
            t.data[c] = s / (tx.h * tx.w) as f32;
        }
        let bytes = (tx.data.len() * 4) as u64;
        ctx.m.charge_bulk(tx.data.len() as u64, bytes);
        t
    }
}

/// A prepared graph node: the op plus its input wiring (`INPUT` = the
/// graph input tensor).
#[derive(Debug)]
pub struct PreparedNode {
    pub op: Box<dyn PreparedOp>,
    pub inputs: Vec<usize>,
}

/// A decode step graph (`m = 1` projections + [`CachedAttnOp`] nodes)
/// prepared alongside the full graph of a decoder model.
#[derive(Debug)]
pub struct StepModel {
    pub nodes: Vec<PreparedNode>,
    /// number of KV cache slots a session of this model owns (one per
    /// `CachedAttn` node, in graph order)
    pub slots: usize,
    /// tightest `max_positions` across the attention nodes: the hard
    /// per-session step limit (`usize::MAX` if the graph has none)
    pub max_positions: usize,
    /// KV-cache bytes one decode step appends across all attention
    /// nodes (packed K column + quantized and packed V, amortized) —
    /// what the server's footprint-based session placement charges a
    /// worker per submitted step
    pub kv_bytes_per_position: usize,
    /// per-slot page-geometry facts (one per `CachedAttn` node, in
    /// graph/slot order) — lets the engine and the server compute a
    /// step's exact page demand before it runs
    pub slot_geoms: Vec<SlotGeomSpec>,
}

/// A whole network prepared once: codegen plans, packed weights and mask
/// tables cached per layer. Shareable across worker threads via `Arc`.
#[derive(Debug)]
pub struct PreparedModel {
    pub nodes: Vec<PreparedNode>,
    /// decode step graph (decoder models only)
    pub step: Option<StepModel>,
}

fn prepare_nodes(nodes: &[Node]) -> (Vec<PreparedNode>, usize) {
    let mut slots = 0usize;
    let prepared = nodes
        .iter()
        .map(|n| {
            let op: Box<dyn PreparedOp> = match n {
                Node::Conv { cfg, .. } => Box::new(PreparedConv::prepare(cfg)),
                Node::Matmul { cfg, weights, .. } => {
                    Box::new(PreparedMatmul::prepare_static(cfg, weights))
                }
                Node::MatmulDyn { cfg, transpose_b, .. } => {
                    if cfg.causal && !*transpose_b {
                        // causal A·V: per-row growing contraction — the
                        // one-shot twin of the KV-cached decode step
                        Box::new(CausalAvOp::prepare(cfg))
                    } else {
                        Box::new(PreparedMatmul::prepare_dyn(cfg, *transpose_b))
                    }
                }
                Node::CachedAttn { cfg, .. } => {
                    let op = CachedAttnOp::prepare(cfg, slots);
                    slots += 1;
                    Box::new(op)
                }
                Node::Softmax { .. } => Box::new(SoftmaxOp),
                Node::LayerNorm { gamma, beta, .. } => {
                    Box::new(LayerNormOp { gamma: gamma.clone(), beta: beta.clone() })
                }
                Node::Gelu { .. } => Box::new(GeluOp),
                Node::TransposeHW { .. } => Box::new(TransposeHWOp),
                Node::SplitHeads { heads, .. } => Box::new(SplitHeadsOp { heads: *heads }),
                Node::MergeHeads { .. } => Box::new(MergeHeadsOp),
                Node::Add { relu, .. } => Box::new(AddOp { relu: *relu }),
                Node::ConcatC { .. } => Box::new(ConcatCOp),
                Node::SliceC { from, to, .. } => Box::new(SliceCOp { from: *from, to: *to }),
                Node::ShuffleC { groups, .. } => Box::new(ShuffleCOp { groups: *groups }),
                Node::Gap { .. } => Box::new(GapOp),
            };
            // input wiring comes from the shared Node::inputs so the
            // executor and the shard planner read one dataflow graph
            PreparedNode { op, inputs: n.inputs() }
        })
        .collect();
    (prepared, slots)
}

impl PreparedModel {
    /// Prepare every layer of a graph exactly once.
    pub fn prepare(nodes: &[Node]) -> PreparedModel {
        let (nodes, _) = prepare_nodes(nodes);
        let model = PreparedModel { nodes, step: None };
        // debug builds statically verify every cached kernel at
        // prepare time, so an emitter defect fails the first test that
        // prepares a model (release serving verifies on --verify only)
        #[cfg(debug_assertions)]
        crate::analysis::debug_verify("prepare", &model);
        model
    }

    /// Prepare a decoder: the full (one-shot / prefill) graph plus its
    /// per-token decode step graph, which sessions execute via
    /// [`EngineMachine::run_step`].
    pub fn prepare_decoder(nodes: &[Node], step_nodes: &[Node]) -> PreparedModel {
        let (nodes, _) = prepare_nodes(nodes);
        let (step_prepared, slots) = prepare_nodes(step_nodes);
        let max_positions = step_nodes
            .iter()
            .filter_map(|n| match n {
                Node::CachedAttn { cfg, .. } => Some(cfg.max_positions),
                _ => None,
            })
            .min()
            .unwrap_or(usize::MAX);
        let kv_bytes_per_position = step_nodes
            .iter()
            .map(|n| match n {
                Node::CachedAttn { cfg, .. } => {
                    let cap = Pattern::uniform(cfg.pos_prec).capacity() as usize;
                    let nch_dh = cfg
                        .dh_asg
                        .chunks
                        .iter()
                        .zip(cfg.dh_asg.valid.iter())
                        .filter(|&(_, &v)| v > 0)
                        .count();
                    // per appended position, per head: one packed K
                    // column, dh quantized V values, and the packed V
                    // columns' amortized growth (16 B per cap positions)
                    cfg.heads * (nch_dh * 16 + cfg.dh * 4 + cfg.dh * 16 / cap.max(1))
                }
                _ => 0,
            })
            .sum();
        let slot_geoms = step_nodes
            .iter()
            .filter_map(|n| match n {
                Node::CachedAttn { cfg, .. } => {
                    let nch_dh = cfg
                        .dh_asg
                        .chunks
                        .iter()
                        .zip(cfg.dh_asg.valid.iter())
                        .filter(|&(_, &v)| v > 0)
                        .count();
                    Some(SlotGeomSpec {
                        heads: cfg.heads,
                        dh: cfg.dh,
                        nch_dh,
                        pos_prec: cfg.pos_prec,
                    })
                }
                _ => None,
            })
            .collect();
        let model = PreparedModel {
            nodes,
            step: Some(StepModel {
                nodes: step_prepared,
                slots,
                max_positions,
                kv_bytes_per_position,
                slot_geoms,
            }),
        };
        #[cfg(debug_assertions)]
        crate::analysis::debug_verify("prepare_decoder", &model);
        model
    }

    /// Number of prepared kernels (conv/FC layers, GEMMs and cached
    /// attention nodes) in the full graph.
    pub fn num_layers(&self) -> usize {
        self.nodes.iter().filter(|n| n.op.name().is_some()).count()
    }

    /// Machine buffer bytes binding this model allocates (full + step
    /// graphs) — what a budget-capped worker must have free to host it,
    /// and what capacity-driven LRU eviction makes room for.
    pub fn bind_bytes(&self) -> usize {
        let step = self.step.iter().flat_map(|s| s.nodes.iter());
        self.nodes.iter().chain(step).map(|n| n.op.bind_bytes()).sum()
    }
}

fn node_input<'a>(outputs: &'a [Tensor], input: &'a Tensor, id: usize) -> &'a Tensor {
    if id == INPUT {
        input
    } else {
        &outputs[id]
    }
}

/// Walk a prepared graph: resolve each node's inputs, dispatch through
/// [`PreparedOp::run`], and collect per-node machine stats. The single
/// execution loop behind one-shot inference, serving and decode steps.
fn run_graph(
    nodes: &[PreparedNode],
    bound: &[Option<BoundKernel>],
    m: &mut Machine,
    scratch: &mut WorkerScratch,
    mut session: Option<&mut SessionState>,
    mut kv: Option<&mut KvPool>,
    input: &Tensor,
) -> NetResult {
    let mut outputs: Vec<Tensor> = Vec::with_capacity(nodes.len());
    let mut layers = Vec::new();
    let mut total = RunStats::default();
    for (ni, node) in nodes.iter().enumerate() {
        let inputs: Vec<&Tensor> =
            node.inputs.iter().map(|&id| node_input(&outputs, input, id)).collect();
        let mut ctx = ExecCtx {
            m: &mut *m,
            bound: bound[ni].as_ref(),
            scratch: &mut *scratch,
            session: session.as_deref_mut(),
            kv: kv.as_deref_mut(),
        };
        let out = node.op.run(&mut ctx, &inputs);
        drop(inputs);
        let stats = m.take_stats();
        total.merge(&stats);
        if let Some(name) = node.op.name() {
            layers.push(LayerStat { name: name.to_string(), shard: None, stats });
        }
        outputs.push(out);
    }
    NetResult { output: outputs.pop().unwrap(), layers, total }
}

/// One resident model on a worker machine: the per-node bind tables of
/// its full and step graphs, plus the LRU stamp eviction orders by.
#[derive(Debug)]
struct ResidentModel {
    model: Arc<PreparedModel>,
    bound: Vec<Option<BoundKernel>>,
    step_bound: Vec<Option<BoundKernel>>,
    last_used: u64,
}

/// One decode session's state plus the model it belongs to — a session
/// id is meaningful only within its model, and a step that addresses it
/// through a different model's handle is a caller bug (the KV slot
/// layout would not match), caught by assertion.
#[derive(Debug)]
struct SessionEntry {
    key: Arc<ModelKey>,
    state: SessionState,
    /// engine tick of the session's most recent step — the coldness
    /// order budget-pressure eviction/spill picks victims by
    last_step: u64,
    /// pages currently parked in the pool's overflow arena (faulted
    /// back before the session's next step)
    spilled: bool,
}

/// Monotone bind-table churn totals an [`EngineMachine`] accumulates
/// over its lifetime (reads are free; see
/// [`counters`](EngineMachine::counters)).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineCounters {
    /// Cold binds: a model made resident (first touch or re-bind after
    /// eviction). LRU hits don't count.
    pub binds: u64,
    /// Resident models evicted to satisfy a count or byte budget.
    pub evictions: u64,
}

/// One bind-table state change, recorded only when event recording is
/// on ([`set_record_events`](EngineMachine::set_record_events)) —
/// drained by the observability layer for trace export.
#[derive(Debug, Clone)]
pub enum EngineEvent {
    /// `key` was made resident (cold bind or re-bind).
    Bound(ModelKey),
    /// `key` was evicted to make room.
    Evicted(ModelKey),
}

/// One worker's execution context: a simulated machine serving one or
/// more prepared models. Each model gets a per-model bind table
/// (buffers + resident weights), populated lazily on the first request
/// that addresses it and evicted LRU once more than `budget` models are
/// resident — plus the KV-cache state of every decode session pinned to
/// this worker.
///
/// Session KV caches live in host-side [`SessionState`], *not* in the
/// evictable machine buffers: evicting and later rebinding a model
/// never loses an open session's cache (the attention ops re-write the
/// resident operand buffers from the session state on every step).
pub struct EngineMachine {
    m: Machine,
    scratch: WorkerScratch,
    resident: HashMap<ModelKey, ResidentModel>,
    /// monotone use counter driving LRU eviction
    tick: u64,
    /// max resident models before the least-recently-used is evicted
    budget: usize,
    /// the model `run`/`run_step` address (single-model compatibility)
    default_model: Option<ModelHandle>,
    sessions: HashMap<u64, SessionEntry>,
    /// paged KV-cache pool; `None` keeps sessions on the legacy
    /// growable-vec storage
    kv_pool: Option<KvPool>,
    counters: EngineCounters,
    /// bind/evict events since the last `take_events` (only filled
    /// when `record_events` is on)
    events: Vec<EngineEvent>,
    record_events: bool,
}

impl EngineMachine {
    /// A machine with no resident models yet: models bind lazily via
    /// [`run_model`](Self::run_model) / [`bind_model`](Self::bind_model)
    /// and at most `budget` stay resident (LRU-evicted beyond that).
    pub fn with_budget(budget: usize) -> EngineMachine {
        EngineMachine::with_limits(budget, None)
    }

    /// [`with_budget`](Self::with_budget) plus a machine buffer budget
    /// in bytes: binding a model whose buffers do not fit panics (see
    /// [`Machine::with_capacity`]) — a shard-scoped deployment
    /// ([`crate::serve::Deployment`]) is how an over-wide model serves
    /// on budgeted workers.
    pub fn with_limits(budget: usize, buffer_bytes: Option<usize>) -> EngineMachine {
        EngineMachine {
            m: match buffer_bytes {
                Some(b) => Machine::with_capacity(b),
                None => Machine::new(),
            },
            scratch: WorkerScratch::default(),
            resident: HashMap::new(),
            tick: 0,
            budget: budget.max(1),
            default_model: None,
            sessions: HashMap::new(),
            kv_pool: None,
            counters: EngineCounters::default(),
            events: Vec::new(),
            record_events: false,
        }
    }

    /// Bind one prepared model to a fresh simulated machine (the
    /// single-model worker of [`crate::serve::Server::start`] and the
    /// one-shot `run_network` path): buffers allocated and weights/masks
    /// written exactly once, for the full graph and — on decoders — the
    /// step graph. [`run`](Self::run) / [`run_step`](Self::run_step)
    /// address this model; the budget is unlimited.
    pub fn new(model: &Arc<PreparedModel>) -> EngineMachine {
        let mut engine = EngineMachine::with_budget(usize::MAX);
        let handle = ModelHandle::new(ModelKey::new("default", "default"), Arc::clone(model));
        engine.bind_model(&handle);
        engine.default_model = Some(handle);
        engine
    }

    /// Make `handle`'s model resident: allocate its buffers and write
    /// its weights/masks (full + step graph) unless already bound, and
    /// stamp it most-recently-used. Evicts LRU models first if the
    /// resident-count budget — or, on a buffer-capacity machine, the
    /// byte budget — would be exceeded; only a model that does not fit
    /// an *empty* machine still panics the capacity assert.
    pub fn bind_model(&mut self, handle: &ModelHandle) {
        self.tick += 1;
        let tick = self.tick;
        if let Some(r) = self.resident.get_mut(&*handle.key) {
            r.last_used = tick;
            return;
        }
        while self.resident.len() >= self.budget {
            match self.lru_key() {
                Some(k) => self.evict_model(&k),
                None => break,
            }
        }
        if let Some(cap) = self.m.capacity() {
            let need = handle.prepared.bind_bytes();
            while self.m.resident_bytes() + need > cap {
                match self.lru_key() {
                    Some(k) => self.evict_model(&k),
                    None => break, // nothing left to evict: alloc enforces
                }
            }
        }
        let bound: Vec<Option<BoundKernel>> =
            handle.prepared.nodes.iter().map(|n| n.op.bind(&mut self.m)).collect();
        let step_bound: Vec<Option<BoundKernel>> = match &handle.prepared.step {
            Some(step) => step.nodes.iter().map(|n| n.op.bind(&mut self.m)).collect(),
            None => Vec::new(),
        };
        self.counters.binds += 1;
        if self.record_events {
            self.events.push(EngineEvent::Bound((*handle.key).clone()));
        }
        self.resident.insert(
            (*handle.key).clone(),
            ResidentModel {
                model: Arc::clone(&handle.prepared),
                bound,
                step_bound,
                last_used: tick,
            },
        );
    }

    /// Key of the least-recently-used resident model, if any.
    fn lru_key(&self) -> Option<ModelKey> {
        self.resident.iter().min_by_key(|(_, r)| r.last_used).map(|(k, _)| k.clone())
    }

    /// Unbind a resident model, freeing every machine buffer its bind
    /// tables own (no-op for a non-resident key). Open sessions of the
    /// model survive: their KV caches are host-side state, and the next
    /// step rebinds the model from its request's handle.
    pub fn evict_model(&mut self, key: &ModelKey) {
        if let Some(r) = self.resident.remove(key) {
            for b in r.bound.iter().chain(r.step_bound.iter()).flatten() {
                self.m.free(b.bufs.input);
                self.m.free(b.bufs.weights);
                self.m.free(b.bufs.out);
                self.m.free(b.bufs.masks);
            }
            self.counters.evictions += 1;
            if self.record_events {
                self.events.push(EngineEvent::Evicted(key.clone()));
            }
        }
    }

    /// Run one inference over `handle`'s prepared full graph, binding
    /// the model first if it is not resident.
    pub fn run_model(&mut self, handle: &ModelHandle, input: &Tensor) -> NetResult {
        self.bind_model(handle);
        let r = self.resident.get(&*handle.key).expect("model resident after bind");
        run_graph(&r.model.nodes, &r.bound, &mut self.m, &mut self.scratch, None, None, input)
    }

    /// Budget policy for one upcoming decode step of `session`: count
    /// the step's exact page demand (one page per slot crossing a page
    /// boundary, plus this session's parked pages if it was spilled),
    /// then evict or spill the coldest *other* sessions until it fits —
    /// so [`KvPool::alloc`] stays infallible during the step. Under
    /// [`KvPolicy::Refuse`] the server's admission gate is the
    /// enforcement point and the engine never blocks; if nothing is
    /// left to reclaim the pool overcommits (gauges report the truth)
    /// rather than deadlocking a session larger than the whole budget.
    fn ensure_kv_capacity(&mut self, handle: &ModelHandle, session: u64) {
        let Some(pool) = self.kv_pool.as_mut() else { return };
        let Some(step) = handle.prepared.step.as_ref() else { return };
        let cfg = *pool.cfg();
        let scfg = cfg.session_cfg();
        let mut needed = pool.parked_pages(session);
        let lens: Vec<usize> = match self.sessions.get(&session) {
            Some(e) => e.state.slots.iter().map(|s| s.len).collect(),
            None => vec![0; step.slot_geoms.len()],
        };
        for (len, sg) in lens.iter().zip(step.slot_geoms.iter()) {
            if len % sg.page_geom(&scfg).page_positions == 0 {
                needed += 1;
            }
        }
        if matches!(cfg.policy, KvPolicy::Evict | KvPolicy::Spill) {
            while pool.would_exceed(needed) {
                let victim = self
                    .sessions
                    .iter()
                    .filter(|&(&id, e)| id != session && !e.spilled && e.state.pages() > 0)
                    .min_by_key(|&(&id, e)| (e.last_step, id))
                    .map(|(&id, _)| id);
                let Some(vid) = victim else { break };
                if cfg.policy == KvPolicy::Evict {
                    let mut e = self.sessions.remove(&vid).expect("victim resident");
                    e.state.release_into(pool);
                    pool.note_eviction();
                } else {
                    let e = self.sessions.get_mut(&vid).expect("victim resident");
                    pool.park(vid, e.state.take_all_pages());
                    e.spilled = true;
                }
            }
        }
        // fault this session's spilled pages back in (room was made
        // above; unbudgeted overcommit if it wasn't)
        if let Some(e) = self.sessions.get_mut(&session) {
            if e.spilled {
                let pages = pool.unpark(session).expect("spilled session has parked pages");
                e.state.restore_all_pages(pages);
                e.spilled = false;
            }
        }
    }

    /// Run one autoregressive decode step of `handle`'s model for
    /// `session`: the step graph executes against the session's KV
    /// caches, which grow by exactly one position. A new session id
    /// starts an empty session (paged when a KV pool is attached).
    pub fn run_step_model(
        &mut self,
        handle: &ModelHandle,
        session: u64,
        token: &Tensor,
    ) -> NetResult {
        self.bind_model(handle);
        if self.kv_pool.is_some() {
            self.ensure_kv_capacity(handle, session);
        }
        let r = self.resident.get(&*handle.key).expect("model resident after bind");
        let step = r.model.step.as_ref().expect("model has no decode step graph");
        let kv_cfg = self.kv_pool.as_ref().map(|p| p.cfg().session_cfg());
        let tick = self.tick;
        let entry = self.sessions.entry(session).or_insert_with(|| SessionEntry {
            key: Arc::clone(&handle.key),
            state: match kv_cfg {
                Some(cfg) => SessionState::new_paged(step.slots, cfg),
                None => SessionState::new(step.slots),
            },
            last_step: tick,
            spilled: false,
        });
        assert_eq!(
            *entry.key, *handle.key,
            "session {session} belongs to model {}, not {} (end it before reusing the id)",
            entry.key, handle.key
        );
        entry.last_step = tick;
        let state = &mut entry.state;
        run_graph(
            &step.nodes,
            &r.step_bound,
            &mut self.m,
            &mut self.scratch,
            Some(state),
            self.kv_pool.as_mut(),
            token,
        )
    }

    /// Run one inference against the default model (the one this engine
    /// was [`new`](Self::new)'d with).
    pub fn run(&mut self, input: &Tensor) -> NetResult {
        let handle = self.default_model.clone().expect("engine has no default model");
        self.run_model(&handle, input)
    }

    /// Run one decode step against the default model.
    pub fn run_step(&mut self, session: u64, token: &Tensor) -> NetResult {
        let handle = self.default_model.clone().expect("engine has no default model");
        self.run_step_model(&handle, session, token)
    }

    /// Free a session's KV caches (no-op for an unknown id): paged
    /// sessions return every resident page to the pool's free list
    /// (spilled pages drop from the arena). A later `run_step` with
    /// the same id starts a fresh, empty session.
    pub fn end_session(&mut self, session: u64) {
        if let Some(mut e) = self.sessions.remove(&session) {
            if let Some(pool) = self.kv_pool.as_mut() {
                if e.spilled {
                    pool.drop_parked(session);
                }
                e.state.release_into(pool);
            }
        }
    }

    /// Attach a paged KV pool: sessions started after this store their
    /// caches as fixed-size pages under the pool's budget and policy.
    /// Call before any session opens (existing growable sessions keep
    /// their storage and are invisible to the pool's accounting).
    pub fn set_kv_pool(&mut self, cfg: KvPoolCfg) {
        self.kv_pool = Some(KvPool::new(cfg));
    }

    /// Occupancy and lifetime counters of the paged KV pool (`None`
    /// when this engine runs legacy growable sessions).
    pub fn kv_pool_stats(&self) -> Option<KvPoolStats> {
        self.kv_pool.as_ref().map(KvPool::stats)
    }

    /// Number of decode sessions resident on this worker.
    pub fn num_sessions(&self) -> usize {
        self.sessions.len()
    }

    /// Number of models currently bound to this machine.
    pub fn num_resident(&self) -> usize {
        self.resident.len()
    }

    /// Actual bytes held by the KV caches of this worker's sessions
    /// (what the server-side placement estimate approximates).
    pub fn session_kv_bytes(&self) -> usize {
        self.sessions.values().map(|e| e.state.kv_bytes()).sum()
    }

    /// Lifetime bind/eviction totals (cheap copy).
    pub fn counters(&self) -> EngineCounters {
        self.counters
    }

    /// Machine buffer bytes currently held by resident bind tables.
    pub fn resident_bytes(&self) -> usize {
        self.m.resident_bytes()
    }

    /// Turn per-event recording on/off (off by default — counters are
    /// always maintained, events cost an allocation each).
    pub fn set_record_events(&mut self, on: bool) {
        self.record_events = on;
    }

    /// Drain the bind/evict events recorded since the last call.
    pub fn take_events(&mut self) -> Vec<EngineEvent> {
        std::mem::take(&mut self.events)
    }
}
