//! Prepare-once execution engine (refactored out of `sim::network`).
//!
//! The legacy path re-quantized and re-packed every layer's weights, re-
//! emitted the Algorithm-4 kernel and re-allocated machine buffers on
//! *every* inference. Serving amortizes all of that: [`prepare_conv`]
//! runs codegen + weight/mask packing exactly once per layer, and
//! [`EngineMachine`] binds the prepared layers to per-worker machine
//! buffers exactly once, so a request only pays for activation packing,
//! kernel replay and the epilogue. Outputs are bit-identical to the
//! legacy path (`sim::network::run_conv` / `run_network` are now thin
//! wrappers over this module).

use crate::codegen::{self, pack, LayerBufs, LayerKind, LayerPlan};
use crate::sim::machine::{Machine, RunStats};
use crate::sim::network::{ConvLayerCfg, LayerStat, NetResult, Node, Tensor, INPUT};
use crate::simd::isa::{Addr, BufId, Instr};
use crate::simd::patterns::Pattern;
use crate::smol::quant;
use std::sync::Arc;

/// One conv/FC layer with everything per-request work does NOT need to
/// recompute: the emitted kernel, SMOL-packed weights, tail masks, the
/// pattern table and the epilogue parameters.
#[derive(Debug, Clone)]
pub struct PreparedConv {
    pub plan: LayerPlan,
    bn_scale: Vec<f32>,
    bn_bias: Vec<f32>,
    bn_mean: Vec<f32>,
    bn_var: Vec<f32>,
    relu: bool,
    /// Algorithm-4 kernel emitted against the symbolic buffer ids
    /// 0=input, 1=weights, 2=out, 3=masks (retargeted at bind time).
    program: Vec<Instr>,
    /// the layer's chunk patterns (machine table base 0, as emitted)
    patterns: Vec<Pattern>,
    packed_weights: Vec<u8>,
    packed_masks: Vec<u8>,
    act_bytes: usize,
    out_bytes: usize,
    out_elems: usize,
}

/// A prepared layer bound to concrete buffers of one [`Machine`]:
/// weights + masks are written once; input/out act as reusable scratch.
#[derive(Debug, Clone)]
pub struct BoundConv {
    bufs: LayerBufs,
    program: Vec<Instr>,
}

/// Buffer sizing shared by the prepared and streaming paths:
/// (packed-activation bytes, output elements, output-buffer bytes).
fn layer_sizes(plan: &LayerPlan) -> (usize, usize, usize) {
    let (hout, wout) = (plan.hout(), plan.wout());
    let n_chunks = plan.chunks().len();
    let act_bytes = plan.hin * plan.win * n_chunks * 16;
    let out_elems = match plan.kind {
        LayerKind::Dense => plan.cout * hout * wout,
        LayerKind::Depthwise => plan.cin * hout * wout,
    };
    // baseline depthwise stores whole 16B chunk vectors per position,
    // which can exceed cin*4 bytes when cin is not a multiple of the
    // lane capacity — size the buffer for both layouts
    let out_bytes = (out_elems * 4).max(hout * wout * n_chunks * 16);
    (act_bytes, out_elems, out_bytes)
}

/// Run codegen + weight/mask packing for one layer (the prepare-once
/// half of what `run_conv` used to do per call).
pub fn prepare_conv(cfg: &ConvLayerCfg) -> PreparedConv {
    let plan = cfg.plan.clone();
    let (act_bytes, out_elems, out_bytes) = layer_sizes(&plan);

    let packed_weights = pack::pack_weights(&plan, &cfg.weights);
    let packed_masks = pack::pack_masks(&plan);

    let mut patterns = Vec::new();
    let base = codegen::register_patterns(&plan, &mut patterns);
    let symbolic = LayerBufs {
        input: BufId(0),
        weights: BufId(1),
        out: BufId(2),
        masks: BufId(3),
    };
    let mut program = Vec::new();
    codegen::emit_layer(&plan, &symbolic, base, &mut program);

    PreparedConv {
        plan,
        bn_scale: cfg.bn_scale.clone(),
        bn_bias: cfg.bn_bias.clone(),
        bn_mean: cfg.bn_mean.clone(),
        bn_var: cfg.bn_var.clone(),
        relu: cfg.relu,
        program,
        patterns,
        packed_weights,
        packed_masks,
        act_bytes,
        out_bytes,
        out_elems,
    }
}

impl PreparedConv {
    /// Allocate this layer's buffers on `m` (same order and sizes as the
    /// legacy per-call path: input, weights, out, masks), write the
    /// cached weights + masks once, and retarget the kernel to the
    /// allocated buffer ids.
    pub fn bind(&self, m: &mut Machine) -> BoundConv {
        let bufs = LayerBufs {
            input: m.alloc(self.act_bytes),
            weights: m.alloc(self.packed_weights.len()),
            out: m.alloc(self.out_bytes),
            masks: m.alloc(self.packed_masks.len()),
        };
        m.write_bytes(bufs.weights, 0, &self.packed_weights);
        m.write_bytes(bufs.masks, 0, &self.packed_masks);
        let program = retarget(&self.program, &bufs);
        BoundConv { bufs, program }
    }
}

/// Rewrite the symbolic buffer ids of a prepared kernel to the buffers a
/// machine actually allocated.
fn retarget(prog: &[Instr], bufs: &LayerBufs) -> Vec<Instr> {
    let map = |a: Addr| -> Addr {
        let buf = match a.buf.0 {
            0 => bufs.input,
            1 => bufs.weights,
            2 => bufs.out,
            3 => bufs.masks,
            _ => a.buf,
        };
        Addr { buf, off: a.off }
    };
    prog.iter()
        .map(|i| match *i {
            Instr::LdQ { dst, addr } => Instr::LdQ { dst, addr: map(addr) },
            Instr::StQ { src, addr } => Instr::StQ { src, addr: map(addr) },
            Instr::ReduceAcc { src, addr } => Instr::ReduceAcc { src, addr: map(addr) },
            Instr::MulAcc { lo, hi, pat, addr, n_valid } => {
                Instr::MulAcc { lo, hi, pat, addr: map(addr), n_valid }
            }
            other => other,
        })
        .collect()
}

/// Number of in-bounds taps for output position (h, w).
pub(crate) fn valid_taps(plan: &LayerPlan, h: usize, w: usize) -> usize {
    let (pt, pl) = (plan.pad_top(), plan.pad_left());
    let mut n = 0;
    for r in 0..plan.kh {
        for s in 0..plan.kw {
            let ih = h as isize * plan.stride as isize + r as isize - pt;
            let iw = w as isize * plan.stride as isize + s as isize - pl;
            if ih >= 0 && iw >= 0 && ih < plan.hin as isize && iw < plan.win as isize {
                n += 1;
            }
        }
    }
    n
}

/// Per-request input staging, shared by both execution paths: pack the
/// activations into the input buffer, zero the accumulator scratch and
/// charge the quantize/rearrange/pack pass as streaming cache traffic.
fn stage_input(m: &mut Machine, plan: &LayerPlan, bufs: &LayerBufs, x: &Tensor) {
    assert_eq!(x.c, plan.cin, "{}: cin mismatch", plan.name);
    assert_eq!((x.h, x.w), (plan.hin, plan.win), "{}: spatial mismatch", plan.name);
    let act = pack::pack_activations(plan, &x.data);
    m.write_bytes(bufs.input, 0, &act);
    m.clear_buffer(bufs.out);
    m.stream_touch(bufs.input, act.len(), true);
    m.charge_bulk(x.data.len() as u64, 0);
}

/// Epilogue shared by both execution paths: accumulators -> f32 with
/// tail-bias correction, BN, ReLU, output traffic charge; returns the
/// layer output and this layer's run statistics.
#[allow(clippy::too_many_arguments)]
fn finish_layer(
    m: &mut Machine,
    plan: &LayerPlan,
    bn: (&[f32], &[f32], &[f32], &[f32]),
    relu: bool,
    bufs: &LayerBufs,
    out_elems: usize,
) -> (Tensor, RunStats) {
    let (bn_scale, bn_bias, bn_mean, bn_var) = bn;
    let (hout, wout) = (plan.hout(), plan.wout());
    let bias = plan.tail_bias();
    let mut out = match plan.kind {
        LayerKind::Dense => {
            let mut t = Tensor::zeros(hout, wout, plan.cout);
            for k in 0..plan.cout {
                for h in 0..hout {
                    for w in 0..wout {
                        let acc = m.read_i32(bufs.out, ((k * hout + h) * wout + w) * 4);
                        let taps = valid_taps(plan, h, w) as i64;
                        let v = (acc as i64 - bias * taps) as f32 / quant::ACC_SCALE;
                        t.data[(h * wout + w) * plan.cout + k] = v;
                    }
                }
            }
            t
        }
        LayerKind::Depthwise => {
            // depthwise MulAcc wrote in *packed* channel order; un-permute
            let mut t = Tensor::zeros(hout, wout, plan.cin);
            for h in 0..hout {
                for w in 0..wout {
                    for (pos, &ch) in plan.asg.order.iter().enumerate() {
                        let acc = m.read_i32(bufs.out, ((h * wout + w) * plan.cin + pos) * 4);
                        t.data[(h * wout + w) * plan.cin + ch as usize] =
                            acc as f32 / quant::ACC_SCALE;
                    }
                }
            }
            t
        }
    };

    // BN + ReLU epilogue (f32, vectorized in hardware; bulk-costed)
    if !bn_scale.is_empty() {
        let cch = out.c;
        for i in 0..out.data.len() {
            let k = i % cch;
            let inv = 1.0 / (bn_var[k] + 1e-5).sqrt();
            out.data[i] = (out.data[i] - bn_mean[k]) * inv * bn_scale[k] + bn_bias[k];
        }
    }
    if relu {
        for v in out.data.iter_mut() {
            *v = v.max(0.0);
        }
    }
    m.stream_touch(bufs.out, out_elems * 4, false);
    m.charge_bulk(out.data.len() as u64, (out.data.len() * 4) as u64);

    (out, m.take_stats())
}

/// Execute one bound layer: pack + write the activations, replay the
/// cached kernel, run the epilogue. This is the per-request half of the
/// legacy `run_conv` — weight packing and codegen are gone from it.
pub fn run_bound(
    m: &mut Machine,
    prep: &PreparedConv,
    bound: &BoundConv,
    x: &Tensor,
) -> (Tensor, RunStats) {
    let plan = &prep.plan;
    stage_input(m, plan, &bound.bufs, x);

    // replay the cached Algorithm-4 kernel under the layer's patterns
    m.patterns.clear();
    m.patterns.extend_from_slice(&prep.patterns);
    m.run(&bound.program);

    let bn = (
        prep.bn_scale.as_slice(),
        prep.bn_bias.as_slice(),
        prep.bn_mean.as_slice(),
        prep.bn_var.as_slice(),
    );
    finish_layer(m, plan, bn, prep.relu, &bound.bufs, prep.out_elems)
}

/// One-shot streaming execution (the legacy `run_conv` shape): pack
/// weights, allocate fresh buffers and emit the kernel *directly into
/// the executing machine*, so no instruction stream is ever
/// materialized. Keeps single-call memory O(1) for paper-scale layers;
/// repeated inference should use [`prepare_conv`] + [`run_bound`]
/// instead. Staging and epilogue are shared with the prepared path, so
/// outputs are bit-identical between the two.
pub fn run_conv_streaming(m: &mut Machine, cfg: &ConvLayerCfg, x: &Tensor) -> (Tensor, RunStats) {
    let plan = &cfg.plan;
    let (act_bytes, out_elems, out_bytes) = layer_sizes(plan);
    let wts = pack::pack_weights(plan, &cfg.weights);
    let msk = pack::pack_masks(plan);
    let bufs = LayerBufs {
        input: m.alloc(act_bytes),
        weights: m.alloc(wts.len()),
        out: m.alloc(out_bytes),
        masks: m.alloc(msk.len()),
    };
    m.write_bytes(bufs.weights, 0, &wts);
    m.write_bytes(bufs.masks, 0, &msk);
    stage_input(m, plan, &bufs, x);

    // generate + execute the Algorithm-4 kernel (Machine is the Sink)
    m.patterns.clear();
    let base = codegen::register_patterns(plan, &mut m.patterns);
    codegen::emit_layer(plan, &bufs, base, m);

    let bn = (
        cfg.bn_scale.as_slice(),
        cfg.bn_bias.as_slice(),
        cfg.bn_mean.as_slice(),
        cfg.bn_var.as_slice(),
    );
    finish_layer(m, plan, bn, cfg.relu, &bufs, out_elems)
}

/// A prepared network node (conv layers carry their prepared form).
#[derive(Debug, Clone)]
pub enum PreparedNode {
    Conv { prep: PreparedConv, input: usize },
    Add { a: usize, b: usize, relu: bool },
    ConcatC { a: usize, b: usize },
    SliceC { x: usize, from: usize, to: usize },
    ShuffleC { x: usize, groups: usize },
    Gap { x: usize },
}

/// A whole network prepared once: codegen plans, packed weights and mask
/// tables cached per layer. Shareable across worker threads via `Arc`.
#[derive(Debug, Clone)]
pub struct PreparedModel {
    pub nodes: Vec<PreparedNode>,
}

impl PreparedModel {
    /// Prepare every conv/FC layer of a graph exactly once.
    pub fn prepare(nodes: &[Node]) -> PreparedModel {
        let nodes = nodes
            .iter()
            .map(|n| match n {
                Node::Conv { cfg, input } => {
                    PreparedNode::Conv { prep: prepare_conv(cfg), input: *input }
                }
                Node::Add { a, b, relu } => PreparedNode::Add { a: *a, b: *b, relu: *relu },
                Node::ConcatC { a, b } => PreparedNode::ConcatC { a: *a, b: *b },
                Node::SliceC { x, from, to } => {
                    PreparedNode::SliceC { x: *x, from: *from, to: *to }
                }
                Node::ShuffleC { x, groups } => {
                    PreparedNode::ShuffleC { x: *x, groups: *groups }
                }
                Node::Gap { x } => PreparedNode::Gap { x: *x },
            })
            .collect();
        PreparedModel { nodes }
    }

    /// Number of prepared conv/FC layers.
    pub fn num_layers(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n, PreparedNode::Conv { .. }))
            .count()
    }
}

/// One worker's execution context: a simulated machine with every layer's
/// weights resident, reused across all requests the worker serves.
pub struct EngineMachine {
    model: Arc<PreparedModel>,
    m: Machine,
    bound: Vec<Option<BoundConv>>,
}

fn node_input<'a>(outputs: &'a [Tensor], input: &'a Tensor, id: usize) -> &'a Tensor {
    if id == INPUT {
        input
    } else {
        &outputs[id]
    }
}

impl EngineMachine {
    /// Bind a prepared model to a fresh simulated machine (one per
    /// worker): buffers allocated and weights/masks written exactly once.
    pub fn new(model: &Arc<PreparedModel>) -> EngineMachine {
        let mut m = Machine::new();
        let bound: Vec<Option<BoundConv>> = model
            .nodes
            .iter()
            .map(|n| match n {
                PreparedNode::Conv { prep, .. } => Some(prep.bind(&mut m)),
                _ => None,
            })
            .collect();
        EngineMachine { model: Arc::clone(model), m, bound }
    }

    /// Run one inference over the prepared graph. Functionally identical
    /// to the legacy `run_network`, minus the per-call weight packing,
    /// codegen and buffer allocation.
    pub fn run(&mut self, input: &Tensor) -> NetResult {
        let model = Arc::clone(&self.model);
        let mut outputs: Vec<Tensor> = Vec::with_capacity(model.nodes.len());
        let mut layers = Vec::new();
        let mut total = RunStats::default();
        for (ni, node) in model.nodes.iter().enumerate() {
            let out = match node {
                PreparedNode::Conv { prep, input: id } => {
                    let x = node_input(&outputs, input, *id);
                    let bound = self.bound[ni].as_ref().expect("conv layer bound");
                    let (t, stats) = run_bound(&mut self.m, prep, bound, x);
                    total.merge(&stats);
                    layers.push(LayerStat { name: prep.plan.name.clone(), stats });
                    t
                }
                PreparedNode::Add { a, b, relu } => {
                    let ta = node_input(&outputs, input, *a);
                    let tb = node_input(&outputs, input, *b);
                    assert_eq!(ta.data.len(), tb.data.len());
                    let mut t = ta.clone();
                    for (v, w) in t.data.iter_mut().zip(&tb.data) {
                        *v += w;
                        if *relu {
                            *v = v.max(0.0);
                        }
                    }
                    let bytes = (t.data.len() * 8) as u64;
                    total.add_bulk(t.data.len() as u64, bytes, &self.m.energy_cfg);
                    t
                }
                PreparedNode::ConcatC { a, b } => {
                    let ta = node_input(&outputs, input, *a);
                    let tb = node_input(&outputs, input, *b);
                    assert_eq!((ta.h, ta.w), (tb.h, tb.w));
                    let mut t = Tensor::zeros(ta.h, ta.w, ta.c + tb.c);
                    for h in 0..ta.h {
                        for w in 0..ta.w {
                            for c in 0..ta.c {
                                t.data[(h * t.w + w) * t.c + c] = ta.at(h, w, c);
                            }
                            for c in 0..tb.c {
                                t.data[(h * t.w + w) * t.c + ta.c + c] = tb.at(h, w, c);
                            }
                        }
                    }
                    t
                }
                PreparedNode::SliceC { x, from, to } => {
                    let tx = node_input(&outputs, input, *x);
                    let mut t = Tensor::zeros(tx.h, tx.w, to - from);
                    for h in 0..tx.h {
                        for w in 0..tx.w {
                            for c in *from..*to {
                                t.data[(h * t.w + w) * t.c + (c - from)] = tx.at(h, w, c);
                            }
                        }
                    }
                    t
                }
                PreparedNode::ShuffleC { x, groups } => {
                    let tx = node_input(&outputs, input, *x);
                    let g = *groups;
                    let per = tx.c / g;
                    let mut t = Tensor::zeros(tx.h, tx.w, tx.c);
                    // NHWC shuffle: out[.., i*g + j] = in[.., j*per + i]
                    for h in 0..tx.h {
                        for w in 0..tx.w {
                            for j in 0..g {
                                for i in 0..per {
                                    t.data[(h * t.w + w) * t.c + (i * g + j)] =
                                        tx.at(h, w, j * per + i);
                                }
                            }
                        }
                    }
                    t
                }
                PreparedNode::Gap { x } => {
                    let tx = node_input(&outputs, input, *x);
                    let mut t = Tensor::zeros(1, 1, tx.c);
                    for c in 0..tx.c {
                        let mut s = 0.0f32;
                        for h in 0..tx.h {
                            for w in 0..tx.w {
                                s += tx.at(h, w, c);
                            }
                        }
                        t.data[c] = s / (tx.h * tx.w) as f32;
                    }
                    let bytes = (tx.data.len() * 4) as u64;
                    total.add_bulk(tx.data.len() as u64, bytes, &self.m.energy_cfg);
                    t
                }
            };
            outputs.push(out);
        }
        NetResult { output: outputs.pop().unwrap(), layers, total }
    }
}
