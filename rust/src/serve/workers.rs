//! The serving worker pool: one dispatcher thread driving the
//! [`DynamicBatcher`], N worker threads each owning a private
//! [`EngineMachine`] (simulated SIMD machine with all prepared weights
//! resident), and unbounded mpsc channels tying them together.
//!
//! Flow: `submit` -> submit channel -> dispatcher (batch close policy)
//! -> batch channel (shared by workers) -> worker executes each request
//! on its machine -> completion channel -> `shutdown` drains.

use crate::serve::batcher::{Batch, BatchConfig, DynamicBatcher, Request};
use crate::serve::engine::{EngineMachine, PreparedModel};
use crate::sim::machine::RunStats;
use crate::sim::network::{LayerStat, Tensor};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Worker-pool + batching configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// worker threads (each with its own simulated machine)
    pub workers: usize,
    pub batch: BatchConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { workers: 4, batch: BatchConfig::default() }
    }
}

/// One finished request with its result and measurements.
#[derive(Debug)]
pub struct Completion {
    pub id: u64,
    /// index of the worker that executed it
    pub worker: usize,
    /// id of the batch it rode in (sequential close order)
    pub batch_id: u64,
    /// size of that batch
    pub batch_size: usize,
    /// enqueue-to-completion latency
    pub latency: Duration,
    pub output: Tensor,
    /// simulated-hardware totals for this inference
    pub total: RunStats,
    pub per_layer: Vec<LayerStat>,
}

/// A running serving instance over one prepared model.
pub struct Server {
    submit: Option<mpsc::Sender<Request>>,
    results: mpsc::Receiver<Completion>,
    dispatcher: Option<thread::JoinHandle<()>>,
    workers: Vec<thread::JoinHandle<()>>,
    next_id: u64,
}

impl Server {
    /// Spawn the dispatcher and worker threads. Each worker instantiates
    /// its own machine from the shared prepared model (weights written
    /// once per worker, then reused for every request it serves).
    pub fn start(model: Arc<PreparedModel>, cfg: &ServeConfig) -> Server {
        let (submit_tx, submit_rx) = mpsc::channel::<Request>();
        let (batch_tx, batch_rx) = mpsc::channel::<(u64, Batch)>();
        let (result_tx, result_rx) = mpsc::channel::<Completion>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        let bcfg = cfg.batch;
        let dispatcher = thread::spawn(move || {
            let mut batcher = DynamicBatcher::new(bcfg);
            let mut batch_id = 0u64;
            loop {
                let closed = match batcher.next_deadline() {
                    // nothing pending: block until a request (or shutdown)
                    // arrives instead of waking on a polling interval
                    None => match submit_rx.recv() {
                        Ok(req) => batcher.push(req),
                        Err(_) => {
                            if let Some(b) = batcher.flush() {
                                let _ = batch_tx.send((batch_id, b));
                            }
                            break;
                        }
                    },
                    // batch open: wait at most until its deadline; a push
                    // that doesn't fill the batch still re-checks the
                    // deadline so sustained arrivals can't starve it
                    Some(deadline) => {
                        let timeout = deadline.saturating_duration_since(Instant::now());
                        match submit_rx.recv_timeout(timeout) {
                            Ok(req) => batcher
                                .push(req)
                                .or_else(|| batcher.poll_deadline(Instant::now())),
                            Err(RecvTimeoutError::Timeout) => {
                                batcher.poll_deadline(Instant::now())
                            }
                            Err(RecvTimeoutError::Disconnected) => {
                                if let Some(b) = batcher.flush() {
                                    let _ = batch_tx.send((batch_id, b));
                                }
                                break;
                            }
                        }
                    }
                };
                if let Some(b) = closed {
                    if batch_tx.send((batch_id, b)).is_err() {
                        break; // all workers gone
                    }
                    batch_id += 1;
                }
            }
        });

        let workers = (0..cfg.workers.max(1))
            .map(|wi| {
                let model = Arc::clone(&model);
                let rx = Arc::clone(&batch_rx);
                let tx = result_tx.clone();
                thread::spawn(move || {
                    let mut engine = EngineMachine::new(&model);
                    loop {
                        // holding the lock only for the dequeue; workers
                        // execute batches concurrently
                        let msg = rx.lock().unwrap().recv();
                        let (batch_id, batch) = match msg {
                            Ok(v) => v,
                            Err(_) => break, // dispatcher done, queue drained
                        };
                        let batch_size = batch.requests.len();
                        for req in batch.requests {
                            let res = engine.run(&req.input);
                            let done = Completion {
                                id: req.id,
                                worker: wi,
                                batch_id,
                                batch_size,
                                latency: req.enqueued.elapsed(),
                                output: res.output,
                                total: res.total,
                                per_layer: res.layers,
                            };
                            if tx.send(done).is_err() {
                                return; // receiver dropped, stop serving
                            }
                        }
                    }
                })
            })
            .collect();
        drop(result_tx); // workers hold the only senders

        Server {
            submit: Some(submit_tx),
            results: result_rx,
            dispatcher: Some(dispatcher),
            workers,
            next_id: 0,
        }
    }

    /// Enqueue one request; returns its id (completions carry it back).
    pub fn submit(&mut self, input: Tensor) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        let req = Request { id, input, enqueued: Instant::now() };
        self.submit
            .as_ref()
            .expect("server already shut down")
            .send(req)
            .expect("dispatcher thread alive");
        id
    }

    /// Completions that have already arrived (non-blocking).
    pub fn drain_ready(&mut self) -> Vec<Completion> {
        self.results.try_iter().collect()
    }

    /// Stop accepting requests, let the pipeline drain, join every
    /// thread and return all remaining completions.
    ///
    /// Panics if any serving thread panicked (e.g. a request whose shape
    /// does not match the model): silently returning fewer completions
    /// than submissions would make the loss invisible to callers that
    /// pair results to requests.
    pub fn shutdown(mut self) -> Vec<Completion> {
        drop(self.submit.take());
        let mut panicked = 0usize;
        if let Some(d) = self.dispatcher.take() {
            panicked += d.join().is_err() as usize;
        }
        for w in self.workers.drain(..) {
            panicked += w.join().is_err() as usize;
        }
        let done: Vec<Completion> = self.results.try_iter().collect();
        assert!(
            panicked == 0,
            "{panicked} serving thread(s) panicked; only {} completions survived",
            done.len()
        );
        done
    }
}
