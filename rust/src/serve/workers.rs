//! The serving worker pool: one dispatcher thread driving the
//! [`DynamicBatcher`], N worker threads each owning a private
//! [`EngineMachine`] (simulated SIMD machine with a per-model bind
//! table, plus the KV caches of every decode session pinned to it).
//!
//! Flow: `submit`/`submit_step` -> submit channel -> dispatcher (batch
//! close policy, per-`(model, target)` groups) -> dispatch queue (a
//! shared FIFO for stateless batches + one pinned FIFO per worker for
//! session batches) -> worker executes each request on its machine
//! (binding the request's model lazily on its first batch, evicting LRU
//! under the resident-model budget) -> completion channel -> `shutdown`
//! drains.
//!
//! One pool serves many models: [`Server::start_pool`] +
//! [`Server::register`] route every registered model's traffic through
//! the same workers, so the quantize/pack/codegen amortization of a hot
//! model is never paid again just because a second model shares the
//! fleet. [`Server::start`] remains the single-model convenience form.
//!
//! Session affinity and placement: a session opened with
//! [`Server::open_session`] / [`Server::open_session_on`] is pinned to
//! one worker for its whole life, because that worker's machine owns
//! the session's packed K/V caches. Placement picks the worker with the
//! smallest resident KV-cache footprint (estimated caller-side from the
//! model's per-step append bytes; ties break on open-session count,
//! then index), so long-lived heavy sessions spread instead of piling
//! onto one machine. Stateless batches stay work-stealable through the
//! shared FIFO.

use crate::serve::batcher::{Batch, BatchConfig, DynamicBatcher, Payload, Request};
use crate::serve::engine::{EngineMachine, PreparedModel};
use crate::serve::{ModelHandle, ModelKey};
use crate::sim::machine::RunStats;
use crate::sim::network::{LayerStat, Tensor};
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Worker-pool + batching configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// worker threads (each with its own simulated machine)
    pub workers: usize,
    pub batch: BatchConfig,
    /// per-worker resident-model budget: a worker machine keeps at most
    /// this many models bound, evicting the least-recently-used beyond
    /// it (`usize::MAX` = never evict)
    pub resident_models: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { workers: 4, batch: BatchConfig::default(), resident_models: usize::MAX }
    }
}

/// Handle to an open decode session (pinned to one worker).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SessionId(pub u64);

/// One finished request with its result and measurements.
#[derive(Debug)]
pub struct Completion {
    pub id: u64,
    /// the model that served it (report aggregation keys on this)
    pub model: Arc<ModelKey>,
    /// index of the worker that executed it
    pub worker: usize,
    /// id of the batch it rode in (sequential close order)
    pub batch_id: u64,
    /// size of that batch
    pub batch_size: usize,
    /// enqueue-to-completion latency
    pub latency: Duration,
    /// the session this completion belongs to (`None` = stateless)
    pub session: Option<u64>,
    pub output: Tensor,
    /// simulated-hardware totals for this inference
    pub total: RunStats,
    pub per_layer: Vec<LayerStat>,
}

/// The dispatch queue between the dispatcher and the workers: closed
/// batches land in the shared FIFO (any worker may take them) or a
/// worker's pinned FIFO (session batches, which can never be stolen
/// away from the worker holding their KV caches). A worker pops its
/// two queue heads in batch-id order, i.e. global close-order FIFO.
struct DispatchQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

struct QueueState {
    shared: VecDeque<(u64, Batch)>,
    pinned: Vec<VecDeque<(u64, Batch)>>,
    closed: bool,
}

impl DispatchQueue {
    fn new(workers: usize) -> DispatchQueue {
        DispatchQueue {
            state: Mutex::new(QueueState {
                shared: VecDeque::new(),
                pinned: (0..workers).map(|_| VecDeque::new()).collect(),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    fn push(&self, batch_id: u64, batch: Batch) {
        let mut st = self.state.lock().unwrap();
        match batch.target {
            Some(w) => st.pinned[w].push_back((batch_id, batch)),
            None => st.shared.push_back((batch_id, batch)),
        }
        drop(st);
        self.cv.notify_all();
    }

    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Blocking pop for `worker`. Batch ids are assigned in close
    /// order, so taking whichever head (pinned or shared) has the
    /// smaller id preserves global FIFO across the two queues —
    /// sustained decode traffic cannot starve an older stateless batch
    /// or vice versa. `None` once the queue is closed and drained.
    fn pop(&self, worker: usize) -> Option<(u64, Batch)> {
        let mut st = self.state.lock().unwrap();
        loop {
            let p_id = st.pinned[worker].front().map(|&(id, _)| id);
            let s_id = st.shared.front().map(|&(id, _)| id);
            match (p_id, s_id) {
                (Some(p), Some(s)) => {
                    return if p < s {
                        st.pinned[worker].pop_front()
                    } else {
                        st.shared.pop_front()
                    }
                }
                (Some(_), None) => return st.pinned[worker].pop_front(),
                (None, Some(_)) => return st.shared.pop_front(),
                (None, None) => {
                    if st.closed {
                        return None;
                    }
                    st = self.cv.wait(st).unwrap();
                }
            }
        }
    }
}

/// Caller-side bookkeeping for one open decode session.
struct SessionMeta {
    handle: ModelHandle,
    /// pinned worker (owns the session's KV caches)
    worker: usize,
    /// steps submitted so far
    steps: usize,
    /// the model's tightest `max_positions`
    step_limit: usize,
    /// estimated KV bytes each step appends on the pinned worker
    kv_bytes_per_step: u64,
}

/// A running serving instance: one worker pool serving every model
/// registered with it (or just the one it was [`start`](Self::start)ed
/// with).
pub struct Server {
    submit: Option<mpsc::Sender<Request>>,
    results: mpsc::Receiver<Completion>,
    dispatcher: Option<thread::JoinHandle<()>>,
    workers: Vec<thread::JoinHandle<()>>,
    next_id: u64,
    next_session: u64,
    n_workers: usize,
    /// the model `submit`/`open_session` address (single-model form)
    default_model: Option<ModelHandle>,
    /// models addressable by key via `submit_model`/`open_session_on`
    registered: HashMap<ModelKey, ModelHandle>,
    /// open sessions; an id absent here (but below `next_session`) is
    /// closed, and a step for it is rejected in the caller's thread
    sessions: HashMap<u64, SessionMeta>,
    /// estimated resident session KV bytes per worker (placement key)
    worker_kv_bytes: Vec<u64>,
    /// open sessions per worker (placement tiebreak)
    worker_sessions: Vec<usize>,
    bind_times: Arc<Mutex<Vec<Duration>>>,
}

impl Server {
    /// Spawn a pool with no models yet: [`register`](Self::register)
    /// models, then route traffic with
    /// [`submit_model`](Self::submit_model) /
    /// [`open_session_on`](Self::open_session_on).
    pub fn start_pool(cfg: &ServeConfig) -> Server {
        Server::spawn(None, cfg)
    }

    /// Spawn the pool around one model (the single-model convenience
    /// form): `submit`/`open_session` address it directly. Each worker
    /// binds it eagerly at startup (weights written once per worker,
    /// then reused for every request it serves), so `bind_times`
    /// reflects the full model-to-machine cost.
    pub fn start(model: Arc<PreparedModel>, cfg: &ServeConfig) -> Server {
        Server::start_named(ModelKey::new("default", "default"), model, cfg)
    }

    /// [`start`](Self::start) with an explicit key, so completions and
    /// reports carry the real model identity instead of `default`.
    pub fn start_named(key: ModelKey, model: Arc<PreparedModel>, cfg: &ServeConfig) -> Server {
        Server::spawn(Some(ModelHandle::new(key, model)), cfg)
    }

    fn spawn(default_model: Option<ModelHandle>, cfg: &ServeConfig) -> Server {
        let n_workers = cfg.workers.max(1);
        let resident_models = cfg.resident_models.max(1);
        let (submit_tx, submit_rx) = mpsc::channel::<Request>();
        let (result_tx, result_rx) = mpsc::channel::<Completion>();
        let queue = Arc::new(DispatchQueue::new(n_workers));
        let bind_times = Arc::new(Mutex::new(Vec::with_capacity(n_workers)));

        let bcfg = cfg.batch;
        let dq = Arc::clone(&queue);
        let dispatcher = thread::spawn(move || {
            let mut batcher = DynamicBatcher::new(bcfg);
            let mut batch_id = 0u64;
            loop {
                let closed = match batcher.next_deadline() {
                    // nothing pending: block until a request (or shutdown)
                    // arrives instead of waking on a polling interval
                    None => match submit_rx.recv() {
                        Ok(req) => batcher.push(req),
                        Err(_) => break,
                    },
                    // a group is open: wait at most until the earliest
                    // deadline; the drain loop below re-checks it, so
                    // sustained arrivals can't starve an open group
                    Some(deadline) => {
                        let timeout = deadline.saturating_duration_since(Instant::now());
                        match submit_rx.recv_timeout(timeout) {
                            Ok(req) => batcher.push(req),
                            Err(RecvTimeoutError::Timeout) => None,
                            Err(RecvTimeoutError::Disconnected) => break,
                        }
                    }
                };
                if let Some(b) = closed {
                    dq.push(batch_id, b);
                    batch_id += 1;
                }
                while let Some(b) = batcher.poll_deadline(Instant::now()) {
                    dq.push(batch_id, b);
                    batch_id += 1;
                }
            }
            // shutdown: close whatever is pending, in FIFO order
            while let Some(b) = batcher.flush() {
                dq.push(batch_id, b);
                batch_id += 1;
            }
            dq.close();
        });

        let workers = (0..n_workers)
            .map(|wi| {
                let default = default_model.clone();
                let queue = Arc::clone(&queue);
                let tx = result_tx.clone();
                let binds = Arc::clone(&bind_times);
                thread::spawn(move || {
                    let t0 = Instant::now();
                    let mut engine = EngineMachine::with_budget(resident_models);
                    if let Some(h) = &default {
                        engine.bind_model(h);
                    }
                    binds.lock().unwrap().push(t0.elapsed());
                    while let Some((batch_id, batch)) = queue.pop(wi) {
                        // completion-producing requests only, so the
                        // field stays consistent with report batch math
                        let batch_size = batch
                            .requests
                            .iter()
                            .filter(|r| !matches!(r.payload, Payload::Close { .. }))
                            .count();
                        for req in batch.requests {
                            let Request { id, model, payload, enqueued, .. } = req;
                            let (output, total, per_layer, session) = match payload {
                                Payload::Infer(input) => {
                                    let r = engine.run_model(&model, &input);
                                    (r.output, r.total, r.layers, None)
                                }
                                Payload::Step { session, token } => {
                                    let r = engine.run_step_model(&model, session, &token);
                                    (r.output, r.total, r.layers, Some(session))
                                }
                                Payload::Close { session } => {
                                    // frees the KV caches; no completion
                                    engine.end_session(session);
                                    continue;
                                }
                            };
                            let done = Completion {
                                id,
                                model: Arc::clone(&model.key),
                                worker: wi,
                                batch_id,
                                batch_size,
                                latency: enqueued.elapsed(),
                                session,
                                output,
                                total,
                                per_layer,
                            };
                            if tx.send(done).is_err() {
                                return; // receiver dropped, stop serving
                            }
                        }
                    }
                })
            })
            .collect();
        drop(result_tx); // workers hold the only senders

        let mut registered = HashMap::new();
        if let Some(h) = &default_model {
            registered.insert((*h.key).clone(), h.clone());
        }
        Server {
            submit: Some(submit_tx),
            results: result_rx,
            dispatcher: Some(dispatcher),
            workers,
            next_id: 0,
            next_session: 0,
            n_workers,
            default_model,
            registered,
            sessions: HashMap::new(),
            worker_kv_bytes: vec![0; n_workers],
            worker_sessions: vec![0; n_workers],
            bind_times,
        }
    }

    /// Register a prepared model under `key`, making it addressable via
    /// [`submit_model`](Self::submit_model) /
    /// [`open_session_on`](Self::open_session_on). Registration is
    /// caller-side only — workers bind the model lazily on its first
    /// batch — so registering is cheap and can happen while the pool is
    /// already serving other models. Returns the handle.
    ///
    /// Re-registering a key with the *same* prepared instance is a
    /// no-op; a *different* instance panics: workers cache bind tables
    /// per key, so they would keep replaying the first instance's
    /// kernels for the new one's requests. Deploy a changed model under
    /// a new key (e.g. bump the design label) or start a fresh pool.
    pub fn register(&mut self, key: ModelKey, prepared: Arc<PreparedModel>) -> ModelHandle {
        if let Some(existing) = self.registered.get(&key) {
            assert!(
                Arc::ptr_eq(&existing.prepared, &prepared),
                "model {key} is already registered with a different prepared instance \
                 (workers cache bind tables per key)"
            );
            return existing.clone();
        }
        let handle = ModelHandle::new(key, prepared);
        self.registered.insert((*handle.key).clone(), handle.clone());
        handle
    }

    /// Keys of every model registered with this pool.
    pub fn model_keys(&self) -> Vec<ModelKey> {
        self.registered.keys().cloned().collect()
    }

    fn registered_handle(&self, key: &ModelKey) -> ModelHandle {
        self.registered
            .get(key)
            .cloned()
            .unwrap_or_else(|| panic!("model {key} is not registered with this server"))
    }

    fn default_handle(&self) -> ModelHandle {
        self.default_model
            .clone()
            .expect("pool server has no default model (use the *_model / *_on forms)")
    }

    fn send(&mut self, req: Request) -> u64 {
        let id = req.id;
        self.next_id += 1;
        self.submit
            .as_ref()
            .expect("server already shut down")
            .send(req)
            .expect("dispatcher thread alive");
        id
    }

    /// Enqueue one stateless request for the default model; returns its
    /// id (completions carry it back).
    pub fn submit(&mut self, input: Tensor) -> u64 {
        let handle = self.default_handle();
        let req = Request::infer(self.next_id, &handle, input, Instant::now());
        self.send(req)
    }

    /// Enqueue one stateless request for a registered model.
    pub fn submit_model(&mut self, key: &ModelKey, input: Tensor) -> u64 {
        let handle = self.registered_handle(key);
        let req = Request::infer(self.next_id, &handle, input, Instant::now());
        self.send(req)
    }

    /// The worker a new session lands on: smallest estimated KV-cache
    /// footprint, ties broken by fewest open sessions, then index (so a
    /// fresh pool fills round-robin instead of piling onto worker 0).
    fn place_session(&self) -> usize {
        (0..self.n_workers)
            .min_by_key(|&w| (self.worker_kv_bytes[w], self.worker_sessions[w], w))
            .expect("at least one worker")
    }

    fn open_session_handle(&mut self, handle: ModelHandle) -> SessionId {
        let step = handle
            .prepared
            .step
            .as_ref()
            .expect("model has no decode step graph (open_session needs a decoder)");
        let worker = self.place_session();
        let sid = SessionId(self.next_session);
        self.next_session += 1;
        self.worker_sessions[worker] += 1;
        self.sessions.insert(
            sid.0,
            SessionMeta {
                worker,
                steps: 0,
                step_limit: step.max_positions,
                kv_bytes_per_step: step.kv_bytes_per_position as u64,
                handle,
            },
        );
        sid
    }

    /// Open a decode session on the default model. The session is
    /// pinned to the worker with the smallest current KV-cache
    /// footprint, whose machine will own its K/V caches; every step of
    /// this session executes there.
    pub fn open_session(&mut self) -> SessionId {
        let handle = self.default_handle();
        self.open_session_handle(handle)
    }

    /// Open a decode session on a registered model (same placement as
    /// [`open_session`](Self::open_session)).
    pub fn open_session_on(&mut self, key: &ModelKey) -> SessionId {
        let handle = self.registered_handle(key);
        self.open_session_handle(handle)
    }

    /// Enqueue one decode step for an open session; returns its request
    /// id. Steps of one session execute in submission order on its
    /// pinned worker; same-step submissions of co-located same-model
    /// sessions may batch together.
    ///
    /// Panics in the *caller's* thread — never a worker's — if the
    /// session is closed, was never opened, or would exceed the model's
    /// `max_positions`: a stale or runaway caller must not take a
    /// worker (and with it every co-located session) down, and a step
    /// sent after `close_session` would execute against freed KV caches
    /// as a silently restarted session.
    pub fn submit_step(&mut self, session: SessionId, token: Tensor) -> u64 {
        let next_session = self.next_session;
        let meta = match self.sessions.get_mut(&session.0) {
            Some(m) => m,
            None if session.0 < next_session => {
                panic!("session {} is closed; step rejected in caller", session.0)
            }
            None => panic!("session {} was never opened", session.0),
        };
        assert!(
            meta.steps < meta.step_limit,
            "session {} exceeded max_positions = {}",
            session.0,
            meta.step_limit
        );
        meta.steps += 1;
        let worker = meta.worker;
        let handle = meta.handle.clone();
        let kv = meta.kv_bytes_per_step;
        self.worker_kv_bytes[worker] += kv;
        let req = Request::step(self.next_id, &handle, session.0, token, worker, Instant::now());
        self.send(req)
    }

    /// Close a finished session, freeing its KV caches on the pinned
    /// worker once every previously submitted step has executed (the
    /// close rides the session's FIFO) and releasing its footprint from
    /// the placement accounting. Long-lived servers should close every
    /// session they open, or worker memory grows per session. Produces
    /// no completion. A later [`submit_step`](Self::submit_step) for
    /// this session is rejected in the caller's thread.
    ///
    /// Panics if the session is not open (double close included).
    pub fn close_session(&mut self, session: SessionId) {
        let meta = self
            .sessions
            .remove(&session.0)
            .unwrap_or_else(|| panic!("session {} is not open", session.0));
        self.worker_sessions[meta.worker] -= 1;
        self.worker_kv_bytes[meta.worker] = self.worker_kv_bytes[meta.worker]
            .saturating_sub(meta.steps as u64 * meta.kv_bytes_per_step);
        let req =
            Request::close(self.next_id, &meta.handle, session.0, meta.worker, Instant::now());
        self.send(req);
    }

    /// Per-worker bind (prepare-to-machine) times. Complete once
    /// serving has started on every worker — in particular after
    /// `shutdown` — and used to report setup separately from
    /// steady-state throughput. Pool servers bind lazily per model, so
    /// their startup entries are near zero and per-model bind cost
    /// lands in the serving window instead.
    pub fn bind_times(&self) -> Arc<Mutex<Vec<Duration>>> {
        Arc::clone(&self.bind_times)
    }

    /// Completions that have already arrived (non-blocking).
    pub fn drain_ready(&mut self) -> Vec<Completion> {
        self.results.try_iter().collect()
    }

    /// Stop accepting requests, let the pipeline drain, join every
    /// thread and return all remaining completions.
    ///
    /// Panics if any serving thread panicked (e.g. a request whose shape
    /// does not match the model): silently returning fewer completions
    /// than submissions would make the loss invisible to callers that
    /// pair results to requests.
    pub fn shutdown(mut self) -> Vec<Completion> {
        drop(self.submit.take());
        let mut panicked = 0usize;
        if let Some(d) = self.dispatcher.take() {
            panicked += d.join().is_err() as usize;
        }
        for w in self.workers.drain(..) {
            panicked += w.join().is_err() as usize;
        }
        let done: Vec<Completion> = self.results.try_iter().collect();
        assert!(
            panicked == 0,
            "{panicked} serving thread(s) panicked; only {} completions survived",
            done.len()
        );
        done
    }
}
