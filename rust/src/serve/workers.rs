//! The serving worker pool: one dispatcher thread driving the
//! [`DynamicBatcher`], N worker threads each owning a private
//! [`EngineMachine`] (simulated SIMD machine with a per-model bind
//! table, plus the KV caches of every decode session pinned to it).
//!
//! Flow: `submit`/`submit_step` -> submit channel -> dispatcher ->
//! dispatch queue -> worker executes each request on its machine
//! (binding the request's model lazily on its first batch, evicting LRU
//! under the resident-model budget) -> completion channel -> `shutdown`
//! drains. Stateless and shard requests go through the dispatcher's
//! batch-close policy (per-`(model, target)` groups, size/deadline
//! triggers) into a shared FIFO (any worker) or a pinned FIFO (shard
//! affinity); decode traffic is *iteration-level scheduled* instead:
//! steps land in per-session lanes on the session's pinned worker, and
//! the worker re-forms its step batch every token from whichever of
//! its sessions currently have a pending step — sessions are admitted
//! mid-flight and retired the moment their lane drains, so a long
//! decode never stalls short ones that shared a closed batch.
//!
//! Backpressure: with [`ServeConfig::queue_depth`] set, the `try_*`
//! submission forms return a typed [`Rejected`] once the in-flight
//! count reaches the limit, so overload sheds measurably instead of
//! queuing unboundedly.
//!
//! One pool serves many models: [`Server::start_pool`] +
//! [`Server::register`] route every registered model's traffic through
//! the same workers, so the quantize/pack/codegen amortization of a hot
//! model is never paid again just because a second model shares the
//! fleet. [`Server::start`] remains the single-model convenience form.
//!
//! Session affinity and placement: a session opened with
//! [`Server::open_session`] / [`Server::open_session_on`] is pinned to
//! one worker for its whole life, because that worker's machine owns
//! the session's packed K/V caches. Placement picks the worker with the
//! smallest resident KV-cache footprint (estimated caller-side from the
//! model's per-step append bytes; ties break on open-session count,
//! then index), so long-lived heavy sessions spread instead of piling
//! onto one machine. Stateless batches stay work-stealable through the
//! shared FIFO.
//!
//! Sharded placement and scatter/gather: models route through
//! [`crate::serve::Deployment`]s. A whole-model deployment behaves
//! exactly like the PR-4 path; a *sharded* one pins each shard to a
//! worker at [`Server::deploy`] time, and every submitted request fans
//! out as one pinned sub-request per shard (all sharing the logical
//! request id). Workers execute shards like any other model — the
//! shard-tagged keys keep their bind tables distinct — and the server's
//! [`GatherBuffer`] reassembles the partial completions on the drain
//! path: `cout` slices concatenate, contraction-split partials reduce
//! (exactly — fixed-point grid), per-shard cycles/energy survive as
//! shard-tagged layer stats, and the caller sees ONE completion whose
//! output is bit-identical to the whole-model run.

use crate::serve::batcher::{Batch, BatchConfig, DynamicBatcher, Payload, Request};
use crate::serve::deploy::Deployment;
use crate::serve::engine::{EngineMachine, PreparedModel};
use crate::serve::kvpool::{KvPolicy, KvPoolCfg};
use crate::serve::obs::{dur_ns, Obs, ObsSnapshot, SpanTrack};
use crate::serve::{ModelHandle, ModelKey};
use crate::sim::machine::RunStats;
use crate::sim::network::{LayerStat, Tensor};
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Worker-pool + batching configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// worker threads (each with its own simulated machine)
    pub workers: usize,
    pub batch: BatchConfig,
    /// per-worker resident-model budget: a worker machine keeps at most
    /// this many models bound, evicting the least-recently-used beyond
    /// it (`usize::MAX` = never evict)
    pub resident_models: usize,
    /// per-worker machine buffer budget in bytes: binding a model whose
    /// buffers exceed it panics the worker, so models wider than one
    /// machine must be deployed sharded ([`Server::deploy`] with a
    /// matching [`crate::serve::DeployConfig::worker_budget`]); `None` =
    /// unlimited
    pub worker_budget: Option<usize>,
    /// collect Chrome trace events (see [`Obs::chrome_trace_json`]).
    /// Off by default: with tracing off no event strings are built, so
    /// the serving hot path stays unchanged.
    pub trace: bool,
    /// admission limit: the maximum number of in-flight requests
    /// (submitted but not yet drained by the caller). With a depth set,
    /// the `try_*` submission forms return [`Rejected`] instead of
    /// queuing past it, so overload degrades into measurable rejections
    /// rather than unbounded queue growth; `None` = unbounded (the
    /// closed-loop default, where callers submit a fixed backlog).
    pub queue_depth: Option<usize>,
    /// paged KV-cache storage: with a config set, every worker machine
    /// allocates session K/V from a [`KvPool`] of fixed-size pages
    /// (exact accounting, budget-driven refuse/evict/spill — see
    /// [`crate::serve::kvpool`]); `None` keeps the growable per-slot
    /// vecs and the byte-estimate placement.
    ///
    /// [`KvPool`]: crate::serve::kvpool::KvPool
    pub kv: Option<KvPoolCfg>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 4,
            batch: BatchConfig::default(),
            resident_models: usize::MAX,
            worker_budget: None,
            trace: false,
            queue_depth: None,
            kv: None,
        }
    }
}

/// Typed admission refusal: the pool is at its configured
/// [`ServeConfig::queue_depth`]. Returned by the `try_*` submission
/// forms; the caller sheds the request (it was never enqueued) and the
/// refusal is counted in [`ObsSnapshot::rejected`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Rejected {
    /// in-flight requests at refusal time
    pub depth: usize,
    /// the configured admission limit
    pub limit: usize,
}

impl std::fmt::Display for Rejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "admission rejected: {} in flight at queue depth limit {}",
            self.depth, self.limit
        )
    }
}

impl std::error::Error for Rejected {}

/// What a drain lost when serving threads died. Produced by
/// [`Server::shutdown`] only when a join failed; a healthy pool never
/// constructs one.
#[derive(Debug, Default, Clone)]
pub struct ServeFaults {
    /// serving threads (dispatcher + workers) that panicked
    pub panicked_threads: usize,
    /// logical request ids submitted but never completed (sorted)
    pub lost: Vec<u64>,
    /// sharded request ids that completed on some shards but whose
    /// gather entry was stranded by a dead worker (sorted); their
    /// partial completions are discarded, never returned as results
    pub partial: Vec<u64>,
}

/// Handle to an open decode session (pinned to one worker).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SessionId(pub u64);

/// One finished request with its result and measurements.
#[derive(Debug)]
pub struct Completion {
    pub id: u64,
    /// the model that served it (report aggregation keys on this)
    pub model: Arc<ModelKey>,
    /// index of the worker that executed it
    pub worker: usize,
    /// id of the batch it rode in (sequential close order)
    pub batch_id: u64,
    /// size of that batch
    pub batch_size: usize,
    /// enqueue-to-completion latency (sharded: the slowest shard's)
    pub latency: Duration,
    /// the session this completion belongs to (`None` = stateless)
    pub session: Option<u64>,
    /// which shard produced this completion. `Some` only on the raw
    /// partial completions inside the gather path; completions handed
    /// to callers are always gathered (`None`), with per-shard stats
    /// surviving as [`LayerStat::shard`] tags in `per_layer`.
    pub shard: Option<usize>,
    pub output: Tensor,
    /// simulated-hardware totals for this inference (sharded: merged
    /// over every shard)
    pub total: RunStats,
    pub per_layer: Vec<LayerStat>,
    /// lifecycle timestamps: queue-wait / bind-wait / service /
    /// gather-wait breakdown instead of one opaque latency (sharded:
    /// shard 0's track, with `gathered` = the slowest shard's finish)
    pub spans: SpanTrack,
}

/// One pinned session's pending decode traffic on its worker: steps
/// (and the final close) in submission order. The lane head is the
/// session's next runnable token — iteration-level scheduling re-forms
/// a step batch from lane heads at every pop, so a long decode never
/// stalls a short one that happened to arrive alongside it.
struct SessionLane {
    model: ModelHandle,
    pending: VecDeque<Request>,
}

/// The dispatch queue between the dispatcher and the workers: closed
/// stateless batches land in the shared FIFO (any worker may take
/// them) or a worker's pinned FIFO (shard sub-batches, which can never
/// be stolen away from the worker their shard is placed on). Decode
/// traffic bypasses batching entirely: steps land in per-session
/// *lanes* on the session's pinned worker, and the worker forms a
/// fresh step batch — one token from each lane head of the leading
/// model — every time it pops. Sessions join the next iteration the
/// moment their step arrives and leave it the moment their lane
/// drains, so batch membership changes token to token.
struct DispatchQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
    /// batch ids are globally unique across dispatcher-closed batches
    /// and worker-formed step batches; the dispatcher allocates in
    /// close order, so queued-batch FIFO arbitration still holds
    next_batch_id: AtomicU64,
    /// step batches take at most this many lane heads per iteration
    max_batch: usize,
    /// depth gauges update inside the queue lock, so snapshots can
    /// never observe a negative depth
    obs: Arc<Obs>,
}

struct QueueState {
    shared: VecDeque<(u64, Batch)>,
    pinned: Vec<VecDeque<(u64, Batch)>>,
    /// per-worker session lanes, keyed by session id; a lane exists
    /// iff it holds at least one pending request
    lanes: Vec<HashMap<u64, SessionLane>>,
    closed: bool,
}

impl DispatchQueue {
    fn new(workers: usize, max_batch: usize, obs: Arc<Obs>) -> DispatchQueue {
        DispatchQueue {
            state: Mutex::new(QueueState {
                shared: VecDeque::new(),
                pinned: (0..workers).map(|_| VecDeque::new()).collect(),
                lanes: (0..workers).map(|_| HashMap::new()).collect(),
                closed: false,
            }),
            cv: Condvar::new(),
            next_batch_id: AtomicU64::new(0),
            max_batch: max_batch.max(1),
            obs,
        }
    }

    fn alloc_batch_id(&self) -> u64 {
        self.next_batch_id.fetch_add(1, Relaxed)
    }

    fn push(&self, batch_id: u64, batch: Batch) {
        let mut st = self.state.lock().unwrap();
        self.obs.queue_add(batch.target, 1);
        match batch.target {
            Some(w) => st.pinned[w].push_back((batch_id, batch)),
            None => st.shared.push_back((batch_id, batch)),
        }
        drop(st);
        self.cv.notify_all();
    }

    /// Append one session request (step or close) to its lane on the
    /// pinned worker, creating the lane if the session had nothing
    /// pending. The pinned depth gauge counts lane requests
    /// individually (they are not batched until pop).
    fn push_step(&self, req: Request) {
        let worker = req.target.expect("session traffic is pinned");
        let session = match &req.payload {
            Payload::Step { session, .. } | Payload::Close { session } => *session,
            Payload::Infer(_) => unreachable!("push_step only takes session traffic"),
        };
        let mut st = self.state.lock().unwrap();
        self.obs.queue_add(Some(worker), 1);
        st.lanes[worker]
            .entry(session)
            .or_insert_with(|| SessionLane { model: req.model.clone(), pending: VecDeque::new() })
            .pending
            .push_back(req);
        drop(st);
        self.cv.notify_all();
    }

    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// The earliest pending arrival across `worker`'s lane heads.
    fn earliest_lane_head(lanes: &HashMap<u64, SessionLane>) -> Option<Instant> {
        lanes.values().filter_map(|l| l.pending.front().map(|r| r.enqueued)).min()
    }

    /// Form this iteration's step batch from `worker`'s lane heads:
    /// the lead lane (earliest head arrival, session id tiebreak)
    /// names the model, then every lane of that model contributes its
    /// head — one token per session — in (arrival, session) order, up
    /// to `max_batch`. Emptied lanes retire immediately; a session
    /// re-enters on its next submitted step. Called under the queue
    /// lock.
    fn form_step_batch(&self, st: &mut QueueState, worker: usize) -> (u64, Batch) {
        let now = Instant::now();
        let lanes = &mut st.lanes[worker];
        let mut heads: Vec<(Instant, u64)> = lanes
            .iter()
            .map(|(&sid, lane)| {
                (lane.pending.front().expect("lanes hold >= 1 request").enqueued, sid)
            })
            .collect();
        heads.sort();
        let lead = heads[0].1;
        let model = lanes.get(&lead).expect("lead lane exists").model.clone();
        let mut requests = Vec::new();
        for &(_, sid) in &heads {
            if requests.len() >= self.max_batch {
                break;
            }
            let lane = lanes.get_mut(&sid).expect("head lane exists");
            if lane.model.key != model.key {
                continue;
            }
            let mut req = lane.pending.pop_front().expect("lane non-empty");
            req.span.batch_closed = Some(now);
            requests.push(req);
            if lane.pending.is_empty() {
                lanes.remove(&sid);
            }
        }
        self.obs.queue_add(Some(worker), -(requests.len() as i64));
        let batch_id = self.alloc_batch_id();
        self.obs.on_step_batch(batch_id, &model.key, worker, requests.len(), now);
        (batch_id, Batch { model, target: Some(worker), requests })
    }

    /// Blocking pop for `worker`. Queued batches are taken in batch-id
    /// order across the pinned and shared FIFOs (ids are assigned in
    /// close order, so this is global close-order FIFO — sustained
    /// shard traffic cannot starve an older stateless batch or vice
    /// versa); session lanes compete with the chosen queued batch by
    /// earliest arrival, and when they win the worker forms a fresh
    /// step batch from its lane heads. `None` once the queue is closed
    /// and fully drained (lanes included).
    fn pop(&self, worker: usize) -> Option<(u64, Batch)> {
        let mut st = self.state.lock().unwrap();
        loop {
            let p_id = st.pinned[worker].front().map(|&(id, _)| id);
            let s_id = st.shared.front().map(|&(id, _)| id);
            let take_pinned = match (p_id, s_id) {
                (Some(p), Some(s)) => Some(p < s),
                (Some(_), None) => Some(true),
                (None, Some(_)) => Some(false),
                (None, None) => None,
            };
            let batch_arrival = match take_pinned {
                Some(true) => st.pinned[worker].front().map(|(_, b)| b.requests[0].enqueued),
                Some(false) => st.shared.front().map(|(_, b)| b.requests[0].enqueued),
                None => None,
            };
            let lane_arrival = Self::earliest_lane_head(&st.lanes[worker]);
            let steps_win = match (batch_arrival, lane_arrival) {
                (None, None) => {
                    if st.closed {
                        return None;
                    }
                    st = self.cv.wait(st).unwrap();
                    continue;
                }
                (None, Some(_)) => true,
                (Some(_), None) => false,
                (Some(b), Some(l)) => l < b,
            };
            if steps_win {
                return Some(self.form_step_batch(&mut st, worker));
            }
            return if take_pinned == Some(true) {
                self.obs.queue_add(Some(worker), -1);
                st.pinned[worker].pop_front()
            } else {
                self.obs.queue_add(None, -1);
                st.shared.pop_front()
            };
        }
    }
}

/// Caller-side bookkeeping for one open decode session.
struct SessionMeta {
    handle: ModelHandle,
    /// pinned worker (owns the session's KV caches)
    worker: usize,
    /// steps submitted so far
    steps: usize,
    /// the model's tightest `max_positions`
    step_limit: usize,
    /// estimated KV bytes each step appends on the pinned worker
    kv_bytes_per_step: u64,
    /// KV bytes actually charged to the placement accounting — closed
    /// sessions release exactly this, so charge and release can never
    /// drift apart (they are one number, not two formulas)
    charged_bytes: u64,
    /// paged mode: each slot's effective (chunk-aligned) page size, in
    /// positions — position `t` opens a fresh page in every slot with
    /// `t % slot_pages[s] == 0`. Empty when the pool is unpaged.
    slot_pages: Vec<usize>,
    /// paged mode: pool pages charged to the pinned worker so far
    charged_pages: u64,
}

/// A deployed model inside a pool: the deployment plus the worker each
/// shard is pinned to (empty for whole-model deployments, whose
/// requests stay work-stealable). Cloning is two `Arc` bumps — entries
/// are cloned per submit on the serving hot path.
#[derive(Clone)]
struct DeployEntry {
    dep: Arc<Deployment>,
    /// `workers[i]` = worker shard `i` is pinned to
    workers: Arc<[usize]>,
}

/// Reassembles sharded partial completions on the server's drain path.
/// Keyed by logical request id; an entry completes once every shard's
/// partial has arrived, producing the single gathered [`Completion`]
/// callers see.
#[derive(Default)]
struct GatherBuffer {
    pending: HashMap<u64, GatherState>,
}

struct GatherState {
    dep: Arc<Deployment>,
    parts: Vec<Option<Completion>>,
}

impl GatherBuffer {
    fn expect(&mut self, id: u64, dep: Arc<Deployment>) {
        let parts = (0..dep.num_shards()).map(|_| None).collect();
        let prev = self.pending.insert(id, GatherState { dep, parts });
        assert!(prev.is_none(), "request id {id} already awaiting gather");
    }

    /// Feed one raw completion through the buffer: whole-model
    /// completions pass straight through; shard partials accumulate
    /// until their logical request is complete, then emerge gathered.
    fn absorb(&mut self, c: Completion) -> Option<Completion> {
        let Some(shard) = c.shard else {
            return Some(c);
        };
        let id = c.id;
        let st = self
            .pending
            .get_mut(&id)
            .unwrap_or_else(|| panic!("no gather entry for sharded completion {id}"));
        assert!(st.parts[shard].is_none(), "duplicate completion for request {id} shard {shard}");
        st.parts[shard] = Some(c);
        if st.parts.iter().any(Option::is_none) {
            return None;
        }
        let st = self.pending.remove(&id).expect("entry exists");
        let parts: Vec<Completion> = st.parts.into_iter().map(Option::unwrap).collect();
        Some(gather_completion(&st.dep, parts))
    }

    fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Tear down every incomplete entry (a worker died mid-gather):
    /// returns `(id, shards_arrived, shards_expected)` per stranded
    /// logical request, sorted by id, and leaves the buffer empty. The
    /// arrived partials are discarded — a partial gather must never
    /// surface as a result.
    fn flush_stranded(&mut self) -> Vec<(u64, usize, usize)> {
        let mut out: Vec<(u64, usize, usize)> = self
            .pending
            .drain()
            .map(|(id, st)| (id, st.parts.iter().filter(|p| p.is_some()).count(), st.parts.len()))
            .collect();
        out.sort_unstable();
        out
    }
}

/// Combine one logical request's shard partials (in shard order) into
/// the completion callers see: outputs assemble via
/// [`Deployment::gather_outputs`] (concat or exact reduce), simulated
/// totals merge, latency is the slowest shard's, and every layer stat
/// is tagged with its shard for `(model, layer, shard)` reporting.
fn gather_completion(dep: &Arc<Deployment>, mut parts: Vec<Completion>) -> Completion {
    let output = {
        let outputs: Vec<&Tensor> = parts.iter().map(|c| &c.output).collect();
        dep.gather_outputs(&outputs)
    };
    let mut total = RunStats::default();
    let mut per_layer = Vec::new();
    let mut latency = Duration::ZERO;
    for (i, c) in parts.iter_mut().enumerate() {
        total.merge(&c.total);
        latency = latency.max(c.latency);
        for mut l in c.per_layer.drain(..) {
            l.shard = Some(i);
            per_layer.push(l);
        }
    }
    // spans likewise come from shard 0's lane, with `gathered` = the
    // slowest shard's finish, so `gather_wait` reads as the time shard
    // 0 spent waiting on its siblings
    let mut spans = parts[0].spans;
    spans.gathered = parts.iter().filter_map(|c| c.spans.executed).max();
    // batching stats come from shard 0's lane: every logical request has
    // exactly one shard-0 sub-request, so its batches partition the
    // logical requests and the report's distinct-batch count / mean
    // batch size stay coherent (a max over shards would correspond to
    // neither the logical nor any physical batching)
    Completion {
        id: parts[0].id,
        model: Arc::clone(dep.key()),
        worker: parts[0].worker,
        batch_id: parts[0].batch_id,
        batch_size: parts[0].batch_size,
        latency,
        session: None,
        shard: None,
        output,
        total,
        per_layer,
        spans,
    }
}

/// Route one submitted request: session traffic (steps and closes)
/// bypasses the batcher straight into its worker's session lane —
/// runnable at the next iteration, no close delay — while stateless
/// and shard requests take the classic batch-close path. Returns a
/// batch the push size-closed, if any.
fn route(
    batcher: &mut DynamicBatcher,
    dq: &DispatchQueue,
    obs: &Obs,
    req: Request,
) -> Option<Batch> {
    match req.payload {
        Payload::Step { .. } | Payload::Close { .. } => {
            dq.push_step(req);
            None
        }
        Payload::Infer(_) => {
            obs.on_group_push(&req.model.key, req.target);
            batcher.push(req)
        }
    }
}

/// Refresh worker `wi`'s engine-derived gauges (bind-table and session
/// state). Called by the owning worker thread after its eager binds and
/// after every batch; plain relaxed stores, no locks.
fn sync_engine_gauges(obs: &Obs, wi: usize, engine: &EngineMachine) {
    let w = &obs.workers[wi];
    let c = engine.counters();
    w.binds.store(c.binds, Relaxed);
    w.evictions.store(c.evictions, Relaxed);
    w.resident_models.store(engine.num_resident() as u64, Relaxed);
    w.resident_bytes.store(engine.resident_bytes() as u64, Relaxed);
    w.kv_bytes.store(engine.session_kv_bytes() as u64, Relaxed);
    w.sessions.store(engine.num_sessions() as u64, Relaxed);
    if let Some(s) = engine.kv_pool_stats() {
        w.kv_pages_used.store(s.used as u64, Relaxed);
        w.kv_pages_free.store(s.free as u64, Relaxed);
        w.kv_spilled_pages.store(s.spilled_pages as u64, Relaxed);
        w.kv_spills.store(s.spills, Relaxed);
        w.kv_faults.store(s.faults, Relaxed);
        w.kv_evictions.store(s.evictions, Relaxed);
    }
}

/// A running serving instance: one worker pool serving every deployment
/// registered with it (or just the one it was [`start`](Self::start)ed
/// with).
pub struct Server {
    submit: Option<mpsc::Sender<Request>>,
    results: mpsc::Receiver<Completion>,
    dispatcher: Option<thread::JoinHandle<()>>,
    workers: Vec<thread::JoinHandle<()>>,
    next_id: u64,
    next_session: u64,
    n_workers: usize,
    /// the per-worker machine buffer budget the pool was spawned with
    /// (deployments with more shards than workers are refused under it:
    /// shard plans size each shard for a machine of its own)
    worker_budget: Option<usize>,
    /// the deployment `submit`/`open_session` address (single-model form)
    default_model: Option<DeployEntry>,
    /// deployments addressable by key via `submit_model`/`open_session_on`
    registered: HashMap<ModelKey, DeployEntry>,
    /// reassembles sharded partial completions on the drain path
    gather: GatherBuffer,
    /// open sessions; an id absent here (but below `next_session`) is
    /// closed, and a step for it is rejected in the caller's thread
    sessions: HashMap<u64, SessionMeta>,
    /// estimated resident session KV bytes per worker (placement key)
    worker_kv_bytes: Vec<u64>,
    /// paged mode: pool pages charged per worker (placement key and
    /// the Refuse policy's admission ledger)
    worker_kv_pages: Vec<u64>,
    /// open sessions per worker (placement tiebreak)
    worker_sessions: Vec<usize>,
    /// paged KV config the pool was spawned with (`None` = growable)
    kv_cfg: Option<KvPoolCfg>,
    bind_times: Arc<Mutex<Vec<Duration>>>,
    /// live metrics registry (shared with the dispatcher and workers)
    obs: Arc<Obs>,
    /// admission limit ([`ServeConfig::queue_depth`]); `None` = unbounded
    queue_depth: Option<usize>,
    /// logical request ids submitted but not yet drained by the caller
    /// (fault accounting: whatever a dead pool leaves here is lost)
    outstanding: HashSet<u64>,
    /// set by [`shutdown`](Self::shutdown) when serving threads died
    faults: Option<ServeFaults>,
}

impl Server {
    /// Spawn a pool with no models yet: [`register`](Self::register)
    /// models, then route traffic with
    /// [`submit_model`](Self::submit_model) /
    /// [`open_session_on`](Self::open_session_on).
    pub fn start_pool(cfg: &ServeConfig) -> Server {
        Server::spawn(None, cfg)
    }

    /// Spawn the pool around one model (the single-model convenience
    /// form): `submit`/`open_session` address it directly. Each worker
    /// binds it eagerly at startup (weights written once per worker,
    /// then reused for every request it serves), so `bind_times`
    /// reflects the full model-to-machine cost.
    pub fn start(model: Arc<PreparedModel>, cfg: &ServeConfig) -> Server {
        Server::start_named(ModelKey::new("default", "default"), model, cfg)
    }

    /// [`start`](Self::start) with an explicit key, so completions and
    /// reports carry the real model identity instead of `default`.
    pub fn start_named(key: ModelKey, model: Arc<PreparedModel>, cfg: &ServeConfig) -> Server {
        Server::start_deployment(Arc::new(Deployment::whole(key, model)), cfg)
    }

    /// Spawn the pool around one [`Deployment`] as the default model:
    /// whole deployments bind eagerly on every worker (the classic
    /// single-model form), sharded ones bind each shard eagerly on its
    /// pinned worker, and `submit` scatter/gathers across them.
    pub fn start_deployment(dep: Arc<Deployment>, cfg: &ServeConfig) -> Server {
        Server::spawn(Some(dep), cfg)
    }

    /// Worker assignment for a deployment's shards: shard `i` pins to
    /// worker `(i + offset) % n_workers`, the offset staggering
    /// successive deployments so their shard-0 hot spots spread.
    fn assign_shards(dep: &Deployment, n_workers: usize, offset: usize) -> Arc<[usize]> {
        if !dep.is_sharded() {
            return Arc::from(Vec::new());
        }
        (0..dep.num_shards()).map(|i| (i + offset) % n_workers).collect()
    }

    /// Under a worker buffer budget, refuse at placement time — in the
    /// caller's thread — anything that could trip a worker machine's
    /// capacity assert mid-serve: more shards than workers (a shard
    /// plan sizes every shard for a machine of its own), or any
    /// (sub)model whose *exact* bind footprint exceeds the budget (e.g.
    /// a deployment planned under a different budget than the pool's,
    /// or a whole model registered into a budgeted pool that it can
    /// never fit). The CLI mirrors the shards-vs-workers rule with a
    /// `bail!` for a friendlier message.
    fn check_budget(dep: &Deployment, n_workers: usize, budget: Option<usize>) {
        let Some(b) = budget else {
            return;
        };
        assert!(
            dep.num_shards() <= n_workers,
            "deployment {} has {} shards but the pool has {n_workers} worker(s) under \
             a {b} B buffer budget; co-resident shards could exceed it — add workers \
             or raise the budget",
            dep.key(),
            dep.num_shards()
        );
        for (i, h) in dep.handles().iter().enumerate() {
            let need = h.prepared.bind_bytes();
            assert!(
                need <= b,
                "deployment {}: shard {i} binds {need} B but the pool's worker budget \
                 is {b} B (was the deployment planned under a different budget?)",
                dep.key()
            );
        }
    }

    fn spawn(default: Option<Arc<Deployment>>, cfg: &ServeConfig) -> Server {
        let n_workers = cfg.workers.max(1);
        let resident_models = cfg.resident_models.max(1);
        let worker_budget = cfg.worker_budget;
        let default_model = default.map(|dep| DeployEntry {
            workers: Server::assign_shards(&dep, n_workers, 0),
            dep,
        });
        if let Some(entry) = &default_model {
            Server::check_budget(&entry.dep, n_workers, worker_budget);
        }
        // the handles each worker binds eagerly at startup
        let mut eager: Vec<Vec<ModelHandle>> = vec![Vec::new(); n_workers];
        if let Some(entry) = &default_model {
            if entry.dep.is_sharded() {
                for (i, h) in entry.dep.handles().iter().enumerate() {
                    eager[entry.workers[i]].push(h.clone());
                }
            } else {
                for w in eager.iter_mut() {
                    w.push(entry.dep.handles()[0].clone());
                }
            }
        }
        let (submit_tx, submit_rx) = mpsc::channel::<Request>();
        let (result_tx, result_rx) = mpsc::channel::<Completion>();
        let obs = Arc::new(Obs::new(n_workers, worker_budget, cfg.trace));
        let kv_cfg = cfg.kv;
        if let Some(kv) = kv_cfg {
            obs.configure_kv(kv.pages_per_worker);
        }
        let queue = Arc::new(DispatchQueue::new(n_workers, cfg.batch.max_batch, Arc::clone(&obs)));
        let bind_times = Arc::new(Mutex::new(Vec::with_capacity(n_workers)));

        let bcfg = cfg.batch;
        let dq = Arc::clone(&queue);
        let obs_d = Arc::clone(&obs);
        let dispatcher = thread::spawn(move || {
            let mut batcher = DynamicBatcher::new(bcfg);
            // close one batch: stamp its requests, account it, queue it
            let mut emit = |mut b: Batch| {
                let now = Instant::now();
                for r in &mut b.requests {
                    r.span.batch_closed = Some(now);
                }
                let batch_id = dq.alloc_batch_id();
                obs_d.on_batch_close(batch_id, &b.model.key, b.target, b.requests.len(), now);
                dq.push(batch_id, b);
            };
            loop {
                let closed = match batcher.next_deadline() {
                    // nothing pending: block until a request (or shutdown)
                    // arrives instead of waking on a polling interval
                    None => match submit_rx.recv() {
                        Ok(req) => route(&mut batcher, &dq, &obs_d, req),
                        Err(_) => break,
                    },
                    // a group is open: wait at most until the earliest
                    // deadline; the drain loop below re-checks it, so
                    // sustained arrivals can't starve an open group
                    Some(deadline) => {
                        let timeout = deadline.saturating_duration_since(Instant::now());
                        match submit_rx.recv_timeout(timeout) {
                            Ok(req) => route(&mut batcher, &dq, &obs_d, req),
                            Err(RecvTimeoutError::Timeout) => None,
                            Err(RecvTimeoutError::Disconnected) => break,
                        }
                    }
                };
                if let Some(b) = closed {
                    emit(b);
                }
                while let Some(b) = batcher.poll_deadline(Instant::now()) {
                    emit(b);
                }
            }
            // shutdown: close whatever is pending, in FIFO order
            while let Some(b) = batcher.flush() {
                emit(b);
            }
            dq.close();
        });

        let workers = (0..n_workers)
            .map(|wi| {
                let eager = std::mem::take(&mut eager[wi]);
                let queue = Arc::clone(&queue);
                let tx = result_tx.clone();
                let binds = Arc::clone(&bind_times);
                let obs = Arc::clone(&obs);
                thread::spawn(move || {
                    let t0 = Instant::now();
                    let mut engine = EngineMachine::with_limits(resident_models, worker_budget);
                    if let Some(kv) = kv_cfg {
                        engine.set_kv_pool(kv);
                    }
                    engine.set_record_events(obs.trace_on());
                    for h in &eager {
                        engine.bind_model(h);
                    }
                    binds.lock().unwrap().push(t0.elapsed());
                    sync_engine_gauges(&obs, wi, &engine);
                    loop {
                        let idle0 = Instant::now();
                        let Some((batch_id, batch)) = queue.pop(wi) else {
                            break;
                        };
                        let t_pop = Instant::now();
                        let wobs = &obs.workers[wi];
                        wobs.idle_ns
                            .fetch_add(dur_ns(t_pop.saturating_duration_since(idle0)), Relaxed);
                        // bind the batch's model up front so the cost
                        // lands in `bind_wait`, not the first request's
                        // service time
                        let c0 = engine.counters();
                        engine.bind_model(&batch.model);
                        let t_bound = Instant::now();
                        wobs.bind_ns
                            .fetch_add(dur_ns(t_bound.saturating_duration_since(t_pop)), Relaxed);
                        if engine.counters().binds > c0.binds {
                            obs.trace_bind(wi, &batch.model.key, t_pop, t_bound);
                        }
                        let batch_model = Arc::clone(&batch.model.key);
                        // completion-producing requests only, so the
                        // field stays consistent with report batch math
                        let batch_size = batch
                            .requests
                            .iter()
                            .filter(|r| !matches!(r.payload, Payload::Close { .. }))
                            .count();
                        let mut t_prev = t_bound;
                        for req in batch.requests {
                            let Request { id, model, payload, enqueued, shard, mut span, .. } =
                                req;
                            span.dispatched = Some(t_pop);
                            span.bound = Some(t_bound);
                            span.started = Some(t_prev);
                            let (output, total, per_layer, session) = match payload {
                                Payload::Infer(input) => {
                                    let r = engine.run_model(&model, &input);
                                    (r.output, r.total, r.layers, None)
                                }
                                Payload::Step { session, token } => {
                                    let r = engine.run_step_model(&model, session, &token);
                                    (r.output, r.total, r.layers, Some(session))
                                }
                                Payload::Close { session } => {
                                    // frees the KV caches; no completion
                                    engine.end_session(session);
                                    continue;
                                }
                            };
                            let t_done = Instant::now();
                            span.executed = Some(t_done);
                            obs.record_exec(&span);
                            obs.trace_exec(wi, id, shard, t_prev, t_done);
                            t_prev = t_done;
                            let done = Completion {
                                id,
                                model: Arc::clone(&model.key),
                                worker: wi,
                                batch_id,
                                batch_size,
                                latency: enqueued.elapsed(),
                                session,
                                shard,
                                output,
                                total,
                                per_layer,
                                spans: span,
                            };
                            if tx.send(done).is_err() {
                                return; // receiver dropped, stop serving
                            }
                        }
                        wobs.busy_ns
                            .fetch_add(dur_ns(t_prev.saturating_duration_since(t_pop)), Relaxed);
                        wobs.batches.fetch_add(1, Relaxed);
                        wobs.requests.fetch_add(batch_size as u64, Relaxed);
                        sync_engine_gauges(&obs, wi, &engine);
                        obs.trace_batch(wi, batch_id, &batch_model, batch_size, t_pop, t_prev);
                        if obs.trace_on() {
                            obs.trace_engine_events(wi, engine.take_events(), t_bound);
                        }
                    }
                })
            })
            .collect();
        drop(result_tx); // workers hold the only senders

        let mut registered = HashMap::new();
        if let Some(entry) = &default_model {
            registered.insert((**entry.dep.key()).clone(), entry.clone());
        }
        Server {
            submit: Some(submit_tx),
            results: result_rx,
            dispatcher: Some(dispatcher),
            workers,
            next_id: 0,
            next_session: 0,
            n_workers,
            worker_budget,
            default_model,
            registered,
            gather: GatherBuffer::default(),
            sessions: HashMap::new(),
            worker_kv_bytes: vec![0; n_workers],
            worker_kv_pages: vec![0; n_workers],
            worker_sessions: vec![0; n_workers],
            kv_cfg,
            bind_times,
            obs,
            queue_depth: cfg.queue_depth,
            outstanding: HashSet::new(),
            faults: None,
        }
    }

    /// The live metrics registry, shared: clone the `Arc` into another
    /// thread to [`Obs::snapshot`] the pool while it serves.
    pub fn obs(&self) -> Arc<Obs> {
        Arc::clone(&self.obs)
    }

    /// Point-in-time view of every counter, gauge and histogram
    /// (sugar for [`Obs::snapshot`]; callable mid-run).
    pub fn snapshot(&self) -> ObsSnapshot {
        self.obs.snapshot()
    }

    /// Register a prepared model under `key` as a whole-model
    /// deployment, making it addressable via
    /// [`submit_model`](Self::submit_model) /
    /// [`open_session_on`](Self::open_session_on). Registration is
    /// caller-side only — workers bind the model lazily on its first
    /// batch — so registering is cheap and can happen while the pool is
    /// already serving other models. Returns the handle.
    ///
    /// Re-registering a key with the *same* prepared instance is a
    /// no-op; a *different* instance panics: workers cache bind tables
    /// per key, so they would keep replaying the first instance's
    /// kernels for the new one's requests. Deploy a changed model under
    /// a new key (e.g. bump the design label) or start a fresh pool.
    pub fn register(&mut self, key: ModelKey, prepared: Arc<PreparedModel>) -> ModelHandle {
        let dep = self.deploy(Arc::new(Deployment::whole(key, prepared)));
        dep.handles()[0].clone()
    }

    /// Register a [`Deployment`] with this pool. Whole deployments
    /// behave exactly like [`register`](Self::register); sharded ones
    /// pin each shard to a worker (staggered across deployments) and
    /// every request submitted for the key scatter/gathers across those
    /// workers. Returns the deployment actually serving the key.
    ///
    /// Re-deploying a key follows the same rule as `register`: the same
    /// deployment (or the same whole-model prepared instance) is a
    /// no-op, anything else panics.
    pub fn deploy(&mut self, dep: Arc<Deployment>) -> Arc<Deployment> {
        let key: &ModelKey = dep.key();
        if let Some(existing) = self.registered.get(key) {
            let same_whole = !existing.dep.is_sharded()
                && !dep.is_sharded()
                && Arc::ptr_eq(&existing.dep.handles()[0].prepared, &dep.handles()[0].prepared);
            assert!(
                Arc::ptr_eq(&existing.dep, &dep) || same_whole,
                "model {key} is already registered with a different deployment \
                 (workers cache bind tables per key)"
            );
            return Arc::clone(&existing.dep);
        }
        Server::check_budget(&dep, self.n_workers, self.worker_budget);
        let workers = Server::assign_shards(&dep, self.n_workers, self.registered.len());
        self.registered.insert(key.clone(), DeployEntry { dep: Arc::clone(&dep), workers });
        dep
    }

    /// Keys of every model registered with this pool.
    pub fn model_keys(&self) -> Vec<ModelKey> {
        self.registered.keys().cloned().collect()
    }

    /// The deployment serving `key`, if any.
    pub fn deployment(&self, key: &ModelKey) -> Option<Arc<Deployment>> {
        self.registered.get(key).map(|e| Arc::clone(&e.dep))
    }

    fn registered_entry(&self, key: &ModelKey) -> DeployEntry {
        self.registered
            .get(key)
            .cloned()
            .unwrap_or_else(|| panic!("model {key} is not registered with this server"))
    }

    fn default_entry(&self) -> DeployEntry {
        self.default_model
            .clone()
            .expect("pool server has no default model (use the *_model / *_on forms)")
    }

    fn alloc_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    fn send(&mut self, req: Request) {
        self.submit
            .as_ref()
            .expect("server already shut down")
            .send(req)
            .expect("dispatcher thread alive");
    }

    /// Scatter one stateless request across a deployment: one request
    /// for a whole deployment, one pinned sub-request per shard (all
    /// sharing the logical id, gathered on the drain path) for a
    /// sharded one.
    /// Admission gate: with a [`ServeConfig::queue_depth`] configured,
    /// refuse new work while the in-flight count (submitted minus
    /// completed, i.e. everything the caller has not drained yet) is at
    /// the limit. Refusals are counted in the live registry. Always
    /// admits when no depth is configured.
    fn admit(&self) -> Result<(), Rejected> {
        let Some(limit) = self.queue_depth else {
            return Ok(());
        };
        let depth = self.obs.in_flight() as usize;
        if depth >= limit {
            self.obs.on_reject();
            return Err(Rejected { depth, limit });
        }
        Ok(())
    }

    fn submit_entry(&mut self, entry: DeployEntry, input: Tensor) -> u64 {
        let id = self.alloc_id();
        self.outstanding.insert(id);
        let now = Instant::now();
        self.obs.on_submit();
        self.obs.trace_request_begin(id, entry.dep.key(), now);
        if !entry.dep.is_sharded() {
            let req = Request::infer(id, &entry.dep.handles()[0], input, now);
            self.send(req);
            return id;
        }
        self.gather.expect(id, Arc::clone(&entry.dep));
        self.obs.gather_add(entry.dep.num_shards() as i64);
        for (i, h) in entry.dep.handles().iter().enumerate() {
            let req = Request::infer_shard(id, h, i, input.clone(), entry.workers[i], now);
            self.send(req);
        }
        id
    }

    /// Enqueue one stateless request for the default model; returns its
    /// id (completions carry it back).
    ///
    /// Under a configured [`ServeConfig::queue_depth`] this panics when
    /// the pool is at its limit — the bound is hard; callers serving
    /// open-loop traffic should use [`try_submit`](Self::try_submit)
    /// and shed the rejection instead.
    pub fn submit(&mut self, input: Tensor) -> u64 {
        self.try_submit(input).unwrap_or_else(|r| panic!("{r}; use try_submit to shed load"))
    }

    /// [`submit`](Self::submit) with admission control: `Err(Rejected)`
    /// when the pool is at its configured queue depth (the request is
    /// not enqueued).
    pub fn try_submit(&mut self, input: Tensor) -> Result<u64, Rejected> {
        self.admit()?;
        let entry = self.default_entry();
        Ok(self.submit_entry(entry, input))
    }

    /// Enqueue one stateless request for a registered model
    /// (scatter/gathered if its deployment is sharded). Panics at the
    /// configured queue depth, like [`submit`](Self::submit).
    pub fn submit_model(&mut self, key: &ModelKey, input: Tensor) -> u64 {
        self.try_submit_model(key, input)
            .unwrap_or_else(|r| panic!("{r}; use try_submit_model to shed load"))
    }

    /// [`submit_model`](Self::submit_model) with admission control.
    pub fn try_submit_model(&mut self, key: &ModelKey, input: Tensor) -> Result<u64, Rejected> {
        self.admit()?;
        let entry = self.registered_entry(key);
        Ok(self.submit_entry(entry, input))
    }

    /// The worker a new session lands on: smallest resident KV-cache
    /// footprint — *exact* charged pool pages when the pool is paged,
    /// the per-step byte estimate otherwise — ties broken by fewest
    /// open sessions, then index (so a fresh pool fills round-robin
    /// instead of piling onto worker 0).
    fn place_session(&self) -> usize {
        let key = |w: usize| {
            let load = if self.kv_cfg.is_some() {
                self.worker_kv_pages[w]
            } else {
                self.worker_kv_bytes[w]
            };
            (load, self.worker_sessions[w], w)
        };
        (0..self.n_workers).min_by_key(|&w| key(w)).expect("at least one worker")
    }

    fn open_session_handle(&mut self, entry: DeployEntry) -> Result<SessionId, Rejected> {
        assert!(
            !entry.dep.is_sharded(),
            "model {} is deployed sharded; decode sessions pin whole models",
            entry.dep.key()
        );
        let handle = entry.dep.handles()[0].clone();
        let step = handle
            .prepared
            .step
            .as_ref()
            .expect("model has no decode step graph (open_session needs a decoder)");
        // paged mode: each slot's effective page size under the pool
        // config (position t opens a page in slots where t % P_s == 0)
        let slot_pages: Vec<usize> = match self.kv_cfg {
            Some(cfg) => {
                let scfg = cfg.session_cfg();
                step.slot_geoms.iter().map(|sg| sg.page_geom(&scfg).page_positions).collect()
            }
            None => Vec::new(),
        };
        let worker = self.place_session();
        // Refuse policy gates at admission: the session's first step
        // allocates one page per slot, so a worker whose charged pages
        // cannot take that many refuses the open outright (no session
        // state is created). Evict/Spill admit and let the engine
        // reclaim pages instead.
        if let Some(cfg) = self.kv_cfg {
            if cfg.policy == KvPolicy::Refuse {
                if let Some(budget) = cfg.pages_per_worker {
                    let need = slot_pages.len() as u64;
                    if self.worker_kv_pages[worker] + need > budget as u64 {
                        self.obs.on_kv_refuse();
                        return Err(Rejected {
                            depth: self.worker_kv_pages[worker] as usize,
                            limit: budget,
                        });
                    }
                }
            }
        }
        let sid = SessionId(self.next_session);
        self.next_session += 1;
        self.worker_sessions[worker] += 1;
        self.sessions.insert(
            sid.0,
            SessionMeta {
                worker,
                steps: 0,
                step_limit: step.max_positions,
                kv_bytes_per_step: step.kv_bytes_per_position as u64,
                charged_bytes: 0,
                slot_pages,
                charged_pages: 0,
                handle,
            },
        );
        self.obs.on_session_open();
        if self.obs.trace_on() {
            let name = format!("open session {} (worker {worker})", sid.0);
            self.obs.trace_session(name, Instant::now());
        }
        Ok(sid)
    }

    /// Open a decode session on the default model. The session is
    /// pinned to the worker with the smallest current KV-cache
    /// footprint, whose machine will own its K/V caches; every step of
    /// this session executes there. Panics at the configured queue
    /// depth, like [`submit`](Self::submit).
    pub fn open_session(&mut self) -> SessionId {
        self.try_open_session().unwrap_or_else(|r| panic!("{r}; use try_open_session to shed load"))
    }

    /// [`open_session`](Self::open_session) with admission control:
    /// `Err(Rejected)` when the pool is at its configured queue depth,
    /// or — under a paged KV pool with the [`KvPolicy::Refuse`] policy
    /// — when the placement worker's charged pages cannot take the
    /// session's first step (no session is opened — overload sheds
    /// whole sessions at open time, before any KV cache is placed).
    pub fn try_open_session(&mut self) -> Result<SessionId, Rejected> {
        self.admit()?;
        let entry = self.default_entry();
        self.open_session_handle(entry)
    }

    /// Open a decode session on a registered model (same placement as
    /// [`open_session`](Self::open_session)).
    pub fn open_session_on(&mut self, key: &ModelKey) -> SessionId {
        self.try_open_session_on(key)
            .unwrap_or_else(|r| panic!("{r}; use try_open_session_on to shed load"))
    }

    /// [`open_session_on`](Self::open_session_on) with admission control.
    pub fn try_open_session_on(&mut self, key: &ModelKey) -> Result<SessionId, Rejected> {
        self.admit()?;
        let entry = self.registered_entry(key);
        self.open_session_handle(entry)
    }

    /// Enqueue one decode step for an open session; returns its request
    /// id. Steps of one session execute in submission order on its
    /// pinned worker; same-step submissions of co-located same-model
    /// sessions may batch together.
    ///
    /// Panics in the *caller's* thread — never a worker's — if the
    /// session is closed, was never opened, or would exceed the model's
    /// `max_positions`: a stale or runaway caller must not take a
    /// worker (and with it every co-located session) down, and a step
    /// sent after `close_session` would execute against freed KV caches
    /// as a silently restarted session. Panics at the configured queue
    /// depth, like [`submit`](Self::submit).
    pub fn submit_step(&mut self, session: SessionId, token: Tensor) -> u64 {
        self.try_submit_step(session, token)
            .unwrap_or_else(|r| panic!("{r}; use try_submit_step to shed load"))
    }

    /// [`submit_step`](Self::submit_step) with admission control:
    /// `Err(Rejected)` at the configured queue depth, or — under a
    /// paged KV pool with the [`KvPolicy::Refuse`] policy — when the
    /// step would open a fresh page past the pinned worker's page
    /// budget (the step is not enqueued; the session stays open and
    /// its earlier steps are unaffected). The session-invariant panics
    /// (closed, never opened, over `max_positions`) are preserved —
    /// those are caller bugs, not load.
    pub fn try_submit_step(&mut self, session: SessionId, token: Tensor) -> Result<u64, Rejected> {
        self.admit()?;
        let next_session = self.next_session;
        let meta = match self.sessions.get_mut(&session.0) {
            Some(m) => m,
            None if session.0 < next_session => {
                panic!("session {} is closed; step rejected in caller", session.0)
            }
            None => panic!("session {} was never opened", session.0),
        };
        assert!(
            meta.steps < meta.step_limit,
            "session {} exceeded max_positions = {}",
            session.0,
            meta.step_limit
        );
        // pages this step's appends allocate on the pinned worker:
        // position `steps` opens a fresh page in every page-aligned slot
        let pages_add = meta.slot_pages.iter().filter(|&&p| meta.steps % p == 0).count() as u64;
        let worker = meta.worker;
        if pages_add > 0 {
            if let Some(cfg) = self.kv_cfg {
                if cfg.policy == KvPolicy::Refuse {
                    if let Some(budget) = cfg.pages_per_worker {
                        if self.worker_kv_pages[worker] + pages_add > budget as u64 {
                            self.obs.on_kv_refuse();
                            return Err(Rejected {
                                depth: self.worker_kv_pages[worker] as usize,
                                limit: budget,
                            });
                        }
                    }
                }
            }
        }
        meta.steps += 1;
        meta.charged_pages += pages_add;
        let kv = meta.kv_bytes_per_step;
        meta.charged_bytes += kv;
        let handle = meta.handle.clone();
        self.worker_kv_bytes[worker] += kv;
        self.worker_kv_pages[worker] += pages_add;
        let id = self.alloc_id();
        self.outstanding.insert(id);
        let now = Instant::now();
        self.obs.on_submit();
        self.obs.trace_request_begin(id, &handle.key, now);
        let req = Request::step(id, &handle, session.0, token, worker, now);
        self.send(req);
        Ok(id)
    }

    /// Close a finished session, freeing its KV caches on the pinned
    /// worker once every previously submitted step has executed (the
    /// close rides the session's FIFO) and releasing its footprint from
    /// the placement accounting. Long-lived servers should close every
    /// session they open, or worker memory grows per session. Produces
    /// no completion. A later [`submit_step`](Self::submit_step) for
    /// this session is rejected in the caller's thread.
    ///
    /// Panics if the session is not open (double close included).
    pub fn close_session(&mut self, session: SessionId) {
        let meta = self
            .sessions
            .remove(&session.0)
            .unwrap_or_else(|| panic!("session {} is not open", session.0));
        self.worker_sessions[meta.worker] -= 1;
        // release exactly what was charged (recorded per session at
        // charge time), never a recomputed formula: a recompute that
        // drifted from the charge path — e.g. counting refused steps —
        // would leak or over-release placement weight forever
        self.worker_kv_bytes[meta.worker] =
            self.worker_kv_bytes[meta.worker].saturating_sub(meta.charged_bytes);
        self.worker_kv_pages[meta.worker] =
            self.worker_kv_pages[meta.worker].saturating_sub(meta.charged_pages);
        let id = self.alloc_id();
        let req = Request::close(id, &meta.handle, session.0, meta.worker, Instant::now());
        self.send(req);
        self.obs.on_session_close();
        if self.obs.trace_on() {
            let name = format!("close session {}", session.0);
            self.obs.trace_session(name, Instant::now());
        }
    }

    /// Snapshot of the per-worker bind (prepare-to-machine) times, one
    /// entry per worker that has started serving — complete after
    /// [`shutdown`](Self::shutdown), which is when benches read it. No
    /// lock handle escapes the API. Pool servers bind lazily per model,
    /// so their startup entries are near zero and per-model bind cost
    /// lands in the serving window instead.
    pub fn bind_times(&self) -> Vec<Duration> {
        self.bind_times.lock().unwrap().clone()
    }

    /// Gather raw completions and fold the finished ones into the
    /// observability registry (the single exit point for completions,
    /// so `completed` stays monotone and pairs with `submitted`).
    fn finish(&mut self, raw: Vec<Completion>) -> Vec<Completion> {
        let mut out = Vec::with_capacity(raw.len());
        for c in raw {
            if c.shard.is_some() {
                self.obs.gather_add(-1);
            }
            if let Some(done) = self.gather.absorb(c) {
                self.outstanding.remove(&done.id);
                self.obs.on_complete(done.id, done.latency, &done.spans);
                out.push(done);
            }
        }
        out
    }

    /// Completions that have already arrived (non-blocking). Sharded
    /// partials are gathered; a logical request whose shards have not
    /// all finished stays buffered until a later drain.
    pub fn drain_ready(&mut self) -> Vec<Completion> {
        let raw: Vec<Completion> = self.results.try_iter().collect();
        self.finish(raw)
    }

    /// What the pool lost, if serving threads died: `None` after a
    /// healthy [`shutdown`](Self::shutdown) (and always before one).
    pub fn faults(&self) -> Option<&ServeFaults> {
        self.faults.as_ref()
    }

    /// Stop accepting requests, let the pipeline drain, join every
    /// thread and return all remaining (gathered) completions.
    ///
    /// If serving threads panicked (e.g. a request whose shape does not
    /// match the model), the surviving completions are still returned,
    /// and the loss is surfaced instead of silently shrinking the
    /// result: [`faults`](Self::faults) reports the panicked-thread
    /// count, the ids of requests that never completed, and the ids of
    /// sharded requests whose gather was stranded partway (their
    /// partial outputs are discarded, and the gather buffer is flushed
    /// so the gauge returns to zero). A healthy shutdown still asserts
    /// the gather buffer drained — an entry left behind *without* a
    /// dead thread is a server bug, not a fault.
    pub fn shutdown(&mut self) -> Vec<Completion> {
        drop(self.submit.take());
        let mut panicked = 0usize;
        if let Some(d) = self.dispatcher.take() {
            panicked += d.join().is_err() as usize;
        }
        for w in self.workers.drain(..) {
            panicked += w.join().is_err() as usize;
        }
        let raw: Vec<Completion> = self.results.try_iter().collect();
        let done: Vec<Completion> = self.finish(raw);
        if panicked > 0 {
            let stranded = self.gather.flush_stranded();
            let partial: Vec<u64> =
                stranded.iter().filter(|&&(_, got, _)| got > 0).map(|&(id, ..)| id).collect();
            // the gather gauge still holds each stranded entry's
            // missing shards (the arrived ones were decremented on
            // drain); settle it so the snapshot returns to zero
            for &(_, got, expected) in &stranded {
                self.obs.gather_add(-((expected - got) as i64));
            }
            let mut lost: Vec<u64> = self.outstanding.drain().collect();
            lost.sort_unstable();
            self.faults = Some(ServeFaults { panicked_threads: panicked, lost, partial });
        } else {
            assert!(
                self.gather.is_empty(),
                "shutdown drained with sharded requests still awaiting gather"
            );
        }
        done
    }
}
