//! The serving worker pool: one dispatcher thread driving the
//! [`DynamicBatcher`], N worker threads each owning a private
//! [`EngineMachine`] (simulated SIMD machine with all prepared weights
//! resident, plus the KV caches of every decode session pinned to it).
//!
//! Flow: `submit`/`submit_step` -> submit channel -> dispatcher (batch
//! close policy, per-target groups) -> dispatch queue (a shared FIFO
//! for stateless batches + one pinned FIFO per worker for session
//! batches) -> worker executes each request on its machine ->
//! completion channel -> `shutdown` drains.
//!
//! Session affinity: a session opened with [`Server::open_session`] is
//! pinned to one worker for its whole life (`session id % workers`),
//! because that worker's machine owns the session's packed K/V caches.
//! Stateless batches stay work-stealable through the shared FIFO.

use crate::serve::batcher::{Batch, BatchConfig, DynamicBatcher, Payload, Request};
use crate::serve::engine::{EngineMachine, PreparedModel};
use crate::sim::machine::RunStats;
use crate::sim::network::{LayerStat, Tensor};
use std::collections::VecDeque;
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Worker-pool + batching configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// worker threads (each with its own simulated machine)
    pub workers: usize,
    pub batch: BatchConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { workers: 4, batch: BatchConfig::default() }
    }
}

/// Handle to an open decode session (pinned to one worker).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SessionId(pub u64);

/// One finished request with its result and measurements.
#[derive(Debug)]
pub struct Completion {
    pub id: u64,
    /// index of the worker that executed it
    pub worker: usize,
    /// id of the batch it rode in (sequential close order)
    pub batch_id: u64,
    /// size of that batch
    pub batch_size: usize,
    /// enqueue-to-completion latency
    pub latency: Duration,
    /// the session this completion belongs to (`None` = stateless)
    pub session: Option<u64>,
    pub output: Tensor,
    /// simulated-hardware totals for this inference
    pub total: RunStats,
    pub per_layer: Vec<LayerStat>,
}

/// The dispatch queue between the dispatcher and the workers: closed
/// batches land in the shared FIFO (any worker may take them) or a
/// worker's pinned FIFO (session batches, which can never be stolen
/// away from the worker holding their KV caches). A worker pops its
/// two queue heads in batch-id order, i.e. global close-order FIFO.
struct DispatchQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

struct QueueState {
    shared: VecDeque<(u64, Batch)>,
    pinned: Vec<VecDeque<(u64, Batch)>>,
    closed: bool,
}

impl DispatchQueue {
    fn new(workers: usize) -> DispatchQueue {
        DispatchQueue {
            state: Mutex::new(QueueState {
                shared: VecDeque::new(),
                pinned: (0..workers).map(|_| VecDeque::new()).collect(),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    fn push(&self, batch_id: u64, batch: Batch) {
        let mut st = self.state.lock().unwrap();
        match batch.target {
            Some(w) => st.pinned[w].push_back((batch_id, batch)),
            None => st.shared.push_back((batch_id, batch)),
        }
        drop(st);
        self.cv.notify_all();
    }

    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Blocking pop for `worker`. Batch ids are assigned in close
    /// order, so taking whichever head (pinned or shared) has the
    /// smaller id preserves global FIFO across the two queues —
    /// sustained decode traffic cannot starve an older stateless batch
    /// or vice versa. `None` once the queue is closed and drained.
    fn pop(&self, worker: usize) -> Option<(u64, Batch)> {
        let mut st = self.state.lock().unwrap();
        loop {
            let p_id = st.pinned[worker].front().map(|&(id, _)| id);
            let s_id = st.shared.front().map(|&(id, _)| id);
            match (p_id, s_id) {
                (Some(p), Some(s)) => {
                    return if p < s {
                        st.pinned[worker].pop_front()
                    } else {
                        st.shared.pop_front()
                    }
                }
                (Some(_), None) => return st.pinned[worker].pop_front(),
                (None, Some(_)) => return st.shared.pop_front(),
                (None, None) => {
                    if st.closed {
                        return None;
                    }
                    st = self.cv.wait(st).unwrap();
                }
            }
        }
    }
}

/// A running serving instance over one prepared model.
pub struct Server {
    submit: Option<mpsc::Sender<Request>>,
    results: mpsc::Receiver<Completion>,
    dispatcher: Option<thread::JoinHandle<()>>,
    workers: Vec<thread::JoinHandle<()>>,
    next_id: u64,
    next_session: u64,
    n_workers: usize,
    has_step: bool,
    /// per-session step limit (the model's tightest `max_positions`)
    step_limit: usize,
    /// steps submitted per open session, to reject over-long sessions
    /// in the caller's thread instead of panicking a worker
    session_steps: std::collections::HashMap<u64, usize>,
    bind_times: Arc<Mutex<Vec<Duration>>>,
}

impl Server {
    /// Spawn the dispatcher and worker threads. Each worker instantiates
    /// its own machine from the shared prepared model (weights written
    /// once per worker, then reused for every request it serves).
    pub fn start(model: Arc<PreparedModel>, cfg: &ServeConfig) -> Server {
        let n_workers = cfg.workers.max(1);
        let (submit_tx, submit_rx) = mpsc::channel::<Request>();
        let (result_tx, result_rx) = mpsc::channel::<Completion>();
        let queue = Arc::new(DispatchQueue::new(n_workers));
        let bind_times = Arc::new(Mutex::new(Vec::with_capacity(n_workers)));
        let has_step = model.step.is_some();
        let step_limit = model.step.as_ref().map(|s| s.max_positions).unwrap_or(usize::MAX);

        let bcfg = cfg.batch;
        let dq = Arc::clone(&queue);
        let dispatcher = thread::spawn(move || {
            let mut batcher = DynamicBatcher::new(bcfg);
            let mut batch_id = 0u64;
            loop {
                let closed = match batcher.next_deadline() {
                    // nothing pending: block until a request (or shutdown)
                    // arrives instead of waking on a polling interval
                    None => match submit_rx.recv() {
                        Ok(req) => batcher.push(req),
                        Err(_) => break,
                    },
                    // a group is open: wait at most until the earliest
                    // deadline; the drain loop below re-checks it, so
                    // sustained arrivals can't starve an open group
                    Some(deadline) => {
                        let timeout = deadline.saturating_duration_since(Instant::now());
                        match submit_rx.recv_timeout(timeout) {
                            Ok(req) => batcher.push(req),
                            Err(RecvTimeoutError::Timeout) => None,
                            Err(RecvTimeoutError::Disconnected) => break,
                        }
                    }
                };
                if let Some(b) = closed {
                    dq.push(batch_id, b);
                    batch_id += 1;
                }
                while let Some(b) = batcher.poll_deadline(Instant::now()) {
                    dq.push(batch_id, b);
                    batch_id += 1;
                }
            }
            // shutdown: close whatever is pending, in FIFO order
            while let Some(b) = batcher.flush() {
                dq.push(batch_id, b);
                batch_id += 1;
            }
            dq.close();
        });

        let workers = (0..n_workers)
            .map(|wi| {
                let model = Arc::clone(&model);
                let queue = Arc::clone(&queue);
                let tx = result_tx.clone();
                let binds = Arc::clone(&bind_times);
                thread::spawn(move || {
                    let t0 = Instant::now();
                    let mut engine = EngineMachine::new(&model);
                    binds.lock().unwrap().push(t0.elapsed());
                    while let Some((batch_id, batch)) = queue.pop(wi) {
                        // completion-producing requests only, so the
                        // field stays consistent with report batch math
                        let batch_size = batch
                            .requests
                            .iter()
                            .filter(|r| !matches!(r.payload, Payload::Close { .. }))
                            .count();
                        for req in batch.requests {
                            let (output, total, per_layer, session) = match req.payload {
                                Payload::Infer(input) => {
                                    let r = engine.run(&input);
                                    (r.output, r.total, r.layers, None)
                                }
                                Payload::Step { session, token } => {
                                    let r = engine.run_step(session, &token);
                                    (r.output, r.total, r.layers, Some(session))
                                }
                                Payload::Close { session } => {
                                    // frees the KV caches; no completion
                                    engine.end_session(session);
                                    continue;
                                }
                            };
                            let done = Completion {
                                id: req.id,
                                worker: wi,
                                batch_id,
                                batch_size,
                                latency: req.enqueued.elapsed(),
                                session,
                                output,
                                total,
                                per_layer,
                            };
                            if tx.send(done).is_err() {
                                return; // receiver dropped, stop serving
                            }
                        }
                    }
                })
            })
            .collect();
        drop(result_tx); // workers hold the only senders

        Server {
            submit: Some(submit_tx),
            results: result_rx,
            dispatcher: Some(dispatcher),
            workers,
            next_id: 0,
            next_session: 0,
            n_workers,
            has_step,
            step_limit,
            session_steps: std::collections::HashMap::new(),
            bind_times,
        }
    }

    fn send(&mut self, req: Request) -> u64 {
        let id = req.id;
        self.next_id += 1;
        self.submit
            .as_ref()
            .expect("server already shut down")
            .send(req)
            .expect("dispatcher thread alive");
        id
    }

    /// Enqueue one stateless request; returns its id (completions carry
    /// it back).
    pub fn submit(&mut self, input: Tensor) -> u64 {
        let req = Request::infer(self.next_id, input, Instant::now());
        self.send(req)
    }

    /// Open a decode session. The session is pinned to one worker
    /// (`id % workers`), whose machine will own its K/V caches; every
    /// step of this session executes there.
    pub fn open_session(&mut self) -> SessionId {
        assert!(self.has_step, "model has no decode step graph (open_session needs a decoder)");
        let sid = SessionId(self.next_session);
        self.next_session += 1;
        sid
    }

    /// Enqueue one decode step for an open session; returns its request
    /// id. Steps of one session execute in submission order on its
    /// pinned worker; same-step submissions of co-located sessions may
    /// batch together.
    ///
    /// Panics in the *caller's* thread if the session would exceed the
    /// model's `max_positions` — an over-long session must not take a
    /// worker (and with it every co-located session) down.
    pub fn submit_step(&mut self, session: SessionId, token: Tensor) -> u64 {
        let steps = self.session_steps.entry(session.0).or_insert(0);
        assert!(
            *steps < self.step_limit,
            "session {} exceeded max_positions = {}",
            session.0,
            self.step_limit
        );
        *steps += 1;
        let target = (session.0 as usize) % self.n_workers;
        let req = Request::step(self.next_id, session.0, token, target, Instant::now());
        self.send(req)
    }

    /// Close a finished session, freeing its KV caches on the pinned
    /// worker once every previously submitted step has executed (the
    /// close rides the session's FIFO). Long-lived servers should close
    /// every session they open, or worker memory grows per session.
    /// Produces no completion.
    pub fn close_session(&mut self, session: SessionId) {
        self.session_steps.remove(&session.0);
        let target = (session.0 as usize) % self.n_workers;
        let req = Request::close(self.next_id, session.0, target, Instant::now());
        self.send(req);
    }

    /// Per-worker bind (prepare-to-machine) times. Complete once
    /// serving has started on every worker — in particular after
    /// `shutdown` — and used to report setup separately from
    /// steady-state throughput.
    pub fn bind_times(&self) -> Arc<Mutex<Vec<Duration>>> {
        Arc::clone(&self.bind_times)
    }

    /// Completions that have already arrived (non-blocking).
    pub fn drain_ready(&mut self) -> Vec<Completion> {
        self.results.try_iter().collect()
    }

    /// Stop accepting requests, let the pipeline drain, join every
    /// thread and return all remaining completions.
    ///
    /// Panics if any serving thread panicked (e.g. a request whose shape
    /// does not match the model): silently returning fewer completions
    /// than submissions would make the loss invisible to callers that
    /// pair results to requests.
    pub fn shutdown(mut self) -> Vec<Completion> {
        drop(self.submit.take());
        let mut panicked = 0usize;
        if let Some(d) = self.dispatcher.take() {
            panicked += d.join().is_err() as usize;
        }
        for w in self.workers.drain(..) {
            panicked += w.join().is_err() as usize;
        }
        let done: Vec<Completion> = self.results.try_iter().collect();
        assert!(
            panicked == 0,
            "{panicked} serving thread(s) panicked; only {} completions survived",
            done.len()
        );
        done
    }
}
