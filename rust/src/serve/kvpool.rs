//! `serve::kvpool` — the paged, budgeted KV-cache page allocator.
//!
//! PR 3's session caches hold one growable host vec per [`KvSlot`],
//! and PR 4 places sessions by an *estimated* worst-case footprint —
//! fine for tens of sessions, hopeless for thousands of mixed-length
//! ones. This module makes KV storage a first-class allocator: session
//! state is carved into fixed-size, chunk-aligned **pages** (one page
//! = `page_positions` decode positions of packed K columns + quantized
//! V for every head of one attention slot), allocated from a
//! per-worker [`KvPool`] with **exact** page accounting:
//!
//! * every allocation bumps `used` by exactly one page and every
//!   release returns the page to a per-geometry free list, so
//!   thousands of open/close cycles reuse the same buffers with zero
//!   fragmentation — `used` equals `Σ ceil(slot_len / page_positions)`
//!   over resident sessions at every instant;
//! * a configurable page budget turns exhaustion into policy
//!   ([`KvPolicy`]): **refuse** new work at the server's admission
//!   gate, **evict** the coldest session (drop its pages — the caller
//!   sees a restart-from-empty on the next step), or **spill** the
//!   coldest session's pages into a host-side overflow arena and fault
//!   them back untouched on its next step (bit-exact round trip);
//! * an optional **low-precision V tier** ([`KvPoolCfg::v_bits`])
//!   stores V pages at a lower SMOL level than compute — capacity per
//!   page goes up, accuracy degrades measurably (see the oracle sweep
//!   in `tests/proptests.rs`).
//!
//! The pool never blocks an allocation itself — policy runs *before*
//! the step (admission in `workers.rs`, evict/spill in
//! `engine::EngineMachine::run_step_model`), so `alloc` is infallible
//! and a session that legitimately exceeds the whole budget overcommits
//! (the gauges report the truth) instead of deadlocking.
//!
//! [`KvSlot`]: crate::serve::session::KvSlot

use crate::simd::patterns::Pattern;
use std::collections::HashMap;

/// What to do when a step would push a worker's pool past its page
/// budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvPolicy {
    /// Refuse at the server's admission gate (`try_open_session` /
    /// `try_submit_step` return [`Rejected`]). The engine itself never
    /// refuses — a race between close-submit and close-execution may
    /// transiently overcommit by the in-flight sessions' pages.
    ///
    /// [`Rejected`]: crate::serve::Rejected
    #[default]
    Refuse,
    /// Evict the coldest *other* session: drop its pages back to the
    /// free list. The caller is not notified; a later step for the
    /// evicted session restarts it from an empty cache (the decode
    /// analogue of losing a model from an LRU bind table).
    Evict,
    /// Spill the coldest *other* session's pages to the host-side
    /// overflow arena; its next step faults them back verbatim.
    Spill,
}

impl KvPolicy {
    /// Parse a `--kv-policy` CLI value.
    pub fn parse(s: &str) -> Option<KvPolicy> {
        match s {
            "refuse" => Some(KvPolicy::Refuse),
            "evict" => Some(KvPolicy::Evict),
            "spill" => Some(KvPolicy::Spill),
            _ => None,
        }
    }
}

impl std::fmt::Display for KvPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvPolicy::Refuse => write!(f, "refuse"),
            KvPolicy::Evict => write!(f, "evict"),
            KvPolicy::Spill => write!(f, "spill"),
        }
    }
}

/// Pool configuration, one per worker (every worker of a server gets
/// an identical copy; pools themselves are per-worker and unshared).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvPoolCfg {
    /// Requested positions per page. The effective per-slot page size
    /// is this rounded *up* to a multiple of the slot's V chunk
    /// capacity ([`PageGeom::new`]), so a packed V chunk never
    /// straddles a page boundary.
    pub page_positions: usize,
    /// Page budget per worker; `None` = unbounded (paged layout and
    /// exact accounting without any eviction pressure).
    pub pages_per_worker: Option<usize>,
    pub policy: KvPolicy,
    /// Store V at this SMOL precision instead of the compute
    /// (`pos_prec`) level — clamped per slot to at most the compute
    /// precision, so pool buffers sized for compute always suffice.
    /// `None` keeps V at compute precision (bit-identical decode).
    pub v_bits: Option<u8>,
}

impl Default for KvPoolCfg {
    fn default() -> KvPoolCfg {
        KvPoolCfg {
            page_positions: 64,
            pages_per_worker: None,
            policy: KvPolicy::default(),
            v_bits: None,
        }
    }
}

/// The session-level paged-storage knobs a worker threads into each
/// [`SessionState`] it creates (the pool-level budget/policy stay in
/// the engine).
///
/// [`SessionState`]: crate::serve::session::SessionState
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionKvCfg {
    pub page_positions: usize,
    pub v_bits: Option<u8>,
}

impl KvPoolCfg {
    pub fn session_cfg(&self) -> SessionKvCfg {
        SessionKvCfg { page_positions: self.page_positions, v_bits: self.v_bits }
    }
}

/// Effective V storage precision for a slot whose compute precision is
/// `pos_prec`: the configured tier, clamped so it never *exceeds*
/// compute — a lower level has larger chunk capacity, so buffers sized
/// for compute always fit, while a higher one would overflow them.
pub fn effective_v_prec(pos_prec: u8, v_bits: Option<u8>) -> u8 {
    v_bits.map(|b| b.min(pos_prec)).unwrap_or(pos_prec)
}

/// One attention slot's page shape: fixed per `(heads, dh, nch_dh,
/// v_prec, page_positions)` and shared by every page of every session
/// decoding through that slot — which is what makes the free list
/// geometry-keyed reuse exact.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PageGeom {
    pub heads: usize,
    pub dh: usize,
    /// chunk count of the dh (score contraction) axis
    pub nch_dh: usize,
    /// V storage precision (compute `pos_prec`, or the lower V tier)
    pub v_prec: u8,
    /// positions per page, aligned up to a multiple of the V chunk
    /// capacity so packed V chunks never straddle pages
    pub page_positions: usize,
}

impl PageGeom {
    /// Build a slot geometry, aligning `page_positions` up to the V
    /// chunk capacity at `v_prec` (a 1-position request at 4-bit V
    /// becomes a 32-position page: the packed-chunk granularity).
    pub fn new(heads: usize, dh: usize, nch_dh: usize, v_prec: u8, page_positions: usize) -> PageGeom {
        let cap = Pattern::uniform(v_prec).capacity() as usize;
        let p = page_positions.max(1).div_ceil(cap) * cap;
        PageGeom { heads, dh, nch_dh, v_prec, page_positions: p }
    }

    /// V chunk capacity (positions per packed 16-byte chunk).
    pub fn cap_v(&self) -> usize {
        Pattern::uniform(self.v_prec).capacity() as usize
    }

    /// Packed V chunks per page per feature column.
    pub fn chunks_per_page(&self) -> usize {
        self.page_positions / self.cap_v()
    }

    /// Packed K bytes per page: `heads * page_positions` columns of
    /// `nch_dh` 16-byte chunks.
    pub fn k_bytes(&self) -> usize {
        self.heads * self.page_positions * self.nch_dh * 16
    }

    /// Quantized V values per page (position-major per head).
    pub fn v_quant_len(&self) -> usize {
        self.heads * self.page_positions * self.dh
    }

    /// Packed V bytes per page: per `(head, feature)` column,
    /// `chunks_per_page` 16-byte chunks along the position axis.
    pub fn v_packed_bytes(&self) -> usize {
        self.heads * self.dh * self.chunks_per_page() * 16
    }

    /// Total host bytes one page of this geometry occupies.
    pub fn page_bytes(&self) -> usize {
        self.k_bytes() + self.v_quant_len() * 4 + self.v_packed_bytes()
    }

    /// Pages a slot of `len` positions occupies.
    pub fn pages_for(&self, len: usize) -> usize {
        len.div_ceil(self.page_positions)
    }
}

/// The geometry-determining facts of one `CachedAttn` slot, recorded
/// on the prepared [`StepModel`] so the engine and the server can
/// compute page needs *before* a step runs (the session itself builds
/// the same [`PageGeom`] lazily on its first step).
///
/// [`StepModel`]: crate::serve::engine::StepModel
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotGeomSpec {
    pub heads: usize,
    pub dh: usize,
    /// chunk count of the dh (score contraction) axis
    pub nch_dh: usize,
    /// compute precision of the position axis
    pub pos_prec: u8,
}

impl SlotGeomSpec {
    /// The page geometry this slot uses under `cfg` — byte-for-byte
    /// the one `CachedAttnOp` builds at first step.
    pub fn page_geom(&self, cfg: &SessionKvCfg) -> PageGeom {
        let v_prec = effective_v_prec(self.pos_prec, cfg.v_bits);
        PageGeom::new(self.heads, self.dh, self.nch_dh, v_prec, cfg.page_positions)
    }
}

/// One fixed-size page: `page_positions` positions of packed K columns
/// plus quantized + packed V, for every head of one attention slot.
/// Contents are only meaningful up to the owning slot's `len`; reused
/// pages are *not* zeroed (every byte the execution path reads is
/// overwritten by the append path first).
#[derive(Debug, Clone)]
pub struct KvPage {
    /// packed K, `(head * page_positions + pos) * nch_dh * 16` layout
    pub k: Vec<u8>,
    /// quantized V, `(head * page_positions + pos) * dh + feat` layout
    pub v_quant: Vec<f32>,
    /// packed V, `((head * dh + feat) * chunks_per_page + chunk) * 16`
    pub v_packed: Vec<u8>,
}

impl KvPage {
    fn new(geom: &PageGeom) -> KvPage {
        KvPage {
            k: vec![0u8; geom.k_bytes()],
            v_quant: vec![0f32; geom.v_quant_len()],
            v_packed: vec![0u8; geom.v_packed_bytes()],
        }
    }
}

/// Point-in-time pool occupancy + lifetime counters, published to the
/// observability registry after every step batch.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KvPoolStats {
    /// page budget (`None` = unbounded)
    pub budget: Option<usize>,
    /// pages currently backing resident sessions
    pub used: usize,
    /// pages parked on the free list awaiting reuse
    pub free: usize,
    /// pages currently spilled to the overflow arena
    pub spilled_pages: usize,
    /// sessions spilled to the arena (lifetime)
    pub spills: u64,
    /// sessions faulted back from the arena (lifetime)
    pub faults: u64,
    /// sessions evicted (pages dropped) under budget pressure (lifetime)
    pub evictions: u64,
}

/// The per-worker page pool: exact occupancy accounting, per-geometry
/// free lists, and the spill arena. Policy decisions live in the
/// engine/server; the pool only moves pages and keeps the books.
#[derive(Debug)]
pub struct KvPool {
    cfg: KvPoolCfg,
    used: usize,
    free: HashMap<PageGeom, Vec<KvPage>>,
    free_count: usize,
    /// spilled sessions: session id -> per-slot page runs, parked
    /// verbatim and restored verbatim on fault-back
    arena: HashMap<u64, Vec<Vec<KvPage>>>,
    spilled_pages: usize,
    spills: u64,
    faults: u64,
    evictions: u64,
}

impl KvPool {
    pub fn new(cfg: KvPoolCfg) -> KvPool {
        KvPool {
            cfg,
            used: 0,
            free: HashMap::new(),
            free_count: 0,
            arena: HashMap::new(),
            spilled_pages: 0,
            spills: 0,
            faults: 0,
            evictions: 0,
        }
    }

    pub fn cfg(&self) -> &KvPoolCfg {
        &self.cfg
    }

    /// Pages currently backing resident sessions.
    pub fn used(&self) -> usize {
        self.used
    }

    /// Whether allocating `extra` more pages would exceed the budget.
    /// Always `false` when unbounded.
    pub fn would_exceed(&self, extra: usize) -> bool {
        self.cfg.pages_per_worker.is_some_and(|b| self.used + extra > b)
    }

    /// Allocate one page: reuse a free-listed page of the same
    /// geometry or grow the pool. Infallible by design — budget policy
    /// runs *before* the step (see the module docs).
    pub fn alloc(&mut self, geom: &PageGeom) -> KvPage {
        self.used += 1;
        if let Some(list) = self.free.get_mut(geom) {
            if let Some(page) = list.pop() {
                self.free_count -= 1;
                return page;
            }
        }
        KvPage::new(geom)
    }

    /// Return a slot's pages to the geometry's free list for reuse.
    pub fn release(&mut self, geom: &PageGeom, pages: Vec<KvPage>) {
        let n = pages.len();
        debug_assert!(self.used >= n, "release of pages the pool never allocated");
        self.used -= n;
        self.free_count += n;
        self.free.entry(*geom).or_default().extend(pages);
    }

    /// Park a whole session's pages (one run per slot) in the overflow
    /// arena. The pages move verbatim — faulting back restores the
    /// exact bytes.
    pub fn park(&mut self, session: u64, slots: Vec<Vec<KvPage>>) {
        let n: usize = slots.iter().map(Vec::len).sum();
        debug_assert!(self.used >= n, "park of pages the pool never allocated");
        self.used -= n;
        self.spilled_pages += n;
        self.spills += 1;
        let prev = self.arena.insert(session, slots);
        debug_assert!(prev.is_none(), "session {session} parked twice");
    }

    /// Fault a parked session's pages back into residency. `None` if
    /// the session was never parked.
    pub fn unpark(&mut self, session: u64) -> Option<Vec<Vec<KvPage>>> {
        let slots = self.arena.remove(&session)?;
        let n: usize = slots.iter().map(Vec::len).sum();
        self.spilled_pages -= n;
        self.used += n;
        self.faults += 1;
        Some(slots)
    }

    /// Pages a spilled session has parked in the arena (0 if never
    /// parked) — what faulting it back will re-add to `used`.
    pub fn parked_pages(&self, session: u64) -> usize {
        self.arena.get(&session).map_or(0, |s| s.iter().map(Vec::len).sum())
    }

    /// Drop a parked session's pages without restoring them (session
    /// closed while spilled). The host buffers are freed, not
    /// free-listed — they were already off the books.
    pub fn drop_parked(&mut self, session: u64) {
        if let Some(slots) = self.arena.remove(&session) {
            self.spilled_pages -= slots.iter().map(Vec::len).sum::<usize>();
        }
    }

    /// Record one budget-pressure session eviction (the engine drops
    /// the pages through [`KvPool::release`] separately).
    pub fn note_eviction(&mut self) {
        self.evictions += 1;
    }

    pub fn stats(&self) -> KvPoolStats {
        KvPoolStats {
            budget: self.cfg.pages_per_worker,
            used: self.used,
            free: self.free_count,
            spilled_pages: self.spilled_pages,
            spills: self.spills,
            faults: self.faults,
            evictions: self.evictions,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom() -> PageGeom {
        PageGeom::new(2, 8, 3, 4, 33)
    }

    #[test]
    fn geometry_aligns_pages_to_v_chunks() {
        // cap at 4-bit = 32 positions/chunk: 33 rounds up to 64
        let g = geom();
        assert_eq!(g.page_positions, 64);
        assert_eq!(g.chunks_per_page(), 2);
        assert_eq!(g.k_bytes(), 2 * 64 * 3 * 16);
        assert_eq!(g.v_quant_len(), 2 * 64 * 8);
        assert_eq!(g.v_packed_bytes(), 2 * 8 * 2 * 16);
        assert_eq!(g.pages_for(0), 0);
        assert_eq!(g.pages_for(64), 1);
        assert_eq!(g.pages_for(65), 2);
        // 2-bit V doubles the chunk capacity (64), so a 1-position
        // request becomes one full chunk worth of positions
        let g2 = PageGeom::new(1, 4, 1, 2, 1);
        assert_eq!(g2.page_positions, 64);
    }

    #[test]
    fn accounting_is_exact_through_alloc_release_cycles() {
        let g = geom();
        let mut pool = KvPool::new(KvPoolCfg { pages_per_worker: Some(4), ..Default::default() });
        let pages: Vec<KvPage> = (0..3).map(|_| pool.alloc(&g)).collect();
        assert_eq!(pool.used(), 3);
        assert!(!pool.would_exceed(1));
        assert!(pool.would_exceed(2));
        pool.release(&g, pages);
        assert_eq!(pool.used(), 0);
        assert_eq!(pool.stats().free, 3);
        // reuse: three more allocs drain the free list, no growth
        let again: Vec<KvPage> = (0..3).map(|_| pool.alloc(&g)).collect();
        assert_eq!(pool.stats().free, 0);
        assert_eq!(pool.used(), 3);
        pool.release(&g, again);
    }

    #[test]
    fn free_lists_are_geometry_keyed() {
        let g1 = geom();
        let g2 = PageGeom::new(1, 4, 1, 4, 32);
        let mut pool = KvPool::new(KvPoolCfg::default());
        let p1 = pool.alloc(&g1);
        pool.release(&g1, vec![p1]);
        // a different geometry must not reuse g1's page
        let p2 = pool.alloc(&g2);
        assert_eq!(p2.k.len(), g2.k_bytes());
        assert_eq!(pool.stats().free, 1, "g1's page stays on its own list");
        pool.release(&g2, vec![p2]);
    }

    #[test]
    fn spill_round_trip_preserves_bytes_and_books() {
        let g = geom();
        let mut pool = KvPool::new(KvPoolCfg::default());
        let mut page = pool.alloc(&g);
        page.k[7] = 0xAB;
        page.v_quant[3] = -1.5;
        page.v_packed[1] = 0xCD;
        pool.park(9, vec![vec![page]]);
        let s = pool.stats();
        assert_eq!((s.used, s.spilled_pages, s.spills), (0, 1, 1));
        let back = pool.unpark(9).unwrap();
        assert_eq!(back[0][0].k[7], 0xAB);
        assert_eq!(back[0][0].v_quant[3], -1.5);
        assert_eq!(back[0][0].v_packed[1], 0xCD);
        let s = pool.stats();
        assert_eq!((s.used, s.spilled_pages, s.faults), (1, 0, 1));
        assert!(pool.unpark(9).is_none());
        pool.release(&g, back.into_iter().flatten().collect());
    }

    #[test]
    fn drop_parked_clears_arena_without_freelisting() {
        let g = geom();
        let mut pool = KvPool::new(KvPoolCfg::default());
        let page = pool.alloc(&g);
        pool.park(1, vec![vec![page]]);
        pool.drop_parked(1);
        let s = pool.stats();
        assert_eq!((s.used, s.free, s.spilled_pages), (0, 0, 0));
        assert!(pool.unpark(1).is_none());
    }
}
