//! `serve::obs` — live observability for the serving pool.
//!
//! Three layers, all cheap on the hot path:
//!
//! 1. **Per-request lifecycle spans.** Every [`Request`] carries a
//!    [`SpanTrack`] of timestamps (enqueued → batch-closed → dispatched
//!    → bound → started → executed → gathered); the dispatcher and the
//!    executing worker stamp the marks as the request moves through the
//!    pool, and every [`Completion`] returns the track, so callers get
//!    a queue-wait / bind-wait / service / gather-wait breakdown
//!    instead of one opaque latency.
//! 2. **A live metrics registry** ([`Obs`]): monotone counters, signed
//!    gauges and fixed-memory log-bucketed histograms ([`LogHist`]),
//!    readable mid-run from any thread via [`Obs::snapshot`] without
//!    pausing the pool. Per-worker slots ([`WorkerObs`]) are relaxed
//!    atomics written by exactly one worker thread — never a global
//!    mutex on the hot path. The only locks are the dispatcher-owned
//!    per-group queue-depth map and the trace lanes below, each with a
//!    single steady-state writer.
//! 3. **Chrome trace export** ([`Obs::chrome_trace_json`]): when the
//!    server starts with tracing on, span events also land in bounded
//!    per-lane buffers (lane 0 = dispatcher + caller marks, lane
//!    `1 + w` = worker `w`) and serialize as Chrome `trace_event` JSON
//!    loadable in Perfetto / `chrome://tracing`. With tracing off no
//!    event strings are ever built.
//!
//! [`Request`]: crate::serve::Request
//! [`Completion`]: crate::serve::Completion

use crate::serve::engine::EngineEvent;
use crate::serve::ModelKey;
use crate::util::json::Json;
use std::collections::HashMap;
use std::sync::atomic::Ordering::{Acquire, Relaxed, Release};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Nanoseconds of `d`, saturating at `u64::MAX`.
pub(crate) fn dur_ns(d: Duration) -> u64 {
    u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
}

fn dur_us(a: Instant, b: Instant) -> f64 {
    b.saturating_duration_since(a).as_secs_f64() * 1e6
}

fn jobj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Non-finite values serialize as `null`, matching the `ServeReport`
/// convention.
fn jnum(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

fn jint(v: u64) -> Json {
    Json::Num(v as f64)
}

// ---------------------------------------------------------------------------
// Per-request lifecycle spans
// ---------------------------------------------------------------------------

/// Timestamp marks a request accumulates on its way through the pool.
/// Marks are optional because a request dies mid-flight on shutdown;
/// every derived duration treats a missing or out-of-order mark as
/// zero (saturating) rather than panicking.
#[derive(Debug, Clone, Copy)]
pub struct SpanTrack {
    /// Caller handed the request to the server.
    pub enqueued: Instant,
    /// Dispatcher closed the batch containing this request.
    pub batch_closed: Option<Instant>,
    /// The executing worker popped the batch from the dispatch queue.
    pub dispatched: Option<Instant>,
    /// The batch's model was resident on the worker (bind/rebind done).
    pub bound: Option<Instant>,
    /// This request's own execution started (earlier requests of the
    /// batch ran in between `bound` and here).
    pub started: Option<Instant>,
    /// This request's own execution finished.
    pub executed: Option<Instant>,
    /// All sibling shards finished (sharded requests only).
    pub gathered: Option<Instant>,
}

impl SpanTrack {
    pub fn new(enqueued: Instant) -> SpanTrack {
        SpanTrack {
            enqueued,
            batch_closed: None,
            dispatched: None,
            bound: None,
            started: None,
            executed: None,
            gathered: None,
        }
    }

    fn span(a: Option<Instant>, b: Option<Instant>) -> Duration {
        match (a, b) {
            (Some(a), Some(b)) => b.saturating_duration_since(a),
            _ => Duration::ZERO,
        }
    }

    /// Enqueue → dispatch-queue pop: everything before the executing
    /// worker first touched the request (batcher close window
    /// included).
    pub fn queue_wait(&self) -> Duration {
        SpanTrack::span(Some(self.enqueued), self.dispatched)
    }

    /// Dispatch-queue pop → model resident: the bind/rebind cost an
    /// LRU miss charges to this batch (near zero on a hit).
    pub fn bind_wait(&self) -> Duration {
        SpanTrack::span(self.dispatched, self.bound)
    }

    /// Bind done → this request's turn within the batch.
    pub fn batch_wait(&self) -> Duration {
        SpanTrack::span(self.bound, self.started)
    }

    /// This request's own execution time.
    pub fn service(&self) -> Duration {
        SpanTrack::span(self.started, self.executed)
    }

    /// Sharded requests: how long the first shard waited for the
    /// slowest sibling after finishing its own slice. Zero for
    /// whole-model requests.
    pub fn gather_wait(&self) -> Duration {
        SpanTrack::span(self.executed, self.gathered)
    }
}

// ---------------------------------------------------------------------------
// Log-bucketed histogram
// ---------------------------------------------------------------------------

const SUB_BITS: usize = 3;
const SUBS: usize = 1 << SUB_BITS;
/// 62 octaves x 8 sub-buckets covers the full `u64` range with a fixed
/// ~4 KiB footprint.
const N_BUCKETS: usize = (64 - SUB_BITS + 1) * SUBS;

/// Fixed-memory log-bucketed histogram (HDR-histogram-lite): values
/// below 8 are exact, larger values land in one of 8 sub-buckets per
/// power of two, so any reported quantile overshoots the exact value
/// by at most 12.5%. `record` is two relaxed atomic increments —
/// concurrent readers see a consistent-enough view for live quantiles.
pub struct LogHist {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for LogHist {
    fn default() -> LogHist {
        LogHist::new()
    }
}

impl LogHist {
    pub fn new() -> LogHist {
        LogHist {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    fn bucket(v: u64) -> usize {
        if v < SUBS as u64 {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros() as usize;
        let sub = ((v >> (msb - SUB_BITS)) - SUBS as u64) as usize;
        (msb - SUB_BITS + 1) * SUBS + sub
    }

    /// Largest value mapping to bucket `i` (the value `quantile`
    /// reports for ranks landing in that bucket).
    fn bucket_upper(i: usize) -> u64 {
        if i < SUBS {
            return i as u64;
        }
        let octave = i / SUBS;
        let sub = i % SUBS;
        let width = 1u64 << (octave - 1);
        let lower = ((SUBS + sub) as u64) << (octave - 1);
        // `lower + width - 1`, written overflow-safe for the top octave
        // where the upper bound is `u64::MAX`.
        lower + (width - 1)
    }

    pub fn record(&self, v: u64) {
        self.buckets[LogHist::bucket(v)].fetch_add(1, Relaxed);
        self.sum.fetch_add(v, Relaxed);
        self.count.fetch_add(1, Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Relaxed)
    }

    /// Mean of all recorded values; `NaN` when empty.
    pub fn mean(&self) -> f64 {
        let n = self.count.load(Relaxed);
        if n == 0 {
            return f64::NAN;
        }
        self.sum.load(Relaxed) as f64 / n as f64
    }

    /// Streaming quantile: upper bound of the bucket holding the
    /// nearest-rank value (within 12.5% of the exact sorted answer).
    /// `NaN` when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count.load(Relaxed);
        if n == 0 {
            return f64::NAN;
        }
        let rank = (q.clamp(0.0, 1.0) * (n - 1) as f64).round() as u64;
        let mut cum = 0u64;
        let mut last = 0usize;
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Relaxed);
            if c == 0 {
                continue;
            }
            last = i;
            cum += c;
            if cum > rank {
                return LogHist::bucket_upper(i) as f64;
            }
        }
        // A concurrent `record` can bump `count` before its bucket;
        // the highest populated bucket is the right answer then.
        LogHist::bucket_upper(last) as f64
    }

    /// Count / mean / p50 / p95 / p99 with every value scaled by
    /// `scale` (e.g. `1e-6` to report nanosecond recordings in ms).
    pub fn summary(&self, scale: f64) -> HistSummary {
        HistSummary {
            count: self.count(),
            mean: self.mean() * scale,
            p50: self.quantile(0.50) * scale,
            p95: self.quantile(0.95) * scale,
            p99: self.quantile(0.99) * scale,
        }
    }
}

/// Point-in-time digest of one [`LogHist`]; non-finite fields
/// serialize as `null`.
#[derive(Debug, Clone, Copy)]
pub struct HistSummary {
    pub count: u64,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl HistSummary {
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("count", jint(self.count)),
            ("mean", jnum(self.mean)),
            ("p50", jnum(self.p50)),
            ("p95", jnum(self.p95)),
            ("p99", jnum(self.p99)),
        ])
    }
}

// ---------------------------------------------------------------------------
// Chrome trace sink
// ---------------------------------------------------------------------------

enum Ph {
    /// `"X"`: a complete span with a duration (µs).
    Complete(f64),
    /// `"i"`: a thread-scoped instant.
    Instant,
    /// `"b"`: async span begin, paired by id within a category.
    AsyncBegin(u64),
    /// `"e"`: async span end.
    AsyncEnd(u64),
}

struct TraceEvent {
    name: String,
    cat: &'static str,
    ph: Ph,
    ts_us: f64,
    args: Vec<(&'static str, Json)>,
}

impl TraceEvent {
    fn new(name: String, cat: &'static str, ph: Ph, ts_us: f64) -> TraceEvent {
        TraceEvent { name, cat, ph, ts_us, args: Vec::new() }
    }

    fn to_json(&self, tid: usize) -> Json {
        let mut pairs = vec![
            ("name", Json::Str(self.name.clone())),
            ("cat", Json::Str(self.cat.to_string())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(tid as f64)),
            ("ts", Json::Num(self.ts_us)),
        ];
        match self.ph {
            Ph::Complete(dur_us) => {
                pairs.push(("ph", Json::Str("X".to_string())));
                pairs.push(("dur", Json::Num(dur_us)));
            }
            Ph::Instant => {
                pairs.push(("ph", Json::Str("i".to_string())));
                pairs.push(("s", Json::Str("t".to_string())));
            }
            Ph::AsyncBegin(id) => {
                pairs.push(("ph", Json::Str("b".to_string())));
                pairs.push(("id", Json::Str(format!("{id}"))));
            }
            Ph::AsyncEnd(id) => {
                pairs.push(("ph", Json::Str("e".to_string())));
                pairs.push(("id", Json::Str(format!("{id}"))));
            }
        }
        if !self.args.is_empty() {
            let args = self.args.iter().map(|(k, v)| (*k, v.clone())).collect();
            pairs.push(("args", jobj(args)));
        }
        jobj(pairs)
    }
}

/// Per-lane event cap: past this, events are dropped (and counted)
/// rather than growing without bound on a long run.
const LANE_CAP: usize = 1 << 20;

/// Bounded per-lane trace buffers. Lane 0 collects dispatcher events
/// plus the caller-side submit/complete marks; lane `1 + w` belongs to
/// worker `w` alone. Each lane has at most two writer threads, so the
/// mutexes are effectively uncontended — and workers never share one.
struct TraceSink {
    lanes: Vec<Mutex<Vec<TraceEvent>>>,
    dropped: AtomicU64,
}

impl TraceSink {
    fn new(lanes: usize) -> TraceSink {
        TraceSink {
            lanes: (0..lanes).map(|_| Mutex::new(Vec::new())).collect(),
            dropped: AtomicU64::new(0),
        }
    }

    fn push(&self, lane: usize, ev: TraceEvent) {
        let mut buf = self.lanes[lane].lock().unwrap();
        if buf.len() < LANE_CAP {
            buf.push(ev);
        } else {
            self.dropped.fetch_add(1, Relaxed);
        }
    }
}

// ---------------------------------------------------------------------------
// The registry
// ---------------------------------------------------------------------------

/// Per-worker metric slots. Written by exactly one worker thread with
/// relaxed stores (plus `sessions`/`kv_bytes` refreshed after session
/// ops on that same worker's engine), read by any snapshotting thread.
#[derive(Default)]
pub(crate) struct WorkerObs {
    pub(crate) busy_ns: AtomicU64,
    pub(crate) idle_ns: AtomicU64,
    pub(crate) bind_ns: AtomicU64,
    pub(crate) batches: AtomicU64,
    pub(crate) requests: AtomicU64,
    pub(crate) binds: AtomicU64,
    pub(crate) evictions: AtomicU64,
    pub(crate) resident_models: AtomicU64,
    pub(crate) resident_bytes: AtomicU64,
    pub(crate) kv_bytes: AtomicU64,
    pub(crate) sessions: AtomicU64,
    // paged KV-pool gauges/counters, refreshed from the worker's
    // engine pool after every step batch (zero when the pool is
    // unpaged)
    pub(crate) kv_pages_used: AtomicU64,
    pub(crate) kv_pages_free: AtomicU64,
    pub(crate) kv_spilled_pages: AtomicU64,
    pub(crate) kv_spills: AtomicU64,
    pub(crate) kv_faults: AtomicU64,
    pub(crate) kv_evictions: AtomicU64,
}

type GroupKey = (Arc<ModelKey>, Option<usize>);

/// The live metrics registry one [`Server`] owns (shared as an `Arc`
/// so [`Obs::snapshot`] works mid-run from any thread).
///
/// [`Server`]: crate::serve::Server
pub struct Obs {
    epoch: Instant,
    worker_budget: Option<usize>,
    submitted: AtomicU64,
    completed: AtomicU64,
    /// Submissions refused at the admission gate (queue depth limit or
    /// KV page budget).
    rejected: AtomicU64,
    batches_closed: AtomicU64,
    sessions_opened: AtomicU64,
    sessions_closed: AtomicU64,
    /// Batches waiting in the shared (any-worker) dispatch queue.
    queue_shared: AtomicI64,
    /// Batches waiting in each worker-pinned dispatch queue.
    queue_pinned: Vec<AtomicI64>,
    /// Requests sitting in the batcher per `(model, target)` group.
    /// Dispatcher-only writer; entries drop out at zero depth.
    groups: Mutex<HashMap<GroupKey, i64>>,
    /// Shards submitted but not yet gathered into a completion.
    gather_outstanding: AtomicI64,
    /// Whether the pool serves from paged KV pools (set once at spawn;
    /// gates the `kv_pool` snapshot block).
    kv_enabled: AtomicBool,
    /// Per-worker KV page budget; `u64::MAX` = unbounded.
    kv_pages_budget: AtomicU64,
    /// Opens/steps refused at the page-budget admission gate
    /// ([`KvPolicy::Refuse`]); also counted in `rejected`.
    ///
    /// [`KvPolicy::Refuse`]: crate::serve::KvPolicy::Refuse
    kv_refused: AtomicU64,
    pub(crate) workers: Vec<WorkerObs>,
    queue_wait_ns: LogHist,
    bind_wait_ns: LogHist,
    service_ns: LogHist,
    gather_wait_ns: LogHist,
    latency_ns: LogHist,
    batch_occupancy: LogHist,
    trace: Option<TraceSink>,
}

impl Obs {
    pub(crate) fn new(n_workers: usize, worker_budget: Option<usize>, tracing: bool) -> Obs {
        Obs {
            epoch: Instant::now(),
            worker_budget,
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            batches_closed: AtomicU64::new(0),
            sessions_opened: AtomicU64::new(0),
            sessions_closed: AtomicU64::new(0),
            queue_shared: AtomicI64::new(0),
            queue_pinned: (0..n_workers).map(|_| AtomicI64::new(0)).collect(),
            groups: Mutex::new(HashMap::new()),
            gather_outstanding: AtomicI64::new(0),
            kv_enabled: AtomicBool::new(false),
            kv_pages_budget: AtomicU64::new(u64::MAX),
            kv_refused: AtomicU64::new(0),
            workers: (0..n_workers).map(|_| WorkerObs::default()).collect(),
            queue_wait_ns: LogHist::new(),
            bind_wait_ns: LogHist::new(),
            service_ns: LogHist::new(),
            gather_wait_ns: LogHist::new(),
            latency_ns: LogHist::new(),
            batch_occupancy: LogHist::new(),
            trace: tracing.then(|| TraceSink::new(n_workers + 1)),
        }
    }

    /// Whether trace-event collection is on. Call sites gate any
    /// event-string building on this so the off path stays free.
    pub(crate) fn trace_on(&self) -> bool {
        self.trace.is_some()
    }

    fn ts_us(&self, t: Instant) -> f64 {
        dur_us(self.epoch, t)
    }

    fn push_trace(&self, lane: usize, ev: TraceEvent) {
        if let Some(sink) = &self.trace {
            sink.push(lane, ev);
        }
    }

    pub(crate) fn on_submit(&self) {
        self.submitted.fetch_add(1, Relaxed);
    }

    /// Caller-side: a submission was refused at the admission gate.
    pub(crate) fn on_reject(&self) {
        self.rejected.fetch_add(1, Relaxed);
    }

    /// Server-spawn-side: the pool serves from paged KV pools with
    /// this per-worker page budget. Turns on the `kv_pool` snapshot
    /// block.
    pub(crate) fn configure_kv(&self, pages_per_worker: Option<usize>) {
        self.kv_enabled.store(true, Relaxed);
        self.kv_pages_budget.store(pages_per_worker.map_or(u64::MAX, |b| b as u64), Relaxed);
    }

    /// Caller-side: an open/step was refused at the page-budget
    /// admission gate. Counted both as a rejection (it sheds load like
    /// any other refusal) and in the pool-specific refusal counter.
    pub(crate) fn on_kv_refuse(&self) {
        self.rejected.fetch_add(1, Relaxed);
        self.kv_refused.fetch_add(1, Relaxed);
    }

    /// Requests submitted but not yet drained by the caller — the
    /// admission gate's depth. Single-caller exact (submits and drains
    /// happen on the owning thread); approximate from other threads.
    pub(crate) fn in_flight(&self) -> u64 {
        let completed = self.completed.load(Acquire);
        self.submitted.load(Relaxed).saturating_sub(completed)
    }

    pub(crate) fn on_session_open(&self) {
        self.sessions_opened.fetch_add(1, Relaxed);
    }

    pub(crate) fn on_session_close(&self) {
        self.sessions_closed.fetch_add(1, Relaxed);
    }

    /// Dispatch-queue depth gauge (shared queue when `target` is
    /// `None`). Called under the queue's own lock, so the gauge can
    /// never go negative.
    pub(crate) fn queue_add(&self, target: Option<usize>, delta: i64) {
        match target {
            Some(w) => self.queue_pinned[w].fetch_add(delta, Relaxed),
            None => self.queue_shared.fetch_add(delta, Relaxed),
        };
    }

    pub(crate) fn gather_add(&self, delta: i64) {
        self.gather_outstanding.fetch_add(delta, Relaxed);
    }

    /// Dispatcher-side: one request entered the batcher group.
    pub(crate) fn on_group_push(&self, key: &Arc<ModelKey>, target: Option<usize>) {
        let mut g = self.groups.lock().unwrap();
        *g.entry((Arc::clone(key), target)).or_insert(0) += 1;
    }

    /// Dispatcher-side: a closed batch left the batcher for the
    /// dispatch queue.
    pub(crate) fn on_batch_close(
        &self,
        batch_id: u64,
        key: &Arc<ModelKey>,
        target: Option<usize>,
        size: usize,
        ts: Instant,
    ) {
        self.batches_closed.fetch_add(1, Relaxed);
        self.batch_occupancy.record(size as u64);
        {
            let k = (Arc::clone(key), target);
            let mut g = self.groups.lock().unwrap();
            if let Some(d) = g.get_mut(&k) {
                *d -= size as i64;
                if *d <= 0 {
                    g.remove(&k);
                }
            }
        }
        if self.trace_on() {
            let name = format!("close batch {batch_id} ({key}, n={size})");
            self.push_trace(0, TraceEvent::new(name, "batcher", Ph::Instant, self.ts_us(ts)));
        }
    }

    /// Worker-side: an iteration-level step batch was formed from
    /// session lane heads (no batcher group to decrement — session
    /// traffic never enters the batcher).
    pub(crate) fn on_step_batch(
        &self,
        batch_id: u64,
        key: &Arc<ModelKey>,
        worker: usize,
        size: usize,
        ts: Instant,
    ) {
        self.batches_closed.fetch_add(1, Relaxed);
        self.batch_occupancy.record(size as u64);
        if self.trace_on() {
            let name = format!("step batch {batch_id} ({key}, n={size}, worker {worker})");
            self.push_trace(0, TraceEvent::new(name, "batcher", Ph::Instant, self.ts_us(ts)));
        }
    }

    /// Worker-side: fold one executed request's span breakdown into
    /// the streaming histograms.
    pub(crate) fn record_exec(&self, span: &SpanTrack) {
        self.queue_wait_ns.record(dur_ns(span.queue_wait()));
        self.bind_wait_ns.record(dur_ns(span.bind_wait()));
        self.service_ns.record(dur_ns(span.service()));
    }

    /// Caller-side: a fully gathered completion left the server.
    pub(crate) fn on_complete(&self, id: u64, latency: Duration, span: &SpanTrack) {
        // Release pairs with the Acquire load in `snapshot` so a
        // concurrent reader that sees this completion also sees its
        // (earlier, same-thread) submit — `completed` can never be
        // observed ahead of `submitted`.
        self.completed.fetch_add(1, Release);
        self.latency_ns.record(dur_ns(latency));
        self.gather_wait_ns.record(dur_ns(span.gather_wait()));
        if self.trace_on() {
            let end = span.gathered.or(span.executed).unwrap_or_else(Instant::now);
            let ts = self.ts_us(end);
            let ev = TraceEvent::new(format!("req {id}"), "request", Ph::AsyncEnd(id), ts);
            self.push_trace(0, ev);
        }
    }

    pub(crate) fn trace_request_begin(&self, id: u64, key: &ModelKey, ts: Instant) {
        if !self.trace_on() {
            return;
        }
        let ts = self.ts_us(ts);
        let mut ev = TraceEvent::new(format!("req {id}"), "request", Ph::AsyncBegin(id), ts);
        ev.args.push(("model", Json::Str(key.to_string())));
        self.push_trace(0, ev);
    }

    /// One request's own execution, as an `"X"` span on the worker
    /// lane (nests inside the batch span).
    pub(crate) fn trace_exec(
        &self,
        wi: usize,
        id: u64,
        shard: Option<usize>,
        t0: Instant,
        t1: Instant,
    ) {
        if !self.trace_on() {
            return;
        }
        let name = match shard {
            Some(s) => format!("req {id} shard {s}"),
            None => format!("req {id}"),
        };
        let ev = TraceEvent::new(name, "exec", Ph::Complete(dur_us(t0, t1)), self.ts_us(t0));
        self.push_trace(1 + wi, ev);
    }

    /// A whole batch's residence on a worker, pop → last request done.
    pub(crate) fn trace_batch(
        &self,
        wi: usize,
        batch_id: u64,
        key: &ModelKey,
        size: usize,
        t0: Instant,
        t1: Instant,
    ) {
        if !self.trace_on() {
            return;
        }
        let name = format!("batch {batch_id} ({key}, n={size})");
        let ev = TraceEvent::new(name, "batch", Ph::Complete(dur_us(t0, t1)), self.ts_us(t0));
        self.push_trace(1 + wi, ev);
    }

    /// The bind/rebind window at the head of a batch (only emitted
    /// when the engine actually had to bind).
    pub(crate) fn trace_bind(&self, wi: usize, key: &ModelKey, t0: Instant, t1: Instant) {
        if !self.trace_on() {
            return;
        }
        let name = format!("bind {key}");
        let ev = TraceEvent::new(name, "bind", Ph::Complete(dur_us(t0, t1)), self.ts_us(t0));
        self.push_trace(1 + wi, ev);
    }

    /// Engine bind-table churn (LRU evictions, new binds) as instants
    /// on the worker lane.
    pub(crate) fn trace_engine_events(&self, wi: usize, events: Vec<EngineEvent>, ts: Instant) {
        if !self.trace_on() {
            return;
        }
        for ev in events {
            let (name, cat) = match ev {
                EngineEvent::Bound(k) => (format!("bound {k}"), "engine"),
                EngineEvent::Evicted(k) => (format!("evict {k}"), "evict"),
            };
            self.push_trace(1 + wi, TraceEvent::new(name, cat, Ph::Instant, self.ts_us(ts)));
        }
    }

    /// Session open/close marks on the dispatcher lane.
    pub(crate) fn trace_session(&self, name: String, ts: Instant) {
        if !self.trace_on() {
            return;
        }
        self.push_trace(0, TraceEvent::new(name, "session", Ph::Instant, self.ts_us(ts)));
    }

    /// Point-in-time view of every counter, gauge and histogram.
    /// Callable from any thread while the pool runs; counters are
    /// monotone across snapshots and gauges are never negative.
    pub fn snapshot(&self) -> ObsSnapshot {
        let workers = self
            .workers
            .iter()
            .enumerate()
            .map(|(i, w)| {
                let busy = Duration::from_nanos(w.busy_ns.load(Relaxed));
                let idle = Duration::from_nanos(w.idle_ns.load(Relaxed));
                let denom = (busy + idle).as_secs_f64();
                WorkerSnapshot {
                    worker: i,
                    busy,
                    idle,
                    bind_time: Duration::from_nanos(w.bind_ns.load(Relaxed)),
                    utilization: if denom > 0.0 { busy.as_secs_f64() / denom } else { f64::NAN },
                    batches: w.batches.load(Relaxed),
                    requests: w.requests.load(Relaxed),
                    binds: w.binds.load(Relaxed),
                    evictions: w.evictions.load(Relaxed),
                    resident_models: w.resident_models.load(Relaxed),
                    resident_bytes: w.resident_bytes.load(Relaxed),
                    kv_bytes: w.kv_bytes.load(Relaxed),
                    kv_pages: w.kv_pages_used.load(Relaxed),
                    sessions: w.sessions.load(Relaxed),
                }
            })
            .collect();
        let kv_pool = self.kv_enabled.load(Relaxed).then(|| {
            let ws = &self.workers;
            let budget = self.kv_pages_budget.load(Relaxed);
            KvPoolSnapshot {
                pages_per_worker: (budget != u64::MAX).then_some(budget as usize),
                pages_used: ws.iter().map(|w| w.kv_pages_used.load(Relaxed)).sum(),
                pages_free: ws.iter().map(|w| w.kv_pages_free.load(Relaxed)).sum(),
                spilled_pages: ws.iter().map(|w| w.kv_spilled_pages.load(Relaxed)).sum(),
                spills: ws.iter().map(|w| w.kv_spills.load(Relaxed)).sum(),
                faults: ws.iter().map(|w| w.kv_faults.load(Relaxed)).sum(),
                evictions: ws.iter().map(|w| w.kv_evictions.load(Relaxed)).sum(),
                refusals: self.kv_refused.load(Relaxed),
            }
        });
        let mut group_depths: Vec<GroupDepth> = self
            .groups
            .lock()
            .unwrap()
            .iter()
            .map(|((key, target), &depth)| GroupDepth {
                model: key.to_string(),
                target: *target,
                depth,
            })
            .collect();
        group_depths.sort_by(|a, b| (&a.model, a.target).cmp(&(&b.model, b.target)));
        // `completed` is read first (Acquire, pairing with the Release
        // increment) so the pair is always consistent: any completion
        // visible here implies its submit is visible too.
        let completed = self.completed.load(Acquire);
        ObsSnapshot {
            uptime: self.epoch.elapsed(),
            submitted: self.submitted.load(Relaxed),
            completed,
            rejected: self.rejected.load(Relaxed),
            batches_closed: self.batches_closed.load(Relaxed),
            sessions_opened: self.sessions_opened.load(Relaxed),
            sessions_closed: self.sessions_closed.load(Relaxed),
            queue_shared: self.queue_shared.load(Relaxed),
            queue_pinned: self.queue_pinned.iter().map(|g| g.load(Relaxed)).collect(),
            group_depths,
            gather_outstanding: self.gather_outstanding.load(Relaxed),
            trace_dropped: self.trace.as_ref().map_or(0, |t| t.dropped.load(Relaxed)),
            worker_budget: self.worker_budget,
            kv_pool,
            workers,
            queue_wait_ms: self.queue_wait_ns.summary(1e-6),
            bind_wait_ms: self.bind_wait_ns.summary(1e-6),
            service_ms: self.service_ns.summary(1e-6),
            gather_wait_ms: self.gather_wait_ns.summary(1e-6),
            latency_ms: self.latency_ns.summary(1e-6),
            batch_occupancy: self.batch_occupancy.summary(1.0),
        }
    }

    /// Serialize the trace buffers as Chrome `trace_event` JSON
    /// (object form: `{"traceEvents": [...]}`), loadable in Perfetto
    /// and `chrome://tracing`. Lane metadata is always present; the
    /// event list is empty when the server ran without tracing.
    pub fn chrome_trace_json(&self) -> Json {
        let mut events: Vec<Json> = Vec::new();
        for tid in 0..self.workers.len() + 1 {
            let name =
                if tid == 0 { "dispatcher".to_string() } else { format!("worker {}", tid - 1) };
            events.push(jobj(vec![
                ("name", Json::Str("thread_name".to_string())),
                ("ph", Json::Str("M".to_string())),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(tid as f64)),
                ("args", jobj(vec![("name", Json::Str(name))])),
            ]));
        }
        if let Some(sink) = &self.trace {
            let mut timed: Vec<(f64, Json)> = Vec::new();
            for (tid, lane) in sink.lanes.iter().enumerate() {
                for ev in lane.lock().unwrap().iter() {
                    timed.push((ev.ts_us, ev.to_json(tid)));
                }
            }
            timed.sort_by(|a, b| a.0.total_cmp(&b.0));
            events.extend(timed.into_iter().map(|(_, j)| j));
        }
        jobj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::Str("ms".to_string())),
        ])
    }
}

// ---------------------------------------------------------------------------
// Snapshots
// ---------------------------------------------------------------------------

/// Requests waiting in one batcher `(model, target)` group.
#[derive(Debug, Clone)]
pub struct GroupDepth {
    pub model: String,
    pub target: Option<usize>,
    pub depth: i64,
}

/// One worker's row in an [`ObsSnapshot`].
#[derive(Debug, Clone)]
pub struct WorkerSnapshot {
    pub worker: usize,
    /// Time spent executing batches (bind included).
    pub busy: Duration,
    /// Time spent blocked on the dispatch queue.
    pub idle: Duration,
    /// Portion of `busy` spent binding/rebinding models.
    pub bind_time: Duration,
    /// `busy / (busy + idle)`; `NaN` before the worker first wakes.
    pub utilization: f64,
    pub batches: u64,
    pub requests: u64,
    pub binds: u64,
    pub evictions: u64,
    pub resident_models: u64,
    pub resident_bytes: u64,
    pub kv_bytes: u64,
    /// Resident KV-pool pages on this worker (0 when unpaged).
    pub kv_pages: u64,
    pub sessions: u64,
}

impl WorkerSnapshot {
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("worker", jint(self.worker as u64)),
            ("busy_ms", jnum(self.busy.as_secs_f64() * 1e3)),
            ("idle_ms", jnum(self.idle.as_secs_f64() * 1e3)),
            ("bind_ms", jnum(self.bind_time.as_secs_f64() * 1e3)),
            ("utilization", jnum(self.utilization)),
            ("batches", jint(self.batches)),
            ("requests", jint(self.requests)),
            ("binds", jint(self.binds)),
            ("evictions", jint(self.evictions)),
            ("resident_models", jint(self.resident_models)),
            ("resident_bytes", jint(self.resident_bytes)),
            ("kv_bytes", jint(self.kv_bytes)),
            ("kv_pages", jint(self.kv_pages)),
            ("sessions", jint(self.sessions)),
        ])
    }
}

/// Pool-wide paged-KV occupancy and policy counters, aggregated over
/// every worker's [`KvPool`]. Present in an [`ObsSnapshot`] (and the
/// `ServeReport`) only when the server was spawned with
/// [`ServeConfig::kv`] set.
///
/// [`KvPool`]: crate::serve::kvpool::KvPool
/// [`ServeConfig::kv`]: crate::serve::ServeConfig::kv
#[derive(Debug, Clone, Copy)]
pub struct KvPoolSnapshot {
    /// Configured page budget per worker (`None` = unbounded).
    pub pages_per_worker: Option<usize>,
    /// Pages backing resident sessions, summed over workers.
    pub pages_used: u64,
    /// Free-listed pages awaiting reuse, summed over workers.
    pub pages_free: u64,
    /// Pages currently parked in overflow arenas.
    pub spilled_pages: u64,
    /// Sessions spilled to an arena (lifetime).
    pub spills: u64,
    /// Sessions faulted back from an arena (lifetime).
    pub faults: u64,
    /// Sessions evicted under budget pressure (lifetime).
    pub evictions: u64,
    /// Opens/steps refused at the page-budget admission gate.
    pub refusals: u64,
}

impl KvPoolSnapshot {
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("pages_per_worker", self.pages_per_worker.map_or(Json::Null, |b| jint(b as u64))),
            ("pages_used", jint(self.pages_used)),
            ("pages_free", jint(self.pages_free)),
            ("spilled_pages", jint(self.spilled_pages)),
            ("spills", jint(self.spills)),
            ("faults", jint(self.faults)),
            ("evictions", jint(self.evictions)),
            ("refusals", jint(self.refusals)),
        ])
    }
}

/// Point-in-time view of the registry (see [`Obs::snapshot`]).
#[derive(Debug, Clone)]
pub struct ObsSnapshot {
    pub uptime: Duration,
    pub submitted: u64,
    pub completed: u64,
    /// Submissions refused at the admission gate (queue depth limit or
    /// KV page budget).
    pub rejected: u64,
    pub batches_closed: u64,
    pub sessions_opened: u64,
    pub sessions_closed: u64,
    pub queue_shared: i64,
    pub queue_pinned: Vec<i64>,
    pub group_depths: Vec<GroupDepth>,
    pub gather_outstanding: i64,
    /// Trace events discarded after a lane hit its cap.
    pub trace_dropped: u64,
    /// Per-worker bind-table byte budget, for reading
    /// `resident_bytes` against it.
    pub worker_budget: Option<usize>,
    /// Aggregated paged-KV pool state (`None` when the pool is
    /// unpaged).
    pub kv_pool: Option<KvPoolSnapshot>,
    pub workers: Vec<WorkerSnapshot>,
    pub queue_wait_ms: HistSummary,
    pub bind_wait_ms: HistSummary,
    pub service_ms: HistSummary,
    pub gather_wait_ms: HistSummary,
    pub latency_ms: HistSummary,
    /// Requests per closed batch (unscaled counts).
    pub batch_occupancy: HistSummary,
}

impl ObsSnapshot {
    pub fn to_json(&self) -> Json {
        let groups = self
            .group_depths
            .iter()
            .map(|g| {
                jobj(vec![
                    ("model", Json::Str(g.model.clone())),
                    ("target", g.target.map_or(Json::Null, |t| jint(t as u64))),
                    ("depth", Json::Num(g.depth as f64)),
                ])
            })
            .collect();
        jobj(vec![
            ("uptime_s", jnum(self.uptime.as_secs_f64())),
            ("submitted", jint(self.submitted)),
            ("completed", jint(self.completed)),
            ("rejected", jint(self.rejected)),
            ("batches_closed", jint(self.batches_closed)),
            ("sessions_opened", jint(self.sessions_opened)),
            ("sessions_closed", jint(self.sessions_closed)),
            ("queue_shared", Json::Num(self.queue_shared as f64)),
            (
                "queue_pinned",
                Json::Arr(self.queue_pinned.iter().map(|&d| Json::Num(d as f64)).collect()),
            ),
            ("group_depths", Json::Arr(groups)),
            ("gather_outstanding", Json::Num(self.gather_outstanding as f64)),
            ("trace_dropped", jint(self.trace_dropped)),
            ("worker_budget", self.worker_budget.map_or(Json::Null, |b| jint(b as u64))),
            ("kv_pool", self.kv_pool.map_or(Json::Null, |p| p.to_json())),
            ("workers", Json::Arr(self.workers.iter().map(WorkerSnapshot::to_json).collect())),
            ("queue_wait_ms", self.queue_wait_ms.to_json()),
            ("bind_wait_ms", self.bind_wait_ms.to_json()),
            ("service_ms", self.service_ms.to_json()),
            ("gather_wait_ms", self.gather_wait_ms.to_json()),
            ("latency_ms", self.latency_ms.to_json()),
            ("batch_occupancy", self.batch_occupancy.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_upper_bounds_value() {
        let samples: Vec<u64> = (0..4096)
            .chain((SUB_BITS as u32 + 1..64).map(|s| (1u64 << s) - 1))
            .chain((SUB_BITS as u32 + 1..64).map(|s| 1u64 << s))
            .chain([u64::MAX / 7, u64::MAX / 2, u64::MAX - 1, u64::MAX])
            .collect();
        for &v in &samples {
            let b = LogHist::bucket(v);
            assert!(b < N_BUCKETS, "bucket {b} out of range for {v}");
            let hi = LogHist::bucket_upper(b);
            assert!(hi >= v, "upper {hi} < value {v}");
            assert!(hi - v <= v / 8, "upper {hi} overshoots {v} by more than 12.5%");
        }
    }

    #[test]
    fn buckets_partition_monotonically() {
        for i in 1..N_BUCKETS {
            assert!(LogHist::bucket_upper(i) > LogHist::bucket_upper(i - 1), "at {i}");
        }
        for v in 1u64..10_000 {
            assert!(LogHist::bucket(v) >= LogHist::bucket(v - 1), "at {v}");
        }
        assert_eq!(LogHist::bucket(u64::MAX), N_BUCKETS - 1);
        assert_eq!(LogHist::bucket_upper(N_BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn quantile_small_values_exact() {
        let h = LogHist::new();
        for v in 1..=7u64 {
            h.record(v);
        }
        assert_eq!(h.quantile(0.0), 1.0);
        assert_eq!(h.quantile(0.5), 4.0);
        assert_eq!(h.quantile(1.0), 7.0);
        assert_eq!(h.count(), 7);
        assert!((h.mean() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn empty_hist_is_nan_and_null() {
        let h = LogHist::new();
        assert!(h.quantile(0.5).is_nan());
        assert!(h.mean().is_nan());
        let s = h.summary(1.0);
        assert_eq!(s.count, 0);
        assert_eq!(s.to_json().get("p99").unwrap(), &Json::Null);
    }

    #[test]
    fn span_missing_or_reordered_marks_are_zero() {
        let t0 = Instant::now();
        let mut s = SpanTrack::new(t0);
        assert_eq!(s.queue_wait(), Duration::ZERO);
        assert_eq!(s.service(), Duration::ZERO);
        s.dispatched = Some(t0 + Duration::from_millis(5));
        assert_eq!(s.queue_wait(), Duration::from_millis(5));
        // out-of-order marks saturate instead of panicking
        s.bound = Some(t0);
        assert_eq!(s.bind_wait(), Duration::ZERO);
    }

    #[test]
    fn snapshot_counts_groups_and_json_shape() {
        let obs = Obs::new(2, Some(1 << 20), true);
        let key = Arc::new(ModelKey::new("m", "d"));
        obs.on_submit();
        obs.on_group_push(&key, None);
        obs.on_group_push(&key, None);
        obs.queue_add(None, 1);
        obs.queue_add(Some(1), 1);
        let snap = obs.snapshot();
        assert_eq!(snap.submitted, 1);
        assert_eq!(snap.queue_shared, 1);
        assert_eq!(snap.queue_pinned, vec![0, 1]);
        assert_eq!(snap.group_depths.len(), 1);
        assert_eq!(snap.group_depths[0].depth, 2);
        let j = snap.to_json();
        assert_eq!(j.get("submitted").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(j.get("latency_ms").unwrap().get("p99").unwrap(), &Json::Null);
        assert_eq!(j.get("workers").unwrap().as_arr().unwrap().len(), 2);

        // closing a batch drains the group and drops the entry at zero
        obs.on_batch_close(0, &key, None, 2, Instant::now());
        assert!(obs.snapshot().group_depths.is_empty());
        assert_eq!(obs.snapshot().batches_closed, 1);

        let trace = obs.chrome_trace_json();
        let evs = trace.get("traceEvents").unwrap().as_arr().unwrap();
        // 3 lane-name metadata events + the batch-close instant
        assert_eq!(evs.len(), 4);
    }

    #[test]
    fn trace_off_emits_only_lane_metadata() {
        let obs = Obs::new(1, None, false);
        assert!(!obs.trace_on());
        obs.trace_request_begin(0, &ModelKey::new("m", "d"), Instant::now());
        let evs_json = obs.chrome_trace_json();
        let evs = evs_json.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
    }
}
