//! Shard-aware model placement: the [`Deployment`] abstraction.
//!
//! A `Deployment` replaces "`ModelHandle` = whole model on one worker"
//! as the unit of serving: it owns a [`ShardPlan`] — a per-layer split
//! of the widest layer's `cout` range into contiguous shards, computed
//! from layer width vs. a per-worker machine buffer budget — and one
//! prepared (sub)model per shard. Small models get `ShardPlan::Whole`,
//! so the existing one-model-one-worker path is the degenerate case.
//!
//! The split exploits the same structure SONIQ's kernels are built on:
//! the output-channel axis partitions cleanly, the sliced kernel is the
//! *ordinary* emitter over a narrower plan (`codegen::shard`), and the
//! reduction where the split axis re-enters as a contraction axis is
//! exact — every shard's accumulators live on the fixed-point grid, so
//! the f32 gather sum rounds nothing and sharded outputs stay
//! **bit-identical** to the whole-model run.
//!
//! Shardable shapes: the widest kernel node (`Conv` dense or static
//! `Matmul`) is sliced by `cout`; from there the planner walks a chain
//! of channel-aligned ops (`Gap`, `Gelu` — per-channel, so they run in
//! sliced space) and either reaches the model output
//! ([`GatherMode::Concat`]: partial `cout` slices concatenate) or a
//! final dense kernel contracting the split axis
//! ([`GatherMode::Reduce`]: the consumer is sliced by `cin`/`k` and the
//! shards' partial sums reduce). Anything else — mid-graph residuals,
//! softmax over the split axis, dynamic-operand GEMMs, decoder step
//! graphs — refuses to shard with a descriptive error rather than
//! serving wrong numbers.
//!
//! [`crate::serve::Server::deploy`] pins each shard to a worker and
//! scatter/gathers requests across them; [`Deployment::gather_outputs`]
//! is the same assembly the serving gather buffer uses, so tests can
//! drive shards directly against [`crate::serve::EngineMachine`]s.

use crate::codegen::shard as cshard;
use crate::codegen::LayerKind;
use crate::serve::engine::{conv_bind_bytes, matmul_bind_bytes, PreparedModel, PreparedOp};
use crate::serve::session::CausalAvOp;
use crate::serve::{ModelHandle, ModelKey};
use crate::sim::network::{ConvLayerCfg, MatmulCfg, Node, Tensor};
use anyhow::{bail, Result};
use std::sync::Arc;

/// How a deployment is sized and split.
#[derive(Debug, Clone, Copy, Default)]
pub struct DeployConfig {
    /// per-worker machine buffer budget in bytes; a model whose bind
    /// footprint exceeds it is split until every shard fits (`None` =
    /// unlimited, shard only on explicit request)
    pub worker_budget: Option<usize>,
    /// explicit shard count (>= 2 to force sharding; `None`/`Some(1)` =
    /// derive from the budget)
    pub shards: Option<usize>,
}

/// How a sharded deployment's partial outputs combine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GatherMode {
    /// the split `cout` axis survives to the model output: concatenate
    /// the shards' channel slices
    Concat,
    /// the split axis re-enters as the final kernel's contraction axis:
    /// sum the shards' partial outputs (exact — fixed-point grid)
    Reduce,
}

/// The per-layer split of a deployment.
#[derive(Debug, Clone)]
pub enum ShardPlan {
    /// the whole model binds to one worker (small models; the
    /// degenerate, PR-4-compatible case)
    Whole,
    Sharded {
        /// graph index of the `cout`-sliced (wide) kernel node
        split_node: usize,
        /// graph index of the `cin`/`k`-sliced reduce consumer
        /// (`None` for [`GatherMode::Concat`])
        consumer_node: Option<usize>,
        /// per-shard contiguous `[start, end)` ranges of the split
        /// node's `cout` axis
        slices: Vec<(usize, usize)>,
        gather: GatherMode,
    },
}

impl ShardPlan {
    /// Number of shards this plan places (1 for `Whole`).
    pub fn num_shards(&self) -> usize {
        match self {
            ShardPlan::Whole => 1,
            ShardPlan::Sharded { slices, .. } => slices.len(),
        }
    }
}

/// A model prepared for placement: the shard plan plus one prepared
/// (sub)model per shard. Shard handles carry shard-tagged [`ModelKey`]s
/// (`design#s<i>of<n>`), so per-worker bind tables — and the batcher's
/// `(model, target)` groups — never collide even when two shards of one
/// model land on the same machine.
#[derive(Debug)]
pub struct Deployment {
    key: Arc<ModelKey>,
    plan: ShardPlan,
    handles: Vec<ModelHandle>,
}

/// Ops that are per-channel on the split axis and may sit between the
/// split kernel and the gather point, executing in sliced space.
fn channel_aligned(node: &Node) -> bool {
    matches!(node, Node::Gap { .. } | Node::Gelu { .. })
}

/// Machine bytes binding this node allocates (0 for buffer-less
/// epilogue/layout ops). Exact for every kernel kind: conv/GEMM bytes
/// come from the shared plan arithmetic, and the causal A·V form —
/// which the executor prepares as the much smaller `CausalAvOp`, not a
/// full GEMM — asks the op itself (its `prepare` copies dims only, so
/// this stays cheap). `CachedAttn` appears only in decoder step graphs,
/// which `Deployment::build` budget-checks via the exact
/// `PreparedModel::bind_bytes` instead.
fn node_bind_bytes(node: &Node) -> usize {
    match node {
        Node::Conv { cfg, .. } => conv_bind_bytes(&cfg.plan),
        Node::MatmulDyn { cfg, transpose_b, .. } if cfg.causal && !*transpose_b => {
            CausalAvOp::prepare(cfg).bind_bytes()
        }
        Node::Matmul { cfg, .. } | Node::MatmulDyn { cfg, .. } => matmul_bind_bytes(&cfg.plan),
        _ => 0,
    }
}

/// `cout` width of a sliceable kernel node (None = not sliceable).
fn split_width(node: &Node) -> Option<usize> {
    match node {
        Node::Conv { cfg, .. } if cfg.plan.kind == LayerKind::Dense => Some(cfg.plan.cout),
        Node::Matmul { cfg, .. } => Some(cfg.plan.n),
        _ => None,
    }
}

/// Nodes whose inputs include `id` (dataflow from the shared
/// [`Node::inputs`], the same wiring the executor runs).
fn consumers(nodes: &[Node], id: usize) -> Vec<usize> {
    nodes
        .iter()
        .enumerate()
        .filter(|(_, n)| n.inputs().contains(&id))
        .map(|(i, _)| i)
        .collect()
}

/// Contiguous `[start, end)` slices splitting `width` channels into `n`
/// near-equal shards (earlier shards take the remainder).
fn even_slices(width: usize, n: usize) -> Vec<(usize, usize)> {
    let (base, rem) = (width / n, width % n);
    let mut out = Vec::with_capacity(n);
    let mut pos = 0usize;
    for i in 0..n {
        let w = base + usize::from(i < rem);
        out.push((pos, pos + w));
        pos += w;
    }
    out
}

/// Bind bytes of the split node restricted to `range`.
fn sliced_split_bytes(node: &Node, (s, e): (usize, usize)) -> usize {
    match node {
        Node::Conv { cfg, .. } => conv_bind_bytes(&cshard::slice_plan_cout(&cfg.plan, s, e)),
        Node::Matmul { cfg, .. } => matmul_bind_bytes(&cfg.plan.slice_n(s, e)),
        _ => unreachable!("split node is a dense kernel"),
    }
}

/// Bind bytes of the reduce consumer restricted to contraction `range`.
fn sliced_consumer_bytes(node: &Node, (s, e): (usize, usize)) -> usize {
    match node {
        Node::Conv { cfg, .. } => conv_bind_bytes(&cshard::slice_plan_cin(&cfg.plan, s, e)),
        Node::Matmul { cfg, .. } => matmul_bind_bytes(&cfg.plan.slice_k(s, e)),
        _ => unreachable!("reduce consumer is a dense kernel"),
    }
}

/// Validate a reduce consumer: a dense kernel contracting exactly the
/// split axis with a pure (grid-exact) epilogue.
fn check_consumer(nodes: &[Node], ci: usize, width: usize) -> Result<()> {
    match &nodes[ci] {
        Node::Conv { cfg, .. } => {
            if cfg.plan.kind != LayerKind::Dense {
                bail!("reduce consumer {} is not a dense kernel", cfg.plan.name);
            }
            if cfg.plan.cin != width {
                bail!(
                    "reduce consumer {} contracts {} channels, split axis has {width}",
                    cfg.plan.name,
                    cfg.plan.cin
                );
            }
            if !cfg.bn_scale.is_empty() || cfg.relu {
                bail!(
                    "reduce consumer {} has a BN/ReLU epilogue; partial sums would \
                     round off the fixed-point grid (gather must happen first)",
                    cfg.plan.name
                );
            }
        }
        Node::Matmul { cfg, .. } => {
            if cfg.plan.k != width {
                bail!(
                    "reduce consumer {} contracts {} channels, split axis has {width}",
                    cfg.plan.name,
                    cfg.plan.k
                );
            }
            if cfg.scale != 1.0 || cfg.causal {
                bail!(
                    "reduce consumer {} has a scaled/causal epilogue; partial sums \
                     would round off the fixed-point grid",
                    cfg.plan.name
                );
            }
        }
        _ => bail!("node {ci} consuming the split axis is not a dense kernel"),
    }
    Ok(())
}

/// Compute the shard plan for a stateless graph (see module docs for
/// the supported shapes).
fn plan_shards(nodes: &[Node], cfg: &DeployConfig) -> Result<ShardPlan> {
    let want = cfg.shards.filter(|&n| n >= 2);
    let est: Vec<usize> = nodes.iter().map(node_bind_bytes).collect();
    let total: usize = est.iter().sum();
    let over_budget = cfg.worker_budget.is_some_and(|b| total > b);
    if want.is_none() && !over_budget {
        return Ok(ShardPlan::Whole);
    }

    // the split node: widest bind footprint among sliceable kernels
    let split = (0..nodes.len())
        .filter(|&i| split_width(&nodes[i]).is_some() && est[i] > 0)
        .max_by_key(|&i| est[i]);
    let Some(split) = split else {
        bail!("model has no sliceable dense kernel to shard");
    };
    let width = split_width(&nodes[split]).expect("split node is sliceable");

    // walk the channel-aligned chain from the split node to the gather
    // point: the model output (Concat) or a final reduce kernel (Reduce)
    let last = nodes.len() - 1;
    let mut cur = split;
    let consumer_node = loop {
        let cs = consumers(nodes, cur);
        match cs.as_slice() {
            [] => {
                if cur != last {
                    bail!("split axis of node {split} dead-ends before the model output");
                }
                break None; // sliced channels reach the output: Concat
            }
            [c] => {
                if channel_aligned(&nodes[*c]) {
                    cur = *c; // per-channel op: runs in sliced space
                } else if *c == last {
                    check_consumer(nodes, *c, width)?;
                    break Some(*c);
                } else {
                    bail!(
                        "node {c} consumes the split axis mid-graph; only \
                         channel-aligned ops or a final reduce kernel may follow \
                         the split node"
                    );
                }
            }
            many => bail!(
                "split axis of node {split} fans out to {} consumers; sharding \
                 needs a single-consumer chain",
                many.len()
            ),
        }
    };
    let gather = if consumer_node.is_some() { GatherMode::Reduce } else { GatherMode::Concat };

    // shard count: explicit, or the smallest split where every shard's
    // bind footprint fits the worker budget
    let replicated: usize = total - est[split] - consumer_node.map(|c| est[c]).unwrap_or(0);
    let fits = |n: usize| -> bool {
        let Some(budget) = cfg.worker_budget else {
            return true;
        };
        even_slices(width, n).iter().all(|&r| {
            let mut bytes = replicated + sliced_split_bytes(&nodes[split], r);
            if let Some(c) = consumer_node {
                bytes += sliced_consumer_bytes(&nodes[c], r);
            }
            bytes <= budget
        })
    };
    let n = match want {
        Some(n) => {
            if n > width {
                bail!("--shards {n} exceeds the split axis width {width}");
            }
            if let Some(budget) = cfg.worker_budget {
                if !fits(n) {
                    bail!(
                        "{n} shards do not fit the {budget} B worker budget (the widest \
                         shard still exceeds it; raise the budget or the shard count)"
                    );
                }
            }
            n
        }
        None => {
            let budget = cfg.worker_budget.expect("over_budget implies a budget");
            let mut n = 2;
            loop {
                if n > width {
                    bail!(
                        "no shard split fits the {budget} B worker budget \
                         (replicated layers alone take {replicated} B)"
                    );
                }
                if fits(n) {
                    break n;
                }
                n += 1;
            }
        }
    };

    Ok(ShardPlan::Sharded {
        split_node: split,
        consumer_node,
        slices: even_slices(width, n),
        gather,
    })
}

fn slice_bn(v: &[f32], s: usize, e: usize) -> Vec<f32> {
    if v.is_empty() {
        Vec::new()
    } else {
        v[s..e].to_vec()
    }
}

/// `cout`-sliced clone of a conv node's config (the split kernel): the
/// cin-side plan, assignment, chunking and tail bias are untouched, and
/// the per-output-channel BN/ReLU epilogue slices with the channels.
fn conv_cout_slice(cfg: &ConvLayerCfg, s: usize, e: usize) -> ConvLayerCfg {
    ConvLayerCfg {
        plan: cshard::slice_plan_cout(&cfg.plan, s, e),
        weights: cshard::slice_dense_weights_cout(&cfg.plan, &cfg.weights, s, e),
        bn_scale: slice_bn(&cfg.bn_scale, s, e),
        bn_bias: slice_bn(&cfg.bn_bias, s, e),
        bn_mean: slice_bn(&cfg.bn_mean, s, e),
        bn_var: slice_bn(&cfg.bn_var, s, e),
        relu: cfg.relu,
    }
}

/// `cin`-sliced clone of a conv node's config (the reduce consumer);
/// [`check_consumer`] guarantees it carries no BN/ReLU to clone.
fn conv_cin_slice(cfg: &ConvLayerCfg, s: usize, e: usize) -> ConvLayerCfg {
    ConvLayerCfg {
        plan: cshard::slice_plan_cin(&cfg.plan, s, e),
        weights: cshard::slice_dense_weights_cin(&cfg.plan, &cfg.weights, s, e),
        bn_scale: cfg.bn_scale.clone(),
        bn_bias: cfg.bn_bias.clone(),
        bn_mean: cfg.bn_mean.clone(),
        bn_var: cfg.bn_var.clone(),
        relu: cfg.relu,
    }
}

fn matmul_n_slice(cfg: &MatmulCfg, w: &[f32], s: usize, e: usize) -> (MatmulCfg, Vec<f32>) {
    (
        MatmulCfg { plan: cfg.plan.slice_n(s, e), scale: cfg.scale, causal: cfg.causal },
        cshard::slice_gemm_weights_n(cfg.plan.k, cfg.plan.n, w, s, e),
    )
}

fn matmul_k_slice(cfg: &MatmulCfg, w: &[f32], s: usize, e: usize) -> (MatmulCfg, Vec<f32>) {
    (
        MatmulCfg { plan: cfg.plan.slice_k(s, e), scale: cfg.scale, causal: cfg.causal },
        cshard::slice_gemm_weights_k(cfg.plan.k, cfg.plan.n, w, s, e),
    )
}

/// The shard-`i` node list: the split kernel restricted to its `cout`
/// range, the reduce consumer (if any) restricted to the matching
/// contraction range, everything else replicated verbatim.
fn shard_nodes(
    nodes: &[Node],
    split: usize,
    consumer: Option<usize>,
    (s, e): (usize, usize),
) -> Vec<Node> {
    nodes
        .iter()
        .enumerate()
        .map(|(ni, node)| match node {
            Node::Conv { cfg, input } if ni == split => {
                Node::Conv { cfg: Box::new(conv_cout_slice(cfg, s, e)), input: *input }
            }
            Node::Matmul { cfg, weights, input } if ni == split => {
                let (cfg, weights) = matmul_n_slice(cfg, weights, s, e);
                Node::Matmul { cfg: Box::new(cfg), weights, input: *input }
            }
            Node::Conv { cfg, input } if Some(ni) == consumer => {
                Node::Conv { cfg: Box::new(conv_cin_slice(cfg, s, e)), input: *input }
            }
            Node::Matmul { cfg, weights, input } if Some(ni) == consumer => {
                let (cfg, weights) = matmul_k_slice(cfg, weights, s, e);
                Node::Matmul { cfg: Box::new(cfg), weights, input: *input }
            }
            other => other.clone(),
        })
        .collect()
}

impl Deployment {
    /// The degenerate whole-model deployment: one shard, the base key,
    /// the prepared model as-is. What [`crate::serve::Server::register`]
    /// wraps every plain registration in.
    pub fn whole(key: ModelKey, prepared: Arc<PreparedModel>) -> Deployment {
        let handle = ModelHandle::new(key, prepared);
        Deployment {
            key: Arc::clone(&handle.key),
            plan: ShardPlan::Whole,
            handles: vec![handle],
        }
    }

    /// Plan and prepare a deployment for `nodes` under `cfg`. Decoder
    /// models (`step_nodes` present) always deploy whole — KV sessions
    /// pin entire models — and refuse an explicit shard request.
    pub fn build(
        key: ModelKey,
        nodes: &[Node],
        step_nodes: Option<&[Node]>,
        cfg: &DeployConfig,
    ) -> Result<Deployment> {
        if let Some(step) = step_nodes {
            if cfg.shards.is_some_and(|n| n >= 2) {
                bail!("{key}: sharded decoders are unsupported (KV sessions pin whole models)");
            }
            let prepared = Arc::new(PreparedModel::prepare_decoder(nodes, step));
            if let Some(budget) = cfg.worker_budget {
                let need = prepared.bind_bytes();
                if need > budget {
                    bail!(
                        "{key}: decoder bind needs {need} B but the worker budget is \
                         {budget} B, and sharded decoders are unsupported — raise the \
                         budget"
                    );
                }
            }
            return Ok(Deployment::whole(key, prepared));
        }
        let plan = plan_shards(nodes, cfg)?;
        let ShardPlan::Sharded { split_node, consumer_node, ref slices, .. } = plan else {
            let prepared = Arc::new(PreparedModel::prepare(nodes));
            if let Some(budget) = cfg.worker_budget {
                // belt over the planner's estimate: the prepared ops
                // report their exact bind bytes, so estimator drift
                // surfaces here as a plan-time error, never as a
                // budgeted worker panicking at bind time
                let need = prepared.bind_bytes();
                if need > budget {
                    bail!(
                        "{key}: whole-model bind needs {need} B but the worker budget \
                         is {budget} B (the shard planner's estimate disagreed; this \
                         is a bug in the bind-byte estimators)"
                    );
                }
            }
            return Ok(Deployment::whole(key, prepared));
        };
        let n = slices.len();
        let handles = slices
            .iter()
            .enumerate()
            .map(|(i, &range)| {
                let sub = shard_nodes(nodes, split_node, consumer_node, range);
                ModelHandle::new(
                    ModelKey::new(key.model.clone(), format!("{}#s{i}of{n}", key.design)),
                    Arc::new(PreparedModel::prepare(&sub)),
                )
            })
            .collect();
        Ok(Deployment { key: Arc::new(key), plan, handles })
    }

    /// The deployment's base key (shard handles carry tagged variants).
    pub fn key(&self) -> &Arc<ModelKey> {
        &self.key
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    pub fn num_shards(&self) -> usize {
        self.handles.len()
    }

    pub fn is_sharded(&self) -> bool {
        self.handles.len() > 1
    }

    /// One handle per shard (a single whole-model handle when not
    /// sharded), in shard order.
    pub fn handles(&self) -> &[ModelHandle] {
        &self.handles
    }

    /// One-line plan description for logs/CLI.
    pub fn describe(&self) -> String {
        match &self.plan {
            ShardPlan::Whole => format!("{}: whole (1 shard)", self.key),
            ShardPlan::Sharded { split_node, slices, gather, .. } => format!(
                "{}: node {split_node} cout split into {} shards {:?}, gather = {:?}",
                self.key,
                slices.len(),
                slices,
                gather
            ),
        }
    }

    /// Assemble shard outputs (in shard order) into the model output —
    /// exactly what the serving gather buffer does. Concat stitches the
    /// channel slices back together; Reduce sums the partial outputs,
    /// which is exact because every shard's values sit on the kernel's
    /// fixed-point accumulator grid.
    pub fn gather_outputs(&self, parts: &[&Tensor]) -> Tensor {
        assert_eq!(parts.len(), self.num_shards(), "{}: one part per shard", self.key);
        match &self.plan {
            ShardPlan::Whole => parts[0].clone(),
            ShardPlan::Sharded { slices, gather: GatherMode::Concat, .. } => {
                let (h, w) = (parts[0].h, parts[0].w);
                let c_total = slices.last().expect("non-empty slices").1;
                let mut out = Tensor::zeros(h, w, c_total);
                for (p, &(s, e)) in parts.iter().zip(slices) {
                    assert_eq!((p.h, p.w, p.c), (h, w, e - s), "{}: shard shape", self.key);
                    let width = e - s;
                    for hw in 0..h * w {
                        out.data[hw * c_total + s..hw * c_total + e]
                            .copy_from_slice(&p.data[hw * width..(hw + 1) * width]);
                    }
                }
                out
            }
            ShardPlan::Sharded { gather: GatherMode::Reduce, .. } => {
                let mut out = parts[0].clone();
                for p in &parts[1..] {
                    assert_eq!(
                        (p.h, p.w, p.c),
                        (out.h, out.w, out.c),
                        "{}: shard shape",
                        self.key
                    );
                    for (o, v) in out.data.iter_mut().zip(&p.data) {
                        *o += v;
                    }
                }
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::{DataFormat, LayerPlan};
    use crate::smol::pattern_match::Assignment;

    fn conv_node(name: &str, cin: usize, cout: usize, hw: usize, input: usize) -> Node {
        Node::Conv {
            cfg: Box::new(ConvLayerCfg {
                plan: LayerPlan {
                    name: name.into(),
                    kind: LayerKind::Dense,
                    cin,
                    cout,
                    kh: 1,
                    kw: 1,
                    stride: 1,
                    hin: hw,
                    win: hw,
                    asg: Assignment::uniform(cin, 4),
                    fmt: DataFormat::Smol,
                },
                weights: vec![0.25; cin * cout],
                bn_scale: vec![],
                bn_bias: vec![],
                bn_mean: vec![],
                bn_var: vec![],
                relu: false,
            }),
            input,
        }
    }

    #[test]
    fn small_models_plan_whole() {
        let nodes = vec![conv_node("a", 8, 16, 4, usize::MAX), conv_node("b", 16, 8, 4, 0)];
        let plan = plan_shards(&nodes, &DeployConfig::default()).unwrap();
        assert!(matches!(plan, ShardPlan::Whole));
        // a generous budget also stays whole
        let cfg = DeployConfig { worker_budget: Some(1 << 24), shards: None };
        assert!(matches!(plan_shards(&nodes, &cfg).unwrap(), ShardPlan::Whole));
    }

    #[test]
    fn explicit_shards_split_the_widest_layer() {
        let nodes = vec![
            conv_node("narrow", 8, 16, 4, usize::MAX),
            conv_node("wide", 16, 100, 4, 0),
            conv_node("fc", 100, 10, 4, 1),
        ];
        let cfg = DeployConfig { worker_budget: None, shards: Some(3) };
        let plan = plan_shards(&nodes, &cfg).unwrap();
        let ShardPlan::Sharded { split_node, consumer_node, slices, gather } = plan else {
            panic!("expected a sharded plan");
        };
        assert_eq!((split_node, consumer_node), (1, Some(2)));
        assert_eq!(slices, vec![(0, 34), (34, 67), (67, 100)]);
        assert_eq!(gather, GatherMode::Reduce);
    }

    #[test]
    fn final_wide_layer_gathers_by_concat() {
        let nodes = vec![conv_node("stem", 8, 16, 4, usize::MAX), conv_node("wide", 16, 64, 4, 0)];
        let cfg = DeployConfig { worker_budget: None, shards: Some(2) };
        let plan = plan_shards(&nodes, &cfg).unwrap();
        let ShardPlan::Sharded { gather, consumer_node, .. } = plan else {
            panic!("expected a sharded plan");
        };
        assert_eq!(gather, GatherMode::Concat);
        assert_eq!(consumer_node, None);
    }

    #[test]
    fn budget_drives_the_shard_count() {
        let nodes = vec![
            conv_node("narrow", 8, 16, 4, usize::MAX),
            conv_node("wide", 16, 96, 4, 0),
            conv_node("fc", 96, 10, 1, 1),
        ];
        let whole: usize = nodes.iter().map(node_bind_bytes).sum();
        let cfg = DeployConfig { worker_budget: Some(whole * 3 / 4), shards: None };
        let plan = plan_shards(&nodes, &cfg).unwrap();
        let n = plan.num_shards();
        assert!(n >= 2, "must shard under a {} B budget", whole * 3 / 4);
        // every planned shard fits
        let ShardPlan::Sharded { split_node, consumer_node, slices, .. } = plan else {
            unreachable!()
        };
        let replicated: usize = nodes
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != split_node && Some(i) != consumer_node)
            .map(|(_, n)| node_bind_bytes(n))
            .sum();
        for &r in &slices {
            let mut bytes = replicated + sliced_split_bytes(&nodes[split_node], r);
            if let Some(c) = consumer_node {
                bytes += sliced_consumer_bytes(&nodes[c], r);
            }
            assert!(bytes <= whole * 3 / 4, "shard {r:?} exceeds the budget");
        }
    }

    #[test]
    fn explicit_shards_must_fit_a_given_budget() {
        // an explicit --shards that cannot fit the budget is refused at
        // plan time with a descriptive error, not left to panic a
        // worker at bind time
        let nodes = vec![conv_node("wide", 16, 96, 4, usize::MAX)];
        let budget = node_bind_bytes(&nodes[0]) / 4;
        let cfg = DeployConfig { worker_budget: Some(budget), shards: Some(2) };
        let err = plan_shards(&nodes, &cfg).unwrap_err();
        assert!(format!("{err}").contains("worker budget"), "{err}");
        // the same shard count without a budget plans fine
        let cfg = DeployConfig { worker_budget: None, shards: Some(2) };
        assert!(plan_shards(&nodes, &cfg).is_ok());
    }

    #[test]
    fn unshardable_shapes_refuse_with_an_error() {
        // mid-graph consumer that is neither channel-aligned nor final
        let nodes = vec![
            conv_node("wide", 8, 64, 4, usize::MAX),
            Node::Softmax { x: 0 },
            conv_node("fc", 64, 10, 4, 1),
        ];
        let cfg = DeployConfig { worker_budget: None, shards: Some(2) };
        assert!(plan_shards(&nodes, &cfg).is_err());
    }
}
