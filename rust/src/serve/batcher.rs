//! Dynamic request batching with model and session affinity: requests
//! group by `(model, target)` — the model they address plus the decode
//! session's pinned worker (`None` for stateless inference) — so every
//! batch stays homogeneous per kernel replay: one resident model per
//! batch, so even under an eviction budget a batch triggers at most
//! one (re)bind and never interleaves two models' kernels. A
//! group closes when it reaches `max_batch` requests (size trigger) or
//! when its oldest request has waited `max_delay` (latency-deadline
//! trigger), and groups close in FIFO order of their oldest request, so
//! interleaved traffic — encode vs decode, hot model vs cold — cannot
//! starve any group.
//!
//! The policy lives in [`DynamicBatcher`], a plain synchronous state
//! machine (unit-testable without threads); the dispatcher thread in
//! [`crate::serve::workers`] drives it from the submit channel and
//! routes closed batches to the shared queue (`target: None`) or the
//! pinned worker's queue (`target: Some(w)`).

use crate::serve::obs::SpanTrack;
use crate::serve::{ModelHandle, ModelKey};
use crate::sim::network::Tensor;
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchConfig {
    /// close a batch as soon as it holds this many requests
    pub max_batch: usize,
    /// close a non-empty batch once its oldest request is this old
    pub max_delay: Duration,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig { max_batch: 16, max_delay: Duration::from_millis(2) }
    }
}

/// What a request asks the engine to do.
#[derive(Debug, Clone)]
pub enum Payload {
    /// stateless one-shot inference over the full prepared graph
    Infer(Tensor),
    /// one autoregressive decode step for an open session
    Step { session: u64, token: Tensor },
    /// free a finished session's KV caches on its pinned worker
    /// (produces no completion)
    Close { session: u64 },
}

/// One queued request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// the model this request addresses; the executing worker binds it
    /// lazily from the handle on its first batch
    pub model: ModelHandle,
    pub payload: Payload,
    /// when the request entered the queue (latency is measured from here)
    pub enqueued: Instant,
    /// worker affinity: decode steps pin to the worker holding their
    /// session's KV cache, shard sub-requests to the worker their shard
    /// is placed on; `None` = any worker
    pub target: Option<usize>,
    /// which shard of a sharded deployment this sub-request addresses
    /// (`None` = whole-model request). Sub-requests of one logical
    /// request share its id; the server's gather buffer reassembles
    /// them by `(id, shard)`.
    pub shard: Option<usize>,
    /// lifecycle timestamps, stamped by the dispatcher and the
    /// executing worker as the request moves through the pool
    pub span: SpanTrack,
}

impl Request {
    /// A stateless inference request (no worker affinity).
    pub fn infer(id: u64, model: &ModelHandle, input: Tensor, enqueued: Instant) -> Request {
        Request {
            id,
            model: model.clone(),
            payload: Payload::Infer(input),
            enqueued,
            target: None,
            shard: None,
            span: SpanTrack::new(enqueued),
        }
    }

    /// One shard's sub-request of a scattered inference, pinned to the
    /// worker the shard is placed on. All of a logical request's shard
    /// sub-requests share `id`.
    pub fn infer_shard(
        id: u64,
        model: &ModelHandle,
        shard: usize,
        input: Tensor,
        target: usize,
        enqueued: Instant,
    ) -> Request {
        Request {
            id,
            model: model.clone(),
            payload: Payload::Infer(input),
            enqueued,
            target: Some(target),
            shard: Some(shard),
            span: SpanTrack::new(enqueued),
        }
    }

    /// A decode-step request pinned to `target` (the worker holding the
    /// session's KV cache).
    pub fn step(
        id: u64,
        model: &ModelHandle,
        session: u64,
        token: Tensor,
        target: usize,
        enqueued: Instant,
    ) -> Request {
        Request {
            id,
            model: model.clone(),
            payload: Payload::Step { session, token },
            enqueued,
            target: Some(target),
            shard: None,
            span: SpanTrack::new(enqueued),
        }
    }

    /// A session-close request pinned to `target`; rides the same FIFO
    /// as the session's steps, so it frees the caches only after every
    /// earlier step has executed.
    pub fn close(
        id: u64,
        model: &ModelHandle,
        session: u64,
        target: usize,
        enqueued: Instant,
    ) -> Request {
        Request {
            id,
            model: model.clone(),
            payload: Payload::Close { session },
            enqueued,
            target: Some(target),
            shard: None,
            span: SpanTrack::new(enqueued),
        }
    }
}

/// A closed batch, ready for a worker. All requests share `model` and
/// `target`: same-step decode requests of co-located sessions batch
/// together, requests for different models never mix (each batch is one
/// bind-table replay), and pinned traffic never mixes across workers.
#[derive(Debug)]
pub struct Batch {
    pub model: ModelHandle,
    pub target: Option<usize>,
    pub requests: Vec<Request>,
}

/// A group's identity: the model it addresses plus its worker affinity.
type GroupKey = (Arc<ModelKey>, Option<usize>);

/// The batch-close policy: accumulates requests into per-`(model,
/// target)` groups (open [`Batch`]es), emits one on the size trigger
/// ([`push`](Self::push)) or the deadline trigger
/// ([`poll_deadline`](Self::poll_deadline)). Groups close in arrival
/// order of their oldest request, so the front group always carries the
/// earliest deadline (FIFO fairness).
///
/// Open groups live in a `(model, target)` index map so `push` is O(1)
/// in the number of live groups — continuous decode keeps one group
/// open per pinned worker, and a linear scan per push would go
/// quadratic exactly under that load. FIFO order is kept in a parallel
/// deque of `(key, generation)` entries; a size-closed group leaves its
/// deque entry behind as a stale marker (its generation no longer
/// matches the map), skipped lazily and dropped when it reaches the
/// front. Each close strands at most one marker, so the lazy cleanup is
/// amortized O(1).
#[derive(Debug)]
pub struct DynamicBatcher {
    cfg: BatchConfig,
    /// open groups; the `u64` is the generation stamped at group
    /// creation, tying each map entry to its `order` entry
    groups: HashMap<GroupKey, (u64, Batch)>,
    /// group creation order — equal to the order of each group's oldest
    /// request, since a group is created by its first request
    order: VecDeque<(GroupKey, u64)>,
    next_gen: u64,
    /// requests currently held across all open groups
    pending: usize,
}

impl DynamicBatcher {
    pub fn new(cfg: BatchConfig) -> DynamicBatcher {
        // normalize rather than panic: a zero max_batch from a CLI flag
        // degenerates to single-request batches
        let cfg = BatchConfig { max_batch: cfg.max_batch.max(1), ..cfg };
        DynamicBatcher {
            cfg,
            groups: HashMap::new(),
            order: VecDeque::new(),
            next_gen: 0,
            pending: 0,
        }
    }

    /// Requests currently waiting for a batch to close.
    pub fn len(&self) -> usize {
        self.pending
    }

    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }

    /// Drop stale front `order` entries left behind by size-closed
    /// groups, so the front always names a live group (or is empty).
    fn prune_front(&mut self) {
        while let Some((key, gen)) = self.order.front() {
            match self.groups.get(key) {
                Some((live, _)) if live == gen => break,
                _ => {
                    self.order.pop_front();
                }
            }
        }
    }

    /// Enqueue one request into its `(model, target)` group; returns
    /// that group as a closed batch if this push filled it to
    /// `max_batch`.
    pub fn push(&mut self, r: Request) -> Option<Batch> {
        let key: GroupKey = (Arc::clone(&r.model.key), r.target);
        if let Some((_, open)) = self.groups.get_mut(&key) {
            open.requests.push(r);
            self.pending += 1;
            if open.requests.len() >= self.cfg.max_batch {
                let (_, batch) = self.groups.remove(&key).expect("group just updated");
                self.pending -= batch.requests.len();
                self.prune_front();
                return Some(batch);
            }
            return None;
        }
        let model = r.model.clone();
        let target = r.target;
        let batch = Batch { model, target, requests: vec![r] };
        if batch.requests.len() >= self.cfg.max_batch {
            // max_batch normalized to >= 1: singleton groups close on
            // arrival and never enter the index
            return Some(batch);
        }
        let gen = self.next_gen;
        self.next_gen += 1;
        self.pending += 1;
        self.groups.insert(key.clone(), (gen, batch));
        self.order.push_back((key, gen));
        None
    }

    /// The instant at which the oldest open group must close (its first
    /// request + `max_delay`); `None` while empty. Because groups close
    /// in first-arrival order, this is the earliest deadline overall.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.order.iter().find_map(|(key, gen)| match self.groups.get(key) {
            Some((live, batch)) if live == gen => {
                Some(batch.requests[0].enqueued + self.cfg.max_delay)
            }
            _ => None,
        })
    }

    /// Close the oldest group if its deadline has passed as of `now`
    /// (call repeatedly to drain every due group).
    pub fn poll_deadline(&mut self, now: Instant) -> Option<Batch> {
        match self.next_deadline() {
            Some(deadline) if now >= deadline => self.flush(),
            _ => None,
        }
    }

    /// Close the oldest open group unconditionally (shutdown drain;
    /// call until `None`).
    pub fn flush(&mut self) -> Option<Batch> {
        self.prune_front();
        let (key, _) = self.order.pop_front()?;
        let (_, batch) = self.groups.remove(&key).expect("front group is live after prune");
        self.pending -= batch.requests.len();
        Some(batch)
    }
}
