//! Dynamic request batching: a batch closes when it reaches
//! `max_batch` requests (size trigger) or when its oldest request has
//! waited `max_delay` (latency-deadline trigger), whichever comes first.
//!
//! The policy lives in [`DynamicBatcher`], a plain synchronous state
//! machine (unit-testable without threads); the dispatcher thread in
//! [`crate::serve::workers`] drives it from the submit channel.

use crate::sim::network::Tensor;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchConfig {
    /// close a batch as soon as it holds this many requests
    pub max_batch: usize,
    /// close a non-empty batch once its oldest request is this old
    pub max_delay: Duration,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig { max_batch: 16, max_delay: Duration::from_millis(2) }
    }
}

/// One queued inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub input: Tensor,
    /// when the request entered the queue (latency is measured from here)
    pub enqueued: Instant,
}

/// A closed batch, ready for a worker.
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<Request>,
}

/// The batch-close policy: accumulates requests, emits a [`Batch`] on
/// the size trigger ([`push`](Self::push)) or the deadline trigger
/// ([`poll_deadline`](Self::poll_deadline)).
#[derive(Debug)]
pub struct DynamicBatcher {
    cfg: BatchConfig,
    pending: Vec<Request>,
}

impl DynamicBatcher {
    pub fn new(cfg: BatchConfig) -> DynamicBatcher {
        // normalize rather than panic: a zero max_batch from a CLI flag
        // degenerates to single-request batches
        let cfg = BatchConfig { max_batch: cfg.max_batch.max(1), ..cfg };
        DynamicBatcher { cfg, pending: Vec::with_capacity(cfg.max_batch) }
    }

    /// Requests currently waiting for a batch to close.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Enqueue one request; returns the closed batch if this push filled
    /// it to `max_batch`.
    pub fn push(&mut self, r: Request) -> Option<Batch> {
        self.pending.push(r);
        if self.pending.len() >= self.cfg.max_batch {
            self.take()
        } else {
            None
        }
    }

    /// The instant at which the current batch must close (oldest request
    /// + `max_delay`); `None` while empty.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.pending.first().map(|r| r.enqueued + self.cfg.max_delay)
    }

    /// Close the batch if its deadline has passed as of `now`.
    pub fn poll_deadline(&mut self, now: Instant) -> Option<Batch> {
        match self.next_deadline() {
            Some(deadline) if now >= deadline => self.take(),
            _ => None,
        }
    }

    /// Close whatever is pending (shutdown path).
    pub fn flush(&mut self) -> Option<Batch> {
        self.take()
    }

    fn take(&mut self) -> Option<Batch> {
        if self.pending.is_empty() {
            None
        } else {
            Some(Batch { requests: std::mem::take(&mut self.pending) })
        }
    }
}
