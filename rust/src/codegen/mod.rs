//! The inference code generator (Sec. IV-D, Algorithm 4).
//!
//! Given a trained layer's shape and precision assignment, emits the
//! vectorized instruction stream for the configurable SIMD architecture:
//! channel-chunk-major dataflow with output anchoring, weight auxiliary
//! stationarity (the 3x3 weight vectors of the current (chunk, k) are
//! stashed in registers across all spatial positions) and input window
//! stashing (reused across overlapping taps), unrolled R/S loops, tail
//! masking with `vand`, `vmac_Pn` MACs accumulated with `vaddq_s16` and
//! reduced with `vpaddlq_s16`/`vaddvq_s32` (fused in `ReduceAcc`).
//!
//! Depthwise separable convolutions use the two-cycle `vmul_Pn` +
//! software-corrected accumulation path (Sec. III-C).
//!
//! Baseline formats (`Fp32`, `Int8`) emit the same dataflow with
//! `vfmaq_f32` / int8-MAC ops for the Key-Finding-1 comparisons.

pub mod gemm;
pub mod pack;
pub mod shard;

use crate::simd::isa::{Addr, BufId, Instr};
use crate::simd::patterns::Pattern;
use crate::smol::pattern_match::Assignment;

/// Data format a layer runs in (design-point dependent).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DataFormat {
    /// SMOL-packed mixed precision (the paper's architecture).
    Smol,
    /// 16 x int8 lanes (INT8 baseline).
    Int8,
    /// 4 x f32 lanes (full-precision baseline).
    Fp32,
}

/// Kind of layer kernel to generate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    /// dense (or grouped, handled per-group) convolution / FC
    Dense,
    /// depthwise convolution (multiply path, Sec. III-C)
    Depthwise,
}

/// Everything the generator needs for one layer.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    pub name: String,
    pub kind: LayerKind,
    pub cin: usize,
    pub cout: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub hin: usize,
    pub win: usize,
    pub asg: Assignment,
    pub fmt: DataFormat,
}

impl LayerPlan {
    pub fn hout(&self) -> usize {
        self.hin.div_ceil(self.stride)
    }
    pub fn wout(&self) -> usize {
        self.win.div_ceil(self.stride)
    }
    /// XLA-SAME padding: total = max((out-1)*stride + k - in, 0),
    /// top/left = total / 2 (floor; asymmetric pad goes to bottom/right).
    pub fn pad_top(&self) -> isize {
        let total =
            ((self.hout() as isize - 1) * self.stride as isize + self.kh as isize) - self.hin as isize;
        total.max(0) / 2
    }
    pub fn pad_left(&self) -> isize {
        let total =
            ((self.wout() as isize - 1) * self.stride as isize + self.kw as isize) - self.win as isize;
        total.max(0) / 2
    }

    /// Channel chunks for the layer's format: SMOL uses the assignment's
    /// pattern chunks; baselines use fixed-capacity chunks.
    pub fn chunks(&self) -> Vec<(Pattern, u32)> {
        match self.fmt {
            DataFormat::Smol => self
                .asg
                .chunks
                .iter()
                .copied()
                .zip(self.asg.valid.iter().copied())
                .filter(|&(_, v)| v > 0)
                .collect(),
            DataFormat::Int8 | DataFormat::Fp32 => {
                let cap = if self.fmt == DataFormat::Int8 { 16 } else { 4 };
                let n = self.cin.div_ceil(cap);
                (0..n)
                    .map(|i| {
                        let v = (self.cin - i * cap).min(cap) as u32;
                        // carrier pattern (uniform) — only capacity matters
                        (Pattern::uniform(4), v)
                    })
                    .collect()
            }
        }
    }

    /// Known tail bias per (partial chunk, single tap): packed code 0 in
    /// both operands contributes mantissa^2 = (2^p - 1)^2 scaled to 2^-6
    /// units. The epilogue subtracts `n_valid_taps(h,w) * tail_bias()`.
    pub fn tail_bias(&self) -> i64 {
        if self.fmt != DataFormat::Smol {
            return 0;
        }
        let mut bias = 0i64;
        for (pat, valid) in self
            .asg
            .chunks
            .iter()
            .zip(self.asg.valid.iter())
            .filter(|&(_, &v)| v > 0)
        {
            let (pat, valid) = (pat, *valid);
            for e in valid..pat.capacity() {
                let p = pat.element_precision(e) as i64;
                let m = (1i64 << p) - 1;
                bias += (m * m) << (8 - 2 * p);
            }
        }
        bias
    }

    /// Bytes of one spatial position's packed activations (all chunks).
    pub fn act_pos_bytes(&self) -> usize {
        self.chunks().len() * 16
    }
}

/// Buffer ids for one generated layer.
#[derive(Debug, Clone, Copy)]
pub struct LayerBufs {
    /// packed input activations, layout ((h * win + w) * n_chunks + c) * 16
    pub input: BufId,
    /// packed weights: dense ((((k*kh)+r)*kw+s)*n_chunks+c)*16,
    /// depthwise (((r*kw)+s)*n_chunks+c)*16
    pub weights: BufId,
    /// i32 accumulators: dense ((k*hout+h)*wout+w)*4,
    /// depthwise ((h*wout+w)*channels + pos)*4
    pub out: BufId,
    /// per-chunk tail masks, chunk c at c*16 (dual-use for both operands)
    pub masks: BufId,
}

/// Register allocation (32 NEON registers, Sec. II-A):
/// 0..8   weight stash (current chunk x k, all taps)
/// 9..17  input window stash (current chunk, sliding over h/w)
/// 28 acc, 27 mac tmp, 26 mask, 25/24 vand tmps, 23 mul-hi
const W_REG: u8 = 0;
const IN_REG: u8 = 9;
const ACC: u8 = 28;
const TMP: u8 = 27;
const MASK: u8 = 26;
const TMP_IN: u8 = 25;
const TMP_W: u8 = 24;
const MUL_HI: u8 = 23;

/// Anything that consumes an instruction stream (the simulator executes,
/// counters just tally).
pub trait Sink {
    fn emit(&mut self, i: Instr);
}

impl Sink for Vec<Instr> {
    fn emit(&mut self, i: Instr) {
        self.push(i);
    }
}

impl Sink for crate::sim::machine::Machine {
    fn emit(&mut self, i: Instr) {
        self.exec(&i);
    }
}

/// Instruction counter sink (for quick instruction-mix statistics).
#[derive(Debug, Default, Clone, Copy)]
pub struct Counter {
    pub total: u64,
    pub vmac: u64,
    pub vmul: u64,
    pub loads: u64,
    pub stores: u64,
    pub vand: u64,
}

impl Sink for Counter {
    fn emit(&mut self, i: Instr) {
        self.total += 1;
        match i {
            Instr::VmacP { .. } | Instr::VfmaF32 { .. } | Instr::VmacI8 { .. } => self.vmac += 1,
            Instr::VmulP { .. } => self.vmul += 1,
            Instr::LdQ { .. } => self.loads += 1,
            Instr::StQ { .. } | Instr::ReduceAcc { .. } | Instr::MulAcc { .. } => {
                self.stores += 1
            }
            Instr::Vand { .. } => self.vand += 1,
            _ => {}
        }
    }
}

/// Emit the full kernel for one layer into `sink`. `pattern_base` is the
/// index of this layer's first chunk pattern in the machine's pattern
/// table (the generator registered them via [`register_patterns`]).
pub fn emit_layer(plan: &LayerPlan, bufs: &LayerBufs, pattern_base: u8, sink: &mut dyn Sink) {
    match plan.kind {
        LayerKind::Dense => emit_dense(plan, bufs, pattern_base, sink),
        LayerKind::Depthwise => emit_depthwise(plan, bufs, pattern_base, sink),
    }
}

/// The layer's chunk patterns, to be appended to the machine's pattern
/// table before execution; returns the base index.
pub fn register_patterns(plan: &LayerPlan, table: &mut Vec<Pattern>) -> u8 {
    let base = table.len();
    for (pat, _) in plan.chunks() {
        table.push(pat);
    }
    u8::try_from(base).expect("pattern table overflow (>255 entries)")
}

fn act_addr(plan: &LayerPlan, bufs: &LayerBufs, h: usize, w: usize, chunk: usize) -> Addr {
    let n = plan.chunks().len();
    Addr { buf: bufs.input, off: (((h * plan.win + w) * n + chunk) * 16) as u32 }
}

fn weight_addr(
    plan: &LayerPlan,
    bufs: &LayerBufs,
    k: usize,
    r: usize,
    s: usize,
    chunk: usize,
) -> Addr {
    let n = plan.chunks().len();
    let idx = match plan.kind {
        LayerKind::Dense => (((k * plan.kh + r) * plan.kw + s) * n + chunk) * 16,
        LayerKind::Depthwise => ((r * plan.kw + s) * n + chunk) * 16,
    };
    Addr { buf: bufs.weights, off: idx as u32 }
}

fn emit_dense(plan: &LayerPlan, bufs: &LayerBufs, pattern_base: u8, sink: &mut dyn Sink) {
    let chunks = plan.chunks();
    let (hout, wout) = (plan.hout(), plan.wout());
    let (pt, pl) = (plan.pad_top(), plan.pad_left());
    let n_taps = plan.kh * plan.kw;
    assert!(n_taps <= 9, "weight stash sized for <= 3x3 kernels");

    for (ci, &(pat, valid)) in chunks.iter().enumerate() {
        let partial = valid < pat.capacity() && plan.fmt == DataFormat::Smol;
        if partial {
            sink.emit(Instr::LdQ { dst: MASK, addr: Addr { buf: bufs.masks, off: (ci * 16) as u32 } });
        }
        let pat_id = pattern_base + ci as u8;
        for k in 0..plan.cout {
            // weight auxiliary stationarity: stash this (chunk, k)'s taps
            for r in 0..plan.kh {
                for s in 0..plan.kw {
                    sink.emit(Instr::LdQ {
                        dst: W_REG + (r * plan.kw + s) as u8,
                        addr: weight_addr(plan, bufs, k, r, s, ci),
                    });
                }
            }
            // input window stash: (ih, iw) held per window slot
            let mut window: [Option<(usize, usize)>; 9] = [None; 9];
            for h in 0..hout {
                for w in 0..wout {
                    sink.emit(Instr::VmovZ { dst: ACC });
                    for r in 0..plan.kh {
                        for s in 0..plan.kw {
                            let ih = h as isize * plan.stride as isize + r as isize - pt;
                            let iw = w as isize * plan.stride as isize + s as isize - pl;
                            if ih < 0 || iw < 0 || ih >= plan.hin as isize || iw >= plan.win as isize
                            {
                                continue; // out-of-bounds tap skipped
                            }
                            let (ih, iw) = (ih as usize, iw as usize);
                            // stash lookup (Algorithm 4 line 14-17)
                            let slot = window.iter().position(|&p| p == Some((ih, iw)));
                            let in_reg = match slot {
                                Some(sl) => IN_REG + sl as u8,
                                None => {
                                    let sl = r * plan.kw + s;
                                    window[sl] = Some((ih, iw));
                                    sink.emit(Instr::LdQ {
                                        dst: IN_REG + sl as u8,
                                        addr: act_addr(plan, bufs, ih, iw, ci),
                                    });
                                    if partial {
                                        // Algorithm 4 line 20's vand,
                                        // hoisted to once per load: the
                                        // packed weights are pre-masked
                                        // at pack time, so masking the
                                        // freshly loaded input suffices.
                                        sink.emit(Instr::Vand {
                                            dst: IN_REG + sl as u8,
                                            a: IN_REG + sl as u8,
                                            b: MASK,
                                        });
                                    }
                                    IN_REG + sl as u8
                                }
                            };
                            let w_reg = W_REG + (r * plan.kw + s) as u8;
                            let (a, b) = (in_reg, w_reg);
                            match plan.fmt {
                                DataFormat::Smol => {
                                    sink.emit(Instr::VmacP { dst: TMP, a, b, pat: pat_id });
                                    sink.emit(Instr::Vaddq16 { dst: ACC, a: ACC, b: TMP });
                                }
                                DataFormat::Int8 => {
                                    sink.emit(Instr::VmacI8 { dst: TMP, a, b });
                                    sink.emit(Instr::Vaddq16 { dst: ACC, a: ACC, b: TMP });
                                }
                                DataFormat::Fp32 => {
                                    // fused multiply-add straight into acc
                                    sink.emit(Instr::VfmaF32 { dst: ACC, a, b });
                                }
                            }
                        }
                    }
                    // Algorithm 4 line 26: horizontal reduce + accumulate
                    sink.emit(Instr::ReduceAcc {
                        src: ACC,
                        addr: Addr {
                            buf: bufs.out,
                            off: (((k * hout + h) * wout + w) * 4) as u32,
                        },
                    });
                }
            }
        }
    }
}

fn emit_depthwise(plan: &LayerPlan, bufs: &LayerBufs, pattern_base: u8, sink: &mut dyn Sink) {
    let chunks = plan.chunks();
    let (hout, wout) = (plan.hout(), plan.wout());
    let (pt, pl) = (plan.pad_top(), plan.pad_left());

    if plan.fmt != DataFormat::Smol {
        return emit_depthwise_baseline(plan, bufs, sink);
    }
    let mut chunk_pos = 0u32; // packed channel position of chunk start
    for (ci, &(pat, valid)) in chunks.iter().enumerate() {
        let pat_id = pattern_base + ci as u8;
        // stash the tap weight vectors for this chunk
        for r in 0..plan.kh {
            for s in 0..plan.kw {
                sink.emit(Instr::LdQ {
                    dst: W_REG + (r * plan.kw + s) as u8,
                    addr: weight_addr(plan, bufs, 0, r, s, ci),
                });
            }
        }
        for h in 0..hout {
            for w in 0..wout {
                for r in 0..plan.kh {
                    for s in 0..plan.kw {
                        let ih = h as isize * plan.stride as isize + r as isize - pt;
                        let iw = w as isize * plan.stride as isize + s as isize - pl;
                        if ih < 0 || iw < 0 || ih >= plan.hin as isize || iw >= plan.win as isize {
                            continue;
                        }
                        sink.emit(Instr::LdQ {
                            dst: TMP,
                            addr: act_addr(plan, bufs, ih as usize, iw as usize, ci),
                        });
                        // two-cycle MUL + software-corrected accumulate
                        sink.emit(Instr::VmulP {
                            dst: TMP_IN,
                            dst2: MUL_HI,
                            a: TMP,
                            b: W_REG + (r * plan.kw + s) as u8,
                            pat: pat_id,
                        });
                        sink.emit(Instr::MulAcc {
                            lo: TMP_IN,
                            hi: MUL_HI,
                            pat: pat_id,
                            addr: Addr {
                                buf: bufs.out,
                                off: (((h * wout + w) * plan.cin as usize
                                    + chunk_pos as usize)
                                    * 4) as u32,
                            },
                            n_valid: valid as u16,
                        });
                    }
                }
            }
        }
        chunk_pos += valid;
    }
}

/// Depthwise layers in the FP32/INT8 baseline formats: elementwise
/// multiply-accumulate over taps in fp/int lanes, one store per position
/// per chunk (timing/energy only — baseline functional paths live in the
/// PJRT eval artifacts).
fn emit_depthwise_baseline(plan: &LayerPlan, bufs: &LayerBufs, sink: &mut dyn Sink) {
    let chunks = plan.chunks();
    let (hout, wout) = (plan.hout(), plan.wout());
    let (pt, pl) = (plan.pad_top(), plan.pad_left());
    for (ci, _) in chunks.iter().enumerate() {
        for r in 0..plan.kh {
            for s in 0..plan.kw {
                sink.emit(Instr::LdQ {
                    dst: W_REG + (r * plan.kw + s) as u8,
                    addr: weight_addr(plan, bufs, 0, r, s, ci),
                });
            }
        }
        for h in 0..hout {
            for w in 0..wout {
                sink.emit(Instr::VmovZ { dst: ACC });
                for r in 0..plan.kh {
                    for s in 0..plan.kw {
                        let ih = h as isize * plan.stride as isize + r as isize - pt;
                        let iw = w as isize * plan.stride as isize + s as isize - pl;
                        if ih < 0 || iw < 0 || ih >= plan.hin as isize || iw >= plan.win as isize {
                            continue;
                        }
                        sink.emit(Instr::LdQ {
                            dst: TMP,
                            addr: act_addr(plan, bufs, ih as usize, iw as usize, ci),
                        });
                        match plan.fmt {
                            DataFormat::Fp32 => {
                                sink.emit(Instr::VfmaF32 {
                                    dst: ACC,
                                    a: TMP,
                                    b: W_REG + (r * plan.kw + s) as u8,
                                });
                            }
                            _ => {
                                sink.emit(Instr::VmacI8 {
                                    dst: TMP_IN,
                                    a: TMP,
                                    b: W_REG + (r * plan.kw + s) as u8,
                                });
                                sink.emit(Instr::Vaddq16 { dst: ACC, a: ACC, b: TMP_IN });
                            }
                        }
                    }
                }
                sink.emit(Instr::StQ {
                    src: ACC,
                    addr: Addr {
                        buf: bufs.out,
                        off: (((h * wout + w) * chunks.len() + ci) * 16) as u32,
                    },
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::smol::pattern_match::Assignment;

    fn plan(cin: usize, cout: usize, k: usize, stride: usize, hw: usize) -> LayerPlan {
        LayerPlan {
            name: "t".into(),
            kind: LayerKind::Dense,
            cin,
            cout,
            kh: k,
            kw: k,
            stride,
            hin: hw,
            win: hw,
            asg: Assignment::uniform(cin, 4),
            fmt: DataFormat::Smol,
        }
    }

    #[test]
    fn padding_matches_xla_same() {
        // k=3, s=1: pad 1/1. k=3, s=2, in=16: out=8, total=(8-1)*2+3-16=1,
        // top=0 (asymmetric). k=1: pad 0.
        assert_eq!(plan(8, 8, 3, 1, 16).pad_top(), 1);
        assert_eq!(plan(8, 8, 3, 2, 16).pad_top(), 0);
        assert_eq!(plan(8, 8, 1, 1, 16).pad_top(), 0);
        assert_eq!(plan(8, 8, 3, 2, 16).hout(), 8);
    }

    #[test]
    fn instruction_mix_dense() {
        let p = plan(32, 4, 3, 1, 8);
        let bufs = LayerBufs {
            input: BufId(0),
            weights: BufId(1),
            out: BufId(2),
            masks: BufId(3),
        };
        let mut c = Counter::default();
        emit_layer(&p, &bufs, 0, &mut c);
        // one chunk (32 ch @4b), 4 out channels, 8x8 out, interior taps 9
        assert!(c.vmac > 0);
        // vmacs = sum over (k,h,w) of valid taps
        let mut taps = 0u64;
        for h in 0..8i64 {
            for w in 0..8i64 {
                for r in -1..=1i64 {
                    for s in -1..=1i64 {
                        if h + r >= 0 && h + r < 8 && w + s >= 0 && w + s < 8 {
                            taps += 1;
                        }
                    }
                }
            }
        }
        assert_eq!(c.vmac, 4 * taps);
        assert_eq!(c.stores, 4 * 64); // one ReduceAcc per output element
        assert_eq!(c.vand, 0); // full chunk, no masking
    }

    #[test]
    fn tail_masking_emitted_for_partial_chunks() {
        let mut p = plan(24, 2, 1, 1, 4); // 24 ch in a 32-cap chunk
        p.asg = Assignment::uniform(24, 4);
        let bufs = LayerBufs {
            input: BufId(0),
            weights: BufId(1),
            out: BufId(2),
            masks: BufId(3),
        };
        let mut c = Counter::default();
        emit_layer(&p, &bufs, 0, &mut c);
        assert!(c.vand > 0);
        assert_eq!(p.tail_bias(), 8 * 225); // 8 masked 4-bit slots
    }

    #[test]
    fn fewer_chunks_means_fewer_instructions() {
        // same channels at 1 bit pack into 1 chunk vs 4-bit's 1 chunk for
        // 32... use 128 channels: 4 chunks @4b vs 1 chunk @1b.
        let bufs = LayerBufs {
            input: BufId(0),
            weights: BufId(1),
            out: BufId(2),
            masks: BufId(3),
        };
        let mut p4 = plan(128, 8, 3, 1, 8);
        p4.asg = Assignment::uniform(128, 4);
        let mut c4 = Counter::default();
        emit_layer(&p4, &bufs, 0, &mut c4);
        let mut p1 = plan(128, 8, 3, 1, 8);
        p1.asg = Assignment::uniform(128, 1);
        let mut c1 = Counter::default();
        emit_layer(&p1, &bufs, 0, &mut c1);
        assert!(c1.total * 3 < c4.total, "{} vs {}", c1.total, c4.total);
    }
}
