//! Algorithm-4 GEMM emitter: matmul kernels for the Transformer path on
//! the same packed-vector MAC datapath as the conv/FC generator.
//!
//! A GEMM `C[m,n] = A[m,k] · B[k,n]` contracts over `k`, which is the
//! per-channel precision axis (the SMOL assignment quantizes both
//! operands channel-wise, exactly like a 1x1 convolution's `cin`). The
//! memory layouts reuse the conv pack format verbatim — A packs as the
//! activations of a `kh=kw=1, hin=m, win=1` dense plan, B as its HWIO
//! weights (`[k][n]` row-major) — so [`crate::codegen::pack`] serves
//! both static (prepare-once) and dynamic (packed-per-request) operands.
//!
//! The loop structure is GEMM-shaped rather than conv-shaped: A rows
//! have no spatial reuse window, so the emitter *register-blocks* them —
//! a block of up to 8 row vectors is stashed once per chunk, then each
//! B column vector is loaded once per block and MACed against every
//! stashed row. That cuts vector loads from `chunks * n * (m + 1)`
//! (what the conv emitter's dataflow would do) to
//! `chunks * (m + n * ceil(m/8))`.
//!
//! Tail handling matches Algorithm 4: partial chunks `vand` the loaded A
//! rows against the chunk mask (B is pre-masked at pack time), and the
//! epilogue subtracts one `tail_bias()` per output element (a GEMM is a
//! single-tap layer — every output accumulates each partial chunk once).

use crate::codegen::{DataFormat, LayerBufs, LayerKind, LayerPlan, Sink};
use crate::simd::isa::{Addr, Instr};
use crate::smol::pattern_match::Assignment;

/// Everything the generator needs for one GEMM.
#[derive(Debug, Clone)]
pub struct GemmPlan {
    pub name: String,
    /// output rows (sequence positions)
    pub m: usize,
    /// contraction dim — the per-channel precision axis
    pub k: usize,
    /// output columns
    pub n: usize,
    /// per-`k`-channel precisions (both operands quantize through it)
    pub asg: Assignment,
    pub fmt: DataFormat,
}

impl GemmPlan {
    /// Shard-scoped emission: restrict this GEMM to the output-column
    /// sub-range `[start, end)`. The contraction axis (and with it the
    /// precision assignment, chunking and tail bias) is untouched, so
    /// the sliced kernel packs its static operand via the same
    /// machinery and its packed bytes are exactly the corresponding
    /// `cout` rows of the full pack.
    pub fn slice_n(&self, start: usize, end: usize) -> GemmPlan {
        assert!(start < end && end <= self.n, "{}: n slice [{start}, {end})", self.name);
        GemmPlan { n: end - start, ..self.clone() }
    }

    /// Shard-scoped reduction operand: restrict the *contraction* axis
    /// to `[start, end)` — the consumer-side view when its producer's
    /// `cout` range was split across shards. Per-channel precisions are
    /// preserved via [`Assignment::slice`]; each shard's partial
    /// accumulators reduce exactly (the fixed-point grid sums without
    /// rounding), so gathered outputs are bit-identical to the whole
    /// kernel.
    pub fn slice_k(&self, start: usize, end: usize) -> GemmPlan {
        assert!(start < end && end <= self.k, "{}: k slice [{start}, {end})", self.name);
        GemmPlan { k: end - start, asg: self.asg.slice(start, end), ..self.clone() }
    }

    /// Lower to the equivalent 1x1 dense conv plan (`hin=m, win=1`):
    /// chunking, packing, buffer sizing and tail bias all reuse the conv
    /// machinery through this view.
    pub fn layer_plan(&self) -> LayerPlan {
        LayerPlan {
            name: self.name.clone(),
            kind: LayerKind::Dense,
            cin: self.k,
            cout: self.n,
            kh: 1,
            kw: 1,
            stride: 1,
            hin: self.m,
            win: 1,
            asg: self.asg.clone(),
            fmt: self.fmt,
        }
    }
}

/// Register allocation (mirrors the conv emitter's split):
/// 0 current B column chunk vector, 9..17 A row stash (block of <= 8),
/// 28 acc (baseline formats), 27 mac tmp, 26 mask.
const B_REG: u8 = 0;
const A_REG: u8 = 9;
const ROW_BLOCK: usize = 8;
const MASK: u8 = 26;
const TMP: u8 = 27;
const ACC: u8 = 28;

/// Emit the full GEMM kernel into `sink`. Buffer layouts (shared with
/// [`crate::codegen::pack`] via [`GemmPlan::layer_plan`]):
/// input `(i * n_chunks + c) * 16`, weights `(j * n_chunks + c) * 16`,
/// out `(j * m + i) * 4` i32 accumulators, masks `c * 16`.
pub fn emit_gemm(plan: &GemmPlan, bufs: &LayerBufs, pattern_base: u8, sink: &mut dyn Sink) {
    let chunks = plan.layer_plan().chunks();
    let nch = chunks.len();
    for (ci, &(pat, valid)) in chunks.iter().enumerate() {
        let partial = valid < pat.capacity() && plan.fmt == DataFormat::Smol;
        if partial {
            sink.emit(Instr::LdQ {
                dst: MASK,
                addr: Addr { buf: bufs.masks, off: (ci * 16) as u32 },
            });
        }
        let pat_id = pattern_base + ci as u8;
        let mut i0 = 0usize;
        while i0 < plan.m {
            let rows = ROW_BLOCK.min(plan.m - i0);
            // stash this block of A rows once per chunk
            for r in 0..rows {
                let reg = A_REG + r as u8;
                sink.emit(Instr::LdQ {
                    dst: reg,
                    addr: Addr { buf: bufs.input, off: (((i0 + r) * nch + ci) * 16) as u32 },
                });
                if partial {
                    sink.emit(Instr::Vand { dst: reg, a: reg, b: MASK });
                }
            }
            for j in 0..plan.n {
                // one B-column load serves the whole row block
                sink.emit(Instr::LdQ {
                    dst: B_REG,
                    addr: Addr { buf: bufs.weights, off: ((j * nch + ci) * 16) as u32 },
                });
                for r in 0..rows {
                    let a_reg = A_REG + r as u8;
                    let out = Addr {
                        buf: bufs.out,
                        off: ((j * plan.m + i0 + r) * 4) as u32,
                    };
                    match plan.fmt {
                        DataFormat::Smol => {
                            // single tap: MAC straight into the reduce,
                            // no in-register tap accumulation needed
                            sink.emit(Instr::VmacP { dst: TMP, a: a_reg, b: B_REG, pat: pat_id });
                            sink.emit(Instr::ReduceAcc { src: TMP, addr: out });
                        }
                        DataFormat::Int8 => {
                            // single tap, like the Smol arm: no
                            // in-register accumulation needed
                            sink.emit(Instr::VmacI8 { dst: TMP, a: a_reg, b: B_REG });
                            sink.emit(Instr::ReduceAcc { src: TMP, addr: out });
                        }
                        DataFormat::Fp32 => {
                            sink.emit(Instr::VmovZ { dst: ACC });
                            sink.emit(Instr::VfmaF32 { dst: ACC, a: a_reg, b: B_REG });
                            sink.emit(Instr::ReduceAcc { src: ACC, addr: out });
                        }
                    }
                }
            }
            i0 += rows;
        }
    }
}

/// Causal-mask variant of [`emit_gemm`] for attention score GEMMs
/// (`m = n` = sequence positions): output `(i, j)` is only accumulated
/// for `j <= i`, and fully-masked columns are skipped outright, so a
/// prefix run never spends MACs (or B-column loads) on future positions.
/// The epilogue is expected to fill the untouched upper triangle with
/// `-inf` before softmax; the skipped accumulators are never read.
///
/// Register blocking matches [`emit_gemm`]: a block of up to 8 A rows is
/// stashed per chunk, and each B column is loaded once per block that
/// contains at least one unmasked row (column `j` feeds rows `i >= j`,
/// so columns past the block's last row are dropped from the `j` loop).
pub fn emit_gemm_causal(plan: &GemmPlan, bufs: &LayerBufs, pattern_base: u8, sink: &mut dyn Sink) {
    assert_eq!(plan.m, plan.n, "causal mask needs a square (position x position) GEMM");
    let chunks = plan.layer_plan().chunks();
    let nch = chunks.len();
    for (ci, &(pat, valid)) in chunks.iter().enumerate() {
        let partial = valid < pat.capacity() && plan.fmt == DataFormat::Smol;
        if partial {
            sink.emit(Instr::LdQ {
                dst: MASK,
                addr: Addr { buf: bufs.masks, off: (ci * 16) as u32 },
            });
        }
        let pat_id = pattern_base + ci as u8;
        let mut i0 = 0usize;
        while i0 < plan.m {
            let rows = ROW_BLOCK.min(plan.m - i0);
            for r in 0..rows {
                let reg = A_REG + r as u8;
                sink.emit(Instr::LdQ {
                    dst: reg,
                    addr: Addr { buf: bufs.input, off: (((i0 + r) * nch + ci) * 16) as u32 },
                });
                if partial {
                    sink.emit(Instr::Vand { dst: reg, a: reg, b: MASK });
                }
            }
            // columns past the block's last row feed no row of this block
            for j in 0..=(i0 + rows - 1) {
                sink.emit(Instr::LdQ {
                    dst: B_REG,
                    addr: Addr { buf: bufs.weights, off: ((j * nch + ci) * 16) as u32 },
                });
                for r in 0..rows {
                    if i0 + r < j {
                        continue; // future position: masked out
                    }
                    let a_reg = A_REG + r as u8;
                    let out = Addr {
                        buf: bufs.out,
                        off: ((j * plan.m + i0 + r) * 4) as u32,
                    };
                    match plan.fmt {
                        DataFormat::Smol => {
                            sink.emit(Instr::VmacP { dst: TMP, a: a_reg, b: B_REG, pat: pat_id });
                            sink.emit(Instr::ReduceAcc { src: TMP, addr: out });
                        }
                        DataFormat::Int8 => {
                            sink.emit(Instr::VmacI8 { dst: TMP, a: a_reg, b: B_REG });
                            sink.emit(Instr::ReduceAcc { src: TMP, addr: out });
                        }
                        DataFormat::Fp32 => {
                            sink.emit(Instr::VmovZ { dst: ACC });
                            sink.emit(Instr::VfmaF32 { dst: ACC, a: a_reg, b: B_REG });
                            sink.emit(Instr::ReduceAcc { src: ACC, addr: out });
                        }
                    }
                }
            }
            i0 += rows;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::Counter;
    use crate::simd::isa::BufId;

    fn bufs() -> LayerBufs {
        LayerBufs { input: BufId(0), weights: BufId(1), out: BufId(2), masks: BufId(3) }
    }

    fn plan(m: usize, k: usize, n: usize, p: u8) -> GemmPlan {
        GemmPlan {
            name: "g".into(),
            m,
            k,
            n,
            asg: Assignment::uniform(k, p),
            fmt: DataFormat::Smol,
        }
    }

    #[test]
    fn instruction_mix_matches_gemm_shape() {
        // k=32 @4b -> 1 full chunk; no masking
        let p = plan(10, 32, 5, 4);
        let mut c = Counter::default();
        emit_gemm(&p, &bufs(), 0, &mut c);
        assert_eq!(c.vmac, 10 * 5); // one MAC per output per chunk
        assert_eq!(c.stores, 10 * 5); // one ReduceAcc per output per chunk
        assert_eq!(c.vand, 0);
        // loads: 10 A rows + 5 B columns per row block (blocks of 8 -> 2)
        assert_eq!(c.loads, 10 + 2 * 5);
    }

    #[test]
    fn row_blocking_amortizes_b_loads() {
        // conv-shaped dataflow would load A m*n times; blocking loads
        // each A row once and each B column ceil(m/8) times per chunk
        let p = plan(16, 32, 16, 4);
        let mut c = Counter::default();
        emit_gemm(&p, &bufs(), 0, &mut c);
        assert_eq!(c.loads, 16 + 2 * 16);
        assert!(c.loads < (16 * 16) as u64);
    }

    #[test]
    fn partial_chunk_masks_a_rows() {
        // k=24 in a 32-capacity chunk: every A row load is vand-masked
        let p = plan(6, 24, 3, 4);
        let mut c = Counter::default();
        emit_gemm(&p, &bufs(), 0, &mut c);
        assert_eq!(c.vand, 6); // one per stashed A row
        assert_eq!(p.layer_plan().tail_bias(), 8 * 225);
    }

    #[test]
    fn causal_emitter_skips_upper_triangle() {
        // m = n = 10, k = 32 @4b (1 full chunk): only j <= i pairs MAC
        let p = plan(10, 32, 10, 4);
        let mut c = Counter::default();
        emit_gemm_causal(&p, &bufs(), 0, &mut c);
        let lower = (10 * 11 / 2) as u64;
        assert_eq!(c.vmac, lower);
        assert_eq!(c.stores, lower);
        // loads: 10 A rows once; block 0 (rows 0..8) needs B cols 0..8,
        // block 1 (rows 8..10) needs B cols 0..10
        assert_eq!(c.loads, 10 + 8 + 10);
        // strictly cheaper than the full emitter
        let mut full = Counter::default();
        emit_gemm(&p, &bufs(), 0, &mut full);
        assert!(c.vmac < full.vmac && c.loads < full.loads);
    }

    #[test]
    fn causal_emitter_masks_partial_chunks() {
        // k = 24 in a 32-capacity chunk: every stashed A row is vand-masked
        let p = plan(4, 24, 4, 4);
        let mut c = Counter::default();
        emit_gemm_causal(&p, &bufs(), 0, &mut c);
        assert_eq!(c.vand, 4);
        assert_eq!(c.vmac, 4 * 5 / 2);
    }

    #[test]
    fn layer_plan_is_single_tap() {
        let lp = plan(7, 40, 2, 2).layer_plan();
        assert_eq!((lp.hout(), lp.wout()), (7, 1));
        assert_eq!((lp.pad_top(), lp.pad_left()), (0, 0));
        assert_eq!(lp.chunks().iter().map(|&(_, v)| v).sum::<u32>(), 40);
    }
}
