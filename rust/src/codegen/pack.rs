//! Packing of activations/weights into the SMOL vector memory layout the
//! generated kernels consume (Observation 4 channel rearrangement +
//! per-chunk precision patterns).

use crate::codegen::{DataFormat, LayerKind, LayerPlan};
use crate::simd::vector::{pack_values, tail_mask};
use crate::smol::pattern_match::Assignment;
use crate::smol::quant;

/// Quantize + rearrange + pack input activations.
///
/// `x` is HWC f32 in *original* channel order (the raw 32-bit fixed-point
/// values the previous layer produced); output layout is
/// `((h*win + w) * n_chunks + c) * 16` bytes.
pub fn pack_activations(plan: &LayerPlan, x: &[f32]) -> Vec<u8> {
    let mut out = Vec::new();
    pack_activations_into(plan, x, &mut out);
    out
}

/// [`pack_activations`] into a caller-owned buffer (cleared + resized),
/// so per-request packing in the serving hot path reuses one allocation.
pub fn pack_activations_into(plan: &LayerPlan, x: &[f32], out: &mut Vec<u8>) {
    assert_eq!(x.len(), plan.hin * plan.win * plan.cin);
    let chunks = plan.chunks();
    out.clear();
    out.resize(plan.hin * plan.win * chunks.len() * 16, 0u8);
    if plan.fmt != DataFormat::Smol {
        return; // baselines: footprint-only buffers
    }
    let mut pos = 0usize;
    let chunk_bases: Vec<usize> = chunks
        .iter()
        .map(|&(_, v)| {
            let b = pos;
            pos += v as usize;
            b
        })
        .collect();
    for h in 0..plan.hin {
        for w in 0..plan.win {
            let base = (h * plan.win + w) * plan.cin;
            for (ci, &(pat, valid)) in chunks.iter().enumerate() {
                let vals: Vec<f32> = (0..valid as usize)
                    .map(|e| {
                        let ch = plan.asg.order[chunk_bases[ci] + e] as usize;
                        quant::quantize(x[base + ch], plan.asg.precision[ch])
                    })
                    .collect();
                let v = pack_values(&pat, &vals);
                let off = ((h * plan.win + w) * chunks.len() + ci) * 16;
                out[off..off + 16].copy_from_slice(&v.to_bytes());
            }
        }
    }
}

/// Quantize + rearrange + pack weights.
///
/// Dense: `w` indexed `[r][s][cin][cout]` (HWIO), output layout
/// `(((k*kh + r)*kw + s) * n_chunks + c) * 16`.
/// Depthwise: `w` indexed `[r][s][c]`, layout `((r*kw + s)*n_chunks + c)*16`.
pub fn pack_weights(plan: &LayerPlan, w: &[f32]) -> Vec<u8> {
    let mut out = Vec::new();
    pack_weights_into(plan, w, &mut out);
    out
}

/// [`pack_weights`] into a caller-owned buffer (cleared + resized): the
/// serving engine packs *dynamic* GEMM operands (QK^T / A·V "weights")
/// per request through reusable per-worker scratch.
pub fn pack_weights_into(plan: &LayerPlan, w: &[f32], out: &mut Vec<u8>) {
    let chunks = plan.chunks();
    let n = chunks.len();
    let mut pos = 0usize;
    let chunk_bases: Vec<usize> = chunks
        .iter()
        .map(|&(_, v)| {
            let b = pos;
            pos += v as usize;
            b
        })
        .collect();
    match plan.kind {
        LayerKind::Dense => {
            assert_eq!(w.len(), plan.kh * plan.kw * plan.cin * plan.cout);
            out.clear();
            out.resize(plan.cout * plan.kh * plan.kw * n * 16, 0u8);
            if plan.fmt != DataFormat::Smol {
                return;
            }
            for k in 0..plan.cout {
                for r in 0..plan.kh {
                    for s in 0..plan.kw {
                        for (ci, &(pat, valid)) in chunks.iter().enumerate() {
                            let vals: Vec<f32> = (0..valid as usize)
                                .map(|e| {
                                    let ch = plan.asg.order[chunk_bases[ci] + e] as usize;
                                    let idx = ((r * plan.kw + s) * plan.cin + ch) * plan.cout + k;
                                    quant::quantize(w[idx], plan.asg.precision[ch])
                                })
                                .collect();
                            let v = pack_values(&pat, &vals);
                            let off = (((k * plan.kh + r) * plan.kw + s) * n + ci) * 16;
                            out[off..off + 16].copy_from_slice(&v.to_bytes());
                        }
                    }
                }
            }
        }
        LayerKind::Depthwise => {
            assert_eq!(w.len(), plan.kh * plan.kw * plan.cin);
            out.clear();
            out.resize(plan.kh * plan.kw * n * 16, 0u8);
            if plan.fmt != DataFormat::Smol {
                return;
            }
            for r in 0..plan.kh {
                for s in 0..plan.kw {
                    for (ci, &(pat, valid)) in chunks.iter().enumerate() {
                        let vals: Vec<f32> = (0..valid as usize)
                            .map(|e| {
                                let ch = plan.asg.order[chunk_bases[ci] + e] as usize;
                                let idx = (r * plan.kw + s) * plan.cin + ch;
                                quant::quantize(w[idx], plan.asg.precision[ch])
                            })
                            .collect();
                        let v = pack_values(&pat, &vals);
                        let off = ((r * plan.kw + s) * n + ci) * 16;
                        out[off..off + 16].copy_from_slice(&v.to_bytes());
                    }
                }
            }
        }
    }
}

/// Quantize + pack one *column* of a SMOL operand: `vals` holds the
/// column's `cin` values in original channel order, and the appended
/// bytes are its chunk vectors in layout order — exactly the
/// `n_chunks * 16` bytes one `cout` index (or one sequence position of a
/// dynamic GEMM operand) occupies in [`pack_weights_into`]'s output, and
/// equally the packed-activation bytes of a single-row (`hin=1, win=1`)
/// plan. This is the per-position unit the serving KV cache appends:
/// one call per new decode position, against a fixed assignment, through
/// caller-owned scratch (`tmp`), so the append path never re-packs the
/// prefix and never allocates beyond amortized `out` growth.
pub fn pack_column_into(asg: &Assignment, vals: &[f32], tmp: &mut Vec<f32>, out: &mut Vec<u8>) {
    assert_eq!(vals.len(), asg.num_channels());
    let mut base = 0usize;
    for (pat, &valid) in asg.chunks.iter().zip(asg.valid.iter()) {
        if valid == 0 {
            continue;
        }
        tmp.clear();
        for e in 0..valid as usize {
            let ch = asg.order[base + e] as usize;
            tmp.push(quant::quantize(vals[ch], asg.precision[ch]));
        }
        out.extend_from_slice(&pack_values(pat, tmp).to_bytes());
        base += valid as usize;
    }
}

/// Packed bytes one `cout` index occupies in a dense layer's weight
/// pack — the layout is `cout`-major, so a contiguous `cout` sub-range
/// of the full pack is exactly `(end - start) * packed_cout_row_bytes`
/// bytes starting at `start * packed_cout_row_bytes`. The shard-scoped
/// emitter relies on this to slice packed weights without re-packing
/// (see `codegen::shard`).
pub fn packed_cout_row_bytes(plan: &LayerPlan) -> usize {
    plan.kh * plan.kw * plan.chunks().len() * 16
}

/// Per-chunk tail masks (16 bytes each).
pub fn pack_masks(plan: &LayerPlan) -> Vec<u8> {
    let mut out = Vec::new();
    pack_masks_into(plan, &mut out);
    out
}

/// [`pack_masks`] into a caller-owned buffer (cleared + resized): the
/// decode path re-derives masks per prefix length through reusable
/// scratch.
pub fn pack_masks_into(plan: &LayerPlan, out: &mut Vec<u8>) {
    let chunks = plan.chunks();
    out.clear();
    out.resize(chunks.len().max(1) * 16, 0u8);
    for (ci, &(pat, valid)) in chunks.iter().enumerate() {
        let m = tail_mask(&pat, valid);
        out[ci * 16..ci * 16 + 16].copy_from_slice(&m.to_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::smol::pattern_match::Assignment;

    #[test]
    fn activation_roundtrip_uniform() {
        let plan = LayerPlan {
            name: "t".into(),
            kind: LayerKind::Dense,
            cin: 32,
            cout: 1,
            kh: 1,
            kw: 1,
            stride: 1,
            hin: 2,
            win: 2,
            asg: Assignment::uniform(32, 4),
            fmt: DataFormat::Smol,
        };
        let x: Vec<f32> = (0..2 * 2 * 32).map(|i| (i as f32) * 0.01 - 0.6).collect();
        let packed = pack_activations(&plan, &x);
        assert_eq!(packed.len(), 2 * 2 * 1 * 16);
        // unpack position (1,1) and compare with direct quantization
        use crate::simd::vector::{unpack_values, V128};
        let off = ((1 * 2 + 1) * 1) * 16;
        let v = V128::from_bytes(&packed[off..off + 16]);
        let vals = unpack_values(&plan.chunks()[0].0, &v);
        for ch in 0..32 {
            let want = quant::quantize(x[(1 * 2 + 1) * 32 + ch], 4);
            assert_eq!(vals[ch], want, "ch{ch}");
        }
    }

    /// Guards the serve engine's cache-once-reuse-forever contract: for a
    /// fixed plan, packing must be a pure function of its inputs (and of
    /// the plan *value*, not its identity).
    #[test]
    fn packing_is_deterministic_for_a_fixed_plan() {
        use crate::simd::patterns::design_subset;
        use crate::smol::pattern_match::pattern_match;
        let cin = 40usize;
        let s: Vec<f32> = (0..cin).map(|i| ((i * 37 % 17) as f32) - 6.0).collect();
        let plan = LayerPlan {
            name: "det".into(),
            kind: LayerKind::Dense,
            cin,
            cout: 3,
            kh: 3,
            kw: 3,
            stride: 1,
            hin: 5,
            win: 5,
            asg: pattern_match(&s, &design_subset(8)),
            fmt: DataFormat::Smol,
        };
        let w: Vec<f32> = (0..3 * 3 * cin * 3).map(|i| (i as f32 * 0.731).sin()).collect();
        let x: Vec<f32> = (0..5 * 5 * cin).map(|i| (i as f32 * 0.413).cos() * 1.7).collect();
        assert_eq!(pack_weights(&plan, &w), pack_weights(&plan, &w));
        assert_eq!(pack_activations(&plan, &x), pack_activations(&plan, &x));
        assert_eq!(pack_masks(&plan), pack_masks(&plan));
        let plan2 = plan.clone();
        assert_eq!(pack_weights(&plan, &w), pack_weights(&plan2, &w));
        assert_eq!(pack_activations(&plan, &x), pack_activations(&plan2, &x));
        assert_eq!(pack_masks(&plan), pack_masks(&plan2));

        // depthwise layout too
        let sdw: Vec<f32> = (0..24).map(|i| ((i * 11 % 7) as f32) - 2.0).collect();
        let dw = LayerPlan {
            name: "det_dw".into(),
            kind: LayerKind::Depthwise,
            cin: 24,
            cout: 24,
            kh: 3,
            kw: 3,
            stride: 1,
            hin: 4,
            win: 4,
            asg: pattern_match(&sdw, &design_subset(4)),
            fmt: DataFormat::Smol,
        };
        let wdw: Vec<f32> = (0..3 * 3 * 24).map(|i| (i as f32 * 0.517).sin()).collect();
        assert_eq!(pack_weights(&dw, &wdw), pack_weights(&dw, &wdw));
        assert_eq!(pack_masks(&dw), pack_masks(&dw));
    }

    /// The KV-cache append unit must produce exactly the bytes the bulk
    /// packer lays down for the same column: appending positions one at
    /// a time is byte-identical to packing the whole operand at once.
    #[test]
    fn column_pack_matches_bulk_weight_pack() {
        use crate::simd::patterns::design_subset;
        use crate::smol::pattern_match::pattern_match;
        let cin = 20usize;
        let cout = 5usize;
        let s: Vec<f32> = (0..cin).map(|i| ((i * 13 % 11) as f32) - 4.0).collect();
        for asg in [Assignment::uniform(cin, 2), pattern_match(&s, &design_subset(8))] {
            let plan = LayerPlan {
                name: "col".into(),
                kind: LayerKind::Dense,
                cin,
                cout,
                kh: 1,
                kw: 1,
                stride: 1,
                hin: 1,
                win: 1,
                asg: asg.clone(),
                fmt: DataFormat::Smol,
            };
            let w: Vec<f32> = (0..cin * cout).map(|i| (i as f32 * 0.291).sin()).collect();
            let bulk = pack_weights(&plan, &w);
            let nch = plan.chunks().len();
            let mut tmp = Vec::new();
            let mut appended = Vec::new();
            for j in 0..cout {
                // column j of the [cin][cout] row-major operand
                let col: Vec<f32> = (0..cin).map(|c| w[c * cout + j]).collect();
                pack_column_into(&asg, &col, &mut tmp, &mut appended);
                assert_eq!(appended.len(), (j + 1) * nch * 16);
            }
            assert_eq!(appended, bulk);
        }
    }
}
