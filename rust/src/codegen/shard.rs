//! Shard-scoped codegen: restrict a layer's plan and raw weights to a
//! contiguous `cout` sub-range (the producer side of a sharded
//! deployment) or a contiguous contraction sub-range (the consumer side
//! that reduces over a split producer).
//!
//! The whole point of sharding by output channel is that nothing about
//! the kernel changes: a `cout`-sliced plan has the same `cin`
//! assignment, the same chunking and the same tail bias, so the sliced
//! emitter is the *ordinary* emitter over a narrower plan, and the
//! sliced pack is byte-identical to the corresponding rows of the full
//! pack (the dense weight layout is `cout`-major — see
//! [`pack::packed_cout_row_bytes`]). Contraction slices re-chunk their
//! per-channel precisions via [`Assignment::slice`]
//! (`crate::smol::pattern_match::Assignment`); the fixed-point partial
//! sums of the shards reduce without rounding, so gathered outputs stay
//! bit-identical to the whole-model kernel.

use crate::codegen::pack;
use crate::codegen::{LayerKind, LayerPlan};

/// Restrict a dense conv/FC plan to output channels `[start, end)`.
pub fn slice_plan_cout(plan: &LayerPlan, start: usize, end: usize) -> LayerPlan {
    assert_eq!(plan.kind, LayerKind::Dense, "{}: only dense layers shard by cout", plan.name);
    assert!(start < end && end <= plan.cout, "{}: cout slice [{start}, {end})", plan.name);
    LayerPlan { cout: end - start, ..plan.clone() }
}

/// The HWIO (`[r][s][cin][cout]`) weight slice matching
/// [`slice_plan_cout`].
pub fn slice_dense_weights_cout(plan: &LayerPlan, w: &[f32], start: usize, end: usize) -> Vec<f32> {
    assert_eq!(w.len(), plan.kh * plan.kw * plan.cin * plan.cout, "{}: weights", plan.name);
    let mut out = Vec::with_capacity(plan.kh * plan.kw * plan.cin * (end - start));
    for rs_c in 0..plan.kh * plan.kw * plan.cin {
        out.extend_from_slice(&w[rs_c * plan.cout + start..rs_c * plan.cout + end]);
    }
    out
}

/// Restrict a dense conv/FC plan to *input* channels `[start, end)` —
/// the reduce-consumer view when its producer's `cout` was split. The
/// per-channel precision assignment is sliced alongside (precisions
/// preserved, chunks rebuilt over the slice).
pub fn slice_plan_cin(plan: &LayerPlan, start: usize, end: usize) -> LayerPlan {
    assert_eq!(plan.kind, LayerKind::Dense, "{}: only dense layers shard by cin", plan.name);
    assert!(start < end && end <= plan.cin, "{}: cin slice [{start}, {end})", plan.name);
    LayerPlan { cin: end - start, asg: plan.asg.slice(start, end), ..plan.clone() }
}

/// The HWIO weight slice matching [`slice_plan_cin`].
pub fn slice_dense_weights_cin(plan: &LayerPlan, w: &[f32], start: usize, end: usize) -> Vec<f32> {
    assert_eq!(w.len(), plan.kh * plan.kw * plan.cin * plan.cout, "{}: weights", plan.name);
    let mut out = Vec::with_capacity(plan.kh * plan.kw * (end - start) * plan.cout);
    for rs in 0..plan.kh * plan.kw {
        let base = rs * plan.cin;
        out.extend_from_slice(&w[(base + start) * plan.cout..(base + end) * plan.cout]);
    }
    out
}

/// Column slice `[start, end)` of a `[k][n]` row-major GEMM operand
/// (matches [`crate::codegen::gemm::GemmPlan::slice_n`]).
pub fn slice_gemm_weights_n(k: usize, n: usize, w: &[f32], start: usize, end: usize) -> Vec<f32> {
    assert_eq!(w.len(), k * n, "gemm weights shape");
    assert!(start < end && end <= n, "n slice [{start}, {end})");
    let mut out = Vec::with_capacity(k * (end - start));
    for row in 0..k {
        out.extend_from_slice(&w[row * n + start..row * n + end]);
    }
    out
}

/// Row slice `[start, end)` of a `[k][n]` row-major GEMM operand
/// (matches [`crate::codegen::gemm::GemmPlan::slice_k`]).
pub fn slice_gemm_weights_k(k: usize, n: usize, w: &[f32], start: usize, end: usize) -> Vec<f32> {
    assert_eq!(w.len(), k * n, "gemm weights shape");
    assert!(start < end && end <= k, "k slice [{start}, {end})");
    w[start * n..end * n].to_vec()
}

/// Pack a `cout` sub-range of a dense layer through the shard-scoped
/// plan — the ordinary [`pack::pack_weights_into`] machinery over the
/// slice. Bit-identical to the corresponding byte range of the
/// full-model pack (`[start, end) * packed_cout_row_bytes`), which the
/// shard-pack proptests assert across precisions.
pub fn pack_weights_cout_range(plan: &LayerPlan, w: &[f32], start: usize, end: usize) -> Vec<u8> {
    let sliced = slice_plan_cout(plan, start, end);
    let sliced_w = slice_dense_weights_cout(plan, w, start, end);
    pack::pack_weights(&sliced, &sliced_w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::DataFormat;
    use crate::simd::patterns::design_subset;
    use crate::smol::pattern_match::{pattern_match, Assignment};

    fn plan(cin: usize, cout: usize, k: usize, asg: Assignment) -> LayerPlan {
        LayerPlan {
            name: "sh".into(),
            kind: LayerKind::Dense,
            cin,
            cout,
            kh: k,
            kw: k,
            stride: 1,
            hin: 4,
            win: 4,
            asg,
            fmt: DataFormat::Smol,
        }
    }

    #[test]
    fn cout_range_pack_is_a_byte_slice_of_the_full_pack() {
        let s: Vec<f32> = (0..24).map(|i| ((i * 7 % 13) as f32) - 5.0).collect();
        for asg in [Assignment::uniform(24, 4), pattern_match(&s, &design_subset(8))] {
            let p = plan(24, 10, 3, asg);
            let w: Vec<f32> = (0..3 * 3 * 24 * 10).map(|i| (i as f32 * 0.37).sin()).collect();
            let full = pack::pack_weights(&p, &w);
            let row = pack::packed_cout_row_bytes(&p);
            for (start, end) in [(0usize, 5usize), (5, 10), (3, 7)] {
                let shard = pack_weights_cout_range(&p, &w, start, end);
                assert_eq!(shard, full[start * row..end * row], "[{start}, {end})");
            }
        }
    }

    #[test]
    fn cin_slices_partition_the_weights() {
        let p = plan(20, 6, 1, Assignment::uniform(20, 2));
        let w: Vec<f32> = (0..20 * 6).map(|i| i as f32).collect();
        let lo = slice_dense_weights_cin(&p, &w, 0, 12);
        let hi = slice_dense_weights_cin(&p, &w, 12, 20);
        let rejoined: Vec<f32> = lo.into_iter().chain(hi).collect();
        assert_eq!(rejoined, w);
        let lp = slice_plan_cin(&p, 12, 20);
        assert_eq!((lp.cin, lp.asg.num_channels()), (8, 8));
    }

    #[test]
    fn gemm_column_and_row_slices_match_layout() {
        let (k, n) = (6usize, 8usize);
        let w: Vec<f32> = (0..k * n).map(|i| i as f32).collect();
        let cols = slice_gemm_weights_n(k, n, &w, 2, 5);
        for row in 0..k {
            assert_eq!(&cols[row * 3..row * 3 + 3], &w[row * n + 2..row * n + 5]);
        }
        let rows = slice_gemm_weights_k(k, n, &w, 1, 4);
        assert_eq!(rows, w[n..4 * n]);
    }
}
