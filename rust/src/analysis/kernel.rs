//! Abstract interpretation over emitted SIMD programs.
//!
//! [`KernelVerifier`] walks an [`Instr`] stream instruction by
//! instruction, tracking an abstract value per vector register and a
//! worst-case accumulator bound per output cell, and proves for the
//! whole program:
//!
//! - **def-before-use**: every register read was written first, every
//!   `BufId` is one the kernel's buffer table declares;
//! - **memory safety**: every `Addr` is in bounds for its buffer's
//!   packed length at the access granularity (16-byte `LdQ`/`StQ`,
//!   4-byte `ReduceAcc` cells, `4 * n_valid` `MulAcc` extents) and
//!   aligned to it;
//! - **pattern coherence**: every `PatId` indexes the registered
//!   table, and the pattern it names is byte-for-byte the pattern of
//!   the chunk the operand vectors were loaded from (chunk provenance
//!   is recovered from the load offsets — all emitter layouts are
//!   chunk-minor, so `(off / 16) % n_chunks` is the chunk index);
//! - **tail masking**: a partial chunk's input-side operand reaches a
//!   `VmacP` only after a `Vand` against that chunk's tail mask
//!   (weights are pre-masked at pack time);
//! - **accumulator range**: per-lane i16 partials (`VmacP` results
//!   accumulated by `Vaddq16`) stay within `i16::MAX`, and the i32
//!   `ReduceAcc`/`MulAcc` running sum per output cell stays within
//!   `i32::MAX` *and* — for SMOL kernels — within the f32
//!   exact-integer range [`F32_EXACT_BOUND`], which is what PR 5's
//!   bit-exact sharded reduction and the 2^-6 fixed-point dequant
//!   grid actually rely on.
//!
//! The bound argument is purely static: a `p`-bit element pair
//! contributes at most [`elem_prod_max`]`(p)` in 2^-6 units (code 0
//! decodes to the maximum-magnitude mantissa `-(2^p - 1)`, so masked
//! lanes never shrink the bound), a 16-bit lane of precision `p` holds
//! `16 / p` elements ([`lane_mac_max`]), and every `ReduceAcc` adds the
//! sum of its source's lane bounds to one output cell. The final
//! per-cell bound is therefore `sum over chunks and taps of the chunk's
//! pattern-wise product bound` — the `chunk_count x max|a|*|b|`
//! quantity of the exact-integer-range argument, computed exactly.
//!
//! The verifier implements [`Sink`], so paper-scale layers verify by
//! *streaming* `codegen::emit_layer` straight into it — no multi-
//! million-instruction program is ever materialized.

use std::borrow::Cow;
use std::collections::{HashMap, HashSet};

use super::equiv::TermSpec;
use super::{KernelVerdict, Violation, WindowTracker, F32_EXACT_BOUND};
use crate::codegen::gemm::GemmPlan;
use crate::codegen::{register_patterns, DataFormat, LayerKind, LayerPlan, Sink};
use crate::simd::isa::{Addr, Instr, NUM_VREGS};
use crate::simd::patterns::Pattern;

/// Per-kernel cap on *recorded* violations: a systemically broken
/// paper-scale program would otherwise allocate millions of records.
/// Further violations are counted in [`KernelVerdict::suppressed`].
pub(crate) const MAX_VIOLATIONS: usize = 64;

/// Worst-case |decoded product| of one `p`-bit element pair in the
/// 2^-6 fixed-point grid: mantissas reach `2^p - 1` in magnitude
/// (packed code 0 decodes to `-(2^p - 1)`), and a `p`-bit product is
/// scaled by `2^(8 - 2p)` onto the grid — the same arithmetic as
/// `LayerPlan::tail_bias`, which is exactly why masked tail slots are
/// covered by this bound rather than excluded from it.
pub fn elem_prod_max(p: u8) -> i64 {
    let m = (1i64 << p) - 1;
    (m * m) << (8 - 2 * p)
}

/// Worst-case |value| of one i16 lane after a single `VmacP`: a
/// `p`-bit lane packs `16 / p` elements, each bounded by
/// [`elem_prod_max`]. (4-bit: 4*225 = 900; 2-bit: 8*144 = 1152;
/// 1-bit: 16*64 = 1024 — the `lane_sums_fit_16_6` invariant.)
pub fn lane_mac_max(p: u8) -> i64 {
    (16 / p as i64) * elem_prod_max(p)
}

/// Everything the abstract interpreter needs to know about the
/// environment a program runs in: buffer extents (indexed by the
/// symbolic `BufId` convention 0=input, 1=weights, 2=out, 3=masks),
/// the registered pattern table, and the contraction-axis chunk layout
/// (`(pattern, valid)` per chunk) the packed operands follow.
#[derive(Debug, Clone)]
pub struct KernelSpec {
    pub name: String,
    /// byte length of each buffer, indexed by `BufId.0`
    pub buf_len: Vec<usize>,
    /// the machine pattern table the program executes under (base 0)
    pub patterns: Vec<Pattern>,
    /// chunk layout of the packed contraction axis
    pub chunks: Vec<(Pattern, u32)>,
    pub fmt: DataFormat,
}

impl KernelSpec {
    /// Spec for a conv/FC layer emitted by `codegen::emit_layer`
    /// against the symbolic buffer ids, with buffer extents derived
    /// from the plan exactly like the engine's bind-time allocation.
    pub fn for_layer(plan: &LayerPlan) -> KernelSpec {
        let chunks = plan.chunks();
        let nch = chunks.len();
        let (hout, wout) = (plan.hout(), plan.wout());
        let act = plan.hin * plan.win * nch * 16;
        let (weights, out_elems) = match plan.kind {
            LayerKind::Dense => {
                (plan.cout * plan.kh * plan.kw * nch * 16, plan.cout * hout * wout)
            }
            LayerKind::Depthwise => (plan.kh * plan.kw * nch * 16, plan.cin * hout * wout),
        };
        // baseline depthwise stores whole 16 B chunk vectors per
        // position — same dual sizing as the engine's `layer_sizes`
        let out = (out_elems * 4).max(hout * wout * nch * 16);
        let mut patterns = Vec::new();
        register_patterns(plan, &mut patterns);
        KernelSpec {
            name: plan.name.clone(),
            buf_len: vec![act, weights, out, nch * 16],
            patterns,
            chunks,
            fmt: plan.fmt,
        }
    }

    /// Spec for a GEMM emitted by `emit_gemm`/`emit_gemm_causal`
    /// (buffer extents via the GEMM's 1x1 dense layer view).
    pub fn for_gemm(plan: &GemmPlan) -> KernelSpec {
        KernelSpec::for_layer(&plan.layer_plan())
    }

    /// Override the buffer extents with the sizes an op *actually*
    /// allocates at bind time (which may exceed the per-program
    /// minimum — e.g. attention buffers sized once for
    /// `max_positions` and shared by every per-length row program).
    pub fn with_buffers(mut self, input: usize, weights: usize, out: usize, masks: usize) -> Self {
        self.buf_len = vec![input, weights, out, masks];
        self
    }
}

/// A program to verify together with its spec — what
/// `PreparedOp::verify_programs` returns. Ops that cache a program
/// borrow it; ops that emit per-request (cached attention, causal A·V)
/// return freshly emitted representative programs, owned.
#[derive(Debug)]
pub struct ProgramToVerify<'a> {
    pub spec: KernelSpec,
    pub program: Cow<'a, [Instr]>,
    /// plan-derived term spec for the equivalence layer — `None` for
    /// baseline formats, whose kernels are timing models rather than
    /// functional contractions
    pub terms: Option<TermSpec>,
}

/// Abstract value of one vector register.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Abs {
    /// packed operand vector: `src` is the buffer it was loaded from,
    /// `chunk` its provenance chunk (None = layout unknown), `masked`
    /// whether a `Vand` against the chunk's tail mask was applied
    Packed { src: u16, chunk: Option<usize>, masked: bool },
    /// tail-mask vector for `chunk`
    Mask { chunk: usize },
    /// 8 i16 lanes of MAC partials; per-lane worst-case |value|
    Lanes([i64; 8]),
    /// `vmul_Pn` low-half product register
    MulLo { chunk: Option<usize> },
    /// `vmul_Pn` high-half product register
    MulHi { chunk: Option<usize> },
}

/// The abstract interpreter. Feed instructions with [`step`]
/// (or stream an emitter into it — it implements [`Sink`]), then
/// [`finish`] to get the [`KernelVerdict`].
///
/// [`step`]: KernelVerifier::step
/// [`finish`]: KernelVerifier::finish
#[derive(Debug)]
pub struct KernelVerifier<'a> {
    spec: &'a KernelSpec,
    regs: [Option<Abs>; NUM_VREGS],
    /// worst-case accumulated bound per i32 output cell `(buf, off)`
    cells: HashMap<(u16, u32), i64>,
    /// cells already reported as overflowing (dedup)
    flagged: HashSet<(u16, u32)>,
    violations: Vec<Violation>,
    suppressed: usize,
    windows: WindowTracker,
    at: usize,
    instrs: u64,
    macs: u64,
    loads: u64,
    stores: u64,
    max_acc: i64,
    max_lane: i64,
}

impl<'a> KernelVerifier<'a> {
    pub fn new(spec: &'a KernelSpec) -> KernelVerifier<'a> {
        KernelVerifier {
            spec,
            regs: [None; NUM_VREGS],
            cells: HashMap::new(),
            flagged: HashSet::new(),
            violations: Vec::new(),
            suppressed: 0,
            windows: WindowTracker::default(),
            at: 0,
            instrs: 0,
            macs: 0,
            loads: 0,
            stores: 0,
            max_acc: 0,
            max_lane: 0,
        }
    }

    fn violate(&mut self, v: Violation) {
        if let Some(at) = v.at() {
            self.windows.record(at);
        }
        if self.violations.len() < MAX_VIOLATIONS {
            self.violations.push(v);
        } else {
            self.suppressed += 1;
        }
    }

    /// Read a register: def-before-use and index checks.
    fn read(&mut self, r: u8) -> Option<Abs> {
        if r as usize >= NUM_VREGS {
            self.violate(Violation::BadReg { at: self.at, reg: r });
            return None;
        }
        let v = self.regs[r as usize];
        if v.is_none() {
            self.violate(Violation::UndefinedReg { at: self.at, reg: r });
        }
        v
    }

    fn write(&mut self, r: u8, a: Abs) {
        if r as usize >= NUM_VREGS {
            self.violate(Violation::BadReg { at: self.at, reg: r });
        } else {
            self.regs[r as usize] = Some(a);
        }
    }

    /// Bounds + alignment check for an `extent`-byte access at `addr`;
    /// returns false when the buffer id itself is undeclared.
    fn check_addr(&mut self, addr: Addr, extent: u32, align: u32) -> bool {
        let b = addr.buf.0 as usize;
        if b >= self.spec.buf_len.len() {
            self.violate(Violation::BadBuf { at: self.at, buf: addr.buf.0 });
            return false;
        }
        if addr.off % align != 0 {
            self.violate(Violation::Misaligned { at: self.at, buf: addr.buf.0, off: addr.off, align });
        }
        if addr.off as usize + extent as usize > self.spec.buf_len[b] {
            self.violate(Violation::OutOfBounds {
                at: self.at,
                buf: addr.buf.0,
                off: addr.off,
                extent,
                len: self.spec.buf_len[b],
            });
        }
        true
    }

    /// Chunk provenance of a 16-byte slot in the input/weights
    /// buffers: every emitter layout is chunk-minor.
    fn chunk_of(&self, off: u32) -> Option<usize> {
        let n = self.spec.chunks.len();
        if n == 0 {
            None
        } else {
            Some((off as usize / 16) % n)
        }
    }

    /// `PatId` validity plus pattern/chunk-layout coherence for a
    /// MAC/MUL reading operands of provenance `chunk`.
    fn check_pattern(&mut self, pat: u8, chunk: Option<usize>) -> bool {
        if pat as usize >= self.spec.patterns.len() {
            self.violate(Violation::BadPatId {
                at: self.at,
                pat,
                table: self.spec.patterns.len(),
            });
            return false;
        }
        if let Some(c) = chunk {
            if c < self.spec.chunks.len() && self.spec.patterns[pat as usize] != self.spec.chunks[c].0
            {
                self.violate(Violation::PatternMismatch { at: self.at, pat, chunk: c });
                return false;
            }
        }
        true
    }

    /// Provenance consistency between a MAC's two packed operands;
    /// returns the merged chunk.
    fn merge_chunks(&mut self, ca: Option<usize>, cb: Option<usize>) -> Option<usize> {
        if let (Some(a), Some(b)) = (ca, cb) {
            if a != b {
                self.violate(Violation::ChunkMismatch { at: self.at, a, b });
            }
        }
        ca.or(cb)
    }

    /// Unpack a MAC operand register into (chunk, masked, from-input).
    fn packed_operand(&mut self, r: u8, what: &str) -> (Option<usize>, bool, bool) {
        match self.read(r) {
            Some(Abs::Packed { src, chunk, masked }) => (chunk, masked, src == 0),
            Some(other) => {
                self.violate(Violation::OperandKind {
                    at: self.at,
                    what: format!("{what} wants a packed operand vector, register holds {other:?}"),
                });
                (None, true, false)
            }
            None => (None, true, false),
        }
    }

    /// Accumulate a worst-case contribution into an output cell and
    /// check the running bound against the i32 range.
    fn accumulate(&mut self, buf: u16, off: u32, contribution: i64) {
        let cell = self.cells.entry((buf, off)).or_insert(0);
        *cell += contribution;
        let bound = *cell;
        self.max_acc = self.max_acc.max(bound);
        if bound > i32::MAX as i64 && self.flagged.insert((buf, off)) {
            self.violate(Violation::AccOverflow { buf, off, bound });
        }
    }

    /// Interpret one instruction.
    pub fn step(&mut self, i: &Instr) {
        self.windows.observe(self.at, i);
        self.instrs += 1;
        match *i {
            Instr::LdQ { dst, addr } => {
                self.loads += 1;
                self.check_addr(addr, 16, 16);
                let abs = match addr.buf.0 {
                    3 => Abs::Mask { chunk: (addr.off / 16) as usize },
                    b @ (0 | 1) => {
                        Abs::Packed { src: b, chunk: self.chunk_of(addr.off), masked: false }
                    }
                    b => Abs::Packed { src: b, chunk: None, masked: false },
                };
                self.write(dst, abs);
            }
            Instr::StQ { src, addr } => {
                self.stores += 1;
                self.read(src);
                self.check_addr(addr, 16, 16);
            }
            Instr::VmovZ { dst } => {
                self.write(dst, Abs::Lanes([0; 8]));
            }
            Instr::Vand { dst, a, b } => {
                let (va, vb) = (self.read(a), self.read(b));
                let abs = match (va, vb) {
                    (Some(Abs::Packed { src, chunk, .. }), Some(Abs::Mask { chunk: mc }))
                    | (Some(Abs::Mask { chunk: mc }), Some(Abs::Packed { src, chunk, .. })) => {
                        if let Some(c) = chunk {
                            if c != mc {
                                self.violate(Violation::ChunkMismatch { at: self.at, a: c, b: mc });
                            }
                        }
                        Abs::Packed { src, chunk: chunk.or(Some(mc)), masked: true }
                    }
                    (Some(x), Some(y)) => {
                        self.violate(Violation::OperandKind {
                            at: self.at,
                            what: format!(
                                "vand wants a packed operand and a tail mask, got {x:?} and {y:?}"
                            ),
                        });
                        Abs::Packed { src: u16::MAX, chunk: None, masked: true }
                    }
                    // undefined operand already reported by read()
                    _ => Abs::Packed { src: u16::MAX, chunk: None, masked: true },
                };
                self.write(dst, abs);
            }
            Instr::VmacP { dst, a, b, pat } => {
                self.macs += 1;
                let (ca, ma, ia) = self.packed_operand(a, "vmac_Pn");
                let (cb, mb, ib) = self.packed_operand(b, "vmac_Pn");
                let chunk = self.merge_chunks(ca, cb);
                let pat_ok = self.check_pattern(pat, chunk);
                // partial chunks must mask the input-side operand (the
                // packed weights are pre-masked at pack time)
                if self.spec.fmt == DataFormat::Smol {
                    if let Some(c) = chunk {
                        if let Some(&(p, valid)) = self.spec.chunks.get(c) {
                            let partial = valid < p.capacity();
                            let input_unmasked = (ia && !ma) || (ib && !mb);
                            if partial && input_unmasked {
                                self.violate(Violation::UnmaskedTail { at: self.at, chunk: c });
                            }
                        }
                    }
                }
                let lanes = if pat_ok && (pat as usize) < self.spec.patterns.len() {
                    let mut l = [0i64; 8];
                    for (o, p) in l.iter_mut().zip(self.spec.patterns[pat as usize].lane_precisions())
                    {
                        *o = lane_mac_max(p);
                    }
                    l
                } else {
                    [0; 8]
                };
                self.max_lane = self.max_lane.max(lanes.iter().copied().max().unwrap_or(0));
                self.write(dst, Abs::Lanes(lanes));
            }
            Instr::VmulP { dst, dst2, a, b, pat } => {
                self.macs += 1;
                if dst == dst2 {
                    self.violate(Violation::OperandKind {
                        at: self.at,
                        what: format!("vmul_Pn lo/hi destinations collide (reg {dst})"),
                    });
                }
                let (ca, _, _) = self.packed_operand(a, "vmul_Pn");
                let (cb, _, _) = self.packed_operand(b, "vmul_Pn");
                let chunk = self.merge_chunks(ca, cb);
                self.check_pattern(pat, chunk);
                self.write(dst, Abs::MulLo { chunk });
                self.write(dst2, Abs::MulHi { chunk });
            }
            Instr::Vaddq16 { dst, a, b } => {
                let (va, vb) = (self.read(a), self.read(b));
                let lane = |v: Option<Abs>, this: &mut Self| match v {
                    Some(Abs::Lanes(l)) => l,
                    Some(other) => {
                        this.violate(Violation::OperandKind {
                            at: this.at,
                            what: format!("vaddq_s16 wants lane accumulators, got {other:?}"),
                        });
                        [0; 8]
                    }
                    None => [0; 8],
                };
                let (la, lb) = (lane(va, self), lane(vb, self));
                let mut sum = [0i64; 8];
                for i in 0..8 {
                    sum[i] = la[i] + lb[i];
                    if sum[i] > i16::MAX as i64 {
                        self.violate(Violation::LaneOverflow { at: self.at, lane: i, bound: sum[i] });
                    }
                }
                self.max_lane = self.max_lane.max(sum.iter().copied().max().unwrap_or(0));
                self.write(dst, Abs::Lanes(sum));
            }
            Instr::ReduceAcc { src, addr } => {
                self.stores += 1;
                let contribution = match self.read(src) {
                    Some(Abs::Lanes(l)) => l.iter().sum(),
                    Some(other) => {
                        self.violate(Violation::OperandKind {
                            at: self.at,
                            what: format!("reduce-acc wants lane accumulators, got {other:?}"),
                        });
                        0
                    }
                    None => 0,
                };
                if self.check_addr(addr, 4, 4) {
                    self.accumulate(addr.buf.0, addr.off, contribution);
                }
            }
            Instr::MulAcc { lo, hi, pat, addr, n_valid } => {
                self.stores += 1;
                let clo = match self.read(lo) {
                    Some(Abs::MulLo { chunk }) => chunk,
                    Some(other) => {
                        self.violate(Violation::OperandKind {
                            at: self.at,
                            what: format!("mul-acc lo wants a vmul low half, got {other:?}"),
                        });
                        None
                    }
                    None => None,
                };
                let chi = match self.read(hi) {
                    Some(Abs::MulHi { chunk }) => chunk,
                    Some(other) => {
                        self.violate(Violation::OperandKind {
                            at: self.at,
                            what: format!("mul-acc hi wants a vmul high half, got {other:?}"),
                        });
                        None
                    }
                    None => None,
                };
                let chunk = self.merge_chunks(clo, chi);
                let pat_ok = self.check_pattern(pat, chunk);
                if pat_ok {
                    let p = self.spec.patterns[pat as usize];
                    if n_valid as u32 > p.capacity() {
                        self.violate(Violation::NValidExceedsCapacity {
                            at: self.at,
                            n_valid,
                            capacity: p.capacity(),
                        });
                    }
                }
                let ok = self.check_addr(addr, 4 * n_valid as u32, 4);
                if ok && pat_ok {
                    let p = self.spec.patterns[pat as usize];
                    for e in 0..(n_valid as u32).min(p.capacity()) {
                        let contribution = elem_prod_max(p.element_precision(e));
                        self.accumulate(addr.buf.0, addr.off + 4 * e, contribution);
                    }
                }
            }
            Instr::VfmaF32 { dst, a, b } => {
                self.macs += 1;
                // FMA reads its destination as the accumulator
                self.read(a);
                self.read(b);
                self.read(dst);
            }
            Instr::VmacI8 { dst, a, b } => {
                self.macs += 1;
                self.read(a);
                self.read(b);
                // functional no-op in the simulator (timing-only
                // baseline); lanes are architecturally zero
                self.write(dst, Abs::Lanes([0; 8]));
            }
        }
        self.at += 1;
    }

    /// Close the analysis and produce the verdict. The f32
    /// exact-integer-range check applies to SMOL kernels only —
    /// baseline formats accumulate outside the fixed-point grid.
    pub fn finish(mut self) -> KernelVerdict {
        if self.spec.fmt == DataFormat::Smol && self.max_acc > F32_EXACT_BOUND {
            let bound = self.max_acc;
            self.violate(Violation::AccExactRange { bound, limit: F32_EXACT_BOUND });
        }
        KernelVerdict {
            name: self.spec.name.clone(),
            instrs: self.instrs,
            macs: self.macs,
            loads: self.loads,
            stores: self.stores,
            max_acc_bound: self.max_acc,
            max_lane_bound: self.max_lane,
            violations: self.violations,
            suppressed: self.suppressed,
            windows: self.windows.finish(),
        }
    }
}

impl Sink for KernelVerifier<'_> {
    fn emit(&mut self, i: Instr) {
        self.step(&i);
    }
}

/// Verify one materialized program against its spec.
pub fn verify_program(spec: &KernelSpec, program: &[Instr]) -> KernelVerdict {
    let mut v = KernelVerifier::new(spec);
    for i in program {
        v.step(i);
    }
    v.finish()
}
