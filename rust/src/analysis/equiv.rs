//! Term-provenance equivalence: prove an emitted kernel computes
//! *exactly* its plan's contraction.
//!
//! [`super::kernel`] proves a program is safe (no out-of-bounds
//! access, no overflow, tails masked); it says nothing about *which*
//! terms an output cell accumulates — a dropped, duplicated or
//! mis-mapped MAC sails straight through it. [`EquivVerifier`] closes
//! that gap. It symbolically interprets the same `Instr` stream (it
//! implements [`Sink`], so paper-scale layers stream the emitter into
//! it exactly like the safety layer), tracking for every vector
//! register the packed 16-byte slot it was loaded from and for every
//! lane accumulator the exact multiset of `(activation slot, weight
//! slot, pattern)` products it holds. Each `ReduceAcc`/`MulAcc` then
//! expands those products into canonical *terms* — `(output cell,
//! original channel index, tap)` triples recovered from the emitters'
//! chunk-minor address decompositions — and checks the recovered
//! multiset against a [`TermSpec`] derived independently from the
//! `LayerPlan`/`GemmPlan`:
//!
//! - every term the contraction requires accumulates **exactly once**
//!   ([`Violation::MissingTerm`] / [`Violation::DuplicateTerm`]);
//! - nothing outside the contraction contributes — wrong chunk pair,
//!   wrong output channel, wrong spatial tap, wrong per-element
//!   precision, or a causal upper-triangle pair
//!   ([`Violation::ForeignTerm`]);
//! - a partial chunk's tail lanes are provably masked before they
//!   contribute ([`Violation::UnmaskedTailTerm`]), and each partial
//!   chunk contributes exactly `valid_taps(h, w)` masked MACs per
//!   cell — the count the engine's tail-bias epilogue subtracts, so a
//!   mismatch means the dequantized output is silently wrong
//!   ([`Violation::EpilogueMismatch`]);
//! - causal GEMM twins skip exactly the upper triangle: a skipped
//!   cell expects zero terms *and* zero epilogue contributions.
//!
//! Equivalence is a SMOL-only property: [`TermSpec::for_layer`]
//! returns `None` for baseline formats, whose kernels are timing
//! models rather than functional contractions, and the plan layer
//! simply skips the pass for them.
//!
//! [`shard_term_partition`] lifts the same term sets to deployments:
//! once every shard's kernel is proven equivalent to its own
//! [`TermSpec`], the shards' term sets (remapped through their slice
//! offsets) must tile the whole node's term set exactly — upgrading
//! the bit-exact-reduce argument from "accumulators stay on the exact
//! grid" to "shards compute disjoint, exhaustive term subsets".

use std::collections::HashSet;

use super::kernel::{KernelSpec, MAX_VIOLATIONS};
use super::{verify_program, DisasmWindow, KernelVerdict, Violation, WindowTracker};
use crate::codegen::gemm::GemmPlan;
use crate::codegen::{DataFormat, LayerKind, LayerPlan, Sink};
use crate::simd::isa::{Instr, NUM_VREGS};
use crate::simd::patterns::Pattern;

/// The plan-side ground truth the symbolic interpreter checks a
/// program against: the layer geometry (which enumerates the required
/// `(cell, channel, tap)` term set) plus the packed chunk layout
/// (which decodes *recovered* slots back to original channels).
/// Derived from the plan alone — never from the program.
#[derive(Debug, Clone, Hash)]
pub struct TermSpec {
    kind: LayerKind,
    /// causal GEMM twin: cell `(j, i)` exists only for `j <= i`
    causal: bool,
    cin: usize,
    cout: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    hin: usize,
    win: usize,
    hout: usize,
    wout: usize,
    pt: isize,
    pl: isize,
    /// packed chunk layout `(pattern, valid)`, zero-valid chunks
    /// filtered — mirrors `LayerPlan::chunks()`
    chunks: Vec<(Pattern, u32)>,
    /// per chunk: original channel index of each valid element
    /// (Observation 4 rearrangement, from `Assignment::order`)
    chan_of: Vec<Vec<u32>>,
    /// packed channel position of each chunk's first element
    chunk_start: Vec<u32>,
    /// per *original* channel: assigned precision
    prec_of: Vec<u8>,
}

impl TermSpec {
    /// Term spec of a conv/FC layer. `None` when the layer is not a
    /// SMOL contraction (baseline formats) or the assignment does not
    /// cover the contraction axis (the plan layer reports that
    /// structurally).
    pub fn for_layer(plan: &LayerPlan) -> Option<TermSpec> {
        TermSpec::for_layer_causal(plan, false)
    }

    /// [`TermSpec::for_layer`] for GEMMs lowered to their 1x1 dense
    /// view, with the causal flag carried through (`emit_gemm_causal`
    /// must skip exactly the upper triangle).
    pub fn for_layer_causal(plan: &LayerPlan, causal: bool) -> Option<TermSpec> {
        if plan.fmt != DataFormat::Smol {
            return None;
        }
        let chunks = plan.chunks();
        let total: u32 = chunks.iter().map(|&(_, v)| v).sum();
        if total as usize != plan.asg.order.len()
            || plan.asg.precision.len() != plan.cin
            || total as usize != plan.cin
        {
            return None; // malformed assignment: plan layer reports it
        }
        let mut chan_of = Vec::with_capacity(chunks.len());
        let mut chunk_start = Vec::with_capacity(chunks.len());
        let mut base = 0usize;
        for &(_, v) in &chunks {
            chunk_start.push(base as u32);
            chan_of.push(plan.asg.order[base..base + v as usize].to_vec());
            base += v as usize;
        }
        if chan_of.iter().flatten().any(|&ch| ch as usize >= plan.cin) {
            return None;
        }
        Some(TermSpec {
            kind: plan.kind,
            causal,
            cin: plan.cin,
            cout: plan.cout,
            kh: plan.kh,
            kw: plan.kw,
            stride: plan.stride,
            hin: plan.hin,
            win: plan.win,
            hout: plan.hout(),
            wout: plan.wout(),
            pt: plan.pad_top(),
            pl: plan.pad_left(),
            chunks,
            chan_of,
            chunk_start,
            prec_of: plan.asg.precision.clone(),
        })
    }

    /// Term spec of a GEMM (`emit_gemm` / `emit_gemm_causal`).
    pub fn for_gemm(plan: &GemmPlan, causal: bool) -> Option<TermSpec> {
        TermSpec::for_layer_causal(&plan.layer_plan(), causal)
    }

    /// Output-cell count in the kernel's own cell encoding.
    fn cells(&self) -> usize {
        match self.kind {
            LayerKind::Dense => self.cout * self.hout * self.wout,
            LayerKind::Depthwise => self.hout * self.wout * self.cin,
        }
    }

    /// Input position tap `(r, s)` reads for output `(h, w)` — `None`
    /// when the tap falls in the XLA-SAME padding.
    fn tap_pos(&self, h: usize, w: usize, r: usize, s: usize) -> Option<(usize, usize)> {
        let ih = h as isize * self.stride as isize + r as isize - self.pt;
        let iw = w as isize * self.stride as isize + s as isize - self.pl;
        (ih >= 0 && iw >= 0 && ih < self.hin as isize && iw < self.win as isize)
            .then_some((ih as usize, iw as usize))
    }

    /// In-bounds tap count for output `(h, w)` — the multiplier of the
    /// engine's per-cell tail-bias subtraction.
    fn valid_taps(&self, h: usize, w: usize) -> u32 {
        let mut n = 0;
        for r in 0..self.kh {
            for s in 0..self.kw {
                if self.tap_pos(h, w, r, s).is_some() {
                    n += 1;
                }
            }
        }
        n
    }

    /// The full `(cell, channel, tap)` term set this spec requires,
    /// with shard remaps applied: `k_off` shifts the output-channel
    /// axis (a `cout`/`n` split slice), `chan_off` the contraction
    /// axis (a `cin`/`k` reduce slice). Spatial extents are untouched
    /// by either split, so the remapped cell encoding matches the
    /// whole-model spec's. `None` for depthwise or causal kinds, which
    /// the shard planner never splits.
    pub fn term_set(&self, k_off: usize, chan_off: usize) -> Option<HashSet<(usize, u32, usize)>> {
        if self.kind != LayerKind::Dense || self.causal {
            return None;
        }
        let mut set = HashSet::with_capacity(self.cells() * self.cin);
        for k in 0..self.cout {
            for h in 0..self.hout {
                for w in 0..self.wout {
                    let cell = ((k + k_off) * self.hout + h) * self.wout + w;
                    for r in 0..self.kh {
                        for s in 0..self.kw {
                            if self.tap_pos(h, w, r, s).is_none() {
                                continue;
                            }
                            let tap = r * self.kw + s;
                            for ch in 0..self.cin {
                                set.insert((cell, (ch + chan_off) as u32, tap));
                            }
                        }
                    }
                }
            }
        }
        Some(set)
    }
}

/// One symbolic product: a `VmacP`/`VmulP` of an activation slot
/// against a weight slot under a pattern, with the activation side's
/// mask provenance (weights are pre-masked at pack time).
#[derive(Debug, Clone, Copy, PartialEq)]
struct Prod {
    a_slot: u32,
    w_slot: u32,
    masked: bool,
    pat: u8,
}

/// Abstract value of one vector register under provenance tracking.
#[derive(Debug, Clone)]
enum EAbs {
    /// 16-byte slot `off / 16` of buffer `src`, `masked` iff a `Vand`
    /// against the slot's own chunk mask was applied
    Packed { src: u16, slot: u32, masked: bool },
    /// tail-mask vector of chunk `chunk`
    MaskV { chunk: u32 },
    /// lane accumulator holding exactly these products
    Acc(Vec<Prod>),
    /// `vmul_Pn` low half of one product
    MulLo(Prod),
    /// `vmul_Pn` high half of one product
    MulHi(Prod),
    /// provenance lost (wrong operand kinds — the safety layer
    /// reports the kind defect; here it poisons downstream terms)
    Unknown,
}

/// Verdict of one equivalence pass, merged into the program's
/// [`KernelVerdict`] by the plan layer.
#[derive(Debug, Clone, Default)]
pub struct EquivVerdict {
    pub violations: Vec<Violation>,
    pub suppressed: usize,
    pub windows: Vec<DisasmWindow>,
}

impl EquivVerdict {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty() && self.suppressed == 0
    }
}

/// The symbolic term-provenance interpreter. Feed instructions with
/// [`step`] (or stream an emitter into it — it implements [`Sink`]),
/// then [`finish`] for the [`EquivVerdict`].
///
/// [`step`]: EquivVerifier::step
/// [`finish`]: EquivVerifier::finish
#[derive(Debug)]
pub struct EquivVerifier<'a> {
    spec: &'a KernelSpec,
    terms: &'a TermSpec,
    regs: Vec<Option<EAbs>>,
    /// saturating accumulation count per required term
    counts: Vec<u8>,
    /// chunk index of each partial chunk, in chunk order
    partials: Vec<usize>,
    /// per chunk: index into `partials` (None = full chunk)
    partial_idx: Vec<Option<usize>>,
    /// masked-MAC count per `(cell, partial chunk)` — must equal the
    /// cell's `valid_taps` so the tail-bias epilogue subtracts exactly
    /// what the tail lanes contributed
    bias: Vec<u32>,
    violations: Vec<Violation>,
    suppressed: usize,
    windows: WindowTracker,
    at: usize,
}

impl<'a> EquivVerifier<'a> {
    pub fn new(spec: &'a KernelSpec, terms: &'a TermSpec) -> EquivVerifier<'a> {
        let ntaps = terms.kh * terms.kw;
        let n_counts = match terms.kind {
            LayerKind::Dense => terms.cells() * terms.cin * ntaps,
            LayerKind::Depthwise => terms.cells() * ntaps,
        };
        let mut partials = Vec::new();
        let mut partial_idx = Vec::with_capacity(terms.chunks.len());
        for (ci, &(pat, valid)) in terms.chunks.iter().enumerate() {
            if valid < pat.capacity() {
                partial_idx.push(Some(partials.len()));
                partials.push(ci);
            } else {
                partial_idx.push(None);
            }
        }
        // bias tracking is a dense-path contract (depthwise `MulAcc`
        // never writes tail elements, so there is nothing to correct)
        let n_bias = match terms.kind {
            LayerKind::Dense => terms.cells() * partials.len(),
            LayerKind::Depthwise => 0,
        };
        EquivVerifier {
            spec,
            terms,
            regs: vec![None; NUM_VREGS],
            counts: vec![0; n_counts],
            partials,
            partial_idx,
            bias: vec![0; n_bias],
            violations: Vec::new(),
            suppressed: 0,
            windows: WindowTracker::default(),
            at: 0,
        }
    }

    fn violate(&mut self, v: Violation) {
        if let Some(at) = v.at() {
            self.windows.record(at);
        }
        if self.violations.len() < MAX_VIOLATIONS {
            self.violations.push(v);
        } else {
            self.suppressed += 1;
        }
    }

    fn read(&self, r: u8) -> EAbs {
        self.regs
            .get(r as usize)
            .and_then(|v| v.clone())
            .unwrap_or(EAbs::Unknown)
    }

    fn write(&mut self, r: u8, v: EAbs) {
        if let Some(slot) = self.regs.get_mut(r as usize) {
            *slot = Some(v);
        }
    }

    /// Split a MAC/MUL operand pair into `(input side, weight side)`
    /// by buffer provenance (symbolic convention: 0 = input,
    /// 1 = weights). `None` loses provenance — the safety layer
    /// reports the operand-kind defect.
    fn product_of(&self, a: EAbs, b: EAbs, pat: u8) -> Option<Prod> {
        match (a, b) {
            (
                EAbs::Packed { src: sa, slot: la, masked: ma },
                EAbs::Packed { src: sb, slot: lb, masked: mb },
            ) => {
                if sa == 0 && sb == 1 {
                    Some(Prod { a_slot: la, w_slot: lb, masked: ma, pat })
                } else if sa == 1 && sb == 0 {
                    Some(Prod { a_slot: lb, w_slot: la, masked: mb, pat })
                } else {
                    None
                }
            }
            _ => None,
        }
    }

    /// Per-element pattern the hardware decodes a product with: the
    /// instruction's `PatId` when registered, else the chunk's layout
    /// pattern (the safety layer flags the bad id itself).
    fn decode_pattern(&self, pat: u8, ci: usize) -> Pattern {
        self.spec
            .patterns
            .get(pat as usize)
            .copied()
            .unwrap_or(self.terms.chunks[ci].0)
    }

    /// Expand one reduced product into dense-layer terms at `cell`.
    fn expand_dense(&mut self, p: Prod, cell: usize) {
        let t = self.terms;
        let nch = t.chunks.len();
        if nch == 0 {
            return;
        }
        let (a_slot, w_slot) = (p.a_slot as usize, p.w_slot as usize);
        let ci = a_slot % nch;
        if w_slot % nch != ci {
            return self.violate(Violation::ForeignTerm {
                at: self.at,
                cell,
                detail: format!(
                    "activation chunk {ci} multiplied against weight chunk {}",
                    w_slot % nch
                ),
            });
        }
        let a_row = a_slot / nch;
        let (ih, iw) = (a_row / t.win, a_row % t.win);
        let wrest = w_slot / nch;
        let s = wrest % t.kw;
        let r = (wrest / t.kw) % t.kh;
        let k = wrest / (t.kw * t.kh);
        let tap = r * t.kw + s;
        if cell >= t.cells() {
            return self.violate(Violation::ForeignTerm {
                at: self.at,
                cell,
                detail: format!("cell outside the {}-cell output extent", t.cells()),
            });
        }
        let w_c = cell % t.wout;
        let h_c = (cell / t.wout) % t.hout;
        let k_c = cell / (t.wout * t.hout);
        if k != k_c {
            return self.violate(Violation::ForeignTerm {
                at: self.at,
                cell,
                detail: format!("weight row k={k} accumulates into output channel {k_c}"),
            });
        }
        if t.causal && k_c > h_c {
            return self.violate(Violation::ForeignTerm {
                at: self.at,
                cell,
                detail: format!("causal upper-triangle term (column {k_c} > row {h_c})"),
            });
        }
        match t.tap_pos(h_c, w_c, r, s) {
            Some(pos) if pos == (ih, iw) => {}
            Some((eh, ew)) => {
                return self.violate(Violation::ForeignTerm {
                    at: self.at,
                    cell,
                    detail: format!(
                        "tap ({r},{s}) reads activation ({ih},{iw}), plan reads ({eh},{ew})"
                    ),
                });
            }
            None => {
                return self.violate(Violation::ForeignTerm {
                    at: self.at,
                    cell,
                    detail: format!("padding tap ({r},{s}) accumulates into cell ({h_c},{w_c})"),
                });
            }
        }
        let valid = t.chunks[ci].1;
        self.count_elements_n(p, ci, cell, tap, cell, valid);
        // tail accounting: a partial chunk's masked MAC is one unit of
        // the bias the epilogue subtracts; unmasked tails are garbage
        let (pat, valid) = t.chunks[ci];
        if valid < pat.capacity() {
            if p.masked {
                if let Some(pi) = self.partial_idx[ci] {
                    self.bias[cell * self.partials.len() + pi] += 1;
                }
            } else {
                self.violate(Violation::UnmaskedTailTerm { at: self.at, cell, chunk: ci });
            }
        }
    }

    /// Expand one `MulAcc` scatter into depthwise terms starting at
    /// packed output position `cell0`.
    fn expand_depthwise(&mut self, p: Prod, cell0: usize, n_valid: u16) {
        let t = self.terms;
        let nch = t.chunks.len();
        if nch == 0 {
            return;
        }
        let (a_slot, w_slot) = (p.a_slot as usize, p.w_slot as usize);
        let ci = a_slot % nch;
        if w_slot % nch != ci {
            return self.violate(Violation::ForeignTerm {
                at: self.at,
                cell: cell0,
                detail: format!(
                    "activation chunk {ci} multiplied against weight chunk {}",
                    w_slot % nch
                ),
            });
        }
        let a_row = a_slot / nch;
        let (ih, iw) = (a_row / t.win, a_row % t.win);
        let wrest = w_slot / nch;
        let s = wrest % t.kw;
        let r = wrest / t.kw;
        if r >= t.kh {
            return self.violate(Violation::ForeignTerm {
                at: self.at,
                cell: cell0,
                detail: format!("weight slot beyond the {}x{} tap extent", t.kh, t.kw),
            });
        }
        let tap = r * t.kw + s;
        let spatial = cell0 / t.cin;
        let pos0 = cell0 % t.cin;
        if spatial >= t.hout * t.wout {
            return self.violate(Violation::ForeignTerm {
                at: self.at,
                cell: cell0,
                detail: format!("cell outside the {}-cell output extent", t.cells()),
            });
        }
        if pos0 != t.chunk_start[ci] as usize {
            return self.violate(Violation::ForeignTerm {
                at: self.at,
                cell: cell0,
                detail: format!(
                    "chunk {ci} scatters at packed position {pos0}, its channels start at {}",
                    t.chunk_start[ci]
                ),
            });
        }
        let (h_c, w_c) = (spatial / t.wout, spatial % t.wout);
        match t.tap_pos(h_c, w_c, r, s) {
            Some(pos) if pos == (ih, iw) => {}
            Some((eh, ew)) => {
                return self.violate(Violation::ForeignTerm {
                    at: self.at,
                    cell: cell0,
                    detail: format!(
                        "tap ({r},{s}) reads activation ({ih},{iw}), plan reads ({eh},{ew})"
                    ),
                });
            }
            None => {
                return self.violate(Violation::ForeignTerm {
                    at: self.at,
                    cell: cell0,
                    detail: format!("padding tap ({r},{s}) accumulates into cell ({h_c},{w_c})"),
                });
            }
        }
        let valid = t.chunks[ci].1;
        if u32::from(n_valid) > valid {
            // widened scatter: elements beyond the chunk's channel set
            self.violate(Violation::ForeignTerm {
                at: self.at,
                cell: cell0 + valid as usize,
                detail: format!(
                    "mul-acc scatters {n_valid} elements, chunk {ci} holds {valid} channels"
                ),
            });
        }
        self.count_elements_n(p, ci, spatial, tap, cell0, u32::from(n_valid).min(valid));
    }

    /// Count terms for elements `0..n` of chunk `ci`, anchored at
    /// output base `row` (dense: the cell itself; depthwise: the
    /// spatial position — the element index selects the channel).
    fn count_elements_n(
        &mut self,
        p: Prod,
        ci: usize,
        row: usize,
        tap: usize,
        at_cell: usize,
        n: u32,
    ) {
        let t = self.terms;
        let ntaps = t.kh * t.kw;
        let ipat = self.decode_pattern(p.pat, ci);
        for e in 0..n {
            let channel = t.chan_of[ci][e as usize];
            let Some(&cp) = t.prec_of.get(channel as usize) else {
                self.violate(Violation::ForeignTerm {
                    at: self.at,
                    cell: at_cell,
                    detail: format!("chunk {ci} element {e} maps to unknown channel {channel}"),
                });
                continue;
            };
            if ipat.element_precision(e) != cp {
                self.violate(Violation::ForeignTerm {
                    at: self.at,
                    cell: at_cell,
                    detail: format!(
                        "chunk {ci} element {e} decodes at {} bits, channel {channel} is \
                         assigned {cp}",
                        ipat.element_precision(e)
                    ),
                });
                continue;
            }
            let idx = (row * t.cin + channel as usize) * ntaps + tap;
            let cell = match t.kind {
                LayerKind::Dense => at_cell,
                LayerKind::Depthwise => at_cell + e as usize,
            };
            let c = &mut self.counts[idx];
            *c = c.saturating_add(1);
            if *c == 2 {
                self.violate(Violation::DuplicateTerm { at: self.at, cell, channel, tap });
            }
        }
    }

    /// Interpret one instruction.
    pub fn step(&mut self, i: &Instr) {
        self.windows.observe(self.at, i);
        match *i {
            Instr::LdQ { dst, addr } => {
                let abs = match addr.buf.0 {
                    3 => EAbs::MaskV { chunk: addr.off / 16 },
                    b @ (0 | 1) => EAbs::Packed { src: b, slot: addr.off / 16, masked: false },
                    _ => EAbs::Unknown,
                };
                self.write(dst, abs);
            }
            Instr::StQ { .. } => {}
            Instr::VmovZ { dst } => {
                self.write(dst, EAbs::Acc(Vec::new()));
            }
            Instr::Vand { dst, a, b } => {
                let (va, vb) = (self.read(a), self.read(b));
                let abs = match (va, vb) {
                    (EAbs::Packed { src, slot, masked }, EAbs::MaskV { chunk })
                    | (EAbs::MaskV { chunk }, EAbs::Packed { src, slot, masked }) => {
                        let nch = self.terms.chunks.len() as u32;
                        // only the slot's *own* chunk mask proves the
                        // tail zeroed; a foreign mask does not
                        let own = nch > 0 && slot % nch == chunk;
                        EAbs::Packed { src, slot, masked: masked || own }
                    }
                    _ => EAbs::Unknown,
                };
                self.write(dst, abs);
            }
            Instr::VmacP { dst, a, b, pat } => {
                let (va, vb) = (self.read(a), self.read(b));
                let abs = match self.product_of(va, vb, pat) {
                    Some(p) => EAbs::Acc(vec![p]),
                    None => EAbs::Unknown,
                };
                self.write(dst, abs);
            }
            Instr::VmulP { dst, dst2, a, b, pat } => {
                let (va, vb) = (self.read(a), self.read(b));
                match self.product_of(va, vb, pat) {
                    Some(p) => {
                        self.write(dst, EAbs::MulLo(p));
                        self.write(dst2, EAbs::MulHi(p));
                    }
                    None => {
                        self.write(dst, EAbs::Unknown);
                        self.write(dst2, EAbs::Unknown);
                    }
                }
            }
            Instr::Vaddq16 { dst, a, b } => {
                let (va, vb) = (self.read(a), self.read(b));
                let abs = match (va, vb) {
                    (EAbs::Acc(mut x), EAbs::Acc(y)) => {
                        x.extend(y);
                        EAbs::Acc(x)
                    }
                    _ => EAbs::Unknown,
                };
                self.write(dst, abs);
            }
            Instr::ReduceAcc { src, addr } => {
                if addr.buf.0 == 2 {
                    let cell = (addr.off / 4) as usize;
                    match self.read(src) {
                        EAbs::Acc(prods) => {
                            for p in prods {
                                self.expand_dense(p, cell);
                            }
                        }
                        _ => self.violate(Violation::ForeignTerm {
                            at: self.at,
                            cell,
                            detail: "accumulator with unknown provenance reduces into the output"
                                .into(),
                        }),
                    }
                }
            }
            Instr::MulAcc { lo, hi, pat: _, addr, n_valid } => {
                if addr.buf.0 == 2 {
                    let cell0 = (addr.off / 4) as usize;
                    match (self.read(lo), self.read(hi)) {
                        (EAbs::MulLo(pl), EAbs::MulHi(ph)) if pl == ph => {
                            self.expand_depthwise(pl, cell0, n_valid);
                        }
                        _ => self.violate(Violation::ForeignTerm {
                            at: self.at,
                            cell: cell0,
                            detail: "mul-acc halves with unknown or mismatched provenance".into(),
                        }),
                    }
                }
            }
            Instr::VfmaF32 { dst, .. } | Instr::VmacI8 { dst, .. } => {
                // baseline-format ops never appear in a SMOL kernel;
                // poison so any reduce of them is a foreign term
                self.write(dst, EAbs::Unknown);
            }
        }
        self.at += 1;
    }

    /// Close the analysis: sweep the required term set for terms that
    /// never accumulated and partial chunks whose masked-MAC count
    /// disagrees with the epilogue's tail-bias subtraction.
    pub fn finish(mut self) -> EquivVerdict {
        let t = self.terms;
        let ntaps = t.kh * t.kw;
        match t.kind {
            LayerKind::Dense => {
                for k in 0..t.cout {
                    for h in 0..t.hout {
                        for w in 0..t.wout {
                            let cell = (k * t.hout + h) * t.wout + w;
                            if t.causal && k > h {
                                // skipped cell: any term there was
                                // already flagged foreign; the engine
                                // never reads (or bias-corrects) it
                                continue;
                            }
                            let want = t.valid_taps(h, w);
                            for pi in 0..self.partials.len() {
                                let got = self.bias[cell * self.partials.len() + pi];
                                if got != want {
                                    let chunk = self.partials[pi];
                                    self.violate(Violation::EpilogueMismatch {
                                        cell,
                                        chunk,
                                        expected: want,
                                        got,
                                    });
                                }
                            }
                            for r in 0..t.kh {
                                for s in 0..t.kw {
                                    if t.tap_pos(h, w, r, s).is_none() {
                                        continue;
                                    }
                                    let tap = r * t.kw + s;
                                    for ch in 0..t.cin {
                                        if self.counts[(cell * t.cin + ch) * ntaps + tap] == 0 {
                                            self.violate(Violation::MissingTerm {
                                                cell,
                                                channel: ch as u32,
                                                tap,
                                            });
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
            LayerKind::Depthwise => {
                for h in 0..t.hout {
                    for w in 0..t.wout {
                        let spatial = h * t.wout + w;
                        for ci in 0..t.chunks.len() {
                            for e in 0..t.chunks[ci].1 as usize {
                                let channel = t.chan_of[ci][e];
                                let cell = spatial * t.cin + t.chunk_start[ci] as usize + e;
                                for r in 0..t.kh {
                                    for s in 0..t.kw {
                                        if t.tap_pos(h, w, r, s).is_none() {
                                            continue;
                                        }
                                        let tap = r * t.kw + s;
                                        let idx =
                                            (spatial * t.cin + channel as usize) * ntaps + tap;
                                        if self.counts[idx] == 0 {
                                            self.violate(Violation::MissingTerm {
                                                cell,
                                                channel,
                                                tap,
                                            });
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        EquivVerdict {
            violations: self.violations,
            suppressed: self.suppressed,
            windows: self.windows.finish(),
        }
    }
}

impl Sink for EquivVerifier<'_> {
    fn emit(&mut self, i: Instr) {
        self.step(&i);
    }
}

/// Verify one materialized program at full depth: the safety pass
/// always, plus the term-equivalence pass when a [`TermSpec`] is
/// derivable (SMOL contractions). Both passes' violations land in one
/// merged [`KernelVerdict`].
pub fn verify_program_full(
    spec: &KernelSpec,
    terms: Option<&TermSpec>,
    program: &[Instr],
) -> KernelVerdict {
    let mut verdict = verify_program(spec, program);
    if let Some(t) = terms {
        let mut v = EquivVerifier::new(spec, t);
        for i in program {
            v.step(i);
        }
        merge_equiv(&mut verdict, v.finish());
    }
    verdict
}

/// Fold an equivalence verdict into a program's safety verdict.
pub(crate) fn merge_equiv(k: &mut KernelVerdict, e: EquivVerdict) {
    k.violations.extend(e.violations);
    k.suppressed += e.suppressed;
    k.windows.extend(e.windows);
}

/// Deployment-level term partition: given the whole node's term spec
/// and each shard's (as actually prepared, with its slice offset on
/// `axis`), the shards' term sets must tile the whole set — disjoint
/// and exhaustive. Sound because each shard's kernel was separately
/// proven equivalent to its own spec, so spec-level set algebra
/// transfers to the kernels. Returns no violation when any spec has
/// no enumerable term set (depthwise/causal — never split today).
pub fn shard_term_partition(
    what: &str,
    whole: &TermSpec,
    shards: &[(TermSpec, usize)],
    axis: ShardAxis,
) -> Vec<Violation> {
    let Some(whole_set) = whole.term_set(0, 0) else {
        return Vec::new();
    };
    let mut union: HashSet<(usize, u32, usize)> = HashSet::with_capacity(whole_set.len());
    let mut overlap = 0usize;
    for (spec, off) in shards {
        let (k_off, chan_off) = match axis {
            ShardAxis::OutputChannels => (*off, 0),
            ShardAxis::Contraction => (0, *off),
        };
        let Some(set) = spec.term_set(k_off, chan_off) else {
            return Vec::new();
        };
        for term in set {
            if !union.insert(term) {
                overlap += 1;
            }
        }
    }
    let missing = whole_set.difference(&union).count();
    let foreign = union.difference(&whole_set).count();
    if overlap + missing + foreign > 0 {
        vec![Violation::ShardTermPartition {
            detail: format!(
                "{what}: shard term sets are not a partition of the whole node's \
                 ({overlap} overlapping, {missing} missing, {foreign} foreign terms)"
            ),
        }]
    } else {
        Vec::new()
    }
}

/// Which axis a shard slice offsets in [`shard_term_partition`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardAxis {
    /// split node: the `cout`/`n` axis is sliced, cells remap
    OutputChannels,
    /// reduce consumer: the `cin`/`k` axis is sliced, channels remap
    Contraction,
}
