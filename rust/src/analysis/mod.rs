//! `soniq::analysis` — static verification of emitted programs and
//! serving plans.
//!
//! Three layers (see DESIGN.md "Static analysis"):
//!
//! - [`kernel`]: an abstract interpreter over [`crate::simd::isa::Instr`]
//!   streams proving def-before-use, memory safety, pattern/chunk
//!   coherence, tail masking, and worst-case i16/i32 accumulator
//!   bounds — including the f32 exact-integer-range bound the
//!   bit-exact sharded reduction (PR 5) and the 2^-6 dequant grid
//!   rely on.
//! - [`equiv`]: a symbolic term-provenance interpreter over the same
//!   streams proving *semantic* equivalence — every output cell
//!   accumulates exactly the `(cell, channel, tap)` term multiset its
//!   plan's contraction requires, tails are masked before they
//!   contribute, partial-chunk tail bias matches the engine epilogue,
//!   and causal twins skip exactly the upper triangle. At deployment
//!   scope, shard term sets must exactly partition the whole node's.
//! - [`plan`]: structural checks over [`crate::serve::PreparedModel`],
//!   [`crate::serve::Deployment`] and [`crate::serve::KvPoolCfg`] —
//!   graph edges shape/precision-compatible, shard slices an exact
//!   partition, shard keys collision-free, bind bytes within budget,
//!   page geometry chunk-aligned with the V tier no wider than the
//!   position precision.
//!
//! Entry points: [`verify_program`] (one kernel, safety only),
//! [`verify_program_full`] (safety + term equivalence),
//! [`verify_model`] (every cached/representative program of a
//! prepared model, both passes; [`verify_model_level`] selects the
//! depth), [`verify_deployment`] (shard structure + term partition +
//! every shard's kernels), [`verify_graph`] / [`verify_kv`]
//! (pre-prepare structural passes).
//! `PreparedModel::prepare`/`prepare_decoder` call [`debug_verify`] in
//! debug builds — deduplicated by program fingerprint so suites that
//! prepare the same model repeatedly verify each unique program once —
//! and `serve-bench --verify` runs the full [`VerifyReport`] in
//! release.

pub mod equiv;
pub mod kernel;
pub mod plan;

pub use equiv::{
    shard_term_partition, verify_program_full, EquivVerdict, EquivVerifier, ShardAxis, TermSpec,
};
pub use kernel::{
    elem_prod_max, lane_mac_max, verify_program, KernelSpec, KernelVerifier, ProgramToVerify,
};
pub use plan::{
    verify_deployment, verify_graph, verify_kv, verify_model, verify_model_level, VerifyLevel,
};

use std::collections::VecDeque;
use std::fmt;

use crate::simd::isa::Instr;

/// Largest integer magnitude f32 represents exactly (2^24). SMOL
/// accumulators must stay within this so the fixed-point sums survive
/// the f32 dequant epilogue — and so sharded partial sums reduce
/// exactly in any association order. `i32::MAX` is the hard overflow
/// line; this is the *contract* line.
pub const F32_EXACT_BOUND: i64 = 1 << 24;

/// One proven defect. Kernel variants carry the instruction index
/// (`at`) they fired at; plan variants carry structural context.
#[derive(Debug, Clone, PartialEq)]
pub enum Violation {
    /// register read before any write
    UndefinedReg { at: usize, reg: u8 },
    /// register index outside the 32-vreg file
    BadReg { at: usize, reg: u8 },
    /// `BufId` not in the kernel's buffer table
    BadBuf { at: usize, buf: u16 },
    /// access extends past the buffer's packed length
    OutOfBounds { at: usize, buf: u16, off: u32, extent: u32, len: usize },
    /// offset not aligned to the access granularity
    Misaligned { at: usize, buf: u16, off: u32, align: u32 },
    /// `PatId` outside the registered pattern table
    BadPatId { at: usize, pat: u8, table: usize },
    /// pattern named by the `PatId` differs from the provenance
    /// chunk's pattern in the layout
    PatternMismatch { at: usize, pat: u8, chunk: usize },
    /// two operands (or operand and mask) from different chunks
    ChunkMismatch { at: usize, a: usize, b: usize },
    /// operand register holds the wrong kind of abstract value
    OperandKind { at: usize, what: String },
    /// partial chunk's input operand reached a MAC without a `Vand`
    /// against its tail mask
    UnmaskedTail { at: usize, chunk: usize },
    /// worst-case i16 lane partial exceeds `i16::MAX`
    LaneOverflow { at: usize, lane: usize, bound: i64 },
    /// worst-case i32 cell sum exceeds `i32::MAX`
    AccOverflow { buf: u16, off: u32, bound: i64 },
    /// SMOL kernel's max cell bound exceeds the f32 exact-integer
    /// range — the bit-exact sharded-reduce contract
    AccExactRange { bound: i64, limit: i64 },
    /// `MulAcc` claims more valid elements than the pattern packs
    NValidExceedsCapacity { at: usize, n_valid: u16, capacity: u32 },

    /// equivalence: a term the plan's contraction requires never
    /// accumulates into its output cell
    MissingTerm { cell: usize, channel: u32, tap: usize },
    /// equivalence: a required term accumulates more than once
    DuplicateTerm { at: usize, cell: usize, channel: u32, tap: usize },
    /// equivalence: a term outside the plan's contraction contributes
    /// (wrong chunk pair, channel, tap, precision, or causal triangle)
    ForeignTerm { at: usize, cell: usize, detail: String },
    /// equivalence: a partial chunk's tail lanes reach the output
    /// without provably passing through their tail mask
    UnmaskedTailTerm { at: usize, cell: usize, chunk: usize },
    /// equivalence: a partial chunk's masked-MAC count per cell
    /// disagrees with the tail bias the engine epilogue subtracts
    EpilogueMismatch { cell: usize, chunk: usize, expected: u32, got: u32 },
    /// equivalence: shard term sets do not partition the whole node's
    ShardTermPartition { detail: String },

    /// graph structural defect at `node`
    Graph { node: usize, detail: String },
    /// shard slices do not partition the split range exactly
    ShardSlices { detail: String },
    /// two shards registered under the same key
    ShardKeyCollision { key: String },
    /// a shard's bind bytes exceed the per-worker budget
    BudgetExceeded { key: String, bytes: usize, budget: usize },
    /// KV page geometry incoherent with the chunk layout / V tier
    PageGeometry { slot: usize, detail: String },
    /// op's declared `bind_bytes` disagrees with its buffer table
    BindBytes { op: String, declared: usize, actual: usize },
}

impl Violation {
    /// Instruction index the violation fired at, when it is tied to a
    /// specific instruction (drives the disassembly-window capture).
    pub fn at(&self) -> Option<usize> {
        use Violation::*;
        match self {
            UndefinedReg { at, .. }
            | BadReg { at, .. }
            | BadBuf { at, .. }
            | OutOfBounds { at, .. }
            | Misaligned { at, .. }
            | BadPatId { at, .. }
            | PatternMismatch { at, .. }
            | ChunkMismatch { at, .. }
            | OperandKind { at, .. }
            | UnmaskedTail { at, .. }
            | LaneOverflow { at, .. }
            | NValidExceedsCapacity { at, .. }
            | DuplicateTerm { at, .. }
            | ForeignTerm { at, .. }
            | UnmaskedTailTerm { at, .. } => Some(*at),
            _ => None,
        }
    }
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        use Violation::*;
        match self {
            UndefinedReg { at, reg } => write!(f, "[{at}] v{reg} read before any write"),
            BadReg { at, reg } => write!(f, "[{at}] register v{reg} outside the 32-vreg file"),
            BadBuf { at, buf } => write!(f, "[{at}] BufId({buf}) not in the kernel's buffer table"),
            OutOfBounds { at, buf, off, extent, len } => write!(
                f,
                "[{at}] buf {buf}: {extent}-byte access at offset {off} exceeds length {len}"
            ),
            Misaligned { at, buf, off, align } => {
                write!(f, "[{at}] buf {buf}: offset {off} not {align}-byte aligned")
            }
            BadPatId { at, pat, table } => {
                write!(f, "[{at}] PatId {pat} outside pattern table of {table}")
            }
            PatternMismatch { at, pat, chunk } => write!(
                f,
                "[{at}] PatId {pat} names a different pattern than chunk {chunk}'s layout"
            ),
            ChunkMismatch { at, a, b } => {
                write!(f, "[{at}] operands from different chunks ({a} vs {b})")
            }
            OperandKind { at, what } => write!(f, "[{at}] {what}"),
            UnmaskedTail { at, chunk } => write!(
                f,
                "[{at}] partial chunk {chunk}: input operand reaches a MAC unmasked"
            ),
            LaneOverflow { at, lane, bound } => write!(
                f,
                "[{at}] lane {lane} worst-case partial {bound} exceeds i16::MAX"
            ),
            AccOverflow { buf, off, bound } => write!(
                f,
                "buf {buf} cell {off}: worst-case sum {bound} exceeds i32::MAX"
            ),
            AccExactRange { bound, limit } => write!(
                f,
                "max accumulator bound {bound} exceeds the f32 exact-integer range {limit} \
                 (bit-exact sharded reduction is no longer guaranteed)"
            ),
            NValidExceedsCapacity { at, n_valid, capacity } => write!(
                f,
                "[{at}] mul-acc n_valid {n_valid} exceeds pattern capacity {capacity}"
            ),
            MissingTerm { cell, channel, tap } => write!(
                f,
                "cell {cell}: required term (channel {channel}, tap {tap}) never accumulates"
            ),
            DuplicateTerm { at, cell, channel, tap } => write!(
                f,
                "[{at}] cell {cell}: term (channel {channel}, tap {tap}) accumulates twice"
            ),
            ForeignTerm { at, cell, detail } => {
                write!(f, "[{at}] cell {cell}: foreign term — {detail}")
            }
            UnmaskedTailTerm { at, cell, chunk } => write!(
                f,
                "[{at}] cell {cell}: partial chunk {chunk}'s tail lanes contribute unmasked"
            ),
            EpilogueMismatch { cell, chunk, expected, got } => write!(
                f,
                "cell {cell}: partial chunk {chunk} contributes {got} masked MACs, the \
                 tail-bias epilogue subtracts {expected}"
            ),
            ShardTermPartition { detail } => write!(f, "shard term partition: {detail}"),
            Graph { node, detail } => write!(f, "node {node}: {detail}"),
            ShardSlices { detail } => write!(f, "shard slices: {detail}"),
            ShardKeyCollision { key } => write!(f, "duplicate shard key {key:?}"),
            BudgetExceeded { key, bytes, budget } => write!(
                f,
                "shard {key}: bind bytes {bytes} exceed worker budget {budget}"
            ),
            PageGeometry { slot, detail } => write!(f, "kv slot {slot}: {detail}"),
            BindBytes { op, declared, actual } => write!(
                f,
                "op {op}: declared bind_bytes {declared} != buffer-table total {actual}"
            ),
        }
    }
}

/// ±3-instruction disassembly context around a faulting instruction,
/// captured while the verifier streams (no program buffering needed).
#[derive(Debug, Clone)]
pub struct DisasmWindow {
    /// faulting instruction index
    pub at: usize,
    /// `(index, instruction)` lines covering `at - 3 ..= at + 3`,
    /// clipped to the program
    pub lines: Vec<(usize, Instr)>,
}

impl fmt::Display for DisasmWindow {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (idx, i) in &self.lines {
            let marker = if *idx == self.at { '>' } else { ' ' };
            writeln!(f, "      {marker} [{idx}] {i:?}")?;
        }
        Ok(())
    }
}

/// Streaming capture of disassembly windows: a verifier feeds every
/// instruction through [`observe`] and marks faults with [`record`];
/// the tracker keeps the 3 preceding instructions rolling and holds
/// each recorded window open until its 3 trailing instructions arrive.
///
/// [`observe`]: WindowTracker::observe
/// [`record`]: WindowTracker::record
#[derive(Debug, Default)]
pub(crate) struct WindowTracker {
    /// rolling last 4 instructions (3 before + the current one)
    recent: VecDeque<(usize, Instr)>,
    /// open windows still collecting `(window, trailing remaining)`
    pending: Vec<(DisasmWindow, usize)>,
    done: Vec<DisasmWindow>,
    seen_at: std::collections::HashSet<usize>,
}

/// Windows kept per program — one per distinct faulting instruction,
/// capped so a pathological kernel cannot balloon the verdict.
const MAX_WINDOWS: usize = 8;

impl WindowTracker {
    pub(crate) fn observe(&mut self, at: usize, i: &Instr) {
        let mut j = 0;
        while j < self.pending.len() {
            let (w, remaining) = &mut self.pending[j];
            w.lines.push((at, *i));
            *remaining -= 1;
            if *remaining == 0 {
                let (w, _) = self.pending.remove(j);
                self.done.push(w);
            } else {
                j += 1;
            }
        }
        self.recent.push_back((at, *i));
        while self.recent.len() > 4 {
            self.recent.pop_front();
        }
    }

    /// Record a fault at index `at` (the instruction most recently
    /// observed). Deduplicates per index and respects the cap.
    pub(crate) fn record(&mut self, at: usize) {
        if self.done.len() + self.pending.len() >= MAX_WINDOWS || !self.seen_at.insert(at) {
            return;
        }
        let lines: Vec<(usize, Instr)> = self.recent.iter().copied().collect();
        self.pending.push((DisasmWindow { at, lines }, 3));
    }

    pub(crate) fn finish(mut self) -> Vec<DisasmWindow> {
        for (w, _) in self.pending.drain(..) {
            self.done.push(w);
        }
        self.done.sort_by_key(|w| w.at);
        self.done
    }
}

/// Verdict for one kernel program: instruction-mix counts, the proven
/// worst-case accumulator/lane bounds, and every violation found.
#[derive(Debug, Clone)]
pub struct KernelVerdict {
    pub name: String,
    pub instrs: u64,
    pub macs: u64,
    pub loads: u64,
    pub stores: u64,
    /// worst-case |i32 cell sum| over all output cells
    pub max_acc_bound: i64,
    /// worst-case |i16 lane partial| over all lanes
    pub max_lane_bound: i64,
    pub violations: Vec<Violation>,
    /// violations beyond the recording cap (count only)
    pub suppressed: usize,
    /// disassembly context around faulting instructions (empty when
    /// the program is clean)
    pub windows: Vec<DisasmWindow>,
}

impl KernelVerdict {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty() && self.suppressed == 0
    }

    /// Does the proven accumulator bound stay in the f32 exact range?
    pub fn f32_exact(&self) -> bool {
        self.max_acc_bound <= F32_EXACT_BOUND
    }

    pub fn num_violations(&self) -> usize {
        self.violations.len() + self.suppressed
    }
}

/// Verdict for one prepared model: a kernel verdict per verified
/// program plus any graph/plan-level violations.
#[derive(Debug, Clone, Default)]
pub struct ModelVerdict {
    pub name: String,
    pub kernels: Vec<KernelVerdict>,
    pub plan_violations: Vec<Violation>,
}

impl ModelVerdict {
    pub fn is_clean(&self) -> bool {
        self.plan_violations.is_empty() && self.kernels.iter().all(|k| k.is_clean())
    }

    pub fn instrs(&self) -> u64 {
        self.kernels.iter().map(|k| k.instrs).sum()
    }

    pub fn max_acc_bound(&self) -> i64 {
        self.kernels.iter().map(|k| k.max_acc_bound).max().unwrap_or(0)
    }

    pub fn num_violations(&self) -> usize {
        self.plan_violations.len() + self.kernels.iter().map(|k| k.num_violations()).sum::<usize>()
    }

    /// All violations (plan first, then per-kernel), for reporting.
    pub fn violations(&self) -> impl Iterator<Item = (&str, &Violation)> {
        self.plan_violations
            .iter()
            .map(|v| ("plan", v))
            .chain(self.kernels.iter().flat_map(|k| {
                k.violations.iter().map(move |v| (k.name.as_str(), v))
            }))
    }
}

/// The `serve-bench --verify` deliverable: per-model verdicts over
/// everything a serving configuration is about to run.
#[derive(Debug, Clone, Default)]
pub struct VerifyReport {
    pub models: Vec<ModelVerdict>,
}

impl VerifyReport {
    pub fn is_clean(&self) -> bool {
        self.models.iter().all(|m| m.is_clean())
    }

    pub fn num_violations(&self) -> usize {
        self.models.iter().map(|m| m.num_violations()).sum()
    }
}

impl fmt::Display for VerifyReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "== verify report ==")?;
        for m in &self.models {
            writeln!(
                f,
                "model {:<28} kernels {:>3}  instrs {:>9}  max-acc {:>9}  ({} ≤ 2^24: {})  violations {}",
                m.name,
                m.kernels.len(),
                m.instrs(),
                m.max_acc_bound(),
                "f32-exact",
                if m.max_acc_bound() <= F32_EXACT_BOUND { "yes" } else { "NO" },
                m.num_violations(),
            )?;
            for (where_, v) in m.violations() {
                writeln!(f, "    [{where_}] {v}")?;
            }
            for k in &m.kernels {
                for w in &k.windows {
                    writeln!(f, "    [{}] disassembly around [{}]:", k.name, w.at)?;
                    write!(f, "{w}")?;
                }
            }
            let suppressed: usize = m.kernels.iter().map(|k| k.suppressed).sum();
            if suppressed > 0 {
                writeln!(f, "    (+{suppressed} further violations suppressed)")?;
            }
        }
        let verdict = if self.is_clean() { "CLEAN" } else { "VIOLATIONS FOUND" };
        write!(f, "verdict: {verdict} ({} models, {} violations)", self.models.len(), self.num_violations())
    }
}

/// Debug-build hook called at the end of
/// `PreparedModel::prepare`/`prepare_decoder`: verify every cached
/// program and panic with the full violation list (plus disassembly
/// windows around each faulting instruction) on any defect, so a bad
/// emitter change fails the *first* debug test that prepares a model —
/// long before an output diverges.
///
/// Verified programs are remembered by fingerprint (spec + emitted
/// instruction stream), so suites that prepare the same model many
/// times — the 300-case sweeps prepare thousands — pay the two
/// verification passes once per *unique* program, not once per
/// `prepare()` call. A program only enters the cache after verifying
/// clean, so a defect is never masked by an earlier clean twin.
pub fn debug_verify(tag: &str, model: &crate::serve::PreparedModel) {
    use std::collections::HashSet;
    use std::sync::{Mutex, OnceLock};
    static SEEN: OnceLock<Mutex<HashSet<u64>>> = OnceLock::new();
    let seen = SEEN.get_or_init(|| Mutex::new(HashSet::new()));
    let mut seen = seen.lock().unwrap_or_else(|e| e.into_inner());
    let verdict = plan::verify_model_cached(tag, model, &mut seen);
    if !verdict.is_clean() {
        let mut msg = format!("static verification failed in {tag}:\n");
        for (where_, v) in verdict.violations() {
            msg.push_str(&format!("  [{where_}] {v}\n"));
        }
        for k in &verdict.kernels {
            for w in &k.windows {
                msg.push_str(&format!("  [{}] disassembly around [{}]:\n{w}", k.name, w.at));
            }
        }
        panic!("{msg}");
    }
}
