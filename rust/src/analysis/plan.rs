//! Structural verification of serving plans: prepared models, graph
//! wiring, shard deployments, and KV page geometry.
//!
//! Where [`super::kernel`] proves properties of one instruction
//! stream, this module proves the *composition* is coherent: every
//! graph edge produces the shape its consumer expects, precision
//! assignments cover their channel axes with supported levels, shard
//! slices partition the split axis exactly, shard keys cannot collide
//! in a worker's bind table, every shard's bind footprint fits the
//! worker budget, and the paged-KV geometry is chunk-aligned with the
//! V storage tier no wider than compute precision.

use std::collections::HashSet;

use super::equiv::{self, ShardAxis, TermSpec};
use super::kernel::ProgramToVerify;
use super::{verify_program_full, ModelVerdict, Violation};
use crate::codegen::{DataFormat, LayerKind};
use crate::serve::deploy::{Deployment, GatherMode, ShardPlan};
use crate::serve::engine::{PreparedModel, StepModel};
use crate::serve::kvpool::{effective_v_prec, KvPoolCfg, SlotGeomSpec};
use crate::sim::network::{Node, INPUT};
use crate::smol::pattern_match::Assignment;
use crate::simd::patterns::Pattern;

/// How deep [`verify_model_level`] analyzes each program.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VerifyLevel {
    /// abstract interpretation only (bounds, alignment, masking,
    /// overflow) — what PR 9 shipped
    Safety,
    /// safety plus the symbolic term-equivalence pass
    Full,
}

/// Verify every program a prepared model caches (full graph and, for
/// decoders, the step graph's representative per-length programs) at
/// [`VerifyLevel::Full`], plus each op's declared `bind_bytes` against
/// its buffer table.
pub fn verify_model(name: &str, model: &PreparedModel) -> ModelVerdict {
    verify_model_level(name, model, VerifyLevel::Full)
}

/// [`verify_model`] with an explicit analysis depth (the serving bench
/// times `Safety` vs `Full` separately).
pub fn verify_model_level(name: &str, model: &PreparedModel, level: VerifyLevel) -> ModelVerdict {
    verify_model_impl(name, model, level, None)
}

/// [`verify_model`] with a cross-call program-fingerprint cache:
/// programs already proven clean (same spec, term spec, and emitted
/// instruction stream) are skipped, and newly clean programs enter the
/// cache. Backs [`super::debug_verify`]'s once-per-unique-program
/// behavior across a debug test suite.
pub(crate) fn verify_model_cached(
    name: &str,
    model: &PreparedModel,
    seen: &mut HashSet<u64>,
) -> ModelVerdict {
    verify_model_impl(name, model, VerifyLevel::Full, Some(seen))
}

fn verify_model_impl(
    name: &str,
    model: &PreparedModel,
    level: VerifyLevel,
    mut seen: Option<&mut HashSet<u64>>,
) -> ModelVerdict {
    let mut verdict = ModelVerdict { name: name.to_string(), ..Default::default() };
    verify_prepared_nodes(
        &mut verdict,
        model.nodes.iter().map(|n| n.op.as_ref()),
        "",
        level,
        seen.as_deref_mut(),
    );
    if let Some(step) = &model.step {
        verify_prepared_nodes(
            &mut verdict,
            step.nodes.iter().map(|n| n.op.as_ref()),
            "step/",
            level,
            seen,
        );
        verify_step_geometry(&mut verdict, step);
    }
    verdict
}

/// Program identity for the verification cache: the spec's machine
/// environment (buffer extents, pattern table, chunk layout, format),
/// the plan-derived term spec, and the emitted instruction stream.
/// The spec *name* is deliberately excluded — two layers emitting the
/// same program under the same environment are the same proof.
fn fingerprint(p: &ProgramToVerify) -> u64 {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    p.spec.buf_len.hash(&mut h);
    p.spec.patterns.hash(&mut h);
    p.spec.chunks.hash(&mut h);
    p.spec.fmt.hash(&mut h);
    p.terms.hash(&mut h);
    p.program.as_ref().hash(&mut h);
    h.finish()
}

fn verify_prepared_nodes<'a>(
    verdict: &mut ModelVerdict,
    ops: impl Iterator<Item = &'a dyn crate::serve::PreparedOp>,
    prefix: &str,
    level: VerifyLevel,
    mut seen: Option<&mut HashSet<u64>>,
) {
    for op in ops {
        let programs = op.verify_programs();
        // ops with machine state must declare bind bytes equal to
        // their program specs' buffer tables (one shared table per op)
        if let Some(spec) = programs.first().map(|p| &p.spec) {
            let actual: usize = spec.buf_len.iter().sum();
            let declared = op.bind_bytes();
            if declared != actual {
                verdict.plan_violations.push(Violation::BindBytes {
                    op: format!("{prefix}{}", spec.name),
                    declared,
                    actual,
                });
            }
        }
        for p in programs {
            let fp = seen.as_ref().map(|_| fingerprint(&p));
            if let (Some(seen), Some(fp)) = (seen.as_deref_mut(), fp) {
                if seen.contains(&fp) {
                    continue;
                }
            }
            let terms = match level {
                VerifyLevel::Full => p.terms.as_ref(),
                VerifyLevel::Safety => None,
            };
            let mut k = verify_program_full(&p.spec, terms, &p.program);
            if !prefix.is_empty() {
                k.name = format!("{prefix}{}", k.name);
            }
            // cache clean proofs only: a defect must resurface on
            // every prepare until the emitter is fixed
            if k.is_clean() {
                if let (Some(seen), Some(fp)) = (seen.as_deref_mut(), fp) {
                    seen.insert(fp);
                }
            }
            verdict.kernels.push(k);
        }
    }
}

/// Step-model bookkeeping coherence: slot count matches the recorded
/// geometries and every geometry is well-formed.
fn verify_step_geometry(verdict: &mut ModelVerdict, step: &StepModel) {
    if step.slots != step.slot_geoms.len() {
        verdict.plan_violations.push(Violation::Graph {
            node: 0,
            detail: format!(
                "step model records {} slots but {} slot geometries",
                step.slots,
                step.slot_geoms.len()
            ),
        });
    }
    for (slot, sg) in step.slot_geoms.iter().enumerate() {
        if !matches!(sg.pos_prec, 1 | 2 | 4) {
            verdict.plan_violations.push(Violation::PageGeometry {
                slot,
                detail: format!("position precision {} is not a SMOL level", sg.pos_prec),
            });
        }
        if sg.heads == 0 || sg.dh == 0 || sg.nch_dh == 0 {
            verdict.plan_violations.push(Violation::PageGeometry {
                slot,
                detail: format!(
                    "degenerate geometry (heads {}, dh {}, nch_dh {})",
                    sg.heads, sg.dh, sg.nch_dh
                ),
            });
        }
    }
}

/// Shape of a tensor flowing along a graph edge, `(h, w, c)`.
type Shape = (usize, usize, usize);

fn check_assignment(asg: &Assignment, axis: usize, what: &str) -> Result<(), String> {
    if asg.num_channels() != axis {
        return Err(format!(
            "{what}: assignment covers {} channels, axis has {axis}",
            asg.num_channels()
        ));
    }
    if let Some(&p) = asg.precision.iter().find(|p| !matches!(p, 1 | 2 | 4)) {
        return Err(format!("{what}: precision {p} is not a SMOL level"));
    }
    let valid_sum: u32 = asg.chunks.iter().zip(&asg.valid).map(|(_, &v)| v).sum();
    if valid_sum as usize != axis {
        return Err(format!(
            "{what}: chunk valid counts sum to {valid_sum}, axis has {axis}"
        ));
    }
    for (ci, (pat, &valid)) in asg.chunks.iter().zip(&asg.valid).enumerate() {
        if !pat.is_valid() {
            return Err(format!("{what}: chunk {ci} pattern is not a legal 128-bit packing"));
        }
        if valid > pat.capacity() {
            return Err(format!(
                "{what}: chunk {ci} claims {valid} valid elements, pattern capacity {}",
                pat.capacity()
            ));
        }
    }
    Ok(())
}

/// Output shape of one node given its resolved input shapes — the
/// static mirror of each `PreparedOp::run`'s shape asserts, returning
/// a description instead of panicking mid-serve.
fn node_shape(node: &Node, ins: &[Shape]) -> Result<Shape, String> {
    match node {
        Node::Conv { cfg, .. } => {
            let p = &cfg.plan;
            let (h, w, c) = ins[0];
            if c != p.cin {
                return Err(format!("{}: input has {c} channels, plan.cin {}", p.name, p.cin));
            }
            if (h, w) != (p.hin, p.win) {
                return Err(format!(
                    "{}: input is {h}x{w}, plan expects {}x{}",
                    p.name, p.hin, p.win
                ));
            }
            if p.fmt == DataFormat::Smol {
                check_assignment(&p.asg, p.cin, &p.name)?;
            }
            let cout = match p.kind {
                LayerKind::Dense => p.cout,
                LayerKind::Depthwise => {
                    if p.cout != p.cin {
                        return Err(format!(
                            "{}: depthwise cout {} != cin {}",
                            p.name, p.cout, p.cin
                        ));
                    }
                    p.cin
                }
            };
            Ok((p.hout(), p.wout(), cout))
        }
        Node::Matmul { cfg, weights, .. } => {
            let p = &cfg.plan;
            let (h, w, c) = ins[0];
            if (w, c) != (p.m, p.k) {
                return Err(format!(
                    "{}: input is ({w} rows, {c} contraction), plan is ({}, {})",
                    p.name, p.m, p.k
                ));
            }
            if weights.len() != p.k * p.n {
                return Err(format!(
                    "{}: {} weights for a {}x{} GEMM",
                    p.name,
                    weights.len(),
                    p.k,
                    p.n
                ));
            }
            if p.fmt == DataFormat::Smol {
                check_assignment(&p.asg, p.k, &p.name)?;
            }
            if cfg.causal && p.m != p.n {
                return Err(format!("{}: causal GEMM needs m == n ({} vs {})", p.name, p.m, p.n));
            }
            Ok((h, p.m, p.n))
        }
        Node::MatmulDyn { cfg, transpose_b, .. } => {
            let p = &cfg.plan;
            let (ha, wa, ca) = ins[0];
            let (hb, wb, cb) = ins[1];
            if (wa, ca) != (p.m, p.k) {
                return Err(format!(
                    "{}: A is ({wa} rows, {ca} contraction), plan is ({}, {})",
                    p.name, p.m, p.k
                ));
            }
            if hb != ha {
                return Err(format!("{}: head batches differ ({ha} vs {hb})", p.name));
            }
            let want = if *transpose_b { (p.n, p.k) } else { (p.k, p.n) };
            if (wb, cb) != want {
                return Err(format!(
                    "{}: B is ({wb}, {cb}), plan expects {want:?} (transpose_b = {transpose_b})",
                    p.name
                ));
            }
            if p.fmt == DataFormat::Smol {
                check_assignment(&p.asg, p.k, &p.name)?;
            }
            if cfg.causal && p.m != p.n {
                return Err(format!("{}: causal GEMM needs m == n ({} vs {})", p.name, p.m, p.n));
            }
            Ok((ha, p.m, p.n))
        }
        Node::CachedAttn { cfg, .. } => {
            for (i, &(h, w, c)) in ins.iter().enumerate() {
                if (h, w, c) != (cfg.heads, 1, cfg.dh) {
                    return Err(format!(
                        "{}: step operand {i} is ({h}, {w}, {c}), needs ({}, 1, {})",
                        cfg.name, cfg.heads, cfg.dh
                    ));
                }
            }
            if cfg.fmt != DataFormat::Smol {
                return Err(format!("{}: cached decode needs SMOL operands", cfg.name));
            }
            if !matches!(cfg.pos_prec, 1 | 2 | 4) {
                return Err(format!(
                    "{}: position precision {} is not a SMOL level",
                    cfg.name, cfg.pos_prec
                ));
            }
            if cfg.max_positions == 0 {
                return Err(format!("{}: max_positions must be positive", cfg.name));
            }
            check_assignment(&cfg.dh_asg, cfg.dh, &cfg.name)?;
            Ok((cfg.heads, 1, cfg.dh))
        }
        Node::Softmax { .. } | Node::Gelu { .. } => Ok(ins[0]),
        Node::LayerNorm { gamma, beta, .. } => {
            let (h, w, c) = ins[0];
            if gamma.len() != c || beta.len() != c {
                return Err(format!(
                    "layernorm affine has {}/{} params for {c} channels",
                    gamma.len(),
                    beta.len()
                ));
            }
            Ok((h, w, c))
        }
        Node::TransposeHW { .. } => {
            let (h, w, c) = ins[0];
            Ok((w, h, c))
        }
        Node::SplitHeads { heads, .. } => {
            let (h, w, c) = ins[0];
            if h != 1 {
                return Err(format!("split-heads input must be unsplit (h = 1), got h = {h}"));
            }
            if *heads == 0 || c % heads != 0 {
                return Err(format!("{c} channels do not split into {heads} heads"));
            }
            Ok((*heads, w, c / heads))
        }
        Node::MergeHeads { .. } => {
            let (h, w, c) = ins[0];
            Ok((1, w, h * c))
        }
        Node::Add { .. } => {
            if ins[0] != ins[1] {
                return Err(format!("residual add over {:?} and {:?}", ins[0], ins[1]));
            }
            Ok(ins[0])
        }
        Node::ConcatC { .. } => {
            let ((ha, wa, ca), (hb, wb, cb)) = (ins[0], ins[1]);
            if (ha, wa) != (hb, wb) {
                return Err(format!(
                    "concat spatial mismatch ({ha}x{wa} vs {hb}x{wb})"
                ));
            }
            Ok((ha, wa, ca + cb))
        }
        Node::SliceC { from, to, .. } => {
            let (h, w, c) = ins[0];
            if !(*from < *to && *to <= c) {
                return Err(format!("slice [{from}, {to}) of {c} channels"));
            }
            Ok((h, w, to - from))
        }
        Node::ShuffleC { groups, .. } => {
            let (h, w, c) = ins[0];
            if *groups == 0 || c % groups != 0 {
                return Err(format!("{c} channels do not shuffle in {groups} groups"));
            }
            Ok((h, w, c))
        }
        Node::Gap { .. } => Ok((1, 1, ins[0].2)),
    }
}

/// Shape-propagate a graph from `input_shape`, collecting every edge
/// or plan defect. A defective node's consumers are not re-reported
/// (its output shape is treated as whatever they expect is unknown —
/// propagation stops along that path).
pub fn verify_graph(nodes: &[Node], input_shape: (usize, usize, usize)) -> Vec<Violation> {
    let mut violations = Vec::new();
    let mut shapes: Vec<Option<Shape>> = Vec::with_capacity(nodes.len());
    for (idx, node) in nodes.iter().enumerate() {
        let mut ins = Vec::new();
        let mut wired = true;
        for &src in &node.inputs() {
            if src == INPUT {
                ins.push(input_shape);
            } else if src >= idx {
                violations.push(Violation::Graph {
                    node: idx,
                    detail: format!(
                        "edge from node {src} is not a forward reference (graphs execute in order)"
                    ),
                });
                wired = false;
            } else if let Some(s) = shapes[src] {
                ins.push(s);
            } else {
                wired = false; // upstream defect already reported
            }
        }
        if !wired {
            shapes.push(None);
            continue;
        }
        match node_shape(node, &ins) {
            Ok(s) => shapes.push(Some(s)),
            Err(detail) => {
                violations.push(Violation::Graph { node: idx, detail });
                shapes.push(None);
            }
        }
    }
    violations
}

/// Plan-derived term spec of a graph node, independent of anything
/// the shards prepared — the "whole" side of the partition check.
fn node_term_spec(node: &Node) -> Option<TermSpec> {
    match node {
        Node::Conv { cfg, .. } => TermSpec::for_layer(&cfg.plan),
        Node::Matmul { cfg, .. } => TermSpec::for_gemm(&cfg.plan, cfg.causal),
        _ => None,
    }
}

/// Term-partition check for one sliced node: every shard's *prepared*
/// term spec (what its kernel was actually proven equivalent to),
/// remapped through its slice offset on `axis`, must tile the whole
/// graph node's term set — disjoint and exhaustive. Skips silently
/// when term specs are unavailable (baseline formats) — the per-shard
/// kernel verdicts still run.
fn check_term_partition(
    dep: &Deployment,
    nodes: &[Node],
    slices: &[(usize, usize)],
    idx: usize,
    axis: ShardAxis,
    what: &str,
) -> Vec<Violation> {
    let Some(whole) = nodes.get(idx).and_then(node_term_spec) else {
        return Vec::new();
    };
    let mut shard_specs = Vec::with_capacity(slices.len());
    for (h, &(start, _)) in dep.handles().iter().zip(slices.iter()) {
        let spec = h
            .prepared
            .nodes
            .get(idx)
            .and_then(|n| n.op.verify_programs().into_iter().next())
            .and_then(|p| p.terms);
        match spec {
            Some(s) => shard_specs.push((s, start)),
            None => return Vec::new(),
        }
    }
    equiv::shard_term_partition(what, &whole, &shard_specs, axis)
}

/// `cout`/`n` width of the node a shard plan may split.
fn split_width(node: &Node) -> Option<usize> {
    match node {
        Node::Conv { cfg, .. } if cfg.plan.kind == LayerKind::Dense => Some(cfg.plan.cout),
        Node::Matmul { cfg, .. } => Some(cfg.plan.n),
        _ => None,
    }
}

/// Contraction width of a reduce consumer.
fn contraction_width(node: &Node) -> Option<usize> {
    match node {
        Node::Conv { cfg, .. } if cfg.plan.kind == LayerKind::Dense => Some(cfg.plan.cin),
        Node::Matmul { cfg, .. } => Some(cfg.plan.k),
        _ => None,
    }
}

/// Verify a deployment against the graph it was built from: shard
/// slices partition the split axis exactly, keys are collision-free,
/// every shard's exact bind footprint fits `budget`, and each shard's
/// prepared programs verify — returns the structural verdict (named
/// `deploy/<key>`) followed by one kernel verdict per shard.
pub fn verify_deployment(
    dep: &Deployment,
    nodes: &[Node],
    budget: Option<usize>,
) -> Vec<ModelVerdict> {
    let mut structural =
        ModelVerdict { name: format!("deploy/{}", dep.key()), ..Default::default() };
    let v = &mut structural.plan_violations;

    match dep.plan() {
        ShardPlan::Whole => {
            if dep.handles().len() != 1 {
                v.push(Violation::ShardSlices {
                    detail: format!("whole plan with {} handles", dep.handles().len()),
                });
            }
        }
        ShardPlan::Sharded { split_node, consumer_node, slices, gather } => {
            let width = match nodes.get(*split_node).and_then(split_width) {
                Some(w) => w,
                None => {
                    v.push(Violation::ShardSlices {
                        detail: format!("split node {split_node} is not a sliceable dense kernel"),
                    });
                    0
                }
            };
            if dep.handles().len() != slices.len() {
                v.push(Violation::ShardSlices {
                    detail: format!(
                        "{} slices but {} shard handles",
                        slices.len(),
                        dep.handles().len()
                    ),
                });
            }
            // exact partition: contiguous, gap-free, covering [0, width)
            let mut pos = 0usize;
            for (i, &(s, e)) in slices.iter().enumerate() {
                if s != pos {
                    v.push(Violation::ShardSlices {
                        detail: format!(
                            "slice {i} starts at {s}, previous ended at {pos} (gap or overlap)"
                        ),
                    });
                }
                if e <= s {
                    v.push(Violation::ShardSlices {
                        detail: format!("slice {i} is empty or inverted ({s}..{e})"),
                    });
                }
                pos = e;
            }
            if width > 0 && pos != width {
                v.push(Violation::ShardSlices {
                    detail: format!("slices cover [0, {pos}), split axis is [0, {width})"),
                });
            }
            match gather {
                GatherMode::Reduce => match consumer_node.and_then(|c| nodes.get(c)) {
                    Some(c) => {
                        if width > 0 && contraction_width(c) != Some(width) {
                            v.push(Violation::ShardSlices {
                                detail: format!(
                                    "reduce consumer contracts {:?} channels, split axis has {width}",
                                    contraction_width(c)
                                ),
                            });
                        }
                    }
                    None => v.push(Violation::ShardSlices {
                        detail: "reduce gather without a valid consumer node".into(),
                    }),
                },
                GatherMode::Concat => {
                    if consumer_node.is_some() {
                        v.push(Violation::ShardSlices {
                            detail: "concat gather must not name a consumer node".into(),
                        });
                    }
                }
            }
            // term partition: shards compute disjoint, exhaustive term
            // subsets — on the split node's output-channel axis, and
            // for reduce gathers also on the consumer's contraction
            // axis (each shard's prepared term spec is what its kernel
            // is separately proven equivalent to, so the set algebra
            // here transfers to the emitted programs)
            if dep.handles().len() == slices.len() {
                v.extend(check_term_partition(
                    dep,
                    nodes,
                    slices,
                    *split_node,
                    ShardAxis::OutputChannels,
                    &format!("split node {split_node}"),
                ));
                if matches!(gather, GatherMode::Reduce) {
                    if let Some(c) = consumer_node {
                        v.extend(check_term_partition(
                            dep,
                            nodes,
                            slices,
                            *c,
                            ShardAxis::Contraction,
                            &format!("reduce consumer {c}"),
                        ));
                    }
                }
            }
        }
    }

    // shard keys must be distinct (per-worker bind tables key by them)
    let mut seen = HashSet::new();
    for h in dep.handles() {
        if !seen.insert(h.key.to_string()) {
            structural
                .plan_violations
                .push(Violation::ShardKeyCollision { key: h.key.to_string() });
        }
        if let Some(budget) = budget {
            let bytes = h.prepared.bind_bytes();
            if bytes > budget {
                structural.plan_violations.push(Violation::BudgetExceeded {
                    key: h.key.to_string(),
                    bytes,
                    budget,
                });
            }
        }
    }

    let mut out = vec![structural];
    for h in dep.handles() {
        out.push(verify_model(&h.key.to_string(), &h.prepared));
    }
    out
}

/// Verify a paged-KV configuration against a model's slot geometries:
/// page positions are chunk-aligned at each slot's effective V tier,
/// never smaller than the configured request, and the V storage
/// precision is a SMOL level no wider than compute.
pub fn verify_kv(cfg: &KvPoolCfg, slot_geoms: &[SlotGeomSpec]) -> Vec<Violation> {
    let mut violations = Vec::new();
    if cfg.page_positions == 0 {
        violations.push(Violation::PageGeometry {
            slot: usize::MAX,
            detail: "page_positions must be positive".into(),
        });
    }
    if let Some(b) = cfg.v_bits {
        if !matches!(b, 1 | 2 | 4) {
            violations.push(Violation::PageGeometry {
                slot: usize::MAX,
                detail: format!("--v-bits {b} is not a SMOL level"),
            });
        }
    }
    for (slot, sg) in slot_geoms.iter().enumerate() {
        let geom = sg.page_geom(&cfg.session_cfg());
        // independently re-derive the tier: configured bits clamped to
        // compute precision — the v_bits <= pos_prec contract
        let want_v = effective_v_prec(sg.pos_prec, cfg.v_bits);
        if geom.v_prec != want_v || geom.v_prec > sg.pos_prec {
            violations.push(Violation::PageGeometry {
                slot,
                detail: format!(
                    "V tier {} (compute {}, configured {:?})",
                    geom.v_prec, sg.pos_prec, cfg.v_bits
                ),
            });
            continue;
        }
        let cap_v = Pattern::uniform(geom.v_prec).capacity() as usize;
        if geom.page_positions % cap_v != 0 {
            violations.push(Violation::PageGeometry {
                slot,
                detail: format!(
                    "page of {} positions is not a multiple of the {cap_v}-position V chunk",
                    geom.page_positions
                ),
            });
        }
        if geom.page_positions < cfg.page_positions {
            violations.push(Violation::PageGeometry {
                slot,
                detail: format!(
                    "page of {} positions below the configured {}",
                    geom.page_positions, cfg.page_positions
                ),
            });
        }
        if geom.k_bytes() == 0 || geom.page_bytes() == 0 {
            violations.push(Violation::PageGeometry {
                slot,
                detail: "degenerate page (zero bytes)".into(),
            });
        }
    }
    violations
}
