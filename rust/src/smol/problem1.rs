//! Problem 1 (Sec. IV-A): pick the pattern combination for one layer.
//!
//! Given the per-channel trained precision counts `(N1, N2, N4)` and the
//! hardware-supported pattern set, find the multiset of patterns that
//! (a) minimizes the number of 128-bit vectors needed to store all
//! channels, subject to the cumulative coverage constraints
//!
//! ```text
//! sum n4_i            >= N4
//! sum (n4_i + n2_i)   >= N4 + N2
//! sum capacity_i      >= N4 + N2 + N1
//! ```
//!
//! and (b) among those, maximizes the average precision per element —
//! equivalently (every pattern spends exactly 128 bits) minimizes the
//! total element capacity. Lower-precision data may be *promoted* into
//! higher-precision slots, never the reverse.
//!
//! Solved exactly by breadth-first dynamic programming over capped
//! coverage states, one vector per round.

use crate::simd::patterns::Pattern;
use std::collections::HashMap;

/// Per-layer trained precision demand (channel counts by precision).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Demand {
    pub n1: u32,
    pub n2: u32,
    pub n4: u32,
}

impl Demand {
    pub fn total(&self) -> u32 {
        self.n1 + self.n2 + self.n4
    }

    pub fn from_precisions(prec: &[u8]) -> Self {
        let mut d = Demand { n1: 0, n2: 0, n4: 0 };
        for &p in prec {
            match p {
                1 => d.n1 += 1,
                2 => d.n2 += 1,
                4 => d.n4 += 1,
                _ => panic!("unsupported precision {p}"),
            }
        }
        d
    }
}

/// The solved combination: the chunk patterns, in the canonical layout
/// order (descending n4, then descending n2) the channel rearrangement
/// uses.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Combination {
    pub chunks: Vec<Pattern>,
}

impl Combination {
    pub fn capacity(&self) -> u32 {
        self.chunks.iter().map(|p| p.capacity()).sum()
    }

    pub fn slots(&self, p: u8) -> u32 {
        self.chunks.iter().map(|c| c.count(p)).sum()
    }

    pub fn num_vectors(&self) -> usize {
        self.chunks.len()
    }

    pub fn avg_precision(&self) -> f64 {
        128.0 * self.chunks.len() as f64 / self.capacity() as f64
    }
}

/// Solve Problem 1 for one layer. Returns `None` only if `supported` is
/// empty (any non-empty set containing at least one pattern can cover any
/// demand by adding vectors — 4-bit slots satisfy every constraint).
pub fn solve(demand: &Demand, supported: &[Pattern]) -> Option<Combination> {
    if supported.is_empty() || demand.total() == 0 {
        return if demand.total() == 0 {
            Some(Combination { chunks: vec![] })
        } else {
            None
        };
    }
    let need4 = demand.n4;
    let need24 = demand.n4 + demand.n2;
    let need_all = demand.total();

    // State: coverage (c4, c24, call) capped at needs; value: (min total
    // capacity, parent state, pattern used).
    type State = (u32, u32, u32);
    let cap = |c4: u32, c24: u32, call: u32| -> State {
        (c4.min(need4), c24.min(need24), call.min(need_all))
    };
    let goal = (need4, need24, need_all);

    let mut frontier: HashMap<State, (u32, Option<(State, usize)>)> = HashMap::new();
    frontier.insert((0, 0, 0), (0, None));
    let mut history: Vec<HashMap<State, (u32, Option<(State, usize)>)>> = vec![frontier.clone()];

    for _round in 0..4096usize {
        if let Some(_) = history.last().unwrap().get(&goal) {
            break;
        }
        let prev = history.last().unwrap().clone();
        let mut next: HashMap<State, (u32, Option<(State, usize)>)> = HashMap::new();
        for (st, (capac, _)) in prev.iter() {
            for (pi, pat) in supported.iter().enumerate() {
                let ns = cap(
                    st.0 + pat.n4 as u32,
                    st.1 + pat.n4 as u32 + pat.n2 as u32,
                    st.2 + pat.capacity(),
                );
                let ncap = capac + pat.capacity();
                let e = next.entry(ns).or_insert((u32::MAX, None));
                if ncap < e.0 {
                    *e = (ncap, Some((*st, pi)));
                }
            }
        }
        history.push(next);
    }

    // Walk back from the goal state in the first round that reached it.
    let round = history.iter().position(|f| f.contains_key(&goal))?;
    let mut chunks = Vec::new();
    let mut st = goal;
    for r in (1..=round).rev() {
        let (_, parent) = history[r][&st];
        let (pst, pi) = parent.expect("non-root state must have a parent");
        chunks.push(supported[pi]);
        st = pst;
    }
    // Canonical layout order: 4-bit-heavy chunks first.
    chunks.sort_by(|a, b| (b.n4, b.n2).cmp(&(a.n4, a.n2)));
    Some(Combination { chunks })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::patterns::{all_patterns, design_subset};

    #[test]
    fn uniform_demand_uses_uniform_patterns() {
        let d = Demand { n1: 0, n2: 0, n4: 64 };
        let c = solve(&d, &all_patterns()).unwrap();
        assert_eq!(c.num_vectors(), 2);
        assert!(c.chunks.iter().all(|p| *p == Pattern::uniform(4)));
    }

    #[test]
    fn coverage_constraints_hold() {
        let demands = [
            Demand { n1: 10, n2: 20, n4: 30 },
            Demand { n1: 100, n2: 0, n4: 4 },
            Demand { n1: 0, n2: 96, n4: 0 },
            Demand { n1: 3, n2: 1, n4: 1 },
            Demand { n1: 200, n2: 100, n4: 50 },
        ];
        for np in [4usize, 8, 45] {
            let pats = design_subset(np);
            for d in &demands {
                let c = solve(d, &pats).unwrap();
                assert!(c.slots(4) >= d.n4, "np={np} {d:?}");
                assert!(c.slots(4) + c.slots(2) >= d.n4 + d.n2, "np={np} {d:?}");
                assert!(c.capacity() >= d.total(), "np={np} {d:?}");
            }
        }
    }

    #[test]
    fn min_vectors_beats_naive() {
        // 128 1-bit channels fit one vector with P45
        let d = Demand { n1: 128, n2: 0, n4: 0 };
        let c = solve(&d, &all_patterns()).unwrap();
        assert_eq!(c.num_vectors(), 1);
        // with only uniform-4 supported, need 4 vectors
        let c4 = solve(&d, &[Pattern::uniform(4)]).unwrap();
        assert_eq!(c4.num_vectors(), 4);
    }

    #[test]
    fn max_avg_precision_tiebreak() {
        // 32 channels, all 1-bit: one vector suffices; best single vector
        // by avg precision is uniform-4 (capacity exactly 32).
        let d = Demand { n1: 32, n2: 0, n4: 0 };
        let c = solve(&d, &all_patterns()).unwrap();
        assert_eq!(c.num_vectors(), 1);
        assert_eq!(c.chunks[0], Pattern::uniform(4));
    }

    #[test]
    fn empty_demand() {
        let d = Demand { n1: 0, n2: 0, n4: 0 };
        assert_eq!(solve(&d, &all_patterns()).unwrap().num_vectors(), 0);
    }
}
