//! SMOL quantization math, the Problem-1 pattern-combination solver,
//! Algorithm 3's pattern matching / channel rearrangement, network-size
//! statistics and metadata (Huffman) analysis.

pub mod huffman;
pub mod pattern_match;
pub mod problem1;
pub mod quant;
pub mod stats;

pub use pattern_match::{pattern_match, Assignment};
pub use problem1::{solve as solve_problem1, Combination, Demand};
