//! Network-size statistics: bits-per-parameter (Fig. 7/8/9), precision
//! distributions (Observations 1-5), and metadata overhead accounting.

use crate::smol::pattern_match::Assignment;

/// Shape of one layer's weights for bpp accounting.
#[derive(Debug, Clone)]
pub struct LayerShape {
    pub name: String,
    /// input channels (the precision axis)
    pub cin: usize,
    /// weights per input channel (cout * kh * kw / groups adjustments
    /// folded in by the caller)
    pub elems_per_channel: usize,
}

impl LayerShape {
    /// A linear/GEMM layer `[k, n]`: `k` contraction channels (the
    /// precision axis), `n` weights per channel. Covers the Transformer
    /// path's static projections and FFN matrices.
    pub fn linear(name: &str, k: usize, n: usize) -> LayerShape {
        LayerShape { name: name.into(), cin: k, elems_per_channel: n }
    }
}

/// Bits-per-parameter of one layer under an assignment.
pub fn layer_bpp(shape: &LayerShape, asg: &Assignment) -> f64 {
    assert_eq!(shape.cin, asg.precision.len(), "{}", shape.name);
    let bits: u64 = asg
        .precision
        .iter()
        .map(|&p| p as u64 * shape.elems_per_channel as u64)
        .sum();
    bits as f64 / (shape.cin * shape.elems_per_channel) as f64
}

/// Network bpp: weighted average over layers + per-layer pattern metadata
/// (three integers per layer — Observation 4's "only three integers are
/// required", charged at 32 bits each).
pub fn network_bpp(layers: &[(LayerShape, Assignment)]) -> f64 {
    let mut bits: u64 = 0;
    let mut params: u64 = 0;
    for (shape, asg) in layers {
        let b: u64 = asg
            .precision
            .iter()
            .map(|&p| p as u64 * shape.elems_per_channel as u64)
            .sum();
        bits += b + 3 * 32; // metadata: #4b, #2b, #1b channel counts
        params += (shape.cin * shape.elems_per_channel) as u64;
    }
    bits as f64 / params as f64
}

/// Precision histogram over channels, weighted by elements.
pub fn precision_histogram(layers: &[(LayerShape, Assignment)]) -> [f64; 5] {
    let mut counts = [0u64; 5];
    let mut total = 0u64;
    for (shape, asg) in layers {
        for &p in &asg.precision {
            counts[p as usize] += shape.elems_per_channel as u64;
            total += shape.elems_per_channel as u64;
        }
    }
    let mut out = [0.0; 5];
    for (o, c) in out.iter_mut().zip(counts) {
        *o = c as f64 / total.max(1) as f64;
    }
    out
}

/// Observation 1/2 analysis on arbitrary per-element precisions (original
/// SMOL): fraction of elements at <= 4 bits.
pub fn fraction_le_4bits(precisions: &[u8]) -> f64 {
    let le4 = precisions.iter().filter(|&&p| p <= 4).count();
    le4 as f64 / precisions.len().max(1) as f64
}

/// Observation 5: fraction of same-precision runs (along the rearranged
/// channel dimension) whose total bit-length is >= 16 — the justification
/// for 16-bit lane granularity.
pub fn same_precision_run_coverage(asg: &Assignment) -> f64 {
    if asg.order.is_empty() {
        return 1.0;
    }
    let prec_in_order: Vec<u8> = asg.order.iter().map(|&c| asg.precision[c as usize]).collect();
    let mut runs: Vec<(u8, u32)> = Vec::new();
    for &p in &prec_in_order {
        match runs.last_mut() {
            Some((q, n)) if *q == p => *n += 1,
            _ => runs.push((p, 1)),
        }
    }
    let ge16 = runs
        .iter()
        .filter(|(p, n)| (*p as u32) * n >= 16)
        .map(|(p, n)| (*p as u64) * (*n as u64))
        .sum::<u64>();
    let total: u64 = runs.iter().map(|(p, n)| (*p as u64) * (*n as u64)).sum();
    ge16 as f64 / total.max(1) as f64
}

/// Per-layer average trained bits (Fig. 9 series).
pub fn per_layer_bpp(layers: &[(LayerShape, Assignment)]) -> Vec<(String, f64)> {
    layers
        .iter()
        .map(|(s, a)| (s.name.clone(), a.bits_per_element()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn asg(prec: Vec<u8>) -> Assignment {
        let order = (0..prec.len() as u32).collect();
        Assignment { chunks: vec![], valid: vec![], precision: prec, order }
    }

    #[test]
    fn bpp_uniform() {
        let shape = LayerShape { name: "l".into(), cin: 8, elems_per_channel: 9 };
        assert_eq!(layer_bpp(&shape, &asg(vec![4; 8])), 4.0);
        assert_eq!(layer_bpp(&shape, &asg(vec![1; 8])), 1.0);
    }

    #[test]
    fn bpp_linear_layer() {
        // a [k=8, n=4] GEMM: 32 weights, precision per k-channel
        let shape = LayerShape::linear("wq", 8, 4);
        assert_eq!(shape.cin, 8);
        assert_eq!(shape.elems_per_channel, 4);
        assert_eq!(layer_bpp(&shape, &asg(vec![4; 8])), 4.0);
        // half the contraction channels at 4b, half at 2b -> 3 bpp
        assert_eq!(layer_bpp(&shape, &asg(vec![4, 4, 4, 4, 2, 2, 2, 2])), 3.0);
    }

    #[test]
    fn bpp_mixed() {
        let shape = LayerShape { name: "l".into(), cin: 4, elems_per_channel: 1 };
        // 4,4,2,2 -> 3.0
        assert_eq!(layer_bpp(&shape, &asg(vec![4, 4, 2, 2])), 3.0);
    }

    #[test]
    fn network_bpp_includes_metadata() {
        let shape = LayerShape { name: "l".into(), cin: 4, elems_per_channel: 1 };
        let layers = vec![(shape, asg(vec![4, 4, 4, 4]))];
        // 16 bits data + 96 bits metadata over 4 params = 28 bpp
        assert_eq!(network_bpp(&layers), 28.0);
    }

    #[test]
    fn run_coverage() {
        // 16 channels of 1-bit in a row = run of 16 bits -> covered
        let a = asg(vec![1; 16]);
        assert_eq!(same_precision_run_coverage(&a), 1.0);
        // alternating 4,2 in 2-channel runs: 4*1=4 bits < 16 -> 0 coverage
        let a2 = asg(vec![4, 2, 4, 2]);
        assert_eq!(same_precision_run_coverage(&a2), 0.0);
    }
}
