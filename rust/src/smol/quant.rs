//! SMOL quantization numerics — the rust mirror of `python/compile/smol.py`.
//!
//! An n-bit SMOL value is an odd multiple of `step = 2^(1-n)` in
//! `[-(2-step), +(2-step)]`; the unsigned n-bit code `u` maps to the value
//! `(2u - (2^n - 1)) * step` (paper Sec. II-B: 4-bit `1101` -> 1.375).
//! There is no zero value. All values and pairwise products are exact
//! dyadic rationals with >= 2^-6 granularity, hence exact in the 16.6
//! fixed-point lanes (and in f32).

/// Fraction bits of the fixed-point accumulator (16.6 lanes widened to
/// 32-bit by `vpaddlq_s16`/`vaddvq_s32`).
pub const ACC_FRAC_BITS: u32 = 6;
/// `2^ACC_FRAC_BITS`.
pub const ACC_SCALE: f32 = (1u32 << ACC_FRAC_BITS) as f32;

/// Precisions the system-aware SMOL variant allows (Observation 2).
pub const SUPPORTED_PRECISIONS: [u8; 3] = [1, 2, 4];

/// Quantization step `2^(1-p)` for a p-bit value.
#[inline]
pub fn step_for(p: u8) -> f32 {
    (2.0f32).powi(1 - p as i32)
}

/// Largest representable magnitude `2 - 2^(1-p)`.
#[inline]
pub fn qmax_for(p: u8) -> f32 {
    2.0 - step_for(p)
}

/// Unsigned n-bit code -> SMOL value `(2u - (2^p - 1)) * 2^(1-p)`.
#[inline]
pub fn code_to_value(u: u32, p: u8) -> f32 {
    let m = 2.0 * u as f32 - ((1u32 << p) - 1) as f32;
    m * step_for(p)
}

/// SMOL value -> unsigned n-bit code (inverse of [`code_to_value`]).
#[inline]
pub fn value_to_code(v: f32, p: u8) -> u32 {
    let m = v / step_for(p); // odd integer in [-(2^p-1), 2^p-1]
    let u = (m + ((1u32 << p) - 1) as f32) * 0.5;
    u.round() as u32
}

/// Signed odd mantissa `m = v / step` of a quantized value.
#[inline]
pub fn value_to_mantissa(v: f32, p: u8) -> i32 {
    (v / step_for(p)).round() as i32
}

/// Quantize `x` to the nearest odd multiple of `step_for(p)`, clamped.
///
/// Ties round half-to-even on the odd-integer grid, matching
/// `jnp.round((u-1)/2)` in the Python oracle (banker's rounding).
#[inline]
pub fn quantize(x: f32, p: u8) -> f32 {
    let step = step_for(p);
    let u = x / step;
    // nearest odd integer: 2 * round_half_even((u - 1) / 2) + 1
    let o = 2.0 * round_half_even((u - 1.0) * 0.5) + 1.0;
    let m_max = ((1u32 << p) - 1) as f32;
    o.clamp(-m_max, m_max) * step
}

/// f32 round-half-to-even (the IEEE default; `f32::round` rounds half away
/// from zero, which would diverge from the Python/XLA oracle on ties).
#[inline]
pub fn round_half_even(x: f32) -> f32 {
    let r = x.round();
    if (x - x.trunc()).abs() == 0.5 {
        // tie: pick the even neighbour
        let lo = x.floor();
        let hi = x.ceil();
        if (lo as i64) % 2 == 0 {
            lo
        } else {
            hi
        }
    } else {
        r
    }
}

/// Round to the accumulator grid (identity for exact SMOL arithmetic).
#[inline]
pub fn fixed_point_round(x: f32) -> f32 {
    round_half_even(x * ACC_SCALE) / ACC_SCALE
}

/// The bits-per-value proxy `log2(1 + e^-s)` used by the regularizer.
#[inline]
pub fn soft_bits(s: f32) -> f32 {
    ((-s).exp().ln_1p()) / std::f32::consts::LN_2
}

/// `p = 1 + round(log2(1 + e^-s))` (Algorithm 1 line 9).
#[inline]
pub fn precision_from_s(s: f32) -> f32 {
    1.0 + soft_bits(s).round()
}

/// Snap a real precision to the closest of {1, 2, 4} (Algorithm 2 line 11).
#[inline]
pub fn snap_precision(p: f32) -> u8 {
    if p < 1.5 {
        1
    } else if p < 3.0 {
        2
    } else {
        4
    }
}

/// Noise scale `sigma(s) = sigmoid(s)` (the quantization half-step).
#[inline]
pub fn sigma(s: f32) -> f32 {
    1.0 / (1.0 + (-s).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_examples() {
        // 4-bit 1101 = 1.375; 2-bit 10 = 0.5; 1-bit {0,1} = {-1,+1}
        assert_eq!(code_to_value(0b1101, 4), 1.375);
        assert_eq!(code_to_value(0b10, 2), 0.5);
        assert_eq!(code_to_value(0, 1), -1.0);
        assert_eq!(code_to_value(1, 1), 1.0);
    }

    #[test]
    fn code_roundtrip_all() {
        for p in SUPPORTED_PRECISIONS {
            for u in 0..(1u32 << p) {
                let v = code_to_value(u, p);
                assert_eq!(value_to_code(v, p), u, "p={p} u={u}");
                // values are odd multiples of step
                let m = v / step_for(p);
                assert_eq!(m.fract(), 0.0);
                assert_eq!((m as i64) % 2 != 0, true);
                assert!(v.abs() <= qmax_for(p));
            }
        }
    }

    #[test]
    fn quantize_is_idempotent_and_in_range() {
        for p in SUPPORTED_PRECISIONS {
            for i in -100..=100 {
                let x = i as f32 * 0.037;
                let q = quantize(x, p);
                assert_eq!(quantize(q, p), q, "p={p} x={x}");
                assert!(q.abs() <= qmax_for(p));
                assert!(q.abs() >= step_for(p)); // no zero value
            }
        }
    }

    #[test]
    fn quantize_error_bounded_by_step() {
        for p in SUPPORTED_PRECISIONS {
            let qm = qmax_for(p);
            for i in -200..=200 {
                let x = i as f32 * 0.009;
                if x.abs() <= qm {
                    assert!((quantize(x, p) - x).abs() <= step_for(p) + 1e-6);
                }
            }
        }
    }

    #[test]
    fn s_to_precision_mapping() {
        // sigma(s_init(p)) = 2^(1-p)  =>  precision_from_s(s_init(p)) = p
        for p in [2u8, 3, 4, 6, 8] {
            let s_init = -((2.0f32.powi(p as i32 - 1) - 1.0).ln());
            assert_eq!(precision_from_s(s_init), p as f32, "p={p}");
        }
    }

    #[test]
    fn snap_boundaries() {
        assert_eq!(snap_precision(1.0), 1);
        assert_eq!(snap_precision(1.4), 1);
        assert_eq!(snap_precision(2.0), 2);
        assert_eq!(snap_precision(2.9), 2);
        assert_eq!(snap_precision(3.1), 4);
        assert_eq!(snap_precision(8.0), 4);
    }
}
