//! Huffman coding of per-element precision metadata.
//!
//! Reproduces the paper's Sec. III-A observation: for networks trained
//! with the *original* SMOL algorithm (arbitrary per-weight precisions up
//! to 8 levels), even Huffman-coded precision metadata inflates the
//! network substantially (+66.4% on a ResNet last layer) — the motivation
//! for the channel-shared, pattern-constrained scheme where three
//! integers per layer suffice.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// Build Huffman code lengths for a symbol frequency map.
pub fn code_lengths(freq: &HashMap<u8, u64>) -> HashMap<u8, u32> {
    let mut lengths: HashMap<u8, u32> = HashMap::new();
    if freq.is_empty() {
        return lengths;
    }
    if freq.len() == 1 {
        lengths.insert(*freq.keys().next().unwrap(), 1);
        return lengths;
    }
    // heap of (weight, node-id); nodes hold child lists of leaf symbols
    #[derive(PartialEq, Eq, PartialOrd, Ord)]
    struct Node(u64, usize);
    let mut heap: BinaryHeap<Reverse<Node>> = BinaryHeap::new();
    let mut members: Vec<Vec<u8>> = Vec::new();
    for (&sym, &f) in freq {
        members.push(vec![sym]);
        heap.push(Reverse(Node(f, members.len() - 1)));
        lengths.insert(sym, 0);
    }
    while heap.len() > 1 {
        let Reverse(Node(fa, a)) = heap.pop().unwrap();
        let Reverse(Node(fb, b)) = heap.pop().unwrap();
        let mut merged = members[a].clone();
        merged.extend(members[b].iter().copied());
        for &sym in &merged {
            *lengths.get_mut(&sym).unwrap() += 1;
        }
        members.push(merged);
        heap.push(Reverse(Node(fa + fb, members.len() - 1)));
    }
    lengths
}

/// Total encoded bits for a precision stream under its own Huffman code.
pub fn encoded_bits(precisions: &[u8]) -> u64 {
    let mut freq: HashMap<u8, u64> = HashMap::new();
    for &p in precisions {
        *freq.entry(p).or_insert(0) += 1;
    }
    let lengths = code_lengths(&freq);
    precisions.iter().map(|p| lengths[p] as u64).sum()
}

/// Metadata overhead analysis for one layer.
#[derive(Debug, Clone, Copy)]
pub struct MetadataCost {
    /// data bits (sum of per-element precisions)
    pub data_bits: u64,
    /// Huffman-coded per-element precision metadata bits (original SMOL)
    pub huffman_bits: u64,
    /// pattern-scheme metadata bits (3 x 32-bit integers per layer)
    pub pattern_bits: u64,
}

impl MetadataCost {
    /// Relative size increase from per-element Huffman metadata.
    pub fn huffman_overhead(&self) -> f64 {
        self.huffman_bits as f64 / self.data_bits as f64
    }

    pub fn pattern_overhead(&self) -> f64 {
        self.pattern_bits as f64 / self.data_bits as f64
    }
}

/// Compare metadata schemes for a per-element precision stream.
pub fn metadata_cost(precisions: &[u8]) -> MetadataCost {
    let data_bits: u64 = precisions.iter().map(|&p| p as u64).sum();
    MetadataCost {
        data_bits,
        huffman_bits: encoded_bits(precisions),
        pattern_bits: 3 * 32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_symbol() {
        assert_eq!(encoded_bits(&[4, 4, 4, 4]), 4);
    }

    #[test]
    fn kraft_inequality() {
        let mut freq = HashMap::new();
        for (s, f) in [(1u8, 50u64), (2, 30), (3, 12), (4, 5), (8, 3)] {
            freq.insert(s, f);
        }
        let lens = code_lengths(&freq);
        let kraft: f64 = lens.values().map(|&l| 2f64.powi(-(l as i32))).sum();
        assert!(kraft <= 1.0 + 1e-9, "kraft={kraft}");
    }

    #[test]
    fn optimality_on_skewed() {
        // heavily skewed stream: most frequent symbol must get length 1
        let mut stream = vec![1u8; 1000];
        stream.extend(vec![2u8; 10]);
        stream.extend(vec![4u8; 10]);
        let mut freq = HashMap::new();
        for &p in &stream {
            *freq.entry(p).or_insert(0u64) += 1;
        }
        let lens = code_lengths(&freq);
        assert_eq!(lens[&1], 1);
    }

    #[test]
    fn huffman_metadata_is_substantial_for_arbitrary_precisions() {
        // original-SMOL-like stream: 8 precision levels, low-bit heavy —
        // the paper reports +66.4% on a ResNet last layer; our synthetic
        // analogue lands in the same regime (> 40% overhead).
        let mut stream = Vec::new();
        let mut x = 123456789u64;
        for _ in 0..4608 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let r = (x % 100) as u8;
            stream.push(match r {
                0..=44 => 1,
                45..=74 => 2,
                75..=84 => 3,
                85..=91 => 4,
                92..=95 => 5,
                96..=97 => 6,
                98 => 7,
                _ => 8,
            });
        }
        let cost = metadata_cost(&stream);
        assert!(cost.huffman_overhead() > 0.40, "{}", cost.huffman_overhead());
        assert!(cost.pattern_overhead() < 0.01);
    }
}
