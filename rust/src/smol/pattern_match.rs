//! Algorithm 3: pattern matching between SASMOL phase I and phase II, and
//! the channel rearrangement of Observation 4.
//!
//! After phase I, each layer has one trained `s` value per input channel.
//! Channels are ranked by importance (lower `s` = higher importance), the
//! Problem-1 combination is solved for the layer's demand, and precisions
//! are (re)assigned so the channel set exactly fills the combination's
//! slots: the most important channels take the 4-bit slots, then 2-bit,
//! then 1-bit (`PatternMatch` in Algorithm 3 — realized here directly as
//! the precision assignment rather than as an `s`-tensor transform; the
//! phase-II step consumes per-channel (step, qmax) arrays derived from
//! it).

use crate::simd::patterns::Pattern;
use crate::smol::problem1::{self, Demand};
use crate::smol::quant;

/// The per-layer outcome of pattern matching.
#[derive(Debug, Clone)]
pub struct Assignment {
    /// chunk patterns in layout order (4-bit-heavy first)
    pub chunks: Vec<Pattern>,
    /// valid element count per chunk (last chunk may be partial)
    pub valid: Vec<u32>,
    /// per *original* channel index: assigned precision in {1,2,4}
    pub precision: Vec<u8>,
    /// rearranged order: `order[j]` = original channel index stored at
    /// packed position j (Observation 4 rearrangement)
    pub order: Vec<u32>,
}

impl Assignment {
    pub fn num_channels(&self) -> usize {
        self.precision.len()
    }

    /// Weight/activation bits per element for this layer.
    pub fn bits_per_element(&self) -> f64 {
        let total: u64 = self.precision.iter().map(|&p| p as u64).sum();
        total as f64 / self.precision.len() as f64
    }

    /// Per-channel (step, qmax) arrays for the phase-II / eval artifacts.
    pub fn step_qmax(&self) -> (Vec<f32>, Vec<f32>) {
        let step: Vec<f32> = self.precision.iter().map(|&p| quant::step_for(p)).collect();
        let qmax: Vec<f32> = self.precision.iter().map(|&p| quant::qmax_for(p)).collect();
        (step, qmax)
    }

    /// Restrict this assignment to the contiguous channel range
    /// `[start, end)` — the contraction-axis view a shard-scoped kernel
    /// sees when a wide producer's `cout` range is split across workers
    /// and a consumer contracts only its shard's slice.
    ///
    /// Per-channel *precisions* are preserved exactly (quantization is
    /// per channel, so any chunking over the sliced channels computes
    /// the identical fixed-point MACs); the sliced channels are
    /// re-chunked into uniform carrier patterns per precision class,
    /// 4-bit first — the same uniform-pattern execution the decode
    /// position axis already uses. Channel indices in the result are
    /// slice-local (`0..end-start`).
    pub fn slice(&self, start: usize, end: usize) -> Assignment {
        assert!(
            start < end && end <= self.num_channels(),
            "assignment slice [{start}, {end}) out of 0..{}",
            self.num_channels()
        );
        let precision: Vec<u8> = self.precision[start..end].to_vec();
        assert!(
            precision.iter().all(|&p| matches!(p, 1 | 2 | 4)),
            "sliceable assignments carry {{1, 2, 4}}-bit channels only"
        );
        let mut chunks = Vec::new();
        let mut valid = Vec::new();
        let mut order = Vec::new();
        for p in [4u8, 2, 1] {
            let class: Vec<u32> = precision
                .iter()
                .enumerate()
                .filter(|&(_, &q)| q == p)
                .map(|(i, _)| i as u32)
                .collect();
            if class.is_empty() {
                continue;
            }
            let pat = Pattern::uniform(p);
            let cap = pat.capacity() as usize;
            for chunk in class.chunks(cap) {
                chunks.push(pat);
                valid.push(chunk.len() as u32);
                order.extend_from_slice(chunk);
            }
        }
        Assignment { chunks, valid, precision, order }
    }

    /// Uniform assignment (U2/U4/INT8-style design points): every channel
    /// at precision `p`, chunked into uniform patterns.
    pub fn uniform(channels: usize, p: u8) -> Assignment {
        let pat = Pattern::uniform(p);
        let cap = pat.capacity() as usize;
        let n_chunks = channels.div_ceil(cap);
        let mut valid = vec![cap as u32; n_chunks];
        if channels % cap != 0 {
            *valid.last_mut().unwrap() = (channels % cap) as u32;
        }
        Assignment {
            chunks: vec![pat; n_chunks],
            valid,
            precision: vec![p; channels],
            order: (0..channels as u32).collect(),
        }
    }
}

/// Demand from trained per-channel `s` values (snap to {1,2,4}).
pub fn demand_from_s(s: &[f32]) -> Demand {
    let prec: Vec<u8> = s
        .iter()
        .map(|&v| quant::snap_precision(quant::precision_from_s(v)))
        .collect();
    Demand::from_precisions(&prec)
}

/// Run Problem 1 + PatternMatch for one layer.
///
/// `s`: trained per-channel sensitivity parameters (phase I output).
/// `supported`: the hardware design point's pattern subset.
pub fn pattern_match(s: &[f32], supported: &[Pattern]) -> Assignment {
    let channels = s.len();
    let demand = demand_from_s(s);
    let comb = problem1::solve(&demand, supported).expect("non-empty pattern set");

    // Rank channels by importance: ascending s (lower s = higher
    // precision demanded = more important).
    let mut rank: Vec<u32> = (0..channels as u32).collect();
    rank.sort_by(|&a, &b| {
        s[a as usize]
            .partial_cmp(&s[b as usize])
            .unwrap()
            .then(a.cmp(&b))
    });

    // Slot budget from the combination; the most important channels take
    // the 4-bit slots, then 2-bit, then 1-bit. Unfilled slots (capacity
    // overshoot) are dropped from the *lowest*-precision end.
    let (s4, s2) = (comb.slots(4) as usize, comb.slots(2) as usize);
    let mut precision = vec![0u8; channels];
    for (i, &ch) in rank.iter().enumerate() {
        precision[ch as usize] = if i < s4 {
            4
        } else if i < s4 + s2 {
            2
        } else {
            1
        };
    }

    // Layout: walk chunks, pull channels from the per-precision pools in
    // rank order. Track how many elements of the final chunk are valid.
    let mut pools: [std::collections::VecDeque<u32>; 3] = Default::default();
    for &ch in &rank {
        let p = precision[ch as usize];
        let pool = match p {
            4 => 0,
            2 => 1,
            _ => 2,
        };
        pools[pool].push_back(ch);
    }
    let mut order = Vec::with_capacity(channels);
    let mut valid = Vec::with_capacity(comb.chunks.len());
    for pat in &comb.chunks {
        let mut v = 0u32;
        for (pool, want) in [(0usize, pat.n4), (1, pat.n2), (2, pat.n1)] {
            for _ in 0..want {
                if let Some(ch) = pools[pool].pop_front() {
                    order.push(ch);
                    v += 1;
                }
            }
        }
        valid.push(v);
    }
    debug_assert_eq!(order.len(), channels, "all channels must be placed");

    Assignment { chunks: comb.chunks, valid, precision, order }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::patterns::{all_patterns, design_subset};

    fn s_for(p: u8) -> f32 {
        match p {
            1 => 20.0,
            2 => 0.0,
            4 => -((2.0f32.powi(3) - 1.0).ln()),
            _ => unreachable!(),
        }
    }

    #[test]
    fn uniform_s_gives_uniform_assignment() {
        let s = vec![s_for(4); 64];
        let a = pattern_match(&s, &all_patterns());
        assert!(a.precision.iter().all(|&p| p == 4));
        assert_eq!(a.chunks.len(), 2);
        assert_eq!(a.order.len(), 64);
    }

    #[test]
    fn important_channels_get_more_bits() {
        // 8 important channels (low s), 120 unimportant
        let mut s = vec![s_for(1); 128];
        for i in 0..8 {
            s[i] = s_for(4);
        }
        let a = pattern_match(&s, &all_patterns());
        for i in 0..8 {
            assert!(a.precision[i] >= a.precision[64], "ch{i}");
        }
        // coverage: total valid slots == channels
        let total_valid: u32 = a.valid.iter().sum();
        assert_eq!(total_valid, 128);
    }

    #[test]
    fn order_is_a_permutation() {
        let s: Vec<f32> = (0..100).map(|i| (i as f32) * 0.1 - 5.0).collect();
        for np in [4, 8, 45] {
            let a = pattern_match(&s, &design_subset(np));
            let mut seen = vec![false; 100];
            for &ch in &a.order {
                assert!(!seen[ch as usize]);
                seen[ch as usize] = true;
            }
            assert!(seen.iter().all(|&b| b));
        }
    }

    #[test]
    fn promotion_never_demotes() {
        // All channels demand 4 bits: with only uniform-1 patterns
        // supported... impossible to honor; but with P4 subset the
        // combination must supply >= N4 4-bit slots, so everyone stays 4.
        let s = vec![s_for(4); 48];
        let a = pattern_match(&s, &design_subset(4));
        assert!(a.precision.iter().all(|&p| p == 4));
    }

    #[test]
    fn slice_preserves_precisions_and_covers_channels() {
        let s: Vec<f32> = (0..96).map(|i| (i as f32) * 0.2 - 8.0).collect();
        let full = pattern_match(&s, &design_subset(8));
        for (start, end) in [(0usize, 48usize), (48, 96), (10, 70), (95, 96)] {
            let a = full.slice(start, end);
            assert_eq!(a.num_channels(), end - start);
            // per-channel precisions survive verbatim
            for i in 0..end - start {
                assert_eq!(a.precision[i], full.precision[start + i], "ch {i}");
            }
            // order is a permutation of the slice-local channels
            let mut seen = vec![false; end - start];
            for &ch in &a.order {
                assert!(!seen[ch as usize]);
                seen[ch as usize] = true;
            }
            assert!(seen.iter().all(|&b| b));
            // chunk slots agree with the assigned precisions
            let mut pos = 0usize;
            for (ci, pat) in a.chunks.iter().enumerate() {
                for e in 0..a.valid[ci] {
                    let ch = a.order[pos] as usize;
                    assert_eq!(a.precision[ch], pat.element_precision(e));
                    pos += 1;
                }
            }
            assert_eq!(pos, end - start);
        }
    }

    #[test]
    fn layout_matches_chunk_shapes() {
        let mut s = vec![s_for(2); 60];
        for i in 0..10 {
            s[i] = s_for(4);
        }
        for i in 50..60 {
            s[i] = s_for(1);
        }
        let a = pattern_match(&s, &all_patterns());
        // walking the layout, precisions are consistent with chunk slots
        let mut pos = 0usize;
        for (ci, pat) in a.chunks.iter().enumerate() {
            for e in 0..a.valid[ci] {
                let ch = a.order[pos] as usize;
                assert_eq!(a.precision[ch], pat.element_precision(e), "chunk {ci} elem {e}");
                pos += 1;
            }
        }
    }
}
