//! Fig. 8 run-time bench: simulated cycles per inference for each
//! {network, design point} on the paper-scale shape tables (full-width
//! networks, where the vectorization effects bite), normalized to U4 —
//! plus the simulator's own wall-clock throughput.

use soniq::coordinator::{paperscale, simulate_paper_scale, DesignPoint};
use soniq::util::bench::section;
use std::time::Instant;

fn main() {
    let designs = [
        DesignPoint::Fp32,
        DesignPoint::Int8,
        DesignPoint::Uniform(4),
        DesignPoint::Uniform(2),
        DesignPoint::Patterns(4),
        DesignPoint::Patterns(8),
        DesignPoint::Patterns(45),
    ];
    // representative trained fractions (later layers lower-precision, as
    // in Fig. 9): front third mostly 4-bit, back third mostly 1-bit.
    for model in ["resnet18", "mobilenetv2", "shufflenetv2"] {
        section(&format!("Fig. 8 run-time — {model} (paper-scale shapes)"));
        let shapes = paperscale::shapes_for(model);
        let n = shapes.len();
        let fractions: Vec<(String, f64, f64)> = shapes
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let t = i as f64 / n as f64;
                // f4 decays with depth, f1 grows (Fig. 9 profile)
                let f4 = (0.9 - 0.8 * t).max(0.05);
                let f2 = 0.3;
                (s.name.clone(), f4, f2)
            })
            .collect();
        let mut results = Vec::new();
        for dp in designs {
            let t0 = Instant::now();
            let (total, _) = simulate_paper_scale(model, dp, &fractions);
            let wall = t0.elapsed();
            results.push((dp.label(), total.cycles(), total.energy_pj, total.instrs, wall));
        }
        let u4 = results.iter().find(|r| r.0 == "U4").map(|r| r.1).unwrap();
        println!(
            "{:<6} {:>14} {:>9} {:>12} {:>12} {:>10}",
            "design", "cycles", "speedup", "energy(uJ)", "sim instrs", "sim wall"
        );
        for (label, cycles, energy, instrs, wall) in &results {
            println!(
                "{:<6} {:>14} {:>9.2} {:>12.1} {:>12} {:>9.2?}",
                label,
                cycles,
                u4 as f64 / *cycles as f64,
                energy / 1e6,
                instrs,
                wall
            );
        }
    }
}
