//! Serving bench: how much of an inference the prepared-model engine
//! amortizes away (weight packing, codegen, buffer allocation), and how
//! end-to-end server throughput scales with workers — the host-side
//! counterpart of the Fig. 8 simulated-cycle results.

use soniq::coordinator::{
    synthetic_inputs, synthetic_network, synthetic_network_seq, synthetic_step_inputs,
    DesignPoint,
};
use soniq::serve::{
    serve_all, BatchConfig, DeployConfig, Deployment, EngineMachine, ModelKey, PreparedModel,
    ServeConfig, Server,
};
use soniq::sim::network::{run_network, Tensor};
use soniq::util::bench::{bench, section};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    for (model, dp) in [
        ("tinynet", DesignPoint::Patterns(4)),
        ("tinydw", DesignPoint::Uniform(2)),
        // Transformer encoder: static projections amortize like convs;
        // QK^T / A·V re-pack their dynamic operand every request, so the
        // amortization gap narrows — that delta is what this row shows
        ("tinyattn", DesignPoint::Patterns(4)),
    ] {
        let net = synthetic_network(model, dp, 7).expect("synthetic net");
        let inputs = synthetic_inputs(&net, 64, 11);

        section(&format!("prepared-model amortization — {model} / {}", dp.label()));
        let legacy = bench("legacy run_network (pack + codegen every call)", || {
            run_network(&net.nodes, &inputs[0]).output.data[0]
        });
        let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
        // what `serve-bench --verify` costs at serve time, split by
        // analysis depth: the safety interpreter alone vs safety plus
        // the term-equivalence pass (what --verify actually runs)
        let t_safety = Instant::now();
        let safety = soniq::analysis::verify_model_level(
            model,
            &prepared,
            soniq::analysis::VerifyLevel::Safety,
        );
        let safety_elapsed = t_safety.elapsed();
        assert!(safety.is_clean());
        let t_full = Instant::now();
        let verdict = soniq::analysis::verify_model(model, &prepared);
        let full_elapsed = t_full.elapsed();
        assert!(verdict.is_clean());
        println!(
            "static verify (safety only):   {} kernels / {} instrs clean in {:.2?}",
            safety.kernels.len(),
            safety.instrs(),
            safety_elapsed,
        );
        println!(
            "static verify (safety+equiv):  {} kernels / {} instrs clean in {:.2?} \
             (max acc bound {})",
            verdict.kernels.len(),
            verdict.instrs(),
            full_elapsed,
            verdict.max_acc_bound()
        );
        let mut engine = EngineMachine::new(&prepared);
        let amortized = bench("prepared engine.run (pack once, replay kernel)", || {
            engine.run(&inputs[0]).output.data[0]
        });
        println!("amortization speedup: {:.2}x", legacy.mean_ns / amortized.mean_ns);

        section(&format!("server throughput scaling — {model} / {}", dp.label()));
        for workers in [1usize, 2, 4] {
            let cfg = ServeConfig {
                workers,
                batch: BatchConfig { max_batch: 16, max_delay: Duration::from_millis(1) },
                ..ServeConfig::default()
            };
            let t0 = Instant::now();
            let done = serve_all(&prepared, &cfg, inputs.clone());
            let wall = t0.elapsed();
            println!(
                "  {workers} worker(s): {} requests in {wall:.2?} -> {:.1} req/s",
                done.len(),
                done.len() as f64 / wall.as_secs_f64().max(1e-9)
            );
        }
    }

    // Multi-model serving: two models' mixed traffic through ONE pool
    // vs one dedicated pool per model run back to back — the pooled
    // form shares workers (and pays per-batch bind-table switches), the
    // dedicated form pays a second fleet. Also shown: the same mixed
    // traffic under a 1-model resident budget, i.e. worst-case LRU
    // eviction churn (rebind on every model switch).
    {
        let dp = DesignPoint::Patterns(4);
        section("multi-model pool — tinynet + tinyattn mixed traffic");
        let keys_nets: Vec<_> = ["tinynet", "tinyattn"]
            .iter()
            .map(|name| {
                let net = synthetic_network(name, dp, 7).expect("synthetic net");
                let inputs = synthetic_inputs(&net, 32, 11);
                let key = ModelKey::new(*name, dp.label());
                let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
                (key, prepared, inputs)
            })
            .collect();

        let t0 = Instant::now();
        for (key, prepared, inputs) in &keys_nets {
            let cfg = ServeConfig {
                workers: 4,
                batch: BatchConfig { max_batch: 16, max_delay: Duration::from_millis(1) },
                ..ServeConfig::default()
            };
            let mut server = Server::start_named(key.clone(), Arc::clone(prepared), &cfg);
            for x in inputs {
                server.submit(x.clone());
            }
            let done = server.shutdown();
            assert_eq!(done.len(), inputs.len());
        }
        let dedicated_wall = t0.elapsed();
        println!("  dedicated pools (4 workers each, sequential): {dedicated_wall:.2?}");

        for budget in [usize::MAX, 1usize] {
            let cfg = ServeConfig {
                workers: 4,
                batch: BatchConfig { max_batch: 16, max_delay: Duration::from_millis(1) },
                resident_models: budget,
                ..ServeConfig::default()
            };
            let t1 = Instant::now();
            let mut server = Server::start_pool(&cfg);
            for (key, prepared, _) in &keys_nets {
                server.register(key.clone(), Arc::clone(prepared));
            }
            for i in 0..32 {
                for (key, _, inputs) in &keys_nets {
                    server.submit_model(key, inputs[i].clone());
                }
            }
            let done = server.shutdown();
            assert_eq!(done.len(), 64);
            let wall = t1.elapsed();
            let label =
                if budget == usize::MAX { "both resident" } else { "budget 1 (evict churn)" };
            println!(
                "  one pool, interleaved, {label}: {wall:.2?} -> {:.1} req/s",
                64.0 / wall.as_secs_f64().max(1e-9)
            );
        }
    }

    // Sharded deployment: tinywide's wide layer split across workers vs
    // the whole model on one worker — scatter/gather overhead against
    // the placement headroom sharding buys (and the only way to serve
    // at all once a worker buffer budget is smaller than the model)
    {
        let dp = DesignPoint::Patterns(4);
        section("shard-aware placement — tinywide wide-layer split");
        let net = synthetic_network("tinywide", dp, 7).expect("tinywide");
        let inputs = synthetic_inputs(&net, 64, 11);
        let key = ModelKey::new("tinywide", dp.label());
        for shards in [1usize, 2, 4] {
            let dcfg = DeployConfig {
                worker_budget: None,
                shards: (shards >= 2).then_some(shards),
            };
            let dep = Arc::new(
                Deployment::build(key.clone(), &net.nodes, None, &dcfg).expect("plan"),
            );
            let cfg = ServeConfig {
                workers: 4,
                batch: BatchConfig { max_batch: 16, max_delay: Duration::from_millis(1) },
                ..ServeConfig::default()
            };
            let t0 = Instant::now();
            let mut server = Server::start_deployment(Arc::clone(&dep), &cfg);
            for x in inputs.iter().cloned() {
                server.submit(x);
            }
            let done = server.shutdown();
            assert_eq!(done.len(), inputs.len());
            let wall = t0.elapsed();
            println!(
                "  {} shard(s) over 4 workers: {} requests in {wall:.2?} -> {:.1} req/s",
                dep.num_shards(),
                done.len(),
                done.len() as f64 / wall.as_secs_f64().max(1e-9)
            );
        }
    }

    // KV-cached autoregressive decode: one session stepping N tokens vs
    // re-running the growing prefix through the one-shot causal graph
    // on every step (what serving without a KV cache would have to do)
    let dp = DesignPoint::Patterns(4);
    section(&format!("KV-cached decode — tinydec / {}", dp.label()));
    let dec = synthetic_network("tinydec", dp, 7).expect("tinydec");
    let prepared = Arc::new(PreparedModel::prepare_decoder(
        &dec.nodes,
        dec.step_nodes.as_ref().expect("decoder step graph"),
    ));
    let steps = 16usize;
    let tokens = synthetic_step_inputs(&dec, 0, steps, 11);
    let mut engine = EngineMachine::new(&prepared);
    let mut sid = 0u64;
    let cached = bench("cached decode (16 steps, append-packed K/V)", || {
        // fresh session per iteration; recycle the machine occasionally
        // so resident session caches stay bounded
        if sid % 256 == 0 {
            engine = EngineMachine::new(&prepared);
        }
        let s = sid;
        sid += 1;
        let mut last = 0.0f32;
        for tok in &tokens {
            last = engine.run_step(s, tok).output.data[0];
        }
        last
    });
    // prebuild the per-length graphs and prefix tensors so the baseline
    // times only what a cache-less server would actually repeat per
    // step: prepare (codegen + repack) + run over the whole prefix
    let baseline_runs: Vec<_> = (0..steps)
        .map(|t| {
            let net_t = synthetic_network_seq("tinydec", dp, 7, Some(t + 1)).expect("tinydec");
            let (h, w, c) = net_t.input_shape;
            let mut data = Vec::with_capacity(w * c);
            for tok in tokens.iter().take(t + 1) {
                data.extend_from_slice(&tok.data);
            }
            (net_t, Tensor { h, w, c, data })
        })
        .collect();
    let baseline = bench("prefix re-run (one-shot causal graph per step)", || {
        let mut last = 0.0f32;
        for (net_t, input) in &baseline_runs {
            last = run_network(&net_t.nodes, input).output.data[0];
        }
        last
    });
    println!("decode speedup (host wall): {:.2}x", baseline.mean_ns / cached.mean_ns);

    // Paged KV pool vs legacy growable session storage: 1000
    // mixed-length sessions decode through one engine in overlapping
    // waves. Growable storage keeps every open session's exact K/V
    // bytes resident; the paged pool allocates fixed-size pages and,
    // under a budget, recycles a constant page set through spill round
    // trips — same simulated cycles (identical staged bytes), bounded
    // peak residency.
    {
        use soniq::serve::{KvPolicy, KvPoolCfg};
        section("paged KV pool vs growable sessions — tinydec, 1000 mixed-length sessions");
        let n_sessions = 1000usize;
        let wave = 50usize;
        let max_len = 16usize;
        let lens: Vec<usize> = (0..n_sessions).map(|i| 1 + (i * 7 + 3) % max_len).collect();
        let step_tokens = synthetic_step_inputs(&dec, 1, max_len, 11);
        let run = |label: &str, kv: Option<KvPoolCfg>| {
            let mut engine = EngineMachine::new(&prepared);
            if let Some(kv) = kv {
                engine.set_kv_pool(kv);
            }
            let t0 = Instant::now();
            let (mut cycles, mut peak) = (0u64, 0usize);
            for w in (0..n_sessions).step_by(wave) {
                let ids: Vec<usize> = (w..(w + wave).min(n_sessions)).collect();
                for (t, tok) in step_tokens.iter().enumerate() {
                    for &si in &ids {
                        if t < lens[si] {
                            cycles += engine.run_step(si as u64, tok).total.cycles();
                        }
                    }
                    peak = peak.max(engine.session_kv_bytes());
                }
                for &si in &ids {
                    engine.end_session(si as u64);
                }
            }
            let wall = t0.elapsed();
            println!("  {label}: {cycles} simulated cycles, peak resident KV {peak} B, {wall:.2?}");
            (cycles, peak)
        };
        let (lc, lp) = run("growable (legacy)", None);
        run(
            "paged, unbounded (exact accounting)",
            Some(KvPoolCfg { page_positions: 4, ..KvPoolCfg::default() }),
        );
        let (pc, pp) = run(
            "paged, 8-page budget (spill round trips)",
            Some(KvPoolCfg {
                page_positions: 4,
                pages_per_worker: Some(8),
                policy: KvPolicy::Spill,
                v_bits: None,
            }),
        );
        println!(
            "  cycles paged/legacy: {:.3}x; peak resident KV paged/legacy: {:.2}x",
            pc as f64 / lc.max(1) as f64,
            pp as f64 / lp.max(1) as f64
        );
    }
}
