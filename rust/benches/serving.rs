//! Serving bench: how much of an inference the prepared-model engine
//! amortizes away (weight packing, codegen, buffer allocation), and how
//! end-to-end server throughput scales with workers — the host-side
//! counterpart of the Fig. 8 simulated-cycle results.

use soniq::coordinator::{synthetic_inputs, synthetic_network, DesignPoint};
use soniq::serve::{serve_all, BatchConfig, EngineMachine, PreparedModel, ServeConfig};
use soniq::sim::network::run_network;
use soniq::util::bench::{bench, section};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    for (model, dp) in [
        ("tinynet", DesignPoint::Patterns(4)),
        ("tinydw", DesignPoint::Uniform(2)),
        // Transformer encoder: static projections amortize like convs;
        // QK^T / A·V re-pack their dynamic operand every request, so the
        // amortization gap narrows — that delta is what this row shows
        ("tinyattn", DesignPoint::Patterns(4)),
    ] {
        let net = synthetic_network(model, dp, 7).expect("synthetic net");
        let inputs = synthetic_inputs(&net, 64, 11);

        section(&format!("prepared-model amortization — {model} / {}", dp.label()));
        let legacy = bench("legacy run_network (pack + codegen every call)", || {
            run_network(&net.nodes, &inputs[0]).output.data[0]
        });
        let prepared = Arc::new(PreparedModel::prepare(&net.nodes));
        let mut engine = EngineMachine::new(&prepared);
        let amortized = bench("prepared engine.run (pack once, replay kernel)", || {
            engine.run(&inputs[0]).output.data[0]
        });
        println!("amortization speedup: {:.2}x", legacy.mean_ns / amortized.mean_ns);

        section(&format!("server throughput scaling — {model} / {}", dp.label()));
        for workers in [1usize, 2, 4] {
            let cfg = ServeConfig {
                workers,
                batch: BatchConfig { max_batch: 16, max_delay: Duration::from_millis(1) },
            };
            let t0 = Instant::now();
            let done = serve_all(&prepared, &cfg, inputs.clone());
            let wall = t0.elapsed();
            println!(
                "  {workers} worker(s): {} requests in {wall:.2?} -> {:.1} req/s",
                done.len(),
                done.len() as f64 / wall.as_secs_f64().max(1e-9)
            );
        }
    }
}
