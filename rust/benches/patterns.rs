//! Table II / Table III / Problem-1 benches: pattern enumeration, the
//! combination solver across design points, and PatternMatch end to end.

use soniq::simd::patterns::{all_patterns, design_subset, index_of, Pattern};
use soniq::smol::pattern_match::pattern_match;
use soniq::smol::problem1::{solve, Demand};
use soniq::util::bench::{bench, section};
use soniq::util::rng::Rng;

fn main() {
    section("Table II — pattern enumeration");
    bench("all_patterns (45 entries)", all_patterns);
    let pats = all_patterns();
    println!(
        "    {} patterns; uniform indices: U4={:?} U2={:?} U1={:?}",
        pats.len(),
        index_of(&Pattern::uniform(4)),
        index_of(&Pattern::uniform(2)),
        index_of(&Pattern::uniform(1))
    );

    section("Problem 1 — combination solver (per layer)");
    let demands = [
        ("small  (C=64)", Demand { n1: 20, n2: 24, n4: 20 }),
        ("medium (C=256)", Demand { n1: 120, n2: 80, n4: 56 }),
        ("large  (C=512)", Demand { n1: 300, n2: 128, n4: 84 }),
    ];
    for np in [4usize, 8, 45] {
        let sub = design_subset(np);
        for (name, d) in &demands {
            bench(&format!("solve P{np} {name}"), || solve(d, &sub).unwrap().num_vectors());
        }
    }
    println!(
        "\nTable III subsets: P4 {:?}  P8 {:?}",
        design_subset(4).iter().map(|p| index_of(p).unwrap()).collect::<Vec<_>>(),
        design_subset(8).iter().map(|p| index_of(p).unwrap()).collect::<Vec<_>>()
    );

    section("PatternMatch (Algorithm 3) end to end");
    let mut rng = Rng::new(5);
    for c in [64usize, 256, 512] {
        let s: Vec<f32> = (0..c).map(|_| rng.range(-4.0, 8.0)).collect();
        bench(&format!("pattern_match C={c}, P45"), || pattern_match(&s, &all_patterns()));
    }
}
