//! Table V / Sec. V-B bench: prints the hardware cost + timing report and
//! times the structural models.

use soniq::hw::{gates, timing};
use soniq::util::bench::{bench, section};

fn main() {
    section("Table V — NAND2-equivalent gate counts");
    let lane = gates::lane_gates();
    println!("  module breakdown (per 16-bit lane):");
    println!("    1-bit unit        {:>8.0}", lane.one_bit_unit);
    println!("    2-bit unit        {:>8.0}", lane.two_bit_unit);
    println!("    4-bit Booth path  {:>8.0}", lane.four_bit_booth);
    println!("    shared 4:2 tree   {:>8.0}", lane.shared_compressor);
    println!("    12-bit CPA        {:>8.0}", lane.cpa);
    println!("    align muxes       {:>8.0}", lane.align_muxes);
    println!("    staging/output    {:>8.0}", lane.staging_and_output);
    println!("    per-lane total    {:>8.0}  (paper: 2805)", lane.total());
    println!("    8-lane ALU        {:>8.0}  (paper: 22440)", 8.0 * lane.total());
    for np in [4usize, 8, 16, 45] {
        println!("    control block P{np:<2} {:>8.0}", gates::control_block_gates(np));
    }
    println!(
        "    overhead vs 300M-gate vector core (P45): {:.6}%",
        100.0 * gates::overhead_fraction(45, 300.0e6)
    );

    section("Sec. V-B — critical path @ 2 GHz");
    for s in timing::CRITICAL_PATH {
        println!("    {:<12} {:>6.1} ps", s.name, s.delay_ps);
    }
    println!(
        "    total {:.1} ps, slack {:.1} ps, meets 2 GHz: {}",
        timing::critical_path_ps(),
        timing::slack_ps(2.0),
        timing::meets_timing(2.0, 0.05)
    );

    section("model evaluation throughput");
    bench("lane_gates()", gates::lane_gates);
    bench("critical_path_ps()", timing::critical_path_ps);
}
