//! Key Finding 1 bench: U4 run-time/energy vs FP32 (paper: ~8x) and vs
//! INT8 (paper: ~2x) on MAC-bound, channel-rich layers; plus the
//! U2-vs-U4 and mixed-precision deltas (Key Findings 2-3 mechanisms).

use soniq::codegen::{DataFormat, LayerKind, LayerPlan};
use soniq::sim::machine::Machine;
use soniq::sim::network::{run_conv, ConvLayerCfg, Tensor};
use soniq::smol::pattern_match::Assignment;
use soniq::util::bench::section;
use soniq::util::rng::Rng;

fn time_layer(cin: usize, cout: usize, hw: usize, fmt: DataFormat, asg: Assignment) -> (u64, f64) {
    let mut rng = Rng::new(3);
    let cfg = ConvLayerCfg {
        plan: LayerPlan {
            name: "kf".into(),
            kind: LayerKind::Dense,
            cin,
            cout,
            kh: 3,
            kw: 3,
            stride: 1,
            hin: hw,
            win: hw,
            asg,
            fmt,
        },
        weights: (0..9 * cin * cout).map(|_| rng.range(-1.0, 1.0)).collect(),
        bn_scale: vec![],
        bn_bias: vec![],
        bn_mean: vec![],
        bn_var: vec![],
        relu: false,
    };
    let x = Tensor {
        h: hw,
        w: hw,
        c: cin,
        data: (0..hw * hw * cin).map(|_| rng.range(-2.0, 2.0)).collect(),
    };
    let mut m = Machine::new();
    let (_, stats) = run_conv(&mut m, &cfg, &x);
    (stats.cycles(), stats.energy_pj)
}

fn main() {
    section("Key Finding 1 — U4 vs FP32 / INT8 (channel-rich conv3x3)");
    println!(
        "{:<28} {:>12} {:>12} {:>10} {:>10}",
        "layer", "design", "cycles", "vs FP32", "energy x"
    );
    for (cin, cout, hw) in [(128usize, 64usize, 14usize), (256, 128, 8), (512, 256, 4)] {
        let (fp_c, fp_e) = time_layer(cin, cout, hw, DataFormat::Fp32, Assignment::uniform(cin, 4));
        for (label, fmt, bits) in [
            ("FP32", DataFormat::Fp32, 4u8),
            ("INT8", DataFormat::Int8, 4),
            ("U4", DataFormat::Smol, 4),
            ("U2", DataFormat::Smol, 2),
        ] {
            let (c, e) = time_layer(cin, cout, hw, fmt, Assignment::uniform(cin, bits));
            println!(
                "{:<28} {:>12} {:>12} {:>10.2} {:>10.2}",
                format!("{cin}x{cout} @{hw}x{hw}"),
                label,
                c,
                fp_c as f64 / c as f64,
                fp_e / e
            );
        }
    }
    println!("\npaper: U4 ~8x FP32 run-time/energy, ~2x INT8 (Key Finding 1);");
    println!("U2 up to ~2x U4 (Fig. 8); both ratios should match in shape above.");
}
