//! Micro-benchmarks of the L3 hot path: the configurable ALU (vmac/vmul
//! per pattern class), SMOL packing/quantization, and raw simulator
//! instruction throughput. These are the paths the Fig. 8 simulations
//! spend their time in — see EXPERIMENTS.md §Perf for the target numbers.

use soniq::sim::machine::Machine;
use soniq::simd::alu;
use soniq::simd::isa::{Addr, Instr};
use soniq::simd::patterns::Pattern;
use soniq::simd::vector::{pack_values, V128};
use soniq::smol::quant;
use soniq::util::bench::{bench, section};
use soniq::util::rng::Rng;

fn rand_packed(rng: &mut Rng, pat: &Pattern) -> V128 {
    let vals: Vec<f32> = (0..pat.capacity())
        .map(|i| {
            let p = pat.element_precision(i);
            quant::code_to_value(rng.below(1 << p) as u32, p)
        })
        .collect();
    pack_values(pat, &vals)
}

fn main() {
    let mut rng = Rng::new(1);

    section("configurable ALU — vmac by pattern class");
    for (name, pat) in [
        ("vmac uniform-4b (32 MACs)", Pattern::uniform(4)),
        ("vmac uniform-2b (64 MACs)", Pattern::uniform(2)),
        ("vmac uniform-1b (128 MACs)", Pattern::uniform(1)),
        ("vmac mixed (16,24,16)", Pattern::new(16, 24, 16)),
    ] {
        let a = rand_packed(&mut rng, &pat);
        let b = rand_packed(&mut rng, &pat);
        let r = bench(name, || alu::reduce_acc(&alu::vmac(&a, &b, &pat)));
        println!(
            "    -> {:.1} M MAC-ops/s",
            r.throughput(pat.capacity() as f64) / 1e6
        );
    }

    section("configurable ALU — vmul (two-cycle product path)");
    for p in [4u8, 2, 1] {
        let pat = Pattern::uniform(p);
        let a = rand_packed(&mut rng, &pat);
        let b = rand_packed(&mut rng, &pat);
        bench(&format!("vmul uniform-{p}b"), || alu::vmul(&a, &b, &pat));
    }

    section("SMOL packing / quantization");
    let vals: Vec<f32> = (0..128).map(|_| rng.range(-2.0, 2.0)).collect();
    let pat = Pattern::uniform(1);
    bench("pack_values 128 x 1-bit", || pack_values(&pat, &vals));
    bench("quantize scalar x 128", || {
        vals.iter().map(|&v| quant::quantize(v, 4)).sum::<f32>()
    });

    section("simulator instruction throughput");
    let mut m = Machine::new();
    m.patterns.push(Pattern::uniform(4));
    let abuf = m.alloc(1 << 14);
    let prog: Vec<Instr> = (0..1024)
        .flat_map(|i| {
            [
                Instr::LdQ { dst: 0, addr: Addr { buf: abuf, off: (i * 16) % 16384 } },
                Instr::LdQ { dst: 1, addr: Addr { buf: abuf, off: (i * 32) % 16384 } },
                Instr::VmacP { dst: 2, a: 0, b: 1, pat: 0 },
                Instr::Vaddq16 { dst: 3, a: 3, b: 2 },
            ]
        })
        .collect();
    let r = bench("machine.run 4096-instr MAC loop", || {
        m.run(&prog);
        m.take_stats().instrs
    });
    println!(
        "    -> {:.1} M simulated instrs/s",
        r.throughput(prog.len() as f64) / 1e6
    );
}
